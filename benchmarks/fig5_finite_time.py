"""Fig. 5: MSE of the asymptotic methods at the moment finite-time consensus
(Sundaram-Hadjicostis linear observer) has enough information for EXACT
recovery — i.e. after deg(minpoly(W)) - 1 iterations.

Paper claims reproduced: on RGGs the proposed method is at machine precision
by that horizon; on the chain the observer's horizon is much more favourable
(N-1 iterations vs the chain's slow mixing).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import baselines, simulator

from .common import accel_params, emit, inits, paper_setup


def run(sizes=(50, 100, 150), trials=5, seed=0):
    rng = np.random.default_rng(seed)
    rows = []
    for topo in ("rgg", "chain"):
        for n in sizes:
            mse = {"MH": [], "MH-Proposed": [], "MH-PolyFilt3": []}
            horizons = []
            for _ in range(trials if topo == "rgg" else 1):
                g, w = paper_setup(topo, n, rng)
                th, lam2, a_star = accel_params(w)
                horizon = baselines.finite_time_iterations(w)
                horizons.append(horizon)
                x0 = inits(g, "slope", 1, rng)
                mse["MH"].append(float(simulator.simulate(w, x0, horizon).mse[-1, 0]))
                mse["MH-Proposed"].append(float(
                    simulator.simulate(w, x0, horizon, alpha=a_star, theta=th).mse[-1, 0]
                ))
                pf3 = baselines.design_poly_filter(w, 3, ridge=1e-12)
                _, traj = baselines.run_poly_filter(w, pf3, x0[:, 0], horizon, record=True)
                d = traj[-1] - x0[:, 0].mean()
                mse["MH-PolyFilt3"].append(float((d * d).mean()))
            rows.append({
                "topology": topo, "n": n,
                "observer_horizon": float(np.mean(horizons)),
                "mse_MH": float(np.mean(mse["MH"])),
                "mse_proposed": float(np.mean(mse["MH-Proposed"])),
                "mse_polyfilt3": float(np.mean(mse["MH-PolyFilt3"])),
                "mse_finite_time": 0.0,  # exact by construction (oracle)
            })
            print(f"fig5[{topo} n={n}]: horizon={rows[-1]['observer_horizon']:.0f} "
                  f"proposed={rows[-1]['mse_proposed']:.3g} MH={rows[-1]['mse_MH']:.3g}")
    emit("fig5_finite_time", rows)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=5)
    a = ap.parse_args()
    run(trials=a.trials)


if __name__ == "__main__":
    main()
