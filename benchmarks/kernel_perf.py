"""Kernel micro-bench: wall time of the Pallas ops (interpret mode on CPU —
a correctness-path timing, NOT a TPU perf claim; TPU numbers come from the
roofline analysis) plus the simulator backend comparison at paper scale.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accel, simulator, topology, weights
from repro.kernels import ops, ref

from .common import emit


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rng = np.random.default_rng(0)
    rows = []

    # Execution-mode tag: pallas-driven rows time the interpreter on CPU and
    # the compiled kernel on TPU — numbers from different modes differ by
    # orders of magnitude and must never be gate-compared (run.py --check
    # skips rows whose mode changed vs the baseline).
    pallas_mode = "pallas-interpret" if ops.use_interpret() else "compiled"

    # simulator backends at paper scale (N=200, 300 trials, 100 iters)
    g = topology.random_geometric(200, rng)
    w = weights.metropolis_hastings(g)
    th = accel.theta_asymptotic(0.5)
    a = accel.alpha_star_from_w(w, th)
    x0 = rng.standard_normal((200, 300))
    for backend in ("numpy", "jax", "pallas"):
        t0 = time.perf_counter()
        simulator.simulate(w, x0, 100, alpha=a, theta=th, backend=backend)
        rows.append({
            "bench": f"simulator_{backend}_N200xF300x100it",
            "us_per_call": (time.perf_counter() - t0) * 1e6,
            "mode": pallas_mode if backend == "pallas" else "compiled",
            "derived": "paper-scale trial batch",
        })

    # fused gossip round vs the unfused matvec + consensus_update pair: the
    # fusion removes the x_w HBM round-trip (1 write + 2 reads of the state
    # block per round) and one kernel launch.
    xp0 = rng.standard_normal((200, 300))
    wj, xj, xpj = (jnp.asarray(t, jnp.float32) for t in (w, x0, xp0))
    def f_fused():
        return ops.gossip_round(wj, xj, xpj, 1.1, 0.2, -0.3)

    def f_pair():
        return ops.consensus_update(
            ops.gossip_matvec(wj, xj), xj, xpj, 1.1, 0.2, -0.3
        )
    rows.append({"bench": "gossip_round_fused_N200xF300",
                 "us_per_call": _time(f_fused), "mode": pallas_mode,
                 "derived": "one pallas_call per round"})
    rows.append({"bench": "gossip_round_unfused_pair_N200xF300",
                 "us_per_call": _time(f_pair), "mode": pallas_mode,
                 "derived": "matvec + FMA, x_w via HBM"})

    # batched round at static vs autotuned tiles. The autotuner only varies
    # output-parallel tiles (bm/bf), so both rows compute bit-identical
    # results; the delta is pure blocking efficiency. Under the default
    # REPRO_KERNEL_TUNE=cache with a cold cache the tuned tiles degrade to
    # the static heuristic and the two rows coincide — set
    # REPRO_KERNEL_TUNE=full to measure and persist a real winner.
    gb, nb, fb = 2, 128, 128
    wsb = jnp.asarray(np.stack([w[:nb, :nb]] * gb), jnp.float32)
    xsb = jnp.asarray(rng.standard_normal((gb, nb, fb)), jnp.float32)
    xpb = jnp.asarray(rng.standard_normal((gb, nb, fb)), jnp.float32)
    cfb = jnp.asarray(np.tile([1.1, 0.2, -0.3], (gb, 1)), jnp.float32)
    interp = ops.use_interpret()
    sbm, sbk, sbf = ops._round_tiles(fb)
    tbm, tbk, tbf = ops.round_tiles(nb, fb, gb, tune=True)

    def f_static():
        return ops.gossip_round_batched_pallas(
            wsb, xsb, xpb, cfb, bm=sbm, bk=sbk, bf=sbf, interpret=interp)

    def f_tuned():
        return ops.gossip_round_batched_pallas(
            wsb, xsb, xpb, cfb, bm=tbm, bk=tbk, bf=tbf, interpret=interp)
    rows.append({"bench": f"gossip_round_batched_static_G{gb}N{nb}F{fb}",
                 "us_per_call": _time(f_static), "mode": pallas_mode,
                 "derived": f"static tiles ({sbm},{sbk},{sbf})"})
    rows.append({"bench": f"gossip_round_batched_tuned_G{gb}N{nb}F{fb}",
                 "us_per_call": _time(f_tuned), "mode": pallas_mode,
                 "derived": f"autotuned tiles ({tbm},{tbk},{tbf})"})

    # ELL segment round at the same footprint (ring topology, low degree):
    # the sparse engine's workhorse, gated like the dense rows.
    gs = topology.sparse_ring(nb)
    e_w, d_w = weights.metropolis_hastings_edges(gs)
    nbr, wgt, wrev, slot, diag = ops.build_ell(gs.edges, e_w, d_w, nb)
    xseg = jnp.asarray(rng.standard_normal((nb, fb)), jnp.float32)
    xpseg = jnp.asarray(rng.standard_normal((nb, fb)), jnp.float32)

    def f_seg():
        return ops.segment_round(
            nbr, wgt, slot, diag, xseg, xpseg, 1.1, 0.2, -0.3)
    rows.append({"bench": f"segment_round_N{nb}F{fb}",
                 "us_per_call": _time(f_seg), "mode": pallas_mode,
                 "derived": "ELL segment reduce, auto-padded wrapper"})

    # batched sweep engine: a full topology x design grid in one program.
    # Build the ensemble once and warm each backend with an untimed call so
    # the row tracks steady-state scan throughput, not host eigensolves or
    # jit trace/compile time.
    from repro.sweep import SweepSpec, build_ensemble, run_ensemble

    spec = SweepSpec(topologies=("chain", "grid2d", "rgg"), sizes=(16, 32),
                     designs=("memoryless", "asymptotic"), num_trials=8, seed=0)
    ens = build_ensemble(spec)
    for backend in ("jax", "pallas"):
        run_ensemble(ens, num_iters=100, backend=backend)  # warm-up/compile
        t0 = time.perf_counter()
        res = run_ensemble(ens, num_iters=100, backend=backend)
        rows.append({
            "bench": f"sweep_{backend}_G{res.ensemble.num_configs}x100it",
            "us_per_call": (time.perf_counter() - t0) * 1e6,
            "mode": pallas_mode if backend == "pallas" else "compiled",
            "derived": "ensemble grid, single jitted scan (warmed)",
        })

    # ssd_scan kernel vs naive recurrence oracle (CPU interpret)
    B, T, H, G, dh, ds = 1, 1024, 8, 1, 64, 64
    x = jnp.asarray(rng.standard_normal((B, T, H, dh)), jnp.float32)
    aa = -jnp.abs(jnp.asarray(rng.standard_normal((B, T, H)), jnp.float32)) * 0.1
    bb = jnp.asarray(rng.standard_normal((B, T, G, ds)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((B, T, G, ds)), jnp.float32)
    f_k = jax.jit(lambda *t: ops.ssd_scan(*t, chunk=128))
    f_r = jax.jit(lambda x, a, b, c: ref.ssd_scan_ref(
        x, a, jnp.repeat(b, H // G, 2), jnp.repeat(c, H // G, 2)))
    rows.append({"bench": "ssd_chunked_B1T1024", "us_per_call": _time(f_k, x, aa, bb, cc),
                 "mode": pallas_mode, "derived": "chunked dual form"})
    rows.append({"bench": "ssd_naive_scan_B1T1024", "us_per_call": _time(f_r, x, aa, bb, cc),
                 "mode": "compiled", "derived": "sequential recurrence"})

    emit("kernel_perf", rows)
    return rows


def main():
    argparse.ArgumentParser().parse_args()
    run()


if __name__ == "__main__":
    main()
