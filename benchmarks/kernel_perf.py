"""Kernel micro-bench: wall time of the Pallas ops (interpret mode on CPU —
a correctness-path timing, NOT a TPU perf claim; TPU numbers come from the
roofline analysis) plus the simulator backend comparison at paper scale.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accel, simulator, topology, weights
from repro.kernels import ops, ref

from .common import emit


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rng = np.random.default_rng(0)
    rows = []

    # Execution-mode tag: pallas-driven rows time the interpreter on CPU and
    # the compiled kernel on TPU — numbers from different modes differ by
    # orders of magnitude and must never be gate-compared (run.py --check
    # skips rows whose mode changed vs the baseline).
    pallas_mode = "pallas-interpret" if ops.use_interpret() else "compiled"

    # simulator backends at paper scale (N=200, 300 trials, 100 iters)
    g = topology.random_geometric(200, rng)
    w = weights.metropolis_hastings(g)
    th = accel.theta_asymptotic(0.5)
    a = accel.alpha_star_from_w(w, th)
    x0 = rng.standard_normal((200, 300))
    for backend in ("numpy", "jax", "pallas"):
        t0 = time.perf_counter()
        simulator.simulate(w, x0, 100, alpha=a, theta=th, backend=backend)
        rows.append({
            "bench": f"simulator_{backend}_N200xF300x100it",
            "us_per_call": (time.perf_counter() - t0) * 1e6,
            "mode": pallas_mode if backend == "pallas" else "compiled",
            "derived": "paper-scale trial batch",
        })

    # fused gossip round vs the unfused matvec + consensus_update pair: the
    # fusion removes the x_w HBM round-trip (1 write + 2 reads of the state
    # block per round) and one kernel launch.
    xp0 = rng.standard_normal((200, 300))
    wj, xj, xpj = (jnp.asarray(t, jnp.float32) for t in (w, x0, xp0))
    def f_fused():
        return ops.gossip_round(wj, xj, xpj, 1.1, 0.2, -0.3)

    def f_pair():
        return ops.consensus_update(
            ops.gossip_matvec(wj, xj), xj, xpj, 1.1, 0.2, -0.3
        )
    rows.append({"bench": "gossip_round_fused_N200xF300",
                 "us_per_call": _time(f_fused), "mode": pallas_mode,
                 "derived": "one pallas_call per round"})
    rows.append({"bench": "gossip_round_unfused_pair_N200xF300",
                 "us_per_call": _time(f_pair), "mode": pallas_mode,
                 "derived": "matvec + FMA, x_w via HBM"})

    # batched sweep engine: a full topology x design grid in one program.
    # Build the ensemble once and warm each backend with an untimed call so
    # the row tracks steady-state scan throughput, not host eigensolves or
    # jit trace/compile time.
    from repro.sweep import SweepSpec, build_ensemble, run_ensemble

    spec = SweepSpec(topologies=("chain", "grid2d", "rgg"), sizes=(16, 32),
                     designs=("memoryless", "asymptotic"), num_trials=8, seed=0)
    ens = build_ensemble(spec)
    for backend in ("jax", "pallas"):
        run_ensemble(ens, num_iters=100, backend=backend)  # warm-up/compile
        t0 = time.perf_counter()
        res = run_ensemble(ens, num_iters=100, backend=backend)
        rows.append({
            "bench": f"sweep_{backend}_G{res.ensemble.num_configs}x100it",
            "us_per_call": (time.perf_counter() - t0) * 1e6,
            "mode": pallas_mode if backend == "pallas" else "compiled",
            "derived": "ensemble grid, single jitted scan (warmed)",
        })

    # ssd_scan kernel vs naive recurrence oracle (CPU interpret)
    B, T, H, G, dh, ds = 1, 1024, 8, 1, 64, 64
    x = jnp.asarray(rng.standard_normal((B, T, H, dh)), jnp.float32)
    aa = -jnp.abs(jnp.asarray(rng.standard_normal((B, T, H)), jnp.float32)) * 0.1
    bb = jnp.asarray(rng.standard_normal((B, T, G, ds)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((B, T, G, ds)), jnp.float32)
    f_k = jax.jit(lambda *t: ops.ssd_scan(*t, chunk=128))
    f_r = jax.jit(lambda x, a, b, c: ref.ssd_scan_ref(
        x, a, jnp.repeat(b, H // G, 2), jnp.repeat(c, H // G, 2)))
    rows.append({"bench": "ssd_chunked_B1T1024", "us_per_call": _time(f_k, x, aa, bb, cc),
                 "mode": pallas_mode, "derived": "chunked dual form"})
    rows.append({"bench": "ssd_naive_scan_B1T1024", "us_per_call": _time(f_r, x, aa, bb, cc),
                 "mode": "compiled", "derived": "sequential recurrence"})

    emit("kernel_perf", rows)
    return rows


def main():
    argparse.ArgumentParser().parse_args()
    run()


if __name__ == "__main__":
    main()
