"""Asynchronous pairwise gossip vs the paper's synchronous methods, tick for tick.

The registry's ``async_pairwise`` algorithm (Boyd-style randomized gossip:
one edge wakes per engine round and the pair averages) runs in the SAME
jitted mixed-algorithm sweep as the synchronous memoryless and two-tap
cells — one program per backend — and this benchmark reads the eps-averaging
times off the shared MSE trajectories.

Tick-fairness (ROADMAP convention): each engine round is one tick of the
algorithm's own clock — a full W-multiply for the synchronous family, a
single pairwise exchange for async. Cross-algorithm comparison normalizes by
communication: one W-multiply activates every edge once, so E exchanges are
charged as one synchronous tick (``T_async_ticks = T_async_exch / E``).

Expected shape (the acceptance criterion checks the chain): per edge
activation the 0.5 pairwise step out-mixes a Metropolis-Hastings synchronous
round (whose per-edge weights are < 1/2), but a memoryless exchange cannot
touch the two-tap memory gain — so on sparse topologies the async tick
counts land strictly BETWEEN the two synchronous curves,

    T_accel  <  T_async_ticks  <  T_memoryless.

On dense graphs (RGG at the connectivity radius) per-edge normalization
flatters async — E is large while MH weights shrink — and the lower bracket
can break; the emitted rows record ``bracketed`` per topology either way.

Emits ``BENCH_fig_async.json`` (+ CSV) via ``benchmarks.common.emit``.
"""
from __future__ import annotations

import argparse
import math

from repro.core import dynamics as dyn
from repro.sweep import SweepSpec, build_ensemble, build_round_masks, run_ensemble

from .common import emit

QUICK = dict(size=16, graph_trials=2, num_trials=2)


def _iter_cap(ens, eps: float) -> int:
    """Scan length: slowest per-tick contraction in the grid plus slack.

    ``ConfigMeta.rho_accel`` already holds each algorithm's per-tick rate —
    for async cells the contraction of the expected per-exchange operator
    I - L/(2E), so the cap is in exchanges there.
    """
    worst = 0.0
    for c in ens.configs:
        if 0.0 < c.rho_accel < 1.0:
            worst = max(worst, math.log(eps) / math.log(c.rho_accel))
    return int(worst * 1.5) + 50


def run(topologies=("chain", "grid2d", "rgg"), size=16, graph_trials=1,
        num_trials=2, eps=1e-3, backend="jax", seed=0, num_iters=None):
    spec = SweepSpec(
        topologies=tuple(topologies), sizes=(size,), designs=("asymptotic",),
        algorithms=("memoryless", "accel", "async_pairwise"),
        graph_trials=graph_trials, num_trials=num_trials, init="paper",
        seed=seed,
    )
    ens = build_ensemble(spec)
    cap = num_iters if num_iters is not None else _iter_cap(ens, eps)
    masks = build_round_masks(ens, cap, seed=seed)
    res = run_ensemble(ens, num_iters=cap, backend=backend, round_masks=masks)
    times = res.averaging_times(eps=eps)                          # (G, F)

    rows = []
    for topo in topologies:
        mem = res.cells(topology=topo, algorithm="memoryless")
        acc = res.cells(topology=topo, algorithm="accel")
        asy = res.cells(topology=topo, algorithm="async_pairwise")

        def agg(cells, per_edge=False):
            """Mean hitting time over (cell, trial), each async cell's raw
            exchange count normalized by ITS OWN edge count (random-family
            draws differ in E) — plus how many (cell, trial) pairs missed
            the horizon, so a biased mean cannot pass silently."""
            ts, missed = [], 0
            for i in cells:
                e_i = len(dyn.edge_index(ens.ws[i]))
                for t in times[i]:
                    if t < 0:
                        missed += 1
                    else:
                        ts.append(t / e_i if per_edge else float(t))
            mean = sum(ts) / len(ts) if ts else float("nan")
            return mean, missed

        t_mem, miss_m = agg(mem)
        t_acc, miss_a = agg(acc)
        t_exch, miss_x = agg(asy)
        t_ticks, _ = agg(asy, per_edge=True)
        missed = miss_m + miss_a + miss_x
        if missed:
            print(f"fig_async[{topo}]: {missed} (cell, trial) pair(s) never "
                  f"reached eps={eps} within {cap} rounds — means are over "
                  f"the survivors; raise num_iters")
        e_mean = sum(len(dyn.edge_index(ens.ws[i])) for i in asy) / len(asy)
        bracketed = t_acc <= t_ticks <= t_mem
        rows.append({
            "topology": topo, "n": size, "edges": e_mean,
            "T_memoryless": t_mem, "T_accel": t_acc,
            "T_async_exchanges": t_exch, "T_async_ticks": t_ticks,
            "bracketed": bracketed, "missed": missed,
        })
        print(f"fig_async[{topo} n={size} E={e_mean:.0f}]: T_mem={t_mem:.0f} "
              f"T_accel={t_acc:.0f} T_async={t_exch:.0f}ex = {t_ticks:.1f} "
              f"ticks -> {'bracketed' if bracketed else 'NOT bracketed'}")
    emit("fig_async", rows)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: toy sizes, jax backend")
    ap.add_argument("--backend", default=None, choices=["jax", "pallas"])
    ap.add_argument("--size", type=int, default=None)
    ap.add_argument("--trials", type=int, default=None, help="graph draws (rgg)")
    a = ap.parse_args(argv)
    kw = dict(QUICK) if a.quick else {}
    if a.backend is not None:
        kw["backend"] = a.backend
    if a.size is not None:
        kw["size"] = a.size
    if a.trials is not None:
        kw["graph_trials"] = a.trials
    run(**kw)


if __name__ == "__main__":
    main()
