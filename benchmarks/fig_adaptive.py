"""Adaptive figure: in-scan lambda_2 re-estimation + the M-tap frontier.

Two questions, one jitted sweep:

1. **Does adaptation recover the failure-induced mistuning?** The nominal
   two-tap design solves Theorem 1 for the intact graph's lambda_2; under
   link failures the effective operator's lambda_2 rises, and the nominal
   alpha* is too aggressive. ``accel_adapt`` re-solves alpha* every tick
   from its in-scan deflated power iteration (floored at nominal — see
   ``core.algorithms.AdaptiveTwoTap``). The yardstick is a **matched oracle**:
   plain ``accel`` cells whose alpha was pre-solved from the mean masked
   operator's lambda_2 (the tuning a genie who knew the failure schedule's
   average would pick), CRN-coupled to the same per-round failure draws.

2. **What does each extra tap buy?** ``accel_m:M`` cells on the static chain
   report design rho, measured tail rho, sustained times, and the Chebyshev
   minimax lower bound over the true spectral interval
   (``accel.averaging_time_lower_bound``). M = 2 reduces exactly to
   Theorem 1; M >= 3 admits lambda_N (true interval) — a better asymptotic
   rate paid for with a larger transient hump, and flat in M beyond 3
   (Golub-Varga saturation, see ``accel.m_tap_weights``).

All cells — adaptive grid, oracle minis, M-tap column — are merged into ONE
ensemble and one compiled scan per backend; a warmed mode-tagged timing row
(``sweep_adaptive_*``) keeps the lane under the perf gate's like-for-like
rules. Emits ``BENCH_fig_adaptive.json`` (+ CSV) via ``benchmarks.common``.
CI runs ``--quick`` on the pallas backend.

Measurement notes (from the design experiments behind this figure):

* iid Bernoulli mistuning on the chain is mild (the random-product average
  forgives a detuned alpha far more than the deterministic-rate arithmetic
  predicts); grid2d separates cleanly at p = 0.1, and bursty schedules
  (``correlated:p:blocks:period``) separate on the chain. The acceptance
  asserts are anchored on the oracle ratio bound and the nominal-vs-adaptive
  AGGREGATE over all failure rows — paired by CRN, so small margins are
  stable, not noise.
* under heavy loss (p >= 0.2 iid, or deep bursts) the mean-operator model
  itself over-corrects on the chain: the random product forgives the nominal
  tuning far more than the averaged-rate arithmetic predicts, so the
  matched oracle — and the estimator faithfully tracking it — lands above
  nominal. Those rows are reported, never asserted against; the aggregate
  assert covers rows with p <= ``AGG_MAX_P``.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import accel, dynamics
from repro.kernels import ops
from repro.sweep import (SweepSpec, build_ensemble, build_round_masks,
                         merge_ensembles, run_ensemble)

from .common import emit

TOPOLOGIES = ("chain", "grid2d")
ALGORITHMS = ("accel", "accel_adapt")
MTAP_ALGOS = ("accel", "accel_m:2", "accel_m:3", "accel_m:4")
DYNAMICS = ("static", "bernoulli:0.05", "bernoulli:0.1", "bernoulli:0.2",
            "correlated:0.1:3:5")

QUICK = dict(num_trials=2, num_iters=800, backend="pallas",
             dynamics_grid=("static", "bernoulli:0.1"))

# Failure rate above which the mean-operator tuning model stops being
# predictive on the chain (see module docstring); heavier rows are reported
# but excluded from the nominal-vs-adaptive aggregate assert.
AGG_MAX_P = 0.1


def _mean_masked_lambda2(w: np.ndarray, ix: np.ndarray, dyn: str, n: int,
                         topo: str, num_iters: int, seed: int) -> float:
    """lambda_2 of the schedule's MEAN effective operator, exactly CRN-paired.

    Samples the same bits ``build_round_masks`` will hand the engine (same
    ``dynamics.graph_rng`` key), averages the per-edge up-fraction, and
    applies the mass-preserving reweighting with those fractional bits —
    the masking rule is linear in the bits, so this IS E[W_eff] under the
    empirical schedule, bursts and all.
    """
    spec = dynamics.parse_dynamics(dyn)
    rng = dynamics.graph_rng(seed, (topo, n, 0))
    bits = dynamics.sample_edge_bits(spec, num_iters, ix, n, rng)
    w_mean = dynamics.masked_w(w[:n, :n], bits.mean(axis=0), ix)
    vals = np.linalg.eigvalsh(w_mean)
    return float(vals[-2])


def _tail_rho(mse_cell: np.ndarray, floor: float = 1e-7) -> float:
    """Per-tick contraction over the last clean decay window of a cell.

    The window ends where the trial-mean MSE first dips under ``floor``
    (past that the f32 plateau contaminates the quotient) and spans the 20
    preceding ticks.
    """
    m = mse_cell.mean(axis=1)
    below = np.nonzero(m < floor)[0]
    hi = int(below[0]) if len(below) else len(m) - 1
    lo = max(hi - 20, 1)
    if hi <= lo or m[lo] <= 0:
        return float("nan")
    return float((m[hi] / m[lo]) ** (1.0 / (2 * (hi - lo))))


def _dwell_times(mse: np.ndarray, eps: float, dwell: int = 50) -> np.ndarray:
    """(G, F) first t after which the MSE stays under eps^2 mse(0) for
    ``dwell`` consecutive ticks (-1 where never).

    The engine's ``sustained=True`` requires holding the threshold through
    the END of the horizon, which long f32 runs of large-coefficient
    recursions fail for a non-physical reason: roundoff drift slowly
    re-grows the floor after convergence. A dwell window keeps the
    robustness against non-monotone masked-dynamics dips without charging
    the algorithms for late-horizon float drift. Crossings within the last
    ``dwell`` ticks count if they hold to the horizon (the window is padded
    with hits), so the metric is monotone in the horizon.
    """
    thresh = (eps * eps) * mse[:, :1, :]
    hit = mse <= np.maximum(thresh, 0.0)                       # (G, T+1, F)
    dwell = min(dwell, hit.shape[1])
    pad = np.ones((hit.shape[0], dwell - 1, hit.shape[2]), dtype=bool)
    padded = np.concatenate([hit, pad], axis=1)
    win = np.lib.stride_tricks.sliding_window_view(
        padded, dwell, axis=1).all(axis=-1)                    # (G, T+1, F)
    t = np.argmax(win, axis=1)
    return np.where(win.any(axis=1), t, -1).astype(np.int64)


def _cell_time(times: np.ndarray, idx: list[int]) -> tuple[float, float]:
    """(mean sustained time over converged trials, converged fraction)."""
    hits = [times[i, f] for i in idx for f in range(times.shape[1])
            if times[i, f] >= 0]
    total = max(len(idx) * times.shape[1], 1)
    return (float(np.mean(hits)) if hits else -1.0, len(hits) / total)


def run(size=16, num_trials=4, num_iters=1300, eps=1e-4, backend="jax",
        dynamics_grid=DYNAMICS, seed=0):
    fail_dyns = [d for d in dynamics_grid if d != "static"]

    main_spec = SweepSpec(
        topologies=TOPOLOGIES, sizes=(size,), designs=("memoryless", "asymptotic"),
        algorithms=ALGORITHMS, dynamics=tuple(dynamics_grid),
        num_trials=num_trials, layout="dense", init="paper", seed=seed,
    )
    main = build_ensemble(main_spec)

    mtap_spec = SweepSpec(
        topologies=("chain",), sizes=(size,), designs=("asymptotic",),
        algorithms=MTAP_ALGOS, dynamics=("static",),
        num_trials=num_trials, layout="dense", init="paper", seed=seed,
    )
    mtap = build_ensemble(mtap_spec)

    # Matched-oracle minis: one accel cell per (topology, failure dynamics),
    # alpha pre-solved from the mean masked operator. Same seed -> same graph
    # draw, same init block, and (graph-keyed RoundMasks sampling) the same
    # per-round failure bits as the nominal/adaptive cells they pair with.
    theta = accel.theta_asymptotic(0.5)
    oracle_alpha: dict[tuple[str, str], float] = {}
    oracle_minis = []
    for topo in TOPOLOGIES:
        i_ref = next(i for i, c in enumerate(main.configs)
                     if c.topology == topo and c.algorithm == "accel")
        n = int(main.node_counts[i_ref])
        w = np.asarray(main.ws[i_ref], dtype=np.float64)
        ix = main.edge_index(i_ref)
        for dyn in fail_dyns:
            lam_eff = _mean_masked_lambda2(w, ix, dyn, n, topo, num_iters, seed)
            al = accel.alpha_star(lam_eff, theta)
            oracle_alpha[(topo, dyn)] = al
            oracle_minis.append(build_ensemble(SweepSpec(
                topologies=(topo,), sizes=(size,), designs=("asymptotic",),
                alphas=(al,), algorithms=("accel",), dynamics=(dyn,),
                num_trials=num_trials, layout="dense", init="paper", seed=seed,
            )))

    ens = merge_ensembles(main, mtap, *oracle_minis)
    oracle_start = main.num_configs + mtap.num_configs
    masks = build_round_masks(ens, num_iters, seed=seed)

    def _go():
        return run_ensemble(ens, num_iters=num_iters, backend=backend,
                            round_masks=masks)

    res = _go()                         # warm: trace + compile
    t0 = time.perf_counter()
    res = _go()
    us = (time.perf_counter() - t0) * 1e6
    times = _dwell_times(res.mse, eps)                        # (G, F)

    pallas_mode = "pallas-interpret" if ops.use_interpret() else "compiled"
    mode = pallas_mode if backend == "pallas" else "compiled"
    nan = float("nan")
    rows = []

    def _row(bench, **kw):
        base = {"bench": bench, "topology": "", "dynamics": "", "variant": "",
                "n": size, "t_avg": nan, "frac_converged": nan,
                "t_oracle_ratio": nan, "rho_design": nan, "rho_tail": nan,
                "t_lower_bound": nan, "mode": mode, "us_per_call": nan}
        base.update(kw)
        rows.append(base)
        return base

    # ---- adaptive grid: memoryless / nominal / adaptive / oracle ----------
    agg_nom, agg_adapt = 0.0, 0.0
    agg_rows = 0
    for topo in TOPOLOGIES:
        for dyn in dynamics_grid:
            variants = {
                "memoryless": [i for i in res.cells(
                    topology=topo, dynamics=dyn, algorithm="accel",
                    design="memoryless") if i < oracle_start],
                "nominal": [i for i in res.cells(
                    topology=topo, dynamics=dyn, algorithm="accel",
                    design="asymptotic") if i < oracle_start],
                "adaptive": [i for i in res.cells(
                    topology=topo, dynamics=dyn, algorithm="accel_adapt",
                    design="asymptotic") if i < oracle_start],
            }
            if dyn != "static":
                variants["oracle"] = [i for i in res.cells(
                    topology=topo, dynamics=dyn, algorithm="accel",
                    design="asymptotic") if i >= oracle_start]
            t, fracs = {}, {}
            for name, idx in variants.items():
                t[name], fracs[name] = _cell_time(times, idx)
                if t[name] < 0:
                    print(f"fig_adaptive[{topo} {dyn} {name}]: no trial "
                          f"sustained eps={eps} within {num_iters} rounds "
                          f"(raise --iters or eps)")
            for name in variants:
                ratio = (t[name] / t["oracle"]
                         if t.get("oracle", -1) > 0 and t[name] > 0
                         and name != "oracle" else nan)
                _row(f"adaptive_{topo}_{dyn}_{name}", topology=topo,
                     dynamics=dyn, variant=name, t_avg=t[name],
                     frac_converged=fracs[name], t_oracle_ratio=ratio)
            msg = " ".join(f"{k}={v:.0f}" for k, v in t.items())
            print(f"fig_adaptive[{topo} {dyn}]: {msg}")
            if dyn != "static" and t.get("nominal", -1) > 0 \
                    and t.get("adaptive", -1) > 0 \
                    and dynamics.parse_dynamics(dyn).p <= AGG_MAX_P:
                agg_nom += t["nominal"]
                agg_adapt += t["adaptive"]
                agg_rows += 1
            if dyn == "bernoulli:0.1" and t.get("oracle", -1) > 0 \
                    and t.get("adaptive", -1) > 0:
                r = t["adaptive"] / t["oracle"]
                assert r <= 1.5, (
                    f"accel_adapt {r:.2f}x oracle on {topo} at p=0.1 "
                    f"(acceptance bound 1.5x)")

    # Paired (CRN) aggregate over every failure row: adaptation must recover
    # at least what the nominal design loses. Per-row margins vary (see
    # module docstring); the aggregate is the robust acceptance anchor.
    if agg_rows:
        print(f"fig_adaptive[aggregate over {agg_rows} failure rows]: "
              f"nominal={agg_nom:.0f} adaptive={agg_adapt:.0f}")
        assert agg_adapt <= agg_nom, (
            f"adaptive aggregate {agg_adapt:.0f} worse than nominal "
            f"{agg_nom:.0f} over {agg_rows} CRN-paired failure rows")

    # ---- M-tap frontier column (static chain) -----------------------------
    i0 = next(i for i in range(main.num_configs, oracle_start)
              if res.configs[i].algorithm == "accel")
    n0 = int(ens.node_counts[i0])
    vals = np.linalg.eigvalsh(np.asarray(ens.ws[i0][:n0, :n0], np.float64))
    lam2, lam_n = float(vals[-2]), float(vals[0])
    t_lb = accel.averaging_time_lower_bound(eps, lam_n, lam2)
    mtap_t = {}
    for spec_name in MTAP_ALGOS:
        idx = [i for i in range(main.num_configs, oracle_start)
               if res.configs[i].algorithm == spec_name]
        t_avg, frac = _cell_time(times, idx)
        per_trial = times[idx[0]]
        rho_d = res.configs[idx[0]].rho_accel
        rho_t = _tail_rho(res.mse[idx[0]])
        mtap_t[spec_name] = (t_avg, per_trial, rho_d, rho_t)
        _row(f"mtap_chain_{spec_name.replace(':', '')}", topology="chain",
             dynamics="static", variant=spec_name, t_avg=t_avg,
             frac_converged=frac, rho_design=rho_d, rho_tail=rho_t,
             t_lower_bound=float(t_lb),
             t_oracle_ratio=(t_avg / t_lb if t_avg > 0 else nan))
        print(f"fig_adaptive[mtap {spec_name}]: t={t_avg:.1f} "
              f"rho_design={rho_d:.4f} rho_tail={rho_t:.4f} "
              f"T_lb={t_lb} ratio={t_avg / t_lb if t_avg > 0 else nan:.2f}")

    t2, pt2 = mtap_t["accel"][0], mtap_t["accel"][1]
    assert np.array_equal(pt2, mtap_t["accel_m:2"][1]), (
        "accel_m:2 must reduce exactly to the two-tap recursion")
    for spec_name in ("accel_m:3", "accel_m:4"):
        t_m, _, rho_d, rho_t = mtap_t[spec_name]
        assert rho_d < mtap_t["accel"][2], (
            f"{spec_name} design rho {rho_d:.4f} not below two-tap "
            f"{mtap_t['accel'][2]:.4f}")
        assert rho_t < mtap_t["accel"][3], (
            f"{spec_name} measured tail rho {rho_t:.4f} not below two-tap "
            f"{mtap_t['accel'][3]:.4f}")
        if t_m > 0 and t2 > 0:
            assert t_m <= t2, (
                f"{spec_name} sustained time {t_m:.1f} above two-tap {t2:.1f} "
                f"on the static chain at eps={eps}")

    _row(f"sweep_adaptive_{backend}_G{ens.num_configs}x{num_iters}it",
         variant="all", us_per_call=us)
    emit("fig_adaptive", rows)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer trials/rounds on the pallas backend")
    ap.add_argument("--backend", default=None, choices=["jax", "pallas"])
    ap.add_argument("--size", type=int, default=None)
    ap.add_argument("--trials", type=int, default=None)
    ap.add_argument("--iters", type=int, default=None)
    a = ap.parse_args(argv)
    kw = dict(QUICK) if a.quick else {}
    if a.backend is not None:
        kw["backend"] = a.backend
    if a.size is not None:
        kw["size"] = a.size
    if a.trials is not None:
        kw["num_trials"] = a.trials
    if a.iters is not None:
        kw["num_iters"] = a.iters
    run(**kw)


if __name__ == "__main__":
    main()
