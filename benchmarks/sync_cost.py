"""Cross-pod gradient-sync cost model: the paper's technique as a systems win.

For P pods on a DCN ring (6.25 GB/s/chip cross-pod), compares bytes-on-wire
and estimated sync seconds per training step for a given gradient size:

  * allreduce       — 2*G*(P-1)/P bytes (ring all-reduce over DCN);
  * gossip          — R_mem rounds x 2 neighbour payloads x G;
  * accel_gossip    — R_acc rounds (Theorem 1/2: R_acc ~ sqrt(R_mem));
  * accel + int8    — accelerated rounds with int8+EF wire (4x fewer bytes).

At small P a single all-reduce wins; the consensus modes win scalability:
per-round cost is CONSTANT in P (2 neighbours), rounds grow as the ring
mixing time — and acceleration takes sqrt of that. The eps knob trades
exactness for staleness (decentralized SGD semantics).
"""
from __future__ import annotations

import argparse


from repro.dist.gossip import make_fabric

from .common import emit

DCN_BW = 6.25e9  # bytes/s/chip cross-pod


def run(grad_gb=3.5, eps=1e-2, pods=(4, 8, 16, 32, 64)):
    g_bytes = grad_gb * 2**30  # bf16 gradient payload per pod
    rows = []
    for p in pods:
        fab = make_fabric(p, "ring")
        r_acc = fab.rounds_for(eps)
        r_mem = fab.rounds_for_memoryless(eps)
        nb = 2 if p > 2 else 1
        bytes_ar = 2 * g_bytes * (p - 1) / p
        bytes_gossip = r_mem * nb * g_bytes
        bytes_acc = r_acc * nb * g_bytes
        bytes_acc_int8 = bytes_acc / 2 if False else r_acc * nb * g_bytes * 0.5
        # int8 wire: 1 byte/elem vs bf16 2 bytes -> x0.5 bytes
        rows.append({
            "pods": p, "lambda2": fab.lambda2,
            "rounds_memoryless": r_mem, "rounds_accel": r_acc,
            "round_ratio": r_mem / max(r_acc, 1),
            "GB_allreduce": bytes_ar / 2**30,
            "GB_gossip": bytes_gossip / 2**30,
            "GB_accel": bytes_acc / 2**30,
            "GB_accel_int8": bytes_acc_int8 / 2**30,
            "s_allreduce": bytes_ar / DCN_BW,
            "s_accel": bytes_acc / DCN_BW,
            "s_accel_int8": bytes_acc_int8 / DCN_BW,
        })
        print(f"sync[P={p}]: rounds {r_mem}->{r_acc} "
              f"({r_mem/max(r_acc,1):.1f}x fewer), accel+int8 "
              f"{rows[-1]['GB_accel_int8']:.1f} GB vs allreduce "
              f"{rows[-1]['GB_allreduce']:.1f} GB")
    emit("sync_cost", rows)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grad-gb", type=float, default=3.5)
    ap.add_argument("--eps", type=float, default=1e-2)
    a = ap.parse_args()
    run(grad_gb=a.grad_gb, eps=a.eps)


if __name__ == "__main__":
    main()
