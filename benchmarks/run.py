"""Benchmark entry point: one suite per paper figure/table + the systems
extensions. Prints CSV blocks; saves CSV + BENCH_*.json under
experiments/bench/.

    PYTHONPATH=src python -m benchmarks.run [--full | --quick]

Default sizes keep a single-core CPU run in minutes; --full uses paper-scale
trial counts; --quick is the CI smoke tier — kernel microbenches plus the
sweep engine at toy sizes, a couple of minutes on a shared runner, emitting
the BENCH_*.json artifacts that the workflow uploads.
"""
from __future__ import annotations

import argparse
import time


def _quick() -> None:
    # fig_robustness is NOT in this tier: CI runs it as its own named step
    # (python -m benchmarks.fig_robustness --quick) so the masked-kernel
    # path's cost and failures stay attributable, and running it here too
    # would double the most expensive interpret-mode bench of the job.
    from . import fig34_scaling, kernel_perf

    kernel_perf.run()
    fig34_scaling.run(
        trials=2,
        rgg_sizes=(30, 50),
        chain_sizes=(10, 20, 30),
        backend="pallas",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale trials (300) instead of CI-scale")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: kernel perf + toy sweep only")
    args = ap.parse_args()
    full = args.full

    t0 = time.time()
    if args.quick:
        _quick()
        print(f"benchmarks (quick) done in {time.time()-t0:.0f}s")
        return

    from . import (fig1_mse, fig2_polyfilt, fig34_scaling, fig5_finite_time,
                   init_cost, kernel_perf, roofline_table, sync_cost)

    fig1_mse.run(trials=300 if full else 8, iters=400)
    fig2_polyfilt.run(trials=100 if full else 5, iters=600)
    fig34_scaling.run(trials=20 if full else 3,
                      rgg_sizes=(50, 100, 150, 200) if full else (50, 100, 150),
                      chain_sizes=(20, 40, 60, 80, 100) if full else (20, 40, 60, 80))
    fig5_finite_time.run(sizes=(50, 100, 150) if full else (40, 80), trials=10 if full else 3)
    init_cost.run()
    sync_cost.run()
    kernel_perf.run()
    roofline_table.run(mesh="single")
    roofline_table.run(mesh="multi")
    print(f"benchmarks done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
