"""Benchmark entry point: one suite per paper figure/table + the systems
extensions. Prints CSV blocks; saves CSV + BENCH_*.json under
experiments/bench/.

    PYTHONPATH=src python -m benchmarks.run [--full | --quick | --check]

Default sizes keep a single-core CPU run in minutes; --full uses paper-scale
trial counts; --quick is the CI smoke tier — kernel microbenches plus the
sweep engine at toy sizes, a couple of minutes on a shared runner, emitting
the BENCH_*.json artifacts that the workflow uploads.

--check is the CI perf gate: re-run the kernel microbenches and compare each
kernel row's us_per_call against the tracked repo-root baseline
``BENCH_kernel_perf.json`` (the baseline is read BEFORE the fresh run
overwrites it), exiting non-zero on any >1.5x regression. The ratio is
overridable via REPRO_PERF_GATE_RATIO for machines much slower than the one
that stamped the baseline; in CI the committed baseline is stashed before
the smoke benches rewrite the root JSON. Comparisons are like-for-like
only: every row carries an execution ``mode`` tag ("compiled" or
"pallas-interpret") and rows whose mode differs from the baseline's are
skipped, never ratioed — interpret-vs-compiled timings are different
experiments.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# rows gated by --check: the warmed kernel/engine rows. The simulator_* rows
# include jit trace+compile time and host eigensolves — tracked, not gated.
GATE_PREFIXES = ("gossip_round", "segment_round", "sweep_", "ssd_")


def _trajectory_path() -> str:
    from .common import OUT_DIR

    return os.path.join(OUT_DIR, "TRAJECTORY.jsonl")


def _append_trajectory(rows, path: str | None = None) -> None:
    """Append one per-commit line of gate-row timings to TRAJECTORY.jsonl.

    The line holds (commit, unix_time, {bench: {us_per_call, mode}}) for
    every GATE_PREFIXES row — the tracked perf trajectory that accumulates
    across commits (the BENCH_*.json files only ever hold the latest run).
    Called from the bench tiers, never from --check: a gate run must not
    stamp its own machine-local timings into the history it gates against.
    """
    entry = {
        r["bench"]: {"us_per_call": float(r["us_per_call"]),
                     "mode": r.get("mode")}
        for r in rows if r["bench"].startswith(GATE_PREFIXES)
    }
    if not entry:
        return
    commit = os.environ.get("GITHUB_SHA", "").strip()
    if not commit:
        import subprocess

        try:
            commit = subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                capture_output=True, text=True, timeout=10,
            ).stdout.strip()
        except (OSError, subprocess.SubprocessError):
            commit = ""
    line = {"commit": commit, "unix_time": time.time(), "rows": entry}
    path = path or _trajectory_path()
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(line, sort_keys=True) + "\n")


def _trajectory_rows(path: str | None = None) -> dict:
    """bench name -> most recent trajectory row dict ({us_per_call, mode}).

    Later lines win; unparseable lines are skipped (the file is appended by
    many commits on many machines — one bad line must not kill the gate).
    Missing file -> empty dict: the gate then runs purely off the baseline.
    """
    path = path or _trajectory_path()
    out: dict = {}
    try:
        with open(path) as f:
            for raw in f:
                raw = raw.strip()
                if not raw:
                    continue
                try:
                    line = json.loads(raw)
                    rows = line["rows"]
                except (ValueError, KeyError, TypeError):
                    continue
                if isinstance(rows, dict):
                    for name, r in rows.items():
                        if isinstance(r, dict) and "us_per_call" in r:
                            out[name] = {"bench": name, **r}
    except OSError:
        return {}
    return out


def _gate_rows(fresh, base_rows, ratio_max):
    """Pure comparison core of the perf gate (unit-tested in test_perf_gate).

    ``fresh`` is the list of freshly timed row dicts, ``base_rows`` maps
    bench name -> baseline row dict. Rows are compared like-for-like only:
    a row whose execution ``mode`` differs from the baseline's (e.g. the
    baseline was stamped in pallas-interpret on CPU and this run compiled on
    a TPU, or vice versa) is SKIPPED — cross-mode timings differ by orders
    of magnitude and would otherwise hard-fail (or silently ratchet) the
    gate. Rows missing a mode on either side gate as before (pre-mode-tag
    baselines stay comparable). Returns (report_lines, failures).
    """
    lines, failures = [], []
    for r in fresh:
        name = r["bench"]
        if not name.startswith(GATE_PREFIXES):
            continue
        b = base_rows.get(name)
        if b is None:
            lines.append(f"{name}: NEW (no baseline row, passes)")
            continue
        mode_f, mode_b = r.get("mode"), b.get("mode")
        if mode_f is not None and mode_b is not None and mode_f != mode_b:
            lines.append(
                f"{name}: SKIP (mode {mode_b} -> {mode_f}; cross-mode "
                f"timings are not comparable)")
            continue
        ratio = float(r["us_per_call"]) / float(b["us_per_call"])
        verdict = "FAIL" if ratio > ratio_max else "ok"
        lines.append(
            f"{name}: {float(b['us_per_call']):.0f} -> "
            f"{float(r['us_per_call']):.0f} us ({ratio:.2f}x) {verdict}")
        if ratio > ratio_max:
            failures.append((name, ratio))
    return lines, failures


def _check(baseline_path: str) -> int:
    try:
        with open(baseline_path) as f:
            base_text = f.read()
        base = json.loads(base_text)
    except FileNotFoundError:
        print(f"perf gate: no baseline at {baseline_path} — run "
              f"`python -m benchmarks.run --quick` and commit the root "
              f"BENCH_kernel_perf.json to start the trajectory")
        return 1
    # The tracked per-commit trajectory widens the baseline: rows that only
    # exist in TRAJECTORY.jsonl (e.g. a bench added after the last committed
    # baseline refresh) still gate. The baseline JSON wins on conflicts — it
    # is the deliberately stamped reference, the trajectory the running log.
    base_rows = {**_trajectory_rows(), **{r["bench"]: r for r in base["rows"]}}

    from . import kernel_perf

    fresh = kernel_perf.run()
    # kernel_perf's emit() just rewrote the root BENCH_kernel_perf.json —
    # which may BE the tracked baseline we gate against. Restore it: a gate
    # run must never self-ratchet the baseline (two sequential 1.4x
    # regressions would otherwise each pass against the drifted file) nor
    # leave the tracked file dirty with machine-local timings. Refreshing
    # the baseline stays a deliberate act: run --quick and commit.
    if os.path.exists(baseline_path):
        with open(baseline_path, "w") as f:
            f.write(base_text)
    ratio_max = float(os.environ.get("REPRO_PERF_GATE_RATIO", "1.5"))
    print(f"### perf gate (>{ratio_max}x vs {baseline_path})")
    lines, failures = _gate_rows(fresh, base_rows, ratio_max)
    for line in lines:
        print(line)
    if failures:
        print(f"perf gate FAILED: {len(failures)} kernel row(s) regressed "
              f">{ratio_max}x: " + ", ".join(f"{n} {r:.2f}x" for n, r in failures))
        return 1
    print("perf gate passed")
    return 0


def _quick() -> None:
    # fig_robustness is NOT in this tier: CI runs it as its own named step
    # (python -m benchmarks.fig_robustness --quick) so the masked-kernel
    # path's cost and failures stay attributable, and running it here too
    # would double the most expensive interpret-mode bench of the job.
    from . import fig34_scaling, kernel_perf

    _append_trajectory(kernel_perf.run())
    fig34_scaling.run(
        trials=2,
        rgg_sizes=(30, 50),
        chain_sizes=(10, 20, 30),
        backend="pallas",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale trials (300) instead of CI-scale")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: kernel perf + toy sweep only")
    ap.add_argument("--check", action="store_true",
                    help="perf gate: fresh kernel bench vs the tracked baseline")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON for --check (default: repo-root "
                         "BENCH_kernel_perf.json)")
    args = ap.parse_args()
    full = args.full

    if args.check:
        from .common import ROOT_DIR

        baseline = args.baseline or os.path.join(ROOT_DIR, "BENCH_kernel_perf.json")
        sys.exit(_check(baseline))

    t0 = time.time()
    if args.quick:
        _quick()
        print(f"benchmarks (quick) done in {time.time()-t0:.0f}s")
        return

    from . import (fig1_mse, fig2_polyfilt, fig34_scaling, fig5_finite_time,
                   init_cost, kernel_perf, roofline_table, sync_cost)

    fig1_mse.run(trials=300 if full else 8, iters=400)
    fig2_polyfilt.run(trials=100 if full else 5, iters=600)
    fig34_scaling.run(trials=20 if full else 3,
                      rgg_sizes=(50, 100, 150, 200) if full else (50, 100, 150),
                      chain_sizes=(20, 40, 60, 80, 100) if full else (20, 40, 60, 80))
    fig5_finite_time.run(sizes=(50, 100, 150) if full else (40, 80), trials=10 if full else 3)
    init_cost.run()
    sync_cost.run()
    _append_trajectory(kernel_perf.run())
    roofline_table.run(mesh="single")
    roofline_table.run(mesh="multi")
    print(f"benchmarks done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
