"""Dense vs sparse engine scaling: us/round and peak memory across N.

    PYTHONPATH=src python -m benchmarks.sparse_scaling [--quick]

For each network size the SAME sweep (one BA power-law cell, accel design,
static topology) runs through the dense (G, N, N) engine and the sparse
edge-list engine, timing steady-state us/round (compile excluded via an
untimed warm-up call at every size/layout) and recording the weight-storage
footprint each layout carries into the scan: O(N^2) f32 for the dense
stack vs O(E) directed arrays (+ O(N) diagonal) for sparse. The crossover
where sparse wins on wall clock lands at a few hundred nodes on CPU; above
``SPARSE_EXACT_SPECTRUM_CUTOFF`` the dense column stops entirely (an
N=1e5 dense cell would need 40 GB for W alone) while the sparse column
keeps scaling — the --quick tier caps at N=2e4 to stay CI-sized, the full
tier pushes to N=2e5.

Emits BENCH_sparse_scaling.json / sparse_scaling.csv via the common
scaffolding; CI uploads the JSON as a workflow artifact.
"""
from __future__ import annotations

import argparse
import time

from repro.sweep.engine import run_ensemble
from repro.sweep.grid import (
    SPARSE_EXACT_SPECTRUM_CUTOFF,
    SweepSpec,
    build_ensemble,
)

from .common import emit


def _weight_bytes(ens) -> int:
    """Bytes of weight-layout state the scan carries (the O(N^2) vs O(E) story)."""
    if ens.is_sparse:
        # directed arrays the jax backend builds: src/dst/eid int32 + wdir
        # f32 (2E each) + the (N,) f32 diagonal
        e2 = 2 * ens.edges.shape[1]
        return ens.edges.shape[0] * (4 * 4 * e2 + 4 * ens.n_max)
    return ens.ws.nbytes


def _time_layout(n: int, layout: str, *, trials: int, iters: int,
                 reps: int) -> tuple[float, int]:
    """(us_per_round, weight_bytes) for one size/layout, compile excluded."""
    spec = SweepSpec(
        topologies=("ba:3",), sizes=(n,), designs=("asymptotic",),
        alphas=(1.0,), num_trials=trials, seed=0, algorithms=("accel",),
        layout=layout,
    )
    ens = build_ensemble(spec)
    run_ensemble(ens, num_iters=iters, backend="jax")   # warm-up/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        run_ensemble(ens, num_iters=iters, backend="jax")
    us_round = (time.perf_counter() - t0) / (reps * iters) * 1e6
    return us_round, _weight_bytes(ens)


def run(sizes=(64, 256, 1024, 4096, 20_000), *, trials: int = 4,
        iters: int = 30, reps: int = 3) -> list[dict]:
    rows = []
    for n in sizes:
        row = {"bench": f"sparse_scaling_N{n}", "n": n}
        if n <= SPARSE_EXACT_SPECTRUM_CUTOFF:
            us_d, mem_d = _time_layout(
                n, "dense", trials=trials, iters=iters, reps=reps)
            row["dense_us_per_round"] = us_d
            row["dense_weight_mb"] = mem_d / 1e6
        else:
            # dense would densify an (N, N) W: skipped, not just slow
            row["dense_us_per_round"] = float("nan")
            row["dense_weight_mb"] = float("nan")
        us_s, mem_s = _time_layout(
            n, "sparse", trials=trials, iters=iters, reps=reps)
        row["sparse_us_per_round"] = us_s
        row["sparse_weight_mb"] = mem_s / 1e6
        rows.append(row)
    emit("sparse_scaling", rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI tier: smaller sizes/trials, caps at N=2e4")
    args = ap.parse_args()
    if args.quick:
        run(sizes=(64, 256, 1024, 4096, 20_000), trials=2, iters=20, reps=2)
    else:
        run(sizes=(64, 256, 1024, 4096, 20_000, 100_000, 200_000),
            trials=4, iters=30, reps=3)


if __name__ == "__main__":
    main()
