"""Fig. 2: proposed vs polynomial filtering (3 & 7 taps), 200-node topologies.

Paper claims reproduced: RGG — proposed beats 3-tap and ~matches 7-tap;
chain — proposed beats even the 7-tap filter. Tick-for-tick accounting
(one W-multiply per tick; a k-tap filter costs k ticks per application).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import baselines, simulator

from .common import accel_params, emit, inits, paper_setup


def run(n=200, trials=10, iters=600, seed=0):
    rng = np.random.default_rng(seed)
    rows = []
    for topo in ("rgg", "chain"):
        curves = {}
        for _ in range(trials if topo == "rgg" else 1):
            g, w = paper_setup(topo, n, rng)
            th, lam2, a_star = accel_params(w)
            x0 = inits(g, "slope", 1, rng)
            pf3 = baselines.design_poly_filter(w, 3, ridge=1e-12)
            pf7 = baselines.design_poly_filter(w, 7, ridge=1e-9)
            runs = {
                "MH": simulator.simulate(w, x0, iters).mse[:, 0],
                "MH-Proposed": simulator.simulate(
                    w, x0, iters, alpha=a_star, theta=th
                ).mse[:, 0],
                "MH-PolyFilt3": _poly_mse(w, pf3, x0, iters),
                "MH-PolyFilt7": _poly_mse(w, pf7, x0, iters),
            }
            for k, v in runs.items():
                curves.setdefault(k, []).append(v)
        for t in range(0, iters + 1, max(iters // 20, 1)):
            row = {"topology": topo, "tick": t}
            for name, cs in curves.items():
                row[f"mse_{name}"] = float(np.mean([c[t] for c in cs]))
            rows.append(row)
        final = rows[-1]
        print(
            f"fig2[{topo}]: final MSE proposed={final['mse_MH-Proposed']:.3g} "
            f"poly3={final['mse_MH-PolyFilt3']:.3g} poly7={final['mse_MH-PolyFilt7']:.3g}"
        )
    emit("fig2_polyfilt", rows)
    return rows


def _poly_mse(w, pf, x0, ticks):
    _, traj = baselines.run_poly_filter(w, pf, x0[:, 0], ticks, record=True)
    xbar = x0[:, 0].mean()
    d = traj - xbar
    return (d * d).mean(axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200)
    ap.add_argument("--trials", type=int, default=10)
    ap.add_argument("--iters", type=int, default=600)
    a = ap.parse_args()
    run(a.n, a.trials, a.iters)


if __name__ == "__main__":
    main()
