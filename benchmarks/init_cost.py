"""Section III-D / IV: Algorithm-1 initialization accuracy & communication
cost, including the paper's regimes (RGG: K=2N, chain: K=N^2, both L=10),
and the O(K) vs O(K^2) comparison against l2-normalized DOI.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import accel, doi, topology, weights

from .common import emit, paper_setup


def run(seed=0):
    rng = np.random.default_rng(seed)
    rows = []
    for topo, n, k in [("rgg", 100, 200), ("rgg", 200, 400),
                       ("chain", 30, 900), ("chain", 50, 2500)]:
        g, w = paper_setup(topo, n, rng)
        lam2 = accel.lambda2(w)
        res = doi.estimate_lambda2(w, g, num_iters=k, normalize_every=10, rng=rng)
        d = topology.diameter(g.adjacency)
        cost_alg1 = doi.doi_cost(k, 10, d)
        cost_l2_doi = k + (k // 10) * k  # prior art: l2 norms via k-consensus each
        rel = abs(res.lambda2_hat - lam2) / lam2
        # effect of the estimate on the achieved rate
        th = accel.theta_asymptotic(0.5)
        rho_oracle = accel.rho_accel(lam2, th)
        rho_est = accel.rho_accel(min(res.lambda2_hat, 0.99999), th)
        rows.append({
            "topology": topo, "n": n, "K": k, "diameter": d,
            "lambda2": lam2, "lambda2_hat": res.lambda2_hat, "rel_err": rel,
            "ticks_alg1": cost_alg1, "ticks_l2_doi": cost_l2_doi,
            "speedup_vs_l2doi": cost_l2_doi / cost_alg1,
            "rho_oracle": rho_oracle, "rho_with_estimate": rho_est,
        })
        print(f"init[{topo} n={n}]: rel_err={rel:.2e} "
              f"alg1={cost_alg1} ticks vs l2-DOI={cost_l2_doi} "
              f"({cost_l2_doi/cost_alg1:.1f}x cheaper)")
    emit("init_cost", rows)
    return rows


def main():
    argparse.ArgumentParser().parse_args()
    run()


if __name__ == "__main__":
    main()
