"""Robustness figure: does the two-tap memory advantage survive link failures?

The paper optimizes alpha* against one fixed W (Theorem 1); real gossip
fabrics drop links. This benchmark runs the accelerated and memoryless
designs over a per-round Bernoulli link-failure grid (p = 0 ... p_max) on
chain / grid2d / RGG topologies — the whole failure grid as ONE jitted
vmapped scan via the sweep engine's ``dynamics`` axis — and reads off
hitting-time gains per failure probability.

Two effects separate cleanly:

* at p = 0 the accelerated design keeps its full Theorem-3 gain
  (T_MH / T_accel >> 1);
* as p grows, alpha* — still computed for the *nominal* W, which is all a
  deployed node can know — is increasingly mismatched against the effective
  (slower-mixing) random operator, so the gain degrades toward 1.

The degradation curve is monotone by construction of the sampling, not by
luck: failure draws are common-random-number coupled across designs and
*nested* across p (``repro.core.dynamics``), so gain(p) is compared on
identical failure histories.

Emits ``BENCH_fig_robustness.json`` (+ CSV) via ``benchmarks.common.emit``.
CI runs ``--quick`` on the pallas backend so the masked fused kernel is
exercised end to end (interpret mode on CPU).
"""
from __future__ import annotations

import argparse
import math

import numpy as np

from repro.sweep import SweepSpec, build_ensemble, build_round_masks, run_ensemble

from .common import emit

QUICK = dict(p_grid=(0.0, 0.15, 0.3), size=16, graph_trials=2, num_trials=2,
             backend="pallas")


def _iter_cap(ens, eps: float, p_max: float) -> int:
    """Scan length: slowest *nominal* cell + slack for the failure slowdown.

    Bernoulli masking keeps (1-p) of each round's mixing in expectation, so
    the nominal hitting time is inflated by ~1/(1-p) plus a safety margin.
    """
    worst = 0.0
    for c in ens.configs:
        rho = c.rho_memoryless if c.design == "memoryless" else c.rho_accel
        if 0.0 < rho < 1.0:
            worst = max(worst, math.log(eps) / math.log(rho))
    slowdown = 1.0 / max(1.0 - p_max, 1e-3)
    return int(worst * 1.5 * slowdown) + 50


def run(p_grid=(0.0, 0.05, 0.1, 0.2, 0.3), topologies=("chain", "grid2d", "rgg"),
        size=36, graph_trials=3, num_trials=2, eps=1e-3, backend="jax",
        seed=0, num_iters=None):
    dyn_axis = tuple(f"bernoulli:{p}" for p in p_grid)
    spec = SweepSpec(
        topologies=tuple(topologies), sizes=(size,),
        designs=("memoryless", "asymptotic"), dynamics=dyn_axis,
        graph_trials=graph_trials, num_trials=num_trials, init="paper",
        seed=seed,
    )
    ens = build_ensemble(spec)
    cap = num_iters if num_iters is not None else _iter_cap(ens, eps, max(p_grid))
    masks = build_round_masks(ens, cap, seed=seed)
    res = run_ensemble(ens, num_iters=cap, backend=backend, round_masks=masks)
    times = res.averaging_times(eps=eps)                      # (G, F)

    rows = []
    for topo in topologies:
        base_gain = None
        prev_gain = None
        monotone = True
        for k, (p, d) in enumerate(zip(p_grid, dyn_axis)):
            mem = res.cells(topology=topo, design="memoryless", dynamics=d)
            acc = res.cells(topology=topo, design="asymptotic", dynamics=d)
            pairs = [
                (times[i, f], times[j, f])
                for i, j in zip(mem, acc) for f in range(times.shape[1])
                if times[i, f] > 0 and times[j, f] > 0
            ]
            if not pairs:
                # a hole in the curve: the monotonicity claim and (for the
                # first grid point) the gain_rel anchor are both void — flag
                # loudly rather than silently re-anchoring to a later p
                print(f"fig_robustness[{topo} p={p}]: no cell reached eps={eps} "
                      f"within {cap} iters — raise num_iters"
                      + ("; gain_rel baseline missing" if k == 0 else ""))
                monotone = False
                continue
            t_mem = float(np.mean([a for a, _ in pairs]))
            t_acc = float(np.mean([b for _, b in pairs]))
            gain = float(np.mean([a / b for a, b in pairs]))
            if k == 0:
                base_gain = gain            # anchored to p_grid[0] ONLY
            if prev_gain is not None and gain > prev_gain + 1e-9:
                monotone = False
            prev_gain = gain
            rows.append({
                "topology": topo, "n": size, "p": float(p),
                "T_MH": t_mem, "T_accel": t_acc,
                "gain": gain,
                "gain_rel": gain / base_gain if base_gain else float("nan"),
                "gain_asym_nominal": float(np.mean(
                    [res.configs[j].gain_asym for j in acc]
                )),
            })
            print(f"fig_robustness[{topo} n={size} p={p}]: T_MH={t_mem:.0f} "
                  f"T_accel={t_acc:.0f} gain={gain:.2f}")
        print(f"fig_robustness[{topo}]: gain degradation "
              f"{'monotone' if monotone else 'NON-monotone (noise — raise trials)'}")
    emit("fig_robustness", rows)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: toy sizes on the pallas (masked-kernel) path")
    ap.add_argument("--backend", default=None, choices=["jax", "pallas"])
    ap.add_argument("--size", type=int, default=None)
    ap.add_argument("--trials", type=int, default=None, help="graph draws (rgg)")
    a = ap.parse_args(argv)
    kw = dict(QUICK) if a.quick else {}
    if a.backend is not None:
        kw["backend"] = a.backend
    if a.size is not None:
        kw["size"] = a.size
    if a.trials is not None:
        kw["graph_trials"] = a.trials
    run(**kw)


if __name__ == "__main__":
    main()
