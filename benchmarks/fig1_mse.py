"""Fig. 1: MSE vs iterations, 200-node RGGs, Slope & Spike inits.

Algorithms: MH weights; optimized weights (Xiao-Boyd subgradient); proposed
(two-tap accelerated, oracle lambda2); proposed with DECENTRALIZED lambda2
(Algorithm 1, K=2N, L=10); accelerated on top of optimized weights.
Paper claims reproduced: (i) proposed >> memoryless MH/opt; (ii) the
decentralized-estimate curve coincides with the oracle curve.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import accel, doi, metrics, simulator, weights

from .common import accel_params, emit, inits, paper_setup


def run(n=200, trials=20, iters=400, seed=0, opt_iters=120):
    rng = np.random.default_rng(seed)
    rows = []
    for init_kind in ("slope", "spike"):
        curves = {}
        for trial in range(trials):
            g, w = paper_setup("rgg", n, rng)
            w_opt = weights.optimal_weights(g, iters=opt_iters)
            th, lam2, a_star = accel_params(w)
            # Algorithm-1 initialization (paper: K=2N, L=10)
            est = doi.estimate_lambda2(w, g, num_iters=2 * n, normalize_every=10, rng=rng)
            a_est = accel.alpha_star(min(est.lambda2_hat, 0.9999), th)
            th_o, lam2_o, a_o = accel_params(w_opt)
            x0 = inits(g, init_kind, 1, rng)

            runs = {
                "MH": simulator.simulate(w, x0, iters),
                "Opt": simulator.simulate(w_opt, x0, iters),
                "MH-Proposed": simulator.simulate(w, x0, iters, alpha=a_star, theta=th),
                "MH-ProposedEst": simulator.simulate(w, x0, iters, alpha=a_est, theta=th),
                "Opt-Proposed": simulator.simulate(w_opt, x0, iters, alpha=a_o, theta=th_o),
            }
            for name, r in runs.items():
                curves.setdefault(name, []).append(r.mse[:, 0])
        for t in range(0, iters + 1, max(iters // 20, 1)):
            row = {"init": init_kind, "iter": t}
            for name, cs in curves.items():
                row[f"mse_{name}"] = float(np.mean([c[t] for c in cs]))
            rows.append(row)
    emit("fig1_mse_rgg200", rows)
    # headline check: oracle vs decentralized-estimate curves coincide
    last = rows[-1]
    ratio = last["mse_MH-ProposedEst"] / max(last["mse_MH-Proposed"], 1e-300)
    gain = last["mse_MH"] / max(last["mse_MH-Proposed"], 1e-300)
    print(f"fig1: est/oracle final-MSE ratio={ratio:.3g} (1.0 = coincide); "
          f"MH/proposed MSE ratio={gain:.3g}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200)
    ap.add_argument("--trials", type=int, default=20)
    ap.add_argument("--iters", type=int, default=400)
    a = ap.parse_args()
    run(a.n, a.trials, a.iters)


if __name__ == "__main__":
    main()
