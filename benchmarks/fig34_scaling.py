"""Figs. 3 & 4: averaging time (eps = 1e-5) and accelerated/memoryless ratio
vs network size, for RGG and chain topologies.

Paper claims reproduced: the measured T_ave(W)/T_ave(Phi3[alpha*]) ratio
grows with N (chain: ~linearly, Theorem 3 Omega(N); RGG: as 1/sqrt(Psi)),
while polynomial filtering and optimal weights give ~constant-factor gains.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import accel, baselines, metrics

from .common import accel_params, emit, paper_setup


def _avg_time_linear(w, x0, eps):
    xbar = np.full_like(x0, x0.mean())
    return metrics.averaging_time(lambda s: w @ s, x0, xbar, eps=eps)


def _avg_time_accel(w, x0, a, th, eps, cap=2_000_000):
    xbar = np.full_like(x0, x0.mean())
    err0 = np.linalg.norm(x0 - xbar)
    x, xp = x0.copy(), x0.copy()
    for t in range(1, cap):
        x, xp = accel.accelerated_step(w, x, xp, a, th)
        if np.linalg.norm(x - xbar) <= eps * err0:
            return t
    raise RuntimeError("accel averaging did not converge")


def _avg_time_poly(w, pf, x0, eps, cap=2_000_000):
    xbar = np.full_like(x0, x0.mean())
    err0 = np.linalg.norm(x0 - xbar)
    x = x0.copy()
    for t in range(1, cap):
        x = baselines.poly_filter_step(w, pf, x)
        if np.linalg.norm(x - xbar) <= eps * err0:
            return t * pf.ticks_per_apply  # ticks, not super-iterations
    raise RuntimeError("poly averaging did not converge")


def run(kind="both", seed=0, eps=1e-5, rgg_sizes=(50, 100, 150, 200),
        chain_sizes=(20, 40, 60, 80), trials=5):
    rng = np.random.default_rng(seed)
    rows = []
    combos = []
    if kind in ("rgg", "both"):
        combos += [("rgg", n, trials) for n in rgg_sizes]
    if kind in ("chain", "both"):
        combos += [("chain", n, 1) for n in chain_sizes]
    for topo, n, tr in combos:
        acc = {"MH": [], "MH-Proposed": [], "MH-PolyFilt3": [], "gain": []}
        for _ in range(tr):
            g, w = paper_setup(topo, n, rng)
            th, lam2, a_star = accel_params(w)
            x0 = metrics.slope_init(g.coords, n)
            t_mh = _avg_time_linear(w, x0, eps)
            t_acc = _avg_time_accel(w, x0, a_star, th, eps)
            pf3 = baselines.design_poly_filter(w, 3, ridge=1e-12)
            t_p3 = _avg_time_poly(w, pf3, x0, eps)
            acc["MH"].append(t_mh)
            acc["MH-Proposed"].append(t_acc)
            acc["MH-PolyFilt3"].append(t_p3)
            acc["gain"].append(t_mh / t_acc)
        rows.append({
            "topology": topo, "n": n,
            "T_MH": float(np.mean(acc["MH"])),
            "T_proposed": float(np.mean(acc["MH-Proposed"])),
            "T_polyfilt3": float(np.mean(acc["MH-PolyFilt3"])),
            "gain_measured": float(np.mean(acc["gain"])),
            "gain_asym_theory": metrics.processing_gain(
                accel.lambda2(w), accel.rho_accel(accel.lambda2(w), th)
            ),
        })
        print(f"fig34[{topo} n={n}]: T_MH={rows[-1]['T_MH']:.0f} "
              f"T_prop={rows[-1]['T_proposed']:.0f} gain={rows[-1]['gain_measured']:.1f} "
              f"(theory {rows[-1]['gain_asym_theory']:.1f})")
    emit("fig34_scaling", rows)
    # chain gain should scale ~linearly with N (Theorem 3)
    chain = [r for r in rows if r["topology"] == "chain"]
    if len(chain) >= 2:
        g0, g1 = chain[0]["gain_measured"], chain[-1]["gain_measured"]
        n0, n1 = chain[0]["n"], chain[-1]["n"]
        print(f"fig4 scaling: gain({n1})/gain({n0}) = {g1/g0:.2f} "
              f"vs N ratio {n1/n0:.2f} (Theorem 3: Omega(N))")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default="both", choices=["rgg", "chain", "both"])
    ap.add_argument("--trials", type=int, default=5)
    a = ap.parse_args()
    run(kind=a.kind, trials=a.trials)


if __name__ == "__main__":
    main()
