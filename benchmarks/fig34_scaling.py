"""Figs. 3 & 4: averaging time and accelerated/memoryless gain vs network
size, for RGG and chain topologies — on the batched sweep engine.

The whole (topology x size x graph draw) x {memoryless, accelerated} grid is
stacked into one (G, Nmax, Nmax) ensemble and evaluated by a single jitted
vmapped scan (``repro.sweep.engine``); per-cell averaging times are then
read off the returned MSE trajectories. This replaces the per-config python
loops of the seed benchmark: the hardware sees one device-saturating
program instead of hundreds of tiny matvecs.

Paper claims reproduced: the measured T_ave(W)/T_ave(Phi3[alpha*]) ratio
grows with N (chain: ~linearly, Theorem 3 Omega(N); RGG: as 1/sqrt(Psi)),
chain gains track the asymptotic theory curve, and polynomial filtering
(degree-3 baseline of ref [14]) gives only ~constant-factor gains vs N.
The poly baseline rides the same ensemble: one super-iteration is x <- p(W)x,
so the dense operator p(W) enters the grid as an extra 'memoryless' cell and
its hitting times are converted to consensus ticks (x degree).

Accuracy note: the engine iterates in fp32, whose consensus error floors
around mse/mse(0) ~ 1e-8, so the default epsilon here is 1e-3 (threshold
1e-6, two decades of margin) rather than the paper's 1e-5; the gain ratio
is epsilon-insensitive (it converges to the asymptotic rate ratio). The
float64 numpy reference path (``metrics.averaging_time``) remains the
eps=1e-5 oracle and is cross-checked in tests.
"""
from __future__ import annotations

import argparse
import math

import numpy as np

from repro.core import baselines
from repro.sweep import (
    ConfigMeta,
    Ensemble,
    SweepSpec,
    build_ensemble,
    merge_ensembles,
    run_ensemble,
)

from .common import emit

POLY_DEGREE = 3


def _poly_cells(ens: Ensemble, degree: int = POLY_DEGREE) -> Ensemble:
    """One p(W) cell per memoryless cell of ``ens`` (same graph, same x0)."""
    ws, x0s, counts, metas = [], [], [], []
    for i, c in enumerate(ens.configs):
        if c.design != "memoryless":
            continue
        n = c.n
        w = ens.ws[i][:n, :n].astype(np.float64)
        pf = baselines.design_poly_filter(w, degree, ridge=1e-12)
        # dense p(W) by Horner on the matrix (N is benchmark-small)
        op = pf.coeffs[-1] * np.eye(n)
        for j in range(len(pf.coeffs) - 2, -1, -1):
            op = w @ op + pf.coeffs[j] * np.eye(n)
        wp = np.zeros_like(ens.ws[i])
        wp[:n, :n] = op
        ws.append(wp)
        x0s.append(ens.x0[i])
        counts.append(n)
        metas.append(ConfigMeta(
            topology=c.topology, n=n, graph_index=c.graph_index,
            design=f"polyfilt{degree}", theta=None, alpha=0.0, lam2=c.lam2,
            rho_memoryless=pf.rho_filtered, psi=1.0 - pf.rho_filtered,
            rho_accel=pf.rho_filtered,
        ))
    return Ensemble(
        ws=np.stack(ws).astype(np.float32),
        x0=np.stack(x0s),
        coefs=np.tile(np.asarray([[1.0, 0.0, 0.0]], np.float32), (len(ws), 1)),
        node_counts=np.asarray(counts, dtype=np.int64),
        configs=tuple(metas),
    )


def _iter_cap(ens, eps: float) -> int:
    """Theory-derived scan length: slowest cell's hitting time + 30% slack."""
    worst = 0.0
    for c in ens.configs:
        rho = c.rho_memoryless if c.design == "memoryless" else c.rho_accel
        if 0.0 < rho < 1.0:
            worst = max(worst, math.log(eps) / math.log(rho))
    return int(worst * 1.3) + 50


def run(kind="both", seed=0, eps=1e-3, rgg_sizes=(50, 100, 150, 200),
        chain_sizes=(20, 40, 60, 80), trials=5, backend="jax", num_iters=None):
    specs = []
    if kind in ("rgg", "both"):
        specs.append(SweepSpec(topologies=("rgg",), sizes=tuple(rgg_sizes),
                               designs=("memoryless", "asymptotic"),
                               graph_trials=trials, num_trials=1,
                               init="paper", seed=seed))
    if kind in ("chain", "both"):
        specs.append(SweepSpec(topologies=("chain",), sizes=tuple(chain_sizes),
                               designs=("memoryless", "asymptotic"),
                               graph_trials=1, num_trials=1,
                               init="paper", seed=seed))
    ens = merge_ensembles(*[build_ensemble(s) for s in specs])
    ens = merge_ensembles(ens, _poly_cells(ens))
    cap = num_iters if num_iters is not None else _iter_cap(ens, eps)
    res = run_ensemble(ens, num_iters=cap, backend=backend)
    times = res.averaging_times(eps=eps)[:, 0]   # slope-init column

    rows = []
    seen = []
    for topo, n in [(c.topology, c.n) for c in res.configs]:
        if (topo, n) not in seen:
            seen.append((topo, n))
    for topo, n in seen:
        mem = res.cells(topology=topo, n=n, design="memoryless")
        acc = res.cells(topology=topo, n=n, design="asymptotic")
        pol = res.cells(topology=topo, n=n, design=f"polyfilt{POLY_DEGREE}")
        pairs = [
            (times[i], times[j], times[k] * POLY_DEGREE)   # poly: ticks
            for i, j, k in zip(mem, acc, pol)
            if times[i] > 0 and times[j] > 0 and times[k] > 0
        ]
        if not pairs:
            print(f"fig34[{topo} n={n}]: no cell reached eps={eps} "
                  f"within {cap} iters — raise num_iters")
            continue
        t_mh = float(np.mean([p[0] for p in pairs]))
        t_acc = float(np.mean([p[1] for p in pairs]))
        t_pol = float(np.mean([p[2] for p in pairs]))
        gain = float(np.mean([p[0] / p[1] for p in pairs]))
        theory = float(np.mean([res.configs[i].gain_asym for i in acc]))
        rows.append({
            "topology": topo, "n": n,
            "T_MH": t_mh, "T_proposed": t_acc, "T_polyfilt3": t_pol,
            "gain_measured": gain, "gain_asym_theory": theory,
            "gain_polyfilt3": float(np.mean([p[0] / p[2] for p in pairs])),
            "psi": float(np.mean([res.configs[i].psi for i in mem])),
        })
        print(f"fig34[{topo} n={n}]: T_MH={t_mh:.0f} T_prop={t_acc:.0f} "
              f"T_p3={t_pol:.0f} gain={gain:.1f} (theory {theory:.1f})")
    emit("fig34_scaling", rows)

    # chain gain should scale ~linearly with N (Theorem 3)
    chain = [r for r in rows if r["topology"] == "chain"]
    if len(chain) >= 2:
        g0, g1 = chain[0]["gain_measured"], chain[-1]["gain_measured"]
        n0, n1 = chain[0]["n"], chain[-1]["n"]
        print(f"fig4 scaling: gain({n1})/gain({n0}) = {g1/g0:.2f} "
              f"vs N ratio {n1/n0:.2f} (Theorem 3: Omega(N))")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default="both", choices=["rgg", "chain", "both"])
    ap.add_argument("--trials", type=int, default=5)
    ap.add_argument("--backend", default="jax", choices=["jax", "pallas"])
    a = ap.parse_args()
    run(kind=a.kind, trials=a.trials, backend=a.backend)


if __name__ == "__main__":
    main()
