"""Aggregate the dry-run JSONs into the EXPERIMENTS.md SRoofline table."""
from __future__ import annotations

import argparse
import json
import os

from .common import emit


def run(dryrun_dir="experiments/dryrun", mesh="single"):
    rows = []
    if not os.path.isdir(dryrun_dir):
        print(f"(no dry-run results at {dryrun_dir} yet)")
        return rows
    for fname in sorted(os.listdir(dryrun_dir)):
        if not fname.endswith(".json") or f"__{mesh}__" not in fname:
            continue
        with open(os.path.join(dryrun_dir, fname)) as f:
            rec = json.load(f)
        if rec.get("status") == "skipped":
            rows.append({
                "arch": rec["arch"], "shape": rec["shape"], "status": "skipped",
                "bound": "-", "compute_s": "-", "memory_s": "-",
                "collective_s": "-", "roofline_frac": "-", "hbm_GiB": "-",
                "useful_flop_ratio": "-",
            })
            continue
        if rec.get("status") != "ok":
            rows.append({
                "arch": rec.get("arch"), "shape": rec.get("shape"),
                "status": rec.get("status"), "bound": "-", "compute_s": "-",
                "memory_s": "-", "collective_s": "-", "roofline_frac": "-",
                "hbm_GiB": "-", "useful_flop_ratio": "-",
            })
            continue
        r = rec["roofline"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
            "bound": r["bound"],
            "compute_s": f"{r['compute_s']:.3e}",
            "memory_s": f"{r['memory_s']:.3e}",
            "collective_s": f"{r['collective_s']:.3e}",
            "roofline_frac": f"{r.get('roofline_fraction', 0):.4f}",
            "hbm_GiB": f"{rec['memory']['total_hbm_bytes']/2**30:.1f}",
            "useful_flop_ratio": f"{r.get('useful_flop_ratio', 0):.2f}",
        })
    emit(f"roofline_{mesh}", rows)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    a = ap.parse_args()
    run(a.dir, a.mesh)


if __name__ == "__main__":
    main()
