"""Directed & lossy figure: push-sum family vs memoryless on digraphs.

Row-stochastic gossip on a directed graph converges to the Perron-weighted
mixture of the initial values, not the average — the drift is structural,
not noise. The push-sum family (``push_sum``, ``ratio_consensus:c``) runs a
column-stochastic (value, mass) pair and displays their ratio, recovering
the true average on any strongly connected digraph, and — with the engine's
sender-side mask re-normalization — under i.i.d. link loss too.

This benchmark runs the three algorithms over the ``directed`` family
(directed-ring backbone + random extra arcs) under static and Bernoulli
lossy dynamics as ONE jitted sweep, and reports per-cell final error
against the true average plus sustained eps-averaging times. A warmed
whole-grid timing row (``sweep_directed_*``, mode-tagged) keeps the lane
comparable under the perf gate's like-for-like rules.

Emits ``BENCH_fig_directed.json`` (+ CSV) via ``benchmarks.common.emit``.
CI runs ``--quick`` on the pallas backend, which exercises the dense
sender-renorm fallback seam inside the jitted scan end to end.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.kernels import ops
from repro.sweep import SweepSpec, build_ensemble, build_round_masks, run_ensemble

from .common import emit

ALGORITHMS = ("memoryless", "push_sum", "ratio_consensus:0.5")
DYNAMICS = ("static", "bernoulli:0.1")

QUICK = dict(size=16, graph_trials=2, num_trials=2, num_iters=300,
             backend="pallas")


def run(size=32, graph_trials=3, num_trials=2, num_iters=800, eps=1e-3,
        backend="jax", seed=0):
    spec = SweepSpec(
        topologies=("directed",), sizes=(size,), designs=("memoryless",),
        algorithms=ALGORITHMS, dynamics=DYNAMICS,
        graph_trials=graph_trials, num_trials=num_trials,
        layout="dense", init="paper", seed=seed,
    )
    ens = build_ensemble(spec)
    masks = build_round_masks(ens, num_iters, seed=seed)

    def _go():
        return run_ensemble(ens, num_iters=num_iters, backend=backend,
                            round_masks=masks)

    res = _go()                         # warm: trace + compile
    t0 = time.perf_counter()
    res = _go()
    us = (time.perf_counter() - t0) * 1e6
    times = res.averaging_times(eps=eps, sustained=True)      # (G, F)
    err = np.sqrt(np.maximum(res.mse[:, -1, :], 0.0))         # (G, F) rel err

    pallas_mode = "pallas-interpret" if ops.use_interpret() else "compiled"
    mode = pallas_mode if backend == "pallas" else "compiled"
    nan = float("nan")
    rows = []
    for algo in ALGORITHMS:
        for d in DYNAMICS:
            idx = res.cells(algorithm=algo, dynamics=d)
            e = float(np.mean([err[i, f] for i in idx
                               for f in range(err.shape[1])]))
            hits = [times[i, f] for i in idx for f in range(times.shape[1])
                    if times[i, f] >= 0]
            frac = len(hits) / (len(idx) * times.shape[1])
            t_avg = float(np.mean(hits)) if hits else -1.0
            rows.append({
                "bench": f"directed_{algo}_{d}", "algorithm": algo,
                "dynamics": d, "n": size, "err_final": e,
                "frac_converged": frac, "t_avg": t_avg,
                "mode": mode, "us_per_call": nan,
            })
            print(f"fig_directed[{algo} {d} n={size}]: err={e:.2e} "
                  f"converged={frac:.0%} t_avg={t_avg:.0f}")
    rows.append({
        "bench": f"sweep_directed_{backend}_G{ens.num_configs}x{num_iters}it",
        "algorithm": "all", "dynamics": "all", "n": size,
        "err_final": nan, "frac_converged": nan, "t_avg": nan,
        "mode": mode, "us_per_call": us,
    })
    emit("fig_directed", rows)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: toy sizes on the pallas backend")
    ap.add_argument("--backend", default=None, choices=["jax", "pallas"])
    ap.add_argument("--size", type=int, default=None)
    a = ap.parse_args(argv)
    kw = dict(QUICK) if a.quick else {}
    if a.backend is not None:
        kw["backend"] = a.backend
    if a.size is not None:
        kw["size"] = a.size
    run(**kw)


if __name__ == "__main__":
    main()
