"""Shared benchmark scaffolding: trial runners + CSV/JSON emit."""
from __future__ import annotations

import json
import os
import platform
import time

import numpy as np

from repro.core import accel, metrics, topology, weights

ROOT_DIR = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
OUT_DIR = os.path.join(ROOT_DIR, "experiments", "bench")


def ensure_out() -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    return OUT_DIR


def emit(name: str, rows: list[dict]) -> None:
    """Print CSV to stdout; save <name>.csv + BENCH_<name>.json artifacts.

    The JSON mirror (rows + environment stamp) is what CI uploads as a
    workflow artifact, so the perf trajectory accumulates across commits.
    """
    if not rows:
        return
    cols = list(rows[0])
    lines = [",".join(cols)]
    for r in rows:
        lines.append(",".join(_fmt(r[c]) for c in cols))
    text = "\n".join(lines)
    print(f"### {name}")
    print(text)
    out = ensure_out()
    with open(os.path.join(out, f"{name}.csv"), "w") as f:
        f.write(text + "\n")
    import jax

    payload = {
        "bench": name,
        "unix_time": time.time(),
        "env": {
            "python": platform.python_version(),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "commit": os.environ.get("GITHUB_SHA", ""),
        },
        "rows": rows,
    }
    # JSON lands BOTH under experiments/bench/ and at the repo root: the
    # perf tracker reads the root-level BENCH_*.json trajectory, which an
    # experiments/-only emit left permanently empty.
    for d in (out, ROOT_DIR):
        with open(os.path.join(d, f"BENCH_{name}.json"), "w") as f:
            json.dump(payload, f, indent=1, default=float)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def paper_setup(kind: str, n: int, rng: np.random.Generator):
    """(graph, W_MH) for the paper's two scenarios."""
    g = topology.random_geometric(n, rng) if kind == "rgg" else topology.chain(n)
    w = weights.metropolis_hastings(g)
    return g, w


def inits(g, kind: str, trials: int, rng: np.random.Generator) -> np.ndarray:
    """(N, trials) initial columns: Slope (deterministic) + Spike per trial."""
    n = g.n
    cols = []
    for t in range(trials):
        if kind == "slope":
            x = metrics.slope_init(g.coords, n)
        else:
            x = metrics.spike_init(n, node=int(rng.integers(0, n)))
        cols.append(x)
    return np.stack(cols, axis=1)


def accel_params(w, theta=None):
    theta = theta or accel.theta_asymptotic(0.5)
    lam2 = accel.lambda2(w)
    return theta, lam2, accel.alpha_star(lam2, theta)


def timer():
    t0 = time.perf_counter()
    return lambda: (time.perf_counter() - t0) * 1e6  # us
