"""Pass 2: the one-compilation contract.

``run_batch`` stakes its throughput on compiling a mixed-algorithm grid to
ONE fused scan per backend: every partition's round is inlined into a single
``lax.scan`` body, traced once. Two statically-checkable ways to lose that:

- the full-grid program contains more (or fewer) than one ``scan`` — some
  layer wrapped rounds in its own loop, or a partition escaped the fused
  body (rule ``scan-count``);
- a ``round_body`` concretizes the traced tick index (Python ``if t % k``,
  ``int(t)`` …): under the real scan that's a trace error, and the only
  "fix" — unrolling per tick — fragments the partition into per-tick
  compilations (rule ``retrace-fragmentation``). We catch it by re-tracing
  each round body with an ABSTRACT int32 tick, exactly the engine's view.

Everything is ``jax.make_jaxpr`` tracing; nothing compiles or runs.
"""

from __future__ import annotations

import jax

from .findings import AnalysisFinding, algo_finding, source_of
from . import trace_utils as tu

PASS = "trace-compile"

_CONCRETIZATION = (
    jax.errors.ConcretizationTypeError,
    jax.errors.TracerArrayConversionError,
    jax.errors.TracerBoolConversionError,
    jax.errors.TracerIntegerConversionError,
)


def _engine_finding(rule, severity, message, backend):
    from repro.sweep import engine

    file, line = source_of(engine.run_batch)
    return AnalysisFinding(
        rule=rule, severity=severity, message=message,
        obj=f"sweep.engine[{backend}]", file=file, line=line, passname=PASS)


def check_compilation(algorithms=None):
    from repro.core.algorithms import get_algorithm, registered_algorithms

    specs = tuple(algorithms or registered_algorithms())
    findings: list[AnalysisFinding] = []

    # (a) per-registration: the round body must trace under an abstract tick.
    # Bodies that can't are excluded from the grid census below — the whole
    # grid would fail to trace for the same root cause, and one finding per
    # defect beats a cascade.
    traceable = []
    for spec in specs:
        algo = get_algorithm(spec)
        ens = tu.probe_ensemble(algo.spec)
        try:
            tu.trace_round_body(algo, ens, 0, abstract_t=True)
            traceable.append(spec)
        except _CONCRETIZATION as exc:
            findings.append(algo_finding(
                "retrace-fragmentation", "error",
                "round_body concretizes the traced tick index (Python "
                "control flow on t): under the engine scan this is a trace "
                "error, and unrolling it fragments the partition into "
                f"per-tick compilations ({type(exc).__name__})", algo, PASS))
        except Exception as exc:
            findings.append(algo_finding(
                "round-trace-failed", "error",
                f"round_body failed to trace with an abstract tick: {exc}",
                algo, PASS))

    # (b) whole-grid scan census per backend
    for backend in ("jax", "pallas") if traceable else ():
        try:
            closed = tu.trace_engine(tuple(traceable), backend)
        except Exception as exc:
            findings.append(_engine_finding(
                "engine-trace-failed", "error",
                f"mixed grid over {traceable} failed to trace: {exc}",
                backend))
            continue
        n_scan = tu.count_primitive(closed.jaxpr, "scan")
        if n_scan != 1:
            findings.append(_engine_finding(
                "scan-count", "error",
                f"grid over {len(traceable)} algorithm(s) traced to "
                f"{n_scan} scan eqns (the one-compilation contract requires "
                f"exactly 1 fused scan per backend)", backend))
    return findings
