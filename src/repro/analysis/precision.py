"""Pass 4: precision lints over the jitted scan bodies.

The sweep contract is float32 end to end: states, weights and MSE tails are
f32, and the only sanctioned low-precision surface is the compression wire
in ``repro.dist`` (stochastic-rounding bfloat16 on the gossip exchange).
Two statically-detectable ways to break that:

- ``weak-f64-promotion`` (error): a Python float closing over a round body
  is weakly typed; under ``jax.experimental.enable_x64`` it promotes the
  whole chain to float64 — 2x memory, several-x slower, and silently
  different roundoff between x64-enabled hosts and default ones. We trace
  each round body (and the full engine scan) INSIDE ``enable_x64()`` with
  f32 operands: any f64 eqn output that is not an explicit cast is a
  promotion leak.
- ``bf16-accumulation`` (error): a bfloat16 (or fp16) array inside the
  engine scan body — accumulating consensus state at 8-bit mantissa breaks
  the paper's convergence-rate measurements. Only the dist wire may hold
  bf16, and it never appears inside ``_sweep_scan``.

Tracing only — ``enable_x64`` changes promotion semantics at trace time,
nothing executes.
"""

from __future__ import annotations

import jax
import numpy as np

from .findings import AnalysisFinding, algo_finding, source_of
from . import trace_utils as tu

PASS = "precision"


def _f64_eqns(closed):
    """Eqns carrying float64/complex128 outputs anywhere in the body.

    The f32 policy admits NO 64-bit float values inside a round body, so
    presence is the lint — no provenance analysis needed (promotion chains
    start with an auto-inserted convert, which this also catches). int64 is
    deliberately exempt: index arithmetic legitimately widens under x64.
    """
    hits = []
    for eqn, _ in tu.iter_eqns(closed.jaxpr):
        if eqn.primitive.name in ("pjit", "scan", "custom_partitioning",
                                  "pallas_call", "while", "cond"):
            continue  # containers: their inner eqns are walked anyway
        if any(str(getattr(v.aval, "dtype", "")) in ("float64", "complex128")
               for v in eqn.outvars):
            hits.append(eqn)
    return hits


def _low_prec_vars(closed):
    hits = []
    for eqn, _ in tu.iter_eqns(closed.jaxpr):
        for v in eqn.outvars:
            dt = str(getattr(v.aval, "dtype", ""))
            if dt in ("bfloat16", "float16"):
                hits.append((eqn, dt))
    return hits


def check_precision(algorithms=None):
    from repro.core.algorithms import get_algorithm, registered_algorithms

    specs = tuple(algorithms or registered_algorithms())
    findings: list[AnalysisFinding] = []

    # per-registration: round body traced under x64 semantics on f32 operands
    with jax.experimental.enable_x64():
        for spec in specs:
            algo = get_algorithm(spec)
            ens = tu.probe_ensemble(algo.spec)
            try:
                closed = tu.trace_round_body(algo, ens, 0, abstract_t=True)
            except Exception:
                continue  # untraceable bodies are pass-2 findings
            hits = _f64_eqns(closed)
            if hits:
                prims = sorted({e.primitive.name for e in hits})
                findings.append(algo_finding(
                    "weak-f64-promotion", "error",
                    f"round_body promotes to float64 under x64 semantics "
                    f"({len(hits)} eqn(s): {', '.join(prims)}) — a weakly "
                    f"typed Python scalar is widening the f32 state chain",
                    algo, PASS))
            low = _low_prec_vars(closed)
            if low:
                dts = sorted({dt for _, dt in low})
                findings.append(algo_finding(
                    "bf16-accumulation", "error",
                    f"round_body carries {'/'.join(dts)} intermediates "
                    f"({len(low)} value(s)) — consensus state must stay "
                    f"f32; only the dist compression wire may narrow",
                    algo, PASS))

    # engine-wide: the jax-backend scan body must be bf16/fp16-free
    try:
        closed = tu.trace_engine(specs, "jax")
    except Exception:
        return findings  # engine-trace failures are pass-2 findings
    low = _low_prec_vars(closed)
    if low:
        from repro.sweep import engine

        file, line = source_of(engine.run_batch)
        dts = sorted({dt for _, dt in low})
        findings.append(AnalysisFinding(
            rule="bf16-accumulation", severity="error",
            message=f"engine scan contains {'/'.join(dts)} intermediates "
            f"({len(low)} value(s)) outside the dist compression wire",
            obj="sweep.engine[jax]", file=file, line=line, passname=PASS))
    return findings
