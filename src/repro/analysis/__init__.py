"""Static verification of the consensus-engine contracts (no rounds run).

Four jaxpr-level passes over the live registry and the sweep engine:

- ``coefficient`` — per-registration coefficient-mass proof: the display
  state's node mean (mean family) / every tap's total (mass family) must be
  an exact convex recombination tick over tick, or the average itself
  drifts (the failure mode that motivates push-sum in lossy settings).
- ``compilation`` — the one-compilation contract: a full mixed-algorithm
  grid traces to exactly one ``scan`` per backend, and no round body
  concretizes traced values (which would fragment the grid into per-cell
  retraces).
- ``meshkernel`` — every ``pallas_call`` reachable under a mesh context is
  behind the ``custom_partitioning`` rule from ``kernels/ops.py`` (an
  unwrapped kernel is silently REPLICATED by GSPMD: every device runs the
  full global grid), plus BlockSpec tile divisibility and the
  ``segment_bn`` VMEM budget against declared shapes.
- ``precision`` — no weak-type float64 promotions or stray bfloat16
  accumulation inside the jitted scan bodies (the compression wire in
  ``repro.dist`` is the only sanctioned low-precision surface).

Everything here inspects jaxprs built with ``jax.make_jaxpr`` /
``jax.eval_shape`` — tracing only, nothing is compiled or executed; the
instrumented round primitive hard-fails if anything tries. Entry points:
``run_all_checks()`` (the CLI / CI lane) and ``verify_static(spec)`` (one
registration, for authors — also re-exported by ``core.algorithms``).
"""

from .findings import AnalysisFinding, has_errors, render_markdown, render_text
from .coefficient import check_coefficient_mass
from .compilation import check_compilation
from .meshkernel import check_mesh_kernels
from .precision import check_precision

__all__ = [
    "AnalysisFinding",
    "check_coefficient_mass",
    "check_compilation",
    "check_mesh_kernels",
    "check_precision",
    "has_errors",
    "render_markdown",
    "render_text",
    "run_all_checks",
    "verify_static",
]

# Pass registry, in report order. Each entry is (pass name, callable taking
# an optional tuple of algorithm specs and returning list[AnalysisFinding]).
PASSES = (
    ("coefficient-mass", check_coefficient_mass),
    ("trace-compile", check_compilation),
    ("mesh-kernel", check_mesh_kernels),
    ("precision", check_precision),
)


def run_all_checks(algorithms=None) -> list[AnalysisFinding]:
    """Run every pass over ``algorithms`` (default: the whole registry)."""
    findings: list[AnalysisFinding] = []
    for _, check in PASSES:
        findings.extend(check(algorithms))
    return findings


def verify_static(spec) -> list[AnalysisFinding]:
    """Statically verify ONE registration (algorithm-scoped passes only).

    Runs the coefficient-mass, trace/compile and precision passes restricted
    to ``spec``; the engine-wide mesh/kernel pass additionally runs when the
    registration overrides ``pallas_round`` (the only per-algorithm kernel
    surface). Returns the findings list — empty means the registration
    holds every statically-checkable contract.
    """
    from repro.core.algorithms import get_algorithm

    algo = get_algorithm(spec)
    specs = (algo.spec,)
    findings = list(check_coefficient_mass(specs))
    findings.extend(check_compilation(specs))
    findings.extend(check_precision(specs))
    if algo.pallas_round is not None:
        findings.extend(check_mesh_kernels(specs))
    return findings
