"""Pass 1: coefficient-mass verification.

The engine's fused round contract is ``out = a*(W_eff @ x) + b*x + c*xp``.
With a mass-preserving base (doubly stochastic for the mean family,
column-stochastic for the mass family) the network statistic obeys
``m(out) = (a+b)*m(x) + c*m(xp)`` — so the statistic survives a round iff
the coefficients recombine convexly. This pass proves that symbolically:
each ``round_body`` is traced through the recording primitive at concrete
ticks t = 0..T-1 (so periodic phase logic resolves), and a small abstract
interpreter propagates *mass linear forms* through the jaxpr:

- ``Known`` (a numpy array): concrete values — design coefficients, tick
  literals, anything computable without state.
- ``Lin``: a linear form ``sum_s c_s * m_s`` over initial-carry symbols,
  with per-cell (G,) coefficient vectors. Mean-family tap slots all start
  as the same ``x0``, so they share one symbol (``xbar``); mass-family
  taps each carry their own (``tap_i`` — value and weight are distinct
  conserved quantities); aux slots get opaque symbols.
- ``UNKNOWN``: anything nonlinear in state (norm estimates, ratios).

Checks per tick: mean family — the display form must be exactly
``{xbar: 1}`` (±``TOL``); mass family — every tap slot's form must be
``{tap_i: 1}``. Call sites whose coefficient operand is itself traced
(adaptive streams) cannot be proven here: they are recorded, reported as
``coef-mass-traced`` (info), and handed to the runtime twin
(``run_sweep(..., debug_checks=True)``) via ``traced_coef_sites``.

Rules: ``coef-mass`` (error), ``coef-base-stochastic`` (error),
``coef-mass-unproven`` (warning), ``coef-mass-traced`` (info).
"""

from __future__ import annotations

import functools

import numpy as np

from .findings import AnalysisFinding, algo_finding
from . import trace_utils as tu

PASS = "coefficient-mass"
TOL = 1e-4
BASE_TOL = 1e-5
PROBE_TICKS = 12

XBAR = "xbar"

_UNKNOWN = object()


class Lin:
    """Linear form over initial-carry symbols; coefficients are (G,) arrays."""

    __slots__ = ("c",)

    def __init__(self, c):
        self.c = {k: np.asarray(v, np.float64) for k, v in c.items()
                  if np.any(np.asarray(v) != 0)}

    def scale(self, k):
        return Lin({s: v * k for s, v in self.c.items()})

    def add(self, other, sign=1.0):
        out = dict(self.c)
        for s, v in other.c.items():
            out[s] = out.get(s, 0.0) + sign * v
        return Lin(out)

    def coeff(self, sym, g):
        return np.asarray(self.c.get(sym, np.zeros(g)), np.float64)


def _lin_equal(a: Lin, b: Lin) -> bool:
    syms = set(a.c) | set(b.c)
    g = max((np.size(v) for v in (*a.c.values(), *b.c.values())), default=1)
    return all(
        np.allclose(a.coeff(s, g), b.coeff(s, g), atol=1e-7) for s in syms)


def _per_cell(val, out_shape, g):
    """(G,) per-cell scalars of ``val`` when it is cell-uniform, else None.

    ``val`` must broadcast to ``out_shape`` and be constant within each cell
    (node/trial axes) — the condition under which scaling a state array
    scales its per-cell statistic linearly.
    """
    try:
        k = np.broadcast_to(np.asarray(val, np.float64), out_shape)
    except ValueError:
        return None
    if not out_shape or out_shape[0] != g:
        if np.all(k == k.flat[0]):        # global scalar
            return np.full(g, k.flat[0])
        return None
    k = k.reshape(g, -1)
    if k.shape[1] and np.all(k == k[:, :1]):
        return k[:, 0].copy()
    return None


class MassInterp:
    """One-tick jaxpr interpreter propagating Known / Lin / UNKNOWN."""

    def __init__(self, g: int):
        self.g = g
        self.traced_sites: list[int] = []
        self.call_idx = 0

    # -- environment ------------------------------------------------------
    def _read(self, env, atom):
        if hasattr(atom, "val"):                       # Literal
            return np.asarray(atom.val)
        return env.get(atom, _UNKNOWN)

    def run(self, closed, in_vals):
        env = {}
        for var, c in zip(closed.jaxpr.constvars, closed.consts):
            env[var] = np.asarray(c)
        for var, v in zip(closed.jaxpr.invars, in_vals):
            if v is not None:
                env[var] = v
        self._run_jaxpr(closed.jaxpr, env)
        return [self._read(env, v) for v in closed.jaxpr.outvars]

    def _run_jaxpr(self, jaxpr, env):
        for eqn in jaxpr.eqns:
            vals = [self._read(env, v) for v in eqn.invars]
            outs = self._eqn(eqn, vals, env)
            if not isinstance(outs, (list, tuple)):
                outs = [outs]
            for var, v in zip(eqn.outvars, outs):
                env[var] = v

    # -- primitive rules --------------------------------------------------
    def _eqn(self, eqn, vals, env):
        name = eqn.primitive.name
        if name == tu.ANALYSIS_PRIM_NAME:
            return self._prim_rule(*vals)
        if name == "pjit":
            inner = eqn.params["jaxpr"]
            sub = MassInterp(self.g)
            sub.call_idx = self.call_idx
            outs = sub.run(inner, vals)
            self.call_idx = sub.call_idx
            self.traced_sites.extend(sub.traced_sites)
            return outs
        if all(isinstance(v, np.ndarray) for v in vals):
            return self._concrete(eqn, vals)
        out_shape = eqn.outvars[0].aval.shape
        if name == "add":
            return self._add(vals[0], vals[1], 1.0, out_shape)
        if name == "sub":
            return self._add(vals[0], vals[1], -1.0, out_shape)
        if name == "neg" and isinstance(vals[0], Lin):
            return vals[0].scale(-1.0)
        if name == "mul":
            return self._mul(vals[0], vals[1], out_shape)
        if name == "div":
            num, den = vals
            if isinstance(num, Lin) and isinstance(den, np.ndarray):
                k = _per_cell(den, out_shape, self.g)
                if k is not None and np.all(k != 0):
                    return num.scale(1.0 / k)
            return _UNKNOWN
        if name in ("convert_element_type", "copy", "reshape",
                    "stop_gradient") and isinstance(vals[0], Lin):
            return vals[0]
        if name == "select_n":
            return self._select(vals[0], vals[1:], out_shape)
        return [_UNKNOWN] * len(eqn.outvars)

    def _concrete(self, eqn, vals):
        import jax.numpy as jnp
        try:
            subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
            out = eqn.primitive.bind(
                *subfuns, *[jnp.asarray(v) for v in vals], **bind_params)
        except Exception:
            return [_UNKNOWN] * len(eqn.outvars)
        if eqn.primitive.multiple_results:
            return [np.asarray(o) for o in out]
        return np.asarray(out)

    def _add(self, a, b, sign, out_shape):
        if isinstance(a, Lin) and isinstance(b, Lin):
            return a.add(b, sign)
        lin, kn = (a, b) if isinstance(a, Lin) else (b, a)
        if isinstance(lin, Lin) and isinstance(kn, np.ndarray) \
                and np.all(kn == 0):
            return lin if lin is a or sign > 0 else lin.scale(sign)
        return _UNKNOWN

    def _mul(self, a, b, out_shape):
        lin, kn = (a, b) if isinstance(a, Lin) else (b, a)
        if not isinstance(lin, Lin) or not isinstance(kn, np.ndarray):
            return _UNKNOWN
        k = _per_cell(kn, out_shape, self.g)
        return lin.scale(k) if k is not None else _UNKNOWN

    def _select(self, pred, cases, out_shape):
        if isinstance(pred, np.ndarray) and np.all(pred == pred.flat[0]) \
                and 0 <= int(pred.flat[0]) < len(cases):
            return cases[int(pred.flat[0])]
        lins = [c for c in cases if isinstance(c, Lin)]
        if len(lins) == len(cases) and all(
                _lin_equal(lins[0], c) for c in lins[1:]):
            return lins[0]
        return _UNKNOWN

    def _prim_rule(self, x, xp, coef):
        idx = self.call_idx
        self.call_idx += 1
        if isinstance(coef, np.ndarray):
            rows = coef.reshape(-1, coef.shape[-1])
            if rows.shape[0] != self.g or rows.shape[1] < 3:
                return _UNKNOWN
            a, b, c = (rows[:, i].astype(np.float64) for i in range(3))
            if isinstance(x, Lin) and isinstance(xp, Lin):
                return x.scale(a + b).add(xp.scale(c))
            return _UNKNOWN
        # traced coefficient stream: statically unprovable — record the
        # site for the runtime twin. When both taps carry the SAME form,
        # any affine recombination with mass 1 returns that form, so we
        # propagate it under the (runtime-checked) convexity assumption.
        self.traced_sites.append(idx)
        if isinstance(x, Lin) and isinstance(xp, Lin) and _lin_equal(x, xp):
            return Lin(dict(x.c))
        return _UNKNOWN


# ---------------------------------------------------------------------------
# Per-registration driver.
# ---------------------------------------------------------------------------

def _initial_forms(algo, n_slots, g):
    forms = []
    for i in range(n_slots):
        if i >= algo.num_taps:
            forms.append(Lin({f"aux{i}": np.ones(g)}))
        elif algo.invariant == "mass":
            forms.append(Lin({f"tap{i}": np.ones(g)}))
        else:
            forms.append(Lin({XBAR: np.ones(g)}))
    return forms


def _step(algo, ens, t, forms):
    """One symbolic tick: returns (new forms, traced site indices)."""
    closed = tu.trace_round_body(algo, ens, t)
    g = ens.x0.shape[0]
    interp = MassInterp(g)
    coefs = np.asarray(ens.coefs, np.float32)
    outs = interp.run(closed, [coefs, *forms])
    outs = [o if isinstance(o, (Lin, np.ndarray)) else _UNKNOWN for o in outs]
    return outs, interp.traced_sites


def _display_form(algo, ens, forms):
    import jax

    carry = tu.carry_structs(algo, ens)
    closed = jax.make_jaxpr(lambda c: algo.display(c))(carry)
    interp = MassInterp(ens.x0.shape[0])
    out = interp.run(closed, list(forms))
    return out[0]


def _check_base(algo, ens):
    """The prim rule assumes a mass-preserving base — verify numerically."""
    if ens.ws is None:
        return []
    ws = np.asarray(ens.ws, np.float64)
    col = np.abs(ws.sum(axis=1) - 1.0).max()
    row = np.abs(ws.sum(axis=2) - 1.0).max()
    bad = (col > BASE_TOL or row > BASE_TOL) if algo.invariant == "mean" \
        else col > BASE_TOL
    if bad:
        need = "doubly" if algo.invariant == "mean" else "column"
        return [algo_finding(
            "coef-base-stochastic", "error",
            f"probe base matrices are not {need}-stochastic "
            f"(max column-sum dev {col:.2e}, row {row:.2e}): the "
            f"coefficient-mass contract has no base to preserve", algo,
            PASS)]
    return []


def check_algorithm(algo) -> list[AnalysisFinding]:
    ens = tu.probe_ensemble(algo.spec)
    g = ens.x0.shape[0]
    findings = _check_base(algo, ens)

    n_slots = len(tu.carry_structs(algo, ens))
    forms = _initial_forms(algo, n_slots, g)
    traced: set[int] = set()
    for t in range(PROBE_TICKS):
        forms, sites = _step(algo, ens, t, forms)
        traced.update(sites)
        if algo.invariant == "mass":
            for i in range(algo.num_taps):
                f = forms[i] if i < len(forms) else _UNKNOWN
                if not isinstance(f, Lin):
                    findings.append(algo_finding(
                        "coef-mass-unproven", "warning",
                        f"tap {i} mass not statically provable at tick {t} "
                        f"(nonlinear or traced update)", algo, PASS))
                    return findings
                dev = max(
                    np.abs(f.coeff(f"tap{i}", g) - 1.0).max(),
                    max((np.abs(v).max() for s, v in f.c.items()
                         if s != f"tap{i}"), default=0.0))
                if dev > TOL:
                    findings.append(algo_finding(
                        "coef-mass", "error",
                        f"tap {i} leaks mass at tick {t}: composed form "
                        f"deviates from identity by {dev:.2e} (> {TOL:g})",
                        algo, PASS))
                    return findings
        else:
            d = _display_form(algo, ens, forms)
            if not isinstance(d, Lin):
                findings.append(algo_finding(
                    "coef-mass-unproven", "warning",
                    f"display mean not statically provable at tick {t} "
                    f"(nonlinear or traced update)", algo, PASS))
                return findings
            dev = max(
                np.abs(d.coeff(XBAR, g) - 1.0).max(),
                max((np.abs(v).max() for s, v in d.c.items() if s != XBAR),
                    default=0.0))
            if dev > TOL:
                findings.append(algo_finding(
                    "coef-mass", "error",
                    f"coefficient mass leaks at tick {t}: display mean is "
                    f"{'+'.join(f'{v.max():.4f}*{s}' for s, v in sorted(d.c.items()))} "
                    f"(deviation {dev:.2e} > {TOL:g}) — the consensus value "
                    f"drifts from the true average", algo, PASS))
                return findings
    if traced:
        findings.append(algo_finding(
            "coef-mass-traced", "info",
            f"{len(traced)} round-prim site(s) take a traced coefficient "
            f"stream (statically assumed convex); covered at runtime by "
            f"run_sweep(debug_checks=True)", algo, PASS))
    return findings


def check_coefficient_mass(algorithms=None) -> list[AnalysisFinding]:
    from repro.core.algorithms import get_algorithm, registered_algorithms

    findings = []
    for spec in (algorithms or registered_algorithms()):
        algo = get_algorithm(spec)
        try:
            findings.extend(check_algorithm(algo))
        except Exception as exc:  # a body that won't even trace is a finding
            findings.append(algo_finding(
                "coef-trace-failed", "error",
                f"round_body failed to trace abstractly: {exc}", algo, PASS))
    return findings


# ---------------------------------------------------------------------------
# Runtime-twin support: which prim call sites carry traced coefficients.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=128)
def _traced_sites_cached(spec_str: str, generation: int) -> frozenset:
    del generation
    from repro.core.algorithms import get_algorithm

    algo = get_algorithm(spec_str)
    ens = tu.probe_ensemble(spec_str)
    g = ens.x0.shape[0]
    n_slots = len(tu.carry_structs(algo, ens))
    forms = _initial_forms(algo, n_slots, g)
    traced: set[int] = set()
    for t in range(PROBE_TICKS):
        forms, sites = _step(algo, ens, t, forms)
        traced.update(sites)
    return frozenset(traced)


def traced_coef_sites(spec_str: str) -> frozenset:
    """Indices (round_body call order) of prim sites with traced coefs.

    Computed with CONCRETE ticks, so merely tick-dependent coefficient
    gathers (poly_filter's Horner taps — individually non-convex by design,
    proven via the held display instead) do NOT qualify; only genuinely
    data-dependent streams (adaptive estimators) do. The engine's
    ``debug_checks`` twin attaches a checkify coefficient-mass guard at
    exactly these sites — the sites where the static pass had to ASSUME
    convexity rather than prove it.
    """
    from repro.core.algorithms import registry_generation

    return _traced_sites_cached(str(spec_str), registry_generation())
