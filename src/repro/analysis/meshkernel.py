"""Pass 3: mesh/kernel contracts.

Traces the engine's pallas program the way a MESH run lowers it
(``force_mesh_dispatch`` routes the batched-round prim builders through
their ``custom_partitioning`` wrappers even on a one-device analysis host)
and checks, per ``pallas_call`` equation:

- ``mesh-unwrapped-kernel`` (error): the call is NOT nested under a
  ``custom_partitioning`` eqn. GSPMD has no partitioning rule for an opaque
  pallas call, so it silently REPLICATES it — every device runs the full
  global grid and the mesh buys nothing (or worse, produces wrong shards).
- ``kernel-tile-divisibility`` (error): a BlockSpec tile does not divide
  its operand extent — the kernel would read OOB-masked garbage or the
  lowering would fail at compile time, long after the sweep was scheduled.
- ``kernel-vmem-budget`` (error): the per-grid-step block working set
  (every input/output block, double-buffered) exceeds the segment VMEM
  policy budget (``kernels.ops._SEGMENT_VMEM_BUDGET``, the bound
  ``segment_bn`` enforces when it picks the source-block size).

Both the dense batched-round program and the sparse ELL segment program
are traced; ``dist/gossip.py``'s coverage of registry algorithms gets an
advisory ``mesh-dist-coverage`` (info) for specs with no dist variant.
"""

from __future__ import annotations

from .findings import AnalysisFinding, source_of
from . import trace_utils as tu

PASS = "mesh-kernel"


def _kernel_name(eqn) -> str:
    info = eqn.params.get("name_and_src_info")
    name = getattr(info, "name", None) or eqn.params.get("name")
    return str(name) if name else "pallas_call"


def _kernel_finding(rule, severity, message, obj):
    from repro.kernels import ops

    file, line = source_of(ops.use_interpret)  # anchor at kernels/ops.py
    return AnalysisFinding(
        rule=rule, severity=severity, message=message, obj=obj,
        file=file, line=line, passname=PASS)


def _block_shapes(eqn):
    """(block_shape, operand_shape, dtype) triples for inputs AND outputs."""
    gm = eqn.params.get("grid_mapping")
    if gm is None:
        return []
    mappings = list(gm.block_mappings)
    in_avals = [v.aval for v in eqn.invars]
    out_avals = list(eqn.params.get("out_avals") or
                     [v.aval for v in eqn.outvars])
    # index-style mappings align leading with inputs, trailing with outputs
    n_in = len(mappings) - len(out_avals)
    avals = in_avals[-n_in:] if 0 <= n_in <= len(in_avals) else in_avals
    avals = list(avals) + out_avals
    out = []
    for bm, aval in zip(mappings, avals):
        bs = tuple(int(d) for d in bm.block_shape
                   if isinstance(d, int) or hasattr(d, "__index__"))
        out.append((bs, tuple(aval.shape), aval.dtype))
    return out


def check_pallas_eqn(eqn, inside_cp: bool) -> list[AnalysisFinding]:
    from repro.kernels import ops

    name = _kernel_name(eqn)
    findings = []
    if not inside_cp:
        findings.append(_kernel_finding(
            "mesh-unwrapped-kernel", "error",
            "pallas_call reachable under a mesh context is not wrapped by "
            "the custom_partitioning rule from kernels/ops.py — GSPMD "
            "silently replicates it (every device runs the full global "
            "grid)", name))
    vmem = 0
    for bs, shape, dtype in _block_shapes(eqn):
        if len(bs) == len(shape):
            for bd, sd in zip(bs, shape):
                if bd and sd % bd != 0:
                    findings.append(_kernel_finding(
                        "kernel-tile-divisibility", "error",
                        f"BlockSpec tile {bs} does not divide operand "
                        f"extent {shape} (dim {sd} % {bd} != 0)", name))
                    break
        n_elem = 1
        for bd in (bs if bs else shape):
            n_elem *= max(int(bd), 1)
        vmem += n_elem * dtype.itemsize
    budget = ops._SEGMENT_VMEM_BUDGET
    if 2 * vmem > budget:  # double-buffered pipeline working set
        findings.append(_kernel_finding(
            "kernel-vmem-budget", "error",
            f"per-step block working set 2*{vmem}B exceeds the segment "
            f"VMEM policy budget {budget}B (segment_bn's bound)", name))
    return findings


def _check_dist_coverage() -> list[AnalysisFinding]:
    from repro.core.algorithms import dist_variant, registered_algorithms
    from repro.dist import gossip

    file, line = source_of(gossip._register_dist_variants)
    exempt = getattr(gossip, "DIST_EXEMPT", ())
    findings = []
    for name in registered_algorithms():
        if dist_variant(name) is None and name not in exempt:
            findings.append(AnalysisFinding(
                rule="mesh-dist-coverage", severity="info",
                message="no dist/gossip variant registered (multi-process "
                "runs fall back to the single-host engine) and not listed "
                "in dist.gossip.DIST_EXEMPT",
                obj=name, file=file, line=line, passname=PASS))
    return findings


def check_mesh_kernels(algorithms=None) -> list[AnalysisFinding]:
    from repro.core.algorithms import registered_algorithms

    specs = tuple(algorithms or registered_algorithms())
    findings: list[AnalysisFinding] = []
    traces = []
    try:
        traces.append(tu.trace_engine(specs, "pallas", force_mesh=True))
    except Exception as exc:
        findings.append(_kernel_finding(
            "engine-trace-failed", "error",
            f"dense pallas grid failed to trace under forced mesh "
            f"dispatch: {exc}", "sweep.engine[pallas]"))
    try:
        traces.append(tu.trace_engine_sparse(specs, force_mesh=True))
    except Exception as exc:
        findings.append(_kernel_finding(
            "engine-trace-failed", "error",
            f"sparse pallas grid failed to trace under forced mesh "
            f"dispatch: {exc}", "sweep.engine[pallas-sparse]"))
    for closed in traces:
        for eqn, inside_cp in tu.iter_eqns(closed.jaxpr):
            if eqn.primitive.name == "pallas_call":
                findings.extend(check_pallas_eqn(eqn, inside_cp))
    if algorithms is None:  # registry-wide advisory, not per-spec
        findings.extend(_check_dist_coverage())
    # the same kernel appears once per partition/branch: dedup exact repeats
    seen, uniq = set(), []
    for f in findings:
        key = (f.rule, f.obj, f.message)
        if key not in seen:
            seen.add(key)
            uniq.append(f)
    return uniq
