"""CLI: ``python -m repro.analysis --check``.

Exit status: 0 when no error-severity findings, 1 otherwise (warnings and
infos never fail the lane). ``--fixtures`` registers the deliberately-broken
fixture algorithms first and INVERTS the contract: the run fails unless
every fixture produces its expected finding — the analysis lane's self-test.
"""

from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static verification of the consensus-engine contracts")
    p.add_argument("--check", action="store_true",
                   help="run all passes over the live registry")
    p.add_argument("--algorithms", nargs="*", default=None,
                   help="restrict to these registered specs")
    p.add_argument("--markdown", action="store_true",
                   help="render findings as a markdown table")
    p.add_argument("--out", default=None,
                   help="also write the report to this file")
    p.add_argument("--fixtures", action="store_true",
                   help="self-test on the deliberately-broken fixtures")
    args = p.parse_args(argv)
    if not args.check:
        p.print_help()
        return 2

    from repro.analysis import has_errors, render_markdown, render_text
    from repro.analysis import run_all_checks

    if args.fixtures:
        from repro.analysis import fixtures

        report, ok = fixtures.selftest()
        sys.stdout.write(report)
        return 0 if ok else 1

    algorithms = tuple(args.algorithms) if args.algorithms else None
    findings = run_all_checks(algorithms)
    report = render_markdown(findings) if args.markdown \
        else render_text(findings)
    sys.stdout.write(report)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(render_markdown(findings))
    return 1 if has_errors(findings) else 0


if __name__ == "__main__":
    sys.exit(main())
