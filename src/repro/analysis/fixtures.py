"""Deliberately-broken registrations: one per pass, for red-path testing.

Each fixture violates exactly ONE contract and holds every other, so the
matching pass must produce exactly one error finding with the expected rule
id and the other passes stay quiet about it. ``selftest()`` (the CLI's
``--fixtures`` flag and the CI lane's second step) registers them, runs the
relevant pass per fixture, and reports pass/fail — the analysis lane
verifying its own teeth.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _convex(x, a, b, c):
    return jnp.broadcast_to(
        jnp.asarray([a, b, c], jnp.float32), (x.shape[0], 3))


def _make_fixture_classes():
    from repro.core.algorithms import ConsensusAlgorithm

    class MassLeaker(ConsensusAlgorithm):
        """a+b+c = 0.99: leaks 1% of the average's mass every round."""

        name = spec = "fx_mass_leaker"
        num_taps = 1

        def round_body(self, prim, params, carry, t):
            (x,) = carry
            return (prim(x, x, _convex(x, 0.66, 0.33, 0.0)),)

        def ref_coef(self, params):
            return (0.66, 0.33, 0.0)

    class TickFragmenter(ConsensusAlgorithm):
        """Branches in Python on the traced tick: fragments the scan."""

        name = spec = "fx_fragmenting"
        num_taps = 1

        def round_body(self, prim, params, carry, t):
            (x,) = carry
            if (t % 2) == 0:  # concretizes t — trace error under the scan
                return (prim(x, x, _convex(x, 0.5, 0.5, 0.0)),)
            return (prim(x, x, _convex(x, 0.25, 0.75, 0.0)),)

        def ref_coef(self, params):
            return (0.5, 0.5, 0.0)

    class UnwrappedKernel(ConsensusAlgorithm):
        """Supplies a raw pallas_call with no custom_partitioning wrapper."""

        name = spec = "fx_unwrapped_kernel"
        num_taps = 1

        def round_body(self, prim, params, carry, t):
            (x,) = carry
            return (prim(x, x, _convex(x, 0.5, 0.5, 0.0)),)

        def ref_coef(self, params):
            return (0.5, 0.5, 0.0)

        def pallas_round(self, ws, tiles=None):
            from jax.experimental import pallas as pl
            from repro.kernels.ops import use_interpret

            def kernel(w_ref, x_ref, o_ref):
                o_ref[...] = x_ref[...]

            interp = use_interpret()

            def prim(x, xp, coef, m=None):
                return pl.pallas_call(
                    kernel,
                    out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                    interpret=interp)(ws, x)

            return prim

    class F64Promoter(ConsensusAlgorithm):
        """Multiplies state by a strong np.float64 scalar: x64 promotion."""

        name = spec = "fx_f64_promoter"
        num_taps = 1

        def round_body(self, prim, params, carry, t):
            (x,) = carry
            y = prim(x, x, _convex(x, 0.5, 0.5, 0.0))
            return (y * np.float64(1.0),)

        def ref_coef(self, params):
            return (0.5, 0.5, 0.0)

    return (MassLeaker, TickFragmenter, UnwrappedKernel, F64Promoter)


def fixture_specs():
    """(spec, pass name, expected rule, pass callable) per fixture."""
    from .coefficient import check_coefficient_mass
    from .compilation import check_compilation
    from .meshkernel import check_mesh_kernels
    from .precision import check_precision

    return (
        ("fx_mass_leaker", "coefficient-mass", "coef-mass",
         check_coefficient_mass),
        ("fx_fragmenting", "trace-compile", "retrace-fragmentation",
         check_compilation),
        ("fx_unwrapped_kernel", "mesh-kernel", "mesh-unwrapped-kernel",
         check_mesh_kernels),
        ("fx_f64_promoter", "precision", "weak-f64-promotion",
         check_precision),
    )


def register_fixtures():
    from repro.core.algorithms import register_algorithm

    for cls in _make_fixture_classes():
        register_algorithm(cls.name, cls)


def unregister_fixtures():
    from repro.core.algorithms import unregister_algorithm

    for cls in _make_fixture_classes():
        unregister_algorithm(cls.name)


def selftest() -> tuple[str, bool]:
    """Red-path self-test: every fixture must trip its pass, exactly once."""
    register_fixtures()
    lines, ok = ["analysis --fixtures self-test:"], True
    try:
        for spec, passname, rule, check in fixture_specs():
            findings = check((spec,))
            errors = [f for f in findings if f.severity == "error"]
            good = len(errors) == 1 and errors[0].rule == rule
            ok = ok and good
            got = [f"{f.rule}({f.severity})" for f in findings] or ["none"]
            lines.append(
                f"  {'PASS' if good else 'FAIL'} {spec}: {passname} "
                f"expected exactly one error `{rule}`, got {', '.join(got)}")
    finally:
        unregister_fixtures()
    lines.append(f"self-test {'passed' if ok else 'FAILED'}.")
    return "\n".join(lines) + "\n", ok
