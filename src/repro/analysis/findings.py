"""Finding schema shared by every analysis pass."""

from __future__ import annotations

import dataclasses
import inspect

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class AnalysisFinding:
    """One statically-detected contract violation (or advisory).

    ``rule`` is the stable machine id tests and CI key on (e.g.
    ``coef-mass``); ``severity`` is ``error`` (CI-failing), ``warning``
    (contract not provable — review) or ``info`` (advisory, e.g. a traced
    coefficient stream that needs the runtime twin). ``obj`` names the
    offending object (algorithm spec, function), ``file``/``line`` its
    source location when resolvable.
    """

    rule: str
    severity: str
    message: str
    obj: str = ""
    file: str = ""
    line: int = 0
    passname: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}, got {self.severity!r}")

    def location(self) -> str:
        return f"{self.file}:{self.line}" if self.file else ""


def source_of(obj) -> tuple[str, int]:
    """(file, line) of ``obj``'s definition; ('', 0) when unresolvable."""
    try:
        target = obj if inspect.isclass(obj) or inspect.isfunction(obj) \
            else type(obj)
        file = inspect.getsourcefile(target) or ""
        _, line = inspect.getsourcelines(target)
        return file, line
    except (OSError, TypeError):
        return "", 0


def algo_finding(rule: str, severity: str, message: str, algo,
                 passname: str = "") -> AnalysisFinding:
    """Finding anchored at an algorithm registration's class definition."""
    file, line = source_of(algo)
    return AnalysisFinding(
        rule=rule, severity=severity, message=message,
        obj=getattr(algo, "spec", str(algo)), file=file, line=line,
        passname=passname)


def has_errors(findings) -> bool:
    return any(f.severity == "error" for f in findings)


def _sorted(findings):
    order = {s: i for i, s in enumerate(SEVERITIES)}
    return sorted(findings, key=lambda f: (order[f.severity], f.passname,
                                           f.rule, f.obj))


def render_text(findings) -> str:
    """Human-readable report (stdout of the CLI)."""
    if not findings:
        return "analysis: all contracts verified, no findings.\n"
    lines = []
    for f in _sorted(findings):
        loc = f" [{f.location()}]" if f.file else ""
        lines.append(
            f"{f.severity.upper():7s} {f.passname}/{f.rule} "
            f"{f.obj}: {f.message}{loc}")
    n_err = sum(1 for f in findings if f.severity == "error")
    lines.append(
        f"-- {len(findings)} finding(s), {n_err} error(s).")
    return "\n".join(lines) + "\n"


def render_markdown(findings) -> str:
    """Markdown table for the CI job summary."""
    head = "### Static analysis (consensus contract checker)\n\n"
    if not findings:
        return head + "All contracts verified — no findings.\n"
    rows = ["| severity | pass | rule | object | message |",
            "|---|---|---|---|---|"]
    for f in _sorted(findings):
        msg = f.message.replace("|", "\\|").replace("\n", " ")
        rows.append(
            f"| {f.severity} | {f.passname} | `{f.rule}` | `{f.obj}` "
            f"| {msg} |")
    n_err = sum(1 for f in findings if f.severity == "error")
    tail = f"\n\n{len(findings)} finding(s), {n_err} error(s).\n"
    return head + "\n".join(rows) + tail
