"""Shared jaxpr-tracing machinery for the analysis passes.

Everything here builds jaxprs (``jax.make_jaxpr`` / ``jax.eval_shape``) and
walks them — nothing compiles or executes. The instrumented round primitive
(``ANALYSIS_PRIM``) stands in for the engine's fused-round ``prim`` when a
``round_body`` is traced in isolation; its impl raises, so any accidental
execution of an analysis trace hard-fails instead of silently simulating.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

try:  # jax >= 0.4.x moved Primitive to jax.extend
    from jax.extend.core import Primitive
except ImportError:  # pragma: no cover - older jax
    from jax.core import Primitive

from jax import core as jax_core

ANALYSIS_PRIM_NAME = "consensus_round_static"

ANALYSIS_PRIM = Primitive(ANALYSIS_PRIM_NAME)


@ANALYSIS_PRIM.def_abstract_eval
def _analysis_abstract(x, xp, coef):
    return jax_core.ShapedArray(x.shape, x.dtype)


def _analysis_impl(*_args, **_kw):
    raise RuntimeError(
        "the static-analysis round primitive must never execute — "
        "analysis passes trace jaxprs only")


ANALYSIS_PRIM.def_impl(_analysis_impl)


def recording_prim(x, xp, coef, m=None):
    """The ``prim`` handed to ``round_body`` during analysis traces.

    Mirrors the engine's fused-round contract ``a*(W_eff@x) + b*x + c*xp``
    abstractly: one opaque primitive per call site, its third operand the
    (Gp, 3) coefficient rows the coefficient-mass pass inspects.
    """
    del m  # masked rounds share the coefficient contract
    return ANALYSIS_PRIM.bind(x, xp, coef)


# ---------------------------------------------------------------------------
# Probe grids: one tiny representative cell per registration, built entirely
# host-side by the ordinary grid machinery (spectra, designs, coefficients —
# no rounds). Cached per registry generation so fixture (re-)registrations
# can never hit a stale ensemble.
# ---------------------------------------------------------------------------

PROBE_N = 8
PROBE_F = 2


@functools.lru_cache(maxsize=64)
def _probe_ensemble_cached(spec_str: str, generation: int):
    del generation  # cache key only
    from repro.sweep.grid import SweepSpec, build_ensemble

    spec = SweepSpec(
        topologies=("chain",), sizes=(PROBE_N,), designs=("asymptotic",),
        algorithms=(spec_str,), num_trials=PROBE_F, seed=0)
    return build_ensemble(spec)


def probe_ensemble(spec_str: str):
    from repro.core.algorithms import registry_generation

    return _probe_ensemble_cached(str(spec_str), registry_generation())


def carry_structs(algo, ens):
    """Abstract carry slot shapes/dtypes via ``eval_shape`` (nothing runs)."""
    from repro.sweep.engine import _algo_init

    g, n, f = ens.x0.shape
    x0 = jax.ShapeDtypeStruct((g, n, f), jnp.float32)
    coefs = jax.ShapeDtypeStruct(np.asarray(ens.coefs).shape, jnp.float32)
    mask = jax.ShapeDtypeStruct((g, n, 1), jnp.float32)
    return jax.eval_shape(
        lambda x, p, m: _algo_init(algo, x, p, m), x0, coefs, mask)


def trace_round_body(algo, ens, t: int, carry=None, *, abstract_t=False):
    """ClosedJaxpr of one ``round_body`` tick through the recording prim.

    ``t`` is baked concrete by default (the coefficient-mass pass enumerates
    phases of periodic algorithms); ``abstract_t=True`` instead traces ``t``
    as an int32 scalar — exactly what the engine's scan does — so the
    trace/compile pass catches bodies that concretize the tick index.
    """
    if carry is None:
        carry = carry_structs(algo, ens)
    coefs = jax.ShapeDtypeStruct(np.asarray(ens.coefs).shape, jnp.float32)
    if abstract_t:
        def fn(params, c, tt):
            return algo.round_body(recording_prim, params, c, tt)
        return jax.make_jaxpr(fn)(
            coefs, carry, jax.ShapeDtypeStruct((), jnp.int32))

    def fn(params, c):
        return algo.round_body(recording_prim, params, c, t)
    return jax.make_jaxpr(fn)(coefs, carry)


# ---------------------------------------------------------------------------
# Jaxpr walking.
# ---------------------------------------------------------------------------

def subjaxprs_of(eqn):
    """Every sub-jaxpr hanging off an equation's params (ducks ClosedJaxpr)."""
    for v in eqn.params.values():
        vals = v if isinstance(v, (list, tuple)) else (v,)
        for x in vals:
            if hasattr(x, "jaxpr") and hasattr(x, "consts"):
                yield x.jaxpr
            elif hasattr(x, "eqns"):
                yield x


def iter_eqns(jaxpr, inside_cp: bool = False):
    """Yield (eqn, inside_custom_partitioning) over a jaxpr, recursively."""
    for eqn in jaxpr.eqns:
        yield eqn, inside_cp
        sub_cp = inside_cp or eqn.primitive.name == "custom_partitioning"
        for sub in subjaxprs_of(eqn):
            yield from iter_eqns(sub, sub_cp)


def count_primitive(jaxpr, name: str) -> int:
    return sum(1 for eqn, _ in iter_eqns(jaxpr) if eqn.primitive.name == name)


# ---------------------------------------------------------------------------
# Engine traces: the full mixed-grid scan as a ClosedJaxpr, per backend.
# Replays run_batch's host-side input preparation (via the shared helpers in
# sweep.engine) on abstract operands, then make_jaxpr's the UNJITTED scan
# body — the same function the jitted path traces, so the jaxpr the analyzer
# walks is the jaxpr the engine compiles.
# ---------------------------------------------------------------------------

def build_probe_grid(specs, *, num_iters: int = 4, seed: int = 0):
    """(ensemble, round_masks) for a representative mixed grid over ``specs``."""
    from repro.sweep.grid import SweepSpec, build_ensemble, build_round_masks

    spec = SweepSpec(
        topologies=("chain",), sizes=(PROBE_N,), designs=("asymptotic",),
        algorithms=tuple(specs), num_trials=PROBE_F, seed=seed)
    ens = build_ensemble(spec)
    masks = build_round_masks(ens, num_iters, seed=seed)
    return ens, masks


def trace_engine(specs, backend: str, *, num_iters: int = 4,
                 force_mesh: bool = False):
    """ClosedJaxpr of the whole sweep scan over ``specs`` on ``backend``.

    ``force_mesh=True`` traces the program a MESH run would lower (the
    batched kernels behind their custom_partitioning wrappers) even on a
    one-device analysis host — the mesh/kernel pass's view.
    """
    from repro.core.algorithms import registry_generation
    from repro.kernels import ops as kops
    from repro.sweep import engine

    ens, masks = build_probe_grid(specs, num_iters=num_iters)
    g, n, f = ens.x0.shape
    x0 = np.asarray(ens.x0, np.float32)
    bits = eidx = None
    if masks is not None:
        bits = np.asarray(masks.bits, np.uint8)
        eidx = np.asarray(masks.idx, np.int32)

    tiles = None
    if backend == "pallas":
        _, x0, tiles, n, f = engine._prep_pallas_dense(None, x0)
        ws_shape = (g, n, n)
    else:
        ws_shape = np.asarray(ens.ws).shape

    raw = engine._sweep_scan.__wrapped__
    statics = dict(
        num_iters=num_iters, use_kernels=(backend == "pallas"), tiles=tiles,
        layout=ens.layout, algo_gen=registry_generation(), sparse=False)

    def fn(ws, x0_, mask, inv_n, coefs, bits_, eidx_):
        return raw(ws, x0_, mask, inv_n, coefs, bits=bits_, eidx=eidx_,
                   **statics)

    avals = (
        jax.ShapeDtypeStruct(ws_shape, jnp.float32),
        jax.ShapeDtypeStruct((g, n, f), jnp.float32),
        jax.ShapeDtypeStruct((g, n), jnp.float32),
        jax.ShapeDtypeStruct((g,), jnp.float32),
        jax.ShapeDtypeStruct(np.asarray(ens.coefs).shape, jnp.float32),
        None if bits is None else jax.ShapeDtypeStruct(bits.shape, jnp.uint8),
        None if eidx is None else jax.ShapeDtypeStruct(eidx.shape, jnp.int32),
    )
    if force_mesh:
        with kops.force_mesh_dispatch():
            return jax.make_jaxpr(fn)(*avals)
    return jax.make_jaxpr(fn)(*avals)


def trace_engine_sparse(specs, *, num_iters: int = 4,
                        force_mesh: bool = False):
    """ClosedJaxpr of the sparse-pallas (ELL segment-kernel) sweep scan.

    Replays ``engine._prep_pallas_sparse`` host-side (numpy-only ELL build —
    no rounds) so the batched segment kernel's real BlockSpecs and VMEM
    footprint appear in the trace the mesh/kernel pass inspects.
    """
    from repro.core.algorithms import registry_generation
    from repro.kernels import ops as kops
    from repro.sweep import engine
    from repro.sweep.grid import SweepSpec, build_ensemble, build_round_masks

    spec = SweepSpec(
        topologies=("chain",), sizes=(PROBE_N,), designs=("asymptotic",),
        algorithms=tuple(specs), num_trials=PROBE_F, seed=0, layout="sparse")
    ens = build_ensemble(spec)
    masks = build_round_masks(ens, num_iters, seed=0)
    g, _, _ = ens.x0.shape
    bits = eidx = None
    if masks is not None:
        bits = np.asarray(masks.bits, np.uint8)
        eidx = np.asarray(masks.idx, np.int32)
    x0, wpack, tiles, bits, n, f = engine._prep_pallas_sparse(
        np.asarray(ens.x0, np.float32),
        np.asarray(ens.edges, np.int32), np.asarray(ens.edge_w, np.float32),
        np.asarray(ens.diag_w, np.float32), ens.edge_counts,
        None if ens.edge_w_rev is None
        else np.asarray(ens.edge_w_rev, np.float32), bits)

    raw = engine._sweep_scan.__wrapped__
    statics = dict(
        num_iters=num_iters, use_kernels=True, tiles=tiles,
        layout=ens.layout, algo_gen=registry_generation(), sparse=True)

    def fn(ws, x0_, mask, inv_n, coefs, bits_, eidx_):
        return raw(ws, x0_, mask, inv_n, coefs, bits=bits_, eidx=eidx_,
                   **statics)

    avals = (
        tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in wpack),
        jax.ShapeDtypeStruct(x0.shape, jnp.float32),
        jax.ShapeDtypeStruct((g, n), jnp.float32),
        jax.ShapeDtypeStruct((g,), jnp.float32),
        jax.ShapeDtypeStruct(np.asarray(ens.coefs).shape, jnp.float32),
        None if bits is None else jax.ShapeDtypeStruct(bits.shape, jnp.uint8),
        None if eidx is None else jax.ShapeDtypeStruct(eidx.shape, jnp.int32),
    )
    if force_mesh:
        with kops.force_mesh_dispatch():
            return jax.make_jaxpr(fn)(*avals)
    return jax.make_jaxpr(fn)(*avals)
