"""Deterministic synthetic LM data stream.

Design goals of a production input pipeline, scaled to this repo:

  * **Stateless indexing** — ``batch_at(step)`` is a pure function of
    (seed, step, shard), so resume-from-checkpoint replays the exact stream
    with no iterator state to save.
  * **Host sharding** — each host materializes only its ``(shard, num_shards)``
    slice of the global batch; shards use disjoint counter streams.
  * **Double-buffered prefetch** — a one-deep background thread hides
    generation latency behind the train step (``prefetch`` wrapper).

Token model: a noisy affine-recurrence language,
``t_{i+1} = (a * t_i + b) mod V`` with probability (1 - noise) else uniform —
learnable structure (a 100M model visibly drops loss within hundreds of
steps) while needing no external data.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from ..configs.base import ArchConfig

__all__ = ["SyntheticStream", "prefetch"]


@dataclasses.dataclass(frozen=True)
class SyntheticStream:
    cfg: ArchConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    noise: float = 0.2
    shard: int = 0
    num_shards: int = 1

    @property
    def local_batch(self) -> int:
        if self.global_batch % self.num_shards:
            raise ValueError("global_batch must divide among shards")
        return self.global_batch // self.num_shards

    def _rng(self, step: int) -> np.random.Generator:
        # counter-based: (seed, step, shard) -> independent Philox streams
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard])
        )

    def batch_at(self, step: int) -> dict:
        rng = self._rng(step)
        b, t, v = self.local_batch, self.seq_len, self.cfg.vocab_size
        a_coef = 7 + 2 * (self.seed % 5)  # odd multiplier, co-prime-ish with V
        toks = np.empty((b, t + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, v, size=b)
        noise_mask = rng.random((b, t)) < self.noise
        noise_vals = rng.integers(0, v, size=(b, t))
        for i in range(t):
            nxt = (toks[:, i].astype(np.int64) * a_coef + 3) % v
            toks[:, i + 1] = np.where(noise_mask[:, i], noise_vals[:, i], nxt)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.family == "encdec":
            batch["frames"] = rng.standard_normal(
                (b, self.cfg.encoder_len, self.cfg.d_model)
            ).astype(np.float32)
        if self.cfg.family == "vlm":
            batch["image_embeds"] = rng.standard_normal(
                (b, self.cfg.num_image_tokens, self.cfg.d_model)
            ).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def prefetch(stream: SyntheticStream, start_step: int = 0, depth: int = 2):
    """Background-thread prefetch (double buffering by default)."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(stream.batch_at(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    th = threading.Thread(target=worker, daemon=True)
    th.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
