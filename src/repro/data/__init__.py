from .synthetic import SyntheticStream, prefetch

__all__ = ["SyntheticStream", "prefetch"]
