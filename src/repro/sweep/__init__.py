"""Batched ensemble sweep subsystem.

Evaluates a full experiment grid — topology ensemble (chain / grid2d /
torus2d / RGG / erdos_renyi) x theta designs x alpha grid x trial blocks —
in a single jitted, vmapped, device-sharded program, with the per-round
compute optionally running through the fused Pallas gossip-round kernel.

* ``grid``   — declarative ``SweepSpec`` -> stacked ``Ensemble`` arrays.
* ``engine`` — the one-compilation scan; ``run_sweep`` / ``run_batch``.

``repro.core.simulator.simulate`` routes its jax/pallas backends through
``run_batch`` as the degenerate G=1 sweep, so single-config simulation and
paper-scale sweeps share one code path and one compilation cache.
"""
from . import engine, grid
from .engine import SweepResult, run_batch, run_ensemble, run_sweep, trace_count
from .grid import (
    ConfigMeta,
    Ensemble,
    RoundMasks,
    SweepSpec,
    build_ensemble,
    build_round_masks,
    merge_ensembles,
)

__all__ = [
    "engine",
    "grid",
    "SweepResult",
    "run_batch",
    "run_ensemble",
    "run_sweep",
    "trace_count",
    "ConfigMeta",
    "Ensemble",
    "RoundMasks",
    "SweepSpec",
    "build_ensemble",
    "build_round_masks",
    "merge_ensembles",
]
