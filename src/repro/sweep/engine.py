"""Batched sweep engine: the whole experiment grid in ONE jitted program.

Axes and their mapping:

* ``G`` (grid axis)  — every sweep cell: (topology family x size x graph
  draw) x theta design x alpha. Stacked as the leading dim of the (G, N, N)
  weight batch and sharded across devices over the mesh 'data' axis
  (``NamedSharding(mesh, P('data'))``, mesh from ``repro.launch.mesh``).
* ``N`` (node axis)  — padded to the largest network in the grid; replicated.
* ``F`` (trial axis) — initial-condition columns, sharded over the mesh
  'model' axis (degenerate on single-host CPU, real on a pod).
* ``T`` (iterations) — a single ``lax.scan``; the carry is (x, x_prev) only,
  so memory is O(G N F) while the returned MSE trajectory is O(T G F).

The per-round body is the fused two-tap update. ``backend='jax'`` vmaps the
single-graph round over the stacked graph axis (XLA fuses it into one batched
matmul); ``backend='pallas'`` drives the batched-grid fused kernel
``kernels.gossip_round_batched`` directly — matvec accumulation and the FMA
taps in one kernel launch per round, no intermediate x_w in HBM.

Everything funnels through one jit entry (``_sweep_scan``): a full sweep —
and the degenerate G=1 sweep that ``repro.core.simulator.simulate`` routes
through — costs exactly one compilation per (shape, backend) signature.
``trace_count()`` exposes the compile counter so tests can assert that.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_cpu_mesh

from .grid import (
    ConfigMeta,
    Ensemble,
    RoundMasks,
    SweepSpec,
    build_ensemble,
    build_round_masks,
)

__all__ = ["SweepResult", "run_batch", "run_ensemble", "run_sweep", "trace_count"]

# Incremented at trace time inside the jitted engine body: one bump per
# compilation. Tests assert a full heterogeneous grid costs exactly one.
_TRACE_COUNT = 0


def trace_count() -> int:
    return _TRACE_COUNT


@functools.partial(jax.jit, static_argnames=("num_iters", "use_kernels", "tiles"))
def _sweep_scan(ws, x0, mask, inv_n, coefs, num_iters: int, use_kernels: bool,
                tiles: tuple[int, int, int] | None = None, bits=None, eidx=None):
    """One jitted scan for both the static and the dynamic-topology sweep.

    ``bits``/``eidx`` (None on the static path) carry the compressed
    (T, G, E) uint8 edge-activity schedule: the scan expands each round's
    bits into the dense (G, N, N) 0/1 mask *inside* the body — one round's
    mask lives in registers/VMEM while the per-round effective matrices
    W_eff(t) = W.*M + diag((W.*(1-M))@1) are never materialized in HBM
    (``repro.core.dynamics`` has the model).
    """
    global _TRACE_COUNT
    _TRACE_COUNT += 1  # trace-time side effect: counts compilations

    ws = ws.astype(jnp.float32)
    x0 = x0.astype(jnp.float32)
    mask = mask.astype(jnp.float32)[:, :, None]
    inv_n = inv_n.astype(jnp.float32)
    coefs = coefs.astype(jnp.float32)
    dynamic = bits is not None

    if dynamic:
        n = ws.shape[1]
        eye = jnp.eye(n, dtype=bool)

        def expand(bits_t):
            """(G, E) bits -> (G, N, N) dense mask: 1 on live edges + diag.

            Padded edge slots carry index (0, 0); whatever they scatter onto
            the diagonal is overwritten by the eye fill, so padding is exact.
            """
            def one(bg, ig):
                b = bg.astype(jnp.float32)
                m0 = jnp.zeros((n, n), jnp.float32)
                m0 = m0.at[ig[:, 0], ig[:, 1]].set(b)
                m0 = m0.at[ig[:, 1], ig[:, 0]].set(b)
                return m0

            return jnp.where(eye, 1.0, jax.vmap(one)(bits_t, eidx))

    # per-cell target: the true initial average over real nodes (padding is 0)
    xbar = x0.sum(axis=1, keepdims=True) * inv_n[:, None, None]   # (G, 1, F)

    if use_kernels:
        # run_batch pre-pads the whole batch to the kernel tiles ONCE (and
        # passes those tiles in), so the scan body drives the raw batched
        # kernel directly — no per-round pad/slice materializations on the
        # carry (the wrapper in kernels.ops pays those per call; over
        # thousands of rounds they would dwarf the x_w round-trip the
        # fusion removes).
        from repro.kernels.ops import use_interpret
        from repro.kernels.gossip_round import (
            gossip_round_batched_pallas,
            gossip_round_masked_batched_pallas,
        )

        bm, bk, bf = tiles
        interpret = use_interpret()

        def round_fn(x, xp, m):
            if m is None:
                return gossip_round_batched_pallas(
                    ws, x, xp, coefs, bm=bm, bk=bk, bf=bf, interpret=interpret
                )
            return gossip_round_masked_batched_pallas(
                ws, m, x, xp, coefs, bm=bm, bk=bk, bf=bf, interpret=interpret
            )
    elif dynamic:
        a = coefs[:, 0, None, None]
        b = coefs[:, 1, None, None]
        c = coefs[:, 2, None, None]

        def round_fn(x, xp, m):
            wm = ws * m
            drop = jnp.sum(ws - wm, axis=2)                       # (G, N)
            xw = jnp.einsum(
                "gij,gjf->gif", wm, x, preferred_element_type=jnp.float32
            ) + drop[:, :, None] * x
            return a * xw + b * x + c * xp
    else:
        def one_graph_round(w, x, xp, coef):
            xw = jnp.dot(w, x, preferred_element_type=jnp.float32)
            return coef[0] * xw + coef[1] * x + coef[2] * xp

        vmapped_round = jax.vmap(one_graph_round)

        def round_fn(x, xp, m):
            return vmapped_round(ws, x, xp, coefs)

    def mse_of(x):
        d = (x - xbar) * mask
        return (d * d).sum(axis=1) * inv_n[:, None]               # (G, F)

    def body(carry, bits_t):
        x, xp = carry
        x_new = round_fn(x, xp, expand(bits_t) if dynamic else None)
        return (x_new, x), mse_of(x_new)

    (x_fin, _), mse_tail = jax.lax.scan(
        body, (x0, x0), bits if dynamic else None, length=num_iters
    )
    mse = jnp.concatenate([mse_of(x0)[None], mse_tail], axis=0)   # (T+1, G, F)
    return x_fin, jnp.moveaxis(mse, 0, 1)                         # (G, T+1, F)


def run_batch(
    ws,
    x0,
    coefs,
    node_counts=None,
    *,
    num_iters: int,
    backend: str = "jax",
    mesh=None,
    round_masks: RoundMasks | None = None,
):
    """Evaluate ``num_iters`` rounds over a stacked (G, N, N) ensemble.

    Args:
      ws:    (G, N, N) stacked weight matrices (zero-padded rows/cols OK).
      x0:    (G, N, F) initial-condition blocks (zeros on padded nodes).
      coefs: (G, 3) fused-round coefficients (a, b, c) per cell.
      node_counts: (G,) real node count per cell; None means no padding.
      num_iters: rounds T.
      backend: 'jax' (vmapped matmul round) or 'pallas' (fused batched kernel).
      mesh: optional jax Mesh; defaults to the host mesh when more than one
        device is visible. The G axis is sharded over 'data' (padded with
        replicas of cell 0 to divisibility; pad rows are dropped on return).
      round_masks: optional ``RoundMasks`` (compressed per-round edge-activity
        bits, see ``repro.sweep.grid.build_round_masks``): routes through the
        dynamic-topology scan, where each round runs on the mass-preservingly
        re-normalized masked W of that round.

    Returns:
      (x_final (G, N, F), mse (G, T+1, F)) as numpy arrays.
    """
    if backend not in ("jax", "pallas"):
        raise ValueError(f"unknown backend {backend!r} (sweep runs 'jax' or 'pallas')")
    ws = np.asarray(ws)
    x0 = np.asarray(x0)
    coefs = np.asarray(coefs)
    g, n, f = x0.shape
    if node_counts is None:
        node_counts = np.full(g, n, dtype=np.int64)
    node_counts = np.asarray(node_counts)

    bits = eidx = None
    if round_masks is not None:
        bits = np.asarray(round_masks.bits, dtype=np.uint8)
        eidx = np.asarray(round_masks.idx, dtype=np.int32)
        if bits.shape[0] != num_iters or bits.shape[1] != g:
            raise ValueError(
                f"round_masks bits {bits.shape} do not cover "
                f"(num_iters={num_iters}, G={g}) rounds x cells"
            )
        if eidx.shape != (g, bits.shape[2], 2):
            raise ValueError(
                f"round_masks idx {eidx.shape} inconsistent with bits {bits.shape}"
            )

    n_orig, f_orig = n, f
    tiles = None
    if backend == "pallas":
        # pad N/F to the kernel's tile multiples ONCE, outside the scan; the
        # node mask (below) keeps padded rows out of the MSE, padded trial
        # columns are sliced off the outputs. The jax backend stays unpadded
        # (padding a 20-node graph to 128 would be a ~40x flop tax there).
        # The tiles chosen here are threaded into _sweep_scan as static args
        # so padding and kernel blocking can never drift apart.
        from repro.kernels import ops as kops

        tiles = kops._round_tiles(f)
        bm, bk, bf = tiles
        n_pad = kops._round_up(n, max(bm, bk)) - n
        f_pad = kops._round_up(f, bf) - f
        if n_pad or f_pad:
            ws = np.pad(ws, ((0, 0), (0, n_pad), (0, n_pad)))
            x0 = np.pad(x0, ((0, 0), (0, n_pad), (0, f_pad)))
            n, f = n + n_pad, f + f_pad

    mask = (np.arange(n)[None, :] < node_counts[:, None]).astype(np.float32)
    inv_n = (1.0 / node_counts).astype(np.float32)

    # G=1 (the simulate() degenerate sweep) gains nothing from the mesh and
    # would pay device_count replicas of the whole problem via G-padding —
    # only auto-engage the mesh for real grids.
    if mesh is None and g > 1 and jax.device_count() > 1:
        mesh = make_cpu_mesh()
    if mesh is not None and backend == "pallas":
        from repro.kernels.ops import use_interpret

        if not use_interpret():
            # Compiled pallas_call is an opaque custom call with no GSPMD
            # partitioning rule yet (cf. the SSD kernel's custom_partitioning
            # wrapper) — sharding the G axis over a real TPU mesh would fail
            # or silently replicate. Fail loudly until the rule lands.
            raise NotImplementedError(
                "sweep backend='pallas' on a multi-device TPU mesh needs a "
                "partitioning rule for the fused kernel (planned: "
                "custom_partitioning over the G axis); use backend='jax' "
                "or a single device for now"
            )

    g_pad = 0
    arrays = (ws, x0, mask, inv_n, coefs)
    if mesh is not None:
        ndata = mesh.shape["data"]
        g_pad = (-g) % ndata
        if g_pad:
            arrays = tuple(
                np.concatenate([a, np.repeat(a[:1], g_pad, axis=0)], axis=0)
                for a in arrays
            )
            if bits is not None:
                bits = np.concatenate(
                    [bits, np.repeat(bits[:, :1], g_pad, axis=1)], axis=1
                )
                eidx = np.concatenate(
                    [eidx, np.repeat(eidx[:1], g_pad, axis=0)], axis=0
                )
        specs = (
            P("data"),                    # ws
            P("data", None, "model"),     # x0
            P("data"),                    # mask
            P("data"),                    # inv_n
            P("data"),                    # coefs
        )
        arrays = tuple(
            jax.device_put(a, NamedSharding(mesh, s))
            for a, s in zip(arrays, specs)
        )
        if bits is not None:
            bits = jax.device_put(bits, NamedSharding(mesh, P(None, "data")))
            eidx = jax.device_put(eidx, NamedSharding(mesh, P("data")))

    x_fin, mse = _sweep_scan(
        *arrays, num_iters=num_iters, use_kernels=(backend == "pallas"),
        tiles=tiles, bits=bits, eidx=eidx,
    )
    x_fin, mse = np.asarray(x_fin), np.asarray(mse)
    if g_pad:
        x_fin, mse = x_fin[:g], mse[:g]
    if n != n_orig or f != f_orig:
        x_fin, mse = x_fin[:, :n_orig, :f_orig], mse[:, :, :f_orig]
    return x_fin, mse


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Trajectories + per-cell metadata for one engine run."""

    ensemble: Ensemble
    x_final: np.ndarray        # (G, N, F)
    mse: np.ndarray            # (G, T+1, F)

    @property
    def configs(self) -> tuple[ConfigMeta, ...]:
        return self.ensemble.configs

    @property
    def num_iters(self) -> int:
        return self.mse.shape[1] - 1

    def averaging_times(self, eps: float = 1e-5) -> np.ndarray:
        """(G, F) empirical eps-averaging times (Eq. 16) from the MSE curves.

        First t with ||x(t) - xbar|| <= eps ||x(0) - xbar||, i.e.
        mse(t) <= eps^2 mse(0); -1 where the cap was never reached.
        """
        thresh = (eps * eps) * self.mse[:, :1, :]                 # (G, 1, F)
        hit = self.mse <= np.maximum(thresh, 0.0)                 # (G, T+1, F)
        # first hit that STAYS below would be stricter; the paper uses first
        # crossing, matching metrics.averaging_time
        t = np.argmax(hit, axis=1)
        reached = hit.any(axis=1)
        return np.where(reached, t, -1).astype(np.int64)

    def cells(self, **match) -> list[int]:
        """Indices of cells whose ConfigMeta fields equal all of ``match``."""
        out = []
        for i, c in enumerate(self.configs):
            if all(getattr(c, k) == v for k, v in match.items()):
                out.append(i)
        return out


def run_ensemble(
    ens: Ensemble,
    *,
    num_iters: int,
    backend: str = "jax",
    mesh=None,
    round_masks: RoundMasks | None = None,
) -> SweepResult:
    """Evaluate an already-built (possibly merged) grid in one program.

    ``round_masks`` carries per-round edge-failure schedules; pass the result
    of ``build_round_masks(ens, num_iters)`` (or None for the static path —
    ``run_sweep`` wires this automatically from ``SweepSpec.dynamics``).
    """
    x_fin, mse = run_batch(
        ens.ws, ens.x0, ens.coefs, ens.node_counts,
        num_iters=num_iters, backend=backend, mesh=mesh,
        round_masks=round_masks,
    )
    return SweepResult(ensemble=ens, x_final=x_fin, mse=mse)


def run_sweep(
    spec: SweepSpec,
    *,
    num_iters: int,
    backend: str = "jax",
    mesh=None,
) -> SweepResult:
    """Build the grid of ``spec`` and evaluate it in one jitted program.

    When ``spec.dynamics`` contains non-static schedules (e.g.
    ``dynamics=("static", "bernoulli:0.1")``), the per-round edge-failure
    bits are sampled host-side (graph-keyed RNG: coupled across failure
    probabilities and shared across designs) and the whole failure grid runs
    as one jitted vmapped scan, exactly like every other sweep axis.
    """
    ens = build_ensemble(spec)
    masks = build_round_masks(ens, num_iters, seed=spec.seed)
    return run_ensemble(
        ens, num_iters=num_iters, backend=backend, mesh=mesh, round_masks=masks
    )
