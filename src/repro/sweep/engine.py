"""Batched sweep engine: the whole experiment grid in ONE jitted program.

Axes and their mapping:

* ``G`` (grid axis)  — every sweep cell: (topology family x size x graph
  draw) x theta design x alpha. Stacked as the leading dim of the (G, N, N)
  weight batch and sharded across devices over the mesh 'data' axis
  (``NamedSharding(mesh, P('data'))``, mesh from ``repro.launch.mesh``).
* ``N`` (node axis)  — padded to the largest network in the grid; replicated.
* ``F`` (trial axis) — initial-condition columns, sharded over the mesh
  'model' axis (degenerate on single-host CPU, real on a pod).
* ``T`` (iterations) — a single ``lax.scan``; the carry is (x, x_prev) only,
  so memory is O(G N F) while the returned MSE trajectory is O(T G F).

The per-round body comes from the consensus-algorithm registry
(``repro.core.algorithms``): the grid is partitioned along G by algorithm
(``Ensemble.layout``), each partition carries its own tap tuple through the
scan and applies its registered ``round_body`` against the engine's
fused-round primitive. ``backend='jax'`` lowers the primitive to a batched
einsum round; ``backend='pallas'`` drives the batched-grid fused kernel
(``kernels.ops.batched_round_prim``) — matvec accumulation and the FMA taps
in one kernel launch per round, no intermediate x_w in HBM.

The same scan serves both weight layouts: dense feeds (G, N, N) stacked
matrices to the primitives above, while ``SweepSpec(layout="sparse")``
(auto-selected for large N) feeds edge-space operands — directed
gather/segment-sum rounds on the jax backend, batched ELLPACK
segment-reduce kernels (``kernels.ops.batched_segment_round_prim``) on
pallas — so W is never materialized and million-node grids cost O(E), not
O(N^2). ``trial_chunk`` tiles the F axis into independent column blocks
when even O(G N F) state is too big.

Everything funnels through one jit entry (``_sweep_scan``): a full sweep —
and the degenerate G=1 sweep that ``repro.core.simulator.simulate`` routes
through — costs exactly one compilation per (shape, backend) signature.
``trace_count()`` exposes the compile counter so tests can assert that.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
import itertools

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_cpu_mesh

from .grid import (
    ConfigMeta,
    Ensemble,
    RoundMasks,
    SweepSpec,
    build_ensemble,
    build_round_masks,
)

__all__ = ["SweepResult", "run_batch", "run_ensemble", "run_sweep", "trace_count"]

# Incremented at trace time inside the jitted engine body: one bump per
# compilation. Tests assert a full heterogeneous grid costs exactly one.
_TRACE_COUNT = 0


def trace_count() -> int:
    return _TRACE_COUNT


def _algo_init(algo, x0_p, coefs_p, mask_p):
    """Dispatch ``init_carry`` across contract generations (trace time).

    The time-varying-coefficient contract passes the partition's traced
    param rows and node mask so aux-carrying algorithms can seed estimator
    state; registrations written against the original one-argument contract
    (including user registrations outside this repo) keep working via the
    same signature-inspection idiom as ``grid._sparse_tick_rho``.
    """
    try:
        takes = "params" in inspect.signature(algo.init_carry).parameters
    except (TypeError, ValueError):
        takes = False
    if takes:
        return algo.init_carry(x0_p, params=coefs_p, mask=mask_p)
    return algo.init_carry(x0_p)


def _dense_round_prim(wsp, renorm: str):
    """Batched dense einsum round over one G partition's (Gp, N, N) slice.

    ``renorm`` picks where a masked-off entry W_ij returns: "receiver" sums
    the dropped weights per ROW (row sums survive — the doubly-stochastic
    family's rule), "sender" per COLUMN (column sums survive — the
    mass-conserving push-sum family).
    """
    axis = 2 if renorm == "receiver" else 1

    def prim(x, xp, coef, m=None):
        a = coef[:, 0, None, None]
        b = coef[:, 1, None, None]
        c = coef[:, 2, None, None]
        if m is None:
            xw = jnp.einsum(
                "gij,gjf->gif", wsp, x,
                preferred_element_type=jnp.float32)
        else:
            wm = wsp * m
            drop = jnp.sum(wsp - wm, axis=axis)                   # (Gp, N)
            xw = jnp.einsum(
                "gij,gjf->gif", wm, x,
                preferred_element_type=jnp.float32
            ) + drop[:, :, None] * x
        return a * xw + b * x + c * xp
    return prim


def _sparse_round_prim(pack, s: int, e: int, nn: int, renorm: str):
    """Directed-arrays gather/segment_sum round over one G partition.

    Each undirected canonical edge appears as two directed slots (forward
    weight W_ij then reverse W_ji — equal for symmetric bases); ``eid`` maps
    a slot back to its RoundMasks bits column. Padded slots have weight 0
    (their src/dst/eid indices are inert), padded rows have diag 0 and x 0,
    so padding is exact. Dropped mass from masked-off edges returns to the
    RECEIVING row's diagonal under "receiver" renorm or to the SENDING
    neighbour's diagonal under "sender" renorm — the latter keeps column
    sums (total mass) intact for the push-sum family.
    """
    src, dst, wdir, eid, diag = pack
    sg, dg = src[s:e], dst[s:e]
    wg = wdir[s:e].astype(jnp.float32)
    eg, gg = eid[s:e], diag[s:e].astype(jnp.float32)
    receiver = renorm == "receiver"

    def prim(x, xp, coef, m=None):
        a = coef[:, 0, None, None]
        b = coef[:, 1, None, None]
        c = coef[:, 2, None, None]
        if m is None:
            def one(s_, d_, w_, g_, x_):
                contrib = w_[:, None] * jnp.take(x_, d_, axis=0)
                return (jax.ops.segment_sum(
                    contrib, s_, num_segments=nn)
                    + g_[:, None] * x_)
            xw = jax.vmap(one)(sg, dg, wg, gg, x)
        else:
            def one(s_, d_, w_, e_, g_, m_, x_):
                sel = jnp.take(m_, e_)                    # (2E,)
                wt = w_ * sel
                drop = jax.ops.segment_sum(
                    w_ - wt, s_ if receiver else d_, num_segments=nn)
                contrib = wt[:, None] * jnp.take(x_, d_, axis=0)
                return (jax.ops.segment_sum(
                    contrib, s_, num_segments=nn)
                    + (g_ + drop)[:, None] * x_)
            xw = jax.vmap(one)(sg, dg, wg, eg, gg, m, x)
        return a * xw + b * x + c * xp
    return prim


@functools.partial(
    jax.jit,
    static_argnames=("num_iters", "use_kernels", "tiles", "layout", "algo_gen",
                     "sparse", "debug_checks", "dbg_sites"))
def _sweep_scan(ws, x0, mask, inv_n, coefs, num_iters: int, use_kernels: bool,
                tiles: tuple[int, int, int] | None = None, bits=None, eidx=None,
                layout: tuple[tuple[str, int, int], ...] | None = None,
                algo_gen: int = 0, sparse: bool = False,
                debug_checks: bool = False,
                dbg_sites: tuple[tuple[int, ...], ...] = ()):
    """One jitted scan for the whole (possibly mixed-algorithm) grid.

    ``layout`` is the static tuple of (algorithm spec, start, stop) G
    partitions (``Ensemble.layout``; None = one two-tap partition). Each
    partition carries its own registry algorithm's tap tuple through the
    scan and applies its own ``round_body``, written against the fused-round
    primitive this function supplies — einsum round on the jax backend, the
    fused batched Pallas kernel (masked or not) on the pallas backend. The
    MSE reduction reads every partition's display state via the algorithm's
    ``display`` hook (carry slot 0 by default; a ratio of taps for the
    push-sum family). Masked-round renormalization follows each partition's
    ``mass_renorm`` ("receiver" keeps row sums, "sender" keeps column sums);
    both renorms have fused masked kernels on the pallas backend (row- and
    column-masked variants), so no partition ever drops to a jnp fallback
    there.

    ``bits``/``eidx`` (None on the static path) carry the compressed
    (T, G, E) uint8 edge-activity schedule: the scan expands each round's
    bits into the dense (G, N, N) 0/1 mask *inside* the body — one round's
    mask lives in registers/VMEM while the per-round effective matrices
    W_eff(t) = W.*M + diag((W.*(1-M))@1) are never materialized in HBM
    (``repro.core.dynamics`` has the model; ``async_pairwise`` rides the
    same machinery with one-hot bits over its pairwise base matrix).

    ``sparse`` (static) switches ``ws`` to the edge-space operand pytree:
    ``(src, dst, wdir, eid, diag)`` directed arrays on the jax backend, or
    the pre-padded ``(nbrs, wgts, wrevs, slots, diags)`` ELL stacks on
    pallas. The
    dynamic path then feeds each round's raw (Gp, E) bits rows straight to
    the primitive — the dense (G, N, N) mask expansion never happens, which
    is what makes N = 1e5–1e6 dynamic-topology sweeps fit in memory.

    ``algo_gen`` is the registry generation (static): layout names resolve
    to algorithm OBJECTS only at trace time, so a re-registered name must
    miss the jit cache rather than silently run the shadowed round body.
    """
    del algo_gen  # participates only in the jit cache key
    global _TRACE_COUNT
    _TRACE_COUNT += 1  # trace-time side effect: counts compilations

    from repro.core.algorithms import get_algorithm

    if not sparse:
        ws = ws.astype(jnp.float32)
    x0 = x0.astype(jnp.float32)
    mask = mask.astype(jnp.float32)[:, :, None]
    inv_n = inv_n.astype(jnp.float32)
    coefs = coefs.astype(jnp.float32)
    dynamic = bits is not None
    if layout is None:
        layout = (("accel", 0, x0.shape[0]),)

    if dynamic and not sparse:
        n = ws.shape[1]
        eye = jnp.eye(n, dtype=bool)

        def expand(bits_t, ei):
            """(Gp, E) bits -> (Gp, N, N) dense mask: 1 on live edges + diag.

            Padded edge slots carry index (0, 0); whatever they scatter onto
            the diagonal is overwritten by the eye fill, so padding is exact.
            """
            def one(bg, ig):
                b = bg.astype(jnp.float32)
                m0 = jnp.zeros((n, n), jnp.float32)
                m0 = m0.at[ig[:, 0], ig[:, 1]].set(b)
                m0 = m0.at[ig[:, 1], ig[:, 0]].set(b)
                return m0

            return jnp.where(eye, 1.0, jax.vmap(one)(bits_t, ei))

    # per-cell target: the true initial average over real nodes (padding is 0)
    xbar = x0.sum(axis=1, keepdims=True) * inv_n[:, None, None]   # (G, 1, F)

    if sparse and use_kernels:
        # Sparse pallas: pre-padded ELL slices drive the batched segment-
        # reduce kernel; `m` is this round's (Gp, E) bits rows gathered by
        # undirected edge id inside the kernel — no (N, N) mask anywhere.
        # ``renorm`` routes straight into the kernel layer: receiver-renorm
        # partitions run the row-masked kernel, sender-renorm partitions
        # (push-sum family) the column-masked kernel via the wrev array —
        # no jnp fallback on this path anymore. ``tiles`` carries the bn
        # source-block size (VMEM policy, see kernels.ops.segment_bn).
        from repro.kernels.ops import batched_segment_round_prim, use_interpret

        nbrs, wgts, wrevs, slots, diags = ws
        bm, bd, bf, bn = tiles
        interpret = use_interpret()

        def make_prim(s, e, renorm):
            return batched_segment_round_prim(
                nbrs[s:e], wgts[s:e], slots[s:e], diags[s:e],
                wrevs=wrevs[s:e], bm=bm, bd=bd, bf=bf, bn=bn,
                interpret=interpret, renorm=renorm)
    elif sparse:
        nn = x0.shape[1]

        def make_prim(s, e, renorm):
            return _sparse_round_prim(ws, s, e, nn, renorm)
    elif use_kernels:
        # run_batch pre-pads the whole batch to the kernel tiles ONCE (and
        # passes those tiles in), so the scan body drives the raw batched
        # kernel directly — no per-round pad/slice materializations on the
        # carry (the wrapper in kernels.ops pays those per call; over
        # thousands of rounds they would dwarf the x_w round-trip the
        # fusion removes). ``renorm`` picks the masked kernel variant
        # (receiver = row renorm, sender = column renorm) — dynamic
        # sender-renorm partitions no longer drop to the einsum fallback.
        from repro.kernels.ops import batched_round_prim, use_interpret

        bm, bk, bf = tiles
        interpret = use_interpret()

        def make_prim(s, e, renorm):
            return batched_round_prim(
                ws[s:e], bm=bm, bk=bk, bf=bf, interpret=interpret,
                renorm=renorm)
    else:
        def make_prim(s, e, renorm):
            return _dense_round_prim(ws[s:e], renorm)

    # per-partition algorithm objects and primitives (trace-time python)
    parts = []
    for name, s, e in layout:
        algo = get_algorithm(name)
        prim = algo.pallas_round(ws[s:e], tiles=tiles) \
            if (use_kernels and not sparse and algo.pallas_round is not None) \
            else make_prim(s, e, algo.mass_renorm)
        parts.append((algo, s, e, prim))

    if debug_checks:
        # runtime twin of the static coefficient-mass pass: checkify guards
        # at exactly the prim sites whose coefficient streams are traced
        # (data-dependent — the analysis pass could only ASSUME convexity
        # there), plus an isfinite guard on every round output. Static sites
        # are already proven by `python -m repro.analysis --check`; guarding
        # e.g. poly_filter's individually-non-convex Horner taps would
        # misfire, so run_batch precomputes `dbg_sites` per partition from
        # the same classifier (outside this trace — jaxpr interpretation
        # can't nest inside the checkify transform).
        from jax.experimental import checkify

    def mse_of(x):
        d = (x - xbar) * mask
        return (d * d).sum(axis=1) * inv_n[:, None]               # (G, F)

    def body(carry, xs_t):
        t, bits_t = xs_t if dynamic else (xs_t, None)
        new_carry, disp = [], []
        for i, ((algo, s, e, prim), sub) in enumerate(zip(parts, carry)):
            if dynamic:
                m = bits_t[s:e].astype(jnp.float32) if sparse \
                    else expand(bits_t[s:e], eidx[s:e])
            else:
                m = None
            if debug_checks:
                calls = itertools.count()  # trace-time call-order counter

                def pr(x, xp, coef, _p=prim, _m=m, _a=algo,
                       _sites=dbg_sites[i], _c=calls):
                    k = next(_c)
                    if k in _sites:
                        ssum = coef[..., 0] + coef[..., 1] + coef[..., 2]
                        checkify.check(
                            jnp.all(jnp.abs(ssum - 1.0) <= 1e-3),
                            f"coefficient-mass guard: traced (a,b,c) stream "
                            f"at {_a.spec} round_body site {k} strayed from "
                            f"sum 1 (tol 1e-3)")
                    out = _p(x, xp, coef, _m)
                    checkify.check(
                        jnp.all(jnp.isfinite(out)),
                        f"nonfinite state out of {_a.spec} round_body "
                        f"site {k}")
                    return out
            else:
                def pr(x, xp, coef, _p=prim, _m=m):
                    return _p(x, xp, coef, _m)
            sub = algo.round_body(pr, coefs[s:e], sub, t)
            new_carry.append(sub)
            disp.append(algo.display(sub))
        x_all = disp[0] if len(disp) == 1 else jnp.concatenate(disp, axis=0)
        return tuple(new_carry), mse_of(x_all)

    init = tuple(_algo_init(algo, x0[s:e], coefs[s:e], mask[s:e])
                 for algo, s, e, _ in parts)
    t_idx = jnp.arange(num_iters, dtype=jnp.int32)
    carry_fin, mse_tail = jax.lax.scan(
        body, init, (t_idx, bits) if dynamic else t_idx, length=num_iters
    )
    disp_fin = [algo.display(sub)
                for (algo, _, _, _), sub in zip(parts, carry_fin)]
    x_fin = disp_fin[0] if len(disp_fin) == 1 else jnp.concatenate(disp_fin, axis=0)
    mse = jnp.concatenate([mse_of(x0)[None], mse_tail], axis=0)   # (T+1, G, F)
    return x_fin, jnp.moveaxis(mse, 0, 1), carry_fin              # (G, T+1, F)


def _prep_pallas_dense(ws, x0):
    """Pad (ws, x0) to the dense-kernel tile multiples ONCE, host-side.

    Returns ``(ws, x0, tiles, n, f)`` with the padded node/trial extents.
    ``ws=None`` skips the weight pad (the static analyzer replays this prep
    on abstract shapes — keeping it here is what guarantees the jaxpr it
    walks has exactly the shapes ``run_batch`` compiles).
    """
    from repro.kernels import ops as kops

    g, n, f = x0.shape
    tiles = kops.round_tiles(n, f, g, tune=True)
    bm, bk, bf = tiles
    n_pad = kops._round_up(n, max(bm, bk)) - n
    f_pad = kops._round_up(f, bf) - f
    if n_pad or f_pad:
        if ws is not None:
            ws = np.pad(ws, ((0, 0), (0, n_pad), (0, n_pad)))
        x0 = np.pad(x0, ((0, 0), (0, n_pad), (0, f_pad)))
    return ws, x0, tiles, n + n_pad, f + f_pad


def _prep_pallas_sparse(x0, edges, edge_w, diag_w, edge_counts, edge_w_rev,
                        bits):
    """Build the padded ELL pack for the sparse-pallas layout, host-side.

    Per-cell ELL arrays are built ONCE (N already padded to the row tile so
    ``build_ell`` sizes them directly), the neighbor-slot axis is padded to
    the common tile-rounded max degree, and the bits E axis to the kernel's
    128-lane block. Padded slots have weight 0; padded bits columns are
    never gathered. Returns ``(x0, wpack, tiles, bits, n, f)``.
    """
    from repro.kernels import ops as kops

    g, n, f = x0.shape
    bm, bd, bf = kops.segment_tiles(n, f, g, tune=True)
    bn, n_tot = kops.segment_bn(n, bm, bf)
    tiles = (bm, bd, bf, bn)
    n_pad = n_tot - n
    f_pad = kops._round_up(f, bf) - f
    if n_pad or f_pad:
        x0 = np.pad(x0, ((0, 0), (0, n_pad), (0, f_pad)))
    n, f = n + n_pad, f + f_pad
    ec = np.full(g, edges.shape[1], dtype=np.int64) \
        if edge_counts is None else np.asarray(edge_counts, dtype=np.int64)
    ells = [
        kops.build_ell(
            edges[i, :int(ec[i])], edge_w[i, :int(ec[i])],
            np.pad(diag_w[i], (0, n_pad)), n,
            edge_w_rev=None if edge_w_rev is None
            else edge_w_rev[i, :int(ec[i])])
        for i in range(g)
    ]
    d_max = kops._round_up(max(e_[0].shape[1] for e_ in ells), bd)

    def padd(a):
        return np.pad(a, ((0, 0), (0, d_max - a.shape[1])))

    wpack = (
        np.stack([padd(e_[0]) for e_ in ells]),   # nbr  (G, N, D)
        np.stack([padd(e_[1]) for e_ in ells]),   # wgt  (G, N, D)
        np.stack([padd(e_[2]) for e_ in ells]),   # wrev (G, N, D)
        np.stack([padd(e_[3]) for e_ in ells]),   # slot (G, N, D)
        np.stack([e_[4] for e_ in ells]),         # diag (G, N, 1)
    )
    if bits is not None:
        e_b = bits.shape[2]
        bits = np.pad(
            bits,
            ((0, 0), (0, 0),
             (0, kops._round_up(max(e_b, 1), 128) - e_b)))
    return x0, wpack, tiles, bits, n, f


def _prep_jax_sparse(edges, edge_w, diag_w, edge_w_rev):
    """Directed-arrays pack for the sparse jax layout.

    Every canonical undirected edge becomes two directed slots (both
    orientations); the eid row maps a directed slot back to its undirected
    RoundMasks bits column. Padded edge slots carry weight 0, so their
    indices are inert.
    """
    g = edges.shape[0]
    e_und = edges.shape[1]
    return (
        np.concatenate([edges[:, :, 0], edges[:, :, 1]], axis=1),
        np.concatenate([edges[:, :, 1], edges[:, :, 0]], axis=1),
        np.concatenate(
            [edge_w, edge_w if edge_w_rev is None else edge_w_rev],
            axis=1),
        np.ascontiguousarray(np.broadcast_to(
            np.concatenate([np.arange(e_und, dtype=np.int32)] * 2)[None],
            (g, 2 * e_und))),
        diag_w,
    )


def run_batch(
    ws,
    x0,
    coefs,
    node_counts=None,
    *,
    num_iters: int,
    backend: str = "jax",
    mesh=None,
    round_masks: RoundMasks | None = None,
    algos: tuple[tuple[str, int, int], ...] | None = None,
    edges=None,
    edge_w=None,
    diag_w=None,
    edge_counts=None,
    edge_w_rev=None,
    trial_chunk: int | None = None,
    return_taps: bool = False,
    debug_checks: bool = False,
):
    """Evaluate ``num_iters`` rounds over a stacked (G, N, N) ensemble.

    Args:
      ws:    (G, N, N) stacked base matrices (zero-padded rows/cols OK), or
        ``None`` for the SPARSE layout — then ``edges`` (G, Emax, 2) int32
        canonical i<j edge lists (zero-padded slots), ``edge_w`` (G, Emax)
        undirected edge weights (0 on padding), ``diag_w`` (G, N) diagonals
        and optionally ``edge_counts`` (G,) real edge counts carry the
        weights in O(E) instead of O(N^2). The jax backend runs a
        gather/segment-sum round over the directed-arrays form; pallas runs
        the batched ELL segment-reduce kernel (``kernels.ops.build_ell`` +
        ``batched_segment_round_prim``). Same registry round bodies, same
        RoundMasks schedules (bits columns are undirected edge ids in both
        layouts), outputs match the dense layout to f32 roundoff.
        ``edge_w_rev`` (G, Emax) optionally carries the reverse-orientation
        weight W[j, i] per canonical edge (i, j) for asymmetric bases
        (push-sum family); None means W is symmetric and ``edge_w`` serves
        both orientations.
      x0:    (G, N, F) initial-condition blocks (zeros on padded nodes).
      coefs: (G, C) per-cell algorithm parameter rows ((a, b, c) for the
        default two-tap partition).
      node_counts: (G,) real node count per cell; None means no padding.
      num_iters: rounds T.
      backend: 'jax' (einsum round) or 'pallas' (fused batched kernel).
      mesh: optional jax Mesh; defaults to the host mesh when more than one
        device is visible. The G axis is sharded over 'data' (padded with
        replicas of the last cell to divisibility; pad rows are dropped on
        return). Mixed-algorithm grids slice G per partition inside the
        program — align partition boundaries with the shard grid to avoid
        resharding (single-algorithm grids always are).
      round_masks: optional ``RoundMasks`` (compressed per-round edge-activity
        bits, see ``repro.sweep.grid.build_round_masks``): routes through the
        dynamic-topology scan, where each round runs on the mass-preservingly
        re-normalized masked W of that round. Required whenever a partition's
        algorithm needs a per-tick schedule (``async_pairwise``).
      algos: static (algorithm spec, start, stop) partition layout along G
        (``Ensemble.layout``); None = one two-tap ("accel") partition.
      trial_chunk: optional F-axis tile: run the sweep in independent
        column blocks of this many trials and concatenate — trial columns
        never interact, so results match the unchunked run to f32 roundoff
        (only XLA's reduction vectorization differs with F) while peak
        memory drops from O(G N F) to O(G N chunk). This is what makes
        N = 1e5–1e6 sparse sweeps with many trials fit on one host.
      return_taps: when True, additionally return the final carry taps per
        merged algorithm partition as a tuple of
        ``(spec, start, stop, (tap0, tap1, ...))`` entries, each tap a
        (stop - start, N, F) numpy array. This exposes the raw two-state
        (value, mass) taps of the push-sum family so conformance tests can
        assert total-mass conservation directly, not just the displayed
        ratio. Only the algorithm's ``num_taps`` state slots are returned:
        auxiliary carry slots (``num_aux`` — estimator probes, running
        spectral estimates) are internal state and invariant-exempt by
        contract.
      debug_checks: opt-in runtime twin of the static analysis pass
        (``repro.analysis``): threads ``jax.experimental.checkify`` guards
        through the scan — an isfinite assertion on every round output, and
        a coefficient-mass (|a+b+c - 1| <= 1e-3) assertion at exactly the
        prim sites whose coefficient streams are traced (data-dependent,
        e.g. ``accel_adapt``'s adaptive stream — the cases the static pass
        can only flag). Raises ``jax.experimental.checkify.JaxRuntimeError``
        on the first violated guard. Costs one extra compilation and the
        functionalized check overhead; leave off for production sweeps.

    Note on ``trial_chunk`` with aux-carrying algorithms: ``accel_adapt``
    pools its F trial columns as independent estimator probes (the Gelfand
    quotient maxes over all of them), so chunking the F axis changes the
    probe pool and hence the coefficient stream — chunked and unchunked
    adaptive runs agree in distribution but not to roundoff. Static-
    coefficient algorithms keep the exact-match guarantee.

    Returns:
      (x_final (G, N, F), mse (G, T+1, F)) as numpy arrays, plus the taps
      tuple when ``return_taps``.
    """
    if backend not in ("jax", "pallas"):
        raise ValueError(f"unknown backend {backend!r} (sweep runs 'jax' or 'pallas')")
    from repro.core.algorithms import get_algorithm

    sparse = ws is None
    if sparse and (edges is None or edge_w is None or diag_w is None):
        raise ValueError(
            "sparse mode (ws=None) requires edges, edge_w and diag_w arrays")

    x0 = np.asarray(x0)
    f_total = x0.shape[2]
    if trial_chunk is not None and 0 < trial_chunk < f_total:
        outs = [
            run_batch(
                ws, x0[:, :, s:s + trial_chunk], coefs, node_counts,
                num_iters=num_iters, backend=backend, mesh=mesh,
                round_masks=round_masks, algos=algos, edges=edges,
                edge_w=edge_w, diag_w=diag_w, edge_counts=edge_counts,
                edge_w_rev=edge_w_rev, return_taps=return_taps,
                debug_checks=debug_checks,
            )
            for s in range(0, f_total, trial_chunk)
        ]
        x_cat = np.concatenate([o[0] for o in outs], axis=2)
        m_cat = np.concatenate([o[1] for o in outs], axis=2)
        if not return_taps:
            return x_cat, m_cat
        taps = tuple(
            (name, s_, e_, tuple(
                np.concatenate([o[2][k][3][j] for o in outs], axis=2)
                for j in range(len(sub))))
            for k, (name, s_, e_, sub) in enumerate(outs[0][2])
        )
        return x_cat, m_cat, taps

    if sparse:
        edges = np.asarray(edges, dtype=np.int32)
        edge_w = np.asarray(edge_w, dtype=np.float32)
        diag_w = np.asarray(diag_w, dtype=np.float32)
        if edge_w_rev is not None:
            edge_w_rev = np.asarray(edge_w_rev, dtype=np.float32)
    else:
        ws = np.asarray(ws)
    coefs = np.asarray(coefs)
    g, n, f = x0.shape
    if node_counts is None:
        node_counts = np.full(g, n, dtype=np.int64)
    node_counts = np.asarray(node_counts)
    if algos is None:
        algos = (("accel", 0, g),)
    if [s for _, s, _ in algos] != [0] + [e for _, _, e in algos][:-1] \
            or algos[-1][2] != g:
        raise ValueError(f"algorithm layout {algos} does not tile G={g}")
    # coalesce adjacent same-algorithm partitions (merged ensembles produce
    # them) so the scan body keeps one fused round per distinct algorithm
    merged = [list(algos[0])]
    for name, s, e in algos[1:]:
        if name == merged[-1][0]:
            merged[-1][2] = e
        else:
            merged.append([name, s, e])
    algos = tuple((n_, s_, e_) for n_, s_, e_ in merged)
    parts_out = algos  # pre-G-padding layout; frames the returned taps
    if round_masks is None and any(
            get_algorithm(name).needs_schedule for name, _, _ in algos):
        raise ValueError(
            "this grid contains a schedule-bearing algorithm (async_pairwise): "
            "pass round_masks=build_round_masks(ens, num_iters)")

    bits = eidx = None
    if round_masks is not None:
        bits = np.asarray(round_masks.bits, dtype=np.uint8)
        eidx = np.asarray(round_masks.idx, dtype=np.int32)
        if bits.shape[0] != num_iters or bits.shape[1] != g:
            raise ValueError(
                f"round_masks bits {bits.shape} do not cover "
                f"(num_iters={num_iters}, G={g}) rounds x cells"
            )
        if eidx.shape != (g, bits.shape[2], 2):
            raise ValueError(
                f"round_masks idx {eidx.shape} inconsistent with bits {bits.shape}"
            )

    n_orig, f_orig = n, f
    tiles = None
    wpack = None
    if backend == "pallas" and sparse:
        x0, wpack, tiles, bits, n, f = _prep_pallas_sparse(
            x0, edges, edge_w, diag_w, edge_counts, edge_w_rev, bits)
    elif backend == "pallas":
        # pad N/F to the kernel's tile multiples ONCE, outside the scan; the
        # node mask (below) keeps padded rows out of the MSE, padded trial
        # columns are sliced off the outputs. The jax backend stays unpadded
        # (padding a 20-node graph to 128 would be a ~40x flop tax there).
        # The tiles chosen here are threaded into _sweep_scan as static args
        # so padding and kernel blocking can never drift apart.
        ws, x0, tiles, n, f = _prep_pallas_dense(ws, x0)
    elif sparse:
        wpack = _prep_jax_sparse(edges, edge_w, diag_w, edge_w_rev)

    mask = (np.arange(n)[None, :] < node_counts[:, None]).astype(np.float32)
    inv_n = (1.0 / node_counts).astype(np.float32)

    # G=1 (the simulate() degenerate sweep) gains nothing from the mesh and
    # would pay device_count replicas of the whole problem via G-padding —
    # only auto-engage the mesh for real grids.
    if mesh is None and g > 1 and jax.device_count() > 1:
        mesh = make_cpu_mesh()
    # backend="pallas" under a mesh needs no special casing: the batched
    # round prims are wrapped in custom_partitioning over the G axis
    # (kernels.ops), so GSPMD shards the kernel calls along "data" exactly
    # like the jax einsum path.

    g_pad = 0
    w_arrays = wpack if sparse else (ws,)
    nw = len(w_arrays)
    arrays = (*w_arrays, x0, mask, inv_n, coefs)
    if mesh is not None:
        ndata = mesh.shape["data"]
        g_pad = (-g) % ndata
        if g_pad:
            # replicate the LAST cell so the pad extends the last algorithm
            # partition (pad rows are dropped on return either way); every
            # weight operand (dense ws, sparse directed/ELL stacks alike)
            # is G-leading so one rule covers both layouts
            arrays = tuple(
                np.concatenate([a, np.repeat(a[-1:], g_pad, axis=0)], axis=0)
                for a in arrays
            )
            if bits is not None:
                bits = np.concatenate(
                    [bits, np.repeat(bits[:, -1:], g_pad, axis=1)], axis=1
                )
                eidx = np.concatenate(
                    [eidx, np.repeat(eidx[-1:], g_pad, axis=0)], axis=0
                )
            name, s, _ = algos[-1]
            algos = algos[:-1] + ((name, s, g + g_pad),)
        specs = tuple([P("data")] * nw) + (  # weight operands
            P("data", None, "model"),     # x0
            P("data"),                    # mask
            P("data"),                    # inv_n
            P("data"),                    # coefs
        )
        arrays = tuple(
            jax.device_put(a, NamedSharding(mesh, s))
            for a, s in zip(arrays, specs)
        )
        if bits is not None:
            bits = jax.device_put(bits, NamedSharding(mesh, P(None, "data")))
            eidx = jax.device_put(eidx, NamedSharding(mesh, P("data")))

    from repro.core.algorithms import registry_generation

    ws_in = tuple(arrays[:nw]) if sparse else arrays[0]
    if debug_checks:
        # checkify must functionalize the user checks BEFORE jit: wrap the
        # raw scan (statics closed over) and throw on the first violated
        # guard. This bypasses _sweep_scan's jit cache on purpose — the
        # debug program is a different computation (error-state carrying).
        from jax.experimental import checkify

        from repro.analysis.coefficient import traced_coef_sites

        fn = functools.partial(
            _sweep_scan.__wrapped__, num_iters=num_iters,
            use_kernels=(backend == "pallas"), tiles=tiles, bits=bits,
            eidx=eidx, layout=tuple(algos),
            algo_gen=registry_generation(), sparse=sparse,
            debug_checks=True,
            dbg_sites=tuple(tuple(sorted(traced_coef_sites(name)))
                            for name, _, _ in algos))
        err, (x_fin, mse, carry_fin) = jax.jit(
            checkify.checkify(fn, errors=checkify.user_checks)
        )(ws_in, *arrays[nw:])
        err.throw()
    else:
        x_fin, mse, carry_fin = _sweep_scan(
            ws_in, *arrays[nw:], num_iters=num_iters,
            use_kernels=(backend == "pallas"),
            tiles=tiles, bits=bits, eidx=eidx, layout=tuple(algos),
            algo_gen=registry_generation(), sparse=sparse,
        )
    x_fin, mse = np.asarray(x_fin), np.asarray(mse)
    if g_pad:
        x_fin, mse = x_fin[:g], mse[:g]
    if n != n_orig or f != f_orig:
        x_fin, mse = x_fin[:, :n_orig, :f_orig], mse[:, :, :f_orig]
    if not return_taps:
        return x_fin, mse
    # G-padding only ever extends the LAST partition, so slicing each
    # partition's taps to its pre-padding span drops exactly the pad rows.
    # Aux carry slots (everything past num_taps) are algorithm-internal
    # estimator state, not network state: excluded by contract.
    taps = tuple(
        (name, s_p, e_p, tuple(
            np.asarray(t)[:e_p - s_p, :n_orig, :f_orig]
            for t in sub[:get_algorithm(name).num_taps]))
        for (name, s_p, e_p), sub in zip(parts_out, carry_fin)
    )
    return x_fin, mse, taps


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Trajectories + per-cell metadata for one engine run."""

    ensemble: Ensemble
    x_final: np.ndarray        # (G, N, F)
    mse: np.ndarray            # (G, T+1, F)
    # Final carry taps per merged algorithm partition, populated only when
    # the run asked for them (``run_ensemble(..., return_taps=True)``):
    # ((spec, start, stop, (tap0, tap1, ...)), ...). Lets tests inspect the
    # raw (value, mass) pair of push-sum-family cells behind the displayed
    # ratio.
    taps: tuple | None = None

    @property
    def configs(self) -> tuple[ConfigMeta, ...]:
        return self.ensemble.configs

    @property
    def num_iters(self) -> int:
        return self.mse.shape[1] - 1

    def averaging_times(self, eps: float = 1e-5, sustained: bool = False) -> np.ndarray:
        """(G, F) empirical eps-averaging times (Eq. 16) from the MSE curves.

        Default (``sustained=False``): first t with
        ||x(t) - xbar|| <= eps ||x(0) - xbar||, i.e. mse(t) <= eps^2 mse(0)
        — the paper's first-crossing definition, matching
        ``metrics.averaging_time``. On non-monotone curves (masked dynamics,
        randomized pairwise exchanges) first crossing under-reports:
        ``sustained=True`` instead returns the first t after which the MSE
        *stays* below the threshold through the end of the horizon. Both
        return -1 where the criterion is never (or never durably) met.
        """
        thresh = (eps * eps) * self.mse[:, :1, :]                 # (G, 1, F)
        hit = self.mse <= np.maximum(thresh, 0.0)                 # (G, T+1, F)
        if sustained:
            # suffix-AND along t: stays[t] == all(hit[t:])
            hit = np.flip(np.logical_and.accumulate(
                np.flip(hit, axis=1), axis=1), axis=1)
        t = np.argmax(hit, axis=1)
        reached = hit.any(axis=1)
        return np.where(reached, t, -1).astype(np.int64)

    def cells(self, **match) -> list[int]:
        """Indices of cells whose ConfigMeta fields equal all of ``match``."""
        out = []
        for i, c in enumerate(self.configs):
            if all(getattr(c, k) == v for k, v in match.items()):
                out.append(i)
        return out


def run_ensemble(
    ens: Ensemble,
    *,
    num_iters: int,
    backend: str = "jax",
    mesh=None,
    round_masks: RoundMasks | None = None,
    trial_chunk: int | None = None,
    return_taps: bool = False,
    debug_checks: bool = False,
) -> SweepResult:
    """Evaluate an already-built (possibly merged) grid in one program.

    ``round_masks`` carries per-round edge-failure schedules; pass the result
    of ``build_round_masks(ens, num_iters)`` (or None for the static path —
    ``run_sweep`` wires this automatically from ``SweepSpec.dynamics``).
    Sparse-layout ensembles (``ens.is_sparse``) route through the edge-space
    engine automatically; ``trial_chunk`` tiles the F axis for memory;
    ``return_taps`` populates ``SweepResult.taps`` with each partition's
    final carry taps (the push-sum family's raw (value, mass) pair);
    ``debug_checks`` threads the checkify runtime guards through the scan
    (see ``run_batch``).
    """
    out = run_batch(
        ens.ws, ens.x0, ens.coefs, ens.node_counts,
        num_iters=num_iters, backend=backend, mesh=mesh,
        round_masks=round_masks, algos=ens.layout,
        edges=ens.edges, edge_w=ens.edge_w, diag_w=ens.diag_w,
        edge_counts=ens.edge_counts, edge_w_rev=ens.edge_w_rev,
        trial_chunk=trial_chunk, return_taps=return_taps,
        debug_checks=debug_checks,
    )
    x_fin, mse = out[0], out[1]
    taps = out[2] if return_taps else None
    return SweepResult(ensemble=ens, x_final=x_fin, mse=mse, taps=taps)


def run_sweep(
    spec: SweepSpec,
    *,
    num_iters: int,
    backend: str = "jax",
    mesh=None,
    trial_chunk: int | None = None,
    debug_checks: bool = False,
) -> SweepResult:
    """Build the grid of ``spec`` and evaluate it in one jitted program.

    When ``spec.dynamics`` contains non-static schedules (e.g.
    ``dynamics=("static", "bernoulli:0.1")``), the per-round edge-failure
    bits are sampled host-side (graph-keyed RNG: coupled across failure
    probabilities and shared across designs) and the whole failure grid runs
    as one jitted vmapped scan, exactly like every other sweep axis.

    ``spec.layout`` picks the weight storage: "dense" stacks (G, N, N)
    matrices, "sparse" keeps per-cell edge lists and runs gather/segment-sum
    rounds (required for N >> 1e4), "auto" switches to sparse when the
    largest size exceeds ``grid.SPARSE_EXACT_SPECTRUM_CUTOFF``. Pair large-N
    sparse sweeps with ``trial_chunk`` to bound peak memory.
    """
    ens = build_ensemble(spec)
    masks = build_round_masks(ens, num_iters, seed=spec.seed)
    return run_ensemble(
        ens, num_iters=num_iters, backend=backend, mesh=mesh,
        round_masks=masks, trial_chunk=trial_chunk,
        debug_checks=debug_checks,
    )
