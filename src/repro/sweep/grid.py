"""Experiment-grid construction: topology ensembles as stacked arrays.

The paper's headline results are *ensemble* claims — Theorems 2-3 bound the
averaging-time gain over families of graphs, and Figs. 3-4 average hundreds
of random-geometric draws per network size. A sweep cell is one

    (topology family, size, graph draw) x (theta design) x (alpha)

configuration; this module materializes the full grid as stacked arrays the
batched engine consumes in one jitted program:

* ``ws``    (G, Nmax, Nmax) — the Metropolis-Hastings weight matrix of every
  cell, zero-padded to the largest network in the grid. Zero padding is
  exact: padded nodes start at 0, receive 0 from W and from both taps, and
  are masked out of the MSE reduction.
* ``x0``    (G, Nmax, F)    — F initial-condition columns per cell (paper
  Section IV inits: one deterministic Slope column, then Spike columns at
  random nodes, or i.i.d. Gaussians).
* ``coefs`` (G, 3)          — the fused-round coefficients
  (1 - alpha + alpha*theta3, alpha*theta2, alpha*theta1); memoryless cells
  are the degenerate row (1, 0, 0).
* ``mask`` / ``node_counts`` — per-cell valid-node indicators for padded
  reductions.

Graph draws are shared across the theta/alpha cells of the same (family,
size, draw) triple — gain ratios (Fig. 4) then compare identical ensembles.

**Sparse layout** (``SweepSpec(layout="sparse")``): cells store the canonical
edge list + edge/diagonal weights instead of ``ws`` — O(E) per cell instead
of O(N^2) — and the engine runs the segment-sum round primitive, which is
what makes power-law sweeps at N = 1e5-1e6 fit on one host. Cells with
n <= ``SPARSE_EXACT_SPECTRUM_CUTOFF`` densify *for metadata only* (exact
eigvalsh spectrum, identical coefficients to the dense layout — the
equivalence suite's bit-level anchor); larger cells use power-iteration
extremes and a surrogate spectrum (``_surrogate_spectrum``) for the alpha*,
phi3 and polynomial-filter designs. ``layout="auto"`` picks sparse as soon
as the grid's largest size crosses the cutoff.
"""
from __future__ import annotations

import dataclasses
import inspect
import math
from typing import Callable

import numpy as np

from repro.core import accel, algorithms, dynamics, metrics, topology, weights
from repro.core.accel import Theta

__all__ = [
    "SweepSpec",
    "ConfigMeta",
    "Ensemble",
    "RoundMasks",
    "build_ensemble",
    "build_round_masks",
    "merge_ensembles",
    "THETA_DESIGNS",
]

# Named predictor designs. ``None`` marks the memoryless baseline
# x(t+1) = W x(t) (alpha = 0), kept in-grid so gains come from one run.
THETA_DESIGNS: dict[str, Callable[[], Theta] | None] = {
    "memoryless": None,
    "ls": accel.theta_ls,
    "asymptotic": lambda: accel.theta_asymptotic(0.5),
}


# Above this size the sparse layout stops densifying for metadata (no exact
# eigvalsh) and "auto" stops choosing the dense layout at all.
SPARSE_EXACT_SPECTRUM_CUTOFF = 1024
SURROGATE_SPECTRUM_POINTS = 64


def _near_square(n: int) -> tuple[int, int]:
    rows = max(int(math.isqrt(n)), 1)
    while n % rows:
        rows -= 1
    return rows, n // rows


def _parse_family(family: str) -> tuple[str, list[str]]:
    """Family specs parse like dynamics specs: ``"ba"`` or ``"ba:5"``."""
    parts = str(family).split(":")
    return parts[0], parts[1:]


def _build_graph(family: str, n: int, rng: np.random.Generator) -> topology.Graph:
    fam, fargs = _parse_family(family)
    if fam == "chain":
        return topology.chain(n)
    if fam == "ring":
        return topology.ring(n)
    if fam == "grid2d":
        return topology.grid2d(*_near_square(n))
    if fam == "torus2d":
        return topology.torus2d(*_near_square(n))
    if fam == "rgg":
        return topology.random_geometric(n, rng)
    if fam == "ba":
        # densified sparse build: both layouts consume identical rng draws,
        # so dense<->sparse equivalence holds on power-law graphs too
        m = int(fargs[0]) if fargs else 3
        return topology.barabasi_albert(n, m, rng).to_dense()
    if fam == "erdos_renyi":
        p = min(1.0, 2.0 * math.log(max(n, 2)) / n)
        for _ in range(200):
            g = topology.erdos_renyi(n, p, rng)
            if topology.is_connected(g.adjacency):
                return g
        raise RuntimeError(f"could not draw a connected G({n}, {p:.3f})")
    if fam == "directed":
        p_extra = float(fargs[0]) if fargs else 0.15
        return topology.random_digraph(n, rng, p_extra=p_extra)
    raise ValueError(
        f"unknown topology family {family!r} (have chain/ring/grid2d/"
        f"torus2d/rgg/ba[:m]/erdos_renyi/directed[:p_extra])")


def _build_sparse_graph(
    family: str, n: int, rng: np.random.Generator
) -> topology.SparseGraph:
    """Edge-list twin of ``_build_graph``; identical rng consumption per draw."""
    fam, fargs = _parse_family(family)
    if fam == "chain":
        return topology.sparse_chain(n)
    if fam == "ring":
        return topology.sparse_ring(n)
    if fam == "grid2d":
        return topology.sparse_grid2d(*_near_square(n))
    if fam == "torus2d":
        return topology.sparse_torus2d(*_near_square(n))
    if fam == "rgg":
        return topology.random_geometric_sparse(n, rng)
    if fam == "ba":
        m = int(fargs[0]) if fargs else 3
        return topology.barabasi_albert(n, m, rng)
    if fam == "erdos_renyi":
        if n > SPARSE_EXACT_SPECTRUM_CUTOFF:
            # O(E) geometric-skip sampler (never touches an (N, N) coin
            # matrix). Its rng consumption differs from the dense sampler's,
            # so CRN coupling across layouts holds only below the cutoff —
            # where this branch densifies anyway.
            p = min(1.0, 2.0 * math.log(max(n, 2)) / n)
            return topology.erdos_renyi_sparse(n, p, rng)
        return topology.SparseGraph.from_graph(_build_graph(family, n, rng))
    if fam == "directed":
        raise ValueError(
            "the 'directed' family is dense-only (its receiver/push weight "
            "builders and complex spectrum metadata need the full matrix); "
            "use layout='dense'")
    raise ValueError(
        f"unknown topology family {family!r} (have chain/ring/grid2d/"
        f"torus2d/rgg/ba[:m]/erdos_renyi/directed[:p_extra])")


def _surrogate_spectrum(
    lam2: float, lam_n: float, k: int = SURROGATE_SPECTRUM_POINTS
) -> np.ndarray:
    """Stand-in spectrum for cells too large to eigensolve.

    Power-iteration extremes, a uniform fill between them, and the trivial
    eigenvalue 1 — sorted ascending like ``eigvalsh``. The consumers
    (alpha*, ``phi3_eigenvalues`` caps, the polynomial-filter Vandermonde
    design) only need the support interval [lam_N, lam_2] plus the top
    eigenvalue, all of which the surrogate carries exactly.
    """
    return np.concatenate([np.linspace(lam_n, lam2, k), [1.0]])


def _design_params(algo, th, al, lam2):
    """design_params dispatch: lam2-aware (adaptive family) or classic 2-arg.

    Aux-carrying algorithms seed their in-scan estimator from the cell's
    nominal lambda_2, so their ``design_params`` takes it as a keyword; the
    original two-argument contract keeps working unchanged.
    """
    try:
        takes = "lam2" in inspect.signature(algo.design_params).parameters
    except (TypeError, ValueError):
        takes = False
    if takes:
        return algo.design_params(th, al, lam2=lam2)
    return algo.design_params(th, al)


def _sparse_tick_rho(algo, lam2, rho_mem, vals, edges, n):
    """tick_rho for a non-densifiable cell; 4-arg fallback for old overrides."""
    try:
        params = inspect.signature(algo.tick_rho).parameters.values()
        takes_edges = any(p.name == "edges" or p.kind is p.VAR_KEYWORD
                          for p in params)
    except (TypeError, ValueError):
        takes_edges = False
    if takes_edges:
        return algo.tick_rho(lam2, rho_mem, None, vals, edges=edges, num_nodes=n)
    return algo.tick_rho(lam2, rho_mem, None, vals)


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Declarative sweep grid (see module docstring for the cell structure)."""

    topologies: tuple[str, ...] = ("chain", "grid2d", "rgg")
    sizes: tuple[int, ...] = (16, 36, 64)
    designs: tuple[str, ...] = ("memoryless", "asymptotic")
    alphas: tuple[float, ...] | None = None   # None -> alpha*(lambda_2) per cell
    graph_trials: int = 1                     # draws per (family, size); random families only
    num_trials: int = 4                       # F: initial conditions per cell
    init: str = "paper"                       # "paper" (slope+spikes) | "gaussian"
    seed: int = 0
    dynamics: tuple[str, ...] = ("static",)   # topology schedules (core.dynamics)
    algorithms: tuple[str, ...] = ("accel",)  # registry specs (core.algorithms)
    layout: str = "auto"                      # "dense" | "sparse" | "auto"

    def __post_init__(self):
        for d in self.designs:
            if d not in THETA_DESIGNS:
                raise ValueError(f"unknown design {d!r} (have {sorted(THETA_DESIGNS)})")
        for s in self.dynamics:
            dynamics.parse_dynamics(s)        # raises on malformed schedules
        for a in self.algorithms:
            algorithms.get_algorithm(a)       # raises on unknown algorithms
        if self.layout not in ("dense", "sparse", "auto"):
            raise ValueError(
                f"unknown layout {self.layout!r} (have dense/sparse/auto)")

    @property
    def resolved_layout(self) -> str:
        """"auto" -> sparse once any size crosses the dense cutoff."""
        if self.layout != "auto":
            return self.layout
        return ("sparse" if max(self.sizes) > SPARSE_EXACT_SPECTRUM_CUTOFF
                else "dense")


@dataclasses.dataclass(frozen=True)
class ConfigMeta:
    """Host-side metadata for one sweep cell (one row of the stacked arrays)."""

    topology: str
    n: int
    graph_index: int
    design: str
    theta: Theta | None
    alpha: float
    lam2: float
    rho_memoryless: float      # rho(W - J)
    psi: float                 # spectral gap 1 - rho(W - J) (Theorem 2's Psi)
    rho_accel: float           # per-tick contraction of this cell's algorithm
    dynamics: str = "static"   # topology schedule (core.dynamics format)
    algorithm: str = "accel"   # registry spec (core.algorithms format)

    @property
    def gain_asym(self) -> float:
        """tau(W)/tau(accel) — Theorem 3's asymptotic processing gain."""
        if self.rho_accel <= 0.0 or self.rho_memoryless <= 0.0:
            return float("inf")
        return metrics.processing_gain(self.rho_memoryless, self.rho_accel)


@dataclasses.dataclass(frozen=True)
class Ensemble:
    """The stacked grid (see module docstring). Arrays are numpy fp32/fp64.

    Exactly one of the two weight storages is populated: dense grids carry
    ``ws``; sparse grids carry ``edges``/``edge_w``/``diag_w``/``edge_counts``
    (``ws`` is None) — the canonical edge list of every cell padded to the
    grid's largest edge count. Padded edge slots have weight 0 and endpoints
    (0, 0), so they are inert under both the round primitive and the
    mass-preserving mask rule; padded diagonal entries are 0 on nodes whose
    state is pinned at 0 by the init padding.
    """

    ws: np.ndarray | None      # (G, Nmax, Nmax) per-cell base matrices (dense)
    x0: np.ndarray             # (G, Nmax, F)
    coefs: np.ndarray          # (G, C) per-cell algorithm parameter rows
    node_counts: np.ndarray    # (G,) int
    configs: tuple[ConfigMeta, ...]
    algos: tuple[tuple[str, int, int], ...] = ()   # (spec, start, stop) partitions
    edges: np.ndarray | None = None        # (G, Emax, 2) int32, canonical i < j
    edge_w: np.ndarray | None = None       # (G, Emax) f32 base edge weights
    diag_w: np.ndarray | None = None       # (G, Nmax) f32 base diagonal
    edge_counts: np.ndarray | None = None  # (G,) int true edge counts
    # (G, Emax) reverse-orientation weights W[j, i] per canonical (i, j);
    # None when every cell's base is symmetric (push-sum-family cells make
    # it real, symmetric cells then carry a copy of edge_w)
    edge_w_rev: np.ndarray | None = None

    @property
    def is_sparse(self) -> bool:
        return self.ws is None

    @property
    def num_configs(self) -> int:
        return self.x0.shape[0]

    def edge_index(self, i: int) -> np.ndarray:
        """Cell i's canonical (E_i, 2) edge list, layout-independent.

        Both layouts yield the identical array for the same graph (the sparse
        builder stores exactly the ordering ``dynamics.edge_index`` recovers
        from a dense matrix), which is what keeps RoundMasks schedules CRN-
        coupled across layouts.
        """
        if self.is_sparse:
            return np.asarray(self.edges[i, : int(self.edge_counts[i])])
        return dynamics.edge_index(self.ws[i])

    @property
    def layout(self) -> tuple[tuple[str, int, int], ...]:
        """Algorithm partitions along G; () normalizes to one accel partition.

        Cells are grouped contiguously by algorithm (build_ensemble iterates
        the algorithm axis outermost) so the engine can give each partition
        its own carry structure and round body inside ONE jitted scan.
        """
        if self.algos:
            return self.algos
        return (("accel", 0, self.num_configs),)

    @property
    def n_max(self) -> int:
        return self.x0.shape[1]

    def mask(self) -> np.ndarray:
        """(G, Nmax) 1.0 on real nodes, 0.0 on padding."""
        idx = np.arange(self.n_max)[None, :]
        return (idx < self.node_counts[:, None]).astype(np.float32)


def merge_ensembles(*ensembles: Ensemble) -> Ensemble:
    """Concatenate grids along G, re-padding to the largest Nmax.

    Lets callers combine specs with per-family size ranges (e.g. Fig. 3's
    RGG sizes with Fig. 4's chain sizes) into ONE engine run. Trial counts
    (F) must match across the inputs.
    """
    if not ensembles:
        raise ValueError("merge_ensembles needs at least one ensemble")
    fs = {e.x0.shape[2] for e in ensembles}
    if len(fs) > 1:
        raise ValueError(f"trial-axis mismatch across ensembles: {sorted(fs)}")
    if len({e.is_sparse for e in ensembles}) > 1:
        raise ValueError("cannot merge dense and sparse ensembles; rebuild "
                         "with a single SweepSpec layout")
    n_max = max(e.n_max for e in ensembles)

    def grow(a: np.ndarray, axes: tuple[int, ...]) -> np.ndarray:
        pad = [(0, 0)] * a.ndim
        for ax in axes:
            pad[ax] = (0, n_max - a.shape[ax])
        return np.pad(a, pad)

    c_max = max(e.coefs.shape[1] for e in ensembles)
    layout, off = [], 0
    for e in ensembles:
        layout.extend((name, s + off, t + off) for name, s, t in e.layout)
        off += e.num_configs

    if ensembles[0].is_sparse:
        e_max = max(e.edges.shape[1] for e in ensembles)

        def grow_edges(a: np.ndarray) -> np.ndarray:
            pad = [(0, 0), (0, e_max - a.shape[1])] + [(0, 0)] * (a.ndim - 2)
            return np.pad(a, pad)

        if any(e.edge_w_rev is not None for e in ensembles):
            rev_cat = np.concatenate([
                grow_edges(e.edge_w if e.edge_w_rev is None else e.edge_w_rev)
                for e in ensembles
            ])
        else:
            rev_cat = None
        weight_arrays = dict(
            ws=None,
            edges=np.concatenate([grow_edges(e.edges) for e in ensembles]),
            edge_w=np.concatenate([grow_edges(e.edge_w) for e in ensembles]),
            diag_w=np.concatenate([grow(e.diag_w, (1,)) for e in ensembles]),
            edge_counts=np.concatenate([e.edge_counts for e in ensembles]),
            edge_w_rev=rev_cat,
        )
    else:
        weight_arrays = dict(
            ws=np.concatenate([grow(e.ws, (1, 2)) for e in ensembles]))

    return Ensemble(
        x0=np.concatenate([grow(e.x0, (1,)) for e in ensembles]),
        coefs=np.concatenate(
            [np.pad(e.coefs, ((0, 0), (0, c_max - e.coefs.shape[1])))
             for e in ensembles]),
        node_counts=np.concatenate([e.node_counts for e in ensembles]),
        configs=tuple(c for e in ensembles for c in e.configs),
        algos=tuple(layout),
        **weight_arrays,
    )


def _init_block(g: topology.Graph, f: int, kind: str, rng: np.random.Generator) -> np.ndarray:
    n = g.n
    if kind == "gaussian":
        return rng.standard_normal((n, f))
    cols = [metrics.slope_init(g.coords, n)]
    for _ in range(f - 1):
        cols.append(metrics.spike_init(n, node=int(rng.integers(0, n))))
    return np.stack(cols[:f], axis=1)


@dataclasses.dataclass
class _GraphDraw:
    """One graph draw: spectra + whichever weight representation(s) exist.

    ``w`` is the dense base weight matrix — present in the dense layout AND
    for sparse cells small enough to densify for metadata (keeping their
    spectra/coefficients bit-identical to the dense layout). For larger
    sparse cells ``w`` is None and ``vals`` is the surrogate spectrum.
    """

    family: str
    gi: int
    g: object                      # Graph | SparseGraph (.n, .coords for inits)
    w: np.ndarray | None
    vals: np.ndarray
    lam2: float
    rho_mem: float
    edges: np.ndarray | None = None
    edge_w: np.ndarray | None = None
    diag_w: np.ndarray | None = None


def _draw_dense(family: str, gi: int, n: int, rng) -> _GraphDraw:
    g = _build_graph(family, n, rng)
    if isinstance(g, topology.DiGraph):
        # Directed cells: the stored base is the naive row-stochastic
        # receiver matrix (what ``memoryless`` iterates — and provably
        # drifts to the Perron-weighted mixture on). Its spectrum is
        # complex, so the contraction metadata uses the second-largest
        # eigenvalue MODULUS and a surrogate real spectrum on that
        # interval; the push-sum family rebuilds its own column-stochastic
        # base from the same support via ``base_matrix``.
        w = weights.receiver_weights(g)
        ev = np.sort(np.abs(np.linalg.eigvals(w)))
        rho_mem = float(ev[-2])
        vals = _surrogate_spectrum(rho_mem, -rho_mem)
        return _GraphDraw(family, gi, g, w, vals,
                          lam2=rho_mem, rho_mem=rho_mem)
    w = weights.metropolis_hastings(g)
    vals = np.linalg.eigvalsh(w)
    if abs(vals[0]) > vals[-2]:
        # Theorem 1 needs |lambda_N| <= lambda_2; lazy map fixes it.
        w = weights.lazy(w)
        vals = np.linalg.eigvalsh(w)
    return _GraphDraw(family, gi, g, w, vals,
                      lam2=float(vals[-2]),
                      rho_mem=float(max(abs(vals[0]), abs(vals[-2]))))


def _draw_sparse(family: str, gi: int, n: int, rng) -> _GraphDraw:
    sg = _build_sparse_graph(family, n, rng)
    if sg.n <= SPARSE_EXACT_SPECTRUM_CUTOFF:
        # densify for METADATA only: the exact spectrum, lazy decision and
        # edge weights then match the dense layout bit for bit
        w = weights.metropolis_hastings(sg.to_dense())
        vals = np.linalg.eigvalsh(w)
        if abs(vals[0]) > vals[-2]:
            w = weights.lazy(w)
            vals = np.linalg.eigvalsh(w)
        ew = w[sg.edges[:, 0], sg.edges[:, 1]].copy()
        dw = np.diag(w).copy()
        return _GraphDraw(family, gi, sg, w, vals,
                          lam2=float(vals[-2]),
                          rho_mem=float(max(abs(vals[0]), abs(vals[-2]))),
                          edges=sg.edges, edge_w=ew, diag_w=dw)
    ew, dw = weights.metropolis_hastings_edges(sg)
    lam2, lam_n = weights.lambda_extremes_sparse(sg.edges, ew, dw)
    if abs(lam_n) > lam2:
        # lazy map in edge space; eigenvalues transform affinely
        ew, dw = weights.lazy_edges(ew, dw)
        lam2, lam_n = 0.5 * (1.0 + lam2), 0.5 * (1.0 + lam_n)
    vals = _surrogate_spectrum(lam2, lam_n)
    return _GraphDraw(family, gi, sg, None, vals,
                      lam2=float(lam2),
                      rho_mem=float(max(abs(lam_n), abs(lam2))),
                      edges=sg.edges, edge_w=ew, diag_w=dw)


def _base_edge_arrays(
    algo, d: _GraphDraw
) -> tuple[np.ndarray, np.ndarray | None, np.ndarray]:
    """(edge_w, edge_w_rev, diag_w) of this algorithm's BASE matrix, sparse.

    ``edge_w_rev`` is None for symmetric bases (one weight serves both
    orientations of a canonical edge); asymmetric bases (``symmetric_base``
    False — the column-stochastic push-sum family) carry W[j, i] per
    canonical (i, j) so the engine's directed-arrays round sees both.
    """
    if d.w is not None:
        bm = algo.base_matrix(d.w)
        fwd = bm[d.edges[:, 0], d.edges[:, 1]].copy()
        rev = None if algo.symmetric_base \
            else bm[d.edges[:, 1], d.edges[:, 0]].copy()
        return fwd, rev, np.diag(bm).copy()
    out = algo.base_edge_weights(d.edges, d.edge_w, d.diag_w, d.g.n)
    if len(out) == 2:                      # symmetric-base (edge_w, diag_w)
        return out[0], None, out[1]
    return out                             # (fwd, rev, diag)


def build_ensemble(spec: SweepSpec) -> Ensemble:
    """Materialize the sweep grid of ``spec`` as stacked padded arrays."""
    rng = np.random.default_rng(spec.seed)
    random_families = {"rgg", "erdos_renyi", "ba", "directed"}
    sparse = spec.resolved_layout == "sparse"

    graphs: list[_GraphDraw] = []
    for family in spec.topologies:
        fam = _parse_family(family)[0]
        for n in spec.sizes:
            draws = spec.graph_trials if fam in random_families else 1
            for gi in range(draws):
                graphs.append((_draw_sparse if sparse else _draw_dense)(
                    family, gi, n, rng))

    if not graphs:
        raise ValueError("empty sweep grid")
    n_max = max(d.g.n for d in graphs)
    e_max = max(1, max(len(d.edges) for d in graphs)) if sparse else 0
    f = spec.num_trials

    # one init block per graph, drawn in graph order and shared across the
    # design/algorithm/dynamics cells of that graph (common random numbers)
    inits = [_init_block(d.g, f, spec.init, rng) for d in graphs]

    ws, x0s, coefs, counts, metas, layout = [], [], [], [], [], []
    edges_l, edge_w_l, diag_w_l, e_counts = [], [], [], []
    edge_w_rev_l: list[np.ndarray | None] = []

    def add_cell(base, x0, n, params, meta):
        if sparse:
            base_ew, base_rev, base_dw, eix = base
            e = len(eix)
            ep = np.zeros((e_max, 2), dtype=np.int32)
            ep[:e] = eix
            ewp = np.zeros(e_max, dtype=np.float32)
            ewp[:e] = base_ew
            dwp = np.zeros(n_max, dtype=np.float32)
            dwp[:n] = base_dw
            edges_l.append(ep)
            edge_w_l.append(ewp)
            diag_w_l.append(dwp)
            e_counts.append(e)
            if base_rev is None:
                edge_w_rev_l.append(None)
            else:
                rvp = np.zeros(e_max, dtype=np.float32)
                rvp[:e] = base_rev
                edge_w_rev_l.append(rvp)
        else:
            wp = np.zeros((n_max, n_max), dtype=np.float32)
            wp[:n, :n] = base
            ws.append(wp)
        xp0 = np.zeros((n_max, f), dtype=np.float32)
        xp0[:n] = x0
        x0s.append(xp0)
        coefs.append(np.asarray(params, dtype=np.float32))
        counts.append(n)
        metas.append(meta)

    # algorithm axis OUTERMOST: each algorithm's cells form one contiguous
    # G partition (Ensemble.layout), which is what lets the engine scan a
    # mixed-algorithm grid with per-partition carries in one jitted program.
    for algo_spec in spec.algorithms:
        algo = algorithms.get_algorithm(algo_spec)
        start = len(metas)
        for d, x0 in zip(graphs, inits):
            n, vals, lam2, rho_mem = d.g.n, d.vals, d.lam2, d.rho_mem
            if sparse:
                base = (*_base_edge_arrays(algo, d), d.edges)
            else:
                base = algo.base_matrix(d.w)
            if algo.uses_theta:
                for design in spec.designs:
                    maker = THETA_DESIGNS[design]
                    if maker is None:
                        cells = [(None, 0.0)]
                    else:
                        th = maker()
                        alphas = spec.alphas if spec.alphas is not None else (
                            accel.alpha_star(lam2, th),
                        )
                        cells = [(th, float(al)) for al in alphas]
                    for th, al in cells:
                        params = _design_params(algo, th, al, lam2)
                        if th is None:
                            rho_acc = rho_mem
                        else:
                            # exact rho(Phi3[alpha] - J) from the spectrum of W
                            # (equals sqrt(-alpha theta1) only at alpha = alpha*)
                            mus = accel.phi3_eigenvalues(np.sort(vals)[:-1], al, th)
                            rho_acc = float(max(np.abs(mus).max(), abs(al * th.t1)))
                        for dyn in spec.dynamics:
                            add_cell(base, x0, n, params, ConfigMeta(
                                topology=d.family, n=n, graph_index=d.gi,
                                design=design, theta=th, alpha=al, lam2=lam2,
                                rho_memoryless=rho_mem, psi=1.0 - rho_mem,
                                rho_accel=rho_acc, dynamics=dyn,
                                algorithm=algo.spec,
                            ))
            else:
                # theta-free algorithms: one cell per (graph, dynamics) —
                # the design axis does not apply (mirrors how the memoryless
                # design ignores the alpha grid)
                params = algo.cell_params(d.w, vals)
                if d.w is None:
                    rho_tick = _sparse_tick_rho(algo, lam2, rho_mem, vals,
                                                d.edges, n)
                else:
                    rho_tick = algo.tick_rho(lam2, rho_mem, d.w, vals)
                for dyn in spec.dynamics:
                    add_cell(base, x0, n, params, ConfigMeta(
                        topology=d.family, n=n, graph_index=d.gi,
                        design=algo.spec, theta=None, alpha=0.0, lam2=lam2,
                        rho_memoryless=rho_mem, psi=1.0 - rho_mem,
                        rho_accel=rho_tick, dynamics=dyn, algorithm=algo.spec,
                    ))
        layout.append((algo.spec, start, len(metas)))

    c_max = max(1, max(len(c) for c in coefs))
    if sparse:
        # edge_w_rev stacks only when some cell's base is asymmetric; cells
        # of symmetric-base algorithms then reuse their forward weights so
        # one (G, Emax) array serves the whole grid.
        if any(r is not None for r in edge_w_rev_l):
            rev_stack = np.stack([
                r if r is not None else f
                for r, f in zip(edge_w_rev_l, edge_w_l)
            ])
        else:
            rev_stack = None
        weight_arrays = dict(
            ws=None,
            edges=np.stack(edges_l),
            edge_w=np.stack(edge_w_l),
            diag_w=np.stack(diag_w_l),
            edge_counts=np.asarray(e_counts, dtype=np.int64),
            edge_w_rev=rev_stack,
        )
    else:
        weight_arrays = dict(ws=np.stack(ws))
    return Ensemble(
        x0=np.stack(x0s),
        coefs=np.stack([np.pad(c, (0, c_max - len(c))) for c in coefs]),
        node_counts=np.asarray(counts, dtype=np.int64),
        configs=tuple(metas),
        algos=tuple(layout),
        **weight_arrays,
    )


@dataclasses.dataclass(frozen=True)
class RoundMasks:
    """Compressed per-round edge-activity schedules for a whole grid.

    ``bits[t, g, e]`` = 1 iff edge ``idx[g, e]`` of cell g is up in round t.
    Cells are padded to the grid's largest edge count with index (0, 0) and
    bit 1 — the engine's dense expansion overwrites the diagonal with ones,
    so padded slots are inert. uint8 keeps a (T, G, E) schedule ~32x smaller
    than the per-round W matrices it replaces.
    """

    bits: np.ndarray           # (T, G, Emax) uint8, 1 = link up
    idx: np.ndarray            # (G, Emax, 2) int32 edge endpoints (i < j)

    @property
    def num_rounds(self) -> int:
        return self.bits.shape[0]


def build_round_masks(ens: Ensemble, num_iters: int, seed: int = 0) -> RoundMasks | None:
    """Sample every cell's per-round edge schedule for ``num_iters`` rounds.

    Returns None when every cell is static AND no cell's algorithm needs a
    schedule (the engine then takes the cheaper mask-free scan). Sampling is
    keyed by the *graph*, not the cell (``dynamics.graph_rng``): cells
    sharing a (family, size, draw) triple — the same graph crossed with
    different designs, algorithms, or failure probabilities — consume
    identical uniforms, so failure sets are common-random-number coupled and
    nested across p. Schedule-bearing algorithms (``async_pairwise``) then
    post-process the dynamics draw through ``schedule_bits`` (the woken-edge
    one-hot ANDed with the failure bits) using the same stream.
    """
    specs = [dynamics.parse_dynamics(c.dynamics) for c in ens.configs]
    algos = [algorithms.get_algorithm(c.algorithm) for c in ens.configs]
    if all(s.is_static for s in specs) and not any(a.needs_schedule for a in algos):
        return None
    g = ens.num_configs
    idx_list = [ens.edge_index(i) for i in range(g)]
    e_max = max(1, max(len(ix) for ix in idx_list))
    bits = np.ones((num_iters, g, e_max), dtype=np.uint8)
    idx = np.zeros((g, e_max, 2), dtype=np.int32)
    for i, (c, s, a, ix) in enumerate(zip(ens.configs, specs, algos, idx_list)):
        e = len(ix)
        idx[i, :e] = ix
        if s.is_static and not a.needs_schedule:
            continue                       # bits already all-ones
        rng = dynamics.graph_rng(seed, (c.topology, c.n, c.graph_index))
        cell_bits = dynamics.sample_edge_bits(s, num_iters, ix, c.n, rng)
        bits[:, i, :e] = a.schedule_bits(cell_bits, ix, c.n, rng)
    return RoundMasks(bits=bits, idx=idx)
