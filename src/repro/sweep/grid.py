"""Experiment-grid construction: topology ensembles as stacked arrays.

The paper's headline results are *ensemble* claims — Theorems 2-3 bound the
averaging-time gain over families of graphs, and Figs. 3-4 average hundreds
of random-geometric draws per network size. A sweep cell is one

    (topology family, size, graph draw) x (theta design) x (alpha)

configuration; this module materializes the full grid as stacked arrays the
batched engine consumes in one jitted program:

* ``ws``    (G, Nmax, Nmax) — the Metropolis-Hastings weight matrix of every
  cell, zero-padded to the largest network in the grid. Zero padding is
  exact: padded nodes start at 0, receive 0 from W and from both taps, and
  are masked out of the MSE reduction.
* ``x0``    (G, Nmax, F)    — F initial-condition columns per cell (paper
  Section IV inits: one deterministic Slope column, then Spike columns at
  random nodes, or i.i.d. Gaussians).
* ``coefs`` (G, 3)          — the fused-round coefficients
  (1 - alpha + alpha*theta3, alpha*theta2, alpha*theta1); memoryless cells
  are the degenerate row (1, 0, 0).
* ``mask`` / ``node_counts`` — per-cell valid-node indicators for padded
  reductions.

Graph draws are shared across the theta/alpha cells of the same (family,
size, draw) triple — gain ratios (Fig. 4) then compare identical ensembles.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from repro.core import accel, algorithms, dynamics, metrics, topology, weights
from repro.core.accel import Theta

__all__ = [
    "SweepSpec",
    "ConfigMeta",
    "Ensemble",
    "RoundMasks",
    "build_ensemble",
    "build_round_masks",
    "merge_ensembles",
    "THETA_DESIGNS",
]

# Named predictor designs. ``None`` marks the memoryless baseline
# x(t+1) = W x(t) (alpha = 0), kept in-grid so gains come from one run.
THETA_DESIGNS: dict[str, Callable[[], Theta] | None] = {
    "memoryless": None,
    "ls": accel.theta_ls,
    "asymptotic": lambda: accel.theta_asymptotic(0.5),
}


def _near_square(n: int) -> tuple[int, int]:
    rows = max(int(math.isqrt(n)), 1)
    while n % rows:
        rows -= 1
    return rows, n // rows


def _build_graph(family: str, n: int, rng: np.random.Generator) -> topology.Graph:
    if family == "chain":
        return topology.chain(n)
    if family == "ring":
        return topology.ring(n)
    if family == "grid2d":
        return topology.grid2d(*_near_square(n))
    if family == "torus2d":
        return topology.torus2d(*_near_square(n))
    if family == "rgg":
        return topology.random_geometric(n, rng)
    if family == "erdos_renyi":
        p = min(1.0, 2.0 * math.log(max(n, 2)) / n)
        for _ in range(200):
            g = topology.erdos_renyi(n, p, rng)
            if topology.is_connected(g.adjacency):
                return g
        raise RuntimeError(f"could not draw a connected G({n}, {p:.3f})")
    raise ValueError(f"unknown topology family {family!r} "
                     f"(have chain/ring/grid2d/torus2d/rgg/erdos_renyi)")


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Declarative sweep grid (see module docstring for the cell structure)."""

    topologies: tuple[str, ...] = ("chain", "grid2d", "rgg")
    sizes: tuple[int, ...] = (16, 36, 64)
    designs: tuple[str, ...] = ("memoryless", "asymptotic")
    alphas: tuple[float, ...] | None = None   # None -> alpha*(lambda_2) per cell
    graph_trials: int = 1                     # draws per (family, size); random families only
    num_trials: int = 4                       # F: initial conditions per cell
    init: str = "paper"                       # "paper" (slope+spikes) | "gaussian"
    seed: int = 0
    dynamics: tuple[str, ...] = ("static",)   # topology schedules (core.dynamics)
    algorithms: tuple[str, ...] = ("accel",)  # registry specs (core.algorithms)

    def __post_init__(self):
        for d in self.designs:
            if d not in THETA_DESIGNS:
                raise ValueError(f"unknown design {d!r} (have {sorted(THETA_DESIGNS)})")
        for s in self.dynamics:
            dynamics.parse_dynamics(s)        # raises on malformed schedules
        for a in self.algorithms:
            algorithms.get_algorithm(a)       # raises on unknown algorithms


@dataclasses.dataclass(frozen=True)
class ConfigMeta:
    """Host-side metadata for one sweep cell (one row of the stacked arrays)."""

    topology: str
    n: int
    graph_index: int
    design: str
    theta: Theta | None
    alpha: float
    lam2: float
    rho_memoryless: float      # rho(W - J)
    psi: float                 # spectral gap 1 - rho(W - J) (Theorem 2's Psi)
    rho_accel: float           # per-tick contraction of this cell's algorithm
    dynamics: str = "static"   # topology schedule (core.dynamics format)
    algorithm: str = "accel"   # registry spec (core.algorithms format)

    @property
    def gain_asym(self) -> float:
        """tau(W)/tau(accel) — Theorem 3's asymptotic processing gain."""
        if self.rho_accel <= 0.0 or self.rho_memoryless <= 0.0:
            return float("inf")
        return metrics.processing_gain(self.rho_memoryless, self.rho_accel)


@dataclasses.dataclass(frozen=True)
class Ensemble:
    """The stacked grid (see module docstring). Arrays are numpy fp32/fp64."""

    ws: np.ndarray             # (G, Nmax, Nmax) per-cell base matrices
    x0: np.ndarray             # (G, Nmax, F)
    coefs: np.ndarray          # (G, C) per-cell algorithm parameter rows
    node_counts: np.ndarray    # (G,) int
    configs: tuple[ConfigMeta, ...]
    algos: tuple[tuple[str, int, int], ...] = ()   # (spec, start, stop) partitions

    @property
    def num_configs(self) -> int:
        return self.ws.shape[0]

    @property
    def layout(self) -> tuple[tuple[str, int, int], ...]:
        """Algorithm partitions along G; () normalizes to one accel partition.

        Cells are grouped contiguously by algorithm (build_ensemble iterates
        the algorithm axis outermost) so the engine can give each partition
        its own carry structure and round body inside ONE jitted scan.
        """
        if self.algos:
            return self.algos
        return (("accel", 0, self.num_configs),)

    @property
    def n_max(self) -> int:
        return self.ws.shape[1]

    def mask(self) -> np.ndarray:
        """(G, Nmax) 1.0 on real nodes, 0.0 on padding."""
        idx = np.arange(self.n_max)[None, :]
        return (idx < self.node_counts[:, None]).astype(np.float32)


def merge_ensembles(*ensembles: Ensemble) -> Ensemble:
    """Concatenate grids along G, re-padding to the largest Nmax.

    Lets callers combine specs with per-family size ranges (e.g. Fig. 3's
    RGG sizes with Fig. 4's chain sizes) into ONE engine run. Trial counts
    (F) must match across the inputs.
    """
    if not ensembles:
        raise ValueError("merge_ensembles needs at least one ensemble")
    fs = {e.x0.shape[2] for e in ensembles}
    if len(fs) > 1:
        raise ValueError(f"trial-axis mismatch across ensembles: {sorted(fs)}")
    n_max = max(e.n_max for e in ensembles)

    def grow(a: np.ndarray, axes: tuple[int, ...]) -> np.ndarray:
        pad = [(0, 0)] * a.ndim
        for ax in axes:
            pad[ax] = (0, n_max - a.shape[ax])
        return np.pad(a, pad)

    c_max = max(e.coefs.shape[1] for e in ensembles)
    layout, off = [], 0
    for e in ensembles:
        layout.extend((name, s + off, t + off) for name, s, t in e.layout)
        off += e.num_configs

    return Ensemble(
        ws=np.concatenate([grow(e.ws, (1, 2)) for e in ensembles]),
        x0=np.concatenate([grow(e.x0, (1,)) for e in ensembles]),
        coefs=np.concatenate(
            [np.pad(e.coefs, ((0, 0), (0, c_max - e.coefs.shape[1])))
             for e in ensembles]),
        node_counts=np.concatenate([e.node_counts for e in ensembles]),
        configs=tuple(c for e in ensembles for c in e.configs),
        algos=tuple(layout),
    )


def _init_block(g: topology.Graph, f: int, kind: str, rng: np.random.Generator) -> np.ndarray:
    n = g.n
    if kind == "gaussian":
        return rng.standard_normal((n, f))
    cols = [metrics.slope_init(g.coords, n)]
    for _ in range(f - 1):
        cols.append(metrics.spike_init(n, node=int(rng.integers(0, n))))
    return np.stack(cols[:f], axis=1)


def build_ensemble(spec: SweepSpec) -> Ensemble:
    """Materialize the sweep grid of ``spec`` as stacked padded arrays."""
    rng = np.random.default_rng(spec.seed)
    random_families = {"rgg", "erdos_renyi"}

    # (family, graph_index, graph, W, eigvals(W), lambda2, rho(W-J)) per draw
    graphs = []
    for family in spec.topologies:
        for n in spec.sizes:
            draws = spec.graph_trials if family in random_families else 1
            for gi in range(draws):
                g = _build_graph(family, n, rng)
                w = weights.metropolis_hastings(g)
                vals = np.linalg.eigvalsh(w)
                if abs(vals[0]) > vals[-2]:
                    # Theorem 1 needs |lambda_N| <= lambda_2; lazy map fixes it.
                    w = weights.lazy(w)
                    vals = np.linalg.eigvalsh(w)
                lam2 = float(vals[-2])
                rho_mem = float(max(abs(vals[0]), abs(lam2)))
                graphs.append((family, gi, g, w, vals, lam2, rho_mem))

    if not graphs:
        raise ValueError("empty sweep grid")
    n_max = max(g.n for _, _, g, *_ in graphs)
    f = spec.num_trials

    # one init block per graph, drawn in graph order and shared across the
    # design/algorithm/dynamics cells of that graph (common random numbers)
    inits = [_init_block(g, f, spec.init, rng) for _, _, g, *_ in graphs]

    ws, x0s, coefs, counts, metas, layout = [], [], [], [], [], []

    def add_cell(base, x0, n, params, meta):
        wp = np.zeros((n_max, n_max), dtype=np.float32)
        wp[:n, :n] = base
        xp0 = np.zeros((n_max, f), dtype=np.float32)
        xp0[:n] = x0
        ws.append(wp)
        x0s.append(xp0)
        coefs.append(np.asarray(params, dtype=np.float32))
        counts.append(n)
        metas.append(meta)

    # algorithm axis OUTERMOST: each algorithm's cells form one contiguous
    # G partition (Ensemble.layout), which is what lets the engine scan a
    # mixed-algorithm grid with per-partition carries in one jitted program.
    for algo_spec in spec.algorithms:
        algo = algorithms.get_algorithm(algo_spec)
        start = len(metas)
        for (family, gi, g, w, vals, lam2, rho_mem), x0 in zip(graphs, inits):
            n = g.n
            if algo.uses_theta:
                base = algo.base_matrix(w)
                for design in spec.designs:
                    maker = THETA_DESIGNS[design]
                    if maker is None:
                        cells = [(None, 0.0)]
                    else:
                        th = maker()
                        alphas = spec.alphas if spec.alphas is not None else (
                            accel.alpha_star(lam2, th),
                        )
                        cells = [(th, float(al)) for al in alphas]
                    for th, al in cells:
                        params = algo.design_params(th, al)
                        if th is None:
                            rho_acc = rho_mem
                        else:
                            # exact rho(Phi3[alpha] - J) from the spectrum of W
                            # (equals sqrt(-alpha theta1) only at alpha = alpha*)
                            mus = accel.phi3_eigenvalues(np.sort(vals)[:-1], al, th)
                            rho_acc = float(max(np.abs(mus).max(), abs(al * th.t1)))
                        for dyn in spec.dynamics:
                            add_cell(base, x0, n, params, ConfigMeta(
                                topology=family, n=n, graph_index=gi,
                                design=design, theta=th, alpha=al, lam2=lam2,
                                rho_memoryless=rho_mem, psi=1.0 - rho_mem,
                                rho_accel=rho_acc, dynamics=dyn,
                                algorithm=algo.spec,
                            ))
            else:
                # theta-free algorithms: one cell per (graph, dynamics) —
                # the design axis does not apply (mirrors how the memoryless
                # design ignores the alpha grid)
                base = algo.base_matrix(w)
                params = algo.cell_params(w, vals)
                rho_tick = algo.tick_rho(lam2, rho_mem, w, vals)
                for dyn in spec.dynamics:
                    add_cell(base, x0, n, params, ConfigMeta(
                        topology=family, n=n, graph_index=gi, design=algo.spec,
                        theta=None, alpha=0.0, lam2=lam2,
                        rho_memoryless=rho_mem, psi=1.0 - rho_mem,
                        rho_accel=rho_tick, dynamics=dyn, algorithm=algo.spec,
                    ))
        layout.append((algo.spec, start, len(metas)))

    c_max = max(1, max(len(c) for c in coefs))
    return Ensemble(
        ws=np.stack(ws),
        x0=np.stack(x0s),
        coefs=np.stack([np.pad(c, (0, c_max - len(c))) for c in coefs]),
        node_counts=np.asarray(counts, dtype=np.int64),
        configs=tuple(metas),
        algos=tuple(layout),
    )


@dataclasses.dataclass(frozen=True)
class RoundMasks:
    """Compressed per-round edge-activity schedules for a whole grid.

    ``bits[t, g, e]`` = 1 iff edge ``idx[g, e]`` of cell g is up in round t.
    Cells are padded to the grid's largest edge count with index (0, 0) and
    bit 1 — the engine's dense expansion overwrites the diagonal with ones,
    so padded slots are inert. uint8 keeps a (T, G, E) schedule ~32x smaller
    than the per-round W matrices it replaces.
    """

    bits: np.ndarray           # (T, G, Emax) uint8, 1 = link up
    idx: np.ndarray            # (G, Emax, 2) int32 edge endpoints (i < j)

    @property
    def num_rounds(self) -> int:
        return self.bits.shape[0]


def build_round_masks(ens: Ensemble, num_iters: int, seed: int = 0) -> RoundMasks | None:
    """Sample every cell's per-round edge schedule for ``num_iters`` rounds.

    Returns None when every cell is static AND no cell's algorithm needs a
    schedule (the engine then takes the cheaper mask-free scan). Sampling is
    keyed by the *graph*, not the cell (``dynamics.graph_rng``): cells
    sharing a (family, size, draw) triple — the same graph crossed with
    different designs, algorithms, or failure probabilities — consume
    identical uniforms, so failure sets are common-random-number coupled and
    nested across p. Schedule-bearing algorithms (``async_pairwise``) then
    post-process the dynamics draw through ``schedule_bits`` (the woken-edge
    one-hot ANDed with the failure bits) using the same stream.
    """
    specs = [dynamics.parse_dynamics(c.dynamics) for c in ens.configs]
    algos = [algorithms.get_algorithm(c.algorithm) for c in ens.configs]
    if all(s.is_static for s in specs) and not any(a.needs_schedule for a in algos):
        return None
    g = ens.num_configs
    idx_list = [dynamics.edge_index(ens.ws[i]) for i in range(g)]
    e_max = max(1, max(len(ix) for ix in idx_list))
    bits = np.ones((num_iters, g, e_max), dtype=np.uint8)
    idx = np.zeros((g, e_max, 2), dtype=np.int32)
    for i, (c, s, a, ix) in enumerate(zip(ens.configs, specs, algos, idx_list)):
        e = len(ix)
        idx[i, :e] = ix
        if s.is_static and not a.needs_schedule:
            continue                       # bits already all-ones
        rng = dynamics.graph_rng(seed, (c.topology, c.n, c.graph_index))
        cell_bits = dynamics.sample_edge_bits(s, num_iters, ix, c.n, rng)
        bits[:, i, :e] = a.schedule_bits(cell_bits, ix, c.n, rng)
    return RoundMasks(bits=bits, idx=idx)
