"""Elastic membership + failure policy for the pod-level consensus fabric.

This is the control plane that makes the paper's cheap initialization a
*systems* feature: with consensus gradient sync, a pod failure is a **graph
edit**, not a world stall. The runtime:

  1. detects failure/stragglers from heartbeat age (``FailureDetector``);
  2. rebuilds the pod graph without the dead pod (``ElasticFabric.resize``);
  3. re-solves the paper's optimization for the new graph — analytic
     lambda_2 for ring/chain/torus, or O(K) distributed Algorithm 1
     (``repro.dist.gossip.distributed_lambda2``) for irregular graphs: this
     is exactly the paper's Section III-D selling point (prior DOI variants
     were O(K^2), making frequent re-initialization impractical);
  4. continues training with P-1 pods — surviving replicas are already
     within the consensus epsilon of each other, so no re-broadcast of
     parameters is needed (vs allreduce mode, where recovery is
     checkpoint-restart, see launch/train.py --resume auto).

Straggler mitigation: gossip rounds wait only on *graph neighbours*. The
policy grants a straggling pod ``backup_rounds`` extra rounds of slack
before it is treated as failed (its neighbours keep mixing; consensus error
from one lagging pod stays bounded by rho^R_extra — same analysis as the
epsilon knob).

In a real deployment the resize triggers a re-lowered train step on the new
device set; in this repo the same happens through launch.train's rebuild
hook, exercised in tests/test_elastic.py.
"""
from __future__ import annotations

import dataclasses
import time

from ..core.accel import Theta
from ..dist.gossip import PodFabric, make_fabric

__all__ = ["FailureDetector", "ElasticFabric", "PodHealth"]


@dataclasses.dataclass
class PodHealth:
    pod_id: int
    last_heartbeat: float
    step_latency_ema: float = 0.0


@dataclasses.dataclass
class FailureDetector:
    """Heartbeat-age classifier: healthy / straggler / dead."""

    dead_after_s: float = 60.0
    straggler_factor: float = 2.0   # x median step latency
    _pods: dict[int, PodHealth] = dataclasses.field(default_factory=dict)

    def heartbeat(self, pod_id: int, step_latency: float | None = None, now: float | None = None):
        now = time.monotonic() if now is None else now
        h = self._pods.setdefault(pod_id, PodHealth(pod_id, now))
        h.last_heartbeat = now
        if step_latency is not None:
            h.step_latency_ema = (
                step_latency if h.step_latency_ema == 0.0
                else 0.9 * h.step_latency_ema + 0.1 * step_latency
            )

    def classify(self, now: float | None = None) -> dict[int, str]:
        now = time.monotonic() if now is None else now
        lats = sorted(h.step_latency_ema for h in self._pods.values() if h.step_latency_ema > 0)
        med = lats[len(lats) // 2] if lats else 0.0
        out = {}
        for pid, h in self._pods.items():
            if now - h.last_heartbeat > self.dead_after_s:
                out[pid] = "dead"
            elif med > 0 and h.step_latency_ema > self.straggler_factor * med:
                out[pid] = "straggler"
            else:
                out[pid] = "healthy"
        return out


@dataclasses.dataclass
class ElasticFabric:
    """Live pod set + the paper-optimal consensus parameters for it."""

    topology: str = "ring"
    theta: Theta | None = None
    backup_rounds: int = 2
    fabric: PodFabric | None = None
    members: list[int] = dataclasses.field(default_factory=list)
    resize_count: int = 0
    retune_count: int = 0

    def bootstrap(self, pod_ids: list[int]) -> PodFabric:
        self.members = sorted(pod_ids)
        self.fabric = make_fabric(len(self.members), self.topology, self.theta)
        return self.fabric

    def resize(
        self,
        remove: list[int] | None = None,
        add: list[int] | None = None,
        lambda2_estimate: float | None = None,
    ) -> PodFabric:
        """Graph edit: recompute W, lambda_2, alpha*, rho* for the new set.

        O(P^3) dense eigensolve by default (P = pods, small); irregular
        fabrics at scale pass ``lambda2_estimate`` from the O(K) in-mesh
        Algorithm 1 (``dist.gossip.distributed_lambda2``) so Theorem 1 is
        re-solved without ever gathering W — the paper's Section III-D point.
        """
        for pid in remove or []:
            self.members.remove(pid)
        for pid in add or []:
            if pid in self.members:
                raise ValueError(f"pod {pid} already a member")
            self.members.append(pid)
        self.members.sort()
        if not self.members:
            raise RuntimeError("all pods lost")
        self.resize_count += 1
        self.fabric = make_fabric(
            len(self.members), self.topology, self.theta, lambda2=lambda2_estimate
        )
        return self.fabric

    def refresh_lambda2(self, lambda2_estimate: float) -> PodFabric:
        """Re-tune Theorem 1 for the CURRENT membership — no graph edit.

        The control-plane twin of the registry's ``accel_adapt`` and the
        in-mesh ``dist.gossip.adaptive_accel_gossip``: a fresh O(K)
        Algorithm-1 estimate (link degradation, congestion-induced effective
        topology drift) re-solves alpha* without touching the member list.
        All three layers apply the same one-sided rule — the estimate is
        floored at the fabric's nominal lambda_2, because underestimates
        (the finite-K transient approaches lambda_2 from below) put alpha*
        in the slow real-root regime while overestimates degrade smoothly,
        and degradation only moves the effective lambda_2 up. Re-seeding
        downward after a topology improvement goes through ``resize``.
        """
        if self.fabric is None:
            raise RuntimeError("bootstrap the fabric before re-tuning")
        est = max(float(lambda2_estimate), self.fabric.lambda2)
        self.retune_count += 1
        self.fabric = make_fabric(
            len(self.members), self.topology, self.theta, lambda2=est
        )
        return self.fabric

    def rounds(self, eps: float) -> int:
        """Per-sync rounds incl. straggler slack."""
        return self.fabric.rounds_for(eps) + self.backup_rounds

    def react(self, classification: dict[int, str]) -> PodFabric | None:
        """Apply a FailureDetector verdict; returns a new fabric if resized."""
        dead = [p for p, s in classification.items() if s == "dead" and p in self.members]
        if dead:
            return self.resize(remove=dead)
        return None
