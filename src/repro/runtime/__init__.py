from .elastic import ElasticFabric, FailureDetector, PodHealth

__all__ = ["ElasticFabric", "FailureDetector", "PodHealth"]
