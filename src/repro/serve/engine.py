"""Slot-based continuous-batching decode engine.

A fixed pool of ``max_batch`` slots over one shared decode cache. Requests
are admitted into free slots (prefill writes that slot's cache region),
``step()`` decodes one token for *all* active slots in a single jitted call
(the decode_32k/long_500k dry-run shapes are exactly this program), and
finished requests free their slots immediately for waiting work — classic
continuous batching (Orca/vLLM style) on a dense cache.

Per-slot positions ride in a (B,) int32 vector; the model's decode path
masks cache entries by stored absolute position, so mixed-progress slots
coexist in one batch.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models.api import Model

__all__ = ["DecodeEngine", "Request"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (T,) int32
    max_new_tokens: int
    eos_id: int | None = None
    out_tokens: list = dataclasses.field(default_factory=list)
    slot: int | None = None
    done: bool = False


class DecodeEngine:
    def __init__(self, model: Model, params: Any, max_batch: int, max_seq: int):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        cfg = model.cfg

        def init_leaf(leaf):
            shape, _axes, dt = leaf
            if dt == jnp.int32:
                return jnp.full(shape, -1, dt)
            return jnp.zeros(shape, dt)

        is_leaf = lambda x: isinstance(x, tuple) and isinstance(x[0], tuple)
        specs = model.cache_specs(max_batch, max_seq)
        self.cache = jax.tree.map(init_leaf, specs, is_leaf=is_leaf)
        # Batch-dim index per cache leaf, read off the spec's logical axes.
        # Inferring it from a shape mismatch (full=B vs one=1) breaks at
        # max_batch == 1, where every dim matches and the prefill cache was
        # silently discarded; -1 marks (hypothetical) slot-shared leaves.
        self._batch_axis = jax.tree.map(
            lambda leaf: leaf[1].index("batch") if "batch" in leaf[1] else -1,
            specs, is_leaf=is_leaf,
        )
        self.positions = np.full((max_batch,), -1, np.int64)  # -1 = free slot
        self.cur_token = np.zeros((max_batch, 1), np.int32)
        self.slot_req: list[Request | None] = [None] * max_batch
        self.waiting: list[Request] = []
        self._done_at_admit: list[Request] = []
        self._decode = jax.jit(self._decode_impl)
        self._prefill1 = jax.jit(self._prefill_impl)

    # --- jitted kernels -----------------------------------------------------
    def _decode_impl(self, params, cache, tokens, pos_vec):
        # per-slot (B,) positions: mixed-progress slots decode in one call
        logits, cache = self.model.decode(params, tokens, pos_vec, cache)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache

    def _prefill_impl(self, params, batch):
        return self.model.prefill(params, batch, self.max_seq)

    # --- scheduling ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def _admit(self) -> None:
        # a request can finish AT prefill (EOS first token / 1-token budget)
        # without ever occupying its slot, so keep pulling from the queue
        # until one claims it — but bound the prefills per step so a burst of
        # finish-at-prefill requests cannot starve already-decoding slots of
        # their tick (leftovers are admitted on subsequent steps)
        budget = self.max_batch
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None:
                continue
            while self.waiting and budget > 0:
                budget -= 1
                req = self.waiting.pop(0)
                t = len(req.prompt)
                batch = {"tokens": jnp.asarray(req.prompt[None], jnp.int32)}
                if self.model.cfg.family == "encdec":
                    batch["frames"] = jnp.zeros(
                        (1, self.model.cfg.encoder_len, self.model.cfg.d_model), jnp.bfloat16
                    )
                if self.model.cfg.family == "vlm":
                    batch["image_embeds"] = jnp.zeros(
                        (1, self.model.cfg.num_image_tokens, self.model.cfg.d_model), jnp.bfloat16
                    )
                logits, cache1 = self._prefill1(self.params, batch)
                first = int(np.argmax(np.asarray(logits[0, -1])))
                req.out_tokens.append(first)
                # the prefill-time token must face the same termination checks
                # as decode-time tokens: an immediate EOS (or a 1-token
                # budget) must not burn max_new_tokens decode ticks on junk —
                # and such a request never occupies the slot (no cache write)
                if (req.eos_id is not None and first == req.eos_id) or \
                        len(req.out_tokens) >= req.max_new_tokens:
                    req.done = True
                    self._done_at_admit.append(req)
                    continue
                # scatter the single-request cache into this slot, each leaf
                # along its spec-declared batch axis
                self.cache = jax.tree.map(
                    lambda full, one, ax: _slot_insert(full, one, slot, ax),
                    self.cache, cache1, self._batch_axis,
                )
                req.slot = slot
                self.cur_token[slot, 0] = first
                self.positions[slot] = t
                self.slot_req[slot] = req
                break

    def step(self) -> list[Request]:
        """Admit + one decode tick for all active slots. Returns finished."""
        self._admit()
        finished_admit, self._done_at_admit = self._done_at_admit, []
        active = self.positions >= 0
        if not active.any():
            return finished_admit
        tok, self.cache = self._decode(
            self.params, self.cache,
            jnp.asarray(self.cur_token), jnp.asarray(self.positions.clip(min=0), jnp.int32),
        )
        tok = np.asarray(tok)
        finished = finished_admit
        for slot in range(self.max_batch):
            req = self.slot_req[slot]
            if req is None:
                continue
            t = int(tok[slot])
            req.out_tokens.append(t)
            self.positions[slot] += 1
            self.cur_token[slot, 0] = t
            hit_eos = req.eos_id is not None and t == req.eos_id
            if len(req.out_tokens) >= req.max_new_tokens or hit_eos or \
               self.positions[slot] >= self.max_seq - 1:
                req.done = True
                finished.append(req)
                self.slot_req[slot] = None
                self.positions[slot] = -1
        return finished

    def run(self, until_idle: bool = True, max_ticks: int = 10_000) -> list[Request]:
        out = []
        for _ in range(max_ticks):
            out.extend(self.step())
            if until_idle and not self.waiting and all(r is None for r in self.slot_req):
                break
        return out


def _slot_insert(full: jax.Array, one: jax.Array, slot: int, axis: int) -> jax.Array:
    """Insert a batch=1 cache leaf into slot ``slot`` of the engine cache.

    ``axis`` is the leaf's batch dim, read off the model's ``cache_specs``
    logical axes (never inferred from shape differences: at max_batch == 1
    every dim matches and inference used to silently drop the prefill
    cache). ``axis == -1`` marks a slot-shared leaf, kept as-is.
    """
    if axis < 0:
        return full
    return jax.lax.dynamic_update_slice_in_dim(
        full, one.astype(full.dtype), slot, axis=axis
    )
