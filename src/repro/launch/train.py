"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --resume auto

Wires together: config -> model -> optimizer (per-config schedule) ->
synthetic data stream -> train step (allreduce or consensus sync) ->
async checkpointing with auto-resume. On CPU this trains the reduced (smoke)
configs; on a real cluster the same driver runs the full configs on the
production mesh (launch.mesh).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import optim
from ..checkpoint import AsyncCheckpointer, latest_valid, restore
from ..configs import ARCH_IDS, get_config
from ..data import SyntheticStream
from ..dist import SyncConfig, make_train_step
from ..models import build
from .mesh import make_cpu_mesh, make_production_mesh

__all__ = ["main", "train_loop"]


def train_loop(
    arch: str,
    smoke: bool = True,
    steps: int = 100,
    global_batch: int = 8,
    seq_len: int = 128,
    sync_mode: str = "allreduce",
    pods: int = 1,
    lr: float = 3e-3,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    resume: str = "none",
    log_every: int = 10,
    production_mesh: bool = False,
    seed: int = 0,
):
    cfg = get_config(arch, smoke=smoke)
    model = build(cfg)
    mesh = (
        make_production_mesh(multi_pod=pods > 1)
        if production_mesh else make_cpu_mesh(pods=pods)
    )
    opt = optim.for_config(cfg, total_steps=steps, peak_lr=lr)
    sync = SyncConfig(mode=sync_mode)
    ts = make_train_step(
        model, opt, mesh, sync, global_batch, seq_len,
        grad_accum=cfg.grad_accum if not smoke else 1,
    )
    params, opt_state = ts.init_state(jax.random.PRNGKey(seed), model, opt)

    start = 0
    ck = AsyncCheckpointer(ckpt_dir, keep=3) if ckpt_dir else None
    if ckpt_dir and resume == "auto":
        found = latest_valid(ckpt_dir)
        if found:
            start, state, extra = restore(found[1])
            params = jax.tree.map(jnp.asarray, state["params"])
            opt_state = jax.tree.map(jnp.asarray, state["opt_state"])
            print(f"resumed from step {start} ({found[1]})")

    stream = SyntheticStream(cfg, global_batch, seq_len, seed=seed)
    step_fn = jax.jit(ts.fn, donate_argnums=(0, 1))
    losses = []
    t0 = time.time()
    for step in range(start, steps):
        batch = jax.tree.map(jnp.asarray, stream.batch_at(step))
        if ts.pod_stacked:
            p = ts.fabric.num_pods
            batch = jax.tree.map(
                lambda t: t.reshape(p, t.shape[0] // p, *t.shape[1:]), batch
            )
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(np.mean(np.asarray(metrics["loss"])))
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(np.mean(np.asarray(metrics['grad_norm']))):.3f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if ck and (step + 1) % ckpt_every == 0:
            ck.submit(step + 1, {"params": params, "opt_state": opt_state},
                      extra={"arch": arch})
    if ck:
        ck.submit(steps, {"params": params, "opt_state": opt_state}, extra={"arch": arch})
        ck.close(flush=True)
    return losses, params


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi-9b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--sync", default="allreduce",
                    choices=["allreduce", "gossip", "accel_gossip"])
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="none", choices=["none", "auto"])
    ap.add_argument("--production-mesh", action="store_true")
    a = ap.parse_args(argv)
    losses, _ = train_loop(
        a.arch, smoke=a.smoke, steps=a.steps, global_batch=a.batch,
        seq_len=a.seq, sync_mode=a.sync, pods=a.pods, lr=a.lr,
        ckpt_dir=a.ckpt_dir, ckpt_every=a.ckpt_every, resume=a.resume,
        production_mesh=a.production_mesh,
    )
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
