"""Roofline-term extraction from compiled (SPMD-partitioned) HLO.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically: a 4-iteration scan reports ~1 layer of flops), which would make
every scan-over-layers model look 'free'. This module therefore walks the
HLO text itself:

  * computations are parsed into op lists with output/operand shapes;
  * the call graph is walked from ENTRY with multipliers — ``while`` bodies
    multiply by their ``backend_config known_trip_count`` (XLA records it for
    counted loops, i.e. every lax.scan), fusions/calls/conditionals recurse
    at x1;
  * FLOPs: 2 * prod(out) * prod(contracted dims) per ``dot`` (matmul-dominated
    models; elementwise/transcendental excluded, <1% for these workloads);
  * HBM bytes: sum of operand+output bytes over *fusion-boundary* ops (the
    post-fusion instruction stream is exactly what goes through HBM on TPU);
  * collective wire bytes per device, with ring-algorithm factors:
    all-reduce 2S(n-1)/n, all-gather S_out(n-1)/n, reduce-scatter S_in(n-1)/n,
    all-to-all S(n-1)/n, collective-permute S.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI, ~6.25 GB/s/chip DCN (cross-pod). Collectives whose group size equals
the pod count in a multi-pod lowering are tagged DCN.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["HW", "HloCost", "analyze_hlo", "roofline_report"]

HW = {
    "peak_flops": 197e12,   # bf16 per chip
    "hbm_bw": 819e9,        # bytes/s per chip
    "ici_bw": 50e9,         # bytes/s per link
    "dcn_bw": 6.25e9,       # bytes/s per chip, cross-pod
}

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z]*\d*(?:fn|fnuz)?)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)
# ops that mark fusion boundaries => HBM traffic on their operands/outputs
_TRAFFIC_OPS = {
    "dot", "fusion", "copy", "convolution", "reduce", "transpose", "gather",
    "scatter", "dynamic-slice", "dynamic-update-slice", "broadcast", "reshape",
    "concatenate", "slice", "pad", "reverse", "sort", "rng", "iota", "select",
    "compare", "add", "multiply", "subtract", "divide", "exponential", "tanh",
    "convert", "reduce-window", "cholesky", "triangular-solve",
} | set(_COLLECTIVES)
_FREE_OPS = {"get-tuple-element", "tuple", "parameter", "bitcast", "constant",
             "after-all", "custom-call", "partition-id", "replica-id"}


def _shape_bytes(type_str: str) -> float:
    """Total bytes of one 'dtype[dims]' or a tuple '(t1, t2, ...)' string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    out_type: str
    operands: list[str]
    attrs: str


_COMP_HDR = re.compile(r"^(ENTRY )?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OP_LINE = re.compile(r"^\s+(?:ROOT )?%([\w\.\-]+) = (.+)$")
_OPCODE_RE = re.compile(r"^((?:\([^)]*\))|(?:[a-z0-9_\[\]\{\},\. ]+?))\s+([\w\-]+)\(")


def _parse_computations(text: str) -> tuple[dict, str | None]:
    comps: dict[str, list[_Op]] = {}
    entry = None
    cur: list[_Op] | None = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr:
            name = hdr.group(2)
            comps[name] = []
            cur = comps[name]
            if hdr.group(1):
                entry = name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        om = _OPCODE_RE.match(rhs)
        if not om:
            # ops with no operands, e.g. 'f32[] constant(1)' handled above; skip others
            continue
        out_type, opcode = om.group(1).strip(), om.group(2)
        # operand list: first balanced (...) after opcode
        start = rhs.index(opcode + "(") + len(opcode) + 1
        depth, i = 1, start
        while i < len(rhs) and depth:
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
            i += 1
        inside = rhs[start : i - 1]
        attrs = rhs[i:]
        operands = re.findall(r"%([\w\.\-]+)", inside)
        cur.append(_Op(name, opcode, out_type, operands, attrs))
    return comps, entry


def _group_size(attrs: str) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"source_target_pairs=", attrs)
    if m:
        return 2
    return 2


def _trip_count(attrs: str) -> int:
    m = re.search(r"known_trip_count[^0-9]{0,16}(\d+)", attrs)
    if m:
        return int(m.group(1))
    return 1


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0                 # per device
    hbm_bytes: float = 0.0             # per device (fusion-boundary estimate)
    wire_bytes_ici: float = 0.0        # per device
    wire_bytes_dcn: float = 0.0        # per device (pod-axis collectives)
    collectives: dict = dataclasses.field(default_factory=dict)  # kind -> bytes
    collective_counts: dict = dataclasses.field(default_factory=dict)
    dots: int = 0

    def terms(self, hw: dict = HW) -> dict:
        t_c = self.flops / hw["peak_flops"]
        t_m = self.hbm_bytes / hw["hbm_bw"]
        t_net = self.wire_bytes_ici / hw["ici_bw"] + self.wire_bytes_dcn / hw["dcn_bw"]
        dom = max((t_c, "compute"), (t_m, "memory"), (t_net, "collective"))[1]
        return {
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_net,
            "bound": dom,
            "step_s": max(t_c, t_m, t_net),
        }


def _wire_bytes(kind: str, in_b: float, out_b: float, n: int) -> float:
    if n <= 1:
        return 0.0
    f = (n - 1) / n
    if kind == "all-reduce":
        return 2 * out_b * f
    if kind == "all-gather":
        return out_b * f
    if kind == "reduce-scatter":
        return in_b * f
    if kind in ("all-to-all", "ragged-all-to-all"):
        return in_b * f
    return out_b  # collective-permute / broadcast


def analyze_hlo(text: str, num_pods: int = 1) -> HloCost:
    comps, entry = _parse_computations(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    cost = HloCost()
    colls = defaultdict(float)
    counts = defaultdict(int)

    def shape_of(comp_ops: dict[str, _Op], name: str) -> str:
        op = comp_ops.get(name)
        return op.out_type if op else ""

    def walk(comp_name: str, mult: float, seen: tuple = (), kernel: bool = False):
        """kernel=True: inside a Pallas interpret body — its fusions/copies
        are VMEM traffic on real TPU, so only dot FLOPs are counted there;
        the kernel's HBM traffic is charged once at the grid-loop call site
        (operand/result block transfers)."""
        ops = comps.get(comp_name)
        if ops is None or comp_name in seen:
            return
        sym = {o.name: o for o in ops}
        for o in ops:
            out_b = _shape_bytes(o.out_type)
            in_b = sum(_shape_bytes(shape_of(sym, x)) for x in o.operands)
            if o.opcode == "dot":
                out_dims = _shape_dims(o.out_type)
                lhs = sym.get(o.operands[0])
                m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", o.attrs)
                k = 1
                if lhs is not None and m and m.group(1):
                    ldims = _shape_dims(lhs.out_type)
                    for d in m.group(1).split(","):
                        k *= ldims[int(d)]
                f = 2.0
                for d in out_dims:
                    f *= d
                cost.flops += f * k * mult  # 2*prod(out)*K
                cost.dots += int(mult)
            if o.opcode in _COLLECTIVES and not kernel:
                n = _group_size(o.attrs)
                wb = _wire_bytes(o.opcode, in_b, out_b, n) * mult
                colls[o.opcode] += wb
                counts[o.opcode] += int(mult)
                if num_pods > 1 and n == num_pods:
                    cost.wire_bytes_dcn += wb
                else:
                    cost.wire_bytes_ici += wb
            # HBM traffic: op-specific — indexed ops touch only the slice,
            # not the full operand (dynamic-slice inside a grid/scan loop
            # would otherwise count the whole buffer per iteration).
            if kernel:
                pass  # VMEM-level ops inside a Pallas body: no HBM charge
            elif o.opcode == "dynamic-slice":
                cost.hbm_bytes += 2 * out_b * mult
            elif o.opcode == "dynamic-update-slice":
                upd = _shape_bytes(shape_of(sym, o.operands[1])) if len(o.operands) > 1 else out_b
                cost.hbm_bytes += 2 * upd * mult
            elif o.opcode == "gather":
                cost.hbm_bytes += 2 * out_b * mult
            elif o.opcode == "scatter":
                upd = _shape_bytes(shape_of(sym, o.operands[2])) if len(o.operands) > 2 else out_b
                cost.hbm_bytes += 2 * upd * mult
            elif o.opcode in ("broadcast", "iota"):
                cost.hbm_bytes += out_b * mult
            elif o.opcode not in _FREE_OPS:
                cost.hbm_bytes += (out_b + in_b) * mult
            # recursion
            if o.opcode == "while":
                tc = _trip_count(o.attrs)
                m = re.search(r"body=%([\w\.\-]+)", o.attrs)
                body = m.group(1) if m else ""
                into_kernel = "_custom_call_lowering_rul" in body
                if into_kernel and not kernel:
                    # Pallas grid loop: charge block I/O once (operand +
                    # result arrays stream HBM<->VMEM exactly once per call)
                    cost.hbm_bytes += (in_b + out_b) * mult
                for key in ("body", "condition"):
                    m = re.search(key + r"=%([\w\.\-]+)", o.attrs)
                    if m:
                        walk(m.group(1), mult * tc, seen + (comp_name,),
                             kernel=kernel or into_kernel)
            elif o.opcode in ("call", "conditional", "async-start"):
                for m in re.finditer(r"(?:to_apply|branch_computations=\{|called_computations=\{)[%]?([\w\.\-]+)", o.attrs):
                    into_kernel = "_custom_call_lowering_rul" in m.group(1)
                    if into_kernel and not kernel:
                        cost.hbm_bytes += (in_b + out_b) * mult
                    walk(m.group(1), mult, seen + (comp_name,),
                         kernel=kernel or into_kernel)
            elif o.opcode == "fusion":
                m = re.search(r"calls=%([\w\.\-]+)", o.attrs)
                if m:
                    _walk_fusion_flops(m.group(1), mult, seen + (comp_name,))

    def _walk_fusion_flops(comp_name: str, mult: float, seen: tuple):
        """Inside fusions only dots matter (internal traffic is VMEM)."""
        ops = comps.get(comp_name)
        if ops is None or comp_name in seen:
            return
        sym = {o.name: o for o in ops}
        for o in ops:
            if o.opcode == "dot":
                out_dims = _shape_dims(o.out_type)
                lhs = sym.get(o.operands[0])
                m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", o.attrs)
                k = 1
                if lhs is not None and m and m.group(1):
                    ldims = _shape_dims(lhs.out_type)
                    for d in m.group(1).split(","):
                        k *= ldims[int(d)]
                f = 2.0
                for d in out_dims:
                    f *= d
                cost.flops += f * k * mult
                cost.dots += int(mult)
            elif o.opcode == "fusion":
                m = re.search(r"calls=%([\w\.\-]+)", o.attrs)
                if m:
                    _walk_fusion_flops(m.group(1), mult, seen + (comp_name,))

    walk(entry, 1.0)
    cost.collectives = dict(colls)
    cost.collective_counts = dict(counts)
    return cost


def roofline_report(cost: HloCost, chips: int, model_flops_global: float | None,
                    hw: dict = HW) -> dict:
    terms = cost.terms(hw)
    rep = {
        "chips": chips,
        "flops_per_device": cost.flops,
        "hbm_bytes_per_device": cost.hbm_bytes,
        "wire_ici_per_device": cost.wire_bytes_ici,
        "wire_dcn_per_device": cost.wire_bytes_dcn,
        **terms,
        "collectives": cost.collectives,
        "collective_counts": cost.collective_counts,
    }
    if model_flops_global:
        hlo_global = cost.flops * chips
        rep["model_flops_global"] = model_flops_global
        rep["useful_flop_ratio"] = model_flops_global / max(hlo_global, 1.0)
        # roofline fraction: useful model flops per device-second at the
        # achieved (bound-limited) step time
        rep["roofline_fraction"] = (
            model_flops_global / chips / hw["peak_flops"] / max(terms["step_s"], 1e-30)
        )
    return rep
