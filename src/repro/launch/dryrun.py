import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "").replace(
        "--xla_force_host_platform_device_count=512", ""
    )
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds the exact production program — train step
(grad accumulation, optimizer, gradient sync mode), prefill, or decode — as
abstract ShapeDtypeStructs with production NamedShardings, then:

    lowered  = jax.jit(step).lower(*input_specs(...))
    compiled = lowered.compile()
    memory   = compiled.memory_analysis()     # proves it fits
    roofline = analyze_hlo(compiled.as_text())  # FLOPs/bytes/collectives

and writes one JSON record per cell under --out. The (16,16) single-pod mesh
is the roofline table; the (2,16,16) multi-pod mesh proves the 'pod' axis
(consensus fabric) shards. Failures here are bugs in the system.

Run a single cell:   python -m repro.launch.dryrun --arch yi-9b --shape train_4k
Run everything:      python -m repro.launch.dryrun --all [--jobs N]
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback

import jax

# Workaround: the Shardy partitioner crashes (C++ CHECK in
# PartitionGather/ExpandDeviceGroupsWithIota) on embedding gathers inside the
# pod-manual shard_map on the 3-axis 512-chip mesh; GSPMD classic handles the
# same programs. Tracked as an XLA bug; revisit on newer jaxlibs.
jax.config.update("jax_use_shardy_partitioner", False)

import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, SHAPES, applicable, get_config
from ..dist import SyncConfig, make_train_step
from ..dist import sharding as shd
from ..models import build
from .. import optim
from .mesh import make_production_mesh
from .roofline import HW, analyze_hlo, roofline_report

__all__ = ["input_specs", "dryrun_cell", "main"]

# params above this bf16-bytes-per-chip budget keep FSDP for serving
SERVE_TP_HBM_BUDGET = 8e9


def _model_flops(cfg, shape) -> float:
    """Standard 6ND (train) / 2ND (inference) useful-FLOPs yardstick."""
    n = cfg.active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def input_specs(arch: str, shape_name: str, multi_pod: bool, sync_mode: str = "accel_gossip",
                pad_heads: int = 0):
    """(step_fn, arg specs tuple, metadata) for one dry-run cell."""
    cfg = get_config(arch)
    if pad_heads:
        cfg = dataclasses.replace(cfg, tp_pad_heads=pad_heads)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build(cfg)
    num_pods = 2 if multi_pod else 1

    if shape.kind == "train":
        opt = optim.for_config(cfg)
        ts = make_train_step(
            model, opt, mesh,
            SyncConfig(mode=sync_mode if multi_pod else "allreduce"),
            shape.global_batch, shape.seq_len, grad_accum=cfg.grad_accum,
        )
        meta = {"rounds": ts.rounds, "pod_stacked": ts.pod_stacked,
                "grad_accum": cfg.grad_accum, "sync": sync_mode if multi_pod else "allreduce"}
        return ts.fn, (ts.params_sharding, ts.opt_sharding, ts.batch_sharding), meta

    # serving: params bf16; pure-TP when the model fits, else FSDP+TP
    tp = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    pure_tp = cfg.num_params() * 2 / tp <= SERVE_TP_HBM_BUDGET
    rules = shd.serving_rules() if pure_tp else None
    params = shd.abstract_params(model.param_specs, mesh, dtype=jnp.bfloat16, rules=rules)
    act = shd.make_activations(mesh, include_pod=True)
    meta = {"serving_layout": "tp" if pure_tp else "fsdp+tp"}

    if shape.kind == "prefill":
        batch_tree = {
            k: v for k, v in model.batch_spec(shape.global_batch, shape.seq_len).items()
            if k != "labels"
        }
        batch = shd.abstract_tree(batch_tree, mesh)

        def step(p, b):
            return model.prefill(p, b, shape.seq_len, act)

        return step, (params, batch), meta

    # decode: one new token against a seq_len cache
    cache = shd.abstract_tree(model.cache_specs(shape.global_batch, shape.seq_len), mesh)
    if cfg.num_heads:
        # pin expanded K/V to the cache storage sharding (see make_activations)
        s_len = min(cfg.sliding_window or shape.seq_len, shape.seq_len)
        kv_spec = shd.partition_spec(
            (shape.global_batch, s_len, cfg.physical_kv_heads, cfg.resolved_head_dim),
            ("batch", "cache_seq", "kv_heads", "head_dim"), mesh,
        )
        act = shd.make_activations(mesh, include_pod=True, kv_spec=kv_spec)
    bspec = shd.batch_pspecs(
        {"tokens": ((shape.global_batch, 1), ("batch", None), jnp.int32)}, mesh
    )["tokens"]
    token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32,
                                 sharding=NamedSharding(mesh, bspec))
    pos = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32,
                               sharding=NamedSharding(mesh, P()))

    def step(p, tok, pos_, c):
        return model.decode(p, tok, pos_, c, act)

    return step, (params, token, pos, cache), meta


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool,
                sync_mode: str = "accel_gossip", verbose: bool = True,
                pad_heads: int = 0) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "params": cfg.num_params(),
        "active_params": cfg.active_params(),
    }
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    chips = 512 if multi_pod else 256
    t0 = time.time()
    try:
        step, specs, meta = input_specs(arch, shape_name, multi_pod, sync_mode, pad_heads)
        rec.update(meta)
        # donate params/opt-state (train) or cache (decode): in-place updates
        donate = ()
        if shape.kind == "train":
            donate = (0, 1)
        elif shape.kind == "decode":
            donate = (3,)
        lowered = jax.jit(step, donate_argnums=donate).lower(*specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        mem["total_hbm_bytes"] = (
            mem["argument_bytes"] + mem["output_bytes"] + mem["temp_bytes"]
            - mem["alias_bytes"]
        )
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # jax<=0.4.x returns [dict]
            ca = ca[0] if ca else {}
        cost = analyze_hlo(compiled.as_text(), num_pods=2 if multi_pod else 1)
        rep = roofline_report(cost, chips, _model_flops(cfg, shape))
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=mem,
            xla_cost_analysis_flops=float(ca.get("flops", -1.0)),
            roofline=rep,
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    if verbose:
        _print_cell(rec)
    return rec


def _print_cell(rec: dict) -> None:
    if rec["status"] == "ok":
        r = rec["roofline"]
        print(
            f"OK   {rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:10s} "
            f"hbm={rec['memory']['total_hbm_bytes']/2**30:6.1f}GiB "
            f"bound={r['bound']:10s} "
            f"tc={r['compute_s']:.3e} tm={r['memory_s']:.3e} tn={r['collective_s']:.3e} "
            f"roofline={r.get('roofline_fraction', 0):.3f} "
            f"compile={rec['compile_s']:.0f}s",
            flush=True,
        )
    elif rec["status"] == "skipped":
        print(f"SKIP {rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:10s} {rec['reason']}",
              flush=True)
    else:
        print(f"FAIL {rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:10s} {rec['error']}",
              flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--sync", default="accel_gossip",
                    choices=["allreduce", "gossip", "accel_gossip"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=1,
                    help="parallel worker subprocesses for --all")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--pad-heads", type=int, default=0,
                    help="SPerf knob: pad head counts to this TP degree")
    args = ap.parse_args(argv)

    cells = []
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    os.makedirs(args.out, exist_ok=True)
    if len(cells) > 1:
        # one subprocess per cell: an XLA C++ CHECK failure (hard abort) in
        # one cell must not take down the rest of the sweep
        return _run_parallel(cells, args)

    failures = 0
    for a, s, m in cells:
        rec = dryrun_cell(a, s, m, args.sync, pad_heads=args.pad_heads)
        fname = f"{a}__{s}__{'multi' if m else 'single'}__{args.sync}.json"
        with open(os.path.join(args.out, fname), "w") as f:
            json.dump(rec, f, indent=1)
        failures += rec["status"] == "error"
    return 1 if failures else 0


def _run_parallel(cells, args) -> int:
    """Each cell in its own subprocess (isolated XLA heap), --jobs at a time.

    A child killed by an XLA CHECK abort leaves no JSON; record the abort."""
    procs: list = []
    failures = 0
    queue = list(cells)
    while queue or procs:
        while queue and len(procs) < args.jobs:
            a, s, m = queue.pop(0)
            fname = os.path.join(
                args.out, f"{a}__{s}__{'multi' if m else 'single'}__{args.sync}.json"
            )
            if os.path.exists(fname):
                with open(fname) as f:
                    if json.load(f).get("status") in ("ok", "skipped"):
                        continue  # incremental: keep prior good results
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", a, "--shape", s,
                "--mesh", "multi" if m else "single",
                "--sync", args.sync, "--out", args.out,
            ]
            procs.append((subprocess.Popen(cmd), a, s, m, fname))
        still = []
        for p, a, s, m, fname in procs:
            if p.poll() is None:
                still.append((p, a, s, m, fname))
                continue
            if p.returncode != 0:
                failures += 1
                if not os.path.exists(fname):  # hard abort: no JSON written
                    with open(fname, "w") as f:
                        json.dump({
                            "arch": a, "shape": s,
                            "mesh": "pod2x16x16" if m else "pod16x16",
                            "status": "error",
                            "error": f"subprocess aborted rc={p.returncode} "
                                     "(XLA CHECK failure)",
                        }, f, indent=1)
                    print(f"ABRT {a:24s} {s:12s} rc={p.returncode}", flush=True)
        procs = still
        time.sleep(0.5)
    print(f"dry-run sweep complete: {failures} failures", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
