"""Serving driver: continuous-batching decode engine demo.

    PYTHONPATH=src python -m repro.launch.serve --arch minicpm-2b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..models import build
from ..serve import DecodeEngine, Request


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="minicpm-2b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=16)
    a = ap.parse_args(argv)

    cfg = get_config(a.arch, smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = DecodeEngine(model, params, max_batch=a.max_batch, max_seq=a.max_seq)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(a.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=(4 + rid % 13,)).astype(np.int32)
        eng.submit(Request(rid, prompt, max_new_tokens=a.new_tokens))
    done = eng.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s, continuous batching over "
          f"{a.max_batch} slots)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
