"""Launch layer: production meshes, multi-pod dry-run, train/serve drivers,
roofline extraction. NOTE: import ``dryrun`` only as __main__ or in a fresh
process — it forces 512 host devices and disables the Shardy partitioner."""
from . import mesh, roofline
from .mesh import make_cpu_mesh, make_production_mesh

__all__ = ["mesh", "roofline", "make_cpu_mesh", "make_production_mesh"]
