"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is the
consensus fabric (DCN), 'data'/'model' stay on ICI.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before first jax init, everything else sees the real device count.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_cpu_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh(pods: int = 1):
    """Degenerate mesh for CPU examples/tests on however many host devices
    are available (1 by default; tests force more via XLA_FLAGS)."""
    n = jax.device_count()
    if pods > 1:
        if n % pods:
            raise ValueError(f"{n} devices not divisible into {pods} pods")
        return jax.make_mesh((pods, n // pods, 1), ("pod", "data", "model"))
    return jax.make_mesh((n, 1), ("data", "model"))
