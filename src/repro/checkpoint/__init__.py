from .store import AsyncCheckpointer, latest_valid, restore, save

__all__ = ["AsyncCheckpointer", "latest_valid", "restore", "save"]
