"""Fault-tolerant checkpointing: atomic, CRC-verified, async, retained.

Layout (one directory per step):

    <root>/step_00001000/
        manifest.json     # step, flat key list, shapes, dtypes, crc32s, extra
        <flat_key>.npy    # one file per leaf (params + optimizer state)

Durability protocol:
  * write into ``step_X.tmp``, fsync files, atomically rename to ``step_X``
    (a crashed writer can never produce a dir that *looks* complete);
  * every leaf carries a CRC32 checked on restore; a corrupt/partial step is
    skipped and the previous one used (``latest_valid``);
  * ``AsyncCheckpointer`` runs saves on a worker thread off the train loop's
    critical path, coalescing to the newest pending request;
  * retention keeps the last ``keep`` checkpoints (never deleting the newest
    valid one).

Multi-host note: in a real deployment each host writes only its addressable
shards and the manifest carries the global sharding; this single-process repo
gathers leaves to host memory (np.asarray) — the protocol is unchanged.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import zlib
from typing import Any

import jax
import numpy as np

PyTree = Any

__all__ = ["save", "restore", "latest_valid", "AsyncCheckpointer"]

_MANIFEST = "manifest.json"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}

    def walk(t, path):
        if isinstance(t, dict):
            for k in sorted(t):
                walk(t[k], path + (str(k),))
        else:
            flat["/".join(path)] = np.asarray(t)

    walk(tree, ())
    return flat


def _unflatten(flat: dict[str, np.ndarray]) -> PyTree:
    root: dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).view(np.uint8).tobytes())


def save(root: str, step: int, state: PyTree, extra: dict | None = None) -> str:
    """Atomic checkpoint write. Returns the final directory path."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for key, arr in flat.items():
        fname = key.replace("/", "__") + ".npy"
        fpath = os.path.join(tmp, fname)
        with open(fpath, "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": _crc(arr),
        }
    mpath = os.path.join(tmp, _MANIFEST)
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _validate(path: str) -> dict | None:
    mpath = os.path.join(path, _MANIFEST)
    if not os.path.exists(mpath):
        return None
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        for key, meta in manifest["leaves"].items():
            arr = np.load(os.path.join(path, meta["file"]))
            if _crc(arr) != meta["crc32"]:
                return None
        return manifest
    except Exception:
        return None


def latest_valid(root: str) -> tuple[int, str] | None:
    """Newest step whose manifest + CRCs verify; skips corrupt/partial dirs."""
    if not os.path.isdir(root):
        return None
    dirs = sorted(
        (d for d in os.listdir(root) if d.startswith("step_") and not d.endswith(".tmp")),
        reverse=True,
    )
    for d in dirs:
        path = os.path.join(root, d)
        if _validate(path) is not None:
            return int(d.split("_")[1]), path
    return None


def restore(path: str) -> tuple[int, PyTree, dict]:
    """Load a verified checkpoint. Returns (step, state, extra)."""
    manifest = _validate(path)
    if manifest is None:
        raise IOError(f"checkpoint at {path} failed validation")
    flat = {
        key: np.load(os.path.join(path, meta["file"]))
        for key, meta in manifest["leaves"].items()
    }
    return manifest["step"], _unflatten(flat), manifest.get("extra", {})


def _retain(root: str, keep: int) -> None:
    entries = sorted(d for d in os.listdir(root) if d.startswith("step_") and not d.endswith(".tmp"))
    for d in entries[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(root, d), ignore_errors=True)


@dataclasses.dataclass
class AsyncCheckpointer:
    """Off-critical-path checkpoint writer with retention."""

    root: str
    keep: int = 3

    def __post_init__(self):
        self._lock = threading.Lock()
        self._pending: tuple | None = None
        self._event = threading.Event()
        self._stop = False
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def submit(self, step: int, state: PyTree, extra: dict | None = None) -> None:
        """Snapshot to host memory now; write in the background. Coalesces to
        the newest pending request (bounded memory under bursty submits)."""
        host_state = jax.tree.map(lambda t: np.asarray(t), state)
        with self._lock:
            self._pending = (step, host_state, extra)
        self._event.set()

    def _worker(self):
        while True:
            self._event.wait()
            self._event.clear()
            if self._stop:
                return
            with self._lock:
                req, self._pending = self._pending, None
            if req is None:
                continue
            step, state, extra = req
            save(self.root, step, state, extra)
            _retain(self.root, self.keep)

    def close(self, flush: bool = True):
        if flush:
            while True:
                with self._lock:
                    if self._pending is None:
                        break
                self._event.set()
                threading.Event().wait(0.01)
        self._stop = True
        self._event.set()
        self._thread.join(timeout=10)
