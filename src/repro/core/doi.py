"""Algorithm 1 — decentralized estimation of lambda_2(W) (Section III-D).

The paper's streamlined decentralized orthogonal iteration (DOI):

  1. draw a random vector v;
  2. v_0 = W v - v            (exactly zero-mean: 1^T W = 1^T kills the bias);
  3. for k = 1..K: v_k = W v_{k-1}; every L steps normalize by ||v_k||_inf,
     where the sup-norm is computed by *max-consensus* (exact agreement after
     D = diameter iterations — every node ends up normalizing by the SAME
     number, unlike the l2-consensus of Kempe-McSherry / Boyd et al.);
  4. lambda2_hat = ||W v_K||_inf / ||v_K||_inf     (Gelfand).

Communication cost: K consensus ticks + (K/L) max-consensus phases of D ticks
+ one final max-consensus  =>  K + D K / L + D.  With L ~ D this is O(K),
vs O(K^2) for the prior DOI variants — the paper's initialization selling point.

This module simulates the algorithm faithfully at the network level (numpy);
``repro.dist.gossip.distributed_lambda2`` runs the same algorithm *inside* a
jitted SPMD program over a mesh axis, and ``repro.core.algorithms``'s
``accel_adapt`` carries the same recursion as auxiliary scan state (the
``sup_normalize`` / ``gelfand_quotient`` primitives below are backend-
agnostic — pass ``xp=jax.numpy`` to trace them — so all three layers share
one definition of Algorithm 1's normalization and Gelfand extraction).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from .topology import Graph, diameter

__all__ = [
    "DoiResult",
    "estimate_lambda2",
    "doi_cost",
    "max_consensus_rounds",
    "sup_normalize",
    "gelfand_quotient",
]


def sup_normalize(v, axis=None, xp=np):
    """Algorithm 1 step 3: normalize by ||v||_inf, guarding the zero vector.

    In the network the sup-norm is a max-consensus; here it is an ``xp.max``.
    ``axis`` (with keepdims) supports batched carries, e.g. per-cell
    normalization of a (G, N, F) probe block with ``axis=(1, 2)``.
    Backend-agnostic: ``xp=np`` on the host, ``xp=jax.numpy`` in a scan.
    """
    norm = xp.max(xp.abs(v), axis=axis, keepdims=axis is not None)
    return v / xp.where(norm > 0, norm, xp.ones_like(norm))


def gelfand_quotient(wv, v, axis=None, xp=np):
    """Algorithm 1 step 4: lambda2_hat = ||W v||_inf / ||v||_inf (Gelfand).

    Returns 0 where ``v`` has collapsed to zero (the estimate is undefined;
    callers treat 0 as "no information"). Same batching/backed conventions
    as :func:`sup_normalize`, without keepdims (the quotient is a scalar
    per reduced block).
    """
    num = xp.max(xp.abs(wv), axis=axis)
    den = xp.max(xp.abs(v), axis=axis)
    return xp.where(den > 0, num / xp.where(den > 0, den, xp.ones_like(den)),
                    xp.zeros_like(den))


@dataclasses.dataclass(frozen=True)
class DoiResult:
    lambda2_hat: float
    num_consensus_ticks: int      # applications of W (one neighbour exchange each)
    num_max_consensus_ticks: int  # max-consensus iterations (neighbour max each)
    v_final: np.ndarray

    @property
    def total_ticks(self) -> int:
        return self.num_consensus_ticks + self.num_max_consensus_ticks


def max_consensus_rounds(graph: Graph) -> int:
    """Exact max-consensus needs diameter(G) neighbour-max iterations."""
    return diameter(graph.adjacency)


def estimate_lambda2(
    w: np.ndarray,
    graph: Graph,
    num_iters: int,
    normalize_every: int = 10,
    rng: np.random.Generator | None = None,
    v_init: np.ndarray | None = None,
    matvec: Callable[[np.ndarray], np.ndarray] | None = None,
) -> DoiResult:
    """Run Algorithm 1. ``num_iters`` is K; ``normalize_every`` is L.

    The max-consensus cost is charged as D ticks per normalization (the
    simulation computes the exact max directly — max-consensus converges to
    exactly that value, so the simulation is faithful; the *cost model* is
    where D enters).

    ``matvec`` overrides the ``w @ v`` application — pass
    ``repro.dist.gossip.fabric_matvec(w)`` to reproduce the in-mesh
    ``distributed_lambda2`` accumulation order bit-for-bit.
    """
    rng = rng if rng is not None else np.random.default_rng(0)
    n = w.shape[0]
    d = diameter(graph.adjacency)
    mv = matvec if matvec is not None else (lambda v: w @ v)

    v = v_init if v_init is not None else rng.standard_normal(n)
    # Line 2: exactly zero-mean start (one consensus tick).
    v = mv(v) - v
    ticks_w = 1
    ticks_max = 0

    for k in range(1, num_iters + 1):
        v = mv(v)
        ticks_w += 1
        if k % normalize_every == 0:
            v = sup_normalize(v)  # sup-norm via max-consensus: D ticks
            ticks_max += d
    wv = mv(v)
    ticks_w += 1
    ticks_max += 2 * d  # two sup-norms (can be pipelined; charge both)
    lam_hat = float(gelfand_quotient(wv, v))
    return DoiResult(
        lambda2_hat=lam_hat,
        num_consensus_ticks=ticks_w,
        num_max_consensus_ticks=ticks_max,
        v_final=v,
    )


def doi_cost(num_iters: int, normalize_every: int, diam: int) -> int:
    """Paper's cost model: K + D*K/L + D ticks (Section III-D)."""
    return int(num_iters + diam * num_iters / normalize_every + diam)
