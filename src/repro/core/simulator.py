"""High-throughput consensus simulation engine.

The paper's numerical experiments (Section IV) iterate x(t+1) = W x(t) or the
accelerated recursion over hundreds of trials x thousands of iterations. This
module provides a vectorized engine that runs *all trials at once* as an
(N, F) block (F = number of trials / feature columns), with three backends:

* ``numpy``  — float64, reference semantics (the theory layer's arithmetic);
* ``jax``    — jitted lax.scan over iterations, fp32 by default;
* ``pallas`` — same scan but the W @ X product and the fused two-tap update run
  through the Pallas kernels in ``repro.kernels`` (interpret mode on CPU,
  compiled VMEM-tiled kernels on TPU).

Returns per-iteration MSE trajectories without materializing the full state
history (the scan carries only (x, x_prev)).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import numpy as np

from .accel import Theta

__all__ = ["SimResult", "simulate", "simulate_memoryless", "simulate_accelerated"]

Backend = Literal["numpy", "jax", "pallas"]


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Final state + per-iteration mean-squared-error trajectory."""

    x_final: np.ndarray      # (N, F)
    mse: np.ndarray          # (T+1, F): MSE vs the true initial average, per trial

    @property
    def num_iters(self) -> int:
        return len(self.mse) - 1


def _mse_to_target(x: np.ndarray, xbar: np.ndarray) -> np.ndarray:
    d = x - xbar
    return (d * d).mean(axis=0)


def simulate_memoryless(
    w: np.ndarray,
    x0: np.ndarray,
    num_iters: int,
    backend: Backend = "numpy",
) -> SimResult:
    return simulate(w, x0, num_iters, alpha=0.0, theta=None, backend=backend)


def simulate_accelerated(
    w: np.ndarray,
    x0: np.ndarray,
    num_iters: int,
    alpha: float,
    theta: Theta,
    backend: Backend = "numpy",
) -> SimResult:
    return simulate(w, x0, num_iters, alpha=alpha, theta=theta, backend=backend)


def simulate(
    w: np.ndarray,
    x0: np.ndarray,
    num_iters: int,
    alpha: float = 0.0,
    theta: Theta | None = None,
    backend: Backend = "numpy",
) -> SimResult:
    """Run ``num_iters`` consensus rounds on an (N,) or (N, F) initial block.

    alpha = 0 (or theta None) gives memoryless consensus; otherwise the
    two-tap accelerated recursion with mixing parameter alpha.
    """
    x0 = np.asarray(x0)
    squeeze = x0.ndim == 1
    if squeeze:
        x0 = x0[:, None]
    xbar = x0.mean(axis=0, keepdims=True) * np.ones_like(x0)

    if theta is None or alpha == 0.0:
        a_w, b_x, c_p = 1.0, 0.0, 0.0
    else:
        a_w = 1.0 - alpha + alpha * theta.t3
        b_x = alpha * theta.t2
        c_p = alpha * theta.t1

    if backend == "numpy":
        x = x0.astype(np.float64)
        xp = x.copy()
        wd = w.astype(np.float64)
        mse = [_mse_to_target(x, xbar)]
        for _ in range(num_iters):
            xw = wd @ x
            x, xp = a_w * xw + b_x * x + c_p * xp, x
            mse.append(_mse_to_target(x, xbar))
        out_x, out_mse = x, np.stack(mse)
    elif backend in ("jax", "pallas"):
        out_x, out_mse = _simulate_jax(
            w, x0, xbar, num_iters, a_w, b_x, c_p, use_kernels=(backend == "pallas")
        )
        out_x, out_mse = np.asarray(out_x), np.asarray(out_mse)
    else:
        raise ValueError(f"unknown backend {backend!r}")

    if squeeze:
        out_x = out_x[:, 0]
    return SimResult(x_final=out_x, mse=out_mse)


@functools.partial(
    __import__("jax").jit,
    static_argnames=("num_iters", "use_kernels"),
)
def _simulate_jax(w, x0, xbar, num_iters, a_w, b_x, c_p, use_kernels=False):
    import jax
    import jax.numpy as jnp

    w = jnp.asarray(w, dtype=jnp.float32)
    x0 = jnp.asarray(x0, dtype=jnp.float32)
    xbar = jnp.asarray(xbar, dtype=jnp.float32)
    coef = (jnp.float32(a_w), jnp.float32(b_x), jnp.float32(c_p))

    if use_kernels:
        from repro.kernels import ops as kops

        def matvec(m, v):
            return kops.gossip_matvec(m, v)

        def fma(xw, x, xp):
            return kops.consensus_update(xw, x, xp, *coef)
    else:
        def matvec(m, v):
            return m @ v

        def fma(xw, x, xp):
            return coef[0] * xw + coef[1] * x + coef[2] * xp

    def body(carry, _):
        x, xp = carry
        xw = matvec(w, x)
        x_new = fma(xw, x, xp)
        d = x_new - xbar
        return (x_new, x), (d * d).mean(axis=0)

    (x_fin, _), mse_tail = jax.lax.scan(body, (x0, x0), None, length=num_iters)
    d0 = x0 - xbar
    mse0 = (d0 * d0).mean(axis=0)
    return x_fin, jnp.concatenate([mse0[None], mse_tail], axis=0)
