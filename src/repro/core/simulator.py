"""High-throughput consensus simulation engine.

The paper's numerical experiments (Section IV) iterate x(t+1) = W x(t) or the
accelerated recursion over hundreds of trials x thousands of iterations. This
module provides a vectorized engine that runs *all trials at once* as an
(N, F) block (F = number of trials / feature columns), with three backends:

* ``numpy``  — float64, reference semantics (the theory layer's arithmetic);
* ``jax``    — jitted lax.scan over iterations, fp32 by default;
* ``pallas`` — same scan but each round runs through the FUSED Pallas
  gossip-round kernel (``repro.kernels.gossip_round``): matvec accumulation
  and the two-tap FMA in one kernel launch, no intermediate x_w in HBM
  (interpret mode on CPU, compiled VMEM-tiled on TPU).

The jax/pallas backends are the degenerate G=1 case of the batched sweep
engine (``repro.sweep.engine``) — one code path from single-config debugging
runs to device-saturating ensemble grids.

Returns per-iteration MSE trajectories without materializing the full state
history (the scan carries only (x, x_prev)).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import numpy as np

from .accel import Theta

__all__ = ["SimResult", "simulate", "simulate_memoryless", "simulate_accelerated"]

Backend = Literal["numpy", "jax", "pallas"]


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Final state + per-iteration mean-squared-error trajectory."""

    x_final: np.ndarray      # (N, F)
    mse: np.ndarray          # (T+1, F): MSE vs the true initial average, per trial

    @property
    def num_iters(self) -> int:
        return len(self.mse) - 1


def _mse_to_target(x: np.ndarray, xbar: np.ndarray) -> np.ndarray:
    d = x - xbar
    return (d * d).mean(axis=0)


def simulate_memoryless(
    w: np.ndarray,
    x0: np.ndarray,
    num_iters: int,
    backend: Backend = "numpy",
) -> SimResult:
    return simulate(w, x0, num_iters, alpha=0.0, theta=None, backend=backend)


def simulate_accelerated(
    w: np.ndarray,
    x0: np.ndarray,
    num_iters: int,
    alpha: float,
    theta: Theta,
    backend: Backend = "numpy",
) -> SimResult:
    return simulate(w, x0, num_iters, alpha=alpha, theta=theta, backend=backend)


def simulate(
    w: np.ndarray,
    x0: np.ndarray,
    num_iters: int,
    alpha: float = 0.0,
    theta: Theta | None = None,
    backend: Backend = "numpy",
) -> SimResult:
    """Run ``num_iters`` consensus rounds on an (N,) or (N, F) initial block.

    alpha = 0 gives memoryless consensus; otherwise the two-tap accelerated
    recursion with mixing parameter alpha (theta required: a non-zero alpha
    without a predictor design is a mis-wired cell, not a baseline).
    """
    if backend not in ("numpy", "jax", "pallas"):
        raise ValueError(f"unknown backend {backend!r}")  # before any array work
    if theta is None and alpha != 0.0:
        # refuse to silently decay to the memoryless baseline: a design that
        # lost its theta would otherwise masquerade as a converged baseline
        raise ValueError(
            f"alpha={alpha} with theta=None: the two-tap recursion needs a "
            f"predictor design (pass theta=, or alpha=0.0 for memoryless)")
    x0 = np.asarray(x0)
    squeeze = x0.ndim == 1
    if squeeze:
        x0 = x0[:, None]

    if theta is None or alpha == 0.0:
        a_w, b_x, c_p = 1.0, 0.0, 0.0
    else:
        a_w = 1.0 - alpha + alpha * theta.t3
        b_x = alpha * theta.t2
        c_p = alpha * theta.t1

    if backend == "numpy":
        xbar = x0.mean(axis=0, keepdims=True) * np.ones_like(x0)
        x = x0.astype(np.float64)
        xp = x.copy()
        wd = w.astype(np.float64)
        mse = [_mse_to_target(x, xbar)]
        for _ in range(num_iters):
            xw = wd @ x
            x, xp = a_w * xw + b_x * x + c_p * xp, x
            mse.append(_mse_to_target(x, xbar))
        out_x, out_mse = x, np.stack(mse)
    else:
        # jax/pallas: the degenerate G=1 sweep through the batched engine —
        # single-config simulation and paper-scale grids share one jitted
        # scan (and its compilation cache). Import here: sweep sits above
        # core in the layer order.
        from repro.sweep import engine as sweep_engine

        x_fin, mse = sweep_engine.run_batch(
            np.asarray(w)[None],
            x0[None],
            np.asarray([[a_w, b_x, c_p]], dtype=np.float32),
            num_iters=num_iters,
            backend=backend,
        )
        out_x, out_mse = x_fin[0], mse[0]

    if squeeze:
        out_x = out_x[:, 0]
    return SimResult(x_final=out_x, mse=out_mse)
