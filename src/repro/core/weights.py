"""Consensus weight-matrix constructions.

The paper assumes a "foundational weight matrix" W that is doubly stochastic,
symmetric, satisfies W1 = 1, and rho(W - J) < 1 (Xiao-Boyd conditions, Eq. 2).
It uses Metropolis-Hastings weights in all experiments and compares against the
numerically optimized weights of Xiao & Boyd [10].

All constructions here are *locally computable* (each node needs only its own
and its neighbours' degrees) except `optimal_weights`, which reproduces the
centralized spectral-norm-minimizing baseline from the paper's comparison set.
"""
from __future__ import annotations

import numpy as np

from .topology import Graph

__all__ = [
    "metropolis_hastings",
    "max_degree",
    "lazy",
    "best_constant",
    "optimal_weights",
    "check_consensus_matrix",
    "averaging_matrix",
]


def averaging_matrix(n: int) -> np.ndarray:
    """J = (1/n) 1 1^T."""
    return np.full((n, n), 1.0 / n)


def metropolis_hastings(graph: Graph) -> np.ndarray:
    """W_ij = 1 / (1 + max(d_i, d_j)) on edges; diagonal absorbs the rest.

    Satisfies the Xiao-Boyd conditions on any connected graph and is the weight
    matrix used throughout the paper's experiments. On a chain its spectrum is
    lambda_i = 1/3 + (2/3) cos(pi (i-1)/N) (paper, Section III-C).
    """
    a = graph.adjacency
    d = graph.degrees
    pair_max = np.maximum(d[:, None], d[None, :])
    w = np.where(a > 0, 1.0 / (1.0 + pair_max), 0.0)
    np.fill_diagonal(w, 0.0)
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def max_degree(graph: Graph) -> np.ndarray:
    """W = I - L / (d_max + 1): uniform edge weight, always doubly stochastic."""
    d_max = float(graph.degrees.max())
    return np.eye(graph.n) - graph.laplacian() / (d_max + 1.0)


def lazy(w: np.ndarray) -> np.ndarray:
    """The local mapping W -> (I + W)/2.

    Transforms any stochastic W into one with all-positive eigenvalues
    (paper, end of Section III-A), guaranteeing |lambda_N| <= |lambda_2| as
    required by Theorem 1, at the cost of a constant-factor slowdown that does
    not change order-wise asymptotics.
    """
    return 0.5 * (np.eye(w.shape[0]) + w)


def best_constant(graph: Graph) -> np.ndarray:
    """Best-constant edge weight: W = I - sigma L, sigma = 2/(l_1 + l_{n-1}).

    The optimal single-parameter weight matrix (Xiao-Boyd); a cheap, closed-form
    stand-in for the full optimal weights.
    """
    lap = graph.laplacian()
    eig = np.linalg.eigvalsh(lap)
    sigma = 2.0 / (eig[-1] + eig[1])
    return np.eye(graph.n) - sigma * lap


def optimal_weights(
    graph: Graph,
    iters: int = 500,
    step0: float = 1.0,
    tol: float = 1e-10,
    verbose: bool = False,
) -> np.ndarray:
    """Symmetric weights minimizing rho(W - J) (Xiao-Boyd [10] baseline).

    We solve  min_w rho(I - B diag(w) B^T - J)  over edge weights w by projected
    subgradient descent on the spectral radius (the problem is convex in w; a
    subgradient of lambda_max at eigenvector u is -(u_i - u_j)^2 per edge, and of
    -lambda_min is +(v_i - v_j)^2). Polyak-style diminishing steps. For the
    N <= ~500 graphs in the paper's experiments this converges comfortably; it
    reproduces the qualitative Fig. 1/3 behaviour (constant-factor gain over MH,
    no change in scaling order — the paper's point).
    """
    edges = graph.edge_list()
    n, m = graph.n, len(edges)
    j = averaging_matrix(n)

    def build(w_e: np.ndarray) -> np.ndarray:
        w = np.eye(n)
        for k, (a, b) in enumerate(edges):
            w[a, b] = w[b, a] = w_e[k]
        w[np.diag_indices(n)] = 1.0 - (w.sum(axis=1) - np.diag(w))
        return w

    # Init from Metropolis-Hastings edge weights.
    mh = metropolis_hastings(graph)
    w_e = np.array([mh[a, b] for a, b in edges])
    best_w_e, best_rho = w_e.copy(), np.inf
    for t in range(iters):
        w = build(w_e)
        vals, vecs = np.linalg.eigh(w - j)
        lo, hi = vals[0], vals[-1]
        rho = max(abs(lo), abs(hi))
        if rho < best_rho - tol:
            best_rho, best_w_e = rho, w_e.copy()
        # subgradient of rho wrt edge weights
        if hi >= abs(lo):
            u = vecs[:, -1]
            g = -((u[edges[:, 0]] - u[edges[:, 1]]) ** 2)
        else:
            v = vecs[:, 0]
            g = (v[edges[:, 0]] - v[edges[:, 1]]) ** 2
        gn = np.linalg.norm(g)
        if gn < 1e-15:
            break
        w_e = w_e - (step0 / np.sqrt(t + 1.0)) * g / gn
        if verbose and t % 100 == 0:
            print(f"  opt_weights iter {t}: rho={rho:.6f} best={best_rho:.6f}")
    return build(best_w_e)


def check_consensus_matrix(
    w: np.ndarray, atol: float = 1e-8, require_contraction: bool = True
) -> None:
    """Assert the Xiao-Boyd convergence conditions (Eq. 2). Raises on violation."""
    n = w.shape[0]
    one = np.ones(n)
    if not np.allclose(w @ one, one, atol=atol):
        raise ValueError("W 1 != 1 (row sums)")
    if not np.allclose(one @ w, one, atol=atol):
        raise ValueError("1^T W != 1^T (column sums)")
    if require_contraction:
        rho = np.max(np.abs(np.linalg.eigvals(w - averaging_matrix(n))))
        if not rho < 1.0:
            raise ValueError(f"rho(W - J) = {rho} >= 1")
