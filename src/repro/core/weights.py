"""Consensus weight-matrix constructions.

The paper assumes a "foundational weight matrix" W that is doubly stochastic,
symmetric, satisfies W1 = 1, and rho(W - J) < 1 (Xiao-Boyd conditions, Eq. 2).
It uses Metropolis-Hastings weights in all experiments and compares against the
numerically optimized weights of Xiao & Boyd [10].

All constructions here are *locally computable* (each node needs only its own
and its neighbours' degrees) except `optimal_weights`, which reproduces the
centralized spectral-norm-minimizing baseline from the paper's comparison set.
"""
from __future__ import annotations

import numpy as np

from .topology import Graph, SparseGraph

__all__ = [
    "metropolis_hastings",
    "max_degree",
    "lazy",
    "best_constant",
    "optimal_weights",
    "check_consensus_matrix",
    "check_column_stochastic",
    "averaging_matrix",
    "metropolis_hastings_edges",
    "lazy_edges",
    "sparse_matvec",
    "lambda_extremes_sparse",
    "receiver_weights",
    "push_sum_weights",
    "ratio_consensus_weights",
    "push_sum_weights_edges",
    "ratio_consensus_weights_edges",
]


def _support(adjacency) -> np.ndarray:
    """Off-diagonal 0/1 support of a Graph/DiGraph/raw matrix (receiver conv.)."""
    a = getattr(adjacency, "adjacency", adjacency)
    s = (np.abs(np.asarray(a, dtype=np.float64)) > 0).astype(np.float64)
    np.fill_diagonal(s, 0.0)
    return s


def averaging_matrix(n: int) -> np.ndarray:
    """J = (1/n) 1 1^T."""
    return np.full((n, n), 1.0 / n)


def metropolis_hastings(graph: Graph) -> np.ndarray:
    """W_ij = 1 / (1 + max(d_i, d_j)) on edges; diagonal absorbs the rest.

    Satisfies the Xiao-Boyd conditions on any connected graph and is the weight
    matrix used throughout the paper's experiments. On a chain its spectrum is
    lambda_i = 1/3 + (2/3) cos(pi (i-1)/N) (paper, Section III-C).
    """
    a = graph.adjacency
    d = graph.degrees
    pair_max = np.maximum(d[:, None], d[None, :])
    w = np.where(a > 0, 1.0 / (1.0 + pair_max), 0.0)
    np.fill_diagonal(w, 0.0)
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def max_degree(graph: Graph) -> np.ndarray:
    """W = I - L / (d_max + 1): uniform edge weight, always doubly stochastic."""
    d_max = float(graph.degrees.max())
    return np.eye(graph.n) - graph.laplacian() / (d_max + 1.0)


def lazy(w: np.ndarray) -> np.ndarray:
    """The local mapping W -> (I + W)/2.

    Transforms any stochastic W into one with all-positive eigenvalues
    (paper, end of Section III-A), guaranteeing |lambda_N| <= |lambda_2| as
    required by Theorem 1, at the cost of a constant-factor slowdown that does
    not change order-wise asymptotics.
    """
    return 0.5 * (np.eye(w.shape[0]) + w)


def best_constant(graph: Graph) -> np.ndarray:
    """Best-constant edge weight: W = I - sigma L, sigma = 2/(l_1 + l_{n-1}).

    The optimal single-parameter weight matrix (Xiao-Boyd); a cheap, closed-form
    stand-in for the full optimal weights.
    """
    lap = graph.laplacian()
    eig = np.linalg.eigvalsh(lap)
    sigma = 2.0 / (eig[-1] + eig[1])
    return np.eye(graph.n) - sigma * lap


def optimal_weights(
    graph: Graph,
    iters: int = 500,
    step0: float = 1.0,
    tol: float = 1e-10,
    verbose: bool = False,
) -> np.ndarray:
    """Symmetric weights minimizing rho(W - J) (Xiao-Boyd [10] baseline).

    We solve  min_w rho(I - B diag(w) B^T - J)  over edge weights w by projected
    subgradient descent on the spectral radius (the problem is convex in w; a
    subgradient of lambda_max at eigenvector u is -(u_i - u_j)^2 per edge, and of
    -lambda_min is +(v_i - v_j)^2). Polyak-style diminishing steps. For the
    N <= ~500 graphs in the paper's experiments this converges comfortably; it
    reproduces the qualitative Fig. 1/3 behaviour (constant-factor gain over MH,
    no change in scaling order — the paper's point).
    """
    edges = graph.edge_list()
    n, m = graph.n, len(edges)
    j = averaging_matrix(n)

    def build(w_e: np.ndarray) -> np.ndarray:
        w = np.eye(n)
        for k, (a, b) in enumerate(edges):
            w[a, b] = w[b, a] = w_e[k]
        w[np.diag_indices(n)] = 1.0 - (w.sum(axis=1) - np.diag(w))
        return w

    # Init from Metropolis-Hastings edge weights.
    mh = metropolis_hastings(graph)
    w_e = np.array([mh[a, b] for a, b in edges])
    best_w_e, best_rho = w_e.copy(), np.inf
    for t in range(iters):
        w = build(w_e)
        vals, vecs = np.linalg.eigh(w - j)
        lo, hi = vals[0], vals[-1]
        rho = max(abs(lo), abs(hi))
        if rho < best_rho - tol:
            best_rho, best_w_e = rho, w_e.copy()
        # subgradient of rho wrt edge weights
        if hi >= abs(lo):
            u = vecs[:, -1]
            g = -((u[edges[:, 0]] - u[edges[:, 1]]) ** 2)
        else:
            v = vecs[:, 0]
            g = (v[edges[:, 0]] - v[edges[:, 1]]) ** 2
        gn = np.linalg.norm(g)
        if gn < 1e-15:
            break
        w_e = w_e - (step0 / np.sqrt(t + 1.0)) * g / gn
        if verbose and t % 100 == 0:
            print(f"  opt_weights iter {t}: rho={rho:.6f} best={best_rho:.6f}")
    return build(best_w_e)


# ---------------------------------------------------------------------------
# Directed / column-stochastic constructions (push-sum family).
#
# Receiver convention throughout: W_ij is the weight node i puts on node j's
# state in x <- W x, so "column j sums to 1" means node j's MASS is split
# exactly among its listeners — the invariant push-sum / ratio-consensus
# need (total mass conserved), dual to the row-sum-1 invariant the
# doubly-stochastic family relies on (consensus fixed points).
# ---------------------------------------------------------------------------


def receiver_weights(adjacency) -> np.ndarray:
    """Naive row-stochastic weights on a digraph: W_ij = 1/(1 + din_i).

    Each node averages what it HEARS, uniformly over in-neighbours + itself.
    Row sums are 1 (so it reaches consensus on a strongly connected digraph),
    but column sums are not — the limit is the Perron-weighted mixture
    v^T x(0), NOT the average, unless the digraph happens to be balanced.
    This is the "naive masked path" baseline the directed benchmarks show
    drifting; ``push_sum_weights`` is the correction.
    """
    s = _support(adjacency)
    din = s.sum(axis=1)
    w = s / (1.0 + din)[:, None]
    np.fill_diagonal(w, 1.0 / (1.0 + din))
    return w


def push_sum_weights(adjacency) -> np.ndarray:
    """Column-stochastic push-sum weights: P_ij = P_jj = 1/(1 + dout_j).

    Node j pushes an equal share of its (value, mass) pair to every
    out-neighbour and itself. Columns sum to exactly 1, so total mass is
    conserved and the ratio state s/w converges to the true average on any
    strongly connected digraph (Kempe-Dobra-Gehrke); rows need not sum to 1.
    On an undirected graph dout is the degree and P is the classic uniform
    push matrix.
    """
    s = _support(adjacency)
    dout = s.sum(axis=0)
    p = s / (1.0 + dout)[None, :]
    np.fill_diagonal(p, 1.0 / (1.0 + dout))
    return p


def ratio_consensus_weights(adjacency, c: float = 0.5) -> np.ndarray:
    """Column-stochastic ratio-consensus weights with self-mass c.

    P_jj = c and P_ij = (1 - c)/dout_j on arcs j -> i: node j keeps fraction
    ``c`` of its mass and splits the rest uniformly over out-neighbours (the
    sigma/rho mass-counter scheme). Larger ``c`` is lazier but more robust to
    bursty loss; c = 1/2 is the usual default.
    """
    if not 0.0 < c < 1.0:
        raise ValueError(f"ratio_consensus self-mass must be in (0, 1), got {c}")
    s = _support(adjacency)
    dout = s.sum(axis=0)
    safe = np.maximum(dout, 1.0)
    p = s * ((1.0 - c) / safe)[None, :]
    # an isolated column (no listeners) keeps all of its mass on itself
    np.fill_diagonal(p, np.where(dout > 0, c, 1.0))
    return p


def push_sum_weights_edges(
    edges: np.ndarray, n: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Edge-space twin of ``push_sum_weights`` on an undirected edge list.

    Returns ``(fwd, rev, diag)``: for canonical edge k = (i, j) with i < j,
    ``fwd[k] = P_ij`` (i's weight on j) and ``rev[k] = P_ji`` (j's weight on
    i) — the two directions differ whenever deg_i != deg_j, which is why the
    symmetric (edge_w, diag_w) pair cannot carry this family.
    """
    edges = np.asarray(edges)
    deg = np.bincount(edges.ravel(), minlength=n).astype(np.float64)
    i, j = edges[:, 0], edges[:, 1]
    fwd = 1.0 / (1.0 + deg[j])
    rev = 1.0 / (1.0 + deg[i])
    return fwd, rev, 1.0 / (1.0 + deg)


def ratio_consensus_weights_edges(
    edges: np.ndarray, n: int, c: float = 0.5
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Edge-space twin of ``ratio_consensus_weights`` (see above for layout)."""
    if not 0.0 < c < 1.0:
        raise ValueError(f"ratio_consensus self-mass must be in (0, 1), got {c}")
    edges = np.asarray(edges)
    deg = np.bincount(edges.ravel(), minlength=n).astype(np.float64)
    safe = np.maximum(deg, 1.0)
    i, j = edges[:, 0], edges[:, 1]
    fwd = (1.0 - c) / safe[j]
    rev = (1.0 - c) / safe[i]
    return fwd, rev, np.where(deg > 0, c, 1.0)


def check_column_stochastic(w: np.ndarray, atol: float = 1e-8) -> None:
    """Assert column sums 1 and nonnegativity — the mass-conservation analog
    of ``check_consensus_matrix``. Raises on violation."""
    w = np.asarray(w)
    one = np.ones(w.shape[0])
    if not np.allclose(one @ w, one, atol=atol):
        raise ValueError("1^T W != 1^T (column sums): total mass not conserved")
    if np.min(w) < -atol:
        raise ValueError("negative weight entries in a push-sum-style matrix")


# ---------------------------------------------------------------------------
# Edge-space constructions for the sparse (million-node) layout.
# ---------------------------------------------------------------------------


def metropolis_hastings_edges(g: SparseGraph) -> tuple[np.ndarray, np.ndarray]:
    """Metropolis-Hastings weights directly in edge space: O(E), no matrix.

    Returns ``(edge_w, diag_w)`` where ``edge_w[k]`` is the weight on the
    canonical undirected edge ``g.edges[k]`` and ``diag_w[i] = W_ii``. On
    graphs small enough to densify this matches ``metropolis_hastings``
    entry-for-entry (the equivalence suite asserts it).
    """
    deg = g.degrees
    i, j = g.edges[:, 0], g.edges[:, 1]
    edge_w = 1.0 / (1.0 + np.maximum(deg[i], deg[j]))
    offdiag_rowsum = np.bincount(i, weights=edge_w, minlength=g.n)
    offdiag_rowsum += np.bincount(j, weights=edge_w, minlength=g.n)
    return edge_w, 1.0 - offdiag_rowsum


def lazy_edges(edge_w: np.ndarray, diag_w: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Edge-space W -> (I + W)/2: halve edge weights, shift diagonal."""
    return 0.5 * edge_w, 0.5 * (1.0 + diag_w)


def sparse_matvec(
    edges: np.ndarray,
    edge_w: np.ndarray,
    diag_w: np.ndarray,
    x: np.ndarray,
) -> np.ndarray:
    """W @ x from the edge list (host numpy; the jnp path lives in the engine).

    ``x`` may be (N,) or (N, F); each undirected edge contributes its weight in
    both directions via two bincounts over edge endpoints.
    """
    i, j = edges[:, 0], edges[:, 1]
    n = len(diag_w)
    if x.ndim == 1:
        y = diag_w * x
        y += np.bincount(i, weights=edge_w * x[j], minlength=n)
        y += np.bincount(j, weights=edge_w * x[i], minlength=n)
        return y
    y = diag_w[:, None] * x
    for f in range(x.shape[1]):
        y[:, f] += np.bincount(i, weights=edge_w * x[j, f], minlength=n)
        y[:, f] += np.bincount(j, weights=edge_w * x[i, f], minlength=n)
    return y


def lambda_extremes_sparse(
    edges: np.ndarray,
    edge_w: np.ndarray,
    diag_w: np.ndarray,
    *,
    iters: int = 500,
    tol: float = 1e-12,
    seed: int = 0,
) -> tuple[float, float]:
    """(lambda_2, lambda_N) of a doubly-stochastic W by power iteration, O(E·iters).

    lambda_2 comes from power-iterating the PSD shift ``(I + W)/2`` with the
    known top eigenvector 1 deflated out (lambda_2 = 2 mu - 1); lambda_N from
    ``I - W`` whose largest eigenvalue is ``1 - lambda_N``. Both operators are
    two bincounts per step. Used for the large-N sparse cells where
    ``eigvalsh`` on a dense (N, N) matrix is out of reach; the resulting
    extremes feed Theorem 1's alpha*(lambda_2) and the surrogate-spectrum
    polynomial designs (see sweep/grid.py).
    """
    n = len(diag_w)
    rng = np.random.default_rng(seed)

    def matvec(x: np.ndarray) -> np.ndarray:
        return sparse_matvec(edges, edge_w, diag_w, x)

    # --- lambda_2 via (I + W)/2 deflated against the all-ones vector ---
    v = rng.standard_normal(n)
    mu_prev = np.inf
    for _ in range(iters):
        v -= v.mean()                      # deflate eigenvector 1
        nv = np.linalg.norm(v)
        if nv < 1e-30:
            v = rng.standard_normal(n)
            continue
        v /= nv
        v_new = 0.5 * (v + matvec(v))
        mu = float(v @ v_new)
        v = v_new
        if abs(mu - mu_prev) < tol:
            break
        mu_prev = mu
    lam2 = 2.0 * mu - 1.0

    # --- lambda_N via I - W (largest eigenvalue 1 - lambda_N) ---
    u = rng.standard_normal(n)
    nu_prev = np.inf
    for _ in range(iters):
        u -= u.mean()
        nu_norm = np.linalg.norm(u)
        if nu_norm < 1e-30:
            u = rng.standard_normal(n)
            continue
        u /= nu_norm
        u_new = u - matvec(u)
        nu = float(u @ u_new)
        u = u_new
        if abs(nu - nu_prev) < tol:
            break
        nu_prev = nu
    lam_n = 1.0 - nu
    return min(lam2, 1.0 - 1e-12), max(lam_n, -1.0)


def check_consensus_matrix(
    w: np.ndarray, atol: float = 1e-8, require_contraction: bool = True
) -> None:
    """Assert the Xiao-Boyd convergence conditions (Eq. 2). Raises on violation."""
    n = w.shape[0]
    one = np.ones(n)
    if not np.allclose(w @ one, one, atol=atol):
        raise ValueError("W 1 != 1 (row sums)")
    if not np.allclose(one @ w, one, atol=atol):
        raise ValueError("1^T W != 1^T (column sums)")
    if require_contraction:
        rho = np.max(np.abs(np.linalg.eigvals(w - averaging_matrix(n))))
        if not rho < 1.0:
            raise ValueError(f"rho(W - J) = {rho} >= 1")
