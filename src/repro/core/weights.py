"""Consensus weight-matrix constructions.

The paper assumes a "foundational weight matrix" W that is doubly stochastic,
symmetric, satisfies W1 = 1, and rho(W - J) < 1 (Xiao-Boyd conditions, Eq. 2).
It uses Metropolis-Hastings weights in all experiments and compares against the
numerically optimized weights of Xiao & Boyd [10].

All constructions here are *locally computable* (each node needs only its own
and its neighbours' degrees) except `optimal_weights`, which reproduces the
centralized spectral-norm-minimizing baseline from the paper's comparison set.
"""
from __future__ import annotations

import numpy as np

from .topology import Graph, SparseGraph

__all__ = [
    "metropolis_hastings",
    "max_degree",
    "lazy",
    "best_constant",
    "optimal_weights",
    "check_consensus_matrix",
    "averaging_matrix",
    "metropolis_hastings_edges",
    "lazy_edges",
    "sparse_matvec",
    "lambda_extremes_sparse",
]


def averaging_matrix(n: int) -> np.ndarray:
    """J = (1/n) 1 1^T."""
    return np.full((n, n), 1.0 / n)


def metropolis_hastings(graph: Graph) -> np.ndarray:
    """W_ij = 1 / (1 + max(d_i, d_j)) on edges; diagonal absorbs the rest.

    Satisfies the Xiao-Boyd conditions on any connected graph and is the weight
    matrix used throughout the paper's experiments. On a chain its spectrum is
    lambda_i = 1/3 + (2/3) cos(pi (i-1)/N) (paper, Section III-C).
    """
    a = graph.adjacency
    d = graph.degrees
    pair_max = np.maximum(d[:, None], d[None, :])
    w = np.where(a > 0, 1.0 / (1.0 + pair_max), 0.0)
    np.fill_diagonal(w, 0.0)
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def max_degree(graph: Graph) -> np.ndarray:
    """W = I - L / (d_max + 1): uniform edge weight, always doubly stochastic."""
    d_max = float(graph.degrees.max())
    return np.eye(graph.n) - graph.laplacian() / (d_max + 1.0)


def lazy(w: np.ndarray) -> np.ndarray:
    """The local mapping W -> (I + W)/2.

    Transforms any stochastic W into one with all-positive eigenvalues
    (paper, end of Section III-A), guaranteeing |lambda_N| <= |lambda_2| as
    required by Theorem 1, at the cost of a constant-factor slowdown that does
    not change order-wise asymptotics.
    """
    return 0.5 * (np.eye(w.shape[0]) + w)


def best_constant(graph: Graph) -> np.ndarray:
    """Best-constant edge weight: W = I - sigma L, sigma = 2/(l_1 + l_{n-1}).

    The optimal single-parameter weight matrix (Xiao-Boyd); a cheap, closed-form
    stand-in for the full optimal weights.
    """
    lap = graph.laplacian()
    eig = np.linalg.eigvalsh(lap)
    sigma = 2.0 / (eig[-1] + eig[1])
    return np.eye(graph.n) - sigma * lap


def optimal_weights(
    graph: Graph,
    iters: int = 500,
    step0: float = 1.0,
    tol: float = 1e-10,
    verbose: bool = False,
) -> np.ndarray:
    """Symmetric weights minimizing rho(W - J) (Xiao-Boyd [10] baseline).

    We solve  min_w rho(I - B diag(w) B^T - J)  over edge weights w by projected
    subgradient descent on the spectral radius (the problem is convex in w; a
    subgradient of lambda_max at eigenvector u is -(u_i - u_j)^2 per edge, and of
    -lambda_min is +(v_i - v_j)^2). Polyak-style diminishing steps. For the
    N <= ~500 graphs in the paper's experiments this converges comfortably; it
    reproduces the qualitative Fig. 1/3 behaviour (constant-factor gain over MH,
    no change in scaling order — the paper's point).
    """
    edges = graph.edge_list()
    n, m = graph.n, len(edges)
    j = averaging_matrix(n)

    def build(w_e: np.ndarray) -> np.ndarray:
        w = np.eye(n)
        for k, (a, b) in enumerate(edges):
            w[a, b] = w[b, a] = w_e[k]
        w[np.diag_indices(n)] = 1.0 - (w.sum(axis=1) - np.diag(w))
        return w

    # Init from Metropolis-Hastings edge weights.
    mh = metropolis_hastings(graph)
    w_e = np.array([mh[a, b] for a, b in edges])
    best_w_e, best_rho = w_e.copy(), np.inf
    for t in range(iters):
        w = build(w_e)
        vals, vecs = np.linalg.eigh(w - j)
        lo, hi = vals[0], vals[-1]
        rho = max(abs(lo), abs(hi))
        if rho < best_rho - tol:
            best_rho, best_w_e = rho, w_e.copy()
        # subgradient of rho wrt edge weights
        if hi >= abs(lo):
            u = vecs[:, -1]
            g = -((u[edges[:, 0]] - u[edges[:, 1]]) ** 2)
        else:
            v = vecs[:, 0]
            g = (v[edges[:, 0]] - v[edges[:, 1]]) ** 2
        gn = np.linalg.norm(g)
        if gn < 1e-15:
            break
        w_e = w_e - (step0 / np.sqrt(t + 1.0)) * g / gn
        if verbose and t % 100 == 0:
            print(f"  opt_weights iter {t}: rho={rho:.6f} best={best_rho:.6f}")
    return build(best_w_e)


# ---------------------------------------------------------------------------
# Edge-space constructions for the sparse (million-node) layout.
# ---------------------------------------------------------------------------


def metropolis_hastings_edges(g: SparseGraph) -> tuple[np.ndarray, np.ndarray]:
    """Metropolis-Hastings weights directly in edge space: O(E), no matrix.

    Returns ``(edge_w, diag_w)`` where ``edge_w[k]`` is the weight on the
    canonical undirected edge ``g.edges[k]`` and ``diag_w[i] = W_ii``. On
    graphs small enough to densify this matches ``metropolis_hastings``
    entry-for-entry (the equivalence suite asserts it).
    """
    deg = g.degrees
    i, j = g.edges[:, 0], g.edges[:, 1]
    edge_w = 1.0 / (1.0 + np.maximum(deg[i], deg[j]))
    offdiag_rowsum = np.bincount(i, weights=edge_w, minlength=g.n)
    offdiag_rowsum += np.bincount(j, weights=edge_w, minlength=g.n)
    return edge_w, 1.0 - offdiag_rowsum


def lazy_edges(edge_w: np.ndarray, diag_w: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Edge-space W -> (I + W)/2: halve edge weights, shift diagonal."""
    return 0.5 * edge_w, 0.5 * (1.0 + diag_w)


def sparse_matvec(
    edges: np.ndarray,
    edge_w: np.ndarray,
    diag_w: np.ndarray,
    x: np.ndarray,
) -> np.ndarray:
    """W @ x from the edge list (host numpy; the jnp path lives in the engine).

    ``x`` may be (N,) or (N, F); each undirected edge contributes its weight in
    both directions via two bincounts over edge endpoints.
    """
    i, j = edges[:, 0], edges[:, 1]
    n = len(diag_w)
    if x.ndim == 1:
        y = diag_w * x
        y += np.bincount(i, weights=edge_w * x[j], minlength=n)
        y += np.bincount(j, weights=edge_w * x[i], minlength=n)
        return y
    y = diag_w[:, None] * x
    for f in range(x.shape[1]):
        y[:, f] += np.bincount(i, weights=edge_w * x[j, f], minlength=n)
        y[:, f] += np.bincount(j, weights=edge_w * x[i, f], minlength=n)
    return y


def lambda_extremes_sparse(
    edges: np.ndarray,
    edge_w: np.ndarray,
    diag_w: np.ndarray,
    *,
    iters: int = 500,
    tol: float = 1e-12,
    seed: int = 0,
) -> tuple[float, float]:
    """(lambda_2, lambda_N) of a doubly-stochastic W by power iteration, O(E·iters).

    lambda_2 comes from power-iterating the PSD shift ``(I + W)/2`` with the
    known top eigenvector 1 deflated out (lambda_2 = 2 mu - 1); lambda_N from
    ``I - W`` whose largest eigenvalue is ``1 - lambda_N``. Both operators are
    two bincounts per step. Used for the large-N sparse cells where
    ``eigvalsh`` on a dense (N, N) matrix is out of reach; the resulting
    extremes feed Theorem 1's alpha*(lambda_2) and the surrogate-spectrum
    polynomial designs (see sweep/grid.py).
    """
    n = len(diag_w)
    rng = np.random.default_rng(seed)

    def matvec(x: np.ndarray) -> np.ndarray:
        return sparse_matvec(edges, edge_w, diag_w, x)

    # --- lambda_2 via (I + W)/2 deflated against the all-ones vector ---
    v = rng.standard_normal(n)
    mu_prev = np.inf
    for _ in range(iters):
        v -= v.mean()                      # deflate eigenvector 1
        nv = np.linalg.norm(v)
        if nv < 1e-30:
            v = rng.standard_normal(n)
            continue
        v /= nv
        v_new = 0.5 * (v + matvec(v))
        mu = float(v @ v_new)
        v = v_new
        if abs(mu - mu_prev) < tol:
            break
        mu_prev = mu
    lam2 = 2.0 * mu - 1.0

    # --- lambda_N via I - W (largest eigenvalue 1 - lambda_N) ---
    u = rng.standard_normal(n)
    nu_prev = np.inf
    for _ in range(iters):
        u -= u.mean()
        nu_norm = np.linalg.norm(u)
        if nu_norm < 1e-30:
            u = rng.standard_normal(n)
            continue
        u /= nu_norm
        u_new = u - matvec(u)
        nu = float(u @ u_new)
        u = u_new
        if abs(nu - nu_prev) < tol:
            break
        nu_prev = nu
    lam_n = 1.0 - nu
    return min(lam2, 1.0 - 1e-12), max(lam_n, -1.0)


def check_consensus_matrix(
    w: np.ndarray, atol: float = 1e-8, require_contraction: bool = True
) -> None:
    """Assert the Xiao-Boyd convergence conditions (Eq. 2). Raises on violation."""
    n = w.shape[0]
    one = np.ones(n)
    if not np.allclose(w @ one, one, atol=atol):
        raise ValueError("W 1 != 1 (row sums)")
    if not np.allclose(one @ w, one, atol=atol):
        raise ValueError("1^T W != 1^T (column sums)")
    if require_contraction:
        rho = np.max(np.abs(np.linalg.eigvals(w - averaging_matrix(n))))
        if not rho < 1.0:
            raise ValueError(f"rho(W - J) = {rho} >= 1")
