"""Network topologies for distributed averaging.

The paper evaluates on chain graphs and random geometric graphs (RGG) with the
connectivity radius sqrt(2 log N / N) (Gupta-Kumar scaling, connected w.h.p.).
We additionally provide ring / 2-D grid / 2-D torus (the topologies used for the
pod-level consensus fabric in ``repro.dist``) plus a few classics used in tests.

The classic generators return a dense symmetric 0/1 adjacency matrix (numpy,
float64) with zero diagonal — the right representation for the paper's own
experiments (N <= a few thousand, dense spectral analysis anyway).

The *sparse* family (:class:`SparseGraph` + ``sparse_*`` / ``barabasi_albert``
/ ``random_geometric_sparse``) stores only the canonical undirected edge list
(i < j, row-major sorted — the exact ordering ``repro.core.dynamics.edge_index``
produces from a dense matrix, which is what keeps RoundMasks schedules and
CRN coupling identical across the dense and sparse engine layouts). It is the
representation the million-node sweep path (``SweepSpec(layout="sparse")``)
consumes: O(E) memory instead of O(N^2), generators that never materialize a
distance or adjacency matrix, and union-find connectivity instead of dense
BFS. See docs/ARCHITECTURE.md for how the two layouts meet in the engine.
"""
from __future__ import annotations

import dataclasses
import numpy as np

__all__ = [
    "Graph",
    "DiGraph",
    "SparseGraph",
    "chain",
    "ring",
    "grid2d",
    "torus2d",
    "random_geometric",
    "complete",
    "star",
    "hypercube",
    "erdos_renyi",
    "erdos_renyi_sparse",
    "random_digraph",
    "is_connected",
    "is_strongly_connected",
    "diameter",
    "sparse_chain",
    "sparse_ring",
    "sparse_grid2d",
    "sparse_torus2d",
    "barabasi_albert",
    "random_geometric_sparse",
    "edges_are_connected",
]


@dataclasses.dataclass(frozen=True)
class Graph:
    """A symmetric communication graph.

    Attributes:
      adjacency: (N, N) 0/1 symmetric matrix, zero diagonal.
      name: topology family name.
      coords: optional (N, d) node coordinates (RGG / grid), for plotting & inits.
    """

    adjacency: np.ndarray
    name: str
    coords: np.ndarray | None = None

    @property
    def n(self) -> int:
        return self.adjacency.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        return self.adjacency.sum(axis=1)

    def neighbors(self, i: int) -> np.ndarray:
        return np.nonzero(self.adjacency[i])[0]

    @property
    def num_edges(self) -> int:
        return int(self.adjacency.sum()) // 2

    def laplacian(self, normalized: bool = False) -> np.ndarray:
        a = self.adjacency
        d = self.degrees
        lap = np.diag(d) - a
        if normalized:
            with np.errstate(divide="ignore"):
                dinv = np.where(d > 0, 1.0 / np.sqrt(d), 0.0)
            lap = dinv[:, None] * lap * dinv[None, :]
        return lap

    def edge_list(self) -> np.ndarray:
        iu = np.triu_indices(self.n, k=1)
        mask = self.adjacency[iu] > 0
        return np.stack([iu[0][mask], iu[1][mask]], axis=1)


def _finalize(a: np.ndarray, name: str, coords: np.ndarray | None = None) -> Graph:
    a = np.asarray(a, dtype=np.float64)
    np.fill_diagonal(a, 0.0)
    a = np.maximum(a, a.T)
    return Graph(adjacency=a, name=name, coords=coords)


def chain(n: int) -> Graph:
    """Path graph on n vertices — the paper's hardest topology (diameter n-1)."""
    if n < 2:
        raise ValueError("chain needs n >= 2")
    a = np.zeros((n, n))
    idx = np.arange(n - 1)
    a[idx, idx + 1] = 1.0
    coords = np.stack([np.arange(n) / max(n - 1, 1), np.zeros(n)], axis=1)
    return _finalize(a, "chain", coords)


def ring(n: int) -> Graph:
    """Cycle on n vertices — the natural cross-pod gossip topology."""
    if n < 3:
        raise ValueError("ring needs n >= 3")
    a = np.zeros((n, n))
    idx = np.arange(n)
    a[idx, (idx + 1) % n] = 1.0
    ang = 2 * np.pi * np.arange(n) / n
    coords = 0.5 + 0.5 * np.stack([np.cos(ang), np.sin(ang)], axis=1)
    return _finalize(a, "ring", coords)


def grid2d(rows: int, cols: int | None = None) -> Graph:
    """2-D grid (no wraparound): rho(W-J) = 1 - Theta(1/N) => gain Omega(sqrt(N))."""
    cols = cols if cols is not None else rows
    n = rows * cols
    a = np.zeros((n, n))
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            if c + 1 < cols:
                a[i, i + 1] = 1.0
            if r + 1 < rows:
                a[i, i + cols] = 1.0
    rr, cc = np.divmod(np.arange(n), cols)
    coords = np.stack([cc / max(cols - 1, 1), rr / max(rows - 1, 1)], axis=1)
    return _finalize(a, "grid2d", coords)


def torus2d(rows: int, cols: int | None = None) -> Graph:
    """2-D torus (wraparound grid) — matches TPU ICI/pod fabric geometry."""
    cols = cols if cols is not None else rows
    n = rows * cols
    a = np.zeros((n, n))
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            a[i, r * cols + (c + 1) % cols] = 1.0
            a[i, ((r + 1) % rows) * cols + c] = 1.0
    rr, cc = np.divmod(np.arange(n), cols)
    coords = np.stack([cc / cols, rr / rows], axis=1)
    return _finalize(a, "torus2d", coords)


def complete(n: int) -> Graph:
    a = np.ones((n, n)) - np.eye(n)
    return _finalize(a, "complete")


def star(n: int) -> Graph:
    a = np.zeros((n, n))
    a[0, 1:] = 1.0
    return _finalize(a, "star")


def hypercube(d: int) -> Graph:
    """d-dimensional hypercube on 2^d vertices."""
    n = 1 << d
    a = np.zeros((n, n))
    for i in range(n):
        for b in range(d):
            a[i, i ^ (1 << b)] = 1.0
    return _finalize(a, "hypercube")


def erdos_renyi(n: int, p: float, rng: np.random.Generator) -> Graph:
    u = rng.random((n, n))
    # Bernoulli(p) on the strictly-upper entries only: masking AFTER the
    # comparison, else the zeroed lower triangle compares 0 < p == True and
    # every draw degenerates to (nearly) complete with doubled entries.
    a = np.triu(u < p, 1).astype(np.float64)
    return _finalize(a + a.T, "erdos_renyi")


def erdos_renyi_sparse(
    n: int,
    p: float,
    rng: np.random.Generator,
    max_tries: int = 200,
) -> SparseGraph:
    """G(n, p) as an edge list in O(E) — the large-N twin of ``erdos_renyi``.

    Uses Batagelj-Brandes geometric-skip sampling over the lexicographic
    upper-triangular pair order: instead of flipping all n(n-1)/2 coins, draw
    geometric gaps between successes, so work and memory are O(E + tries).
    The resulting edge list is canonical (i < j, lexsorted) by construction.

    NOTE on coupling: this sampler consumes the rng *differently* from the
    dense ``erdos_renyi`` (which draws an (n, n) uniform block), so the two
    do NOT produce the same graph for the same rng state. The grid therefore
    keeps densifying below ``SPARSE_EXACT_SPECTRUM_CUTOFF`` (preserving the
    dense<->sparse CRN anchor) and uses this sampler only above it, where the
    dense twin cannot run at all. Resamples until connected, like ``rgg``.
    """
    if not 0.0 < p <= 1.0:
        raise ValueError(f"erdos_renyi_sparse needs p in (0, 1], got {p}")
    total = n * (n - 1) // 2
    log1mp = np.log1p(-p) if p < 1.0 else None
    # row starts in the flattened i<j pair order: pair k of row i is (i, i+1+k)
    row_start = np.concatenate([[0], np.cumsum(np.arange(n - 1, 0, -1))])
    for _ in range(max_tries):
        if p >= 1.0:
            picks = np.arange(total, dtype=np.int64)
        else:
            # expected E + O(sqrt(E)) geometric gaps, drawn in chunks
            chunks, pos = [], -1
            est = int(total * p + 10 * np.sqrt(total * p + 1)) + 16
            while pos < total:
                u = rng.random(est)
                gaps = 1 + np.floor(np.log1p(-u) / log1mp).astype(np.int64)
                idx = pos + np.cumsum(gaps)
                chunks.append(idx)
                pos = int(idx[-1])
            picks = np.concatenate(chunks)
            picks = picks[picks < total]
        i = np.searchsorted(row_start, picks, side="right") - 1
        j = picks - row_start[i] + i + 1
        edges = np.stack([i, j], axis=1).astype(np.int32)
        if edges_are_connected(n, edges):
            return SparseGraph(n=n, edges=edges, name="erdos_renyi")
    raise RuntimeError(f"could not draw a connected sparse G({n}, {p:.4f}) "
                       f"in {max_tries} tries")


@dataclasses.dataclass(frozen=True)
class DiGraph:
    """A directed communication graph in receiver convention.

    ``adjacency[i, j] = 1`` iff node i can RECEIVE from node j (arc j -> i) —
    the same orientation as a weight matrix entry W_ij in the engine's
    ``x <- W x`` rounds. Zero diagonal.
    """

    adjacency: np.ndarray
    name: str
    coords: np.ndarray | None = None

    @property
    def n(self) -> int:
        return self.adjacency.shape[0]

    @property
    def in_degrees(self) -> np.ndarray:
        """Arcs INTO each node (row sums): how many neighbours it hears."""
        return self.adjacency.sum(axis=1)

    @property
    def out_degrees(self) -> np.ndarray:
        """Arcs OUT of each node (column sums): how many neighbours hear it."""
        return self.adjacency.sum(axis=0)

    @property
    def num_arcs(self) -> int:
        return int(self.adjacency.sum())


def random_digraph(
    n: int, rng: np.random.Generator, p_extra: float = 0.15
) -> DiGraph:
    """Strongly connected random digraph: directed ring + extra random arcs.

    The directed ring backbone (arc i -> i+1 mod n) guarantees strong
    connectivity for every draw — no rejection loop — and each remaining
    ordered pair gains an arc independently w.p. ``p_extra``. This is the
    regime where row-stochastic averaging converges to a *non-uniform*
    Perron-weighted mixture instead of the true average, i.e. the testbed
    for push-sum / ratio-consensus corrections.
    """
    if n < 2:
        raise ValueError("random_digraph needs n >= 2")
    u = rng.random((n, n))
    a = (u < p_extra).astype(np.float64)
    np.fill_diagonal(a, 0.0)
    idx = np.arange(n)
    a[(idx + 1) % n, idx] = 1.0      # receiver convention: row i+1 hears i
    ang = 2 * np.pi * np.arange(n) / n
    coords = 0.5 + 0.5 * np.stack([np.cos(ang), np.sin(ang)], axis=1)
    return DiGraph(adjacency=a, name="directed", coords=coords)


def is_strongly_connected(adjacency: np.ndarray) -> bool:
    """Every node reaches every node along arcs: BFS on A and on A^T."""
    a = np.asarray(adjacency)
    return is_connected(a) and is_connected(a.T)


def random_geometric(
    n: int,
    rng: np.random.Generator,
    radius: float | None = None,
    max_tries: int = 200,
) -> Graph:
    """Random geometric graph on the unit square with the paper's radius.

    Nodes are uniform in [0,1]^2; edge iff distance <= sqrt(2 log N / N)
    (Section IV). That radius gives connectivity w.h.p.; we resample until the
    draw is actually connected (the paper implicitly conditions on connectivity:
    averaging is ill-posed otherwise).
    """
    r = radius if radius is not None else float(np.sqrt(2.0 * np.log(n) / n))
    for _ in range(max_tries):
        pts = rng.random((n, 2))
        d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
        a = (d2 <= r * r).astype(np.float64)
        np.fill_diagonal(a, 0.0)
        g = _finalize(a, "rgg", pts)
        if is_connected(g.adjacency):
            return g
    raise RuntimeError(f"could not draw a connected RGG(n={n}, r={r:.4f}) "
                       f"in {max_tries} tries")


# ---------------------------------------------------------------------------
# Sparse (edge-list) graphs: the million-node representation.
# ---------------------------------------------------------------------------


def _canonical_edges(edges: np.ndarray) -> np.ndarray:
    """Normalize an (E, 2) edge array to the canonical undirected ordering.

    i < j per row, rows sorted lexicographically by (i, j), duplicates and
    self-loops dropped. This is exactly the ordering
    ``dynamics.edge_index(dense_w)`` produces (``np.nonzero`` on the upper
    triangle is row-major), so schedules sampled against either
    representation of the same graph consume identical RNG draws.
    """
    e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    lo = np.minimum(e[:, 0], e[:, 1])
    hi = np.maximum(e[:, 0], e[:, 1])
    keep = lo != hi
    lo, hi = lo[keep], hi[keep]
    order = np.lexsort((hi, lo))
    lo, hi = lo[order], hi[order]
    if len(lo):
        dup = np.concatenate([[False], (lo[1:] == lo[:-1]) & (hi[1:] == hi[:-1])])
        lo, hi = lo[~dup], hi[~dup]
    return np.stack([lo, hi], axis=1).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class SparseGraph:
    """A symmetric graph stored as its canonical undirected edge list.

    Attributes:
      n: number of nodes.
      edges: (E, 2) int32, i < j per row, lexicographically sorted
        (``_canonical_edges`` invariant).
      name: topology family name.
      coords: optional (N, d) node coordinates (geometric families).
    """

    n: int
    edges: np.ndarray
    name: str
    coords: np.ndarray | None = None

    @property
    def num_edges(self) -> int:
        return len(self.edges)

    @property
    def degrees(self) -> np.ndarray:
        d = np.bincount(self.edges[:, 0], minlength=self.n)
        d += np.bincount(self.edges[:, 1], minlength=self.n)
        return d

    @classmethod
    def from_graph(cls, g: Graph) -> "SparseGraph":
        return cls(n=g.n, edges=_canonical_edges(g.edge_list()), name=g.name,
                   coords=g.coords)

    def to_dense(self) -> Graph:
        """Materialize the (N, N) adjacency — small-N bridging only."""
        a = np.zeros((self.n, self.n))
        a[self.edges[:, 0], self.edges[:, 1]] = 1.0
        return _finalize(a, self.name, self.coords)


def edges_are_connected(n: int, edges: np.ndarray) -> bool:
    """Union-find connectivity over an edge list — O(E alpha(N)), no matrix."""
    if n <= 1:
        return True
    parent = np.arange(n, dtype=np.int64)

    def find(i: int) -> int:
        root = i
        while parent[root] != root:
            root = parent[root]
        while parent[i] != root:      # path compression
            parent[i], i = root, parent[i]
        return root

    components = n
    for i, j in np.asarray(edges, dtype=np.int64):
        ri, rj = find(i), find(j)
        if ri != rj:
            parent[ri] = rj
            components -= 1
            if components == 1:
                return True
    return components == 1


def sparse_chain(n: int) -> SparseGraph:
    """Path graph as an edge list — O(N) at any size."""
    if n < 2:
        raise ValueError("chain needs n >= 2")
    idx = np.arange(n - 1, dtype=np.int32)
    coords = np.stack([np.arange(n) / max(n - 1, 1), np.zeros(n)], axis=1)
    return SparseGraph(n=n, edges=np.stack([idx, idx + 1], axis=1),
                       name="chain", coords=coords)


def sparse_ring(n: int) -> SparseGraph:
    if n < 3:
        raise ValueError("ring needs n >= 3")
    idx = np.arange(n, dtype=np.int64)
    edges = _canonical_edges(np.stack([idx, (idx + 1) % n], axis=1))
    ang = 2 * np.pi * np.arange(n) / n
    coords = 0.5 + 0.5 * np.stack([np.cos(ang), np.sin(ang)], axis=1)
    return SparseGraph(n=n, edges=edges, name="ring", coords=coords)


def _grid_edges(rows: int, cols: int, wrap: bool) -> np.ndarray:
    i = np.arange(rows * cols, dtype=np.int64)
    r, c = np.divmod(i, cols)
    pairs = []
    if wrap:
        pairs.append(np.stack([i, r * cols + (c + 1) % cols], axis=1))
        pairs.append(np.stack([i, ((r + 1) % rows) * cols + c], axis=1))
    else:
        right = i[c < cols - 1]
        down = i[r < rows - 1]
        pairs.append(np.stack([right, right + 1], axis=1))
        pairs.append(np.stack([down, down + cols], axis=1))
    return _canonical_edges(np.concatenate(pairs))


def sparse_grid2d(rows: int, cols: int | None = None) -> SparseGraph:
    cols = cols if cols is not None else rows
    n = rows * cols
    rr, cc = np.divmod(np.arange(n), cols)
    coords = np.stack([cc / max(cols - 1, 1), rr / max(rows - 1, 1)], axis=1)
    return SparseGraph(n=n, edges=_grid_edges(rows, cols, wrap=False),
                       name="grid2d", coords=coords)


def sparse_torus2d(rows: int, cols: int | None = None) -> SparseGraph:
    cols = cols if cols is not None else rows
    n = rows * cols
    rr, cc = np.divmod(np.arange(n), cols)
    coords = np.stack([cc / cols, rr / rows], axis=1)
    return SparseGraph(n=n, edges=_grid_edges(rows, cols, wrap=True),
                       name="torus2d", coords=coords)


def barabasi_albert(n: int, m: int, rng: np.random.Generator) -> SparseGraph:
    """Barabási–Albert preferential attachment: power-law degrees, O(E) build.

    Starts from a star on m+1 nodes (connected, so the result is always
    connected); each subsequent node attaches to ``m`` distinct existing
    nodes sampled by degree. Sampling uses the standard repeated-endpoint
    trick — picking a uniform element of the running edge-endpoint list IS
    degree-proportional sampling — so the build never forms a degree
    histogram, let alone a matrix. Hub degree grows ~sqrt(N): exactly the
    heavy-tailed regime the dense (N, N) layout cannot reach and the
    edge-list engine is for.
    """
    if m < 1:
        raise ValueError(f"barabasi_albert needs m >= 1, got {m}")
    if n < m + 1:
        raise ValueError(f"barabasi_albert needs n >= m + 1 = {m + 1}, got {n}")
    # seed star: node m attached to 0..m-1 keeps early degrees nonuniform-safe
    src = [np.repeat(np.int64(m), m)]
    dst = [np.arange(m, dtype=np.int64)]
    # running endpoint pool; grows by 2m per node — preallocate once
    pool = np.empty(2 * m * (n - m), dtype=np.int64)
    pool[: 2 * m : 2] = np.arange(m)
    pool[1 : 2 * m : 2] = m
    fill = 2 * m
    for v in range(m + 1, n):
        targets = np.empty(m, dtype=np.int64)
        chosen: set[int] = set()
        k = 0
        while k < m:
            t = int(pool[rng.integers(0, fill)])
            if t not in chosen:
                chosen.add(t)
                targets[k] = t
                k += 1
        src.append(np.repeat(np.int64(v), m))
        dst.append(targets)
        pool[fill : fill + m] = targets
        pool[fill + m : fill + 2 * m] = v
        fill += 2 * m
    edges = _canonical_edges(
        np.stack([np.concatenate(src), np.concatenate(dst)], axis=1))
    return SparseGraph(n=n, edges=edges, name="ba")


def random_geometric_sparse(
    n: int,
    rng: np.random.Generator,
    radius: float | None = None,
    max_tries: int = 200,
) -> SparseGraph:
    """RGG with the paper's radius via cell binning — O(N) memory, no (N, N) d2.

    Draws the SAME uniforms as ``random_geometric`` (one (n, 2) block per
    try), so at sizes where both run they produce the identical graph for the
    identical rng state — the invariant the dense/sparse engine-equivalence
    suite leans on. Neighbor search bins points into a grid of cells of side
    >= r and compares only the 9-cell neighborhoods, which at the
    connectivity radius sqrt(2 log N / N) costs O(N log N) comparisons
    instead of O(N^2).
    """
    r = radius if radius is not None else float(np.sqrt(2.0 * np.log(n) / n))
    for _ in range(max_tries):
        pts = rng.random((n, 2))
        edges = _rgg_edges_binned(pts, r)
        if edges_are_connected(n, edges):
            return SparseGraph(n=n, edges=edges, name="rgg", coords=pts)
    raise RuntimeError(f"could not draw a connected RGG(n={n}, r={r:.4f}) "
                       f"in {max_tries} tries")


def _rgg_edges_binned(pts: np.ndarray, r: float) -> np.ndarray:
    """Edges (distance <= r) via 9-neighborhood cell binning on [0,1]^2."""
    n = len(pts)
    ncell = max(1, int(1.0 / r)) if r > 0 else 1
    cell = np.minimum((pts * ncell).astype(np.int64), ncell - 1)
    cid = cell[:, 0] * ncell + cell[:, 1]
    order = np.argsort(cid, kind="stable")
    sorted_cid = cid[order]
    # bucket boundaries per occupied cell
    starts = np.searchsorted(sorted_cid, np.arange(ncell * ncell))
    ends = np.searchsorted(sorted_cid, np.arange(ncell * ncell), side="right")
    r2 = r * r
    out = []
    for cx in range(ncell):
        for cy in range(ncell):
            me = cx * ncell + cy
            mine = order[starts[me]:ends[me]]
            if len(mine) == 0:
                continue
            # same-cell pairs
            p = pts[mine]
            if len(mine) > 1:
                d2 = ((p[:, None, :] - p[None, :, :]) ** 2).sum(-1)
                ii, jj = np.nonzero(np.triu(d2 <= r2, k=1))
                if len(ii):
                    out.append(np.stack([mine[ii], mine[jj]], axis=1))
            # forward half of the 8-neighborhood (avoid double-visiting)
            for dx, dy in ((0, 1), (1, -1), (1, 0), (1, 1)):
                ox, oy = cx + dx, cy + dy
                if not (0 <= ox < ncell and 0 <= oy < ncell):
                    continue
                other = ox * ncell + oy
                theirs = order[starts[other]:ends[other]]
                if len(theirs) == 0:
                    continue
                q = pts[theirs]
                d2 = ((p[:, None, :] - q[None, :, :]) ** 2).sum(-1)
                ii, jj = np.nonzero(d2 <= r2)
                if len(ii):
                    out.append(np.stack([mine[ii], theirs[jj]], axis=1))
    if not out:
        return np.zeros((0, 2), dtype=np.int32)
    return _canonical_edges(np.concatenate(out))


def is_connected(adjacency: np.ndarray) -> bool:
    """BFS connectivity check (vectorized frontier expansion)."""
    n = adjacency.shape[0]
    visited = np.zeros(n, dtype=bool)
    frontier = np.zeros(n, dtype=bool)
    visited[0] = frontier[0] = True
    while frontier.any():
        nxt = (adjacency[frontier].sum(axis=0) > 0) & ~visited
        visited |= nxt
        frontier = nxt
    return bool(visited.all())


def diameter(adjacency: np.ndarray, max_iter: int | None = None) -> int:
    """Graph diameter via repeated boolean matrix powering (N <= few thousand).

    This is also the number of max-consensus iterations Algorithm 1 needs for
    exact sup-norm agreement (paper, Section III-D).
    """
    n = adjacency.shape[0]
    reach = (adjacency > 0) | np.eye(n, dtype=bool)
    dist = np.where(adjacency > 0, 1, np.where(np.eye(n, dtype=bool), 0, -1))
    cur = reach
    d = 1
    limit = max_iter if max_iter is not None else n
    while (dist < 0).any() and d < limit:
        nxt = cur @ reach
        newly = nxt & ~cur
        d += 1
        dist[newly] = d
        cur = nxt
        if not newly.any():
            break
    if (dist < 0).any():
        raise ValueError("graph is disconnected; diameter undefined")
    return int(dist.max())
