"""Network topologies for distributed averaging.

The paper evaluates on chain graphs and random geometric graphs (RGG) with the
connectivity radius sqrt(2 log N / N) (Gupta-Kumar scaling, connected w.h.p.).
We additionally provide ring / 2-D grid / 2-D torus (the topologies used for the
pod-level consensus fabric in ``repro.dist``) plus a few classics used in tests.

All functions return a dense symmetric 0/1 adjacency matrix (numpy, float64) with
zero diagonal. Dense is the right representation here: the paper's experiments are
N <= a few thousand, and spectral analysis (eigenvalues of W) is dense anyway.
"""
from __future__ import annotations

import dataclasses
import numpy as np

__all__ = [
    "Graph",
    "chain",
    "ring",
    "grid2d",
    "torus2d",
    "random_geometric",
    "complete",
    "star",
    "hypercube",
    "erdos_renyi",
    "is_connected",
    "diameter",
]


@dataclasses.dataclass(frozen=True)
class Graph:
    """A symmetric communication graph.

    Attributes:
      adjacency: (N, N) 0/1 symmetric matrix, zero diagonal.
      name: topology family name.
      coords: optional (N, d) node coordinates (RGG / grid), for plotting & inits.
    """

    adjacency: np.ndarray
    name: str
    coords: np.ndarray | None = None

    @property
    def n(self) -> int:
        return self.adjacency.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        return self.adjacency.sum(axis=1)

    def neighbors(self, i: int) -> np.ndarray:
        return np.nonzero(self.adjacency[i])[0]

    @property
    def num_edges(self) -> int:
        return int(self.adjacency.sum()) // 2

    def laplacian(self, normalized: bool = False) -> np.ndarray:
        a = self.adjacency
        d = self.degrees
        lap = np.diag(d) - a
        if normalized:
            with np.errstate(divide="ignore"):
                dinv = np.where(d > 0, 1.0 / np.sqrt(d), 0.0)
            lap = dinv[:, None] * lap * dinv[None, :]
        return lap

    def edge_list(self) -> np.ndarray:
        iu = np.triu_indices(self.n, k=1)
        mask = self.adjacency[iu] > 0
        return np.stack([iu[0][mask], iu[1][mask]], axis=1)


def _finalize(a: np.ndarray, name: str, coords: np.ndarray | None = None) -> Graph:
    a = np.asarray(a, dtype=np.float64)
    np.fill_diagonal(a, 0.0)
    a = np.maximum(a, a.T)
    return Graph(adjacency=a, name=name, coords=coords)


def chain(n: int) -> Graph:
    """Path graph on n vertices — the paper's hardest topology (diameter n-1)."""
    if n < 2:
        raise ValueError("chain needs n >= 2")
    a = np.zeros((n, n))
    idx = np.arange(n - 1)
    a[idx, idx + 1] = 1.0
    coords = np.stack([np.arange(n) / max(n - 1, 1), np.zeros(n)], axis=1)
    return _finalize(a, "chain", coords)


def ring(n: int) -> Graph:
    """Cycle on n vertices — the natural cross-pod gossip topology."""
    if n < 3:
        raise ValueError("ring needs n >= 3")
    a = np.zeros((n, n))
    idx = np.arange(n)
    a[idx, (idx + 1) % n] = 1.0
    ang = 2 * np.pi * np.arange(n) / n
    coords = 0.5 + 0.5 * np.stack([np.cos(ang), np.sin(ang)], axis=1)
    return _finalize(a, "ring", coords)


def grid2d(rows: int, cols: int | None = None) -> Graph:
    """2-D grid (no wraparound): rho(W-J) = 1 - Theta(1/N) => gain Omega(sqrt(N))."""
    cols = cols if cols is not None else rows
    n = rows * cols
    a = np.zeros((n, n))
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            if c + 1 < cols:
                a[i, i + 1] = 1.0
            if r + 1 < rows:
                a[i, i + cols] = 1.0
    rr, cc = np.divmod(np.arange(n), cols)
    coords = np.stack([cc / max(cols - 1, 1), rr / max(rows - 1, 1)], axis=1)
    return _finalize(a, "grid2d", coords)


def torus2d(rows: int, cols: int | None = None) -> Graph:
    """2-D torus (wraparound grid) — matches TPU ICI/pod fabric geometry."""
    cols = cols if cols is not None else rows
    n = rows * cols
    a = np.zeros((n, n))
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            a[i, r * cols + (c + 1) % cols] = 1.0
            a[i, ((r + 1) % rows) * cols + c] = 1.0
    rr, cc = np.divmod(np.arange(n), cols)
    coords = np.stack([cc / cols, rr / rows], axis=1)
    return _finalize(a, "torus2d", coords)


def complete(n: int) -> Graph:
    a = np.ones((n, n)) - np.eye(n)
    return _finalize(a, "complete")


def star(n: int) -> Graph:
    a = np.zeros((n, n))
    a[0, 1:] = 1.0
    return _finalize(a, "star")


def hypercube(d: int) -> Graph:
    """d-dimensional hypercube on 2^d vertices."""
    n = 1 << d
    a = np.zeros((n, n))
    for i in range(n):
        for b in range(d):
            a[i, i ^ (1 << b)] = 1.0
    return _finalize(a, "hypercube")


def erdos_renyi(n: int, p: float, rng: np.random.Generator) -> Graph:
    u = rng.random((n, n))
    # Bernoulli(p) on the strictly-upper entries only: masking AFTER the
    # comparison, else the zeroed lower triangle compares 0 < p == True and
    # every draw degenerates to (nearly) complete with doubled entries.
    a = np.triu(u < p, 1).astype(np.float64)
    return _finalize(a + a.T, "erdos_renyi")


def random_geometric(
    n: int,
    rng: np.random.Generator,
    radius: float | None = None,
    max_tries: int = 200,
) -> Graph:
    """Random geometric graph on the unit square with the paper's radius.

    Nodes are uniform in [0,1]^2; edge iff distance <= sqrt(2 log N / N)
    (Section IV). That radius gives connectivity w.h.p.; we resample until the
    draw is actually connected (the paper implicitly conditions on connectivity:
    averaging is ill-posed otherwise).
    """
    r = radius if radius is not None else float(np.sqrt(2.0 * np.log(n) / n))
    for _ in range(max_tries):
        pts = rng.random((n, 2))
        d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
        a = (d2 <= r * r).astype(np.float64)
        np.fill_diagonal(a, 0.0)
        g = _finalize(a, "rgg", pts)
        if is_connected(g.adjacency):
            return g
    raise RuntimeError(f"could not draw a connected RGG(n={n}, r={r:.4f}) "
                       f"in {max_tries} tries")


def is_connected(adjacency: np.ndarray) -> bool:
    """BFS connectivity check (vectorized frontier expansion)."""
    n = adjacency.shape[0]
    visited = np.zeros(n, dtype=bool)
    frontier = np.zeros(n, dtype=bool)
    visited[0] = frontier[0] = True
    while frontier.any():
        nxt = (adjacency[frontier].sum(axis=0) > 0) & ~visited
        visited |= nxt
        frontier = nxt
    return bool(visited.all())


def diameter(adjacency: np.ndarray, max_iter: int | None = None) -> int:
    """Graph diameter via repeated boolean matrix powering (N <= few thousand).

    This is also the number of max-consensus iterations Algorithm 1 needs for
    exact sup-norm agreement (paper, Section III-D).
    """
    n = adjacency.shape[0]
    reach = (adjacency > 0) | np.eye(n, dtype=bool)
    dist = np.where(adjacency > 0, 1, np.where(np.eye(n, dtype=bool), 0, -1))
    cur = reach
    d = 1
    limit = max_iter if max_iter is not None else n
    while (dist < 0).any() and d < limit:
        nxt = cur @ reach
        newly = nxt & ~cur
        d += 1
        dist[newly] = d
        cur = nxt
        if not newly.any():
            break
    if (dist < 0).any():
        raise ValueError("graph is disconnected; diameter undefined")
    return int(dist.max())
