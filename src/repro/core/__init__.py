"""Paper core: two-tap memory-accelerated distributed averaging.

Faithful implementation of Oreshkin, Coates & Rabbat, "Optimization and
Analysis of Distributed Averaging with Short Node Memory" (2009): topologies,
weight matrices, the accelerated operator and its optimal mixing parameter
(Theorem 1), Algorithm-1 decentralized lambda_2 estimation, the comparison
baselines, convergence metrics, and a vectorized simulation engine.
"""
from . import accel, algorithms, baselines, doi, dynamics, metrics, simulator, topology, weights
from .algorithms import ConsensusAlgorithm, get_algorithm, register_algorithm, registered_algorithms
from .dynamics import DynamicsSpec, masked_w, parse_dynamics
from .accel import (
    Theta,
    alpha_star,
    alpha_star_from_w,
    phi3_matrix,
    rho_accel,
    spectral_radius_minus_j,
    theta_asymptotic,
    theta_ls,
)
from .doi import estimate_lambda2
from .metrics import EPS_PAPER, averaging_time, processing_gain, tau_asym
from .weights import lazy, metropolis_hastings

__all__ = [
    "accel",
    "algorithms",
    "ConsensusAlgorithm",
    "get_algorithm",
    "register_algorithm",
    "registered_algorithms",
    "baselines",
    "doi",
    "dynamics",
    "DynamicsSpec",
    "masked_w",
    "parse_dynamics",
    "metrics",
    "simulator",
    "topology",
    "weights",
    "Theta",
    "alpha_star",
    "alpha_star_from_w",
    "phi3_matrix",
    "rho_accel",
    "spectral_radius_minus_j",
    "theta_asymptotic",
    "theta_ls",
    "estimate_lambda2",
    "EPS_PAPER",
    "averaging_time",
    "processing_gain",
    "tau_asym",
    "lazy",
    "metropolis_hastings",
]
