"""Time-varying & failure-injected consensus dynamics.

The paper's analysis (Theorems 1-3) fixes one symmetric W, but the
deployments that motivate it — sensor networks, gossip-based learning — run
on links that drop and nodes that churn. This module provides the *topology
schedule* layer: per-round edge activity masks over a nominal graph, plus
the mass-preserving re-weighting that turns a masked W back into a valid
consensus matrix, and the float64 numpy reference the accelerated engines
are tested against.

Masking rule (mass-preserving Metropolis re-weighting): when edge (i, j) is
down in round t, its weight W_ij returns to BOTH diagonals,

    W_eff(t) = W .* M(t) + diag( (W .* (1 - M(t))) @ 1 ),

with M(t) symmetric 0/1 on the off-diagonal support of W and 1 on the
diagonal. W_eff(t) stays symmetric and doubly stochastic for every mask, so
the network average is conserved round by round no matter which links fail —
an isolated node simply holds its value (W_eff row -> e_i). What is *lost*
under failures is the optimality of alpha*: the two-tap predictor keeps the
mixing parameter computed for the nominal W, and ``benchmarks/fig_robustness``
measures what that mismatch costs.

Schedules (all produce per-round edge bits; 1 = link up):

* ``bernoulli:p``  — every edge fails independently each round w.p. p
  (i.i.d. link failures, the model of Sirocchi & Bogliolo, arXiv:2309.01144).
* ``rewire:p:T``   — the failure set is redrawn every T rounds and held in
  between (periodic rewiring: the active graph B(t) is piecewise-constant).
* ``churn:p``      — node churn: each *node* is down w.p. p per round; an
  edge is live iff both endpoints are up. A down node keeps its value
  (mass-preserving re-weighting above), so returning nodes rejoin without
  biasing the average.
* ``correlated:p[:blocks[:period]]`` — correlated/adversarial regional
  outages: nodes are partitioned into ``blocks`` contiguous index blocks
  (contiguous indices ARE geographic blocks on the lattice families — chain
  and grid2d number nodes in spatial row-major order), each block goes down
  w.p. p per ``period``-round window and stays down for the whole window,
  and an edge is dead iff EITHER endpoint's block is down. Unlike bernoulli,
  failures arrive in large simultaneous slabs — partition events when two or
  more blocks drop at once — which is the loss pattern that separates
  mass-conserving (push-sum-style) registrations from ones that merely
  tolerate i.i.d. erasures.
* ``static``       — all edges up every round (the paper's regime).

Schedules are sampled on the host with a numpy RNG keyed by the *graph*
(not the grid cell), and thresholded as ``U >= p``: cells that share a graph
share the underlying uniforms, so failure sets are **nested across failure
probabilities** (monotone coupling) and identical across theta designs
(common random numbers). Gain-vs-p curves read off such a grid are
variance-reduced and degrade monotonically instead of bouncing with the
draw.

The accelerated execution paths live elsewhere: ``repro.sweep.engine``
scans compressed (R, E) bit masks and expands them in the scan body (never
materializing per-round W matrices in HBM), and
``repro.kernels.gossip_round`` has the fused masked Pallas kernel.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np

__all__ = [
    "DynamicsSpec",
    "parse_dynamics",
    "edge_index",
    "graph_rng",
    "sample_edge_bits",
    "masked_w",
    "simulate_dynamic_reference",
]


@dataclasses.dataclass(frozen=True)
class DynamicsSpec:
    """One parsed topology schedule (see module docstring for the kinds)."""

    kind: str          # "static" | "bernoulli" | "rewire" | "churn" | "correlated"
    p: float = 0.0     # failure probability (per-edge, per-node or per-block)
    period: int = 1    # rewire/correlated: rounds between redraws
    blocks: int = 4    # correlated: number of contiguous geographic blocks

    def __post_init__(self):
        if self.kind not in ("static", "bernoulli", "rewire", "churn",
                             "correlated"):
            raise ValueError(
                f"unknown dynamics kind {self.kind!r} "
                f"(have static/bernoulli/rewire/churn/correlated)"
            )
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"failure probability must be in [0, 1], got {self.p}")
        if self.period < 1:
            raise ValueError(f"rewire period must be >= 1, got {self.period}")
        if self.blocks < 1:
            raise ValueError(f"correlated needs >= 1 block, got {self.blocks}")

    @property
    def is_static(self) -> bool:
        return self.kind == "static" or self.p == 0.0


def parse_dynamics(spec: str | DynamicsSpec) -> DynamicsSpec:
    """Parse ``"static"`` / ``"bernoulli:p"`` / ``"rewire:p:period"`` / ``"churn:p"``."""
    if isinstance(spec, DynamicsSpec):
        return spec
    parts = str(spec).split(":")
    kind = parts[0]
    if kind == "static":
        if len(parts) != 1:
            raise ValueError(f"static takes no parameters, got {spec!r}")
        return DynamicsSpec("static")
    if kind in ("bernoulli", "churn"):
        if len(parts) != 2:
            raise ValueError(f"{kind} needs one parameter, e.g. '{kind}:0.1', got {spec!r}")
        return DynamicsSpec(kind, p=float(parts[1]))
    if kind == "rewire":
        if len(parts) != 3:
            raise ValueError(f"rewire needs 'rewire:p:period', got {spec!r}")
        return DynamicsSpec(kind, p=float(parts[1]), period=int(parts[2]))
    if kind == "correlated":
        if not 2 <= len(parts) <= 4:
            raise ValueError(
                f"correlated needs 'correlated:p[:blocks[:period]]', got {spec!r}")
        return DynamicsSpec(
            kind, p=float(parts[1]),
            blocks=int(parts[2]) if len(parts) > 2 else 4,
            period=int(parts[3]) if len(parts) > 3 else 1)
    raise ValueError(f"unknown dynamics kind {kind!r} in {spec!r} "
                     f"(have static/bernoulli/rewire/churn/correlated)")


def edge_index(w: np.ndarray) -> np.ndarray:
    """(E, 2) int32 upper-triangular off-diagonal support of W (i < j).

    Deterministic row-major order, so two cells built from the same graph get
    identical edge orderings — the invariant the coupled-RNG sampling relies
    on. Zero-padded rows/cols contribute no edges. The support is symmetrized
    (|W| + |W|^T) before the triangle is read, so an asymmetric
    (column-stochastic / directed) W yields one undirected mask slot per node
    PAIR — masking a pair kills whichever arcs exist — and a symmetric W is
    unchanged.
    """
    a = np.abs(np.asarray(w))
    i, j = np.nonzero(np.triu(a + a.T, k=1))
    return np.stack([i, j], axis=1).astype(np.int32)


def graph_rng(seed: int, key: tuple) -> np.random.Generator:
    """Host RNG stream keyed by (seed, graph identity) — NOT by grid cell.

    crc32 (unsalted, unlike ``hash``) keeps the stream reproducible across
    processes; cells sharing a graph share the stream, which is what couples
    their failure draws.
    """
    return np.random.default_rng([int(seed), zlib.crc32(repr(key).encode("utf-8"))])


def sample_edge_bits(
    spec: str | DynamicsSpec,
    num_rounds: int,
    idx: np.ndarray,
    num_nodes: int,
    rng: np.random.Generator,
) -> np.ndarray:
    """(R, E) uint8 per-round edge activity bits (1 = link up) for ``spec``.

    Always consumes the same uniforms from ``rng`` in the same order —
    (R, E) edge uniforms then (R, N) node uniforms — regardless of kind, so
    different specs sampled from clones of one graph-keyed stream stay
    coupled (bits at p' >= p are a subset of bits at p). ``correlated`` draws
    its (R, blocks) block uniforms AFTER the two standard arrays, preserving
    the consumption prefix every pre-existing kind relies on while keeping
    correlated outages themselves nested across p.
    """
    spec = parse_dynamics(spec)
    e = len(idx)
    u_edges = rng.random((num_rounds, e))
    u_nodes = rng.random((num_rounds, num_nodes))
    if spec.is_static:
        return np.ones((num_rounds, e), dtype=np.uint8)
    if spec.kind == "bernoulli":
        return (u_edges >= spec.p).astype(np.uint8)
    if spec.kind == "rewire":
        held = (np.arange(num_rounds) // spec.period) * spec.period
        return (u_edges[held] >= spec.p).astype(np.uint8)
    if spec.kind == "correlated":
        # contiguous index blocks == geographic blocks on the lattice
        # families; a block outage is held for a whole period window and an
        # edge dies with EITHER endpoint's block (partition events included)
        u_blocks = rng.random((num_rounds, spec.blocks))
        held = (np.arange(num_rounds) // spec.period) * spec.period
        block_up = u_blocks[held] >= spec.p                    # (R, B)
        blk = np.minimum(
            (idx.astype(np.int64) * spec.blocks) // max(num_nodes, 1),
            spec.blocks - 1)                                   # (E, 2)
        return (block_up[:, blk[:, 0]] & block_up[:, blk[:, 1]]).astype(np.uint8)
    # churn: edge live iff both endpoints are up this round
    up = u_nodes >= spec.p
    return (up[:, idx[:, 0]] & up[:, idx[:, 1]]).astype(np.uint8)


def masked_w(w: np.ndarray, bits: np.ndarray, idx: np.ndarray,
             renorm: str = "receiver") -> np.ndarray:
    """One round's re-normalized effective matrix W_eff (numpy reference).

    ``bits`` is the (E,) activity row for this round, ``idx`` the (E, 2)
    edge list. ``renorm`` picks where a dropped entry W_ij goes:

    * ``"receiver"`` (default) — W_ij returns to RECEIVER i's diagonal
      (row-sum-preserving). On a symmetric doubly-stochastic W this is also
      the sender's diagonal, so W_eff stays symmetric doubly stochastic
      (module docstring) and the mean is conserved.
    * ``"sender"`` — W_ij returns to SENDER j's diagonal
      (column-sum-preserving): the un-delivered share of node j's mass stays
      with node j instead of inflating the receiver's self-weight. This is
      the loss model of push-sum / ratio-consensus, where the masked W_eff
      must remain column stochastic for total mass to be conserved — the
      symmetric diagonal rule would silently break exactly the invariant
      those algorithms exist to keep.
    """
    if renorm not in ("receiver", "sender"):
        raise ValueError(f"unknown mask renorm {renorm!r} (receiver/sender)")
    w = np.asarray(w)
    m = np.ones_like(w)
    b = np.asarray(bits, dtype=w.dtype)
    m[idx[:, 0], idx[:, 1]] = b
    m[idx[:, 1], idx[:, 0]] = b
    weff = w * m
    dropped = w * (1.0 - m)
    drop = dropped.sum(axis=1) if renorm == "receiver" else dropped.sum(axis=0)
    np.fill_diagonal(weff, weff.diagonal() + drop)
    return weff


def simulate_dynamic_reference(
    w: np.ndarray,
    x0: np.ndarray,
    coef: tuple[float, float, float],
    bits: np.ndarray,
    idx: np.ndarray,
    dtype=np.float64,
) -> tuple[np.ndarray, np.ndarray]:
    """Per-round masked-W reference run (the engines' correctness oracle).

    Materializes W_eff(t) = ``masked_w(w, bits[t], idx)`` each round and
    iterates the fused two-tap recursion

        x(t+1) = a W_eff(t) x(t) + b x(t) + c x(t-1)

    mirroring the engine's MSE semantics (vs the true initial average, mean
    over nodes, round 0 included). Returns (x_final (N, F), mse (R+1, F)).
    """
    a, b, c = (float(v) for v in coef)
    x = np.asarray(x0, dtype=dtype)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    xp = x.copy()
    xbar = x.mean(axis=0, keepdims=True)
    mse = [((x - xbar) ** 2).mean(axis=0)]
    wd = np.asarray(w, dtype=dtype)
    for t in range(bits.shape[0]):
        weff = masked_w(wd, bits[t], idx)
        x, xp = a * (weff @ x) + b * x + c * xp, x
        mse.append(((x - xbar) ** 2).mean(axis=0))
    if squeeze:
        x = x[:, 0]
    return x, np.stack(mse)
