"""The acceleration baselines the paper compares against (Section IV).

* Polynomial filtering  [Kokiopoulou & Frossard, ref 14]: each super-iteration
  applies a degree-k polynomial p(W) (k consensus ticks + local history
  combination). The optimal coefficients minimize the filtered spectrum and
  are found numerically by (pseudo-)inverting a Vandermonde matrix in the
  eigenvalues of W — which the paper's footnote 2 observes becomes
  ill-conditioned for k > 7; we expose the ridge knob and reproduce the
  instability in a test.

* Finite-time consensus [Sundaram & Hadjicostis, ref 16]: with the full value
  history, after deg(minpoly(W)) - 1 iterations every node can recover the
  exact average by a topology-dependent linear combination of its history.
  We implement the oracle: q(W) = prod_{j>=2} (W - mu_j I)/(1 - mu_j) = J for
  the distinct eigenvalues mu_j != 1 of W. The benchmark only needs the
  iteration horizon (d - 1) plus exactness.

* The optimal-weight-matrix baseline [Xiao-Boyd, ref 10] lives in
  ``repro.core.weights.optimal_weights``.
"""
from __future__ import annotations

import dataclasses
import numpy as np


__all__ = [
    "PolyFilter",
    "design_poly_filter",
    "design_poly_filter_from_spectrum",
    "poly_filter_step",
    "run_poly_filter",
    "distinct_eigenvalues",
    "finite_time_iterations",
    "finite_time_matrix",
]


# ---------------------------------------------------------------------------
# Polynomial filtering (ref [14]).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PolyFilter:
    """Coefficients a_0..a_k of p(z) = sum_j a_j z^j with p(1) = 1."""

    coeffs: np.ndarray          # (k+1,)
    rho_filtered: float         # rho(p(W) - J) at design time
    cond: float                 # condition number of the Vandermonde gram

    @property
    def degree(self) -> int:
        return len(self.coeffs) - 1

    @property
    def ticks_per_apply(self) -> int:
        """One application of p(W) costs k consensus ticks (W-multiplies)."""
        return self.degree

    def rho_per_tick(self) -> float:
        """Effective per-consensus-tick contraction: rho^(1/k)."""
        if self.rho_filtered <= 0:
            return 0.0
        return float(self.rho_filtered ** (1.0 / max(self.degree, 1)))


def design_poly_filter(
    w: np.ndarray, degree: int, ridge: float = 0.0
) -> PolyFilter:
    """LS design from ref [14]: minimize sum_i p(lambda_i)^2 s.t. p(1) = 1.

    Eigensolves W and delegates to ``design_poly_filter_from_spectrum`` —
    call that directly when the spectrum is already in hand (the sweep grid
    computes it once per graph).
    """
    vals = np.linalg.eigvalsh(w)
    return design_poly_filter_from_spectrum(vals, degree, ridge)


def design_poly_filter_from_spectrum(
    eigvals: np.ndarray, degree: int, ridge: float = 0.0
) -> PolyFilter:
    """The ref-[14] LS design from the (full) spectrum of W.

    Closed form via the Vandermonde gram G = V^T V (+ ridge I):
    a = G^-1 c / (c^T G^-1 c), c = ones (the powers of z = 1).
    The paper's footnote-2 ill-conditioning is exactly cond(G) blowing up with
    degree; ridge > 0 regularizes (we default to exact LS like the reference).
    """
    lam = np.sort(np.asarray(eigvals))[:-1]  # exclude the eigenvalue 1
    v = np.vander(lam, degree + 1, increasing=True)  # (N-1, k+1)
    g = v.T @ v + ridge * np.eye(degree + 1)
    c = np.ones(degree + 1)
    cond = float(np.linalg.cond(g))
    try:
        gi_c = np.linalg.solve(g, c)
    except np.linalg.LinAlgError:
        gi_c = np.linalg.lstsq(g, c, rcond=None)[0]
    a = gi_c / (c @ gi_c)
    # evaluate the achieved filtered spectral radius
    pw = np.polynomial.polynomial.polyval(lam, a)
    rho = float(np.max(np.abs(pw)))
    return PolyFilter(coeffs=np.asarray(a, dtype=np.float64), rho_filtered=rho, cond=cond)


def poly_filter_matrix(w: np.ndarray, filt: PolyFilter) -> np.ndarray:
    """Dense p(W) (for analysis; the distributed algorithm never forms it)."""
    n = w.shape[0]
    acc = np.zeros_like(w)
    pk = np.eye(n)
    for a_j in filt.coeffs:
        acc = acc + a_j * pk
        pk = pk @ w
    return acc


def poly_filter_step(w: np.ndarray, filt: PolyFilter, x: np.ndarray) -> np.ndarray:
    """One super-iteration via Horner (k W-multiplies, no dense p(W))."""
    a = filt.coeffs
    acc = a[-1] * x
    for j in range(len(a) - 2, -1, -1):
        acc = w @ acc + a[j] * x
    return acc


def run_poly_filter(
    w: np.ndarray,
    filt: PolyFilter,
    x0: np.ndarray,
    num_ticks: int,
    record: bool = False,
):
    """Run for a budget of ``num_ticks`` consensus ticks (k per super-iteration).

    The recorded trajectory is per-tick with the state held constant inside a
    super-iteration (fair tick-for-tick comparison against one-W-multiply
    methods, as in the paper's figures).
    """
    x = np.asarray(x0, dtype=np.float64)
    k = filt.ticks_per_apply
    traj = [x.copy()] if record else None
    done = 0
    while done + k <= num_ticks:
        x = poly_filter_step(w, filt, x)
        done += k
        if record:
            traj.extend([x.copy()] * k)
    if record:
        while len(traj) < num_ticks + 1:
            traj.append(x.copy())
        return x, np.stack(traj)
    return x


# ---------------------------------------------------------------------------
# Finite-time consensus (ref [16]) — minimal-polynomial oracle.
# ---------------------------------------------------------------------------

def distinct_eigenvalues(w: np.ndarray, tol: float = 1e-8) -> np.ndarray:
    """Distinct eigenvalues of symmetric W, clustered with absolute tolerance."""
    vals = np.sort(np.linalg.eigvalsh(w))
    out = [vals[0]]
    for v in vals[1:]:
        if v - out[-1] > tol:
            out.append(v)
    return np.asarray(out)


def finite_time_iterations(w: np.ndarray, tol: float = 1e-8) -> int:
    """Iterations after which the linear-observer method can recover the average.

    = deg(minpoly(W)) - 1 = (#distinct eigenvalues) - 1 for diagonalizable W.
    """
    return len(distinct_eigenvalues(w, tol)) - 1


def finite_time_matrix(w: np.ndarray, tol: float = 1e-8) -> np.ndarray:
    """q(W) = prod_{mu != 1} (W - mu I) / (1 - mu) — equals J exactly.

    Evaluated in product form (numerically stable for the small-N test graphs;
    the distributed algorithm works on local histories and never forms this).
    """
    n = w.shape[0]
    mus = distinct_eigenvalues(w, tol)
    acc = np.eye(n)
    for mu in mus:
        if abs(mu - 1.0) <= tol:
            continue
        acc = acc @ (w - mu * np.eye(n)) / (1.0 - mu)
    return acc
