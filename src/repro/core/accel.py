"""Two-tap memory-accelerated consensus — the paper's core contribution.

Implements:

* predictor designs: the least-squares design of Aysal et al. (Eq. 8),
  theta = (-2/3, 1/3, 4/3), and the asymptotically-optimal design
  theta = (-eps, 0, 1+eps) from Section III-B;
* ``alpha_star`` — Theorem 1 / Eq. (14): the closed-form optimal mixing
  parameter, a function of theta and lambda_2(W) only;
* ``rho_accel`` — the resulting spectral radius sqrt(-alpha* theta_1)
  (Section V-C), plus the Theorem-2 bound 1 - sqrt(Psi(N));
* ``phi3_matrix`` — the 2N x 2N companion operator Phi_3[alpha] (Eq. 7);
* ``phi3_eigenvalues`` — the analytic eigenvalues of Phi_3[alpha] via the
  quadratic eigenvalue problem (Eq. 35/36), used to cross-check the dense
  eigendecomposition in tests;
* ``accelerated_step`` / ``run_accelerated`` — the node-local recursion
  (Eq. 4a-4c), vectorized over feature columns.

Everything here is plain numpy float64: this is the *theory* layer. The
high-throughput simulation engine lives in ``repro.core.simulator`` and the
SPMD/pjit mapping in ``repro.dist``.
"""
from __future__ import annotations

import dataclasses
import numpy as np


__all__ = [
    "Theta",
    "theta_ls",
    "theta_asymptotic",
    "alpha_star",
    "alpha_star_jnp",
    "alpha_star_from_w",
    "two_tap_interval_weights",
    "m_tap_weights",
    "averaging_time_lower_bound",
    "rho_accel",
    "rho_accel_bound",
    "gain_bound",
    "w3_matrix",
    "phi3_matrix",
    "phi3_eigenvalues",
    "spectral_radius_minus_j",
    "lambda2",
    "accelerated_step",
    "run_accelerated",
    "run_memoryless",
]


@dataclasses.dataclass(frozen=True)
class Theta:
    """Two-tap predictor coefficients theta = (theta1, theta2, theta3).

    Theorem 1's technical conditions: theta1 + theta2 + theta3 = 1,
    theta3 >= 1, theta2 >= 0 (which force theta1 <= 0).
    """

    t1: float
    t2: float
    t3: float

    def __post_init__(self) -> None:
        if abs(self.t1 + self.t2 + self.t3 - 1.0) > 1e-9:
            raise ValueError(f"theta must sum to 1, got {self.t1+self.t2+self.t3}")
        if self.t3 < 1.0 - 1e-12:
            raise ValueError(f"theta3 must be >= 1, got {self.t3}")
        if self.t2 < -1e-12:
            raise ValueError(f"theta2 must be >= 0, got {self.t2}")

    @property
    def as_tuple(self) -> tuple[float, float, float]:
        return (self.t1, self.t2, self.t3)

    @property
    def alpha_max(self) -> float:
        """Stability boundary: Phi_3[alpha] is convergent iff alpha in [0, -1/theta1)."""
        if self.t1 >= 0.0:
            return np.inf
        return -1.0 / self.t1

    @property
    def gamma(self) -> float:
        """Rate coefficient gamma(theta2, theta3) = sqrt((2(t3-1)+t2)/(t3-1+t2)).

        Eq. (15): rho(Phi3[alpha*]-J) = 1 - gamma sqrt(Psi(N)) + O(Psi(N)).
        Maximized (= sqrt(2)) by theta2 = 0, any theta3 > 1.
        """
        num = 2.0 * (self.t3 - 1.0) + self.t2
        den = (self.t3 - 1.0) + self.t2
        if den <= 0:
            return 0.0
        return float(np.sqrt(num / den))


def theta_ls() -> Theta:
    """Least-squares predictor design of Aysal et al. (Eq. 8).

    A = [[-2, 1], [-1, 1], [0, 1]] (times -2,-1,0 regress to a line), B = [1, 1]
    extrapolates to time +1: theta^T = B^T A^dagger = (-2/3, 1/3, 4/3).
    Computed here from the pseudo-inverse rather than hard-coded so the test
    suite can cross-check the closed form against the construction.
    """
    a = np.array([[-2.0, 1.0], [-1.0, 1.0], [0.0, 1.0]])
    b = np.array([1.0, 1.0])
    theta = np.linalg.pinv(a).T @ b
    return Theta(*theta)


def theta_asymptotic(eps: float = 0.5) -> Theta:
    """Asymptotically optimal design theta = (-eps, 0, 1+eps) (Section III-B).

    gamma = sqrt(2) independent of eps; the paper's experiments use eps = 1/2.
    """
    if eps <= 0:
        raise ValueError("eps must be > 0")
    return Theta(-eps, 0.0, 1.0 + eps)


def lambda2(w: np.ndarray) -> float:
    """Second-largest eigenvalue of a symmetric consensus matrix W."""
    vals = np.linalg.eigvalsh(w)
    return float(np.sort(vals)[-2])


def alpha_star(lam2: float, theta: Theta) -> float:
    """Theorem 1 / Eq. (14): optimal mixing parameter alpha*.

    alpha* = [-((t3-1) l^2 + t2 l + 2 t1) - 2 sqrt(t1^2 + t1 l (t2 + (t3-1) l))]
             / (t2 + (t3-1) l)^2,   l = lambda_2(W).

    Requires |lambda_N(W)| <= lambda_2(W) (ensured e.g. by the lazy (I+W)/2 map).
    """
    t1, t2, t3 = theta.as_tuple
    lam = float(lam2)
    den = (t2 + (t3 - 1.0) * lam) ** 2
    if den < 1e-300:
        # lam -> 0 with theta2 = 0: alpha* -> lam^2 / (4 eps) -> 0 (Taylor).
        return 0.0
    rad = t1 * t1 + t1 * lam * (t2 + (t3 - 1.0) * lam)
    if rad < 0:
        if rad < -1e-12:
            raise ValueError(
                f"negative discriminant {rad}: conditions of Theorem 1 violated "
                f"(lambda2={lam}, theta={theta.as_tuple})"
            )
        rad = 0.0
    num = -((t3 - 1.0) * lam * lam + t2 * lam + 2.0 * t1) - 2.0 * np.sqrt(rad)
    return float(num / den)


def alpha_star_jnp(lam2, theta):
    """Traceable twin of :func:`alpha_star` for in-scan re-solves.

    Same closed form (Theorem 1 / Eq. 14), but every host-side branch is a
    ``jnp.where`` so it can run on a traced ``lam2`` inside a jitted scan
    (the ``accel_adapt`` algorithm re-solves alpha* every round from its
    power-iteration lambda_2 estimate). Differences from the host oracle,
    both deliberate:

    * the ``den -> 0`` cutoff follows the *dtype* of ``lam2`` (f32 traces
      would flush the host's 1e-300 threshold to zero);
    * a negative discriminant clamps to 0 instead of raising — inside a
      scan a transiently out-of-model estimate must degrade gracefully,
      not abort the program. The host twin keeps the loud error.

    ``theta`` may be a :class:`Theta` or a plain ``(t1, t2, t3)`` tuple.
    Agreement with the host version to f64 roundoff is pinned by
    ``tests/test_adaptive.py``.
    """
    import jax.numpy as jnp

    t1, t2, t3 = theta.as_tuple if isinstance(theta, Theta) else tuple(theta)
    lam = jnp.asarray(lam2)
    edge = t2 + (t3 - 1.0) * lam
    den = edge * edge
    rad = jnp.maximum(t1 * t1 + t1 * lam * edge, 0.0)
    num = -((t3 - 1.0) * lam * lam + t2 * lam + 2.0 * t1) - 2.0 * jnp.sqrt(rad)
    cutoff = jnp.asarray(jnp.finfo(den.dtype).tiny, den.dtype) * 4.0
    safe = jnp.where(den < cutoff, 1.0, den)
    return jnp.where(den < cutoff, 0.0, num / safe)


def alpha_star_from_w(w: np.ndarray, theta: Theta) -> float:
    """alpha* computed from the matrix itself (convenience wrapper)."""
    return alpha_star(lambda2(w), theta)


def two_tap_interval_weights(lam_lo: float, lam_hi: float) -> tuple[float, float, float, float]:
    """Optimal stationary two-tap weights for a spectral interval [lo, hi].

    Shifted second-order Richardson (stationary Chebyshev limit / shifted
    heavy ball): for the recursion  x' = a (W x) + b x + c x_prev  with the
    non-consensus spectrum of W inside [lam_lo, lam_hi] (lam_hi < 1),

        d = 1 - (lo + hi)/2,    h = (hi - lo)/4,
        a = (d - sqrt(d^2 - 4 h^2)) / (2 h^2),
        b = -a (lo + hi)/2,     c = 1 - a - b,

    gives the minimax asymptotic rate rho = a*h (= sqrt(-c); every error
    mode lands on the complex circle |mu| = rho). Returns ``(a, b, c, rho)``.

    The symmetric case lo = -hi reduces *exactly* to Theorem 1 with the
    asymptotic design theta = (-eps, 0, 1+eps): a = 1 + rho^2, b = 0,
    c = -rho^2, rho = (1 - sqrt(1 - hi^2)) / hi. The asymmetric case is
    what Theorem 1 leaves on the table: the paper symmetrizes via the lazy
    (I + W)/2 map, while Metropolis chains/grids here have lam_N far from
    -lam_2, so centering the interval (the shift b) strictly beats alpha*
    tuned to [-lam_2, lam_2]. Used by :func:`m_tap_weights`.
    """
    lo, hi = float(lam_lo), float(lam_hi)
    if not (-1.0 < lo <= hi < 1.0):
        raise ValueError(f"need -1 < lam_lo <= lam_hi < 1, got [{lo}, {hi}]")
    d = 1.0 - 0.5 * (lo + hi)
    h = 0.25 * (hi - lo)
    if h < 1e-15:
        # degenerate single-point spectrum: first-order a = 1/d kills it
        a = 1.0 / d
        return a, -a * 0.5 * (lo + hi), 1.0 - a - (-a * 0.5 * (lo + hi)), 0.0
    disc = d * d - 4.0 * h * h  # = (1 - hi)(1 - lo) > 0 on the open interval
    a = (d - np.sqrt(disc)) / (2.0 * h * h)
    b = -a * 0.5 * (lo + hi)
    c = 1.0 - a - b
    return float(a), float(b), float(c), float(a * h)


def m_tap_weights(
    num_taps: int, lam2: float, lam_n: float | None = None
) -> tuple[np.ndarray, float]:
    """Analytic optimal stationary M-tap weights (the memory frontier).

    Weights ``(a, b, c_1, ..., c_{M-1})`` for the one-matvec recursion

        x(t+1) = a W x(t) + b x(t) + sum_m c_m x(t-m),

    minimizing the asymptotic rate over all stationary M-tap schemes given
    the admitted spectral statistics. Returns ``(weights, rho)``.

    The frontier is an *information* frontier, not a degree frontier:

    * M = 2 admits lambda_2 only, so the design must cover the symmetric
      interval [-lam2, lam2] — this is exactly Theorem 1's alpha* with the
      asymptotic theta (pinned by a property test).
    * M >= 3 admits the second statistic lambda_N, covering the true
      interval [lam_n, lam2]; by Golub & Varga's saturation theorem the
      optimal stationary rate over an interval is already achieved at two
      taps, so the analytic optimum puts *zero* weight on taps older than
      one round and all of the M >= 3 gain comes from the tighter interval.
      (Numerically re-confirmed on the discrete chain spectrum in
      ``tests/test_adaptive.py`` — a direct search over genuine 3-tap
      weights cannot beat the shifted two-tap rate.)

    So ``accel_m:3`` and ``accel_m:4`` share a rate and differ only in the
    (zero-padded) carry depth — the honest statement of Yi-Chai-Zhang-style
    analytic designs under a one-matvec-per-round cost model.
    """
    if num_taps < 2:
        raise ValueError(f"m_tap_weights needs num_taps >= 2, got {num_taps}")
    if num_taps == 2 or lam_n is None:
        lo, hi = -abs(float(lam2)), abs(float(lam2))
    else:
        lo, hi = float(lam_n), float(lam2)
    a, b, c, rho = two_tap_interval_weights(lo, hi)
    weights = np.zeros(num_taps + 1, dtype=np.float64)
    weights[0], weights[1], weights[2] = a, b, c
    return weights, rho


def averaging_time_lower_bound(eps: float, lam_lo: float, lam_hi: float) -> int:
    """Chebyshev minimax lower bound on the eps-averaging time.

    Any consensus protocol whose round-t state is a degree-t polynomial in W
    applied to x(0) — every algorithm in the registry, memoryless through
    M-tap — has worst-case error over the interval [lam_lo, lam_hi] at
    least 1/|T_t(sigma)|, sigma = (2 - lo - hi)/(hi - lo) (the Chebyshev
    extremality theorem; the graph-topological counterpart is the
    Olshevsky-Tsitsiklis Omega(n^2) chain bound, arXiv:1003.5941). So

        T(eps) >= ceil( arccosh(1/eps) / arccosh(sigma) ).

    ``benchmarks/fig_adaptive.py`` reports T_measured / T_lb per cell — the
    distance-to-optimal column for the whole registry.
    """
    lo, hi = float(lam_lo), float(lam_hi)
    if not (-1.0 < lo <= hi < 1.0):
        raise ValueError(f"need -1 < lam_lo <= lam_hi < 1, got [{lo}, {hi}]")
    if not (0.0 < eps < 1.0):
        raise ValueError(f"need 0 < eps < 1, got {eps}")
    sigma = (2.0 - lo - hi) / max(hi - lo, 1e-15)
    if sigma <= 1.0 + 1e-15:
        return 1
    return int(np.ceil(np.arccosh(1.0 / eps) / np.arccosh(sigma)))


def rho_accel(lam2: float, theta: Theta) -> float:
    """Exact optimized spectral radius rho(Phi3[alpha*] - J) = sqrt(-alpha* theta1).

    (Section V-C.)  For theta = (-eps, 0, 1+eps) this reduces to the
    Chebyshev-type rate (1 - sqrt(1 - lam2^2)) / lam2, independent of eps.
    """
    a = alpha_star(lam2, theta)
    return float(np.sqrt(max(-a * theta.t1, 0.0)))


def rho_accel_bound(psi: float) -> float:
    """Theorem 2 upper bound: rho(W-J) <= 1 - Psi  =>  rho(Phi3[alpha*]-J) <= 1 - sqrt(Psi)."""
    return 1.0 - np.sqrt(psi)


def gain_bound(psi: float) -> float:
    """Theorem 3: G(W) = E{tau(W)/tau(Phi3[alpha*])} >= 1/sqrt(Psi(N))."""
    return 1.0 / np.sqrt(psi)


def w3_matrix(w: np.ndarray, alpha: float, theta: Theta) -> np.ndarray:
    """W_3[alpha] = (1 - alpha + alpha theta3) W + alpha theta2 I   (Eq. 5)."""
    n = w.shape[0]
    return (1.0 - alpha + alpha * theta.t3) * w + alpha * theta.t2 * np.eye(n)


def phi3_matrix(w: np.ndarray, alpha: float, theta: Theta) -> np.ndarray:
    """The 2N x 2N companion operator Phi_3[alpha] (Eq. 7).

    Phi_3[alpha] = [[W_3[alpha], alpha theta1 I], [I, 0]].
    """
    n = w.shape[0]
    top = np.concatenate([w3_matrix(w, alpha, theta), alpha * theta.t1 * np.eye(n)], axis=1)
    bot = np.concatenate([np.eye(n), np.zeros((n, n))], axis=1)
    return np.concatenate([top, bot], axis=0)


def _require_symmetric(w: np.ndarray, fn: str) -> None:
    w = np.asarray(w)
    if w.ndim != 2 or w.shape[0] != w.shape[1]:
        raise ValueError(f"{fn} needs a square (N, N) matrix, got shape {w.shape}")
    if not np.allclose(w, w.T, atol=1e-8):
        raise ValueError(
            f"{fn} requires a symmetric W (paper Eq. 2: W = W^T); "
            f"max asymmetry {np.abs(w - w.T).max():.3g}. Symmetrize the weight "
            f"matrix (e.g. metropolis_hastings) before the spectral analysis."
        )


def phi3_eigenvalues(w_eigs: np.ndarray, alpha: float, theta: Theta) -> np.ndarray:
    """Analytic eigenvalues of Phi_3[alpha] from the eigenvalues of W.

    Each eigenvalue lambda_i(W) spawns the two roots of the quadratic (Eq. 34)
        mu^2 - lambda_i(W_3[alpha]) mu - alpha theta1 = 0,
    with lambda_i(W_3[alpha]) = (1 - alpha + alpha theta3) lambda_i(W) + alpha theta2.
    Returns a complex array of length 2N.
    """
    w_eigs = np.asarray(w_eigs)
    if np.iscomplexobj(w_eigs) and np.abs(w_eigs.imag).max(initial=0.0) > 1e-9:
        raise ValueError(
            "phi3_eigenvalues got complex W eigenvalues — the quadratic "
            "eigenvalue map (Eq. 34) assumes a symmetric W with a real "
            "spectrum; non-symmetric weight matrices are outside Theorem 1."
        )
    lam_w3 = (1.0 - alpha + alpha * theta.t3) * w_eigs.real + alpha * theta.t2
    disc = lam_w3.astype(np.complex128) ** 2 + 4.0 * alpha * theta.t1
    root = np.sqrt(disc)
    return np.concatenate([0.5 * (lam_w3 + root), 0.5 * (lam_w3 - root)])


def spectral_radius_minus_j(w: np.ndarray, alpha: float, theta: Theta) -> float:
    """rho(Phi3[alpha] - J) computed analytically from the spectrum of W.

    Equals max |mu| over the 2N quadratic-eigenvalue roots with the single
    mu = 1 root (from lambda_1(W) = 1) excluded; the companion root -alpha
    theta1 of that branch *is* included (Section V-B, Eq. 38).
    """
    _require_symmetric(w, "spectral_radius_minus_j")
    vals = np.linalg.eigvalsh(w)
    lam_rest = np.sort(vals)[:-1]  # drop the top eigenvalue 1
    mus = phi3_eigenvalues(lam_rest, alpha, theta)
    cand = np.abs(mus)
    # the lambda_1 = 1 branch contributes mu = 1 (dropped with J) and mu = -alpha theta1
    cand = np.append(cand, abs(alpha * theta.t1))
    return float(cand.max())


# ---------------------------------------------------------------------------
# Node-local recursion (Eq. 4a-4c), vectorized over an (N, F) state block.
# ---------------------------------------------------------------------------

def accelerated_step(
    w: np.ndarray,
    x: np.ndarray,
    x_prev: np.ndarray,
    alpha: float,
    theta: Theta,
) -> tuple[np.ndarray, np.ndarray]:
    """One accelerated round: returns (x_next, x).

    x^W  = W x
    x^P  = theta3 x^W + theta2 x + theta1 x_prev
    x'   = alpha x^P + (1 - alpha) x^W
         = (1 - alpha + alpha theta3) x^W + alpha theta2 x + alpha theta1 x_prev
    """
    xw = w @ x
    a = 1.0 - alpha + alpha * theta.t3
    b = alpha * theta.t2
    c = alpha * theta.t1
    return a * xw + b * x + c * x_prev, x


def run_accelerated(
    w: np.ndarray,
    x0: np.ndarray,
    alpha: float,
    theta: Theta,
    num_iters: int,
    record: bool = False,
) -> np.ndarray | tuple[np.ndarray, np.ndarray]:
    """Run the two-tap recursion for ``num_iters`` rounds from x(-1) = x(0) = x0.

    x0 may be (N,) or (N, F). If ``record``, also returns the (T+1, ...) state
    trajectory (used by the MSE-vs-iteration benchmarks).
    """
    x = np.asarray(x0, dtype=np.float64)
    x_prev = x.copy()
    traj = [x.copy()] if record else None
    for _ in range(num_iters):
        x, x_prev = accelerated_step(w, x, x_prev, alpha, theta)
        if record:
            traj.append(x.copy())
    if record:
        return x, np.stack(traj)
    return x


def run_memoryless(
    w: np.ndarray, x0: np.ndarray, num_iters: int, record: bool = False
) -> np.ndarray | tuple[np.ndarray, np.ndarray]:
    """Standard distributed averaging x(t+1) = W x(t) (the paper's baseline)."""
    x = np.asarray(x0, dtype=np.float64)
    traj = [x.copy()] if record else None
    for _ in range(num_iters):
        x = w @ x
        if record:
            traj.append(x.copy())
    if record:
        return x, np.stack(traj)
    return x
