"""Pluggable consensus-algorithm registry — the seam every layer routes through.

The paper's two-tap recursion is one point in a family of memory-augmented
consensus algorithms (Yi, Chai & Zhang 2021 generalize the tap structure;
Olshevsky & Tsitsiklis 2010 lower-bound exactly this short-memory class).
Before this module each new update rule meant forking four layers — the
host simulator, the jitted sweep scan, the fused Pallas kernels, and the
shard_map SPMD path. A :class:`ConsensusAlgorithm` now declares, once:

* its **carry layout** — how many state taps the scan carries (memoryless 1,
  two-tap 2, polynomial filter 2: display state + Horner accumulator), plus
  optionally ``num_aux`` auxiliary slots appended after the taps: estimator
  state (probes, running spectral estimates, cached masks) that is carried
  through the scan but is NOT a network state — aux slots are exempt from
  the display/invariant contract and are never returned by ``return_taps``;
* **per-round coefficient streams** — ``round_body`` receives the per-cell
  parameter rows and the carry every tick and may *recompute* the ``prim``
  coefficients from its aux state inside the one jitted scan (the
  coefficients were always a per-call traced operand of the primitive; the
  contract now says so). Static-coefficient algorithms are the degenerate
  stream that ignores the carry;
* a **host float64 reference step** (``reference_run``) — the correctness
  oracle the cross-backend conformance suite checks every engine against;
* a **jnp round body** (``round_body``) usable inside the sweep engine's one
  jitted scan. The body is written against a *fused-round primitive*
  ``prim(x, xp, coef3)`` = ``a*(W_eff@x) + b*x + c*xp`` supplied by the
  engine, so the same body runs on the jax backend (einsum round) and the
  pallas backend (fused batched kernel, masked or not) without knowing which;
* optional **hooks**: ``pallas_round`` overrides the engine's default kernel
  primitive for algorithms whose tick is not a fused two-tap round, and
  ``register_dist_variant`` attaches an in-mesh shard_map implementation
  (``repro.dist.gossip`` registers gossip / accel_gossip / pairwise_gossip).

Seed algorithms:

* ``memoryless``      — x(t+1) = W_eff(t) x(t), one tap.
* ``accel``           — the paper's two-tap recursion; coefficients
  (a, b, c) = (1 - alpha + alpha*t3, alpha*t2, alpha*t1) come from the sweep
  grid's (theta design x alpha) axis (``uses_theta``).
* ``poly_filter[:k]`` — degree-k polynomial filtering [Kokiopoulou-Frossard,
  paper ref 14], migrated off the numpy-only island in ``core.baselines``:
  each super-iteration applies p(W) via Horner, ONE W-multiply per engine
  tick (k ticks per super-iteration), with the display state held constant
  inside a super-iteration — the tick-fairness accounting of
  ``baselines.run_poly_filter``.
* ``async_pairwise``  — Boyd-style randomized gossip: one edge (i, j) wakes
  per tick and the pair averages, x_i, x_j <- (x_i + x_j)/2. The edge
  schedule is sampled host-side (graph-keyed RNG, coupled with the dynamics
  axis draws) into the same compressed per-tick bit masks the time-varying
  sweep already scans, and the *pairwise averaging matrix falls out of the
  mass-preserving masked-W machinery*: with base matrix B (0.5 on every
  edge, row sums 1) and a one-hot edge mask M(t),

      B .* M(t) + diag((B .* (1 - M(t))) @ 1)

  is exactly the Boyd pairwise matrix — 0.5 on the woken pair, identity
  elsewhere. One engine, one kernel, zero new scan paths.

* ``accel_adapt[:eta]`` — the ADAPTIVE two-tap recursion: the carry holds,
  besides the two taps, a deflated power-iteration probe block and a
  per-cell lambda_2 estimate (``core.doi``'s Algorithm 1 recursion run
  *inside* the scan, one extra ``prim`` application per tick), and the
  round body re-solves Theorem 1's alpha* from that estimate every tick via
  the traceable twin ``accel.alpha_star_jnp``. As dynamics kill links the
  estimate tracks the effective operator and the coefficients follow —
  recovering most of the gain a nominal alpha* loses in
  ``fig_robustness``'s mismatch curves (``benchmarks/fig_adaptive.py``).
* ``accel_m:M`` — the analytic M-tap memory frontier (Yi-Chai-Zhang-style
  designs, ``accel.m_tap_weights``): older taps are pre-combined into the
  predictor operand of the SAME fused ``prim(x, p, coef3)`` round, so the
  dense, sparse/ELLPACK and masked Pallas paths inherit M > 2 untouched.
  M = 2 reduces exactly to Theorem 1; M >= 3 admits the second spectral
  statistic lambda_N (the true interval) — and saturates there, which is
  the honest frontier statement (see ``m_tap_weights``).

* ``push_sum`` / ``ratio_consensus[:c]`` — the directed/lossy family: both
  carry a two-state (value, mass-counter) tuple against a COLUMN-stochastic
  base matrix (``weights.push_sum_weights`` / ``ratio_consensus_weights``)
  and display the ratio s/w, which converges to the true average on strongly
  connected digraphs where the row-stochastic family converges to a
  Perron-weighted mixture. Their ``invariant`` is total-mass (not mean)
  conservation, and their ``mass_renorm = "sender"`` keeps dropped edge mass
  with the SENDER's diagonal under failure masks — column sums survive every
  mask, so the ratio still finds the average under packet loss
  (Kempe-Dobra-Gehrke push-sum; the sigma/rho mass counters of
  ratio-consensus).

Tick-fairness convention (also in ROADMAP): one engine round = one tick of
the algorithm's own clock — a W-multiply for the synchronous family, a
single pairwise exchange for ``async_pairwise``. Cross-algorithm comparisons
normalize by communication: one W-multiply activates every edge once, so
E pairwise exchanges are charged as one synchronous tick
(``benchmarks/fig_async.py`` reports both raw exchanges and ticks).

The full authoring guide — carry layout, the ``display`` transform, the
invariant-class declaration (``invariant`` / ``mass_renorm`` /
``symmetric_base``), the layout-polymorphic ``prim(x, xp, coef)`` contract
(dense einsum, fused Pallas kernel, AND the sparse segment-sum path all
satisfy it), host-reference requirements, and the conformance suite a
registration inherits — is in ``docs/REGISTERING_ALGORITHMS.md``.
"""
from __future__ import annotations

import math

import numpy as np

from . import accel, baselines, doi, dynamics, weights

__all__ = [
    "ConsensusAlgorithm",
    "Memoryless",
    "TwoTapAccel",
    "AdaptiveTwoTap",
    "MTapAccel",
    "PolyFilterAlgorithm",
    "AsyncPairwise",
    "PushSum",
    "RatioConsensus",
    "register_algorithm",
    "registered_algorithms",
    "get_algorithm",
    "register_dist_variant",
    "dist_variant",
    "pairwise_base_matrix",
]


class ConsensusAlgorithm:
    """One registered consensus update rule (see module docstring).

    Subclasses set the class attributes and implement ``round_body`` (jnp)
    plus, when the tick is not a degenerate two-tap round, ``reference_run``
    (host float64/float32 oracle).
    """

    name: str = "?"            # base registry name
    spec: str = "?"            # full spec string, e.g. "poly_filter:4"
    num_taps: int = 1          # scan-carry state slots (see ``display``)
    # Auxiliary carry slots AFTER the taps (estimator probes, running
    # spectral estimates, cached node masks): threaded through the scan but
    # exempt from the display/invariant contract and excluded from
    # ``return_taps`` — they are algorithm-internal state, not network state.
    num_aux: int = 0
    num_coefs: int = 0         # width of this algorithm's per-cell param row
    # Trajectory-tolerance multiplier for the cross-backend conformance
    # comparisons ONLY (invariant checks stay exact). Feedback algorithms
    # that recompute coefficients from carried estimates amplify f32
    # backend noise through the coefficient loop (d alpha / d lambda ~ 20
    # near lambda ~ 0.99, compounding over the horizon); a plain tolerance
    # sized for static-coefficient trajectories would flake on them.
    ref_tol_factor: float = 1.0
    uses_theta: bool = False   # crossed with the (theta design x alpha) axis?
    needs_schedule: bool = False  # requires per-tick edge bits even when static
    pallas_round = None        # optional kernel-primitive override hook
    # Which conservation law the conformance suite holds this algorithm to:
    # "mean" (doubly-stochastic family: the display state's node mean is the
    # initial mean, round by round) or "mass" (push-sum family: the TOTAL of
    # every carry tap is conserved; the displayed ratio converges to the
    # average but its node mean is not itself invariant).
    invariant: str = "mean"
    # Where a failure-masked edge's weight returns under the engine's
    # mass-preserving masking rule: "receiver" adds W_ij to receiver i's
    # diagonal (row sums survive — right for the row/doubly-stochastic
    # family), "sender" adds it to sender j's diagonal (column sums survive —
    # required by the mass-conserving family above).
    mass_renorm: str = "receiver"
    # False when base_matrix is asymmetric (column-stochastic family): the
    # sparse layout then stores both per-direction edge weights.
    symmetric_base: bool = True

    # -- grid-construction hooks (host, numpy) ------------------------------
    def base_matrix(self, w: np.ndarray) -> np.ndarray:
        """The (N, N) matrix stored in the ensemble's ws row for this cell."""
        return w

    def base_edge_weights(
        self, edges: np.ndarray, edge_w: np.ndarray, diag_w: np.ndarray, n: int
    ) -> tuple[np.ndarray, np.ndarray]:
        """Edge-space counterpart of ``base_matrix`` for the sparse layout.

        ``edges`` is the cell's canonical (E, 2) edge list, ``edge_w`` /
        ``diag_w`` its Metropolis-Hastings weights; return the pair the
        ensemble actually stores. Only consulted for sparse cells too large
        to densify — the small-N sparse path extracts edge weights from
        ``base_matrix`` so both layouts stay bit-identical.
        """
        return edge_w, diag_w

    def cell_params(self, w: np.ndarray, eigvals: np.ndarray) -> np.ndarray:
        """(num_coefs,) static per-cell parameters (non-theta algorithms).

        In the sparse layout ``w`` is None for cells too large to densify and
        ``eigvals`` is the surrogate spectrum (power-iteration extremes +
        linspace fill) — implementations should prefer ``eigvals``.
        """
        return np.zeros(0)

    def design_params(self, theta, alpha: float) -> np.ndarray:
        """Map one (theta, alpha) design cell to this algorithm's param row.

        Only consulted when ``uses_theta`` — the grid crosses such algorithms
        with the design axis and asks the algorithm (not the grid builder)
        how a design becomes coefficients.
        """
        raise NotImplementedError(
            f"{self.spec} declares uses_theta but no design_params mapping")

    def tick_rho(self, lam2: float, rho_mem: float, w: np.ndarray,
                 eigvals: np.ndarray | None = None, *,
                 edges: np.ndarray | None = None,
                 num_nodes: int | None = None) -> float:
        """Per-tick contraction estimate for iteration caps (ConfigMeta.rho_accel).

        Sparse cells too large to densify call this with ``w=None`` and the
        cell's edge list in the keyword args; overrides that need W itself
        should handle that case (the grid falls back to the 4-argument call
        for overrides without the keywords).
        """
        return rho_mem

    def schedule_bits(self, dyn_bits: np.ndarray, idx: np.ndarray, n: int,
                      rng: np.random.Generator) -> np.ndarray:
        """(T, E) per-tick edge-activity bits; default = the dynamics draw."""
        return dyn_bits

    # -- engine hooks (jnp, trace time) -------------------------------------
    def init_carry(self, x0, params=None, mask=None):
        """Initial carry tuple: ``num_taps`` tap slots + ``num_aux`` aux slots.

        ``params`` is the partition's (Gp, C) traced coefficient rows and
        ``mask`` its (Gp, N, 1) valid-node indicator — aux-carrying
        algorithms seed estimator state from them (e.g. the nominal
        lambda_2 in the param row, the mask for padded-node-exact
        deflation). Legacy single-argument overrides keep working: the
        engine inspects the signature and falls back to ``init_carry(x0)``.
        """
        return (x0,) * self.num_taps

    def display(self, carry):
        """User-visible estimate from a carry tuple (jnp, trace time).

        The MSE reduction and ``SweepResult.x_final`` read THIS, every tick.
        Default: carry slot 0 — the contract every pre-existing registration
        was written against. Ratio-state algorithms (push-sum family)
        override it to return the value/mass quotient; overrides must map
        all-zero carry rows (padded nodes) to exactly 0.0.
        """
        return carry[0]

    def round_body(self, prim, params, carry, t):
        """One tick on this algorithm's grid partition.

        ``prim(x, xp, coef3)`` computes ``a*(W_eff@x) + b*x + c*xp`` with
        coef3 a traced (Gp, 3) row batch and W_eff this tick's (masked)
        partition weights; ``params`` is the (Gp, C) static param rows;
        ``t`` the traced tick index. Returns the new carry tuple; the
        engine passes it through ``display`` (default: carry[0]) for the
        MSE reduction.
        """
        raise NotImplementedError

    # -- host reference (the conformance oracle) ----------------------------
    def ref_coef(self, params: np.ndarray) -> tuple[float, float, float]:
        """(a, b, c) for algorithms expressible as one fused round per tick."""
        raise NotImplementedError

    def reference_run(self, w, x0, params, num_iters, bits=None, idx=None,
                      dtype=np.float64):
        """Host per-tick masked-W reference; mirrors the engine tick for tick.

        ``w`` is the cell's *base* matrix (``base_matrix``), ``bits``/``idx``
        the per-tick edge schedule (None = all edges up every tick).
        Returns (x_final (N, F), mse (T+1, F)) in ``dtype``.
        """
        bits, idx = _full_bits(w, num_iters, bits, idx)
        return dynamics.simulate_dynamic_reference(
            w, x0, self.ref_coef(params), bits, idx, dtype=dtype)

    def __repr__(self):
        return f"<ConsensusAlgorithm {self.spec}>"


def _full_bits(w, num_iters, bits, idx):
    if bits is None:
        idx = dynamics.edge_index(w)
        bits = np.ones((num_iters, len(idx)), dtype=np.uint8)
    return np.asarray(bits), np.asarray(idx)


def _coef_rows(g, a, b, c):
    import jax.numpy as jnp

    row = jnp.asarray([a, b, c], jnp.float32)
    return jnp.broadcast_to(row, (g, 3))


# ---------------------------------------------------------------------------
# Seed algorithms.
# ---------------------------------------------------------------------------

class Memoryless(ConsensusAlgorithm):
    """x(t+1) = W_eff(t) x(t) — the paper's baseline as a 1-tap registration."""

    name = spec = "memoryless"
    num_taps = 1

    def round_body(self, prim, params, carry, t):
        (x,) = carry
        return (prim(x, x, _coef_rows(x.shape[0], 1.0, 0.0, 0.0)),)

    def ref_coef(self, params):
        return (1.0, 0.0, 0.0)


class TwoTapAccel(ConsensusAlgorithm):
    """The paper's two-tap recursion; (a, b, c) rows come from the design axis."""

    name = spec = "accel"
    num_taps = 2
    num_coefs = 3
    uses_theta = True

    def design_params(self, theta, alpha):
        """(a, b, c) = (1 - alpha + alpha*t3, alpha*t2, alpha*t1) (Eq. 4a-4c);
        the memoryless design (theta None) is the degenerate (1, 0, 0) row."""
        if theta is None:
            return np.asarray([1.0, 0.0, 0.0])
        return np.asarray([1.0 - alpha + alpha * theta.t3,
                           alpha * theta.t2, alpha * theta.t1])

    def round_body(self, prim, params, carry, t):
        x, xp = carry
        return (prim(x, xp, params[:, :3]), x)

    def ref_coef(self, params):
        a, b, c = np.asarray(params, np.float64)[:3]
        return (float(a), float(b), float(c))


def _probe_block(n: int, f: int) -> np.ndarray:
    """Deterministic power-iteration probe columns, (N, F) float32.

    Knuth multiplicative hash of the (node, column) index mapped to
    [-0.5, 0.5): pure uint32 arithmetic plus one f32 division, so the numpy
    host oracle and the traced engine init produce bit-identical probes (no
    transcendental whose libm and XLA implementations could differ in the
    last ulp — the adaptive coefficient loop would amplify even that).
    """
    idx = (np.arange(n, dtype=np.uint32)[:, None] * np.uint32(f)
           + np.arange(f, dtype=np.uint32)[None, :])
    h = idx * np.uint32(2654435761)
    return h.astype(np.float32) / np.float32(2.0 ** 32) - np.float32(0.5)


def _alpha_star_graceful(lam: float, t1: float, t2: float, t3: float,
                         cutoff: float) -> float:
    """Host mirror of ``accel.alpha_star_jnp``'s in-scan semantics.

    Same closed form as ``accel.alpha_star`` but with the traced twin's
    graceful guards (discriminant clamps to 0 instead of raising, ``den``
    cutoff passed in to match the engine dtype): the conformance oracle must
    reproduce what the scan DOES, not what the theory layer would reject.
    """
    edge = t2 + (t3 - 1.0) * lam
    den = edge * edge
    if den < cutoff:
        return 0.0
    rad = max(t1 * t1 + t1 * lam * edge, 0.0)
    num = -((t3 - 1.0) * lam * lam + t2 * lam + 2.0 * t1) - 2.0 * math.sqrt(rad)
    return num / den


class AdaptiveTwoTap(ConsensusAlgorithm):
    """Two-tap recursion with in-scan lambda_2 re-estimation (``accel_adapt``).

    Carry: ``(x, x_prev, v, lam_hat, mask)`` — two taps plus three aux
    slots. Every tick the round body

    1. re-solves Theorem 1's alpha* from the carried estimate via the
       traceable ``accel.alpha_star_jnp`` and applies the resulting
       (a, b, c) coefficient row through the SAME fused primitive as
       ``accel`` — a per-round coefficient stream, one compilation;
    2. advances ``core.doi``'s Algorithm 1 on the probe block ``v`` with one
       extra ``prim`` application (coefficients (1, 0, 0) make the primitive
       a pure W_eff matvec — so the probe iterates the *masked* operator of
       this very tick, which is the whole point), deflates the consensus
       mode by masked mean subtraction, folds the per-cell Gelfand quotient
       into the carried EMA ``lam_hat`` with weight eta, and sup-normalizes.

    The re-solve uses ``max(lam2_nom, lam_hat)`` — the estimate is FLOORED
    at the nominal lambda_2 from the param row. This one-sidedness is the
    load-bearing design decision: alpha*'s failure modes are asymmetric
    (underestimating lambda_2 drops into the slow real-root regime, a
    cliff; overestimating degrades smoothly), the power iteration's
    transient approaches the true quotient FROM BELOW (so an unfloored EMA
    first detunes the recursion before helping it), and link failures only
    move the effective operator's lambda_2 UP from nominal
    (E[W_eff] = (1-p) W + p I). On a static graph the floor makes
    ``accel_adapt`` match ``accel`` exactly in rate; under failures the EMA
    rises above the floor and tracks the effective operator. Re-seeding the
    floor after a topology *improvement* is the deferred direction
    (ROADMAP).

    The F trial columns double as independent probe columns (the quotient
    maxes over all of them). Param row: (lam2_nom, t1, t2, t3, eta); the
    memoryless design degenerates to (1, 0, 0) rows exactly (theta (0,0,1)
    puts alpha* at 0) with a frozen estimator. Estimation cost is one extra
    fused round per tick — in a deployment the probe column piggybacks on
    the same neighbour exchange, so the tick count is the honest cost.
    ``benchmarks/fig_adaptive.py`` measures the recovered gain against a
    matched-alpha* oracle under iid and bursty failure schedules.
    """

    name = spec = "accel_adapt"
    num_taps = 2
    num_aux = 3
    num_coefs = 5
    uses_theta = True
    # Trajectory agreement across backends is Lyapunov-limited for this
    # algorithm: under heavy masking the estimate rises into the region
    # where d rho / d lambda ~ 1/sqrt(1 - lambda) blows up, so backend
    # rounding differences in lam_hat (pallas kernel accumulation order vs
    # numpy) amplify exponentially through the coefficient loop. The
    # conformance suite therefore only bounds gross divergence here; the
    # exact checks that survive chaos (mean conservation, aux-exempt taps)
    # stay tight, and tests/test_adaptive.py pins a TIGHT trajectory match
    # in the regimes where one is meaningful (static + mild bernoulli,
    # where the nominal floor pins the coefficient stream).
    ref_tol_factor = 5e4
    # estimates clip here: alpha* needs lambda_2 < 1, and a transient
    # quotient above 1 (possible under heavy masking) must not stick
    _LAM_CAP = 0.999999

    def __init__(self, eta: float = 0.2):
        if not 0.0 <= eta <= 1.0:
            raise ValueError(f"accel_adapt EMA weight must be in [0, 1], got {eta}")
        self.eta = float(eta)
        self.spec = f"accel_adapt:{self.eta}" if eta != 0.2 else "accel_adapt"

    def design_params(self, theta, alpha, lam2=0.0):
        """(lam2_nom, t1, t2, t3, eta); ``alpha`` is ignored — the whole point
        is that the round body re-solves it from the carried estimate, seeded
        at the nominal lam2 (so tick 0 starts from Theorem 1's nominal
        alpha*). The memoryless design is theta (0, 0, 1) + frozen EMA."""
        if theta is None:
            return np.asarray([lam2, 0.0, 0.0, 1.0, 0.0])
        return np.asarray([lam2, theta.t1, theta.t2, theta.t3, self.eta])

    def init_carry(self, x0, params=None, mask=None):
        import jax.numpy as jnp

        g, n, f = x0.shape
        m = jnp.ones((g, n, 1), x0.dtype) if mask is None else mask
        v = jnp.broadcast_to(jnp.asarray(_probe_block(n, f))[None], x0.shape) * m
        denom = jnp.maximum(m.sum(axis=1, keepdims=True), 1.0)
        v = (v - (v * m).sum(axis=1, keepdims=True) / denom) * m
        v = doi.sup_normalize(v, axis=(1, 2), xp=jnp)
        lam = params[:, 0] if params is not None else jnp.zeros((g,), x0.dtype)
        return (x0, x0, v, lam, m)

    def round_body(self, prim, params, carry, t):
        import jax.numpy as jnp

        x, xp, v, lam, m = carry
        t1, t2, t3, eta = (params[:, 1], params[:, 2], params[:, 3],
                           params[:, 4])
        lam_eff = jnp.clip(jnp.maximum(params[:, 0], lam), 0.0, self._LAM_CAP)
        al = accel.alpha_star_jnp(lam_eff, (t1, t2, t3))
        coef = jnp.stack([1.0 - al + al * t3, al * t2, al * t1], axis=1)
        x_new = prim(x, xp, coef)
        # estimator tick: pure W_eff matvec of the probe block, then masked
        # deflation (padded rows stay exactly 0: their W rows and mask are 0)
        one = jnp.stack([jnp.ones_like(al), jnp.zeros_like(al),
                         jnp.zeros_like(al)], axis=1)
        wv = prim(v, v, one)
        denom = jnp.maximum(m.sum(axis=1, keepdims=True), 1.0)
        wv = (wv - (wv * m).sum(axis=1, keepdims=True) / denom) * m
        q = jnp.clip(doi.gelfand_quotient(wv, v, axis=(1, 2), xp=jnp),
                     0.0, self._LAM_CAP)
        lam_new = jnp.where(q > 0.0, (1.0 - eta) * lam + eta * q, lam)
        v_new = doi.sup_normalize(wv, axis=(1, 2), xp=jnp)
        return (x_new, x, v_new, lam_new, m)

    def reference_run(self, w, x0, params, num_iters, bits=None, idx=None,
                      dtype=np.float64):
        """Tick-for-tick host mirror: same probe, same EMA, same re-solve."""
        bits, idx = _full_bits(w, num_iters, bits, idx)
        p = np.asarray(params, np.float64)
        lam = float(p[0])
        t1, t2, t3, eta = (float(p[1]), float(p[2]), float(p[3]), float(p[4]))
        cutoff = float(np.finfo(np.float32).tiny) * 4.0
        x = np.asarray(x0, dtype=dtype)
        squeeze = x.ndim == 1
        if squeeze:
            x = x[:, None]
        xprev = x.copy()
        v = _probe_block(*x.shape).astype(dtype)
        v = v - v.mean(axis=0, keepdims=True)
        v = doi.sup_normalize(v)
        xbar = x.mean(axis=0, keepdims=True)
        mse = [((x - xbar) ** 2).mean(axis=0)]
        wd = np.asarray(w, dtype=dtype)
        lam_nom = lam
        for t in range(bits.shape[0]):
            weff = dynamics.masked_w(wd, bits[t], idx)
            al = _alpha_star_graceful(min(max(lam_nom, lam), self._LAM_CAP),
                                      t1, t2, t3, cutoff)
            a, b, c = 1.0 - al + al * t3, al * t2, al * t1
            x, xprev = ((dtype(a) * (weff @ x) + dtype(b) * x
                         + dtype(c) * xprev).astype(dtype), x)
            wv = (weff @ v).astype(dtype)
            wv = wv - wv.mean(axis=0, keepdims=True)
            q = min(float(doi.gelfand_quotient(wv, v)), self._LAM_CAP)
            if q > 0.0:
                lam = (1.0 - eta) * lam + eta * q
            v = doi.sup_normalize(wv)
            mse.append(((x - xbar) ** 2).mean(axis=0))
        if squeeze:
            x = x[:, 0]
        return x, np.stack(mse)


class MTapAccel(ConsensusAlgorithm):
    """Analytic M-tap memory (``accel_m:M``) through the two-operand primitive.

    Carry: ``(x, x_{t-1}, ..., x_{t-M+1})`` — M taps, no aux. The update

        x(t+1) = a W_eff x(t) + b x(t) + sum_m c_m x(t-m)

    rides the existing fused round by pre-combining the older taps into the
    predictor operand in jnp: ``p = sum_m c_m x(t-m)`` and coefficient row
    (a, b, 1) — so the dense einsum, the sparse segment-sum and both Pallas
    kernels inherit every M untouched (the combine is O(G N F M) adds, dwarfed
    by the matvec). Weights come from ``accel.m_tap_weights``: M = 2 is
    exactly Theorem 1 + theta_asymptotic; M >= 3 admits lambda_N (the true
    spectral interval) and saturates there — older-tap weights are
    analytically zero, so the depth is carried but not paid for in rate.
    """

    name = "accel_m"
    # The true-interval design runs larger coefficients (a ~ 2.5 on chains)
    # through a more non-normal recursion, so f32 backend-order noise is
    # amplified ~7x relative to the two-tap baseline; 20x covers it with
    # headroom while staying a real bound.
    ref_tol_factor = 20.0

    def __init__(self, num_taps: int = 3):
        if num_taps < 2:
            raise ValueError(f"accel_m needs at least 2 taps, got {num_taps}")
        self.num_taps = int(num_taps)
        self.num_coefs = self.num_taps + 1
        self.spec = f"accel_m:{self.num_taps}"

    def _weights(self, eigvals):
        vals = np.sort(np.asarray(eigvals, np.float64))
        return accel.m_tap_weights(self.num_taps, float(vals[-2]),
                                   float(vals[0]))

    def cell_params(self, w, eigvals):
        return self._weights(eigvals)[0]

    def tick_rho(self, lam2, rho_mem, w, eigvals=None, *, edges=None,
                 num_nodes=None):
        if eigvals is None:
            if w is None:
                return rho_mem
            eigvals = np.linalg.eigvalsh(np.asarray(w, np.float64))
        return self._weights(eigvals)[1]

    def round_body(self, prim, params, carry, t):
        import jax.numpy as jnp

        x, *hist = carry
        pred = sum(params[:, 2 + m, None, None] * h
                   for m, h in enumerate(hist))
        coef = jnp.stack([params[:, 0], params[:, 1],
                          jnp.ones_like(params[:, 0])], axis=1)
        return (prim(x, pred, coef), x, *hist[:-1])

    def reference_run(self, w, x0, params, num_iters, bits=None, idx=None,
                      dtype=np.float64):
        bits, idx = _full_bits(w, num_iters, bits, idx)
        p = np.asarray(params, np.float64)
        a, b = dtype(p[0]), dtype(p[1])
        cs = [dtype(c) for c in p[2:self.num_taps + 1]]
        x = np.asarray(x0, dtype=dtype)
        squeeze = x.ndim == 1
        if squeeze:
            x = x[:, None]
        hist = [x.copy() for _ in range(self.num_taps - 1)]
        xbar = x.mean(axis=0, keepdims=True)
        mse = [((x - xbar) ** 2).mean(axis=0)]
        wd = np.asarray(w, dtype=dtype)
        for t in range(bits.shape[0]):
            weff = dynamics.masked_w(wd, bits[t], idx)
            pred = sum(c * h for c, h in zip(cs, hist))
            x_new = (a * (weff @ x) + b * x + pred).astype(dtype)
            hist = [x] + hist[:-1]
            x = x_new
            mse.append(((x - xbar) ** 2).mean(axis=0))
        if squeeze:
            x = x[:, 0]
        return x, np.stack(mse)


class PolyFilterAlgorithm(ConsensusAlgorithm):
    """Degree-k polynomial filtering (paper ref 14) as per-tick Horner steps.

    One engine tick = one W-multiply of the Horner evaluation
    ``p(W) x = a_k W^k x + ... + a_0 x``; every k ticks the display state
    (carry slot 0) jumps to the finished super-iteration — inside a
    super-iteration it is held constant, matching the tick accounting of
    ``baselines.run_poly_filter``. Carry: (x_display, horner_accumulator).
    """

    name = "poly_filter"
    num_taps = 2
    uses_theta = False

    def __init__(self, degree: int = 3, ridge: float = 0.0):
        if degree < 1:
            raise ValueError(f"poly_filter degree must be >= 1, got {degree}")
        self.degree = int(degree)
        self.ridge = float(ridge)
        self.num_coefs = self.degree + 1
        self.spec = f"poly_filter:{self.degree}"

    def cell_params(self, w, eigvals):
        # the grid hands us the spectrum it already computed for this graph —
        # no extra O(N^3) eigensolve per cell
        filt = baselines.design_poly_filter_from_spectrum(
            eigvals, self.degree, ridge=self.ridge)
        return np.asarray(filt.coeffs, np.float64)

    def tick_rho(self, lam2, rho_mem, w, eigvals=None, *, edges=None,
                 num_nodes=None):
        filt = (baselines.design_poly_filter_from_spectrum(
                    eigvals, self.degree, ridge=self.ridge)
                if eigvals is not None else
                baselines.design_poly_filter(w, self.degree, ridge=self.ridge))
        return filt.rho_per_tick()

    def round_body(self, prim, params, carry, t):
        import jax
        import jax.numpy as jnp

        x_disp, acc = carry
        k = self.degree
        g = params.shape[0]
        p = t % k
        # phase 0 seeds the Horner accumulator with a_k * x_display; the tick
        # then contracts once and folds in a_{k-1-p} * x_display via the
        # primitive's xp tap: y = W_eff @ acc_in + a_j * x_display.
        acc_in = jnp.where(p == 0, params[:, k:k + 1, None] * x_disp, acc)
        aj = jax.lax.dynamic_slice_in_dim(params, k - 1 - p, 1, axis=1)
        coef = jnp.concatenate(
            [jnp.ones((g, 1), jnp.float32), jnp.zeros((g, 1), jnp.float32),
             aj.astype(jnp.float32)], axis=1)
        y = prim(acc_in, x_disp, coef)
        return (jnp.where(p == k - 1, y, x_disp), y)

    def reference_run(self, w, x0, params, num_iters, bits=None, idx=None,
                      dtype=np.float64):
        bits, idx = _full_bits(w, num_iters, bits, idx)
        a = np.asarray(params, np.float64)[: self.degree + 1]
        k = self.degree
        x = np.asarray(x0, dtype=dtype)
        squeeze = x.ndim == 1
        if squeeze:
            x = x[:, None]
        acc = x.copy()
        xbar = x.mean(axis=0, keepdims=True)
        mse = [((x - xbar) ** 2).mean(axis=0)]
        wd = np.asarray(w, dtype=dtype)
        for t in range(bits.shape[0]):
            weff = dynamics.masked_w(wd, bits[t], idx)
            p = t % k
            acc_in = dtype(a[k]) * x if p == 0 else acc
            acc = (weff @ acc_in + dtype(a[k - 1 - p]) * x).astype(dtype)
            if p == k - 1:
                x = acc.copy()
            mse.append(((x - xbar) ** 2).mean(axis=0))
        if squeeze:
            x = x[:, 0]
        return x, np.stack(mse)


def pairwise_base_matrix(w: np.ndarray) -> np.ndarray:
    """B with 0.5 on every edge of W's support and row sums 1 (diag 1 - deg/2).

    Masking B down to a one-hot edge set under the engine's mass-preserving
    rule reproduces the Boyd pairwise averaging matrix exactly: the woken
    pair's rows become (0.5, 0.5), every other row collapses to e_i.
    """
    w = np.asarray(w)
    support = (np.abs(w) > 0).astype(np.float64)
    np.fill_diagonal(support, 0.0)
    b = 0.5 * support
    np.fill_diagonal(b, 1.0 - b.sum(axis=1))
    return b


class AsyncPairwise(ConsensusAlgorithm):
    """Boyd-style asynchronous randomized pairwise gossip, one edge per tick.

    The host-side schedule samples one edge uniformly per tick (graph-keyed
    RNG — coupled across designs and failure probabilities like every other
    schedule) and ANDs it with the cell's dynamics bits: a woken edge that is
    down this tick simply exchanges nothing (identity round, mean preserved).
    """

    name = spec = "async_pairwise"
    num_taps = 1
    needs_schedule = True

    def base_matrix(self, w):
        return pairwise_base_matrix(w)

    def base_edge_weights(self, edges, edge_w, diag_w, n):
        """0.5 on every edge, diag 1 - deg/2 — pairwise_base_matrix in edge space."""
        deg = np.bincount(np.asarray(edges).ravel(), minlength=n)
        return np.full(len(edges), 0.5), 1.0 - 0.5 * deg.astype(np.float64)

    def tick_rho(self, lam2, rho_mem, w, eigvals=None, *, edges=None,
                 num_nodes=None):
        """Contraction of the expected per-exchange operator I - L/(2E)."""
        if w is None:
            # sparse large-N cell: power-iterate I - L/(2E) in edge space
            if edges is None or num_nodes is None or len(edges) == 0:
                return rho_mem
            e = float(len(edges))
            deg = np.bincount(np.asarray(edges).ravel(), minlength=num_nodes)
            ew = np.full(len(edges), 1.0 / (2.0 * e))
            dw = 1.0 - deg.astype(np.float64) / (2.0 * e)
            l2, ln = weights.lambda_extremes_sparse(np.asarray(edges), ew, dw)
            return float(max(abs(ln), abs(l2)))
        support = (np.abs(np.asarray(w)) > 0).astype(np.float64)
        np.fill_diagonal(support, 0.0)
        e = support.sum() / 2.0
        if e == 0:
            return 0.0
        lap = np.diag(support.sum(axis=1)) - support
        wbar = np.eye(len(support)) - lap / (2.0 * e)
        vals = np.sort(np.linalg.eigvalsh(wbar))
        return float(max(abs(vals[0]), abs(vals[-2])))

    def schedule_bits(self, dyn_bits, idx, n, rng):
        e = len(idx)
        if e == 0:
            return dyn_bits
        t = dyn_bits.shape[0]
        choice = rng.integers(0, e, size=t)
        onehot = np.zeros((t, e), dtype=np.uint8)
        onehot[np.arange(t), choice] = 1
        return onehot & dyn_bits

    def round_body(self, prim, params, carry, t):
        (x,) = carry
        return (prim(x, x, _coef_rows(x.shape[0], 1.0, 0.0, 0.0)),)

    def ref_coef(self, params):
        return (1.0, 0.0, 0.0)

    def reference_run(self, w, x0, params, num_iters, bits=None, idx=None,
                      dtype=np.float64):
        if bits is None:
            raise ValueError(
                "async_pairwise needs a per-tick edge schedule (bits/idx); "
                "build one via sweep.build_round_masks or schedule_bits()")
        return super().reference_run(w, x0, params, num_iters, bits, idx, dtype)


class _RatioStateAlgorithm(ConsensusAlgorithm):
    """Shared machinery of the column-stochastic (value, mass) family.

    Carry: ``(s, w)`` — the value state seeded with x0 and the mass counter
    seeded with 1 at every node. Each tick multiplies BOTH by the same
    effective matrix (two fused rounds per tick, one shared mask), and the
    display is the quotient s/w. Because the base matrix is column
    stochastic and the mask rule is sender-renormalizing, the totals of s
    and of w survive every failure pattern; the quotient converges to
    sum(x0)/N on any strongly connected support. Subclasses supply the dense
    and edge-space weight builders.
    """

    num_taps = 2
    invariant = "mass"
    mass_renorm = "sender"
    symmetric_base = False

    # tiny mass cutoff for the displayed quotient: below it the node has
    # received nothing yet (or is padding) and displays 0 instead of 0/0
    _MASS_FLOOR = 1e-12

    def init_carry(self, x0, params=None, mask=None):
        import jax.numpy as jnp

        return (x0, jnp.ones_like(x0))

    def display(self, carry):
        import jax.numpy as jnp

        s, w = carry
        safe = jnp.abs(w) > self._MASS_FLOOR
        return jnp.where(safe, s, 0.0) / jnp.where(safe, w, 1.0)

    def round_body(self, prim, params, carry, t):
        s, w = carry
        coef = _coef_rows(s.shape[0], 1.0, 0.0, 0.0)
        return (prim(s, s, coef), prim(w, w, coef))

    def reference_run(self, w, x0, params, num_iters, bits=None, idx=None,
                      dtype=np.float64):
        """Two-state host oracle: per-tick sender-renormalized masked P.

        Mirrors the engine tick for tick — P_eff(t) multiplies both the
        value and the mass state, and the MSE is measured on the displayed
        quotient against the true initial average.
        """
        bits, idx = _full_bits(w, num_iters, bits, idx)
        s = np.asarray(x0, dtype=dtype)
        squeeze = s.ndim == 1
        if squeeze:
            s = s[:, None]
        m = np.ones_like(s)
        xbar = s.mean(axis=0, keepdims=True)

        def disp(sv, mv):
            safe = np.abs(mv) > self._MASS_FLOOR
            return np.where(safe, sv, 0.0) / np.where(safe, mv, 1.0)

        mse = [((disp(s, m) - xbar) ** 2).mean(axis=0)]
        wd = np.asarray(w, dtype=dtype)
        for t in range(bits.shape[0]):
            weff = dynamics.masked_w(wd, bits[t], idx, renorm="sender")
            s = (weff @ s).astype(dtype)
            m = (weff @ m).astype(dtype)
            mse.append(((disp(s, m) - xbar) ** 2).mean(axis=0))
        x = disp(s, m)
        if squeeze:
            x = x[:, 0]
        return x, np.stack(mse)


class PushSum(_RatioStateAlgorithm):
    """Kempe-Dobra-Gehrke push-sum: uniform column-stochastic push weights.

    Node j pushes share 1/(1 + dout_j) of its (value, mass) pair to each
    out-neighbour and itself; the displayed quotient converges to the true
    average on strongly connected digraphs where ``memoryless`` lands on the
    Perron-weighted mixture instead.
    """

    name = spec = "push_sum"

    def base_matrix(self, w):
        return weights.push_sum_weights(w)

    def base_edge_weights(self, edges, edge_w, diag_w, n):
        return weights.push_sum_weights_edges(edges, n)


class RatioConsensus(_RatioStateAlgorithm):
    """Loss-robust ratio consensus (sigma/rho mass counters) with self-mass c.

    ``ratio_consensus[:c]``: node j keeps fraction c of its mass per tick
    and splits 1 - c uniformly over out-neighbours. Under the sender-renorm
    mask rule an un-delivered share simply stays in the sender's running
    totals — the matrix form of the sigma/rho counter scheme, where receivers
    difference cumulative counters so lost packets delay but never destroy
    mass. The quotient therefore converges to the true average under i.i.d.
    AND correlated packet loss.
    """

    name = "ratio_consensus"

    def __init__(self, c: float = 0.5):
        if not 0.0 < c < 1.0:
            raise ValueError(
                f"ratio_consensus self-mass must be in (0, 1), got {c}")
        self.c = float(c)
        self.spec = f"ratio_consensus:{self.c}"

    def base_matrix(self, w):
        return weights.ratio_consensus_weights(w, self.c)

    def base_edge_weights(self, edges, edge_w, diag_w, n):
        return weights.ratio_consensus_weights_edges(edges, n, self.c)


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

_FACTORIES: dict = {}
_INSTANCES: dict[str, ConsensusAlgorithm] = {}
_DIST_VARIANTS: dict = {}
_GENERATION = 0


def registry_generation() -> int:
    """Monotone counter bumped on every (re-)registration.

    The sweep engine threads it through its jit static args: algorithms are
    identified inside the traced program only by their spec STRINGS, so
    shadowing a name would otherwise hit the stale cached executable of the
    previous registration and silently run the old round body.
    """
    return _GENERATION


def _validate_registration(name: str, inst: "ConsensusAlgorithm") -> None:
    """Fail-fast structural contract for a registration's default instance.

    Raises at ``register_algorithm`` time instead of at first trace (or,
    worse, at the first conformance comparison): a registration whose carry
    contract is malformed or whose oracle hooks are absent would otherwise
    surface as an opaque scan-structure error deep inside the jitted engine.
    """
    if not isinstance(inst, ConsensusAlgorithm):
        raise TypeError(
            f"factory for {name!r} returned {type(inst).__name__}, "
            f"not a ConsensusAlgorithm")
    cls = type(inst)
    if not isinstance(inst.num_taps, int) or inst.num_taps < 1:
        raise ValueError(
            f"{name!r}: num_taps must be an int >= 1 (the display contract "
            f"reads carry slot 0), got {inst.num_taps!r}")
    if not isinstance(inst.num_aux, int) or inst.num_aux < 0:
        raise ValueError(
            f"{name!r}: num_aux must be an int >= 0, got {inst.num_aux!r}")
    if inst.invariant not in ("mean", "mass"):
        raise ValueError(
            f"{name!r}: invariant must be 'mean' or 'mass', "
            f"got {inst.invariant!r}")
    if inst.mass_renorm not in ("receiver", "sender"):
        raise ValueError(
            f"{name!r}: mass_renorm must be 'receiver' or 'sender', "
            f"got {inst.mass_renorm!r}")
    if cls.round_body is ConsensusAlgorithm.round_body:
        raise TypeError(f"{name!r}: round_body is not implemented")
    if not callable(getattr(inst, "display", None)):
        raise TypeError(f"{name!r}: display must be callable")
    # The conformance oracle needs ONE of the reference hooks: a per-tick
    # (a, b, c) row (ref_coef) or a full host reference (reference_run).
    if (cls.ref_coef is ConsensusAlgorithm.ref_coef
            and cls.reference_run is ConsensusAlgorithm.reference_run):
        raise TypeError(
            f"{name!r}: implement ref_coef or override reference_run — "
            f"without either the conformance suite has no oracle")


def register_algorithm(name: str, factory) -> None:
    """Register ``factory(*string_args) -> ConsensusAlgorithm`` under ``name``.

    Spec strings are ``name`` or ``name:arg1:arg2`` (args passed as strings,
    like the dynamics axis). Re-registration replaces (and drops cached
    instances + invalidates the engine's jit cache via the registry
    generation) so tests can shadow entries. The factory's zero-argument
    (default-spec) instance is validated here — malformed contracts raise
    NOW, not at first trace (see ``_validate_registration``); the deeper
    semantic contracts (coefficient mass, compile stability, precision) are
    checked statically by ``verify_static`` / ``python -m repro.analysis``.
    """
    global _GENERATION
    _validate_registration(name, factory())
    _FACTORIES[name] = factory
    _GENERATION += 1
    for k in [k for k in _INSTANCES if k.split(":")[0] == name]:
        del _INSTANCES[k]


def unregister_algorithm(name: str) -> None:
    """Remove a registration (cached instances + dist variant included).

    Primarily for tests and the analysis fixtures, which shadow the registry
    with deliberately-broken entries and must restore it exactly.
    """
    global _GENERATION
    _FACTORIES.pop(name, None)
    _DIST_VARIANTS.pop(name, None)
    _GENERATION += 1
    for k in [k for k in _INSTANCES if k.split(":")[0] == name]:
        del _INSTANCES[k]


def verify_static(spec) -> list:
    """Static contract check for one registration (no rounds executed).

    Delegates to ``repro.analysis.verify_static``: traces the algorithm's
    ``round_body`` to jaxprs and runs the coefficient-mass, trace/compile
    and precision passes against it, returning the list of
    ``AnalysisFinding``s (empty = clean). Registration authors run this at
    review time; CI runs it over the whole registry as the analysis lane.
    """
    from repro.analysis import verify_static as _verify

    return _verify(spec)


def registered_algorithms() -> tuple[str, ...]:
    """Base names of every registered algorithm, registration order."""
    return tuple(_FACTORIES)


def get_algorithm(spec) -> ConsensusAlgorithm:
    """Resolve ``"name[:args]"`` (or pass through an instance) via the registry.

    Instances are cached per spec string, so trace-time lookups inside the
    jitted engine always see the same object.
    """
    if isinstance(spec, ConsensusAlgorithm):
        return spec
    spec = str(spec)
    inst = _INSTANCES.get(spec)
    if inst is None:
        parts = spec.split(":")
        factory = _FACTORIES.get(parts[0])
        if factory is None:
            raise ValueError(
                f"unknown consensus algorithm {spec!r} "
                f"(registered: {sorted(_FACTORIES)})")
        inst = factory(*parts[1:])
        if not isinstance(inst, ConsensusAlgorithm):
            raise TypeError(f"factory for {parts[0]!r} returned {type(inst)}")
        # record the spec AS LOOKED UP: ConfigMeta.algorithm then round-trips
        # through SweepResult.cells(algorithm=...) with the exact string the
        # user put in SweepSpec.algorithms (e.g. "poly_filter", not the
        # default-expanded "poly_filter:3")
        inst.spec = spec
        _INSTANCES[spec] = inst
    return inst


def register_dist_variant(name: str, fn) -> None:
    """Attach an in-mesh shard_map implementation to a registered algorithm.

    ``repro.dist.gossip`` calls this at import for the seed algorithms; the
    registry stays importable without jax's distributed machinery.
    """
    if name.split(":")[0] not in _FACTORIES:
        raise ValueError(f"cannot attach dist variant to unknown algorithm {name!r}")
    _DIST_VARIANTS[name.split(":")[0]] = fn


def dist_variant(name: str):
    """The registered shard_map implementation for ``name`` (None if absent)."""
    return _DIST_VARIANTS.get(str(name).split(":")[0])


register_algorithm("memoryless", Memoryless)
register_algorithm("accel", TwoTapAccel)
register_algorithm("accel_adapt",
                   lambda eta="0.2": AdaptiveTwoTap(eta=float(eta)))
register_algorithm("accel_m",
                   lambda m="3": MTapAccel(num_taps=int(m)))
register_algorithm(
    "poly_filter", lambda degree="3", ridge="0.0":
    PolyFilterAlgorithm(degree=int(degree), ridge=float(ridge)))
register_algorithm("async_pairwise", AsyncPairwise)
register_algorithm("push_sum", PushSum)
register_algorithm("ratio_consensus",
                   lambda c="0.5": RatioConsensus(c=float(c)))
