"""Convergence metrics from the paper.

* ``tau_asym``           — Eq. (10): asymptotic convergence time 1/log(1/rho).
* ``averaging_time``     — Eq. (11)/(16): empirical epsilon-averaging time of an
                           iteration operator on a given initialization.
* ``averaging_time_sup`` — the sup over initializations, approximated on the
                           dominant eigenspace (worst-case direction).
* ``processing_gain``    — Theorem 3's ratio tau(W)/tau(Phi3[alpha*]).
* ``mse_trajectory``     — per-iteration MSE curves for the Fig. 1/2/5 suite.

The paper's accuracy level: "-100 dB, i.e. a relative error of 1e-5"; we keep
that as the default epsilon.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "EPS_PAPER",
    "tau_asym",
    "averaging_time",
    "averaging_time_operator",
    "processing_gain",
    "mse_trajectory",
    "slope_init",
    "spike_init",
]

EPS_PAPER = 1e-5  # -100 dB


def tau_asym(rho: float) -> float:
    """Eq. (10): tau = 1 / log(1/rho); iterations per e-fold of error, asymptotically."""
    if not 0.0 < rho < 1.0:
        return np.inf if rho >= 1.0 else 0.0
    return float(1.0 / np.log(1.0 / rho))


def processing_gain(rho_w: float, rho_accel: float) -> float:
    """tau_asym(W) / tau_asym(Phi3[alpha*]) = log rho_accel / log rho_w (Eq. 50)."""
    return float(np.log(rho_accel) / np.log(rho_w))


def averaging_time(
    step,
    x0: np.ndarray,
    target: np.ndarray,
    eps: float = EPS_PAPER,
    max_iters: int = 10_000_000,
) -> int:
    """Empirical Eq. (16): first t with ||x(t) - target|| <= eps ||x(0) - target||.

    ``step`` maps state -> state; the state may be the stacked X(t) (2N) or the
    plain x(t) (N) — ``target`` must match. Returns the hitting time (or raises
    if ``max_iters`` is exceeded, which in the paper's regime means rho >= 1).
    """
    x = np.asarray(x0, dtype=np.float64)
    err0 = np.linalg.norm(x - target)
    if err0 == 0.0:
        return 0
    thresh = eps * err0
    for t in range(1, max_iters + 1):
        x = step(x)
        if np.linalg.norm(x - target) <= thresh:
            return t
    raise RuntimeError(f"averaging_time did not reach eps={eps} in {max_iters} iters")


def averaging_time_operator(
    phi: np.ndarray,
    phi_bar: np.ndarray,
    eps: float = EPS_PAPER,
    x0: np.ndarray | None = None,
    max_iters: int = 10_000_000,
) -> int:
    """Averaging time of the linear operator ``phi`` with limit ``phi_bar``.

    If ``x0`` is None, uses the worst-case direction: the top singular/eigen
    direction of (phi - phi_bar) restricted to the non-fixed subspace — the
    empirical counterpart of the sup in Eq. (16).
    """
    m = phi - phi_bar
    if x0 is None:
        vals, vecs = np.linalg.eig(m)
        x0 = np.real(vecs[:, int(np.argmax(np.abs(vals)))])
        # keep a valid initialization (X(t) = [x(t); x(t-1)] duplicated block is
        # handled by callers; for the generic operator test any direction works)
    x = np.asarray(x0, dtype=np.float64)
    target = phi_bar @ x
    return averaging_time(lambda s: phi @ s, x, target, eps=eps, max_iters=max_iters)


def mse_trajectory(traj: np.ndarray, xbar: float | np.ndarray) -> np.ndarray:
    """Per-iteration MSE (1/N)||x(t) - xbar||^2 from a (T, N) or (T, N, F) trajectory."""
    t = np.asarray(traj, dtype=np.float64)
    err = t - xbar
    axes = tuple(range(1, err.ndim))
    return (err * err).mean(axis=axes)


# ---------------------------------------------------------------------------
# Paper initializations (Section IV).
# ---------------------------------------------------------------------------

def _normalize_unit_variance(x: np.ndarray) -> np.ndarray:
    """Paper: 'initial values normalized so the initial variance ... is 1'."""
    v = x.var()
    if v <= 0:
        return x
    return (x - x.mean()) / np.sqrt(v) + x.mean()


def slope_init(coords: np.ndarray | None, n: int) -> np.ndarray:
    """"Slope": x_i(0) = sum of coordinates (RGG) or i/N (chain); unit variance."""
    if coords is not None:
        x = coords.sum(axis=1)
    else:
        x = np.arange(1, n + 1) / n
    return _normalize_unit_variance(np.asarray(x, dtype=np.float64))


def spike_init(n: int, node: int = 0) -> np.ndarray:
    """"Spike": all zero except one node at 1; unit variance normalization."""
    x = np.zeros(n)
    x[node] = 1.0
    return _normalize_unit_variance(x)
