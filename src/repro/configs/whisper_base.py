"""whisper-base [audio]: enc-dec transformer backbone, conv frontend stubbed.

6L (encoder) + 6L (decoder), d_model=512, 8H (kv=8), d_ff=2048, vocab=51865.
[arXiv:2212.04356; unverified]. The audio frontend (2x conv1d stem over
mel-spectrogram) is a STUB: ``input_specs`` provides precomputed frame
embeddings (B, encoder_len, d_model). Whisper uses LayerNorm, GELU MLPs,
learned decoder positions (we extend the 448-position table to the assigned
sequence lengths — recorded as a hardware-shape adaptation in DESIGN.md).
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-base",
    family="encdec",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51_865,
    activation="gelu",
    norm="layernorm",
    pos="learned",
    encoder_layers=6,
    encoder_len=1500,
    grad_accum=1,
    source="arXiv:2212.04356",
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    encoder_layers=2,
    encoder_len=16,
)
