"""minicpm-2b [dense]: llama-like with WSD schedule and tied embeddings.

40L, d_model=2304, 36H (kv=36, MHA), d_ff=5760, vocab=122753.
[arXiv:2404.06395; hf]. The paper's contribution is the WSD
(warmup-stable-decay) LR schedule — wired to ``lr_schedule="wsd"`` here.
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122_753,
    activation="swiglu",
    tie_embeddings=True,
    lr_schedule="wsd",
    grad_accum=1,
    source="arXiv:2404.06395",
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=72,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
)
