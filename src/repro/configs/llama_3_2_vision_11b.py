"""llama-3.2-vision-11b [vlm]: text decoder with cross-attention image layers.

40L, d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=128256; one gated
cross-attention layer after every 4 self-attention layers (8 cross + 32 self).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]. The vision frontend
(ViT tower + projector) is a STUB: ``input_specs`` provides precomputed patch
embeddings (B, 1601, d_model).
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=128_256,
    activation="swiglu",
    rope_theta=5e5,
    cross_attn_every=4,
    num_image_tokens=1601,
    grad_accum=2,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=5,          # 1 superblock: 4 self + 1 cross
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    num_image_tokens=16,
)
