"""Assigned input shapes (the 4 columns of the 10 x 4 = 40-cell matrix)."""
from __future__ import annotations

import dataclasses
from typing import Literal

from .base import ArchConfig

Kind = Literal["train", "prefill", "decode"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Kind


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason). long_500k needs sub-quadratic attention (SSM/hybrid/SWA);
    skipped for pure full-attention archs per the assignment and DESIGN.md."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "full quadratic attention: 512k KV cache not architecturally bounded"
    return True, ""


def cells(cfg: ArchConfig) -> list[tuple[ShapeSpec, bool, str]]:
    return [(s, *applicable(cfg, s)) for s in SHAPES.values()]
