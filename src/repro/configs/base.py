"""Architecture configuration schema.

One ``ArchConfig`` per assigned architecture (exact published hyper-parameters)
plus a ``smoke`` reduction of the same family for CPU tests. The model layer
(`repro.models`) consumes these; the launcher builds input specs and sharding
from them.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
Activation = Literal["swiglu", "gelu", "squared_relu"]


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    load_balance_coef: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int          # N (ssm_state)
    head_dim: int = 64      # P
    expand: int = 2         # d_inner = expand * d_model
    conv_kernel: int = 4
    chunk: int = 128        # SSD chunk length
    num_groups: int = 1     # B/C groups (broadcast to heads)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    activation: Activation = "swiglu"
    head_dim: int | None = None          # default d_model // num_heads
    rope_theta: float = 10_000.0
    sliding_window: int | None = None    # mixtral SWA
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    pos: Literal["rope", "learned"] = "rope"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- family extensions ---
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # hybrid: repeating pattern, 'm' = mamba2 layer, 'a' = shared attention
    # block (single weight set reused at every 'a' site), e.g. 'mmmmma'.
    hybrid_pattern: str | None = None
    hybrid_tail: int = 0                 # trailing mamba layers after the blocks
    # encoder-decoder (whisper): encoder depth/length; num_layers = decoder depth
    encoder_layers: int = 0
    encoder_len: int = 1500              # stub audio frontend frames (30 s)
    # vlm: one cross-attention layer after every `cross_attn_every` self layers
    cross_attn_every: int = 0
    num_image_tokens: int = 1601         # stub vision frontend patches
    # --- training details ---
    optimizer: Literal["adamw", "adafactor"] = "adamw"
    lr_schedule: Literal["cosine", "wsd"] = "cosine"
    # microbatching: number of gradient-accumulation steps for train_4k
    grad_accum: int = 1
    # perf knob (§Perf): pad head counts up to a multiple of the TP degree so
    # attention shards over 'model' (e.g. minicpm 36->48, yi-34b 56->64).
    # Padded heads are extra zero-capacity heads: +FLOPs proportional to the
    # padding, but the attention block stops being replicated 16-way.
    tp_pad_heads: int = 0   # 0 = off; else the TP degree to pad to
    # notes for DESIGN/EXPERIMENTS provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def physical_q_heads(self) -> int:
        if self.tp_pad_heads and self.num_heads % self.tp_pad_heads:
            return round_up(self.num_heads, self.tp_pad_heads)
        return self.num_heads

    @property
    def physical_kv_heads(self) -> int:
        # kv heads padded only when q/kv grouping requires it (MHA) or when
        # padding q changes the group size unevenly
        if not self.tp_pad_heads:
            return self.num_kv_heads
        if self.num_kv_heads == self.num_heads:        # MHA: pad together
            return self.physical_q_heads
        g = self.physical_q_heads // self.num_kv_heads
        if g * self.num_kv_heads != self.physical_q_heads:
            return self.physical_q_heads  # fall back to MHA-style padding
        return self.num_kv_heads

    @property
    def padded_vocab(self) -> int:
        """Physical vocab padded to 256 (16-way TP x MXU lane alignment)."""
        return round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm.head_dim if self.ssm else 0

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k: SSM/hybrid state or bounded (sliding) KV."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def has_decoder(self) -> bool:
        return True  # all 10 assigned archs are decoders or enc-dec

    def num_params(self) -> int:
        """Approximate parameter count (embeddings included, physical vocab)."""
        d = self.d_model
        nq, nkv = self.num_heads, self.num_kv_heads
        hd = self.resolved_head_dim if nq else 0
        attn = d * nq * hd + 2 * d * nkv * hd + nq * hd * d
        if self.activation == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.family == "moe":
            m = self.moe
            e_mlp = 3 * d * m.d_ff_expert if self.activation == "swiglu" else 2 * d * m.d_ff_expert
            mlp = m.num_experts * e_mlp + m.num_shared_experts * e_mlp + d * m.num_experts
        embed = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        if self.pos == "learned":
            embed += 32_768 * d  # learned position table (whisper decoder)
        if self.family == "ssm":
            c = self.ssm
            di = self.d_inner
            layer = (
                d * (2 * di + 2 * c.num_groups * c.state_dim + self.ssm_heads)
                + di * d + 3 * self.ssm_heads + di
            )
            return self.num_layers * layer + embed
        if self.family == "hybrid":
            nm, na = self._hybrid_counts()
            c = self.ssm
            di = self.d_inner
            mamba_layer = (
                d * (2 * di + 2 * c.num_groups * c.state_dim + self.ssm_heads)
                + di * d + 3 * self.ssm_heads + di
            )
            return nm * mamba_layer + (attn + mlp) + embed
        if self.family == "encdec":
            enc = self.encoder_layers * (attn + mlp)
            dec = self.num_layers * (2 * attn + mlp)  # self + cross
            return enc + dec + embed
        if self.family == "vlm":
            n_cross = self.num_layers // (self.cross_attn_every + 1)
            n_self = self.num_layers - n_cross
            return n_self * (attn + mlp) + n_cross * (attn + mlp) + embed
        return self.num_layers * (attn + mlp) + embed

    def active_params(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.num_params()
        m = self.moe
        d = self.d_model
        e_mlp = 3 * d * m.d_ff_expert if self.activation == "swiglu" else 2 * d * m.d_ff_expert
        dense_total = self.num_params() - self.num_layers * (m.num_experts - 1) * e_mlp
        active = dense_total - self.num_layers * e_mlp * m.num_shared_experts
        # keep top_k + shared active
        hd = self.resolved_head_dim
        attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d
        per_layer_active = attn + (m.top_k + m.num_shared_experts) * e_mlp + d * m.num_experts
        embed = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return self.num_layers * per_layer_active + embed

    def _hybrid_counts(self) -> tuple[int, int]:
        """(num mamba layers, num shared-attn sites) from the pattern."""
        if not self.hybrid_pattern:
            return 0, 0
        per = self.hybrid_pattern
        n_blocks = (self.num_layers - self.hybrid_tail) // len(per)
        nm = n_blocks * per.count("m") + self.hybrid_tail
        na = n_blocks * per.count("a")
        return nm, na
