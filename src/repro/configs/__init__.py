"""Architecture registry: the 10 assigned configs + shapes + the paper's own
consensus-fabric configuration knobs (see repro.dist.grad_sync)."""
from __future__ import annotations

import importlib

from .base import ArchConfig, MoEConfig, SSMConfig
from .shapes import SHAPES, ShapeSpec, applicable, cells

_MODULES = {
    "whisper-base": "whisper_base",
    "zamba2-7b": "zamba2_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "mixtral-8x7b": "mixtral_8x7b",
    "yi-34b": "yi_34b",
    "nemotron-4-340b": "nemotron_4_340b",
    "minicpm-2b": "minicpm_2b",
    "yi-9b": "yi_9b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "mamba2-780m": "mamba2_780m",
}

ARCH_IDS = list(_MODULES)


def get_config(arch_id: str, smoke: bool = False) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.SMOKE if smoke else mod.CONFIG


__all__ = [
    "ArchConfig",
    "MoEConfig",
    "SSMConfig",
    "SHAPES",
    "ShapeSpec",
    "applicable",
    "cells",
    "ARCH_IDS",
    "get_config",
]
