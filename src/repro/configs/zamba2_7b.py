"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention block.

81L, d_model=3584, 32H (kv=32), d_ff=14336, vocab=32000, ssm_state=64.
[arXiv:2411.15242; unverified]. Pattern: 13 superblocks of (5 x Mamba2 +
1 shared-attention site) + 3 trailing Mamba2 layers = 81. The shared
attention+MLP block has ONE weight set reused at every site (the Zamba
design point: attention quality at marginal parameter cost); each site keeps
its own KV cache at inference. Simplification vs the released model (single
shared block rather than two alternating, no per-site LoRA) recorded in
DESIGN.md SArch-applicability.
"""
import dataclasses

from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14_336,
    vocab_size=32_000,
    activation="swiglu",
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk=128),
    hybrid_pattern="mmmmma",
    hybrid_tail=3,
    grad_accum=4,
    source="arXiv:2411.15242",
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=7,          # 1 superblock (5m + 1a) + 1 tail mamba
    hybrid_tail=1,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, chunk=16),
)
