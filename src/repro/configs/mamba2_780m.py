"""mamba2-780m [ssm]: attention-free SSD (state-space duality).

48L, d_model=1536, vocab=50280, ssm_state=128, head_dim=64, expand=2
(d_inner=3072, 48 ssm heads). [arXiv:2405.21060; unverified]. The SSD
intra-chunk block runs through the Pallas kernel (repro.kernels.ssd_chunk);
the inter-chunk recurrence is a log-depth associative scan.
"""
import dataclasses

from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    arch_id="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,           # attention-free
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    activation="swiglu",   # unused (no MLP); mamba block is gated internally
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk=128),
    tie_embeddings=True,
    grad_accum=1,
    source="arXiv:2405.21060",
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    vocab_size=512,
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, chunk=16),
)
