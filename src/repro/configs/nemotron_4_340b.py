"""nemotron-4-340b [dense]: GQA + squared-ReLU MLP.

96L, d_model=18432, 96H (GQA kv=8), d_ff=73728, vocab=256000.
[arXiv:2402.16819; unverified]. Squared-ReLU (Primer) MLP: two matrices,
no gate. Adafactor optimizer (AdamW moments for 340B fp32 would not fit the
per-chip HBM budget alongside params + activations); grad_accum=16 keeps the
train_4k activation footprint inside VMEM/HBM limits at global_batch=256.
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18_432,
    num_heads=96,
    num_kv_heads=8,
    d_ff=73_728,
    vocab_size=256_000,
    activation="squared_relu",
    optimizer="adafactor",
    grad_accum=16,
    source="arXiv:2402.16819",
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    grad_accum=2,
)
