"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention.

32L, d_model=4096, 32H (GQA kv=8), d_ff=14336 per expert, vocab=32000,
SWA window 4096, rope_theta=1e6. [arXiv:2401.04088; hf].
"""
import dataclasses

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=32_000,
    activation="swiglu",
    rope_theta=1e6,
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14_336),
    grad_accum=4,
    source="arXiv:2401.04088",
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    sliding_window=32,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
)
