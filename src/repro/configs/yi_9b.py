"""yi-9b [dense]: llama-architecture GQA (depth-extended Yi-6B).

48L, d_model=4096, 32H (GQA kv=4), d_ff=11008, vocab=64000.
[arXiv:2403.04652; hf].
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11_008,
    vocab_size=64_000,
    activation="swiglu",
    rope_theta=5e6,
    grad_accum=2,
    source="arXiv:2403.04652",
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
)
