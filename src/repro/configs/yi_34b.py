"""yi-34b [dense]: llama-architecture GQA.

60L, d_model=7168, 56H (GQA kv=8), d_ff=20480, vocab=64000.
[arXiv:2403.04652; hf]. rope_theta=5e6 per the released model.
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20_480,
    vocab_size=64_000,
    activation="swiglu",
    rope_theta=5e6,
    grad_accum=4,
    source="arXiv:2403.04652",
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
)
