"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) [moe]: 64 experts, top-6.

48L, d_model=2048, 16H (kv=16), d_ff_expert=1408, vocab=163840, 64e top-6
+ 2 shared experts (DeepSeek-V3-style fine-grained MoE).
[hf:moonshotai/Moonlight-16B-A3B; hf]. ~16B total / ~3B active.
"""
import dataclasses

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,  # per-expert FFN width
    vocab_size=163_840,
    activation="swiglu",
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408, num_shared_experts=2),
    grad_accum=2,
    source="hf:moonshotai/Moonlight-16B-A3B",
)

SMOKE = dataclasses.replace(
    CONFIG,
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=96,
    vocab_size=512,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=96, num_shared_experts=1),
)
