"""Optimizers: AdamW and Adafactor (factored second moments), with global-norm
clipping and the schedules the assigned archs require (cosine, minicpm's WSD).

Written against a minimal (init, update) protocol so the train step can treat
them uniformly; states are plain pytrees (per-leaf dicts), so they shard and
checkpoint exactly like parameters.

Adafactor is the default for nemotron-4-340b: full AdamW moments at 340B fp32
(2 x 1.36 TB) would crowd out activations at 256 chips; factored second
moments cut optimizer state to ~1 byte/param equivalent.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Protocol

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = [
    "Optimizer",
    "adamw",
    "adafactor",
    "cosine_schedule",
    "wsd_schedule",
    "constant_schedule",
    "global_norm",
]


class Optimizer(Protocol):
    def init(self, params: PyTree) -> PyTree: ...
    def update(self, grads: PyTree, state: PyTree, params: PyTree) -> tuple[PyTree, PyTree]: ...


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def _clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return jax.tree.map(lambda t: t * scale, grads)


# ---------------------------------------------------------------------------
# Schedules.
# ---------------------------------------------------------------------------

def constant_schedule(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1) -> Callable:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, peak * cos)

    return fn


def wsd_schedule(
    peak: float, warmup: int, total: int, decay_frac: float = 0.1, floor: float = 0.01
) -> Callable:
    """Warmup-Stable-Decay (minicpm): warmup -> flat -> sharp final decay."""
    decay_start = int(total * (1.0 - decay_frac))

    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - decay_start) / max(total - decay_start, 1), 0.0, 1.0)
        dec = peak * (floor ** frac)  # exponential decay to floor*peak
        out = jnp.where(step < warmup, warm, jnp.where(step < decay_start, peak, dec))
        return out

    return fn


# ---------------------------------------------------------------------------
# AdamW.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip: float = 1.0

    def init(self, params):
        zeros = lambda: jax.tree.map(jnp.zeros_like, params)
        return {"count": jnp.zeros((), jnp.int32), "mu": zeros(), "nu": zeros()}

    def update(self, grads, state, params):
        grads = _clip_by_global_norm(grads, self.clip)
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        b1c = 1.0 - self.b1 ** cf
        b2c = 1.0 - self.b2 ** cf
        lr = self.lr(count)

        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g, state["mu"], grads)
        nu = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g, state["nu"], grads)

        def upd(m, v, p):
            step = (m / b1c) / (jnp.sqrt(v / b2c) + self.eps)
            if p.ndim >= 2:
                step = step + self.weight_decay * p
            return -lr * step

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"count": count, "mu": mu, "nu": nu}


def adamw(lr: Callable | float, **kw) -> AdamW:
    return AdamW(lr=lr if callable(lr) else constant_schedule(lr), **kw)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments over the last two dims).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Adafactor:
    lr: Callable
    decay: float = 0.99
    eps: float = 1e-30
    clip: float = 1.0
    weight_decay: float = 0.0

    def init(self, params):
        def leaf(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros((*p.shape[:-2], p.shape[-1]), jnp.float32),
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

        return {"count": jnp.zeros((), jnp.int32), "v": jax.tree.map(leaf, params)}

    def update(self, grads, state, params):
        grads = _clip_by_global_norm(grads, self.clip)
        count = state["count"] + 1
        lr = self.lr(count)
        d = self.decay

        def upd(g, s, p):
            g2 = g.astype(jnp.float32) ** 2 + self.eps
            if g.ndim >= 2:
                vr = d * s["vr"] + (1 - d) * g2.mean(axis=-1)
                vc = d * s["vc"] + (1 - d) * g2.mean(axis=-2)
                denom = vr[..., None] * vc[..., None, :] / jnp.maximum(
                    vr.mean(axis=-1)[..., None, None], self.eps
                )
                step = g / jnp.sqrt(denom + self.eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = d * s["v"] + (1 - d) * g2
                step = g / jnp.sqrt(v + self.eps)
                new_s = {"v": v}
            if p.ndim >= 2 and self.weight_decay:
                step = step + self.weight_decay * p
            return -lr * step, new_s

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state["v"])
        flat_p = jax.tree.leaves(params)
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        updates = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_v = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return updates, {"count": count, "v": new_v}


def adafactor(lr: Callable | float, **kw) -> Adafactor:
    return Adafactor(lr=lr if callable(lr) else constant_schedule(lr), **kw)


def for_config(cfg, total_steps: int = 10_000, peak_lr: float = 3e-4) -> Optimizer:
    """The optimizer + schedule an ArchConfig asks for."""
    warm = max(total_steps // 100, 10)
    sched = (
        wsd_schedule(peak_lr, warm, total_steps)
        if cfg.lr_schedule == "wsd"
        else cosine_schedule(peak_lr, warm, total_steps)
    )
    if cfg.optimizer == "adafactor":
        return adafactor(sched)
    return adamw(sched)
