from .base import (
    Optimizer,
    adafactor,
    adamw,
    constant_schedule,
    cosine_schedule,
    for_config,
    global_norm,
    wsd_schedule,
)

__all__ = [
    "Optimizer",
    "adafactor",
    "adamw",
    "constant_schedule",
    "cosine_schedule",
    "for_config",
    "global_norm",
    "wsd_schedule",
]
