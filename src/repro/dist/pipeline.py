"""GPipe-style pipeline parallelism over a 'stage' mesh axis.

Small and self-contained: stages hold disjoint layer blocks, microbatches
march through a shard_map ppermute ring. ``pipeline_forward`` is the SPMD
program; ``reference_forward`` is the single-device layer loop it must match
to fp tolerance. Used by the multidevice suite and as the template for
stacking pipeline stages under the consensus fabric (a 'pod' axis outside
the 'stage' axis composes: gossip syncs gradients per stage block).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_forward", "reference_forward"]


def _stage_block(w1, w2, h):
    """Apply one stage's layer stack: h -> tanh(h @ w1[l]) @ w2[l] per layer."""
    for layer in range(w1.shape[0]):
        h = jnp.tanh(h @ w1[layer]) @ w2[layer]
    return h


def reference_forward(w1, w2, x):
    """Sequential reference: every stage's layers applied in order.

    w1 (S, L, D, H), w2 (S, L, H, D), x (M, B, D) -> (M, B, D); microbatches
    are independent rows of the leading axis.
    """
    def one(mb):
        h = mb
        for stage in range(w1.shape[0]):
            h = _stage_block(w1[stage], w2[stage], h)
        return h

    return jax.vmap(one)(x)


def pipeline_forward(w1, w2, x, mesh, axis_name: str = "stage"):
    """GPipe forward: stage s runs microbatch t-s at tick t, handoffs via
    ppermute. M + S - 1 ticks total; the last stage's outputs are broadcast
    back with a psum of a one-hot-masked collect (all stages see the result,
    matching the replicated out_spec).
    """
    num_stages = dict(mesh.shape)[axis_name]
    num_micro = x.shape[0]

    def body(w1_blk, w2_blk, x_all):
        w1_, w2_ = w1_blk[0], w2_blk[0]
        idx = jax.lax.axis_index(axis_name)
        is_first = idx == 0
        is_last = idx == num_stages - 1
        carry = jnp.zeros(x_all.shape[1:], x_all.dtype)
        shift = [(i, (i + 1) % num_stages) for i in range(num_stages)]
        collected = []
        for t in range(num_micro + num_stages - 1):
            feed = x_all[t] if t < num_micro else jnp.zeros_like(carry)
            h = jnp.where(is_first, feed, carry)
            out = _stage_block(w1_, w2_, h)
            collected.append(jnp.where(is_last, out, jnp.zeros_like(out)))
            carry = jax.lax.ppermute(out, axis_name, shift)
        # microbatch m leaves the last stage at tick m + S - 1
        stacked = jnp.stack(
            [collected[m + num_stages - 1] for m in range(num_micro)]
        )
        return jax.lax.psum(stacked, axis_name)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axis_name), P(axis_name), P()),
        out_specs=P(),
        check_rep=False,
    )(w1, w2, x)
