"""Gradient-sync train step: allreduce, gossip, or accelerated gossip.

``make_train_step`` wires one model + optimizer + mesh into a jittable
``(params, opt_state, batch) -> (params, opt_state, metrics)`` step under one
of three sync modes (``SyncConfig``):

* ``allreduce`` — classic data parallelism: one replica of the parameters,
  the global batch sharded over the ('pod', 'data') axes, GSPMD inserts the
  cross-pod all-reduce. Recovery from pod loss is checkpoint-restart.
* ``gossip`` / ``accel_gossip`` — decentralized consensus: each pod keeps its
  own replica (parameters gain a leading (P, ...) pod axis, ``pod_stacked``),
  computes gradients on its own shard of the batch, then mixes gradients with
  R rounds of (accelerated) gossip over the fabric graph instead of an
  all-reduce. R = ceil(log eps / log rho) comes off the fabric — the paper's
  Theorem 2 is why ``accel_gossip`` needs ~sqrt the rounds of ``gossip``.
  A pod failure is then a graph edit (``repro.runtime.elastic``), not a
  world stall.

The consensus region is a shard_map pinned to the 'pod' mesh axis; every
other dimension keeps its GSPMD sharding, so each parameter shard gossips
with the matching shard of the neighbour pods — per-round wire cost is two
neighbour payloads regardless of P.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import optim
from .compression import BF16Wire, Int8Wire
from .gossip import PodFabric, accel_gossip, gossip, make_fabric
from .sharding import abstract_params, partition_spec

PyTree = Any

__all__ = ["SyncConfig", "TrainStep", "make_train_step"]

_WIRES = {None: None, "bf16": BF16Wire, "int8": Int8Wire}


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    """How gradients cross pods."""

    mode: str = "allreduce"        # allreduce | gossip | accel_gossip
    eps: float = 1e-2              # consensus epsilon (rounds knob)
    topology: str = "ring"         # pod fabric graph
    wire: str | None = None        # None | bf16 | int8 (EF compression)
    backup_rounds: int = 0         # straggler slack (ElasticFabric policy)

    def __post_init__(self):
        if self.mode not in ("allreduce", "gossip", "accel_gossip"):
            raise ValueError(f"unknown sync mode {self.mode!r}")
        if self.wire not in _WIRES:
            raise ValueError(f"unknown wire {self.wire!r}")


@dataclasses.dataclass(frozen=True)
class TrainStep:
    """One lowered-shape train step + the input specs to lower/run it with."""

    fn: Callable                   # (params, opt_state, batch) -> (params, opt_state, metrics)
    init_state: Callable           # (key, model, opt) -> (params, opt_state)
    params_sharding: PyTree        # ShapeDtypeStructs with NamedShardings
    opt_sharding: PyTree
    batch_sharding: PyTree
    fabric: PodFabric | None       # None in allreduce mode
    rounds: int                    # consensus rounds per step (0 for allreduce)
    pod_stacked: bool              # params/batch carry a leading (P, ...) axis
    mesh: Any
    sync: SyncConfig


def _opt_sharding(opt, params_sds: PyTree, mesh, num_pods: int, stacked: bool) -> PyTree:
    """Best-effort shardings for the optimizer state.

    Subtrees that mirror the parameter tree exactly (AdamW's mu/nu) reuse the
    parameter specs; anything else (step counts, factored Adafactor moments)
    keeps the leading pod axis in stacked mode and replicates the rest.
    """
    init = jax.vmap(opt.init) if stacked else opt.init
    state_sds = jax.eval_shape(init, params_sds)
    param_struct = jax.tree.structure(params_sds)

    def generic(leaf):
        pod = stacked and leaf.ndim >= 1 and leaf.shape[0] == num_pods
        return jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype,
            sharding=NamedSharding(mesh, P("pod") if pod else P()),
        )

    def mirror(sub):
        return jax.tree.map(
            lambda leaf, src: jax.ShapeDtypeStruct(
                leaf.shape, leaf.dtype, sharding=src.sharding
            ),
            sub, params_sds,
        )

    if isinstance(state_sds, dict):
        return {
            k: mirror(sub) if jax.tree.structure(sub) == param_struct
            else jax.tree.map(generic, sub)
            for k, sub in state_sds.items()
        }
    return jax.tree.map(generic, state_sds)


def _accum_grads(loss_fn, params, batch, grad_accum: int):
    """value_and_grad with optional micro-batch accumulation (mean-of-means)."""
    if grad_accum <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)

    micro = jax.tree.map(
        lambda t: t.reshape(grad_accum, t.shape[0] // grad_accum, *t.shape[1:]),
        batch,
    )

    def body(carry, mb):
        loss, grads = jax.value_and_grad(loss_fn)(params, mb)
        acc_loss, acc_grads = carry
        return (acc_loss + loss, jax.tree.map(jnp.add, acc_grads, grads)), None

    zero = (jnp.zeros(()), jax.tree.map(jnp.zeros_like, params))
    (loss, grads), _ = jax.lax.scan(body, zero, micro)
    scale = 1.0 / grad_accum
    return loss * scale, jax.tree.map(lambda g: g * scale, grads)


def make_train_step(
    model,
    opt,
    mesh,
    sync: SyncConfig,
    global_batch: int,
    seq_len: int,
    grad_accum: int = 1,
) -> TrainStep:
    """Build the train step + input specs for one (model, mesh, sync) cell."""
    axis_sizes = dict(mesh.shape)
    num_pods = axis_sizes.get("pod", 1)
    consensus = sync.mode != "allreduce"
    stacked = consensus and num_pods > 1
    fabric = make_fabric(num_pods, sync.topology) if consensus else None
    if stacked and global_batch % num_pods:
        raise ValueError(f"global batch {global_batch} not divisible by {num_pods} pods")
    if consensus:
        rounds = (
            fabric.rounds_for(sync.eps) if sync.mode == "accel_gossip"
            else fabric.rounds_for_memoryless(sync.eps)
        ) + sync.backup_rounds
    else:
        rounds = 0
    wire_cls = _WIRES[sync.wire]

    # ---- input specs -------------------------------------------------------
    params_sds = abstract_params(
        model.param_specs, mesh, stacked_pods=num_pods if stacked else 0
    )
    batch_sds = {}
    for name, (shape, axes, dtype) in model.batch_spec(global_batch, seq_len).items():
        if stacked:
            shape = (num_pods, shape[0] // num_pods, *shape[1:])
            axes = ("pod", *axes)
        batch_sds[name] = jax.ShapeDtypeStruct(
            shape, dtype,
            sharding=NamedSharding(mesh, partition_spec(shape, axes, mesh)),
        )
    opt_sds = _opt_sharding(opt, params_sds, mesh, num_pods, stacked)
    param_pspecs = jax.tree.map(lambda s: s.sharding.spec, params_sds)

    # ---- gradient sync (the consensus region) ------------------------------
    def sync_grads(grads: PyTree) -> PyTree:
        flat, treedef = jax.tree.flatten(grads)
        specs = tuple(jax.tree.leaves(param_pspecs))

        def body(*blocks):
            wire = wire_cls() if wire_cls is not None else None
            run = accel_gossip if sync.mode == "accel_gossip" else gossip
            return tuple(
                run(b[0], "pod", fabric, rounds, wire=wire)[None] for b in blocks
            )

        synced = shard_map(
            body, mesh=mesh, in_specs=specs, out_specs=specs, check_rep=False
        )(*flat)
        return jax.tree.unflatten(treedef, synced)

    # ---- the step ----------------------------------------------------------
    def loss_fn(p, b):
        return model.loss(p, b)

    def fn(params, opt_state, batch):
        if stacked:
            loss, grads = jax.vmap(
                lambda p, b: _accum_grads(loss_fn, p, b, grad_accum)
            )(params, batch)
            grads = sync_grads(grads)
            gnorm = jax.vmap(optim.global_norm)(grads)
            updates, opt_state = jax.vmap(opt.update)(grads, opt_state, params)
        else:
            loss, grads = _accum_grads(loss_fn, params, batch, grad_accum)
            gnorm = optim.global_norm(grads)
            updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree.map(jnp.add, params, updates)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    # ---- state init --------------------------------------------------------
    def init_state(key, model_, opt_):
        params = model_.init(key)
        if stacked:
            # every pod starts from the same replica: already in consensus
            params = jax.tree.map(
                lambda t: jnp.broadcast_to(t[None], (num_pods, *t.shape)), params
            )
            opt_state = jax.vmap(opt_.init)(params)
        else:
            opt_state = opt_.init(params)
        params = jax.device_put(params, jax.tree.map(lambda s: s.sharding, params_sds))
        opt_state = jax.device_put(opt_state, jax.tree.map(lambda s: s.sharding, opt_sds))
        return params, opt_state

    return TrainStep(
        fn=fn,
        init_state=init_state,
        params_sharding=params_sds,
        opt_sharding=opt_sds,
        batch_sharding=batch_sds,
        fabric=fabric,
        rounds=rounds,
        pod_stacked=stacked,
        mesh=mesh,
        sync=sync,
    )
