"""Distributed consensus fabric (SPMD layer).

Two halves, one package:

* **Host-side description** — ``gossip.make_fabric`` builds the pod graph's
  Metropolis-Hastings W, its spectral gap, and the paper-optimal two-tap
  parameters (Theorem 1); ``compression`` is the wire-level error-feedback
  quantization the consensus rounds ride on.
* **SPMD execution** — ``accel_gossip`` / ``gossip`` run consensus rounds
  inside shard_map over the mesh 'pod' axis; ``distributed_lambda2`` is the
  in-mesh Algorithm 1 (Section III-D); ``make_train_step`` wires either mode
  (or a plain all-reduce) into the training drivers; ``sharding`` maps the
  model layer's logical axes onto mesh axes; ``pipeline`` is the GPipe-style
  stage ring the multidevice suite exercises.
"""
from . import compression, gossip, pipeline, sharding
from .compression import BF16Wire, Int8Wire
from .gossip import (
    PodFabric,
    accel_gossip,
    algorithm_gossip,
    distributed_lambda2,
    edge_permutations,
    fabric_matvec,
    make_fabric,
    pairwise_gossip,
)
from .gossip import gossip as gossip_rounds
from .sharding import partition_spec
from .train_step import SyncConfig, TrainStep, make_train_step

__all__ = [
    "compression",
    "gossip",
    "pipeline",
    "sharding",
    "BF16Wire",
    "Int8Wire",
    "PodFabric",
    "make_fabric",
    "accel_gossip",
    "algorithm_gossip",
    "pairwise_gossip",
    "gossip_rounds",
    "distributed_lambda2",
    "edge_permutations",
    "fabric_matvec",
    "partition_spec",
    "SyncConfig",
    "TrainStep",
    "make_train_step",
]
