"""Distributed consensus fabric (SPMD layer).

This package grows toward the full SPMD consensus layer referenced across
the tree (``make_train_step``, in-mesh ``accel_gossip``/``distributed_lambda2``,
``sharding``): those land with the consensus-training PR. What is here today
is the host-side fabric description (``gossip.make_fabric``) and the
wire-level compression layer — both self-contained and test-covered.
"""
from . import compression, gossip
from .compression import BF16Wire, Int8Wire
from .gossip import PodFabric, make_fabric

__all__ = ["compression", "gossip", "BF16Wire", "Int8Wire", "PodFabric", "make_fabric"]
