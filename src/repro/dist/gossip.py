"""Pod-level gossip fabric: the paper's optimization applied to pod graphs.

A ``PodFabric`` is the static description of cross-pod consensus for P pods
on a named topology: the Metropolis-Hastings weight matrix W, its spectral
gap, and the paper-optimal two-tap parameters (Theorem 1) for it. The
elastic runtime (``repro.runtime.elastic``) rebuilds a fabric on every graph
edit; the sync-cost model (``benchmarks/sync_cost.py``) reads round counts
off it.

The SPMD execution half lives here too: ``gossip`` / ``accel_gossip`` run a
consensus round *inside* shard_map over a mesh axis (ppermute along the
fabric's graph edges, the accelerated variant carrying the ``(x, x_prev)``
taps across rounds), and ``distributed_lambda2`` is Algorithm 1 run in-mesh —
power iteration with periodic max-consensus normalization, mirroring the
host-side ``repro.core.doi`` network simulation op for op.
``adaptive_accel_gossip`` composes the two: periodic in-mesh re-estimation
feeding a traced Theorem-1 re-solve between gossip segments — the shard_map
mirror of the registry's ``accel_adapt`` time-varying coefficient stream.

The edge structure of W is lowered to a static list of permutations (greedy
matching decomposition of the directed edge set, one ppermute each); per-node
weights are looked up by ``axis_index``, so one code path serves any fabric
topology.
"""
from __future__ import annotations

import dataclasses
import math
from fractions import Fraction

import jax
import jax.numpy as jnp
import numpy as np

from ..core import accel, topology, weights
from ..core.accel import Theta

__all__ = [
    "PodFabric",
    "make_fabric",
    "gossip",
    "accel_gossip",
    "adaptive_accel_gossip",
    "pairwise_gossip",
    "push_sum_gossip",
    "algorithm_gossip",
    "distributed_lambda2",
    "default_doi_iters",
    "edge_permutations",
    "fabric_matvec",
]


@dataclasses.dataclass(frozen=True)
class PodFabric:
    """Pod graph + paper-optimal consensus parameters for it."""

    w: np.ndarray            # (P, P) Metropolis-Hastings weights
    topology: str
    theta: Theta
    lambda2: float           # lambda_2(W)
    alpha: float             # alpha* (Theorem 1)
    rho_accel: float         # rho(Phi3[alpha*] - J)
    rho_memoryless: float    # rho(W - J)

    @property
    def num_pods(self) -> int:
        return self.w.shape[0]

    def _rounds(self, rho: float, eps: float) -> int:
        """First R with rho^R <= eps (1 when the graph mixes exactly)."""
        if rho <= 0.0:
            return 1
        if rho >= 1.0:
            raise ValueError(f"non-contracting fabric (rho={rho})")
        return max(1, math.ceil(math.log(eps) / math.log(rho)))

    def rounds_for(self, eps: float) -> int:
        """Accelerated rounds to reach relative consensus error eps."""
        return self._rounds(self.rho_accel, eps)

    def rounds_for_memoryless(self, eps: float) -> int:
        """Memoryless x(t+1) = W x(t) rounds for the same eps."""
        return self._rounds(self.rho_memoryless, eps)


def _pod_graph(p: int, kind: str) -> topology.Graph:
    if p < 1:
        raise ValueError("need at least one pod")
    if p == 1:
        return topology.Graph(adjacency=np.zeros((1, 1)), name=kind)
    if p == 2:
        return topology.chain(2)
    if kind == "ring":
        return topology.ring(p)
    if kind == "chain":
        return topology.chain(p)
    if kind == "torus":
        side = int(round(math.sqrt(p)))
        if side * side != p:
            raise ValueError(f"torus fabric needs a square pod count, got {p}")
        return topology.torus2d(side)
    raise ValueError(f"unknown fabric topology {kind!r}")


def make_fabric(p: int, kind: str = "ring", theta: Theta | None = None,
                lambda2: float | None = None) -> PodFabric:
    """Build the fabric for ``p`` pods: W, lambda_2, alpha*, rho*.

    Dense O(P^3) eigensolve — P is the pod count (tens), not the node count.
    Passing ``lambda2`` skips the eigensolve and re-solves Theorem 1 from a
    supplied estimate (the O(K) in-mesh ``distributed_lambda2`` / Algorithm 1),
    which is how ``ElasticFabric.resize`` re-optimizes an irregular fabric
    without gathering W; it assumes |lambda_P| <= lambda_2 (the lazy map or a
    regular topology guarantees it), so rho_memoryless = lambda_2 there.
    """
    theta = theta or accel.theta_asymptotic(0.5)
    g = _pod_graph(p, kind)
    if p == 1:
        w = np.ones((1, 1))
        return PodFabric(w=w, topology=kind, theta=theta, lambda2=0.0,
                         alpha=0.0, rho_accel=0.0, rho_memoryless=0.0)
    w = weights.metropolis_hastings(g)
    if lambda2 is None:
        vals = np.linalg.eigvalsh(w)
        if abs(vals[0]) > vals[-2]:
            # Theorem 1 needs |lambda_P| <= lambda_2; the lazy map guarantees it.
            w = weights.lazy(w)
            vals = np.linalg.eigvalsh(w)
        lam2 = float(vals[-2])
        rho_mem = float(max(abs(vals[0]), abs(lam2)))
    else:
        lam2 = float(lambda2)
        rho_mem = lam2
    if lam2 <= 0.0:
        # complete-graph-like mixing: one round is exact, nothing to optimize
        return PodFabric(w=w, topology=kind, theta=theta, lambda2=max(lam2, 0.0),
                         alpha=0.0, rho_accel=0.0, rho_memoryless=rho_mem)
    a_star = accel.alpha_star(lam2, theta)
    return PodFabric(
        w=w, topology=kind, theta=theta, lambda2=lam2, alpha=a_star,
        rho_accel=accel.rho_accel(lam2, theta), rho_memoryless=rho_mem,
    )


# ---------------------------------------------------------------------------
# SPMD execution half: consensus rounds inside shard_map over a mesh axis.
# ---------------------------------------------------------------------------

def edge_permutations(w: np.ndarray) -> list[tuple[tuple[tuple[int, int], ...], np.ndarray]]:
    """Decompose the off-diagonal support of W into ppermute-able matchings.

    Returns ``[(perm, wvec), ...]`` where ``perm`` is a list of (src, dst)
    device pairs with each src/dst used at most once (a valid ``ppermute``
    argument) and ``wvec[dst] = W[dst, src]`` scales what dst receives. The
    greedy matching decomposition needs at most ~max-degree passes and is
    deterministic (edges visited in sorted order), so the lowered program is
    stable across hosts.
    """
    p = w.shape[0]
    remaining = [
        (s, d) for s in range(p) for d in range(p)
        if s != d and w[d, s] != 0.0
    ]
    perms = []
    while remaining:
        used_src: set[int] = set()
        used_dst: set[int] = set()
        perm = []
        for s, d in remaining:
            if s not in used_src and d not in used_dst:
                perm.append((s, d))
                used_src.add(s)
                used_dst.add(d)
        remaining = [e for e in remaining if e not in set(perm)]
        wvec = np.zeros(p, dtype=w.dtype)
        for s, d in perm:
            wvec[d] = w[d, s]
        perms.append((tuple(perm), wvec))
    return perms


def _fma(a: float, b: float, c: float) -> float:
    """Correctly-rounded fused multiply-add a*b + c (one rounding).

    Exact rational arithmetic then round-to-nearest-even via float(Fraction):
    the portable stand-in for ``math.fma`` (3.13+) at the tiny sizes the
    bit-for-bit tests need (P <= 8 components).
    """
    return float(Fraction(a) * Fraction(b) + Fraction(c))


def fabric_matvec(w: np.ndarray, contraction: str = "fma"):
    """Host mirror of the in-mesh neighbour sum — the bit-for-bit reference
    for Algorithm 1 agreement tests
    (``doi.estimate_lambda2(..., matvec=fabric_matvec(w))``).

    ``contraction`` selects the floating-point recipe:

    * ``"fma"`` — mirror LLVM's mul+add contraction as XLA:CPU emits it for
      ``_neighbor_sum``: the first accumulation fuses the diagonal product
      (``fma(W_ii, v_i, p_0)``), every later matching fuses its own product
      (``fma(wvec_k, recv_k, acc)``). Emulated with exact rational arithmetic
      and a single rounding per fma, so the host trajectory reproduces the
      jitted SPMD trajectory bit for bit.
    * ``"none"`` — plain mul-then-add (the reference on backends that do not
      contract).
    """
    if contraction not in ("fma", "none"):
        raise ValueError(f"unknown contraction {contraction!r}")
    diag = np.diag(w).copy()
    perms = edge_permutations(w)

    def recv_of(v, perm):
        recv = np.zeros_like(v)
        for s, d in perm:
            recv[d] = v[s]
        return recv

    def mv_plain(v: np.ndarray) -> np.ndarray:
        out = diag * v
        for perm, wvec in perms:
            out = out + wvec * recv_of(v, perm)
        return out

    def mv_fma(v: np.ndarray) -> np.ndarray:
        if not perms:
            return diag * v
        (perm0, wvec0), rest = perms[0], perms[1:]
        p0 = wvec0 * recv_of(v, perm0)
        out = np.array([_fma(diag[i], v[i], p0[i]) for i in range(len(v))])
        for perm, wvec in rest:
            recv = recv_of(v, perm)
            out = np.array([_fma(wvec[i], recv[i], out[i]) for i in range(len(v))])
        return out

    return mv_fma if contraction == "fma" else mv_plain


def _neighbor_sum(x_self, payload, axis_name, idx, diag, perms, live=None):
    """x_w[i] = W[i,i] x_self + sum_j W[i,j] payload_j — one exchange tick.

    ``x_self`` is the node's true state (never quantized); ``payload`` is what
    goes on the wire. One ppermute per matching; nodes outside a matching
    receive zeros and carry a zero weight, so the same program serves every
    fabric topology. The accumulation is written mul-then-add; XLA:CPU
    contracts it to the fma chain ``fabric_matvec(w, "fma")`` mirrors.

    ``live`` (optional, one 0/1 scalar per matching) marks which matchings
    delivered this round. A dead matching's weight returns to the node's own
    state — the mass-preserving re-weighting of ``repro.core.dynamics`` —
    instead of scaling whatever stale/zero payload ppermute produced, so the
    round's effective W stays doubly stochastic and the pod-mean exact.
    """
    out = diag[idx] * x_self
    for k, (perm, wvec) in enumerate(perms):
        recv = jax.lax.ppermute(payload, axis_name, perm)
        w_k = wvec[idx]
        if live is None:
            out = out + w_k * recv
        else:
            out = out + w_k * (live[k] * recv + (1.0 - live[k]) * x_self)
    return out


def _wire_rounds(x, axis_name, fabric, num_rounds, wire, step, drop_mask=None):
    """Shared driver: carries (state, wire error-feedback) across rounds."""
    idx = jax.lax.axis_index(axis_name)
    diag = jnp.asarray(np.diag(fabric.w), x.dtype)
    perms = [(perm, jnp.asarray(wvec, x.dtype))
             for perm, wvec in edge_permutations(fabric.w)]
    if drop_mask is not None:
        drop_mask = jnp.asarray(drop_mask, x.dtype)
        if drop_mask.shape != (num_rounds, len(perms)):
            raise ValueError(
                f"drop_mask shape {drop_mask.shape} != (num_rounds, num_matchings)"
                f" = ({num_rounds}, {len(perms)})"
            )
    err = jnp.zeros_like(x) if wire is not None else None
    carry = None
    for r in range(num_rounds):
        payload = x
        if wire is not None:
            payload, err = wire.encode_decode(x, err)
        live = None if drop_mask is None else drop_mask[r]
        xw = _neighbor_sum(x, payload, axis_name, idx, diag, perms, live)
        x, carry = step(xw, x, carry)
    return x


def gossip(x, axis_name: str, fabric: PodFabric, num_rounds: int, wire=None,
           drop_mask=None):
    """Memoryless consensus x(t+1) = W x(t), run inside shard_map.

    ``x`` is this pod's block (any shape); ``axis_name`` the mesh axis the
    fabric lives on (one device slot per pod). ``num_rounds`` is static —
    read it off ``fabric.rounds_for_memoryless(eps)``. ``wire`` optionally
    compresses the neighbour payload (error feedback carried across rounds).
    ``drop_mask`` (num_rounds, num_matchings), 1 = delivered: failed
    matchings return their weight to the sender's own state (mass-preserving,
    see ``_neighbor_sum``) so consensus degrades gracefully instead of
    averaging stale ppermute data.
    """
    return _wire_rounds(x, axis_name, fabric, num_rounds, wire,
                        lambda xw, x, carry: (xw, None), drop_mask=drop_mask)


def accel_gossip(x, axis_name: str, fabric: PodFabric, num_rounds: int, wire=None,
                 drop_mask=None):
    """The paper's two-tap accelerated recursion (Eq. 4a-4c), in-mesh.

    Carries the ``(x, x_prev)`` taps across rounds; per round one neighbour
    exchange (same wire cost as memoryless gossip) plus two local FMAs:

        x(t+1) = (1 - alpha + alpha theta3) W x(t)
                 + alpha theta2 x(t) + alpha theta1 x(t-1)

    with (alpha*, theta) read off the fabric (Theorem 1). ``num_rounds``
    comes from ``fabric.rounds_for(eps)`` = ceil(log eps / log rho_accel) —
    ~sqrt of the memoryless round count (Theorem 2). ``drop_mask``
    (num_rounds, num_matchings) injects per-round matching failures with the
    same mass-preserving semantics as ``gossip``; alpha* stays the nominal
    one, mirroring what a real deployment can actually compute.
    """
    t = fabric.theta
    a = 1.0 - fabric.alpha + fabric.alpha * t.t3
    b = fabric.alpha * t.t2
    c = fabric.alpha * t.t1

    def step(xw, x, x_prev):
        x_prev = x if x_prev is None else x_prev
        return a * xw + b * x + c * x_prev, x

    return _wire_rounds(x, axis_name, fabric, num_rounds, wire, step,
                        drop_mask=drop_mask)


def adaptive_accel_gossip(x, axis_name: str, fabric: PodFabric, num_rounds: int,
                          resolve_every: int | None = None,
                          doi_iters: int | None = None,
                          normalize_every: int = 10, v_init=None,
                          wire=None, drop_mask=None):
    """Two-tap gossip with periodic in-mesh re-solve of Theorem 1.

    The SPMD mirror of the registry's ``accel_adapt``: before each segment of
    ``resolve_every`` rounds (default: one leading segment covering the whole
    run) the pods run Algorithm 1 *in-mesh* (``distributed_lambda2``) and
    re-solve alpha* from the fresh estimate as traced scalars — the
    one-program analogue of ``ElasticFabric.refresh_lambda2``, with the
    ``(x, x_prev)`` taps carried straight across segment boundaries (the
    recursion never restarts, only its coefficient stream moves).

    The re-solve applies the same one-sided rule as ``accel_adapt``:
    ``lambda_used = max(fabric.lambda2, lambda2_hat)``. Underestimates are
    the catastrophic direction for alpha* (real-root regime) and the finite-K
    power iteration approaches lambda_2 from below, so the fabric's nominal
    value is a floor; a degraded fabric raises the estimate above it.

    ``v_init`` seeds the (P,) DOI probe; None derives a deterministic
    integer-hash probe (no key threading, reproducible across hosts).
    Estimation ticks run on the intact fabric — ``drop_mask``
    (num_rounds, num_matchings) applies to the consensus rounds only,
    modelling the deployment where re-tuning is a slow control-plane sweep
    while per-round losses hit the data path.
    """
    from ..core.algorithms import _probe_block

    t = fabric.theta
    p = fabric.num_pods
    if p == 1 or num_rounds <= 0:
        return x
    if resolve_every is None:
        resolve_every = num_rounds
    if resolve_every < 1:
        raise ValueError(f"resolve_every must be >= 1, got {resolve_every}")
    idx = jax.lax.axis_index(axis_name)
    diag = jnp.asarray(np.diag(fabric.w), x.dtype)
    perms = [(perm, jnp.asarray(wvec, x.dtype))
             for perm, wvec in edge_permutations(fabric.w)]
    if drop_mask is not None:
        drop_mask = jnp.asarray(drop_mask, x.dtype)
        if drop_mask.shape != (num_rounds, len(perms)):
            raise ValueError(
                f"drop_mask shape {drop_mask.shape} != (num_rounds, num_matchings)"
                f" = ({num_rounds}, {len(perms)})"
            )
    if v_init is None:
        v_init = _probe_block(p, 1)[:, 0].astype(np.float64)
    lam_floor = jnp.asarray(min(max(fabric.lambda2, 0.0), 0.999999), x.dtype)
    err = jnp.zeros_like(x) if wire is not None else None
    x_prev = None
    for start in range(0, num_rounds, resolve_every):
        lam_hat = distributed_lambda2(
            axis_name, p, None, num_iters=doi_iters,
            normalize_every=normalize_every, fabric=fabric,
            v_init=v_init, dtype=x.dtype)
        lam_eff = jnp.clip(jnp.maximum(lam_floor, lam_hat), 0.0, 0.999999)
        al = accel.alpha_star_jnp(lam_eff, t)
        a = 1.0 - al + al * t.t3
        b = al * t.t2
        c = al * t.t1
        for r in range(start, min(start + resolve_every, num_rounds)):
            payload = x
            if wire is not None:
                payload, err = wire.encode_decode(x, err)
            live = None if drop_mask is None else drop_mask[r]
            xw = _neighbor_sum(x, payload, axis_name, idx, diag, perms, live)
            xp = x if x_prev is None else x_prev
            x, x_prev = a * xw + b * x + c * xp, x
    return x


def pairwise_gossip(x, axis_name: str, fabric: PodFabric, num_rounds: int,
                    schedule=None, seed: int = 0):
    """Boyd-style asynchronous randomized pairwise gossip, in-mesh.

    One fabric edge wakes per round: the woken pair swaps states over a
    single two-element ppermute and averages, x_i, x_j <- (x_i + x_j)/2;
    every other pod holds its value (the in-mesh mirror of the registry's
    ``async_pairwise`` engine algorithm — one exchange = one round here too).

    ``schedule`` is the host-sampled (num_rounds,) edge-index sequence; None
    samples it from ``dynamics.graph_rng(seed, ...)`` keyed by the fabric
    topology, so the lowered program is reproducible across hosts (the edge
    list, like ``edge_permutations``, is visited in deterministic sorted
    order). The pod mean is conserved exactly in real arithmetic: every
    round's effective matrix is symmetric doubly stochastic.
    """
    from ..core import dynamics

    w = fabric.w
    p = w.shape[0]
    edges = [(i, j) for i in range(p) for j in range(i + 1, p) if w[i, j] != 0.0]
    if not edges:
        return x
    if schedule is None:
        rng = dynamics.graph_rng(seed, ("pairwise", fabric.topology, p))
        schedule = rng.integers(0, len(edges), size=num_rounds)
    schedule = np.asarray(schedule)
    if schedule.shape != (num_rounds,):
        raise ValueError(
            f"schedule shape {schedule.shape} != (num_rounds,) = ({num_rounds},)")
    idx = jax.lax.axis_index(axis_name)
    for r in range(num_rounds):
        i, j = edges[int(schedule[r])]
        recv = jax.lax.ppermute(x, axis_name, [(i, j), (j, i)])
        awake = (idx == i) | (idx == j)
        x = jnp.where(awake, 0.5 * (x + recv), x)
    return x


def push_sum_gossip(x, axis_name: str, fabric: PodFabric, num_rounds: int,
                    drop_mask=None):
    """Kempe-Dobra-Gehrke push-sum over the fabric's support, in-mesh.

    Each pod carries a (value, mass) pair — the value seeded with its block,
    the mass with 1 — and per round both ride the SAME exchanges under the
    column-stochastic push matrix ``weights.push_sum_weights`` built on the
    fabric's support. The returned estimate is the quotient value/mass.

    ``drop_mask`` (num_rounds, num_matchings), 1 = delivered, uses SENDER
    renormalization: a failed matching's share stays in the sending pod's
    own pair (column sums — total value and total mass — survive every
    failure pattern), unlike ``gossip``'s receiver rule which preserves row
    sums. The quotient therefore still converges to the true mean under
    sustained loss, where the memoryless receiver rule drifts.
    """
    pm = weights.push_sum_weights(fabric.w)
    idx = jax.lax.axis_index(axis_name)
    diag = jnp.asarray(np.diag(pm), x.dtype)
    packs = []
    for perm, wvec in edge_permutations(pm):
        svec = np.zeros(pm.shape[0], dtype=pm.dtype)
        for s, d in perm:
            svec[s] = pm[d, s]           # the share s fails to deliver to d
        packs.append((perm, jnp.asarray(wvec, x.dtype),
                      jnp.asarray(svec, x.dtype)))
    if drop_mask is not None:
        drop_mask = jnp.asarray(drop_mask, x.dtype)
        if drop_mask.shape != (num_rounds, len(packs)):
            raise ValueError(
                f"drop_mask shape {drop_mask.shape} != (num_rounds, "
                f"num_matchings) = ({num_rounds}, {len(packs)})"
            )

    def tick(v, live):
        out = diag[idx] * v
        for k, (perm, wvec, svec) in enumerate(packs):
            recv = jax.lax.ppermute(v, axis_name, perm)
            if live is None:
                out = out + wvec[idx] * recv
            else:
                out = (out + wvec[idx] * live[k] * recv
                       + svec[idx] * (1.0 - live[k]) * v)
        return out

    m = jnp.ones_like(x)
    for r in range(num_rounds):
        live = None if drop_mask is None else drop_mask[r]
        x, m = tick(x, live), tick(m, live)
    safe = jnp.abs(m) > 1e-12
    return jnp.where(safe, x, 0.0) / jnp.where(safe, m, 1.0)


def algorithm_gossip(x, axis_name: str, fabric: PodFabric, num_rounds: int,
                     algorithm: str = "accel", **kwargs):
    """Run ``num_rounds`` of a *registered* consensus algorithm in-mesh.

    Dispatches through the ``repro.core.algorithms`` registry's dist-variant
    hook table — the shard_map mirror of the sweep engine's algorithm axis.
    This module registers the seed variants at import (memoryless ->
    ``gossip``, accel -> ``accel_gossip``, async_pairwise ->
    ``pairwise_gossip``); extra keyword arguments (``wire``, ``drop_mask``,
    ``schedule``) pass through to the variant.
    """
    from ..core.algorithms import dist_variant, get_algorithm

    algo = get_algorithm(algorithm)      # raises on unknown spec
    fn = dist_variant(algo.name)
    if fn is None:
        raise NotImplementedError(
            f"algorithm {algo.spec!r} has no registered dist variant "
            f"(register one via core.algorithms.register_dist_variant)")
    return fn(x, axis_name, fabric, num_rounds, **kwargs)


# Registrations with no shard_map gossip mirror, ON PURPOSE — consumed by
# the static analyzer's mesh-dist-coverage advisory (repro.analysis), so a
# deliberate gap is distinguishable from a forgotten one:
#   accel_m        — the M-tap frontier study runs through the sweep engine
#                    only; its memory-order sweep has no in-mesh use case.
#   poly_filter    — the Chebyshev/polynomial filter needs the full period's
#                    taps resident; the per-round wire protocol here has no
#                    super-iteration framing.
#   ratio_consensus — in-mesh lossy averaging is served by push_sum_gossip;
#                    the ratio variant differs only in engine-side seams.
DIST_EXEMPT = ("accel_m", "poly_filter", "ratio_consensus")


def _register_dist_variants():
    from ..core.algorithms import register_dist_variant

    register_dist_variant("memoryless", gossip)
    register_dist_variant("accel", accel_gossip)
    register_dist_variant("accel_adapt", adaptive_accel_gossip)
    register_dist_variant("async_pairwise", pairwise_gossip)
    register_dist_variant("push_sum", push_sum_gossip)


_register_dist_variants()


def default_doi_iters(fab: PodFabric, dtype, tol: float = 1e-4) -> int:
    """Largest safe K for Algorithm 1 on this fabric at this precision.

    Floating-point rounding re-injects a lambda_1 = 1 (mean) component that
    the W-applications amplify by (1/lambda_2)^K relative to the dominant
    mode, so K cannot grow freely on fast-mixing fabrics: pick the largest K
    whose contamination floor eps_mach * (1/lambda_2)^K stays below ``tol``,
    capped at the paper's K ~ N^2 slow-mixing budget. The dtype is
    canonicalized first: with x64 disabled a float64 request silently runs in
    float32, and K must budget for the eps that will actually round.
    """
    eps_mach = float(jnp.finfo(jax.dtypes.canonicalize_dtype(dtype)).eps)
    k_paper = max(4 * fab.num_pods * fab.num_pods, 8)
    lam2 = fab.lambda2
    if not 0.0 < lam2 < 1.0:
        return 8
    k_cap = int(math.log(tol / eps_mach) / math.log(1.0 / lam2))
    return max(1, min(k_paper, k_cap))


def distributed_lambda2(
    axis_name: str,
    num_pods: int,
    key,
    num_iters: int | None = None,
    normalize_every: int = 10,
    topology_kind: str = "ring",
    fabric: PodFabric | None = None,
    v_init=None,
    dtype=jnp.float32,
):
    """Algorithm 1 (Section III-D) run *inside* a jitted SPMD program.

    Each device holds one component of the iterate; consensus ticks are
    neighbour ppermutes, and the sup-norm normalizations are genuine
    max-consensus (diameter(G) neighbour-max sweeps — every node normalizes by
    the SAME number). Mirrors ``repro.core.doi.estimate_lambda2`` op for op:
    with ``matvec=fabric_matvec(fab.w)`` and the same ``v_init`` the host
    simulation agrees bit-for-bit in float64. Returns the per-device scalar
    lambda2_hat (identical on every device); cost is O(K) ticks, which is what
    lets ``ElasticFabric.resize`` re-solve Theorem 1 after a graph edit
    without gathering W (``make_fabric(..., lambda2=estimate)``).

    ``num_iters=None`` picks K via ``default_doi_iters``: explicit K is
    honoured as-is, but beware the contamination floor it documents —
    K=80 on a lambda_2=1/3 ring returns ~1.0, not lambda_2, at any precision.
    """
    fab = fabric if fabric is not None else make_fabric(num_pods, topology_kind)
    p = fab.num_pods
    # with x64 off a float64 request silently runs in float32; resolve it
    # up front so the K guard and the array dtypes agree
    dtype = jax.dtypes.canonicalize_dtype(dtype)
    if p == 1:
        return jnp.zeros((), dtype)
    if num_iters is None:
        num_iters = default_doi_iters(fab, dtype)
    idx = jax.lax.axis_index(axis_name)
    diag = jnp.asarray(np.diag(fab.w), dtype)
    perms = [(perm, jnp.asarray(wvec, dtype))
             for perm, wvec in edge_permutations(fab.w)]
    adj = (np.abs(fab.w) > 0).astype(np.float64)
    np.fill_diagonal(adj, 0.0)
    diam = topology.diameter(adj)

    def matvec(v):
        return _neighbor_sum(v, v, axis_name, idx, diag, perms)

    def max_consensus(m):
        # |v| >= 0, so the zero fill of off-matching ppermute slots is the
        # identity for max; D sweeps reach exact global agreement.
        for _ in range(diam):
            recvs = [jax.lax.ppermute(m, axis_name, perm) for perm, _ in perms]
            for r in recvs:
                m = jnp.maximum(m, r)
        return m

    v_full = (jnp.asarray(v_init, dtype)
              if v_init is not None else jax.random.normal(key, (p,), dtype))
    v = v_full[idx]
    v = matvec(v) - v           # line 2: exactly zero-mean start
    for k in range(1, num_iters + 1):
        v = matvec(v)
        if k % normalize_every == 0:
            norm = max_consensus(jnp.abs(v))
            v = jnp.where(norm > 0, v / norm, v)
    wv = matvec(v)
    num = max_consensus(jnp.abs(wv))
    den = max_consensus(jnp.abs(v))
    return jnp.where(den > 0, num / den, jnp.zeros_like(den))
