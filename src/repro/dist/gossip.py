"""Pod-level gossip fabric: the paper's optimization applied to pod graphs.

A ``PodFabric`` is the static description of cross-pod consensus for P pods
on a named topology: the Metropolis-Hastings weight matrix W, its spectral
gap, and the paper-optimal two-tap parameters (Theorem 1) for it. The
elastic runtime (``repro.runtime.elastic``) rebuilds a fabric on every graph
edit; the sync-cost model (``benchmarks/sync_cost.py``) reads round counts
off it.

The SPMD execution half (``accel_gossip`` inside shard_map, in-mesh
``distributed_lambda2`` / Algorithm 1) lands with the consensus-training PR;
everything here is host-side numpy and cheap (P is small).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..core import accel, topology, weights
from ..core.accel import Theta

__all__ = ["PodFabric", "make_fabric"]


@dataclasses.dataclass(frozen=True)
class PodFabric:
    """Pod graph + paper-optimal consensus parameters for it."""

    w: np.ndarray            # (P, P) Metropolis-Hastings weights
    topology: str
    theta: Theta
    lambda2: float           # lambda_2(W)
    alpha: float             # alpha* (Theorem 1)
    rho_accel: float         # rho(Phi3[alpha*] - J)
    rho_memoryless: float    # rho(W - J)

    @property
    def num_pods(self) -> int:
        return self.w.shape[0]

    def _rounds(self, rho: float, eps: float) -> int:
        """First R with rho^R <= eps (1 when the graph mixes exactly)."""
        if rho <= 0.0:
            return 1
        if rho >= 1.0:
            raise ValueError(f"non-contracting fabric (rho={rho})")
        return max(1, math.ceil(math.log(eps) / math.log(rho)))

    def rounds_for(self, eps: float) -> int:
        """Accelerated rounds to reach relative consensus error eps."""
        return self._rounds(self.rho_accel, eps)

    def rounds_for_memoryless(self, eps: float) -> int:
        """Memoryless x(t+1) = W x(t) rounds for the same eps."""
        return self._rounds(self.rho_memoryless, eps)


def _pod_graph(p: int, kind: str) -> topology.Graph:
    if p < 1:
        raise ValueError("need at least one pod")
    if p == 1:
        return topology.Graph(adjacency=np.zeros((1, 1)), name=kind)
    if p == 2:
        return topology.chain(2)
    if kind == "ring":
        return topology.ring(p)
    if kind == "chain":
        return topology.chain(p)
    if kind == "torus":
        side = int(round(math.sqrt(p)))
        if side * side != p:
            raise ValueError(f"torus fabric needs a square pod count, got {p}")
        return topology.torus2d(side)
    raise ValueError(f"unknown fabric topology {kind!r}")


def make_fabric(p: int, kind: str = "ring", theta: Theta | None = None) -> PodFabric:
    """Build the fabric for ``p`` pods: W, lambda_2, alpha*, rho*.

    Dense O(P^3) eigensolve — P is the pod count (tens), not the node count.
    """
    theta = theta or accel.theta_asymptotic(0.5)
    g = _pod_graph(p, kind)
    if p == 1:
        w = np.ones((1, 1))
        return PodFabric(w=w, topology=kind, theta=theta, lambda2=0.0,
                         alpha=0.0, rho_accel=0.0, rho_memoryless=0.0)
    w = weights.metropolis_hastings(g)
    vals = np.linalg.eigvalsh(w)
    if abs(vals[0]) > vals[-2]:
        # Theorem 1 needs |lambda_P| <= lambda_2; the lazy map guarantees it.
        w = weights.lazy(w)
        vals = np.linalg.eigvalsh(w)
    lam2 = float(vals[-2])
    rho_mem = float(max(abs(vals[0]), abs(lam2)))
    if lam2 <= 0.0:
        # complete-graph-like mixing: one round is exact, nothing to optimize
        return PodFabric(w=w, topology=kind, theta=theta, lambda2=max(lam2, 0.0),
                         alpha=0.0, rho_accel=0.0, rho_memoryless=rho_mem)
    a_star = accel.alpha_star(lam2, theta)
    return PodFabric(
        w=w, topology=kind, theta=theta, lambda2=lam2, alpha=a_star,
        rho_accel=accel.rho_accel(lam2, theta), rho_memoryless=rho_mem,
    )
