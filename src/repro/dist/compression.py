"""Gossip wire compression with error feedback.

A consensus round exchanges full gradient buckets between pods over DCN; the
wire formats here cut that traffic 2-4x. Both wires follow the standard
error-feedback contract (Seide et al. / EF-SGD): ``encode_decode(x, err)``
quantizes ``x + err`` (the signal plus the residual the wire failed to send
last round), returns the dequantized payload the receiver will see, and the
new residual. Accumulated payloads are therefore unbiased for the true
signal: ``sum_t payload_t = T x + err_0 - err_T``.

Everything is shape-polymorphic and jit-safe (no python branching on data);
the wires are stateless — the caller carries ``err`` in its scan/loop state.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["BF16Wire", "Int8Wire"]


class BF16Wire:
    """Truncate mantissas to bfloat16 on the wire (2x traffic cut).

    bf16 keeps fp32's exponent range, so the residual is pure mantissa
    rounding — tiny, but still tracked for exactness of the EF contract.
    """

    bits_per_value = 16

    def encode_decode(self, x: jnp.ndarray, err: jnp.ndarray):
        target = x + err
        payload = target.astype(jnp.bfloat16).astype(x.dtype)
        return payload, target - payload


class Int8Wire:
    """Symmetric per-bucket int8 quantization (4x traffic cut).

    Scale = max|x + err| / 127, so the quantization error per element is at
    most half a step. The max-abs reduction is per call (per bucket), which
    matches how the fabric shards gradients into buckets.
    """

    bits_per_value = 8

    def __init__(self, levels: int = 127):
        self.levels = levels

    def encode_decode(self, x: jnp.ndarray, err: jnp.ndarray):
        target = x + err
        scale = jnp.max(jnp.abs(target)) / self.levels
        # all-zero bucket: keep scale finite, payload exactly zero
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(target / safe), -self.levels, self.levels)
        payload = (q * safe).astype(x.dtype)
        return payload, target - payload
