"""Logical-axis -> mesh-axis sharding rules.

One rule table maps the model layer's logical axis vocabulary ('batch',
'vocab', 'embed', 'heads', ...) onto mesh axes, with two hard guarantees:

  * a dimension is sharded only if the mesh axis (or axis product) divides it
    exactly — non-divisible dims are replicated, never unevenly sharded;
  * each mesh axis is consumed at most once per array, assigned in logical
    priority order (TP consumers like 'heads'/'kv_heads' outrank the
    'cache_seq' fallback, so a KV cache gives 'model' to the head dim when it
    divides and falls back to flash-decode-style sequence sharding when not).

``partition_spec`` is pure logic over shapes (works on ``AbstractMesh``, no
devices needed); the ``abstract_*`` helpers attach ``NamedSharding`` to
ShapeDtypeStructs for the dry-run/compile-only paths.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.common import Activations, ParamSpec

PyTree = Any

__all__ = [
    "TRAIN_RULES",
    "partition_spec",
    "serving_rules",
    "abstract_params",
    "abstract_tree",
    "batch_pspecs",
    "make_activations",
]

# logical axis -> (priority, candidate mesh axes). Candidates are tried in
# order; a tuple candidate means the product of those axes shards the dim.
# Lower priority number = assigned earlier (wins contended mesh axes).
TRAIN_RULES: dict[str, tuple[int, tuple]] = {
    "pod":       (0, ("pod",)),
    "batch":     (0, (("pod", "data"), "data")),
    "vocab":     (0, ("model",)),
    "heads":     (0, ("model",)),
    "kv_heads":  (0, ("model",)),
    "mlp":       (0, ("model",)),
    "expert":    (0, ("model",)),
    "ssm_heads": (0, ("model",)),
    "ssm_inner": (0, ("model",)),
    "embed":     (1, ("data",)),          # FSDP: shard the embed dim over DP
    "cache_seq": (2, ("model", "data")),  # fallback when TP found no taker
}


def serving_rules() -> dict[str, tuple[int, tuple]]:
    """Pure-TP layout for serving: params replicated over 'data', TP dims on
    'model' (no FSDP gather in the decode loop)."""
    rules = dict(TRAIN_RULES)
    rules["embed"] = (1, ())
    return rules


def partition_spec(
    shape: tuple[int, ...],
    axes: tuple[str | None, ...],
    mesh,
    rules: dict[str, tuple[int, tuple]] | None = None,
) -> P:
    """Best valid PartitionSpec for an array with the given logical axes."""
    if len(shape) != len(axes):
        raise ValueError(f"shape {shape} vs axes {axes} rank mismatch")
    rules = rules if rules is not None else TRAIN_RULES
    sizes = dict(mesh.shape)
    taken: set[str] = set()
    assigned: dict[int, str | tuple[str, ...]] = {}
    order = sorted(
        (i for i, name in enumerate(axes) if name in rules),
        key=lambda i: (rules[axes[i]][0], i),
    )
    for i in order:
        for cand in rules[axes[i]][1]:
            names = cand if isinstance(cand, tuple) else (cand,)
            if any(n not in sizes or n in taken for n in names):
                continue
            total = 1
            for n in names:
                total *= sizes[n]
            if shape[i] % total:
                continue
            assigned[i] = cand
            taken.update(names)
            break
    # trailing replicated dims are dropped (P("data", None) == P("data"))
    last = max(assigned) if assigned else -1
    return P(*(assigned.get(i) for i in range(last + 1)))


def abstract_params(
    specs: PyTree, mesh, dtype=None, rules=None, stacked_pods: int = 0
) -> PyTree:
    """ParamSpec tree -> ShapeDtypeStructs with production NamedShardings.

    ``stacked_pods > 0`` prepends a (P, ...) per-pod replica axis sharded over
    'pod' — the decentralized-sync layout of ``make_train_step``.
    """

    def conv(tree):
        if isinstance(tree, ParamSpec):
            shape, axes = tree.shape, tree.axes
            if stacked_pods:
                shape, axes = (stacked_pods, *shape), ("pod", *axes)
            return jax.ShapeDtypeStruct(
                shape,
                dtype if dtype is not None else tree.dtype,
                sharding=NamedSharding(
                    mesh, partition_spec(shape, axes, mesh, rules)
                ),
            )
        return {k: conv(v) for k, v in tree.items()}

    return conv(specs)


def abstract_tree(tree: PyTree, mesh, rules=None) -> PyTree:
    """(shape, axes, dtype) tree -> sharded ShapeDtypeStructs."""

    def conv(node):
        if isinstance(node, tuple) and len(node) == 3:
            shape, axes, dtype = node
            return jax.ShapeDtypeStruct(
                shape, dtype,
                sharding=NamedSharding(
                    mesh, partition_spec(shape, axes, mesh, rules)
                ),
            )
        return {k: conv(v) for k, v in node.items()}

    return conv(tree)


def batch_pspecs(tree: PyTree, mesh, rules=None) -> PyTree:
    """(shape, axes, dtype) tree -> matching tree of PartitionSpecs."""

    def conv(node):
        if isinstance(node, tuple) and len(node) == 3:
            shape, axes, _ = node
            return partition_spec(shape, axes, mesh, rules)
        return {k: conv(v) for k, v in node.items()}

    return conv(tree)


# activation kind -> logical axes per rank (None entries replicate)
_ACT_AXES: dict[str, dict[int, tuple]] = {
    "embed":       {3: ("batch", None, None)},
    "residual":    {3: ("batch", None, None)},
    "logits":      {3: ("batch", None, "vocab")},
    "kv_expanded": {4: ("batch", "cache_seq", "kv_heads", None)},
    "moe_tokens":  {2: ("batch", None), 3: ("batch", None, None)},
    "moe_buf":     {3: ("expert", None, None), 4: ("expert", None, None, None)},
    "moe_buf_dp":  {3: (None, "batch", None), 4: (None, "batch", None, None)},
}


def make_activations(mesh, include_pod: bool = False, kv_spec: P | None = None,
                     rules=None) -> Activations:
    """Activation-sharding constraints for the model forward passes.

    ``include_pod`` lets the batch dim absorb the 'pod' axis (decentralized
    replicas share no batch, so activations shard over pod x data); when the
    mesh has no 'pod' axis the rule falls through to plain 'data'.
    ``kv_spec`` pins the expanded K/V blocks to the cache storage layout.
    """
    rules = dict(rules if rules is not None else TRAIN_RULES)
    if not include_pod or "pod" not in dict(mesh.shape):
        rules["batch"] = (0, ("data",))

    def constrain(x, kind: str):
        if kind == "kv_expanded" and kv_spec is not None:
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, kv_spec))
        axes = _ACT_AXES.get(kind, {}).get(jnp.ndim(x))
        if axes is None:
            return x
        spec = partition_spec(jnp.shape(x), axes, mesh, rules)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return Activations(constrain=constrain)
