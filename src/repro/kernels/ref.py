"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` implements the exact same math as its kernel with plain jnp
ops — no tiling, no pallas. Tests sweep shapes/dtypes and assert_allclose
kernel-vs-oracle; the simulator/model layers can also run directly on these
for debugging (``backend="jax"``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "consensus_update_ref",
    "gossip_matvec_ref",
    "gossip_round_ref",
    "gossip_round_batched_ref",
    "gossip_round_masked_ref",
    "gossip_round_masked_batched_ref",
    "ssd_chunk_ref",
    "ssd_scan_ref",
]


def consensus_update_ref(xw, x, xp, a, b, c):
    """y = a*xw + b*x + c*xp (elementwise, any shape)."""
    return a * xw + b * x + c * xp


def gossip_matvec_ref(w, x):
    """Y = W @ X in fp32 accumulation."""
    return jnp.dot(
        w.astype(jnp.float32), x.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def gossip_round_ref(w, x, xp, a, b, c):
    """One fused accelerated round: y = a*(W@X) + b*X + c*Xp, fp32."""
    x32 = x.astype(jnp.float32)
    return (
        a * gossip_matvec_ref(w, x32)
        + b * x32
        + c * xp.astype(jnp.float32)
    )


def gossip_round_batched_ref(ws, xs, xps, coefs):
    """Ensemble round: Ws (G,N,N), Xs/Xps (G,N,F), coefs (G,3) -> (G,N,F)."""
    xw = jnp.einsum(
        "gij,gjf->gif", ws.astype(jnp.float32), xs.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    a = coefs[:, 0, None, None]
    b = coefs[:, 1, None, None]
    c = coefs[:, 2, None, None]
    return a * xw + b * xs.astype(jnp.float32) + c * xps.astype(jnp.float32)


def gossip_round_masked_ref(w, m, x, xp, a, b, c):
    """Masked fused round: W_eff = W.*M + diag((W.*(1-M))@1), then the FMA.

    ``m`` is a 0/1 edge-activity mask with ones on the diagonal; dropped
    weight returns to the diagonal (mass-preserving re-weighting, see
    ``repro.core.dynamics``).
    """
    w32 = w.astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    wm = w32 * m.astype(jnp.float32)
    drop = jnp.sum(w32 - wm, axis=1, keepdims=True)
    xw = gossip_matvec_ref(wm, x32) + drop * x32
    return a * xw + b * x32 + c * xp.astype(jnp.float32)


def gossip_round_masked_batched_ref(ws, ms, xs, xps, coefs):
    """Ensemble masked round: Ws/Ms (G,N,N), Xs/Xps (G,N,F), coefs (G,3)."""
    ws32 = ws.astype(jnp.float32)
    xs32 = xs.astype(jnp.float32)
    wm = ws32 * ms.astype(jnp.float32)
    drop = jnp.sum(ws32 - wm, axis=2, keepdims=True)          # (G, N, 1)
    xw = jnp.einsum(
        "gij,gjf->gif", wm, xs32, preferred_element_type=jnp.float32
    ) + drop * xs32
    a = coefs[:, 0, None, None]
    b = coefs[:, 1, None, None]
    c = coefs[:, 2, None, None]
    return a * xw + b * xs32 + c * xps.astype(jnp.float32)


def ssd_chunk_ref(x, a, b, c):
    """Intra-chunk SSD oracle (grouped B/C, no head broadcast).

    x (N, H, L, dh), a (N, H, 1, L), b (N, G, L, ds), c (N, G, L, ds) ->
    (y (N,H,L,dh), state (N,H,ds,dh), din (N,H,1,L), dout (N,H,1,1)).
    Heads are processed in G groups of H/G; all einsums keep the group dim
    factored so no (N,H,L,ds) broadcast is ever materialized.
    """
    n, h, l, dh = x.shape
    g = b.shape[1]
    ds = b.shape[-1]
    hg = h // g
    a2 = a[:, :, 0, :].astype(jnp.float32)            # (N, H, L)
    cums = jnp.cumsum(a2, axis=-1)
    diff = cums[..., :, None] - cums[..., None, :]    # (N, H, L, L)
    causal = jnp.tril(jnp.ones((l, l), dtype=bool))
    decay = jnp.where(causal, jnp.exp(jnp.where(causal, diff, 0.0)), 0.0)

    xg = x.astype(jnp.float32).reshape(n, g, hg, l, dh)
    decg = decay.reshape(n, g, hg, l, l)
    base = jnp.einsum("ngls,ngms->nglm", c.astype(jnp.float32), b.astype(jnp.float32))
    scores = base[:, :, None] * decg                  # (N, G, Hg, L, L)
    y = jnp.einsum("nghlm,nghmd->nghld", scores, xg).reshape(n, h, l, dh)

    dlast = cums[..., -1]                             # (N, H)
    w_state = jnp.exp(dlast[..., None] - cums)        # (N, H, L)
    wg = w_state.reshape(n, g, hg, l)
    state = jnp.einsum(
        "ngls,nghl,nghld->nghsd", b.astype(jnp.float32), wg, xg
    ).reshape(n, h, ds, dh)
    din = jnp.exp(cums)[:, :, None, :]                # (N, H, 1, L)
    dout = jnp.exp(dlast)[:, :, None, None]           # (N, H, 1, 1)
    return y, state, din, dout


def ssd_scan_ref(x, a, b, c, h0=None):
    """Full-sequence SSD oracle via the naive per-step recurrence.

    x (B, T, H, dh), a (B, T, H), b (B, T, H, ds), c (B, T, H, ds).
    h_t = exp(a_t) h_{t-1} + b_t (x) x_t ;   y_t = c_t . h_t
    Returns (y (B,T,H,dh), h_final (B,H,ds,dh)).
    """
    bsz, t, h, dh = x.shape
    ds = b.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((bsz, h, ds, dh), dtype=jnp.float32)

    def step(hprev, inp):
        xt, at, bt, ct = inp       # (B,H,dh), (B,H), (B,H,ds), (B,H,ds)
        hnew = jnp.exp(at)[..., None, None] * hprev + jnp.einsum(
            "bhs,bhd->bhsd", bt, xt
        )
        yt = jnp.einsum("bhs,bhsd->bhd", ct, hnew)
        return hnew, yt

    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(a, 1, 0).astype(jnp.float32),
        jnp.moveaxis(b, 1, 0).astype(jnp.float32),
        jnp.moveaxis(c, 1, 0).astype(jnp.float32),
    )
    h_fin, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_fin
