"""Pallas TPU kernel: fused two-tap consensus update (Eq. 4a-4c, combined form).

    y = a * x_w + b * x + c * x_prev

with a = 1 - alpha + alpha*theta3, b = alpha*theta2, c = alpha*theta1.

This is the elementwise half of one accelerated gossip round applied to a
gradient bucket (x_w is the neighbour-weighted sum produced by the
ppermute/matvec half). It is purely bandwidth-bound: the fused kernel does
3 reads + 1 write per element; composing three separate HBM-level ops would
do 6 reads + 3 writes (each binary op reads 2 writes 1). On a v5e
(819 GB/s HBM) that is the difference between ~2.0 GB and ~4.5 GB of traffic
per 512 MB bucket per round.

TPU tiling: the flat buffer is viewed as (rows, 1024) — 1024 = 8 sublanes x
128 lanes = one fp32 VREG tile — and blocked (block_rows, 1024) into VMEM.
Coefficients arrive as a (1, 3) array broadcast to every block (they are
traced values: alpha comes from lambda_2(W), which may itself be computed
inside the program by distributed DOI).
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

__all__ = ["consensus_update_kernel", "consensus_update_pallas", "LANES"]

LANES = 1024  # 8 sublanes x 128 lanes: one fp32 register tile per row


def consensus_update_kernel(coef_ref, xw_ref, x_ref, xp_ref, y_ref):
    """y = coef[0]*xw + coef[1]*x + coef[2]*xp on one (block_rows, LANES) tile."""
    a = coef_ref[0, 0]
    b = coef_ref[0, 1]
    c = coef_ref[0, 2]
    y_ref[...] = a * xw_ref[...] + b * x_ref[...] + c * xp_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def consensus_update_pallas(
    xw: jax.Array,
    x: jax.Array,
    xp: jax.Array,
    coef: jax.Array,
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Fused update over (rows, LANES)-shaped operands.

    ``coef`` is a (1, 3) array [a, b, c]. Shape/padding management lives in
    ``repro.kernels.ops.consensus_update`` — this wrapper requires operands
    already tiled to (rows, LANES) with rows % block_rows == 0.
    """
    rows, lanes = xw.shape
    if lanes != LANES:
        raise ValueError(f"expected trailing dim {LANES}, got {lanes}")
    if rows % block_rows:
        raise ValueError(f"rows={rows} not a multiple of block_rows={block_rows}")
    grid = (rows // block_rows,)
    blk = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    coef_spec = pl.BlockSpec((1, 3), lambda i: (0, 0))
    return pl.pallas_call(
        consensus_update_kernel,
        grid=grid,
        in_specs=[coef_spec, blk, blk, blk],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), xw.dtype),
        interpret=interpret,
    )(coef, xw, x, xp)
