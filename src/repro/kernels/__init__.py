"""Pallas TPU kernels for the perf-critical compute layers.

* ``consensus_update`` — fused two-tap accelerated-gossip update (Eq. 4a-4c),
  the bandwidth-bound elementwise half of a gossip round over gradient buckets.
* ``gossip_matvec``    — blocked W @ X, the paper-scale simulator inner loop.
* ``gossip_round``     — ONE fused accelerated round a*(W@X) + b*X + c*Xp:
  matvec accumulation and the two-tap FMA in a single pallas_call (no x_w HBM
  round-trip), with a batched-grid variant over a (G, N, N) topology ensemble
  that the sweep engine (``repro.sweep``) drives directly.
* ``ssd_chunk``        — Mamba-2 SSD intra-chunk block (MXU-matmul dual form),
  the dominant compute of the ssm/hybrid assigned architectures.

Each kernel ships with a pure-jnp oracle in ``ref.py`` and a jit'd public
wrapper in ``ops.py`` (interpret mode on CPU, compiled VMEM-tiled on TPU).
"""
from . import ops, ref
from .ops import (
    consensus_update,
    gossip_matvec,
    gossip_round,
    gossip_round_batched,
    ssd_scan,
)

__all__ = [
    "ops",
    "ref",
    "consensus_update",
    "gossip_matvec",
    "gossip_round",
    "gossip_round_batched",
    "ssd_scan",
]
