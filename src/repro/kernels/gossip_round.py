"""Pallas TPU kernel: one fully-fused accelerated gossip round.

    Y = a * (W @ X) + b * X + c * Xp

with a = 1 - alpha + alpha*theta3, b = alpha*theta2, c = alpha*theta1
(Eq. 4a-4c in combined form). This fuses the two kernels the simulator
previously chained per iteration — ``gossip_matvec`` (W @ X) and
``consensus_update`` (the two-tap FMA) — into a single ``pallas_call``:
the matvec accumulates in the output VMEM block across the K grid steps,
and on the final K step the FMA taps are applied to the resident block
before writeback. The intermediate x_w = W @ X therefore never round-trips
through HBM: per round this saves one full write + one full read of the
(N, F) state block, on top of the second kernel's launch and its extra
X read — the simulator's inner loop runs thousands of such rounds.

Grid layout (single graph): (N/bm, F/bf, N/bk) with K innermost, exactly as
in ``gossip_matvec`` — the output index map ignores k, so Pallas keeps the
(bm, bf) block resident across the contraction. X is passed twice with two
different index maps: (kk, j) tiles feed the MXU contraction; the (i, j)
tile (k-independent, fetched once) provides the ``b * X`` tap.

Batched variant: a leading G grid axis indexes a (G, N, N) stacked topology
ensemble with per-graph coefficients (G, 3) — one kernel launch evaluates a
full topology x theta x alpha sweep grid. The sweep engine
(``repro.sweep.engine``) drives this directly; blocks carry a leading
length-1 graph dim which is squeezed inside the kernel.

VMEM budget per step at the default 128/128/512 tiles, fp32: out 256 KB +
W 64 KB + three X-shaped tiles 768 KB — comfortably inside ~16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "gossip_round_kernel",
    "gossip_round_pallas",
    "gossip_round_batched_kernel",
    "gossip_round_batched_pallas",
    "gossip_round_masked_kernel",
    "gossip_round_masked_pallas",
    "gossip_round_masked_batched_kernel",
    "gossip_round_masked_batched_pallas",
    "gossip_round_sender_masked_batched_kernel",
    "gossip_round_sender_masked_batched_pallas",
]


def gossip_round_kernel(nk: int, coef_ref, w_ref, xk_ref, xi_ref, xp_ref, y_ref):
    """Accumulate one (bm,bk)@(bk,bf) partial product; FMA on the last K step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += jnp.dot(
        w_ref[...], xk_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _fma():
        a = coef_ref[0, 0]
        b = coef_ref[0, 1]
        c = coef_ref[0, 2]
        y_ref[...] = a * y_ref[...] + b * xi_ref[...] + c * xp_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bf", "interpret"))
def gossip_round_pallas(
    w: jax.Array,
    x: jax.Array,
    xp: jax.Array,
    coef: jax.Array,
    *,
    bm: int = 128,
    bk: int = 128,
    bf: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused Y = coef[0]*(W@X) + coef[1]*X + coef[2]*Xp, operands pre-padded.

    ``coef`` is a (1, 3) traced array [a, b, c] (alpha* may be computed
    in-program from a DOI lambda_2 estimate). Shape management lives in
    ``repro.kernels.ops.gossip_round``.
    """
    n, k = w.shape
    k2, f = x.shape
    if k != k2 or x.shape != xp.shape:
        raise ValueError(f"shape mismatch: W {w.shape}, X {x.shape}, Xp {xp.shape}")
    if n % bm or k % bk or f % bf:
        raise ValueError(f"shapes ({n},{k},{f}) not multiples of tiles ({bm},{bk},{bf})")
    nk = k // bk
    grid = (n // bm, f // bf, nk)
    return pl.pallas_call(
        functools.partial(gossip_round_kernel, nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bf), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bf), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, bf), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, f), jnp.float32),
        interpret=interpret,
    )(coef, w, x, x, xp)


def gossip_round_batched_kernel(nk: int, coef_ref, w_ref, xk_ref, xi_ref, xp_ref, y_ref):
    """Batched-grid body: blocks carry a leading length-1 graph dim."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[0] += jnp.dot(
        w_ref[0], xk_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _fma():
        a = coef_ref[0, 0]
        b = coef_ref[0, 1]
        c = coef_ref[0, 2]
        y_ref[...] = a * y_ref[...] + b * xi_ref[...] + c * xp_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bf", "interpret"))
def gossip_round_batched_pallas(
    ws: jax.Array,
    xs: jax.Array,
    xps: jax.Array,
    coefs: jax.Array,
    *,
    bm: int = 128,
    bk: int = 128,
    bf: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused round over a stacked ensemble: Ws (G,N,N), Xs/Xps (G,N,F), coefs (G,3).

    Grid (G, N/bm, F/bf, N/bk); each graph g reads its own W stack slice and
    (a, b, c) row, so one launch covers the whole sweep grid.
    """
    g, n, k = ws.shape
    g2, k2, f = xs.shape
    if g != g2 or k != k2 or xs.shape != xps.shape or coefs.shape != (g, 3):
        raise ValueError(
            f"shape mismatch: Ws {ws.shape}, Xs {xs.shape}, Xps {xps.shape}, "
            f"coefs {coefs.shape}"
        )
    if n % bm or k % bk or f % bf:
        raise ValueError(f"shapes ({n},{k},{f}) not multiples of tiles ({bm},{bk},{bf})")
    nk = k // bk
    grid = (g, n // bm, f // bf, nk)
    return pl.pallas_call(
        functools.partial(gossip_round_batched_kernel, nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda gg, i, j, kk: (gg, 0)),
            pl.BlockSpec((1, bm, bk), lambda gg, i, j, kk: (gg, i, kk)),
            pl.BlockSpec((1, bk, bf), lambda gg, i, j, kk: (gg, kk, j)),
            pl.BlockSpec((1, bm, bf), lambda gg, i, j, kk: (gg, i, j)),
            pl.BlockSpec((1, bm, bf), lambda gg, i, j, kk: (gg, i, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bf), lambda gg, i, j, kk: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, n, f), jnp.float32),
        interpret=interpret,
    )(coefs, ws, xs, xs, xps)


# ---------------------------------------------------------------------------
# Masked variants: per-round edge-failure masks applied INSIDE the kernel.
#
#     W_eff = W .* M + diag((W .* (1 - M)) @ 1)        (mass-preserving)
#     Y     = a * (W_eff @ X) + b * X + c * Xp
#
# M is the 0/1 edge-activity mask of this round (1 on the diagonal, 1 on live
# edges; see repro.core.dynamics). The kernel never materializes W_eff: each
# K step contracts the elementwise-masked tile W.*M against X on the MXU and
# folds that tile's dropped row mass back onto the node's own state via the
# k-independent (i, j) X tile — so a time-varying topology costs one extra
# VPU multiply and row-sum per tile, and the per-round W matrices never
# round-trip through HBM (the scan carries only the compressed bit masks).
# ---------------------------------------------------------------------------


def gossip_round_masked_kernel(nk: int, coef_ref, w_ref, m_ref, xk_ref, xi_ref,
                               xp_ref, y_ref):
    """Masked matvec + dropped-mass return per K tile; FMA on the last step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    w = w_ref[...]
    wm = w * m_ref[...]
    # dropped mass of this K tile's columns returns to the diagonal: the
    # (bm, 1) row sum of W .* (1 - M) scales the node's own (i, j) X tile,
    # accumulating the diag((W .* (1-M)) @ 1) @ X term across the contraction.
    drop = jnp.sum(w - wm, axis=1, keepdims=True)
    y_ref[...] += (
        jnp.dot(wm, xk_ref[...], preferred_element_type=jnp.float32)
        + drop * xi_ref[...]
    )

    @pl.when(k == nk - 1)
    def _fma():
        a = coef_ref[0, 0]
        b = coef_ref[0, 1]
        c = coef_ref[0, 2]
        y_ref[...] = a * y_ref[...] + b * xi_ref[...] + c * xp_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bf", "interpret"))
def gossip_round_masked_pallas(
    w: jax.Array,
    m: jax.Array,
    x: jax.Array,
    xp: jax.Array,
    coef: jax.Array,
    *,
    bm: int = 128,
    bk: int = 128,
    bf: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused masked round Y = a*(W_eff@X) + b*X + c*Xp, operands pre-padded.

    ``m`` is this round's (N, N) 0/1 activity mask (1 on the diagonal). Pad
    the mask region beyond the real nodes with zeros — padded W entries are
    zero either way. Shape management lives in ``repro.kernels.ops``.
    """
    n, k = w.shape
    k2, f = x.shape
    if k != k2 or x.shape != xp.shape or m.shape != w.shape:
        raise ValueError(
            f"shape mismatch: W {w.shape}, M {m.shape}, X {x.shape}, Xp {xp.shape}"
        )
    if n % bm or k % bk or f % bf:
        raise ValueError(f"shapes ({n},{k},{f}) not multiples of tiles ({bm},{bk},{bf})")
    nk = k // bk
    grid = (n // bm, f // bf, nk)
    return pl.pallas_call(
        functools.partial(gossip_round_masked_kernel, nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bf), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bf), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, bf), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, f), jnp.float32),
        interpret=interpret,
    )(coef, w, m, x, x, xp)


def gossip_round_masked_batched_kernel(nk: int, coef_ref, w_ref, m_ref, xk_ref,
                                       xi_ref, xp_ref, y_ref):
    """Batched-grid masked body: blocks carry a leading length-1 graph dim."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    w = w_ref[0]
    wm = w * m_ref[0]
    drop = jnp.sum(w - wm, axis=1, keepdims=True)
    y_ref[0] += (
        jnp.dot(wm, xk_ref[0], preferred_element_type=jnp.float32)
        + drop * xi_ref[0]
    )

    @pl.when(k == nk - 1)
    def _fma():
        a = coef_ref[0, 0]
        b = coef_ref[0, 1]
        c = coef_ref[0, 2]
        y_ref[...] = a * y_ref[...] + b * xi_ref[...] + c * xp_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bf", "interpret"))
def gossip_round_masked_batched_pallas(
    ws: jax.Array,
    ms: jax.Array,
    xs: jax.Array,
    xps: jax.Array,
    coefs: jax.Array,
    *,
    bm: int = 128,
    bk: int = 128,
    bf: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Masked fused round over a stacked ensemble (the dynamic-sweep inner loop).

    Ws/Ms (G, N, N), Xs/Xps (G, N, F), coefs (G, 3): each graph g reads its
    own W slice, this round's mask slice, and its (a, b, c) row — one launch
    evaluates a whole failure-probability grid's round.
    """
    g, n, k = ws.shape
    g2, k2, f = xs.shape
    if g != g2 or k != k2 or xs.shape != xps.shape or coefs.shape != (g, 3) \
            or ms.shape != ws.shape:
        raise ValueError(
            f"shape mismatch: Ws {ws.shape}, Ms {ms.shape}, Xs {xs.shape}, "
            f"Xps {xps.shape}, coefs {coefs.shape}"
        )
    if n % bm or k % bk or f % bf:
        raise ValueError(f"shapes ({n},{k},{f}) not multiples of tiles ({bm},{bk},{bf})")
    nk = k // bk
    grid = (g, n // bm, f // bf, nk)
    return pl.pallas_call(
        functools.partial(gossip_round_masked_batched_kernel, nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda gg, i, j, kk: (gg, 0)),
            pl.BlockSpec((1, bm, bk), lambda gg, i, j, kk: (gg, i, kk)),
            pl.BlockSpec((1, bm, bk), lambda gg, i, j, kk: (gg, i, kk)),
            pl.BlockSpec((1, bk, bf), lambda gg, i, j, kk: (gg, kk, j)),
            pl.BlockSpec((1, bm, bf), lambda gg, i, j, kk: (gg, i, j)),
            pl.BlockSpec((1, bm, bf), lambda gg, i, j, kk: (gg, i, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bf), lambda gg, i, j, kk: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, n, f), jnp.float32),
        interpret=interpret,
    )(coefs, ws, ms, xs, xs, xps)


# ---------------------------------------------------------------------------
# Sender-renorm masked variant: column-stochastic mass preservation.
#
#     W_eff = W .* M + diag(1' @ (W .* (1 - M)))       (column renorm)
#     Y     = a * (W_eff @ X) + b * X + c * Xp
#
# The push_sum / ratio_consensus family keeps W COLUMN stochastic: node j's
# outgoing mass sums to 1 down column j. A dropped edge's mass must return
# to the SENDER's diagonal — W_eff[j, j] += sum_i W[i, j] * (1 - M[i, j]) —
# or masking silently creates/destroys mass. Per output row i that is a
# COLUMN sum of W .* (1 - M), which a row-tiled kernel cannot form from its
# (i, kk) tile alone: W and M are therefore passed twice, once as the usual
# (bm, bk) contraction tile and once as the transposed-access (bk, bm) tile
# at block index (kk, i), whose axis-0 sum accumulates column i's dropped
# mass across the K grid steps. M is symmetric (per undirected edge, 1 on
# the diagonal), so the same mask array serves both access patterns.
# ---------------------------------------------------------------------------


def gossip_round_sender_masked_batched_kernel(nk: int, coef_ref, w_ref, wt_ref,
                                              m_ref, mt_ref, xk_ref, xi_ref,
                                              xp_ref, y_ref):
    """Masked matvec + sender-side (column) dropped-mass return per K tile."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    wm = w_ref[0] * m_ref[0]
    # this K tile's rows are columns of the (kk, i) transposed-access tile:
    # the (bm,) axis-0 sum of W .* (1 - M) accumulates diag(1' @ (W .* (1-M)))
    # restricted to senders in the current K block.
    dropc = jnp.sum(wt_ref[0] * (1.0 - mt_ref[0]), axis=0)
    y_ref[0] += (
        jnp.dot(wm, xk_ref[0], preferred_element_type=jnp.float32)
        + dropc[:, None] * xi_ref[0]
    )

    @pl.when(k == nk - 1)
    def _fma():
        a = coef_ref[0, 0]
        b = coef_ref[0, 1]
        c = coef_ref[0, 2]
        y_ref[...] = a * y_ref[...] + b * xi_ref[...] + c * xp_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bf", "interpret"))
def gossip_round_sender_masked_batched_pallas(
    ws: jax.Array,
    ms: jax.Array,
    xs: jax.Array,
    xps: jax.Array,
    coefs: jax.Array,
    *,
    bm: int = 128,
    bk: int = 128,
    bf: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Sender-renorm masked fused round over a stacked ensemble.

    Operand contract matches ``gossip_round_masked_batched_pallas`` —
    Ws/Ms (G, N, N), Xs/Xps (G, N, F), coefs (G, 3) — but Ws is column
    stochastic and Ms MUST be symmetric with ones on the diagonal (per
    undirected edge activity, as repro.core.dynamics expands it). Requires
    bm == bk so the transposed-access tile grid lines up.
    """
    g, n, k = ws.shape
    g2, k2, f = xs.shape
    if g != g2 or k != k2 or xs.shape != xps.shape or coefs.shape != (g, 3) \
            or ms.shape != ws.shape:
        raise ValueError(
            f"shape mismatch: Ws {ws.shape}, Ms {ms.shape}, Xs {xs.shape}, "
            f"Xps {xps.shape}, coefs {coefs.shape}"
        )
    if bm != bk:
        raise ValueError(f"sender renorm needs square W tiles, got bm={bm} bk={bk}")
    if n % bm or k % bk or f % bf:
        raise ValueError(f"shapes ({n},{k},{f}) not multiples of tiles ({bm},{bk},{bf})")
    nk = k // bk
    grid = (g, n // bm, f // bf, nk)
    return pl.pallas_call(
        functools.partial(gossip_round_sender_masked_batched_kernel, nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda gg, i, j, kk: (gg, 0)),
            pl.BlockSpec((1, bm, bk), lambda gg, i, j, kk: (gg, i, kk)),
            pl.BlockSpec((1, bk, bm), lambda gg, i, j, kk: (gg, kk, i)),
            pl.BlockSpec((1, bm, bk), lambda gg, i, j, kk: (gg, i, kk)),
            pl.BlockSpec((1, bk, bm), lambda gg, i, j, kk: (gg, kk, i)),
            pl.BlockSpec((1, bk, bf), lambda gg, i, j, kk: (gg, kk, j)),
            pl.BlockSpec((1, bm, bf), lambda gg, i, j, kk: (gg, i, j)),
            pl.BlockSpec((1, bm, bf), lambda gg, i, j, kk: (gg, i, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bf), lambda gg, i, j, kk: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, n, f), jnp.float32),
        interpret=interpret,
    )(coefs, ws, ws, ms, ms, xs, xs, xps)
