"""Pallas segment-reduce kernel: one fused SPARSE gossip round.

    Y = a * (W_eff @ X) + b * X + c * Xp

where W is never materialized: each cell stores an ELLPACK (padded per-row
neighbor list) view of its canonical edge list —

    nbr  (N, D) int32   neighbor node index per row slot
    wgt  (N, D) f32     edge weight per slot (0 on padding slots)
    slot (N, D) int32   undirected-edge id per slot (RoundMasks bits column)
    diag (N, 1) f32     W's diagonal

and one round is a gather + weighted segment reduction:

    y[i] = a * (diag[i] * x[i] + sum_d wgt[i,d] * x[nbr[i,d]]) + b*x[i] + c*xp[i]

Grid layout mirrors ``gossip_round.py``: (N/bm, F/bf, N/bn, D/bd) with the
slot axis D innermost and an optional source-row block axis S = N/bn above
it — the output index map ignores (s, d), so Pallas keeps the (bm, bf) block
resident across the whole reduction, initializing at s == d == 0 and applying
the FMA taps (and the diagonal term) on the final (s, d) step. The masked
variants apply this round's 0/1 edge-activity bits per slot with the
mass-preserving rule: a dropped slot's weight returns to its row's diagonal,
so W_eff stays doubly stochastic (identical semantics to the dense masked
kernel; the per-cell bits row is gathered through ``slot``). The sender
variant returns dropped mass to the *sender's* diagonal instead (column
renormalization), which needs the reverse weight ``wrev[i, d] =
W[nbr[i,d], i]`` of each slot's edge — the column-stochastic family
(push_sum / ratio_consensus) stays exactly column-stochastic under masking.

VMEM policy: the gather targets arbitrary rows of X, so the kernel holds a
(bn, bf) source block resident and masks each slot tile to the rows that
live in the current block (``bn`` defaults to the full padded N — one
resident block, no masking overhead, bitwise identical to the historical
un-tiled kernel). When N * bf * 4 bytes would blow the VMEM cap, callers
pass bn < N and the kernel sweeps S = N/bn source blocks per output tile:
per-slot selection ``bn <= nbr < bn + bn`` zeroes out-of-block weights, so
each slot contributes exactly once across the S sweep. See
``repro.kernels.ops.segment_bn`` for the budget policy
(REPRO_SEGMENT_VMEM_BUDGET).

Padding invariants (``repro.kernels.ops`` pads): padded row slots carry
wgt = 0 *and* wrev = 0 (inert in both the reduction and the dropped-mass
sums, whatever nbr/slot say), padded rows carry diag = 0 and x = 0, padded
bits columns are never referenced by a real slot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "segment_round_kernel",
    "segment_round_pallas",
    "segment_round_batched_kernel",
    "segment_round_batched_pallas",
    "segment_round_masked_kernel",
    "segment_round_masked_pallas",
    "segment_round_masked_batched_kernel",
    "segment_round_masked_batched_pallas",
    "segment_round_sender_masked_batched_kernel",
    "segment_round_sender_masked_batched_pallas",
]


def _gather_rows(xf, nbr):
    """(bn, bf) x block, (bm, bd) local indices -> (bm, bd, bf) gathered rows."""
    bm, bd = nbr.shape
    return jnp.take(xf, nbr.reshape(-1), axis=0).reshape(bm, bd, -1)


def _block_select(nbr, s, bn):
    """0/1 mask of slots whose neighbor lives in source block s, + local ids."""
    base = s * bn
    sel = ((nbr >= base) & (nbr < base + bn)).astype(jnp.float32)
    local = jnp.clip(nbr - base, 0, bn - 1)
    return sel, local


def _check_tiles(n, dmax, f, bm, bd, bf, bn):
    if n % bm or dmax % bd or f % bf or n % bn:
        raise ValueError(
            f"shapes ({n},{dmax},{f}) not multiples of tiles ({bm},{bd},{bf},{bn})")


def segment_round_kernel(ns: int, nd: int, bn: int, coef_ref, nbr_ref, wgt_ref,
                         diag_ref, xf_ref, xi_ref, xp_ref, y_ref):
    """Accumulate one bd-slot gather partial; diagonal + FMA on the last step."""
    s = pl.program_id(2)
    d = pl.program_id(3)

    @pl.when((s == 0) & (d == 0))
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    nbr = nbr_ref[...]
    sel, local = _block_select(nbr, s, bn)
    gathered = _gather_rows(xf_ref[...], local)
    y_ref[...] += jnp.sum((wgt_ref[...] * sel)[..., None] * gathered, axis=1)

    @pl.when((s == ns - 1) & (d == nd - 1))
    def _fma():
        a = coef_ref[0, 0]
        b = coef_ref[0, 1]
        c = coef_ref[0, 2]
        xi = xi_ref[...]
        y_ref[...] = a * (y_ref[...] + diag_ref[...] * xi) + b * xi + c * xp_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bd", "bf", "bn", "interpret"))
def segment_round_pallas(
    nbr: jax.Array,
    wgt: jax.Array,
    diag: jax.Array,
    x: jax.Array,
    xp: jax.Array,
    coef: jax.Array,
    *,
    bm: int = 128,
    bd: int = 8,
    bf: int = 128,
    bn: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused sparse Y = a*(W@X) + b*X + c*Xp, operands pre-padded.

    nbr/wgt (N, D), diag (N, 1), X/Xp (N, F), coef (1, 3) traced. ``bn``
    (default: full N) tiles the resident X source block over N for the VMEM
    cap. Shape management lives in ``repro.kernels.ops.segment_round``.
    """
    n, dmax = nbr.shape
    n2, f = x.shape
    if n != n2 or x.shape != xp.shape or wgt.shape != nbr.shape \
            or diag.shape != (n, 1):
        raise ValueError(f"shape mismatch: nbr {nbr.shape}, wgt {wgt.shape}, "
                         f"diag {diag.shape}, X {x.shape}, Xp {xp.shape}")
    bn = n if bn is None else bn
    _check_tiles(n, dmax, f, bm, bd, bf, bn)
    ns, nd = n // bn, dmax // bd
    grid = (n // bm, f // bf, ns, nd)
    return pl.pallas_call(
        functools.partial(segment_round_kernel, ns, nd, bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda i, j, s, d: (0, 0)),
            pl.BlockSpec((bm, bd), lambda i, j, s, d: (i, d)),
            pl.BlockSpec((bm, bd), lambda i, j, s, d: (i, d)),
            pl.BlockSpec((bm, 1), lambda i, j, s, d: (i, 0)),
            pl.BlockSpec((bn, bf), lambda i, j, s, d: (s, j)),
            pl.BlockSpec((bm, bf), lambda i, j, s, d: (i, j)),
            pl.BlockSpec((bm, bf), lambda i, j, s, d: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j, s, d: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, f), jnp.float32),
        interpret=interpret,
    )(coef, nbr, wgt, diag, x, x, xp)


def segment_round_batched_kernel(ns: int, nd: int, bn: int, coef_ref, nbr_ref,
                                 wgt_ref, diag_ref, xf_ref, xi_ref, xp_ref,
                                 y_ref):
    """Batched-grid body: blocks carry a leading length-1 graph dim."""
    s = pl.program_id(3)
    d = pl.program_id(4)

    @pl.when((s == 0) & (d == 0))
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    nbr = nbr_ref[0]
    sel, local = _block_select(nbr, s, bn)
    gathered = _gather_rows(xf_ref[0], local)
    y_ref[0] += jnp.sum((wgt_ref[0] * sel)[..., None] * gathered, axis=1)

    @pl.when((s == ns - 1) & (d == nd - 1))
    def _fma():
        a = coef_ref[0, 0]
        b = coef_ref[0, 1]
        c = coef_ref[0, 2]
        xi = xi_ref[...]
        y_ref[...] = a * (y_ref[...] + diag_ref[...] * xi) + b * xi + c * xp_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bd", "bf", "bn", "interpret"))
def segment_round_batched_pallas(
    nbrs: jax.Array,
    wgts: jax.Array,
    diags: jax.Array,
    xs: jax.Array,
    xps: jax.Array,
    coefs: jax.Array,
    *,
    bm: int = 128,
    bd: int = 8,
    bf: int = 128,
    bn: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused sparse round over a stacked ensemble.

    nbrs/wgts (G, N, D), diags (G, N, 1), Xs/Xps (G, N, F), coefs (G, 3):
    grid (G, N/bm, F/bf, N/bn, D/bd), each graph g reads its own ELL slices
    and (a, b, c) row — one launch covers the whole sparse sweep grid.
    """
    g, n, dmax = nbrs.shape
    g2, n2, f = xs.shape
    if g != g2 or n != n2 or xs.shape != xps.shape or coefs.shape != (g, 3) \
            or wgts.shape != nbrs.shape or diags.shape != (g, n, 1):
        raise ValueError(
            f"shape mismatch: nbrs {nbrs.shape}, wgts {wgts.shape}, "
            f"diags {diags.shape}, Xs {xs.shape}, coefs {coefs.shape}")
    bn = n if bn is None else bn
    _check_tiles(n, dmax, f, bm, bd, bf, bn)
    ns, nd = n // bn, dmax // bd
    grid = (g, n // bm, f // bf, ns, nd)
    return pl.pallas_call(
        functools.partial(segment_round_batched_kernel, ns, nd, bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda gg, i, j, s, d: (gg, 0)),
            pl.BlockSpec((1, bm, bd), lambda gg, i, j, s, d: (gg, i, d)),
            pl.BlockSpec((1, bm, bd), lambda gg, i, j, s, d: (gg, i, d)),
            pl.BlockSpec((1, bm, 1), lambda gg, i, j, s, d: (gg, i, 0)),
            pl.BlockSpec((1, bn, bf), lambda gg, i, j, s, d: (gg, s, j)),
            pl.BlockSpec((1, bm, bf), lambda gg, i, j, s, d: (gg, i, j)),
            pl.BlockSpec((1, bm, bf), lambda gg, i, j, s, d: (gg, i, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bf), lambda gg, i, j, s, d: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, n, f), jnp.float32),
        interpret=interpret,
    )(coefs, nbrs, wgts, diags, xs, xs, xps)


# ---------------------------------------------------------------------------
# Masked variants: per-round edge-activity bits applied INSIDE the kernel.
#
#     wt[i, d] = wgt[i, d] * bits[slot[i, d]]          (this round's live edges)
#     drop[i]  = sum_d (wgt[i, d] - wt[i, d])          (mass back to the diagonal)
#     y[i]     = a*( (diag[i]+drop[i])*x[i] + sum_d wt[i,d]*x[nbr[i,d]] )
#                + b*x[i] + c*xp[i]
#
# Exactly the dense masked kernel's mass-preserving rule, evaluated per slot:
# the compressed (G, E) bits row replaces the (G, N, N) mask expansion, so
# the sparse dynamic sweep never materializes a mask matrix at all. Under
# N-tiling the drop term is added on the s == 0 sweep only — every slot's
# dropped mass is counted exactly once.
# ---------------------------------------------------------------------------


def segment_round_masked_kernel(ns: int, nd: int, bn: int, coef_ref, bits_ref,
                                nbr_ref, wgt_ref, slot_ref, diag_ref, xf_ref,
                                xi_ref, xp_ref, y_ref):
    """Masked gather partial + dropped-mass return per slot tile."""
    s = pl.program_id(2)
    d = pl.program_id(3)

    @pl.when((s == 0) & (d == 0))
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    w = wgt_ref[...]
    live = jnp.take(bits_ref[0], slot_ref[...].reshape(-1)).reshape(w.shape)
    wt = w * live
    nbr = nbr_ref[...]
    sel, local = _block_select(nbr, s, bn)
    gathered = _gather_rows(xf_ref[...], local)
    contrib = jnp.sum((wt * sel)[..., None] * gathered, axis=1)

    @pl.when(s == 0)
    def _with_drop():
        drop = jnp.sum(w - wt, axis=1, keepdims=True)
        y_ref[...] += contrib + drop * xi_ref[...]

    @pl.when(s > 0)
    def _partial():
        y_ref[...] += contrib

    @pl.when((s == ns - 1) & (d == nd - 1))
    def _fma():
        a = coef_ref[0, 0]
        b = coef_ref[0, 1]
        c = coef_ref[0, 2]
        xi = xi_ref[...]
        y_ref[...] = a * (y_ref[...] + diag_ref[...] * xi) + b * xi + c * xp_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bd", "bf", "bn", "interpret"))
def segment_round_masked_pallas(
    nbr: jax.Array,
    wgt: jax.Array,
    slot: jax.Array,
    diag: jax.Array,
    bits: jax.Array,
    x: jax.Array,
    xp: jax.Array,
    coef: jax.Array,
    *,
    bm: int = 128,
    bd: int = 8,
    bf: int = 128,
    bn: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused masked sparse round, operands pre-padded.

    ``bits`` is this round's (1, E) 0/1 edge-activity row (E = the padded
    undirected edge count ``slot`` indexes into).
    """
    n, dmax = nbr.shape
    n2, f = x.shape
    if n != n2 or x.shape != xp.shape or wgt.shape != nbr.shape \
            or slot.shape != nbr.shape or diag.shape != (n, 1) \
            or bits.ndim != 2 or bits.shape[0] != 1:
        raise ValueError(f"shape mismatch: nbr {nbr.shape}, wgt {wgt.shape}, "
                         f"slot {slot.shape}, diag {diag.shape}, "
                         f"bits {bits.shape}, X {x.shape}, Xp {xp.shape}")
    bn = n if bn is None else bn
    _check_tiles(n, dmax, f, bm, bd, bf, bn)
    ns, nd = n // bn, dmax // bd
    e = bits.shape[1]
    grid = (n // bm, f // bf, ns, nd)
    return pl.pallas_call(
        functools.partial(segment_round_masked_kernel, ns, nd, bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda i, j, s, d: (0, 0)),
            pl.BlockSpec((1, e), lambda i, j, s, d: (0, 0)),
            pl.BlockSpec((bm, bd), lambda i, j, s, d: (i, d)),
            pl.BlockSpec((bm, bd), lambda i, j, s, d: (i, d)),
            pl.BlockSpec((bm, bd), lambda i, j, s, d: (i, d)),
            pl.BlockSpec((bm, 1), lambda i, j, s, d: (i, 0)),
            pl.BlockSpec((bn, bf), lambda i, j, s, d: (s, j)),
            pl.BlockSpec((bm, bf), lambda i, j, s, d: (i, j)),
            pl.BlockSpec((bm, bf), lambda i, j, s, d: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j, s, d: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, f), jnp.float32),
        interpret=interpret,
    )(coef, bits, nbr, wgt, slot, diag, x, x, xp)


def segment_round_masked_batched_kernel(ns: int, nd: int, bn: int, coef_ref,
                                        bits_ref, nbr_ref, wgt_ref, slot_ref,
                                        diag_ref, xf_ref, xi_ref, xp_ref,
                                        y_ref):
    """Batched-grid masked body: blocks carry a leading length-1 graph dim."""
    s = pl.program_id(3)
    d = pl.program_id(4)

    @pl.when((s == 0) & (d == 0))
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    w = wgt_ref[0]
    live = jnp.take(bits_ref[0], slot_ref[0].reshape(-1)).reshape(w.shape)
    wt = w * live
    nbr = nbr_ref[0]
    sel, local = _block_select(nbr, s, bn)
    gathered = _gather_rows(xf_ref[0], local)
    contrib = jnp.sum((wt * sel)[..., None] * gathered, axis=1)

    @pl.when(s == 0)
    def _with_drop():
        drop = jnp.sum(w - wt, axis=1, keepdims=True)
        y_ref[0] += contrib + drop * xi_ref[0]

    @pl.when(s > 0)
    def _partial():
        y_ref[0] += contrib

    @pl.when((s == ns - 1) & (d == nd - 1))
    def _fma():
        a = coef_ref[0, 0]
        b = coef_ref[0, 1]
        c = coef_ref[0, 2]
        xi = xi_ref[...]
        y_ref[...] = a * (y_ref[...] + diag_ref[...] * xi) + b * xi + c * xp_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bd", "bf", "bn", "interpret"))
def segment_round_masked_batched_pallas(
    nbrs: jax.Array,
    wgts: jax.Array,
    slots: jax.Array,
    diags: jax.Array,
    bits: jax.Array,
    xs: jax.Array,
    xps: jax.Array,
    coefs: jax.Array,
    *,
    bm: int = 128,
    bd: int = 8,
    bf: int = 128,
    bn: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Masked fused sparse round over a stacked ensemble (dynamic sparse sweep).

    nbrs/wgts/slots (G, N, D), diags (G, N, 1), bits (G, E) this round's
    activity rows, Xs/Xps (G, N, F), coefs (G, 3) -> (G, N, F) fp32.
    """
    g, n, dmax = nbrs.shape
    g2, n2, f = xs.shape
    if g != g2 or n != n2 or xs.shape != xps.shape or coefs.shape != (g, 3) \
            or wgts.shape != nbrs.shape or slots.shape != nbrs.shape \
            or diags.shape != (g, n, 1) or bits.shape[0] != g:
        raise ValueError(
            f"shape mismatch: nbrs {nbrs.shape}, wgts {wgts.shape}, "
            f"slots {slots.shape}, diags {diags.shape}, bits {bits.shape}, "
            f"Xs {xs.shape}, coefs {coefs.shape}")
    bn = n if bn is None else bn
    _check_tiles(n, dmax, f, bm, bd, bf, bn)
    ns, nd = n // bn, dmax // bd
    e = bits.shape[1]
    grid = (g, n // bm, f // bf, ns, nd)
    return pl.pallas_call(
        functools.partial(segment_round_masked_batched_kernel, ns, nd, bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda gg, i, j, s, d: (gg, 0)),
            pl.BlockSpec((1, e), lambda gg, i, j, s, d: (gg, 0)),
            pl.BlockSpec((1, bm, bd), lambda gg, i, j, s, d: (gg, i, d)),
            pl.BlockSpec((1, bm, bd), lambda gg, i, j, s, d: (gg, i, d)),
            pl.BlockSpec((1, bm, bd), lambda gg, i, j, s, d: (gg, i, d)),
            pl.BlockSpec((1, bm, 1), lambda gg, i, j, s, d: (gg, i, 0)),
            pl.BlockSpec((1, bn, bf), lambda gg, i, j, s, d: (gg, s, j)),
            pl.BlockSpec((1, bm, bf), lambda gg, i, j, s, d: (gg, i, j)),
            pl.BlockSpec((1, bm, bf), lambda gg, i, j, s, d: (gg, i, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bf), lambda gg, i, j, s, d: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, n, f), jnp.float32),
        interpret=interpret,
    )(coefs, bits, nbrs, wgts, slots, diags, xs, xs, xps)


# ---------------------------------------------------------------------------
# Sender-renorm masked variant: column-stochastic mass preservation.
#
# For the push_sum / ratio_consensus family W is COLUMN stochastic: node j's
# outgoing mass sums to 1 down column j. When edge {i, j} drops this round,
# the mass j would have sent to i must return to j's own diagonal (the sender
# keeps it) — receiver-side renormalization would silently create or destroy
# mass. Per output row i the returned mass is the column sum
#
#     drop[i] = sum_k W[k, i] * (1 - M[k, i])
#             = sum_d wrev[i, d] * (1 - bits[slot[i, d]])
#
# because masks are per undirected edge (bits hit both directions) and
# wrev[i, d] = W[nbr[i,d], i] stores the reverse weight of slot d's edge.
# Everything else matches the receiver-masked kernel.
# ---------------------------------------------------------------------------


def segment_round_sender_masked_batched_kernel(ns: int, nd: int, bn: int,
                                               coef_ref, bits_ref, nbr_ref,
                                               wgt_ref, wrev_ref, slot_ref,
                                               diag_ref, xf_ref, xi_ref,
                                               xp_ref, y_ref):
    """Masked gather partial with sender-side (column) dropped-mass return."""
    s = pl.program_id(3)
    d = pl.program_id(4)

    @pl.when((s == 0) & (d == 0))
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    w = wgt_ref[0]
    live = jnp.take(bits_ref[0], slot_ref[0].reshape(-1)).reshape(w.shape)
    wt = w * live
    nbr = nbr_ref[0]
    sel, local = _block_select(nbr, s, bn)
    gathered = _gather_rows(xf_ref[0], local)
    contrib = jnp.sum((wt * sel)[..., None] * gathered, axis=1)

    @pl.when(s == 0)
    def _with_drop():
        drop = jnp.sum(wrev_ref[0] * (1.0 - live), axis=1, keepdims=True)
        y_ref[0] += contrib + drop * xi_ref[0]

    @pl.when(s > 0)
    def _partial():
        y_ref[0] += contrib

    @pl.when((s == ns - 1) & (d == nd - 1))
    def _fma():
        a = coef_ref[0, 0]
        b = coef_ref[0, 1]
        c = coef_ref[0, 2]
        xi = xi_ref[...]
        y_ref[...] = a * (y_ref[...] + diag_ref[...] * xi) + b * xi + c * xp_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bd", "bf", "bn", "interpret"))
def segment_round_sender_masked_batched_pallas(
    nbrs: jax.Array,
    wgts: jax.Array,
    wrevs: jax.Array,
    slots: jax.Array,
    diags: jax.Array,
    bits: jax.Array,
    xs: jax.Array,
    xps: jax.Array,
    coefs: jax.Array,
    *,
    bm: int = 128,
    bd: int = 8,
    bf: int = 128,
    bn: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Sender-renorm masked sparse round over a stacked ensemble.

    Operands match ``segment_round_masked_batched_pallas`` plus wrevs
    (G, N, D): the reverse weight of each slot's edge, 0 on padding slots.
    Dropped mass returns to the sender's diagonal, keeping W_eff exactly
    column stochastic (push_sum / ratio_consensus dynamic sweeps).
    """
    g, n, dmax = nbrs.shape
    g2, n2, f = xs.shape
    if g != g2 or n != n2 or xs.shape != xps.shape or coefs.shape != (g, 3) \
            or wgts.shape != nbrs.shape or wrevs.shape != nbrs.shape \
            or slots.shape != nbrs.shape or diags.shape != (g, n, 1) \
            or bits.shape[0] != g:
        raise ValueError(
            f"shape mismatch: nbrs {nbrs.shape}, wgts {wgts.shape}, "
            f"wrevs {wrevs.shape}, slots {slots.shape}, diags {diags.shape}, "
            f"bits {bits.shape}, Xs {xs.shape}, coefs {coefs.shape}")
    bn = n if bn is None else bn
    _check_tiles(n, dmax, f, bm, bd, bf, bn)
    ns, nd = n // bn, dmax // bd
    e = bits.shape[1]
    grid = (g, n // bm, f // bf, ns, nd)
    return pl.pallas_call(
        functools.partial(segment_round_sender_masked_batched_kernel, ns, nd, bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda gg, i, j, s, d: (gg, 0)),
            pl.BlockSpec((1, e), lambda gg, i, j, s, d: (gg, 0)),
            pl.BlockSpec((1, bm, bd), lambda gg, i, j, s, d: (gg, i, d)),
            pl.BlockSpec((1, bm, bd), lambda gg, i, j, s, d: (gg, i, d)),
            pl.BlockSpec((1, bm, bd), lambda gg, i, j, s, d: (gg, i, d)),
            pl.BlockSpec((1, bm, bd), lambda gg, i, j, s, d: (gg, i, d)),
            pl.BlockSpec((1, bm, 1), lambda gg, i, j, s, d: (gg, i, 0)),
            pl.BlockSpec((1, bn, bf), lambda gg, i, j, s, d: (gg, s, j)),
            pl.BlockSpec((1, bm, bf), lambda gg, i, j, s, d: (gg, i, j)),
            pl.BlockSpec((1, bm, bf), lambda gg, i, j, s, d: (gg, i, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bf), lambda gg, i, j, s, d: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, n, f), jnp.float32),
        interpret=interpret,
    )(coefs, bits, nbrs, wgts, wrevs, slots, diags, xs, xs, xps)
