"""Pallas segment-reduce kernel: one fused SPARSE gossip round.

    Y = a * (W_eff @ X) + b * X + c * Xp

where W is never materialized: each cell stores an ELLPACK (padded per-row
neighbor list) view of its canonical edge list —

    nbr  (N, D) int32   neighbor node index per row slot
    wgt  (N, D) f32     edge weight per slot (0 on padding slots)
    slot (N, D) int32   undirected-edge id per slot (RoundMasks bits column)
    diag (N, 1) f32     W's diagonal

and one round is a gather + weighted segment reduction:

    y[i] = a * (diag[i] * x[i] + sum_d wgt[i,d] * x[nbr[i,d]]) + b*x[i] + c*xp[i]

Grid layout mirrors ``gossip_round.py`` exactly: (N/bm, F/bf, D/bd) with the
contraction axis (here the neighbor-slot axis D) innermost — the output index
map ignores d, so Pallas keeps the (bm, bf) block resident across the
reduction, initializing at d == 0 and applying the FMA taps (and the
diagonal term) on the final d step. The masked variants apply this round's
0/1 edge-activity bits per slot with the mass-preserving rule: a dropped
slot's weight returns to its row's diagonal, so W_eff stays doubly
stochastic (identical semantics to the dense masked kernel; the per-cell
bits row is gathered through ``slot``).

The full (N, F) state block rides into VMEM once per (i, j) tile — the
gather targets arbitrary rows, so the kernel holds X resident rather than
streaming K tiles. That caps the single-kernel problem size at VMEM
(~N * bf * 4 bytes); the engine uses this kernel as the sparse pallas
correctness/small-N path and routes million-node sweeps through the jnp
``segment_sum`` primitive, which has no such cap (see repro.sweep.engine).

Padding invariants (``repro.kernels.ops`` pads): padded row slots carry
wgt = 0 (inert in both the reduction and the dropped-mass sum, whatever
nbr/slot say), padded rows carry diag = 0 and x = 0, padded bits columns are
never referenced by a real slot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "segment_round_kernel",
    "segment_round_pallas",
    "segment_round_batched_kernel",
    "segment_round_batched_pallas",
    "segment_round_masked_kernel",
    "segment_round_masked_pallas",
    "segment_round_masked_batched_kernel",
    "segment_round_masked_batched_pallas",
]


def _gather_rows(xf, nbr):
    """(Np, bf) x, (bm, bd) indices -> (bm, bd, bf) gathered neighbor states."""
    bm, bd = nbr.shape
    return jnp.take(xf, nbr.reshape(-1), axis=0).reshape(bm, bd, -1)


def segment_round_kernel(nd: int, coef_ref, nbr_ref, wgt_ref, diag_ref,
                         xf_ref, xi_ref, xp_ref, y_ref):
    """Accumulate one bd-slot gather partial; diagonal + FMA on the last step."""
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    gathered = _gather_rows(xf_ref[...], nbr_ref[...])
    y_ref[...] += jnp.sum(wgt_ref[...][..., None] * gathered, axis=1)

    @pl.when(d == nd - 1)
    def _fma():
        a = coef_ref[0, 0]
        b = coef_ref[0, 1]
        c = coef_ref[0, 2]
        xi = xi_ref[...]
        y_ref[...] = a * (y_ref[...] + diag_ref[...] * xi) + b * xi + c * xp_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bd", "bf", "interpret"))
def segment_round_pallas(
    nbr: jax.Array,
    wgt: jax.Array,
    diag: jax.Array,
    x: jax.Array,
    xp: jax.Array,
    coef: jax.Array,
    *,
    bm: int = 128,
    bd: int = 8,
    bf: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fused sparse Y = a*(W@X) + b*X + c*Xp, operands pre-padded.

    nbr/wgt (N, D), diag (N, 1), X/Xp (N, F), coef (1, 3) traced. Shape
    management lives in ``repro.kernels.ops.segment_round``.
    """
    n, dmax = nbr.shape
    n2, f = x.shape
    if n != n2 or x.shape != xp.shape or wgt.shape != nbr.shape \
            or diag.shape != (n, 1):
        raise ValueError(f"shape mismatch: nbr {nbr.shape}, wgt {wgt.shape}, "
                         f"diag {diag.shape}, X {x.shape}, Xp {xp.shape}")
    if n % bm or dmax % bd or f % bf:
        raise ValueError(
            f"shapes ({n},{dmax},{f}) not multiples of tiles ({bm},{bd},{bf})")
    nd = dmax // bd
    grid = (n // bm, f // bf, nd)
    return pl.pallas_call(
        functools.partial(segment_round_kernel, nd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda i, j, d: (0, 0)),
            pl.BlockSpec((bm, bd), lambda i, j, d: (i, d)),
            pl.BlockSpec((bm, bd), lambda i, j, d: (i, d)),
            pl.BlockSpec((bm, 1), lambda i, j, d: (i, 0)),
            pl.BlockSpec((n, bf), lambda i, j, d: (0, j)),
            pl.BlockSpec((bm, bf), lambda i, j, d: (i, j)),
            pl.BlockSpec((bm, bf), lambda i, j, d: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j, d: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, f), jnp.float32),
        interpret=interpret,
    )(coef, nbr, wgt, diag, x, x, xp)


def segment_round_batched_kernel(nd: int, coef_ref, nbr_ref, wgt_ref, diag_ref,
                                 xf_ref, xi_ref, xp_ref, y_ref):
    """Batched-grid body: blocks carry a leading length-1 graph dim."""
    d = pl.program_id(3)

    @pl.when(d == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    gathered = _gather_rows(xf_ref[0], nbr_ref[0])
    y_ref[0] += jnp.sum(wgt_ref[0][..., None] * gathered, axis=1)

    @pl.when(d == nd - 1)
    def _fma():
        a = coef_ref[0, 0]
        b = coef_ref[0, 1]
        c = coef_ref[0, 2]
        xi = xi_ref[...]
        y_ref[...] = a * (y_ref[...] + diag_ref[...] * xi) + b * xi + c * xp_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bd", "bf", "interpret"))
def segment_round_batched_pallas(
    nbrs: jax.Array,
    wgts: jax.Array,
    diags: jax.Array,
    xs: jax.Array,
    xps: jax.Array,
    coefs: jax.Array,
    *,
    bm: int = 128,
    bd: int = 8,
    bf: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fused sparse round over a stacked ensemble.

    nbrs/wgts (G, N, D), diags (G, N, 1), Xs/Xps (G, N, F), coefs (G, 3):
    grid (G, N/bm, F/bf, D/bd), each graph g reads its own ELL slices and
    (a, b, c) row — one launch covers the whole sparse sweep grid.
    """
    g, n, dmax = nbrs.shape
    g2, n2, f = xs.shape
    if g != g2 or n != n2 or xs.shape != xps.shape or coefs.shape != (g, 3) \
            or wgts.shape != nbrs.shape or diags.shape != (g, n, 1):
        raise ValueError(
            f"shape mismatch: nbrs {nbrs.shape}, wgts {wgts.shape}, "
            f"diags {diags.shape}, Xs {xs.shape}, coefs {coefs.shape}")
    if n % bm or dmax % bd or f % bf:
        raise ValueError(
            f"shapes ({n},{dmax},{f}) not multiples of tiles ({bm},{bd},{bf})")
    nd = dmax // bd
    grid = (g, n // bm, f // bf, nd)
    return pl.pallas_call(
        functools.partial(segment_round_batched_kernel, nd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda gg, i, j, d: (gg, 0)),
            pl.BlockSpec((1, bm, bd), lambda gg, i, j, d: (gg, i, d)),
            pl.BlockSpec((1, bm, bd), lambda gg, i, j, d: (gg, i, d)),
            pl.BlockSpec((1, bm, 1), lambda gg, i, j, d: (gg, i, 0)),
            pl.BlockSpec((1, n, bf), lambda gg, i, j, d: (gg, 0, j)),
            pl.BlockSpec((1, bm, bf), lambda gg, i, j, d: (gg, i, j)),
            pl.BlockSpec((1, bm, bf), lambda gg, i, j, d: (gg, i, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bf), lambda gg, i, j, d: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, n, f), jnp.float32),
        interpret=interpret,
    )(coefs, nbrs, wgts, diags, xs, xs, xps)


# ---------------------------------------------------------------------------
# Masked variants: per-round edge-activity bits applied INSIDE the kernel.
#
#     wt[i, d] = wgt[i, d] * bits[slot[i, d]]          (this round's live edges)
#     drop[i]  = sum_d (wgt[i, d] - wt[i, d])          (mass back to the diagonal)
#     y[i]     = a*( (diag[i]+drop[i])*x[i] + sum_d wt[i,d]*x[nbr[i,d]] )
#                + b*x[i] + c*xp[i]
#
# Exactly the dense masked kernel's mass-preserving rule, evaluated per slot:
# the compressed (G, E) bits row replaces the (G, N, N) mask expansion, so
# the sparse dynamic sweep never materializes a mask matrix at all.
# ---------------------------------------------------------------------------


def segment_round_masked_kernel(nd: int, coef_ref, bits_ref, nbr_ref, wgt_ref,
                                slot_ref, diag_ref, xf_ref, xi_ref, xp_ref,
                                y_ref):
    """Masked gather partial + dropped-mass return per slot tile."""
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    w = wgt_ref[...]
    sel = jnp.take(bits_ref[0], slot_ref[...].reshape(-1)).reshape(w.shape)
    wt = w * sel
    drop = jnp.sum(w - wt, axis=1, keepdims=True)
    gathered = _gather_rows(xf_ref[...], nbr_ref[...])
    y_ref[...] += jnp.sum(wt[..., None] * gathered, axis=1) + drop * xi_ref[...]

    @pl.when(d == nd - 1)
    def _fma():
        a = coef_ref[0, 0]
        b = coef_ref[0, 1]
        c = coef_ref[0, 2]
        xi = xi_ref[...]
        y_ref[...] = a * (y_ref[...] + diag_ref[...] * xi) + b * xi + c * xp_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bd", "bf", "interpret"))
def segment_round_masked_pallas(
    nbr: jax.Array,
    wgt: jax.Array,
    slot: jax.Array,
    diag: jax.Array,
    bits: jax.Array,
    x: jax.Array,
    xp: jax.Array,
    coef: jax.Array,
    *,
    bm: int = 128,
    bd: int = 8,
    bf: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fused masked sparse round, operands pre-padded.

    ``bits`` is this round's (1, E) 0/1 edge-activity row (E = the padded
    undirected edge count ``slot`` indexes into).
    """
    n, dmax = nbr.shape
    n2, f = x.shape
    if n != n2 or x.shape != xp.shape or wgt.shape != nbr.shape \
            or slot.shape != nbr.shape or diag.shape != (n, 1) \
            or bits.ndim != 2 or bits.shape[0] != 1:
        raise ValueError(f"shape mismatch: nbr {nbr.shape}, wgt {wgt.shape}, "
                         f"slot {slot.shape}, diag {diag.shape}, "
                         f"bits {bits.shape}, X {x.shape}, Xp {xp.shape}")
    if n % bm or dmax % bd or f % bf:
        raise ValueError(
            f"shapes ({n},{dmax},{f}) not multiples of tiles ({bm},{bd},{bf})")
    nd = dmax // bd
    e = bits.shape[1]
    grid = (n // bm, f // bf, nd)
    return pl.pallas_call(
        functools.partial(segment_round_masked_kernel, nd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda i, j, d: (0, 0)),
            pl.BlockSpec((1, e), lambda i, j, d: (0, 0)),
            pl.BlockSpec((bm, bd), lambda i, j, d: (i, d)),
            pl.BlockSpec((bm, bd), lambda i, j, d: (i, d)),
            pl.BlockSpec((bm, bd), lambda i, j, d: (i, d)),
            pl.BlockSpec((bm, 1), lambda i, j, d: (i, 0)),
            pl.BlockSpec((n, bf), lambda i, j, d: (0, j)),
            pl.BlockSpec((bm, bf), lambda i, j, d: (i, j)),
            pl.BlockSpec((bm, bf), lambda i, j, d: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j, d: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, f), jnp.float32),
        interpret=interpret,
    )(coef, bits, nbr, wgt, slot, diag, x, x, xp)


def segment_round_masked_batched_kernel(nd: int, coef_ref, bits_ref, nbr_ref,
                                        wgt_ref, slot_ref, diag_ref, xf_ref,
                                        xi_ref, xp_ref, y_ref):
    """Batched-grid masked body: blocks carry a leading length-1 graph dim."""
    d = pl.program_id(3)

    @pl.when(d == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    w = wgt_ref[0]
    sel = jnp.take(bits_ref[0], slot_ref[0].reshape(-1)).reshape(w.shape)
    wt = w * sel
    drop = jnp.sum(w - wt, axis=1, keepdims=True)
    gathered = _gather_rows(xf_ref[0], nbr_ref[0])
    y_ref[0] += jnp.sum(wt[..., None] * gathered, axis=1) + drop * xi_ref[0]

    @pl.when(d == nd - 1)
    def _fma():
        a = coef_ref[0, 0]
        b = coef_ref[0, 1]
        c = coef_ref[0, 2]
        xi = xi_ref[...]
        y_ref[...] = a * (y_ref[...] + diag_ref[...] * xi) + b * xi + c * xp_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bd", "bf", "interpret"))
def segment_round_masked_batched_pallas(
    nbrs: jax.Array,
    wgts: jax.Array,
    slots: jax.Array,
    diags: jax.Array,
    bits: jax.Array,
    xs: jax.Array,
    xps: jax.Array,
    coefs: jax.Array,
    *,
    bm: int = 128,
    bd: int = 8,
    bf: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Masked fused sparse round over a stacked ensemble (dynamic sparse sweep).

    nbrs/wgts/slots (G, N, D), diags (G, N, 1), bits (G, E) this round's
    activity rows, Xs/Xps (G, N, F), coefs (G, 3) -> (G, N, F) fp32.
    """
    g, n, dmax = nbrs.shape
    g2, n2, f = xs.shape
    if g != g2 or n != n2 or xs.shape != xps.shape or coefs.shape != (g, 3) \
            or wgts.shape != nbrs.shape or slots.shape != nbrs.shape \
            or diags.shape != (g, n, 1) or bits.shape[0] != g:
        raise ValueError(
            f"shape mismatch: nbrs {nbrs.shape}, wgts {wgts.shape}, "
            f"slots {slots.shape}, diags {diags.shape}, bits {bits.shape}, "
            f"Xs {xs.shape}, coefs {coefs.shape}")
    if n % bm or dmax % bd or f % bf:
        raise ValueError(
            f"shapes ({n},{dmax},{f}) not multiples of tiles ({bm},{bd},{bf})")
    nd = dmax // bd
    e = bits.shape[1]
    grid = (g, n // bm, f // bf, nd)
    return pl.pallas_call(
        functools.partial(segment_round_masked_batched_kernel, nd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda gg, i, j, d: (gg, 0)),
            pl.BlockSpec((1, e), lambda gg, i, j, d: (gg, 0)),
            pl.BlockSpec((1, bm, bd), lambda gg, i, j, d: (gg, i, d)),
            pl.BlockSpec((1, bm, bd), lambda gg, i, j, d: (gg, i, d)),
            pl.BlockSpec((1, bm, bd), lambda gg, i, j, d: (gg, i, d)),
            pl.BlockSpec((1, bm, 1), lambda gg, i, j, d: (gg, i, 0)),
            pl.BlockSpec((1, n, bf), lambda gg, i, j, d: (gg, 0, j)),
            pl.BlockSpec((1, bm, bf), lambda gg, i, j, d: (gg, i, j)),
            pl.BlockSpec((1, bm, bf), lambda gg, i, j, d: (gg, i, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bf), lambda gg, i, j, d: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, n, f), jnp.float32),
        interpret=interpret,
    )(coefs, bits, nbrs, wgts, slots, diags, xs, xs, xps)
