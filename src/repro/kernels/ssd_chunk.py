"""Pallas TPU kernel: Mamba-2 SSD intra-chunk block (state-space duality).

The dominant compute of the ssm/hybrid assigned architectures (mamba2-780m,
zamba2-7b). The SSD decomposition (Dao & Gu, arXiv:2405.21060) splits the
selective-scan into:

  * intra-chunk (this kernel, MXU-friendly dense matmuls):
        cums_i   = sum_{k<=i} a_k                       (per-head log decay)
        L_ij     = exp(cums_i - cums_j)  for i >= j     (causal decay mask)
        Y_intra  = ((C B^T) .* L) X                     (L x L attention-like)
        S_chunk  = (B .* exp(cums_last - cums))^T X     (ds x dh state update)
        d_in_i   = exp(cums_i)                          (carry-in decay)
        d_out    = exp(cums_last)                       (chunk decay)
  * inter-chunk (log-depth associative scan in the ops wrapper):
        H_c      = d_out_c * H_{c-1} + S_chunk_c
        Y_i     += d_in_i * (C_i H_{prev(c)})

Hardware adaptation (DESIGN.md): the original recurrent scan is
sequential/VPU-bound; the chunked dual form turns >90% of the FLOPs into
(L x ds)(ds x dh) and (L x L)(L x dh) matmuls that run on the MXU.

Grid & sharding: 2-D grid (batch*chunks, heads). The batch*chunks axis keeps
the (data-sharded) batch dim MAJOR so GSPMD shards the grid over 'data'; the
head axis shards over 'model'. B/C projections arrive per GROUP
(B (G, L, ds), mamba2 G=1) and are index-mapped to heads inside the grid —
no H-times broadcast is ever materialized in HBM.

VMEM per program (L=256, ds=128, dh=64, fp32): x 64KB + b,c 2x128KB +
scores 256KB + y 64KB + state 32KB < 1 MB. L and ds should be multiples of
128 (lane tile); dh=64 wastes half a lane on X/Y loads — acceptable, the
matmul M/K dims stay 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_chunk_kernel", "ssd_chunk_pallas"]


def ssd_chunk_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_ref, din_ref, dout_ref):
    """One (batch-chunk, head) SSD block. Block shapes (grid dims squeezed):

    x (1, 1, L, dh), a (1, 1, 1, L), b (1, 1, L, ds), c (1, 1, L, ds) ->
    y (1, 1, L, dh), state (1, 1, ds, dh), din (1, 1, 1, L), dout (1, 1, 1, 1).
    """
    x = x_ref[0, 0]
    a = a_ref[0, 0, 0]        # (L,)
    b = b_ref[0, 0]
    c = c_ref[0, 0]
    l = x.shape[0]

    cums = jnp.cumsum(a)                      # (L,)
    diff = cums[:, None] - cums[None, :]      # (L, L)
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    causal = ii >= jj
    decay = jnp.where(causal, jnp.exp(jnp.where(causal, diff, 0.0)), 0.0)

    scores = jnp.dot(c, b.T, preferred_element_type=jnp.float32) * decay
    y_ref[0, 0] = jnp.dot(scores, x, preferred_element_type=jnp.float32)

    dlast = cums[l - 1]
    w_state = jnp.exp(dlast - cums)           # (L,)
    state_ref[0, 0] = jnp.dot(
        (b * w_state[:, None]).T, x, preferred_element_type=jnp.float32
    )
    din_ref[0, 0, 0] = jnp.exp(cums)
    dout_ref[0, 0, 0, 0] = jnp.exp(dlast)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(
    x: jax.Array,   # (N, H, L, dh)   N = batch * nchunks
    a: jax.Array,   # (N, H, 1, L)
    b: jax.Array,   # (N, G, L, ds)   G groups broadcast to H heads in-grid
    c: jax.Array,   # (N, G, L, ds)
    *,
    interpret: bool = False,
):
    """Returns (y (N,H,L,dh), state (N,H,ds,dh), din (N,H,1,L), dout (N,H,1,1))."""
    n, h, l, dh = x.shape
    g = b.shape[1]
    ds = b.shape[-1]
    heads_per_group = h // g
    grid = (n, h)

    def bc_map(i, j):
        return (i, j // heads_per_group, 0, 0)

    return pl.pallas_call(
        ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, l, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, l), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, l, ds), bc_map),
            pl.BlockSpec((1, 1, l, ds), bc_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, l, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, ds, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, l), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, h, l, dh), jnp.float32),
            jax.ShapeDtypeStruct((n, h, ds, dh), jnp.float32),
            jax.ShapeDtypeStruct((n, h, 1, l), jnp.float32),
            jax.ShapeDtypeStruct((n, h, 1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, a, b, c)
