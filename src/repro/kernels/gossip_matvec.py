"""Pallas TPU kernel: blocked W @ X for the consensus simulation engine.

Computes Y = W X with W (N, N) the consensus weight matrix and X (N, F) the
per-node state block (F = trials/features). This is the inner loop of the
paper-scale numerical experiments (Section IV): hundreds of trials x
thousands of iterations, so the matvec dominates simulator runtime.

TPU mapping: classic 3-loop tiling with the K (contraction) dimension as the
innermost grid axis, fp32 accumulation directly in the output VMEM block
(revisited across the K steps — Pallas keeps the block resident because the
output index map is independent of k). Tiles default to 128 x 128 x 512:
(bm, bk) and (bk, bf) input tiles are MXU-aligned (128 = systolic array edge),
and the working set stays comfortably inside the ~16 MB VMEM budget:
128*512*4 = 256 KB out + 128*128*4 + 128*512*4 = 320 KB in per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gossip_matvec_kernel", "gossip_matvec_pallas"]


def gossip_matvec_kernel(w_ref, x_ref, y_ref):
    """One (bm, bk) @ (bk, bf) partial product accumulated into y (bm, bf)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += jnp.dot(
        w_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bf", "interpret")
)
def gossip_matvec_pallas(
    w: jax.Array,
    x: jax.Array,
    *,
    bm: int = 128,
    bk: int = 128,
    bf: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Y = W @ X with fp32 accumulation; operands pre-padded to tile multiples."""
    n, k = w.shape
    k2, f = x.shape
    if k != k2:
        raise ValueError(f"shape mismatch: W {w.shape} @ X {x.shape}")
    if n % bm or k % bk or f % bf:
        raise ValueError(f"shapes ({n},{k},{f}) not multiples of tiles ({bm},{bk},{bf})")
    grid = (n // bm, f // bf, k // bk)
    return pl.pallas_call(
        gossip_matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bf), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, f), jnp.float32),
        interpret=interpret,
    )(w, x)
