"""Block-size autotuner for the Pallas round kernels.

The fused round kernels are tiled by (bm, bk, bf) — dense — or (bm, bd, bf)
— ELLPACK segment — block sizes. Historically those were hard-coded
heuristics; this module turns them into a measured choice with three modes,
selected by ``REPRO_KERNEL_TUNE``:

    off    always return the static heuristic (the pre-autotuner tiles)
    cache  (default) return a cached winner if one exists — in-process
           first, then the JSON cache — else the static heuristic; never
           spends time measuring
    full   on a cache miss, time every candidate with the caller-provided
           bench closure and persist the winner to both caches

Caches
------
In-process: a plain dict keyed by (device_key, problem_key) — one entry per
(kind, G, N, F) problem per process.  On disk: a JSON file keyed by device
kind (``cpu:TFRT_CPU`` / ``tpu:TPU v5e`` …) so a cache written on one
accelerator generation never leaks onto another. Default location
``~/.cache/repro/kernel_tune.json``, overridable via
``REPRO_KERNEL_TUNE_CACHE``. Corrupt or unreadable cache files are treated
as empty, never fatal.

Bit-identicality contract
-------------------------
Candidates vary ONLY the output-parallel tiles bm (rows) and bf (feature
columns). The contraction tiles — bk for the dense matvec, bd for the
segment slot axis — are pinned to the static values, because splitting the
contraction differently reorders the float accumulation and would make the
"winner" numerically different from the static tiles. Varying bm/bf only
repartitions which grid step computes which output block; every candidate
therefore produces bit-identical results (tests/test_autotune.py asserts
this property on both kernel families).

The bench closure is supplied by the caller (``repro.kernels.ops``) so this
module never imports the kernels — it only ranks (tiles -> seconds).
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Callable, Iterable

import jax

__all__ = [
    "static_round_tiles",
    "static_segment_tiles",
    "round_candidates",
    "segment_candidates",
    "get_tiles",
    "device_key",
    "cache_path",
    "clear_memory_cache",
    "time_candidate",
]

_BK = 128   # dense contraction tile: pinned (reduction order = numerics)
_BD = 8     # segment slot-axis tile: pinned for the same reason

# in-process winners: {(device_key, problem_key): (bm, bx, bf)}
_MEM: dict[tuple[str, str], tuple[int, ...]] = {}
# lazily-loaded disk snapshot per cache path, so repeated misses in
# ``cache`` mode do not re-read the file
_DISK: dict[str, dict] = {}


def static_round_tiles(f: int) -> tuple[int, int, int]:
    """The historical dense heuristic: (bm, bk, bf)."""
    return (128, _BK, 512 if f > 256 else 128)


def static_segment_tiles(f: int) -> tuple[int, int, int]:
    """The historical ELLPACK heuristic: (bm, bd, bf)."""
    return (128, _BD, 512 if f > 256 else 128)


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _axis_candidates(dim: int, sizes: Iterable[int], static: int) -> list[int]:
    """Tile sizes no larger than the padded axis, static choice always in."""
    padded = _round_up(max(dim, 1), 128)
    out = [s for s in sizes if s <= padded]
    if static not in out:
        out.append(static)
    return sorted(set(out))


def round_candidates(n: int, f: int) -> list[tuple[int, int, int]]:
    """Bounded dense candidate grid; bk pinned, bm/bf output-parallel only."""
    _, _, sbf = static_round_tiles(f)
    bms = _axis_candidates(n, (128, 256), 128)
    bfs = _axis_candidates(f, (128, 256, 512), sbf)
    return [(bm, _BK, bf) for bm in bms for bf in bfs]


def segment_candidates(n: int, f: int) -> list[tuple[int, int, int]]:
    """Bounded ELLPACK candidate grid; bd pinned, bm/bf output-parallel only."""
    _, _, sbf = static_segment_tiles(f)
    bms = _axis_candidates(n, (128, 256), 128)
    bfs = _axis_candidates(f, (128, 256, 512), sbf)
    return [(bm, _BD, bf) for bm in bms for bf in bfs]


def device_key() -> str:
    """Backend + device kind, the disk-cache namespace."""
    try:
        return f"{jax.default_backend()}:{jax.devices()[0].device_kind}"
    except Exception:  # pragma: no cover - no devices at all
        return "unknown:unknown"


def cache_path() -> Path:
    env = os.environ.get("REPRO_KERNEL_TUNE_CACHE", "").strip()
    if env:
        return Path(env)
    return Path(os.path.expanduser("~")) / ".cache" / "repro" / "kernel_tune.json"


def clear_memory_cache() -> None:
    """Drop in-process winners and the disk snapshot (tests / bench sweeps)."""
    _MEM.clear()
    _DISK.clear()


def _mode() -> str:
    mode = os.environ.get("REPRO_KERNEL_TUNE", "cache").strip().lower() or "cache"
    if mode not in ("off", "cache", "full"):
        raise ValueError(
            f"REPRO_KERNEL_TUNE={mode!r}: expected off, cache, or full")
    return mode


def _problem_key(kind: str, g: int, n: int, f: int) -> str:
    return f"{kind}:g{g}:n{n}:f{f}:f32"


def _disk_load(path: Path) -> dict:
    spath = str(path)
    if spath not in _DISK:
        try:
            with open(path) as fh:
                data = json.load(fh)
            _DISK[spath] = data if isinstance(data, dict) else {}
        except (OSError, ValueError):
            _DISK[spath] = {}
    return _DISK[spath]


def _disk_store(path: Path, dev: str, key: str, tiles: tuple[int, ...]) -> None:
    data = _disk_load(path)
    data.setdefault(dev, {})[key] = list(tiles)
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(".tmp")
        with open(tmp, "w") as fh:
            json.dump(data, fh, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass  # read-only FS: in-process cache still holds the winner


def time_candidate(bench: Callable[[tuple[int, ...]], None],
                   tiles: tuple[int, ...], reps: int = 3) -> float:
    """Best-of-reps wall time of one candidate; one warmup call for compile."""
    bench(tiles)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        bench(tiles)
        best = min(best, time.perf_counter() - t0)
    return best


def get_tiles(
    kind: str,
    n: int,
    f: int,
    g: int = 1,
    bench: Callable[[tuple[int, ...]], None] | None = None,
) -> tuple[int, int, int]:
    """Resolve (bm, bk|bd, bf) for a (kind, G, N, F) f32 round problem.

    ``kind`` is "round" (dense) or "segment" (ELLPACK). ``bench(tiles)``
    must run the real kernel once at those tiles and block until done; it is
    only invoked in ``full`` mode on a cache miss. All modes degrade to the
    static heuristic rather than raising.
    """
    if kind == "round":
        static = static_round_tiles(f)
        candidates = round_candidates(n, f)
    elif kind == "segment":
        static = static_segment_tiles(f)
        candidates = segment_candidates(n, f)
    else:
        raise ValueError(f"unknown kernel kind {kind!r}")

    mode = _mode()
    if mode == "off":
        return static

    dev = device_key()
    key = _problem_key(kind, g, n, f)
    hit = _MEM.get((dev, key))
    if hit is not None:
        return tuple(hit)

    disk = _disk_load(cache_path()).get(dev, {}).get(key)
    if disk is not None and len(disk) == 3:
        tiles = tuple(int(t) for t in disk)
        _MEM[(dev, key)] = tiles
        return tiles

    if mode != "full" or bench is None:
        return static

    timed = []
    for cand in candidates:
        try:
            timed.append((time_candidate(bench, cand), cand))
        except Exception:
            continue  # candidate invalid on this backend: skip, never fatal
    if not timed:
        return static
    best = min(timed)[1]
    _MEM[(dev, key)] = best
    _disk_store(cache_path(), dev, key, best)
    return best
