"""Public jit'd wrappers around the Pallas kernels.

These handle shape normalization (flattening, tile padding), backend
detection (interpret mode on CPU, compiled on TPU), autodiff (Pallas calls
have no reverse-mode rule: ``ssd_scan`` is a ``jax.custom_vjp`` — kernel
forward, differentiable chunked-jnp backward, the standard "kernel fwd /
XLA bwd" production pattern), and the inter-chunk associative scan for SSD.
Models and the simulator call these — never the raw ``*_pallas`` entries.
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
import inspect
import os

import jax
import jax.numpy as jnp

from jax.experimental.custom_partitioning import custom_partitioning
from jax.sharding import NamedSharding, PartitionSpec as P

from . import autotune
from .consensus_update import LANES, consensus_update_pallas
from .gossip_matvec import gossip_matvec_pallas
from .gossip_round import (
    gossip_round_batched_pallas,
    gossip_round_masked_batched_pallas,
    gossip_round_masked_pallas,
    gossip_round_pallas,
    gossip_round_sender_masked_batched_pallas,
)
from .ref import ssd_chunk_ref
from .segment_round import (
    segment_round_batched_pallas,
    segment_round_masked_batched_pallas,
    segment_round_masked_pallas,
    segment_round_pallas,
    segment_round_sender_masked_batched_pallas,
)
from .ssd_chunk import ssd_chunk_pallas

__all__ = [
    "batched_round_prim",
    "batched_segment_round_prim",
    "build_ell",
    "consensus_update",
    "cp_partition_count",
    "gossip_matvec",
    "gossip_round",
    "gossip_round_batched",
    "gossip_round_masked",
    "gossip_round_masked_batched",
    "round_tiles",
    "segment_bn",
    "segment_round",
    "segment_tiles",
    "ssd_scan",
    "use_interpret",
]


def use_interpret() -> bool:
    """Pallas interpret mode everywhere except on a real TPU backend.

    ``REPRO_REQUIRE_COMPILED=1`` turns silent interpret fallback into a hard
    failure — the CI compiled-bench lane sets it so a kernel quietly running
    under the interpreter (orders of magnitude slower, and not the artifact
    being measured) fails the job instead of polluting the trajectory.
    """
    interp = jax.default_backend() != "tpu"
    if interp and os.environ.get("REPRO_REQUIRE_COMPILED", "").strip() == "1":
        raise RuntimeError(
            "REPRO_REQUIRE_COMPILED=1 but the Pallas kernels would run in "
            f"interpret mode (jax backend: {jax.default_backend()!r}); "
            "run on a TPU backend or unset the flag")
    return interp


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# consensus_update: fused y = a*xw + b*x + c*xp over arbitrary-shape operands.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block_rows",))
def consensus_update(xw, x, xp, a, b, c, *, block_rows: int = 256):
    """Fused two-tap update. Operands: any (matching) shape; a/b/c scalars."""
    shape = xw.shape
    dtype = xw.dtype
    flat = xw.size
    rows = _round_up(max(_round_up(flat, LANES) // LANES, 1), block_rows)
    pad = rows * LANES - flat

    def prep(t):
        t = t.reshape(-1)
        if pad:
            t = jnp.pad(t, (0, pad))
        return t.reshape(rows, LANES)

    coef = jnp.stack(
        [jnp.asarray(a, dtype), jnp.asarray(b, dtype), jnp.asarray(c, dtype)]
    ).reshape(1, 3)
    y = consensus_update_pallas(
        prep(xw), prep(x), prep(xp), coef,
        block_rows=block_rows, interpret=use_interpret(),
    )
    return y.reshape(-1)[:flat].reshape(shape)


# ---------------------------------------------------------------------------
# gossip_matvec: Y = W @ X with tile padding.
# ---------------------------------------------------------------------------

@jax.jit
def gossip_matvec(w, x):
    """Y = W(N,N) @ X(N,F), fp32 accumulation, auto-padded to MXU tiles."""
    n, f = w.shape[0], x.shape[1]
    bm, bk, bf = _round_tiles(f)
    np_, fp_ = _round_up(n, 128), _round_up(f, bf)
    wp = jnp.pad(w, ((0, np_ - n), (0, np_ - n)))
    xp_ = jnp.pad(x, ((0, np_ - n), (0, fp_ - f)))
    y = gossip_matvec_pallas(
        wp, xp_, bm=bm, bk=bk, bf=bf, interpret=use_interpret()
    )
    return y[:n, :f]


# ---------------------------------------------------------------------------
# gossip_round: fused Y = a*(W@X) + b*X + c*Xp (one accelerated round).
# ---------------------------------------------------------------------------

def _round_tiles(f: int) -> tuple[int, int, int]:
    """(bm, bk, bf) MXU-aligned tiles; narrow trial blocks get narrow bf."""
    return autotune.static_round_tiles(f)


def _round_bench(n: int, f: int, g: int):
    """Bench closure for the dense autotuner: run one batched round, blocked.

    Dummy operands are cached per padded shape so repeat timings measure the
    kernel, not host array construction. The ensemble axis is clamped — tile
    quality is shape-per-graph-driven, and a G=192 sweep grid would make
    every candidate probe pay the full sweep's memory.
    """
    gb = max(1, min(g, 4))
    arrays = {}

    def bench(tiles):
        bm, bk, bf = tiles
        np_, fp_ = _round_up(n, max(bm, bk)), _round_up(f, bf)
        if (np_, fp_) not in arrays:
            arrays[(np_, fp_)] = (
                jnp.full((gb, np_, np_), 1.0 / np_, jnp.float32),
                jnp.ones((gb, np_, fp_), jnp.float32),
                jnp.ones((gb, 3), jnp.float32),
            )
        ws, xs, coefs = arrays[(np_, fp_)]
        gossip_round_batched_pallas(
            ws, xs, xs, coefs, bm=bm, bk=bk, bf=bf, interpret=use_interpret()
        ).block_until_ready()

    return bench


def round_tiles(n: int, f: int, g: int = 1, tune: bool = False):
    """Autotune-aware (bm, bk, bf) for a dense (G, N, F) round problem.

    ``tune=True`` enables measuring on a cache miss (``REPRO_KERNEL_TUNE=
    full`` only) — callers must be OUTSIDE any jit trace to pass it, which
    the sweep engine and the benches are. Jitted wrappers call with the
    default and get the cached winner or the static heuristic.
    """
    bench = _round_bench(n, f, g) if tune else None
    return autotune.get_tiles("round", n, f, g, bench=bench)


# ---------------------------------------------------------------------------
# custom_partitioning over G: the batched round kernels are embarrassingly
# parallel over the ensemble axis — every operand (Ws, masks, ELL arrays,
# states, coefs, bits) carries G as dim 0 and nothing crosses graphs. Without
# a rule, GSPMD treats the pallas_call as an opaque custom call and
# replicates it: every device would run the FULL (G, ...) grid. The wrappers
# below declare "shard dim 0 however the operands are sharded, replicate the
# rest", so the sweep engine's existing NamedSharding(mesh, P('data')) G
# layout flows straight through — no shard_map, no replicated dispatch.
# Dispatch skips the wrapper entirely on single-device processes (the
# common CPU/test path).
# ---------------------------------------------------------------------------

_CP_PARTITION_CALLS = 0


def cp_partition_count() -> int:
    """How many times GSPMD invoked a round-kernel partition rule (tests)."""
    return _CP_PARTITION_CALLS


def reset_cp_partition_count() -> None:
    """Zero the fired-counter. The counter is process-global; any test that
    asserts on absolute values (rather than deltas) must reset it first or
    an earlier multidevice test's compilations leak into the assertion."""
    global _CP_PARTITION_CALLS
    _CP_PARTITION_CALLS = 0


@contextlib.contextmanager
def cp_partition_calls():
    """Scoped delta view of the fired-counter: yields a zero-arg callable
    returning how many partition-rule invocations happened since entry.
    Robust against interleaved suites — each scope measures its own delta,
    so absolute counts never leak across assertions."""
    start = _CP_PARTITION_CALLS
    yield lambda: _CP_PARTITION_CALLS - start


# Trace-time override for the single-device fast path below: the static
# analyzer (repro.analysis.meshkernel) traces the engine on a one-device
# host but must see the jaxpr a MESH run would lower — i.e. every batched
# kernel behind its custom_partitioning wrapper — to verify no pallas_call
# escapes unwrapped. Never set during real runs.
_FORCE_MESH = contextvars.ContextVar("force_mesh_dispatch", default=False)


@contextlib.contextmanager
def force_mesh_dispatch():
    """Make batched-round prim builders take the custom_partitioning path
    regardless of ``jax.device_count()`` (static-analysis tracing only)."""
    token = _FORCE_MESH.set(True)
    try:
        yield
    finally:
        _FORCE_MESH.reset(token)


def _g_axis(arg_shapes):
    """The mesh axis dim 0 is sharded over, from the first sharded operand."""
    for a in arg_shapes:
        s = getattr(a, "sharding", None)
        if isinstance(s, NamedSharding) and len(s.spec) and s.spec[0] is not None:
            return s.spec[0]
    return None


def _dim0_sharding(mesh, g_ax, ndim):
    return NamedSharding(mesh, P(*((g_ax,) + (None,) * (ndim - 1))))


def _batched_infer(mesh, arg_shapes, result_shape):
    g_ax = _g_axis(arg_shapes)
    return _dim0_sharding(mesh, g_ax, len(result_shape.shape))


def _make_batched_partition(call):
    def _partition(mesh, arg_shapes, result_shape):
        global _CP_PARTITION_CALLS
        _CP_PARTITION_CALLS += 1
        g_ax = _g_axis(arg_shapes)
        arg_shardings = tuple(
            _dim0_sharding(mesh, g_ax, len(a.shape)) for a in arg_shapes)
        out_sharding = _dim0_sharding(mesh, g_ax, len(result_shape.shape))

        def lower_fn(*args):
            return call(*args)

        return mesh, lower_fn, out_sharding, arg_shardings

    return _partition


@functools.lru_cache(maxsize=None)
def _round_cp(variant: str, bm: int, bk: int, bf: int, interpret: bool):
    """custom_partitioning wrapper for one dense batched-kernel variant.

    Cached per (variant, tiles, interpret) so a sweep's scan body reuses one
    wrapped callable — custom_partitioning instances are identity-keyed in
    the jaxpr, and rebuilding one per trace would defeat the jit cache.
    No Shardy ``sharding_rule``: X's node axis is both contracted (W @ X)
    and elementwise (the taps), which an einsum-factor rule cannot express;
    the GSPMD callbacks fully describe the G-only partitioning.
    """
    kw = dict(bm=bm, bk=bk, bf=bf, interpret=interpret)
    if variant == "plain":
        def call(ws, xs, xps, coefs):
            return gossip_round_batched_pallas(ws, xs, xps, coefs, **kw)
    elif variant == "masked":
        def call(ws, ms, xs, xps, coefs):
            return gossip_round_masked_batched_pallas(ws, ms, xs, xps, coefs, **kw)
    elif variant == "sender":
        def call(ws, ms, xs, xps, coefs):
            return gossip_round_sender_masked_batched_pallas(
                ws, ms, xs, xps, coefs, **kw)
    else:
        raise ValueError(f"unknown dense round variant {variant!r}")
    cp = custom_partitioning(call)
    cp.def_partition(
        partition=_make_batched_partition(call),
        infer_sharding_from_operands=_batched_infer,
        decode_shardings=True,
    )
    return cp


def batched_round_prim(ws, *, bm: int = 128, bk: int = 128, bf: int = 512,
                       interpret: bool | None = None,
                       renorm: str = "receiver"):
    """Fused-round primitive over a pre-padded (Gp, N, N) partition slice.

    This is the kernel-layer dispatch point every registry algorithm's
    ``round_body`` routes through on the pallas backend (an algorithm may
    override it via its ``pallas_round`` hook): the returned

        prim(x, xp, coef, m=None) -> coef[:,0]*(W_eff@x) + coef[:,1]*x
                                     + coef[:,2]*xp

    picks the plain or a masked fused batched kernel by whether a per-round
    (Gp, N, N) activity mask ``m`` is supplied; ``renorm`` selects where a
    dropped edge's mass returns — "receiver" (row renorm, the doubly
    stochastic family) or "sender" (column renorm, push_sum /
    ratio_consensus; masks must be symmetric per undirected edge). Operands
    must already be padded to the (bm, bk, bf) tiles — the sweep engine pads
    ONCE outside its scan (see ``repro.sweep.engine``).

    On multi-device processes every kernel call goes through a
    ``custom_partitioning`` wrapper that shards the G axis however the
    operands are sharded (see ``_round_cp``), so the same prim serves both
    the single-device jit and the mesh-sharded sweep.

    ``coef`` is a traced per-CALL operand, never a compile-time constant:
    the kernels read it from memory each launch, so per-round coefficient
    streams (``accel_adapt`` re-solving alpha* every tick from its in-scan
    estimate) flow through unchanged with zero recompilation — the
    time-varying coefficient contract (docs/ARCHITECTURE.md) is free at
    this layer. The same holds for ``batched_segment_round_prim``.
    """
    if interpret is None:
        interpret = use_interpret()
    if renorm not in ("receiver", "sender"):
        raise ValueError(f"renorm must be receiver or sender, got {renorm!r}")
    single = jax.device_count() == 1 and not _FORCE_MESH.get()
    kw = dict(bm=bm, bk=bk, bf=bf, interpret=interpret)

    def prim(x, xp, coef, m=None):
        if m is None:
            if single:
                return gossip_round_batched_pallas(ws, x, xp, coef, **kw)
            return _round_cp("plain", bm, bk, bf, interpret)(ws, x, xp, coef)
        if renorm == "receiver":
            if single:
                return gossip_round_masked_batched_pallas(ws, m, x, xp, coef, **kw)
            return _round_cp("masked", bm, bk, bf, interpret)(ws, m, x, xp, coef)
        if single:
            return gossip_round_sender_masked_batched_pallas(
                ws, m, x, xp, coef, **kw)
        return _round_cp("sender", bm, bk, bf, interpret)(ws, m, x, xp, coef)

    return prim


@jax.jit
def gossip_round(w, x, xp, a, b, c):
    """One fused two-tap round on a single graph, auto-padded to MXU tiles.

    W (N, N), X/Xp (N, F), a/b/c scalars (python or traced). Zero padding is
    exact: padded W rows/cols contribute nothing and padded X/Xp entries are
    zero, so the sliced (N, F) output equals the unpadded computation.

    Interpret-mode dispatch (trace-time branch) runs the unfused
    matvec + FMA pair instead: the fusion's win is skipping the x_w HBM
    round-trip, but the interpreter evaluates the fused grid's k-independent
    X/Xp tile loads and FMA predicate on EVERY grid step in Python, which
    costs more than the spill it saves (2.7ms vs 1.8ms per round at
    N200xF300 in BENCH_kernel_perf.json). On a real TPU backend the fused
    kernel is the whole point and is always used.
    """
    if use_interpret():
        return consensus_update(gossip_matvec(w, x), x, xp, a, b, c)
    n, f = w.shape[0], x.shape[1]
    bm, bk, bf = round_tiles(n, f)
    np_, fp_ = _round_up(n, max(bm, bk)), _round_up(f, bf)
    wp = jnp.pad(w.astype(jnp.float32), ((0, np_ - n), (0, np_ - n)))
    xpad = jnp.pad(x.astype(jnp.float32), ((0, np_ - n), (0, fp_ - f)))
    xppad = jnp.pad(xp.astype(jnp.float32), ((0, np_ - n), (0, fp_ - f)))
    coef = jnp.stack(
        [jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32),
         jnp.asarray(c, jnp.float32)]
    ).reshape(1, 3)
    y = gossip_round_pallas(
        wp, xpad, xppad, coef, bm=bm, bk=bk, bf=bf, interpret=use_interpret()
    )
    return y[:n, :f]


@jax.jit
def gossip_round_batched(ws, xs, xps, coefs):
    """Fused round over a stacked ensemble (the sweep-engine inner loop).

    Ws (G, N, N), Xs/Xps (G, N, F), coefs (G, 3) -> (G, N, F) fp32. One
    kernel launch covers the whole grid; per-graph coefficients ride in the
    (G, 3) operand so heterogeneous (alpha, theta) cells share the program.
    """
    g, n, f = xs.shape
    bm, bk, bf = round_tiles(n, f, g)
    np_, fp_ = _round_up(n, max(bm, bk)), _round_up(f, bf)
    wp = jnp.pad(ws.astype(jnp.float32), ((0, 0), (0, np_ - n), (0, np_ - n)))
    xpad = jnp.pad(xs.astype(jnp.float32), ((0, 0), (0, np_ - n), (0, fp_ - f)))
    xppad = jnp.pad(xps.astype(jnp.float32), ((0, 0), (0, np_ - n), (0, fp_ - f)))
    y = gossip_round_batched_pallas(
        wp, xpad, xppad, coefs.astype(jnp.float32),
        bm=bm, bk=bk, bf=bf, interpret=use_interpret(),
    )
    return y[:, :n, :f]


@jax.jit
def gossip_round_masked(w, m, x, xp, a, b, c):
    """One fused masked round on a single graph, auto-padded to MXU tiles.

    ``m`` is the round's (N, N) 0/1 edge-activity mask (ones on the diagonal;
    see ``repro.core.dynamics``): dropped weight returns to the diagonal, so
    W_eff stays doubly stochastic. Mask padding is zeros — padded W entries
    are zero, so they contribute neither matvec nor dropped mass.
    """
    n, f = w.shape[0], x.shape[1]
    bm, bk, bf = round_tiles(n, f)
    np_, fp_ = _round_up(n, max(bm, bk)), _round_up(f, bf)
    wp = jnp.pad(w.astype(jnp.float32), ((0, np_ - n), (0, np_ - n)))
    mp = jnp.pad(m.astype(jnp.float32), ((0, np_ - n), (0, np_ - n)))
    xpad = jnp.pad(x.astype(jnp.float32), ((0, np_ - n), (0, fp_ - f)))
    xppad = jnp.pad(xp.astype(jnp.float32), ((0, np_ - n), (0, fp_ - f)))
    coef = jnp.stack(
        [jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32),
         jnp.asarray(c, jnp.float32)]
    ).reshape(1, 3)
    y = gossip_round_masked_pallas(
        wp, mp, xpad, xppad, coef, bm=bm, bk=bk, bf=bf, interpret=use_interpret()
    )
    return y[:n, :f]


@jax.jit
def gossip_round_masked_batched(ws, ms, xs, xps, coefs):
    """Masked fused round over a stacked ensemble (dynamic-sweep inner loop).

    Ws/Ms (G, N, N), Xs/Xps (G, N, F), coefs (G, 3) -> (G, N, F) fp32.
    """
    g, n, f = xs.shape
    bm, bk, bf = round_tiles(n, f, g)
    np_, fp_ = _round_up(n, max(bm, bk)), _round_up(f, bf)
    wp = jnp.pad(ws.astype(jnp.float32), ((0, 0), (0, np_ - n), (0, np_ - n)))
    mp = jnp.pad(ms.astype(jnp.float32), ((0, 0), (0, np_ - n), (0, np_ - n)))
    xpad = jnp.pad(xs.astype(jnp.float32), ((0, 0), (0, np_ - n), (0, fp_ - f)))
    xppad = jnp.pad(xps.astype(jnp.float32), ((0, 0), (0, np_ - n), (0, fp_ - f)))
    y = gossip_round_masked_batched_pallas(
        wp, mp, xpad, xppad, coefs.astype(jnp.float32),
        bm=bm, bk=bk, bf=bf, interpret=use_interpret(),
    )
    return y[:, :n, :f]


# ---------------------------------------------------------------------------
# segment_round: fused SPARSE Y = a*(W@X) + b*X + c*Xp from an edge list.
# ---------------------------------------------------------------------------


def _segment_tiles(f: int) -> tuple[int, int, int]:
    """(bm, bd, bf) tiles for the ELL kernels; bd is the neighbor-slot axis."""
    return autotune.static_segment_tiles(f)


def _segment_bench(n: int, f: int, g: int):
    """Bench closure for the ELL autotuner: ring-graph dummy, one tile of D."""
    gb = max(1, min(g, 4))
    arrays = {}

    def bench(tiles):
        bm, bd, bf = tiles
        np_, fp_ = _round_up(n, bm), _round_up(f, bf)
        if (np_, fp_) not in arrays:
            idx = jnp.arange(np_, dtype=jnp.int32)
            nbrs = jnp.stack([(idx + 1) % np_, (idx - 1) % np_], axis=1)
            nbrs = jnp.broadcast_to(
                jnp.pad(nbrs, ((0, 0), (0, bd - 2))), (gb, np_, bd))
            wgts = jnp.broadcast_to(
                jnp.pad(jnp.full((np_, 2), 0.25, jnp.float32),
                        ((0, 0), (0, bd - 2))), (gb, np_, bd))
            arrays[(np_, fp_)] = (
                nbrs, wgts,
                jnp.full((gb, np_, 1), 0.5, jnp.float32),
                jnp.ones((gb, np_, fp_), jnp.float32),
                jnp.ones((gb, 3), jnp.float32),
            )
        nbrs, wgts, diags, xs, coefs = arrays[(np_, fp_)]
        segment_round_batched_pallas(
            nbrs, wgts, diags, xs, xs, coefs,
            bm=bm, bd=bd, bf=bf, interpret=use_interpret()
        ).block_until_ready()

    return bench


def segment_tiles(n: int, f: int, g: int = 1, tune: bool = False):
    """Autotune-aware (bm, bd, bf) for an ELLPACK (G, N, F) round problem.

    Same contract as ``round_tiles``: ``tune=True`` only from host code
    outside a jit trace; jitted wrappers take the cached/static answer.
    """
    bench = _segment_bench(n, f, g) if tune else None
    return autotune.get_tiles("segment", n, f, g, bench=bench)


_SEGMENT_VMEM_BUDGET = 8 * 1024 * 1024  # resident X source block, bytes


def segment_bn(n: int, bm: int, bf: int) -> tuple[int, int]:
    """VMEM tiling policy for the segment kernels' resident X source block.

    Returns (bn, n_padded): the source-row block size and the padded node
    count (a multiple of both bn and bm). The kernels gather from a (bn, bf)
    X block held in VMEM; bn * bf * 4 bytes must fit the budget
    (``REPRO_SEGMENT_VMEM_BUDGET`` overrides the 8 MiB default). Small
    problems get bn = N (one resident block, S = 1 — bitwise identical to
    the historical un-tiled kernel); past the cap, bn is the budget-sized
    multiple of bm that wastes the least padding. bn is a deliberate
    POLICY parameter, not an autotuned one: splitting the gather reduction
    reorders float accumulation, so tuning it would break the autotuner's
    bit-identicality contract.
    """
    budget = int(os.environ.get(
        "REPRO_SEGMENT_VMEM_BUDGET", _SEGMENT_VMEM_BUDGET))
    cap_rows = max(bm, (budget // (bf * 4)) // bm * bm)
    n_bm = _round_up(n, bm)
    if n_bm <= cap_rows:
        return n_bm, n_bm
    best = None
    for bn in range(cap_rows, 0, -bm):
        n_pad = _round_up(n_bm, bn)
        if best is None or n_pad < best[1]:
            best = (bn, n_pad)
    return best


def build_ell(edges, edge_w, diag_w, n: int, edge_w_rev=None):
    """ELLPACK (padded per-row neighbor list) arrays from a canonical edge list.

    Host numpy. ``edges`` (E, 2) i < j canonical, ``edge_w`` (E,) the
    undirected weights, ``diag_w`` (N,) the diagonal. Each undirected edge
    becomes two directed slots (one per endpoint row); ``edge_w_rev`` (E,)
    optionally carries the reverse-orientation weight W[j, i] per canonical
    (i, j) for asymmetric bases (push-sum family) — row i's slot then keeps
    ``edge_w`` = W[i, j] while row j's slot gets W[j, i]. None means the
    base is symmetric and ``edge_w`` serves both orientations. Returns

        nbr  (N, D) int32   neighbor node index per slot
        wgt  (N, D) f32     this orientation's weight W[i, nbr[i, d]]
        wrev (N, D) f32     the REVERSE orientation W[nbr[i, d], i]
        slot (N, D) int32   undirected edge id per slot
        diag (N, 1) f32     W's diagonal

    with D = max degree and padding slots wgt = wrev = 0 / nbr = 0 /
    slot = 0 — inert in the kernels whatever their index values.
    ``slot[i, d]`` is the undirected edge id (the RoundMasks bits column)
    the slot mirrors, so the masked kernels gather one (E,) bits row
    instead of an (N, N) mask; ``wrev`` feeds the sender-renorm masked
    kernel's column dropped-mass sum (for symmetric bases wrev == wgt).
    """
    import numpy as np

    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    e = len(edges)
    src = np.concatenate([edges[:, 0], edges[:, 1]])
    dst = np.concatenate([edges[:, 1], edges[:, 0]])
    w_fwd = np.asarray(edge_w, dtype=np.float64)
    w_bwd = w_fwd if edge_w_rev is None else np.asarray(edge_w_rev, np.float64)
    wdir = np.concatenate([w_fwd, w_bwd])       # weight INTO the slot's row
    wrev_dir = np.concatenate([w_bwd, w_fwd])   # weight OUT of the slot's row
    eid = np.concatenate([np.arange(e), np.arange(e)])
    deg = np.bincount(src, minlength=n)
    d_max = max(1, int(deg.max()) if e else 1)
    order = np.argsort(src, kind="stable")
    src_s, dst_s, eid_s = src[order], dst[order], eid[order]
    w_s, wr_s = wdir[order], wrev_dir[order]
    starts = np.concatenate([[0], np.cumsum(deg)[:-1]])
    pos = np.arange(len(src_s)) - starts[src_s]
    nbr = np.zeros((n, d_max), dtype=np.int32)
    wgt = np.zeros((n, d_max), dtype=np.float32)
    wrev = np.zeros((n, d_max), dtype=np.float32)
    slot = np.zeros((n, d_max), dtype=np.int32)
    nbr[src_s, pos] = dst_s
    wgt[src_s, pos] = w_s
    wrev[src_s, pos] = wr_s
    slot[src_s, pos] = eid_s
    diag = np.asarray(diag_w, dtype=np.float32).reshape(n, 1)
    return nbr, wgt, wrev, slot, diag


@jax.jit
def segment_round(nbr, wgt, slot, diag, x, xp, a, b, c, bits=None):
    """One fused sparse round on a single graph, auto-padded to kernel tiles.

    ELL operands from ``build_ell``; X/Xp (N, F); a/b/c scalars; ``bits``
    an optional (E,) 0/1 activity row for this round (None = all edges up).
    Padding is exact: padded rows have diag 0 and x 0, padded slots have
    weight 0, padded bits columns are unreferenced.
    """
    n, f = x.shape
    d = nbr.shape[1]
    bm, bd, bf = segment_tiles(n, f)
    np_, dp_, fp_ = _round_up(n, bm), _round_up(d, bd), _round_up(f, bf)
    nbrp = jnp.pad(nbr, ((0, np_ - n), (0, dp_ - d)))
    wgtp = jnp.pad(wgt.astype(jnp.float32), ((0, np_ - n), (0, dp_ - d)))
    diagp = jnp.pad(diag.astype(jnp.float32), ((0, np_ - n), (0, 0)))
    xpad = jnp.pad(x.astype(jnp.float32), ((0, np_ - n), (0, fp_ - f)))
    xppad = jnp.pad(xp.astype(jnp.float32), ((0, np_ - n), (0, fp_ - f)))
    coef = jnp.stack(
        [jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32),
         jnp.asarray(c, jnp.float32)]
    ).reshape(1, 3)
    if bits is None:
        y = segment_round_pallas(
            nbrp, wgtp, diagp, xpad, xppad, coef,
            bm=bm, bd=bd, bf=bf, interpret=use_interpret())
    else:
        slotp = jnp.pad(slot, ((0, np_ - n), (0, dp_ - d)))
        e = bits.shape[0]
        bitsp = jnp.pad(bits.astype(jnp.float32),
                        (0, _round_up(max(e, 1), 128) - e)).reshape(1, -1)
        y = segment_round_masked_pallas(
            nbrp, wgtp, slotp, diagp, bitsp, xpad, xppad, coef,
            bm=bm, bd=bd, bf=bf, interpret=use_interpret())
    return y[:n, :f]


@functools.lru_cache(maxsize=None)
def _seg_cp(variant: str, bm: int, bd: int, bf: int, bn: int | None,
            interpret: bool):
    """custom_partitioning wrapper for one ELLPACK batched-kernel variant.

    Same G-only partitioning contract as ``_round_cp``: every operand
    (ELL arrays, bits, states, coefs) leads with the ensemble axis, nothing
    crosses graphs, so dim 0 shards and everything else stays whole.
    """
    kw = dict(bm=bm, bd=bd, bf=bf, bn=bn, interpret=interpret)
    if variant == "plain":
        def call(nbrs, wgts, diags, xs, xps, coefs):
            return segment_round_batched_pallas(
                nbrs, wgts, diags, xs, xps, coefs, **kw)
    elif variant == "masked":
        def call(nbrs, wgts, slots, diags, bits, xs, xps, coefs):
            return segment_round_masked_batched_pallas(
                nbrs, wgts, slots, diags, bits, xs, xps, coefs, **kw)
    elif variant == "sender":
        def call(nbrs, wgts, wrevs, slots, diags, bits, xs, xps, coefs):
            return segment_round_sender_masked_batched_pallas(
                nbrs, wgts, wrevs, slots, diags, bits, xs, xps, coefs, **kw)
    else:
        raise ValueError(f"unknown segment round variant {variant!r}")
    cp = custom_partitioning(call)
    cp.def_partition(
        partition=_make_batched_partition(call),
        infer_sharding_from_operands=_batched_infer,
        decode_shardings=True,
    )
    return cp


def batched_segment_round_prim(nbrs, wgts, slots, diags, *, wrevs=None,
                               bm: int = 128, bd: int = 8, bf: int = 128,
                               bn: int | None = None,
                               interpret: bool | None = None,
                               renorm: str = "receiver"):
    """Sparse fused-round primitive over pre-padded (Gp, N, D) ELL slices.

    The sparse-layout counterpart of ``batched_round_prim`` — the returned

        prim(x, xp, coef, m=None)

    satisfies the identical layout-polymorphic contract every registry
    algorithm's ``round_body`` is written against, with ``m`` this round's
    (Gp, E) compressed bits rows (NOT an (N, N) mask — the sparse path never
    builds one). ``renorm`` selects where a dropped edge's mass returns
    ("receiver" = row renorm; "sender" = column renorm, which requires the
    (Gp, N, D) reverse weights ``wrevs`` from ``build_ell``). ``bn`` tiles
    the kernels' resident X source block over N for the VMEM cap (see
    ``segment_bn``; None = one full-N block). Operands must already be
    padded to the (bm, bd, bf) tiles — and N to a bn multiple — by the
    sweep engine, ONCE outside its scan.

    Multi-device processes route every call through a G-axis
    ``custom_partitioning`` wrapper (``_seg_cp``), mirroring the dense prim.
    """
    if interpret is None:
        interpret = use_interpret()
    if renorm not in ("receiver", "sender"):
        raise ValueError(f"renorm must be receiver or sender, got {renorm!r}")
    if renorm == "sender" and wrevs is None:
        raise ValueError("renorm='sender' needs the wrevs ELL array")
    single = jax.device_count() == 1 and not _FORCE_MESH.get()
    kw = dict(bm=bm, bd=bd, bf=bf, bn=bn, interpret=interpret)

    def prim(x, xp, coef, m=None):
        if m is None:
            if single:
                return segment_round_batched_pallas(
                    nbrs, wgts, diags, x, xp, coef, **kw)
            return _seg_cp("plain", bm, bd, bf, bn, interpret)(
                nbrs, wgts, diags, x, xp, coef)
        if renorm == "receiver":
            if single:
                return segment_round_masked_batched_pallas(
                    nbrs, wgts, slots, diags, m, x, xp, coef, **kw)
            return _seg_cp("masked", bm, bd, bf, bn, interpret)(
                nbrs, wgts, slots, diags, m, x, xp, coef)
        if single:
            return segment_round_sender_masked_batched_pallas(
                nbrs, wgts, wrevs, slots, diags, m, x, xp, coef, **kw)
        return _seg_cp("sender", bm, bd, bf, bn, interpret)(
            nbrs, wgts, wrevs, slots, diags, m, x, xp, coef)

    return prim


# ---------------------------------------------------------------------------
# ssd_scan: full-sequence Mamba-2 SSD = intra-chunk kernel + inter-chunk scan.
# ---------------------------------------------------------------------------
#
# pallas_call is an opaque custom call: without a partitioning rule GSPMD
# replicates it (every device would run the FULL global grid — observed as a
# 393216-trip sequential loop per device in the first dry-run). The
# custom_partitioning wrapper below tells GSPMD the op is embarrassingly
# parallel over (batch*chunks, heads): each device runs its LOCAL grid, with
# B/C group projections replicated over the head ('model') axis.

_FORCE_REF = contextvars.ContextVar("ssd_force_ref", default=False)


def in_manual_pod_region() -> bool:
    """True while tracing inside the pod-manual shard_map (consensus mode).

    Model code consults this to avoid constructs XLA cannot partition under
    manual subgroups on this jaxlib: Pallas custom_partitioning, lax.top_k,
    and batched scatter/gather (MoE dispatch)."""
    return _FORCE_REF.get()


@contextlib.contextmanager
def force_ssd_ref():
    """Trace-time escape hatch: jax's custom_partitioning cannot parse the
    manual-subgroup shardings produced inside a partial-auto shard_map
    (NotImplementedError: 'Unhandled OpSharding type ... manual'), so the
    consensus-mode train step traces the SSD intra-chunk block through the
    pure-jnp oracle (GSPMD shards its einsums natively). Everything outside
    the pod-manual region keeps the Pallas kernel."""
    tok = _FORCE_REF.set(True)
    try:
        yield
    finally:
        _FORCE_REF.reset(tok)


def _ssd_chunk_dispatch(xg, ag, bg, cg):
    if _FORCE_REF.get():
        return ssd_chunk_ref(xg, ag, bg, cg)
    if jax.device_count() == 1:
        return ssd_chunk_pallas(xg, ag, bg, cg, interpret=use_interpret())
    return _ssd_chunk_cp(xg, ag, bg, cg)


@custom_partitioning
def _ssd_chunk_cp(xg, ag, bg, cg):
    return ssd_chunk_pallas(xg, ag, bg, cg, interpret=use_interpret())


def _first_dims_spec(shardings, ndim_map):
    """(n_axis, h_axis) from the x operand's sharding; None when replicated."""
    xs = shardings[0]
    spec = xs.spec if isinstance(xs, NamedSharding) else P()
    parts = list(spec) + [None] * 4
    return parts[0], parts[1]


def _ssd_out_shardings(mesh, n_ax, h_ax):
    mk = lambda *s: NamedSharding(mesh, P(*s))
    return (
        mk(n_ax, h_ax, None, None),  # y
        mk(n_ax, h_ax, None, None),  # state
        mk(n_ax, h_ax, None, None),  # din
        mk(n_ax, h_ax, None, None),  # dout
    )


def _ssd_infer(mesh, arg_shapes, result_shape):
    shardings = [a.sharding for a in arg_shapes]
    n_ax, h_ax = _first_dims_spec(shardings, None)
    return _ssd_out_shardings(mesh, n_ax, h_ax)


def _ssd_partition(mesh, arg_shapes, result_shape):
    shardings = [a.sharding for a in arg_shapes]
    n_ax, h_ax = _first_dims_spec(shardings, None)
    mk = lambda *s: NamedSharding(mesh, P(*s))
    arg_shardings = (
        mk(n_ax, h_ax, None, None),   # x
        mk(n_ax, h_ax, None, None),   # a
        mk(n_ax, None, None, None),   # b: groups replicated over 'model'
        mk(n_ax, None, None, None),   # c
    )
    out_shardings = _ssd_out_shardings(mesh, n_ax, h_ax)

    def lower_fn(xg, ag, bg, cg):
        return ssd_chunk_pallas(xg, ag, bg, cg, interpret=use_interpret())

    return mesh, lower_fn, out_shardings, arg_shardings


# Shardy rule: n (batch*chunks) and h (heads) are parallel factors; the
# chunk/state/head_dim factors stay whole per program; g (groups) is
# replicated (its head mapping happens inside the kernel grid). jaxlib builds
# that predate Shardy's custom_partitioning hook don't accept the kwarg —
# GSPMD then relies on the infer/partition callbacks alone.
_def_partition_kwargs = dict(
    partition=_ssd_partition,
    infer_sharding_from_operands=_ssd_infer,
    decode_shardings=True,
)
if "sharding_rule" in inspect.signature(custom_partitioning.def_partition).parameters:
    _def_partition_kwargs["sharding_rule"] = (
        "n h l p, n h o l, n g l s, n g l s -> n h l p, n h s p, n h o l, n h o q"
    )
_ssd_chunk_cp.def_partition(**_def_partition_kwargs)


def _ssd_core(x, a, b, c, h0, chunk: int, use_kernel: bool):
    """Chunked SSD on pre-padded (T % chunk == 0) fp32 operands.

    The intra-chunk block runs through the Pallas kernel (fwd) or the pure
    jnp oracle (differentiable bwd recompute); the inter-chunk recurrence is
    a log-depth associative scan either way.

    Layout: all intermediate tensors keep the (data-sharded) batch dim major
    and the (model-sharded) head dim separate — merging them would force
    GSPMD to replicate the SSD einsums (verified in the dry-run; this exact
    bug cost 19x flops before the layout was fixed).
    """
    bsz, t, h, dh = x.shape
    g = b.shape[2]
    ds = b.shape[-1]
    nc = t // chunk
    hg = h // g

    def to_blocks(v, nh, feat):
        # (B, T, nh, f) -> (B*nc, nh, L, f); B stays the major factor of dim0
        v = v.reshape(bsz, nc, chunk, nh, feat)
        v = jnp.moveaxis(v, 3, 2)
        return v.reshape(bsz * nc, nh, chunk, feat)

    xg = to_blocks(x.astype(jnp.float32), h, dh)
    bg = to_blocks(b.astype(jnp.float32), g, ds)
    cg = to_blocks(c.astype(jnp.float32), g, ds)
    ag = a.astype(jnp.float32).reshape(bsz, nc, chunk, h)
    ag = jnp.moveaxis(ag, 3, 2).reshape(bsz * nc, h, 1, chunk)

    if use_kernel:
        y_intra, s_chunk, din, dout = _ssd_chunk_dispatch(xg, ag, bg, cg)
    else:
        y_intra, s_chunk, din, dout = ssd_chunk_ref(xg, ag, bg, cg)

    s_chunk = s_chunk.reshape(bsz, nc, h, ds, dh)
    dout = dout.reshape(bsz, nc, h)
    din = din.reshape(bsz, nc, h, chunk)

    def combine(left, right):
        dl, sl = left
        dr, sr = right
        return dl * dr, dr[..., None, None] * sl + sr

    d_inc, h_inc = jax.lax.associative_scan(combine, (dout, s_chunk), axis=1)

    h_shift = jnp.concatenate([jnp.zeros_like(h_inc[:, :1]), h_inc[:, :-1]], axis=1)
    d_shift = jnp.concatenate([jnp.ones_like(d_inc[:, :1]), d_inc[:, :-1]], axis=1)
    h_prev = h_shift + d_shift[..., None, None] * h0[:, None]

    # carry-in: y_inter = din * (C @ h_prev), grouped einsum (no broadcast)
    c_blk = cg.reshape(bsz, nc, g, chunk, ds)
    hp_g = h_prev.reshape(bsz, nc, g, hg, ds, dh)
    y_inter = jnp.einsum("bngls,bnghsd->bnghld", c_blk, hp_g)
    y_inter = y_inter.reshape(bsz, nc, h, chunk, dh)
    y = y_intra.reshape(bsz, nc, h, chunk, dh) + din[..., None] * y_inter

    h_final = h_inc[:, -1] + d_inc[:, -1][..., None, None] * h0
    y = jnp.moveaxis(y, 2, 3).reshape(bsz, t, h, dh)
    return y, h_final


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _ssd_cv(x, a, b, c, h0, chunk):
    return _ssd_core(x, a, b, c, h0, chunk, use_kernel=True)


def _ssd_cv_fwd(x, a, b, c, h0, chunk):
    out = _ssd_core(x, a, b, c, h0, chunk, use_kernel=True)
    return out, (x, a, b, c, h0)


def _ssd_cv_bwd(chunk, res, cotangents):
    x, a, b, c, h0 = res
    _, vjp = jax.vjp(
        lambda x_, a_, b_, c_, h0_: _ssd_core(x_, a_, b_, c_, h0_, chunk, use_kernel=False),
        x, a, b, c, h0,
    )
    return vjp(cotangents)


_ssd_cv.defvjp(_ssd_cv_fwd, _ssd_cv_bwd)


def ssd_scan(x, a, b, c, h0=None, *, chunk: int = 128):
    """Chunked SSD selective scan. (Not jitted here: callers jit the whole
    step, and the force_ssd_ref trace-time flag must not be frozen into a
    jit cache entry.)

    Args:
      x: (B, T, H, dh) inputs (post in-proj, post conv, gated branch).
      a: (B, T, H) per-step log decay (must be <= 0 for stability).
      b: (B, T, G, ds) input->state projection, G groups (mamba2: G=1);
         heads are group-mapped inside the kernel grid, never broadcast.
      c: (B, T, G, ds) state->output projection.
      h0: optional (B, H, ds, dh) initial state (decode/prefill carry).
      chunk: intra-chunk length (multiple of 128 on real TPU).

    Returns: (y (B, T, H, dh) fp32, h_final (B, H, ds, dh) fp32).
    """
    bsz, t, h, dh = x.shape
    ds = b.shape[-1]
    t_orig = t
    if t % chunk:
        # pad to a chunk multiple with identity dynamics: a=0 (decay exp(0)=1)
        # and x=b=0 leave the state untouched; padded y rows are sliced off.
        pad = chunk - t % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if h0 is None:
        h0 = jnp.zeros((bsz, h, ds, dh), dtype=jnp.float32)
    else:
        h0 = h0.astype(jnp.float32)
    y, h_final = _ssd_cv(
        x.astype(jnp.float32), a.astype(jnp.float32),
        b.astype(jnp.float32), c.astype(jnp.float32), h0, chunk
    )
    return y[:, :t_orig], h_final
