"""Unified causal LM covering the dense / moe / ssm / hybrid families.

All stacks are scan-over-layers with per-block rematerialization: parameters
are stored stacked with a leading 'layers' axis and consumed by ``lax.scan``,
keeping the HLO size O(1) in depth (essential for the 96-layer dry-runs).

Three entry points per family, shared by the trainer and the serving engine:

  * ``forward_train``  — full-sequence logits + MoE aux losses;
  * ``prefill``        — full-sequence forward that also materializes the
    decode cache (KV ring/linear buffers, SSM/conv states);
  * ``decode_step``    — one token against the cache.

The hybrid (zamba2) family scans superblocks: a (n_blocks, per_block, ...)
stack of Mamba2 layers with a *single shared* attention+MLP block applied at
the end of every superblock (own KV cache per site), plus trailing Mamba2
layers.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import attn_param_specs, decode_mha, mha, out_project, qkv_project
from .common import (
    Activations,
    ParamSpec,
    apply_rope,
    cross_entropy_loss,
    layer_norm,
    rms_norm,
    rotary,
)
from .mlp import mlp_forward, mlp_param_specs, moe_forward, moe_param_specs
from .ssm import ssm_cache_shapes, ssm_decode_step, ssm_forward, ssm_param_specs

PyTree = Any

__all__ = [
    "param_specs",
    "forward_train",
    "loss_fn",
    "prefill",
    "decode_step",
    "cache_specs",
    "stack_specs",
]


# ---------------------------------------------------------------------------
# Param specs.
# ---------------------------------------------------------------------------

def stack_specs(specs: PyTree, n: int, axis: str = "layers") -> PyTree:
    def s(t):
        if isinstance(t, ParamSpec):
            return dataclasses.replace(
                t, shape=(n, *t.shape), axes=(axis, *t.axes)
            )
        return {k: s(v) for k, v in t.items()}

    return s(specs)


def norm_specs(cfg: ArchConfig, dim: int | None = None) -> dict:
    d = dim or cfg.d_model
    out = {"gamma": ParamSpec((d,), (None,), init="ones")}
    if cfg.norm == "layernorm":
        out["beta"] = ParamSpec((d,), (None,), init="zeros")
    return out


def apply_norm(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.norm == "layernorm":
        return layer_norm(x, p["gamma"], p["beta"], cfg.norm_eps)
    return rms_norm(x, p["gamma"], cfg.norm_eps)


def dense_block_specs(cfg: ArchConfig) -> dict:
    hd = cfg.resolved_head_dim
    specs = {
        "ln1": norm_specs(cfg),
        "attn": attn_param_specs(
            cfg.d_model, cfg.physical_q_heads, cfg.physical_kv_heads, hd
        ),
        "ln2": norm_specs(cfg),
    }
    if cfg.family == "moe":
        specs["moe"] = moe_param_specs(cfg.d_model, cfg.moe, cfg.activation)
    else:
        specs["mlp"] = mlp_param_specs(cfg.d_model, cfg.d_ff, cfg.activation)
    return specs


def mamba_block_specs(cfg: ArchConfig) -> dict:
    return {"ln": norm_specs(cfg), "ssm": ssm_param_specs(cfg.d_model, cfg.ssm)}


def param_specs(cfg: ArchConfig) -> PyTree:
    d, v = cfg.d_model, cfg.padded_vocab
    specs: dict = {
        "embed": ParamSpec((v, d), ("vocab", "embed"), scale=0.02),
        "final_norm": norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((d, v), ("embed", "vocab"))
    if cfg.pos == "learned":
        specs["pos_embed"] = ParamSpec((32_768, d), (None, "embed"), scale=0.02)

    if cfg.family in ("dense", "moe"):
        specs["blocks"] = stack_specs(dense_block_specs(cfg), cfg.num_layers)
    elif cfg.family == "ssm":
        specs["blocks"] = stack_specs(mamba_block_specs(cfg), cfg.num_layers)
    elif cfg.family == "hybrid":
        nb, per = _hybrid_geometry(cfg)
        inner = stack_specs(mamba_block_specs(cfg), per, axis="inner")
        specs["mamba"] = stack_specs(inner, nb)
        if cfg.hybrid_tail:
            specs["tail"] = stack_specs(mamba_block_specs(cfg), cfg.hybrid_tail)
        specs["shared"] = dense_block_specs(
            dataclasses.replace(cfg, family="dense")
        )
    else:
        raise ValueError(f"family {cfg.family} handled elsewhere (encdec/vlm)")
    return specs


def _hybrid_geometry(cfg: ArchConfig) -> tuple[int, int]:
    per = cfg.hybrid_pattern.count("m")
    if cfg.hybrid_pattern.count("a") != 1:
        raise ValueError("hybrid_pattern must contain exactly one 'a'")
    nb = (cfg.num_layers - cfg.hybrid_tail) // (per + 1)
    return nb, per


# ---------------------------------------------------------------------------
# Embedding / head.
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: ArchConfig, pos_offset: int = 0, dtype=jnp.bfloat16):
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    if cfg.pos == "learned":
        t = tokens.shape[1]
        pe = jax.lax.dynamic_slice_in_dim(params["pos_embed"], pos_offset, t, axis=0)
        x = x + pe[None].astype(dtype)
    return x


def unembed(params, x, cfg: ArchConfig):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return jnp.einsum("btd,dv->btv", x, w.astype(x.dtype))


# ---------------------------------------------------------------------------
# Dense/MoE block application (train + prefill + decode variants).
# ---------------------------------------------------------------------------

def _attn_train(bp, x, cfg: ArchConfig, q_offset: int = 0):
    """Returns (attn_out, (k, v)) — roped k/v handed to prefill cache fill."""
    hd = cfg.resolved_head_dim
    t = x.shape[1]
    q, k, v = qkv_project(bp["attn"], x)
    pos = jnp.arange(t) + q_offset
    sin, cos = rotary(pos, hd, cfg.rope_theta)
    if cfg.pos == "rope":
        q = apply_rope(q, sin[None], cos[None])
        k = apply_rope(k, sin[None], cos[None])
    o = mha(q, k, v, causal=True, window=cfg.sliding_window, q_offset=0)
    return out_project(bp["attn"], o), (k, v)


def _mlp_apply(bp, x, cfg: ArchConfig, act=None):
    if cfg.family == "moe" and "moe" in bp:
        return moe_forward(bp["moe"], x, cfg.moe, cfg.activation, act)
    return mlp_forward(bp["mlp"], x, cfg.activation), {}


def _dense_block(bp, x, cfg: ArchConfig, act: Activations):
    a, kv = _attn_train(bp, apply_norm(bp["ln1"], x, cfg), cfg)
    x = act(x + a, "residual")
    m, aux = _mlp_apply(bp, apply_norm(bp["ln2"], x, cfg), cfg, act)
    x = act(x + m, "residual")
    return x, aux, kv


def _dense_block_decode(bp, x, cache, pos, cfg: ArchConfig, act=None):
    """One decode block. pos: per-row (B,) absolute positions (continuous
    batching decodes mixed-progress slots in one call)."""
    hd = cfg.resolved_head_dim
    b = x.shape[0]
    h = apply_norm(bp["ln1"], x, cfg)
    q, k, v = qkv_project(bp["attn"], h)
    sin, cos = rotary(pos[:, None], hd, cfg.rope_theta)  # (B,1,half)
    if cfg.pos == "rope":
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    s_len = cache["k"].shape[1]
    slot = pos % s_len if cfg.sliding_window else pos    # (B,)
    rows = jnp.arange(b)
    kc = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
    vc = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))
    o = decode_mha(q, kc, vc, pos, cache["key_pos"], window=cfg.sliding_window, act=act)
    x = x + out_project(bp["attn"], o)
    m, _ = _mlp_apply(bp, apply_norm(bp["ln2"], x, cfg), cfg, act)
    return x + m, {"k": kc, "v": vc, "key_pos": cache["key_pos"]}


# ---------------------------------------------------------------------------
# Forward (train): scan over blocks with remat.
# ---------------------------------------------------------------------------

def forward_train(params, tokens, cfg: ArchConfig, act: Activations | None = None,
                  dtype=jnp.bfloat16):
    act = act or Activations(lambda x, kind: x)
    x = act(embed_tokens(params, tokens, cfg, dtype=dtype), "embed")

    if cfg.family in ("dense", "moe"):
        @jax.checkpoint
        def body(carry, bp):
            h, lb, rz = carry
            h, aux, _ = _dense_block(bp, h, cfg, act)
            return (h, lb + aux.get("load_balance", 0.0), rz + aux.get("router_z", 0.0)), None

        (x, lb, rz), _ = jax.lax.scan(body, (x, 0.0, 0.0), params["blocks"])
        aux = {"load_balance": lb, "router_z": rz}

    elif cfg.family == "ssm":
        @jax.checkpoint
        def body(h, bp):
            o, _ = ssm_forward(bp["ssm"], apply_norm(bp["ln"], h, cfg), cfg.ssm)
            return act(h + o, "residual"), None

        x, _ = jax.lax.scan(body, x, params["blocks"])
        aux = {}

    elif cfg.family == "hybrid":
        shared = params["shared"]

        @jax.checkpoint
        def mamba_body(h, bp):
            o, _ = ssm_forward(bp["ssm"], apply_norm(bp["ln"], h, cfg), cfg.ssm)
            return act(h + o, "residual"), None

        @jax.checkpoint
        def super_body(h, blk):
            h, _ = jax.lax.scan(mamba_body, h, blk)
            h, _, _ = _dense_block(shared, h, cfg, act)
            return h, None

        x, _ = jax.lax.scan(super_body, x, params["mamba"])
        if cfg.hybrid_tail:
            x, _ = jax.lax.scan(mamba_body, x, params["tail"])
        aux = {}
    else:
        raise ValueError(cfg.family)

    x = apply_norm(params["final_norm"], x, cfg)
    logits = act(unembed(params, x, cfg), "logits")
    return logits, aux


def loss_fn(params, tokens, labels, cfg: ArchConfig, act: Activations | None = None):
    logits, aux = forward_train(params, tokens, cfg, act)
    loss = cross_entropy_loss(logits, labels, cfg.vocab_size)
    if cfg.family == "moe":
        loss = (
            loss
            + cfg.moe.load_balance_coef * aux.get("load_balance", 0.0) / cfg.num_layers
            + cfg.moe.router_z_coef * aux.get("router_z", 0.0) / cfg.num_layers
        )
    return loss


# ---------------------------------------------------------------------------
# Cache: specs + prefill + decode.
# ---------------------------------------------------------------------------

def _attn_cache_len(cfg: ArchConfig, max_seq: int) -> int:
    return min(cfg.sliding_window, max_seq) if cfg.sliding_window else max_seq


def cache_specs(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Tree of (shape, logical axes, dtype) describing the decode cache."""
    hd = cfg.resolved_head_dim if cfg.num_heads else 0
    s = _attn_cache_len(cfg, max_seq)
    kv = lambda: ((batch, s, cfg.physical_kv_heads, hd), ("batch", "cache_seq", "kv_heads", "head_dim"), dtype)
    kp = ((batch, s), ("batch", "cache_seq"), jnp.int32)

    if cfg.family in ("dense", "moe"):
        l = cfg.num_layers
        return {
            "k": _stk(kv(), l), "v": _stk(kv(), l), "key_pos": kp,
        }
    if cfg.family == "ssm":
        conv, state = ssm_cache_shapes(cfg, batch)
        l = cfg.num_layers
        return {
            "conv": _stk((*conv, dtype), l),
            "state": _stk((*state, jnp.float32), l),
        }
    if cfg.family == "hybrid":
        nb, per = _hybrid_geometry(cfg)
        conv, state = ssm_cache_shapes(cfg, batch)
        tree = {
            "mamba_conv": _stk(_stk((*conv, dtype), per, "inner"), nb),
            "mamba_state": _stk(_stk((*state, jnp.float32), per, "inner"), nb),
            "attn_k": _stk(kv(), nb), "attn_v": _stk(kv(), nb), "key_pos": kp,
        }
        if cfg.hybrid_tail:
            tree["tail_conv"] = _stk((*conv, dtype), cfg.hybrid_tail)
            tree["tail_state"] = _stk((*state, jnp.float32), cfg.hybrid_tail)
        return tree
    raise ValueError(cfg.family)


def _stk(spec3, n, axis="layers"):
    shape, axes, dt = spec3
    return ((n, *shape), (axis, *axes), dt)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    def mk(leaf):
        shape, _axes, dt = leaf
        if dt == jnp.int32:
            return jnp.full(shape, -1, dt)  # key_pos: -1 = unwritten
        return jnp.zeros(shape, dt)

    return jax.tree.map(
        mk, cache_specs(cfg, batch, max_seq, dtype),
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple),
    )


def prefill(params, tokens, cfg: ArchConfig, max_seq: int,
            act: Activations | None = None, dtype=jnp.bfloat16):
    """Full forward + cache build. Returns (last-position logits, cache)."""
    act = act or Activations(lambda x, kind: x)
    b, t = tokens.shape
    s = _attn_cache_len(cfg, max_seq)
    x = act(embed_tokens(params, tokens, cfg, dtype=dtype), "embed")

    def kv_to_cache(k, v):
        """Keep the last ``s`` roped keys; slot = position % s (ring/linear)."""
        kk, vv = k[:, -s:], v[:, -s:]
        if t < s:  # pad to cache length at the tail
            pad = [(0, 0), (0, s - t), (0, 0), (0, 0)]
            kk, vv = jnp.pad(kk, pad), jnp.pad(vv, pad)
            key_pos = jnp.concatenate(
                [jnp.arange(t), jnp.full((s - t,), -1, jnp.int32)]
            )
        else:
            first = t - s
            pos = jnp.arange(first, t)
            slots = pos % s
            kk = jnp.zeros_like(kk).at[:, slots].set(k[:, -s:])
            vv = jnp.zeros_like(vv).at[:, slots].set(v[:, -s:])
            key_pos = jnp.zeros((s,), jnp.int32).at[slots].set(pos)
        return kk.astype(dtype), vv.astype(dtype), jnp.broadcast_to(key_pos, (b, s))

    if cfg.family in ("dense", "moe"):
        def body(h, bp):
            h2, _aux, (k, v) = _dense_block(bp, h, cfg, act)
            kk, vv, key_pos = kv_to_cache(k, v)
            return h2, (kk, vv, key_pos)

        x, (ks, vs, kps) = jax.lax.scan(body, x, params["blocks"])
        cache = {"k": ks, "v": vs, "key_pos": kps[0]}

    elif cfg.family == "ssm":
        def body(h, bp):
            o, (cs, st) = ssm_forward(bp["ssm"], apply_norm(bp["ln"], h, cfg), cfg.ssm)
            return h + o, (cs.astype(dtype), st)

        x, (convs, states) = jax.lax.scan(body, x, params["blocks"])
        cache = {"conv": convs, "state": states}

    elif cfg.family == "hybrid":
        shared = params["shared"]

        def mamba_body(h, bp):
            o, (cs, st) = ssm_forward(bp["ssm"], apply_norm(bp["ln"], h, cfg), cfg.ssm)
            return h + o, (cs.astype(dtype), st)

        def super_body(h, blk):
            h, (cs, st) = jax.lax.scan(mamba_body, h, blk)
            h, _aux, (k, v) = _dense_block(shared, h, cfg, act)
            kk, vv, key_pos = kv_to_cache(k, v)
            return h, (cs, st, kk, vv, key_pos)

        x, (mc, ms, ks, vs, kps) = jax.lax.scan(super_body, x, params["mamba"])
        cache = {
            "mamba_conv": mc, "mamba_state": ms,
            "attn_k": ks, "attn_v": vs, "key_pos": kps[0],
        }
        if cfg.hybrid_tail:
            x, (tc, ts) = jax.lax.scan(mamba_body, x, params["tail"])
            cache["tail_conv"], cache["tail_state"] = tc, ts
    else:
        raise ValueError(cfg.family)

    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(params, x[:, -1:], cfg)
    return logits, cache


def decode_step(params, token, pos, cache, cfg: ArchConfig, dtype=jnp.bfloat16, act=None):
    """One decode step. token (B, 1) int32; pos scalar or per-row (B,) int32.

    Returns (logits (B, 1, V), updated cache).
    """
    b = token.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    rows = jnp.arange(b)
    x = jnp.take(params["embed"], token, axis=0).astype(dtype)
    if cfg.pos == "learned":
        pe = jnp.take(params["pos_embed"], pos, axis=0)  # (B, D)
        x = x + pe[:, None].astype(dtype)

    if cfg.family in ("dense", "moe"):
        slot = pos % cache["k"].shape[2] if cfg.sliding_window else pos
        key_pos = cache["key_pos"].at[rows, slot].set(pos)

        def body(h, layer):
            bp, kc, vc = layer
            h2, new = _dense_block_decode(
                bp, h, {"k": kc, "v": vc, "key_pos": key_pos}, pos, cfg, act
            )
            return h2, (new["k"], new["v"])

        x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs, "key_pos": key_pos}

    elif cfg.family == "ssm":
        def body(h, layer):
            bp, cs, st = layer
            o, ncs, nst = ssm_decode_step(
                bp["ssm"], apply_norm(bp["ln"], h, cfg), cs.astype(dtype), st, cfg.ssm
            )
            return h + o, (ncs.astype(cs.dtype), nst)

        x, (convs, states) = jax.lax.scan(
            body, x, (params["blocks"], cache["conv"], cache["state"])
        )
        new_cache = {"conv": convs, "state": states}

    elif cfg.family == "hybrid":
        shared = params["shared"]
        slot = pos  # hybrid attn: linear cache
        key_pos = cache["key_pos"].at[rows, slot].set(pos)

        def mamba_body(h, layer):
            bp, cs, st = layer
            o, ncs, nst = ssm_decode_step(
                bp["ssm"], apply_norm(bp["ln"], h, cfg), cs.astype(dtype), st, cfg.ssm
            )
            return h + o, (ncs.astype(cs.dtype), nst)

        def super_body(h, blk):
            bp, cs, st, kc, vc = blk
            h, (ncs, nst) = jax.lax.scan(mamba_body, h, (bp, cs, st))
            h, new = _dense_block_decode(
                shared, h, {"k": kc, "v": vc, "key_pos": key_pos}, pos, cfg, act
            )
            return h, (ncs, nst, new["k"], new["v"])

        x, (mc, ms, ks, vs) = jax.lax.scan(
            super_body, x,
            (params["mamba"], cache["mamba_conv"], cache["mamba_state"],
             cache["attn_k"], cache["attn_v"]),
        )
        new_cache = {
            "mamba_conv": mc, "mamba_state": ms,
            "attn_k": ks, "attn_v": vs, "key_pos": key_pos,
        }
        if cfg.hybrid_tail:
            x, (tc, ts) = jax.lax.scan(
                mamba_body, x,
                (params["tail"], cache["tail_conv"], cache["tail_state"]),
            )
            new_cache["tail_conv"], new_cache["tail_state"] = tc, ts
    else:
        raise ValueError(cfg.family)

    x = apply_norm(params["final_norm"], x, cfg)
    return unembed(params, x, cfg), new_cache
