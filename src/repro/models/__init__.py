"""Model zoo: scan-over-layers JAX implementations of the assigned families
(dense / moe / ssm / hybrid decoder LMs, enc-dec, vlm) behind one API."""
from . import api, attention, common, encdec, lm, mlp, ssm, vlm
from .api import Model, build

__all__ = ["api", "attention", "common", "encdec", "lm", "mlp", "ssm", "vlm",
           "Model", "build"]
