"""MLP blocks: dense (SwiGLU / GELU / squared-ReLU) and mixture-of-experts.

MoE uses capacity-based top-k routing with scatter dispatch / gather combine
(GShard-style semantics without materializing the (T, E, C) one-hot):

  1. router logits -> top-k expert ids + renormalized weights per token;
  2. slot position within each expert via a cumsum over assignments; tokens
     beyond capacity C = ceil(T*k/E * cf) are dropped (standard capacity drop);
  3. scatter tokens into the (E, C, D) expert buffer, run the batched expert
     FFN as (E,C,D) x (E,D,F) einsums (shardable over E for expert-parallel or
     over F for per-expert tensor-parallel — the PartitionSpec choice is made
     in repro.dist.sharding based on divisibility), gather back and combine.

Aux losses (Switch-style load-balance + router z-loss) are returned to the
trainer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.ops import in_manual_pod_region
from .common import ParamSpec

__all__ = [
    "mlp_param_specs",
    "mlp_forward",
    "moe_param_specs",
    "moe_forward",
]


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "squared_relu":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def mlp_param_specs(d_model: int, d_ff: int, activation: str) -> dict:
    if activation == "swiglu":
        return {
            "wi_gate": ParamSpec((d_model, d_ff), ("embed", "mlp")),
            "wi_up": ParamSpec((d_model, d_ff), ("embed", "mlp")),
            "wo": ParamSpec((d_ff, d_model), ("mlp", "embed")),
        }
    return {
        "wi": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "wo": ParamSpec((d_ff, d_model), ("mlp", "embed")),
    }


def mlp_forward(p: dict, x: jax.Array, activation: str) -> jax.Array:
    dt = x.dtype
    if activation == "swiglu":
        g = jnp.einsum("btd,df->btf", x, p["wi_gate"].astype(dt))
        u = jnp.einsum("btd,df->btf", x, p["wi_up"].astype(dt))
        h = jax.nn.silu(g) * u
    else:
        h = _act(jnp.einsum("btd,df->btf", x, p["wi"].astype(dt)), activation)
    return jnp.einsum("btf,fd->btd", h, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# Mixture of experts.
# ---------------------------------------------------------------------------

def _topk_argmax(probs: jax.Array, k: int):
    """top-k via k argmax+mask passes.

    Equivalent to lax.top_k for routing (k <= 8, E <= 64: cost is noise next
    to the expert FFNs) but built from reduce/iota ops only —
    ``jax.lax.top_k`` crashes XLA's SPMD partitioner under a manual-subgroup
    (pod) axis (CHECK target.IsManualSubgroup() == sharding()...).
    """
    remaining = probs
    ws, is_ = [], []
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)
        w = jnp.max(remaining, axis=-1)
        ws.append(w)
        is_.append(idx.astype(jnp.int32))
        remaining = remaining - jax.nn.one_hot(idx, probs.shape[-1], dtype=probs.dtype) * (
            w[..., None] + 1.0
        )
    return jnp.stack(ws, axis=-1), jnp.stack(is_, axis=-1)

def moe_param_specs(d_model: int, moe, activation: str) -> dict:
    e, f = moe.num_experts, moe.d_ff_expert
    specs = {
        "router": ParamSpec((d_model, e), ("embed", None), scale=0.02),
    }
    if activation == "swiglu":
        specs.update(
            we_gate=ParamSpec((e, d_model, f), ("expert", "embed", "mlp")),
            we_up=ParamSpec((e, d_model, f), ("expert", "embed", "mlp")),
            we_down=ParamSpec((e, f, d_model), ("expert", "mlp", "embed")),
        )
    else:
        specs.update(
            we_in=ParamSpec((e, d_model, f), ("expert", "embed", "mlp")),
            we_down=ParamSpec((e, f, d_model), ("expert", "mlp", "embed")),
        )
    if moe.num_shared_experts:
        specs["shared"] = mlp_param_specs(
            d_model, f * moe.num_shared_experts, activation
        )
    return specs


def moe_forward(
    p: dict, x: jax.Array, moe, activation: str, act=None
) -> tuple[jax.Array, dict]:
    """x (B, T, D) -> (out (B, T, D), aux losses {load_balance, router_z}).

    GShard-style GROUPED dispatch: each batch row is a routing group with its
    own capacity C = ceil(T*k/E * cf). The (B, E, C, D) buffer keeps the
    batch dim — folding all tokens into one (E, C, D) buffer would erase the
    data-parallel dimension and replicate the expert FFN across the DP axis
    (observed as a 14x compute overcount in the dry-run). Within a group,
    dispatch/combine are scatter-adds (gathers over sharded dims crash the
    SPMD partitioner under a manual pod subgroup; scatters partition fine).
    """
    b, t, d = x.shape
    e, k = moe.num_experts, moe.top_k
    cap = max(int(t * k / e * moe.capacity_factor), 1)
    dt = x.dtype

    logits = jnp.einsum("btd,de->bte", x, p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = _topk_argmax(probs, k)                          # (B, T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # --- aux losses (global batch statistics) ---
    density = jnp.mean(
        jax.nn.one_hot(topi, e, dtype=jnp.float32).sum(axis=2), axis=(0, 1)
    )
    mean_prob = probs.mean(axis=(0, 1))
    aux = {
        "load_balance": e * jnp.sum(density / k * mean_prob),
        "router_z": jnp.mean(jax.scipy.special.logsumexp(logits, -1) ** 2),
    }

    if in_manual_pod_region():
        # XLA cannot partition the batched dispatch scatter under a manual
        # (pod) subgroup on this jaxlib — use the dense-mask mixture instead:
        # every expert runs on every token, combined by the top-k gate. Pure
        # einsums (partition cleanly); costs E/top_k x the routed FLOPs, so
        # multi-pod MoE roofline cells carry a documented compute overcount.
        gate = (
            jax.nn.one_hot(topi, e, dtype=jnp.float32) * topw[..., None]
        ).sum(axis=2).astype(dt)                                 # (B, T, E)
        if activation == "swiglu":
            g = jnp.einsum("btd,edf->btef", x, p["we_gate"].astype(dt))
            u = jnp.einsum("btd,edf->btef", x, p["we_up"].astype(dt))
            h = jax.nn.silu(g) * u
        else:
            h = _act(jnp.einsum("btd,edf->btef", x, p["we_in"].astype(dt)), activation)
        y = jnp.einsum("btef,efd,bte->btd", h, p["we_down"].astype(dt), gate)
        if "shared" in p:
            y = y + mlp_forward(p["shared"], x, activation)
        return y, aux

    # --- per-group slot assignment: rank within each (group, expert) ---
    flat_e = topi.reshape(b, t * k)                              # (B, T*k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)          # (B, T*k, E)
    pos = (jnp.cumsum(onehot, axis=1) - 1) * onehot
    slot = pos.sum(axis=-1)                                      # (B, T*k)
    keep = slot < cap
    slot = jnp.where(keep, slot, cap - 1)

    # --- dispatch: per-group scatter into the (B, E, C, D) buffer ---
    # token replication is broadcast+reshape (uniform k), NOT a gather
    repeated = jnp.broadcast_to(x[:, :, None, :], (b, t, k, d)).reshape(b, t * k, d)
    vals = repeated * keep[..., None].astype(dt)
    if act is not None:
        vals = act(vals, "moe_tokens")
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    # Scatter entirely in data-parallel space (every operand sharded over B
    # only): pinning the buffer to expert-parallel BEFORE the scatter makes
    # GSPMD all-gather the (B, T*k, D) updates onto every model shard
    # (measured: 86% of moonshot train's collective bytes). Scatter locally,
    # THEN reshard the compact (B, E, C, D) buffer once — an all-to-all of
    # tokens*cf bytes, the textbook expert-parallel dispatch cost.
    buf = jnp.zeros((b, e, cap, d), dtype=dt)
    buf = buf.at[rows, flat_e, slot].add(vals, mode="drop")
    if act is not None:
        # anchor the scatter OUTPUT in dp-only space (keeps the scatter
        # local), then reshard the compact buffer to expert-parallel — two
        # back-to-back constraints force the boundary where the a2a belongs
        buf = act(buf, "moe_buf_dp")
        buf = act(buf, "moe_buf")  # (B, E, C, D): B->dp, E->model if it divides

    # --- batched expert FFN (shardable over E for EP or over F for TP) ---
    if activation == "swiglu":
        g = jnp.einsum("becd,edf->becf", buf, p["we_gate"].astype(dt))
        u = jnp.einsum("becd,edf->becf", buf, p["we_up"].astype(dt))
        h = jax.nn.silu(g) * u
    else:
        h = _act(jnp.einsum("becd,edf->becf", buf, p["we_in"].astype(dt)), activation)
    out_buf = jnp.einsum("becf,efd->becd", h, p["we_down"].astype(dt))
    if act is not None:
        # reshard the compact buffer back to data-parallel-only BEFORE the
        # combine scatter (same asymmetry as dispatch, mirrored)
        out_buf = act(out_buf, "moe_buf_dp")

    # --- combine: per-group scatter-add back to token space ---
    flat_slot = flat_e * cap + slot                              # (B, T*k)
    tok_idx = jnp.broadcast_to(
        jnp.arange(t * k, dtype=jnp.int32) // k, (b, t * k)
    )
    sentinel = t  # out-of-range row target -> dropped
    tok_of_slot = jnp.full((b, e * cap), sentinel, jnp.int32).at[rows, flat_slot].set(
        jnp.where(keep, tok_idx, sentinel), mode="drop"
    )
    w_of_slot = jnp.zeros((b, e * cap), jnp.float32).at[rows, flat_slot].set(
        jnp.where(keep, topw.reshape(b, t * k), 0.0), mode="drop"
    )
    flat_out = out_buf.reshape(b, e * cap, d)
    contrib = flat_out * w_of_slot.astype(dt)[..., None]
    y = jnp.zeros((b, t, d), dt).at[rows, tok_of_slot].add(contrib, mode="drop")
    if act is not None:
        y = act(y, "moe_tokens")

    if "shared" in p:
        y = y + mlp_forward(p["shared"], x, activation)
    return y, aux
