"""Unified model API: one entry point per (family-dispatched) operation.

The trainer, serving engine, and dry-run launcher all work against this
interface; they never touch family modules directly.

Batch layouts (all int32 tokens; stub-frontend embeddings bf16):
  dense/moe/ssm/hybrid : {tokens (B,T), labels (B,T)}
  encdec               : {frames (B,S_enc,D), tokens (B,T), labels (B,T)}
  vlm                  : {tokens (B,T), image_embeds (B,N_img,D), labels (B,T)}
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import encdec, lm, vlm
from .common import Activations, init_params

PyTree = Any

__all__ = ["Model", "build"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    param_specs: PyTree
    loss: Callable            # (params, batch, act=None) -> scalar loss
    prefill: Callable         # (params, batch, max_seq, act=None) -> (logits, cache)
    decode: Callable          # (params, token, pos, cache, act=None) -> (logits, cache')
    cache_specs: Callable     # (batch, max_seq) -> tree of (shape, axes, dtype)

    def init(self, key: jax.Array) -> PyTree:
        return init_params(self.param_specs, key)

    def batch_spec(self, batch: int, seq: int) -> dict:
        """(shape, logical axes, dtype) tree for one training batch."""
        tok = ((batch, seq), ("batch", None), jnp.int32)
        spec = {"tokens": tok, "labels": tok}
        if self.cfg.family == "encdec":
            spec["frames"] = (
                (batch, self.cfg.encoder_len, self.cfg.d_model),
                ("batch", None, "embed_act"), jnp.bfloat16,
            )
        if self.cfg.family == "vlm":
            spec["image_embeds"] = (
                (batch, self.cfg.num_image_tokens, self.cfg.d_model),
                ("batch", None, "embed_act"), jnp.bfloat16,
            )
        return spec


def build(cfg: ArchConfig) -> Model:
    if cfg.family in ("dense", "moe", "ssm", "hybrid"):
        return Model(
            cfg=cfg,
            param_specs=lm.param_specs(cfg),
            loss=lambda p, b, act=None: lm.loss_fn(p, b["tokens"], b["labels"], cfg, act),
            prefill=lambda p, b, max_seq, act=None: lm.prefill(
                p, b["tokens"], cfg, max_seq, act
            ),
            decode=lambda p, tok, pos, cache, act=None: lm.decode_step(p, tok, pos, cache, cfg, act=act),
            cache_specs=lambda batch, max_seq: lm.cache_specs(cfg, batch, max_seq),
        )
    if cfg.family == "encdec":
        return Model(
            cfg=cfg,
            param_specs=encdec.param_specs(cfg),
            loss=lambda p, b, act=None: encdec.loss_fn(
                p, b["frames"], b["tokens"], b["labels"], cfg, act
            ),
            prefill=lambda p, b, max_seq, act=None: encdec.prefill(
                p, b["frames"], b["tokens"], cfg, max_seq, act
            ),
            decode=lambda p, tok, pos, cache, act=None: encdec.decode_step(p, tok, pos, cache, cfg, act=act),
            cache_specs=lambda batch, max_seq: encdec.cache_specs(cfg, batch, max_seq),
        )
    if cfg.family == "vlm":
        return Model(
            cfg=cfg,
            param_specs=vlm.param_specs(cfg),
            loss=lambda p, b, act=None: vlm.loss_fn(
                p, b["tokens"], b["image_embeds"], b["labels"], cfg, act
            ),
            prefill=lambda p, b, max_seq, act=None: vlm.prefill(
                p, b["tokens"], b["image_embeds"], cfg, max_seq, act
            ),
            decode=lambda p, tok, pos, cache, act=None: vlm.decode_step(p, tok, pos, cache, cfg, act=act),
            cache_specs=lambda batch, max_seq: vlm.cache_specs(cfg, batch, max_seq),
        )
    raise ValueError(cfg.family)
