"""Encoder-decoder transformer (whisper-base backbone).

The audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, encoder_len, d_model) from ``input_specs``.
Encoder: bidirectional self-attention + GELU MLP, sinusoidal positions.
Decoder: causal self-attention + cross-attention to the encoder output +
GELU MLP, learned positions (table extended beyond whisper's 448 to cover
the assigned shapes — recorded in DESIGN.md).

Decode cache: per-layer self-attn KV (linear) + cross-attn KV computed once
from the encoder output at prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import attn_param_specs, decode_mha, mha, out_project, qkv_project
from .common import Activations, ParamSpec, cross_entropy_loss
from .lm import apply_norm, norm_specs, stack_specs
from .mlp import mlp_forward, mlp_param_specs

__all__ = [
    "param_specs",
    "encode",
    "forward_train",
    "loss_fn",
    "prefill",
    "decode_step",
    "cache_specs",
]


def _enc_block_specs(cfg: ArchConfig) -> dict:
    hd = cfg.resolved_head_dim
    return {
        "ln1": norm_specs(cfg),
        "attn": attn_param_specs(cfg.d_model, cfg.physical_q_heads, cfg.physical_kv_heads, hd),
        "ln2": norm_specs(cfg),
        "mlp": mlp_param_specs(cfg.d_model, cfg.d_ff, cfg.activation),
    }


def _dec_block_specs(cfg: ArchConfig) -> dict:
    hd = cfg.resolved_head_dim
    return {
        "ln1": norm_specs(cfg),
        "self_attn": attn_param_specs(cfg.d_model, cfg.physical_q_heads, cfg.physical_kv_heads, hd),
        "ln2": norm_specs(cfg),
        "cross_attn": attn_param_specs(cfg.d_model, cfg.physical_q_heads, cfg.physical_kv_heads, hd),
        "ln3": norm_specs(cfg),
        "mlp": mlp_param_specs(cfg.d_model, cfg.d_ff, cfg.activation),
    }


def param_specs(cfg: ArchConfig) -> dict:
    d, v = cfg.d_model, cfg.padded_vocab
    return {
        "embed": ParamSpec((v, d), ("vocab", "embed"), scale=0.02),
        "pos_embed": ParamSpec((32_768, d), (None, "embed"), scale=0.02),
        "enc_blocks": stack_specs(_enc_block_specs(cfg), cfg.encoder_layers),
        "enc_norm": norm_specs(cfg),
        "dec_blocks": stack_specs(_dec_block_specs(cfg), cfg.num_layers),
        "final_norm": norm_specs(cfg),
        "unembed": ParamSpec((d, v), ("embed", "vocab")),
    }


def _sinusoid(t: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def encode(params, frames, cfg: ArchConfig, act: Activations | None = None):
    """frames (B, S_enc, D) stub embeddings -> encoder output (B, S_enc, D)."""
    act = act or Activations(lambda x, k: x)
    x = frames + _sinusoid(frames.shape[1], cfg.d_model, frames.dtype)[None]

    @jax.checkpoint
    def body(h, bp):
        a_in = apply_norm(bp["ln1"], h, cfg)
        q, k, v = qkv_project(bp["attn"], a_in)
        h = h + out_project(bp["attn"], mha(q, k, v, causal=False))
        h = h + mlp_forward(bp["mlp"], apply_norm(bp["ln2"], h, cfg), cfg.activation)
        return act(h, "residual"), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return apply_norm(params["enc_norm"], x, cfg)


def _dec_block(bp, x, enc_out, cfg: ArchConfig, pos_offset: int = 0):
    """Train/prefill decoder block. Returns (x, (self_k, self_v), (cross_k, cross_v))."""
    h = apply_norm(bp["ln1"], x, cfg)
    q, k, v = qkv_project(bp["self_attn"], h)
    x = x + out_project(bp["self_attn"], mha(q, k, v, causal=True))
    h = apply_norm(bp["ln2"], x, cfg)
    cq, ck, cv = qkv_project(bp["cross_attn"], h, kv_x=enc_out)
    x = x + out_project(bp["cross_attn"], mha(cq, ck, cv, causal=False))
    x = x + mlp_forward(bp["mlp"], apply_norm(bp["ln3"], x, cfg), cfg.activation)
    return x, (k, v), (ck, cv)


def forward_train(params, frames, tokens, cfg: ArchConfig,
                  act: Activations | None = None, dtype=jnp.bfloat16):
    act = act or Activations(lambda x, k: x)
    enc_out = encode(params, frames.astype(dtype), cfg, act)
    t = tokens.shape[1]
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    x = x + params["pos_embed"][:t][None].astype(dtype)

    @jax.checkpoint
    def body(h, bp):
        h, _, _ = _dec_block(bp, h, enc_out, cfg)
        return act(h, "residual"), None

    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = apply_norm(params["final_norm"], x, cfg)
    logits = jnp.einsum("btd,dv->btv", x, params["unembed"].astype(x.dtype))
    return logits


def loss_fn(params, frames, tokens, labels, cfg: ArchConfig,
            act: Activations | None = None):
    logits = forward_train(params, frames, tokens, cfg, act)
    return cross_entropy_loss(logits, labels, cfg.vocab_size)


def cache_specs(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    l = cfg.num_layers
    kv_self = ((l, batch, max_seq, cfg.physical_kv_heads, hd),
               ("layers", "batch", "cache_seq", "kv_heads", "head_dim"), dtype)
    kv_cross = ((l, batch, cfg.encoder_len, cfg.physical_kv_heads, hd),
                ("layers", "batch", None, "kv_heads", "head_dim"), dtype)
    return {
        "self_k": kv_self, "self_v": kv_self,
        "cross_k": kv_cross, "cross_v": kv_cross,
        "key_pos": ((batch, max_seq), ("batch", "cache_seq"), jnp.int32),
    }


def prefill(params, frames, tokens, cfg: ArchConfig, max_seq: int,
            act: Activations | None = None, dtype=jnp.bfloat16):
    """Encoder pass + decoder prefill. Returns (last logits, cache)."""
    act = act or Activations(lambda x, k: x)
    enc_out = encode(params, frames.astype(dtype), cfg, act)
    b, t = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    x = x + params["pos_embed"][:t][None].astype(dtype)

    def body(h, bp):
        h, (k, v), (ck, cv) = _dec_block(bp, h, enc_out, cfg)
        pad = [(0, 0), (0, max_seq - t), (0, 0), (0, 0)]
        return h, (jnp.pad(k, pad).astype(dtype), jnp.pad(v, pad).astype(dtype),
                   ck.astype(dtype), cv.astype(dtype))

    x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["dec_blocks"])
    key_pos = jnp.concatenate(
        [jnp.arange(t, dtype=jnp.int32), jnp.full((max_seq - t,), -1, jnp.int32)]
    )
    cache = {
        "self_k": ks, "self_v": vs, "cross_k": cks, "cross_v": cvs,
        "key_pos": jnp.broadcast_to(key_pos, (b, max_seq)),
    }
    x = apply_norm(params["final_norm"], x, cfg)
    logits = jnp.einsum("btd,dv->btv", x[:, -1:], params["unembed"].astype(x.dtype))
    return logits, cache


def decode_step(params, token, pos, cache, cfg: ArchConfig, dtype=jnp.bfloat16, act=None):
    """One decoder token vs (self cache, fixed cross cache).

    pos: scalar or per-row (B,) absolute positions.
    """
    b = token.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    rows = jnp.arange(b)
    x = jnp.take(params["embed"], token, axis=0).astype(dtype)
    pe = jnp.take(params["pos_embed"], pos, axis=0)  # (B, D)
    x = x + pe[:, None].astype(dtype)
    key_pos = cache["key_pos"].at[rows, pos].set(pos)
    n_enc = cache["cross_k"].shape[2]
    enc_pos = jnp.broadcast_to(jnp.arange(n_enc, dtype=jnp.int32), (b, n_enc))
    far = jnp.full((b,), 2**30, jnp.int32)

    def body(h, layer):
        bp, kc, vc, ck, cv = layer
        a_in = apply_norm(bp["ln1"], h, cfg)
        q, k, v = qkv_project(bp["self_attn"], a_in)
        kc = kc.at[rows, pos].set(k[:, 0].astype(kc.dtype))
        vc = vc.at[rows, pos].set(v[:, 0].astype(vc.dtype))
        h = h + out_project(bp["self_attn"], decode_mha(q, kc, vc, pos, key_pos, act=act))
        c_in = apply_norm(bp["ln2"], h, cfg)
        cq = jnp.einsum("btd,dhk->bthk", c_in, bp["cross_attn"]["wq"].astype(c_in.dtype))
        h = h + out_project(
            bp["cross_attn"],
            decode_mha(cq, ck.astype(c_in.dtype), cv.astype(c_in.dtype), far, enc_pos),
        )
        h = h + mlp_forward(bp["mlp"], apply_norm(bp["ln3"], h, cfg), cfg.activation)
        return h, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x,
        (params["dec_blocks"], cache["self_k"], cache["self_v"],
         cache["cross_k"], cache["cross_v"]),
    )
    new_cache = dict(cache, self_k=ks, self_v=vs, key_pos=key_pos)
    x = apply_norm(params["final_norm"], x, cfg)
    return jnp.einsum("btd,dv->btv", x, params["unembed"].astype(x.dtype)), new_cache
