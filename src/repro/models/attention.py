"""Grouped-query attention: training (full causal / sliding-window), prefill
(causal + cache write), decode (single query vs cache), and cross-attention.

Sharding-aware design decisions (verified in the multi-pod dry-run):

  * GQA is computed by *repeating* KV heads up to the query-head count
    (a gather, cheap and shardable) rather than reshaping Q to
    (Hkv, group) — that reshape splits the model-sharded head dim and forces
    GSPMD to replicate the score computation.
  * Long sequences (q_len >= CHUNK_THRESHOLD) use a query-chunked softmax:
    a lax.scan over Q blocks materializes (B, H, Cq, S) scores instead of
    (B, H, T, S) — prefill_32k would otherwise need a 4 TB score tensor.
    Numerically identical to full softmax (each row is complete).

Scores and softmax run in fp32; masks are built from iota comparisons.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamSpec, apply_rope, rotary

__all__ = [
    "attn_param_specs",
    "qkv_project",
    "out_project",
    "mha",
    "decode_mha",
]

NEG_INF = -1e30
CHUNK_THRESHOLD = 8192   # q_len above this uses the chunked path
Q_CHUNK = 1024


def attn_param_specs(
    d_model: int, n_heads: int, n_kv: int, head_dim: int, cross: bool = False
) -> dict:
    """Q/K/V/O projection specs. ``cross`` adds a tanh gate (VLM-style)."""
    specs = {
        "wq": ParamSpec((d_model, n_heads, head_dim), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d_model, n_kv, head_dim), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d_model, n_kv, head_dim), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((n_heads, head_dim, d_model), ("heads", "head_dim", "embed")),
    }
    if cross:
        specs["gate"] = ParamSpec((1,), (None,), init="zeros")
    return specs


def qkv_project(p: dict, x: jax.Array, kv_x: jax.Array | None = None):
    """x (B,T,D) -> q (B,T,Hq,hd), k/v (B,S,Hkv,hd). kv_x: cross-attn source."""
    src = x if kv_x is None else kv_x
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(dt))
    return q, k, v


def out_project(p: dict, o: jax.Array) -> jax.Array:
    out = jnp.einsum("bthk,hkd->btd", o, p["wo"].astype(o.dtype))
    if "gate" in p:
        out = jnp.tanh(p["gate"].astype(o.dtype)) * out
    return out


def _expand_kv(k: jax.Array, h_q: int) -> jax.Array:
    """(B,S,Hkv,hd) -> (B,S,Hq,hd) by repeating each kv head G times."""
    hkv = k.shape[2]
    if hkv == h_q:
        return k
    return jnp.repeat(k, h_q // hkv, axis=2)


def _mask(qi, ki, causal: bool, window: int | None):
    m = jnp.ones(jnp.broadcast_shapes(qi.shape, ki.shape), dtype=bool)
    if causal:
        m &= ki <= qi
    if window is not None:
        m &= ki > qi - window
    return m


def _attend_block(q_blk, k, v, qi, ki, causal, window):
    """q_blk (B,C,H,hd) vs full k/v (B,S,H,hd) -> (B,C,H,hd); fp32 softmax."""
    scores = jnp.einsum(
        "bchd,bshd->bhcs", q_blk, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.float32(q_blk.shape[-1]))
    mask = _mask(qi[:, None], ki[None, :], causal, window)  # (C, S)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q_blk.dtype)
    return jnp.einsum("bhcs,bshd->bchd", probs, v)


def mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """Attention for training/prefill. q (B,T,Hq,hd), k/v (B,S,Hkv,hd).

    ``q_offset``: absolute position of q[0] relative to k[0].
    ``window``: sliding-window width (mixtral); None = unbounded.
    """
    b, t, h, hd = q.shape
    s = k.shape[1]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    ki = jnp.arange(s)

    if t <= CHUNK_THRESHOLD:
        qi = jnp.arange(t) + q_offset
        return _attend_block(q, k, v, qi, ki, causal, window)

    nq = t // Q_CHUNK
    if t % Q_CHUNK:
        raise ValueError(f"long q_len {t} must be a multiple of {Q_CHUNK}")
    q_blocks = q.reshape(b, nq, Q_CHUNK, h, hd)

    def body(_, blk):
        qb, idx = blk
        qi = idx * Q_CHUNK + jnp.arange(Q_CHUNK) + q_offset
        return None, _attend_block(qb, k, v, qi, ki, causal, window)

    _, out = jax.lax.scan(
        body, None, (jnp.moveaxis(q_blocks, 1, 0), jnp.arange(nq))
    )
    return jnp.moveaxis(out, 0, 1).reshape(b, t, h, hd)


def decode_mha(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    key_positions: jax.Array,
    *,
    window: int | None = None,
    act=None,
) -> jax.Array:
    """Single-token decode: q (B,1,Hq,hd) vs cache (B,S,Hkv,hd).

    ``key_positions`` (B, S) int32 holds the *absolute* position stored in
    each cache slot (-1 = never written): uniformly supports linear caches
    and ring buffers (windowed archs: slot = position % window). ``pos`` is
    scalar or per-row (B,) — continuous batching decodes mixed-progress
    slots in one call. Masking: written, <= pos, inside the window.
    """
    h = q.shape[2]
    k = _expand_kv(k_cache, h)
    v = _expand_kv(v_cache, h)
    if act is not None:
        k = act(k, "kv_expanded")
        v = act(v, "kv_expanded")
    scores = jnp.einsum(
        "bchd,bshd->bhcs", q, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.float32(q.shape[-1]))
    kp = key_positions
    pos = jnp.asarray(pos)
    posb = pos[:, None] if pos.ndim == 1 else pos
    valid = (kp >= 0) & (kp <= posb)
    if window is not None:
        valid &= kp > posb - window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhcs,bshd->bchd", probs, v)


def rope_qk(q, k, positions_q, positions_k, head_dim, theta):
    sin_q, cos_q = rotary(positions_q, head_dim, theta)
    sin_k, cos_k = rotary(positions_k, head_dim, theta)
    return apply_rope(q, sin_q, cos_q), apply_rope(k, sin_k, cos_k)
