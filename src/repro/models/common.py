"""Shared model substrate: param specs, norms, RoPE, embeddings, losses.

Parameters are described *declaratively*: each model family builds a nested
dict of ``ParamSpec`` (shape + logical axis names + init). From one spec tree
we derive all three views the framework needs:

  * ``init_params``     — materialized fp32 arrays (deterministic per-path keys);
  * ``abstract_params`` — ShapeDtypeStructs with NamedShardings (dry-run: no
    allocation, exact production sharding);
  * sharding rules      — ``repro.dist.sharding`` maps logical axes -> mesh axes.

Logical axis vocabulary: 'vocab', 'embed', 'heads', 'kv_heads', 'head_dim',
'mlp', 'expert', 'layers', 'ssm_heads', 'ssm_state', 'ssm_inner', 'conv',
'pod' (leading per-pod replica dim in decentralized sync mode), None.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ParamSpec",
    "init_params",
    "spec_tree_shapes",
    "rms_norm",
    "layer_norm",
    "rotary",
    "apply_rope",
    "cross_entropy_loss",
    "Activations",
]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative parameter: shape + logical sharding axes + initializer."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"       # normal | zeros | ones
    scale: float | None = None  # stddev; None => 1/sqrt(fan_in) (first dim heuristic)
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")

    def materialize(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "normal":
            fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
            std = self.scale if self.scale is not None else 1.0 / np.sqrt(fan_in)
            return (std * jax.random.normal(key, self.shape)).astype(self.dtype)
        raise ValueError(f"unknown init {self.init!r}")


def _iter_specs(tree: PyTree, path: tuple = ()):
    if isinstance(tree, ParamSpec):
        yield path, tree
    elif isinstance(tree, dict):
        for k in sorted(tree):
            yield from _iter_specs(tree[k], path + (k,))
    else:
        raise TypeError(f"unexpected node {type(tree)} at {path}")


def init_params(specs: PyTree, key: jax.Array) -> PyTree:
    """Materialize a spec tree; each leaf key is fold_in'd from its path hash
    so initialization is stable under tree edits."""

    def build(tree, path=()):
        if isinstance(tree, ParamSpec):
            sub = jax.random.fold_in(key, hash("/".join(map(str, path))) % (2**31))
            return tree.materialize(sub)
        return {k: build(v, path + (k,)) for k, v in tree.items()}

    return build(specs)


def spec_tree_shapes(specs: PyTree) -> PyTree:
    """Spec tree -> matching tree of (shape, axes) tuples (for tests/docs)."""

    def conv(tree):
        if isinstance(tree, ParamSpec):
            return (tree.shape, tree.axes)
        return {k: conv(v) for k, v in tree.items()}

    return conv(specs)


# ---------------------------------------------------------------------------
# NN primitives. Compute dtype is the input dtype (bf16 in production paths);
# normalization statistics and softmax always run in fp32.
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def layer_norm(
    x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5
) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


def rotary(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """(sin, cos) tables for ``positions`` (any shape) -> (+ (head_dim/2,))."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x (..., T, H, head_dim); sin/cos (..., T, head_dim/2) (broadcast over H)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s, c = sin[..., None, :], cos[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def cross_entropy_loss(
    logits: jax.Array,
    labels: jax.Array,
    vocab_size: int,
    z_coef: float = 1e-4,
) -> jax.Array:
    """Mean token NLL over a (B, T, V_padded) logits block.

    Columns >= vocab_size (physical padding for TP divisibility) are masked to
    -inf before the softmax. A small z-loss keeps the partition function
    centred (production stability; set z_coef=0 to disable).
    """
    v_pad = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    col = jax.lax.broadcasted_iota(jnp.int32, (v_pad,), 0)
    if v_pad != vocab_size:
        logits = jnp.where(col < vocab_size, logits, -1e30)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    # gold logit via masked sum, NOT take_along_axis: a gather over the
    # TP-sharded vocab dim would make GSPMD all-gather the full logits
    # (16.8 GB/device at train_4k); the masked sum reduces shard-locally.
    gold = jnp.sum(jnp.where(col == labels[..., None], logits, 0.0), axis=-1)
    nll = (lse - gold).mean()
    if z_coef:
        nll = nll + z_coef * (lse * lse).mean()
    return nll


@dataclasses.dataclass
class Activations:
    """Activation-sharding annotations threaded through model forward passes."""

    constrain: Any  # callable(x, kind) -> x (with_sharding_constraint or identity)

    def __call__(self, x, kind: str):
        return self.constrain(x, kind)


def no_constraint() -> Activations:
    return Activations(constrain=lambda x, kind: x)
