"""Vision-language decoder (llama-3.2-vision backbone).

Text decoder with a gated cross-attention layer to image patch embeddings
after every ``cross_attn_every`` self-attention layers (llama-3.2-vision: one
cross layer per 4 self layers, 8 + 32 = 40). The ViT tower + projector are a
STUB per the assignment: ``input_specs`` provides precomputed patch
embeddings (B, num_image_tokens, d_model).

Structure: scan over superblocks of (cross_attn_every self layers + 1 gated
cross layer). Cross K/V are position-independent (no RoPE on image tokens)
and cached once at prefill.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import attn_param_specs, decode_mha, mha, out_project, qkv_project
from .common import Activations, ParamSpec, cross_entropy_loss
from .lm import (
    _dense_block,
    _dense_block_decode,
    apply_norm,
    dense_block_specs,
    norm_specs,
    stack_specs,
)
from .mlp import mlp_forward, mlp_param_specs

__all__ = [
    "param_specs",
    "forward_train",
    "loss_fn",
    "prefill",
    "decode_step",
    "cache_specs",
]


def _geometry(cfg: ArchConfig) -> tuple[int, int]:
    per = cfg.cross_attn_every
    nb = cfg.num_layers // (per + 1)
    if nb * (per + 1) != cfg.num_layers:
        raise ValueError("num_layers must be divisible by cross_attn_every + 1")
    return nb, per


def _cross_block_specs(cfg: ArchConfig) -> dict:
    hd = cfg.resolved_head_dim
    return {
        "ln1": norm_specs(cfg),
        "attn": attn_param_specs(
            cfg.d_model, cfg.physical_q_heads, cfg.physical_kv_heads, hd, cross=True
        ),
        "ln2": norm_specs(cfg),
        "mlp": mlp_param_specs(cfg.d_model, cfg.d_ff, cfg.activation),
    }


def param_specs(cfg: ArchConfig) -> dict:
    nb, per = _geometry(cfg)
    d, v = cfg.d_model, cfg.padded_vocab
    self_cfg = dataclasses.replace(cfg, family="dense")
    inner = stack_specs(dense_block_specs(self_cfg), per, axis="inner")
    return {
        "embed": ParamSpec((v, d), ("vocab", "embed"), scale=0.02),
        "self_blocks": stack_specs(inner, nb),
        "cross_blocks": stack_specs(_cross_block_specs(cfg), nb),
        "final_norm": norm_specs(cfg),
        "unembed": ParamSpec((d, v), ("embed", "vocab")),
    }


def _cross_apply(bp, x, image_embeds, cfg: ArchConfig):
    """Gated cross-attention + MLP. Returns (x, (ck, cv))."""
    h = apply_norm(bp["ln1"], x, cfg)
    q, ck, cv = qkv_project(bp["attn"], h, kv_x=image_embeds)
    x = x + out_project(bp["attn"], mha(q, ck, cv, causal=False))
    x = x + mlp_forward(bp["mlp"], apply_norm(bp["ln2"], x, cfg), cfg.activation)
    return x, (ck, cv)


def forward_train(params, tokens, image_embeds, cfg: ArchConfig,
                  act: Activations | None = None, dtype=jnp.bfloat16):
    act = act or Activations(lambda x, k: x)
    img = image_embeds.astype(dtype)
    x = act(jnp.take(params["embed"], tokens, axis=0).astype(dtype), "embed")
    self_cfg = dataclasses.replace(cfg, family="dense")

    @jax.checkpoint
    def super_body(h, blk):
        sp, cp = blk

        def self_body(hh, bp):
            hh, _, _ = _dense_block(bp, hh, self_cfg, act)
            return hh, None

        h, _ = jax.lax.scan(self_body, h, sp)
        h, _ = _cross_apply(cp, h, img, cfg)
        return act(h, "residual"), None

    x, _ = jax.lax.scan(super_body, x, (params["self_blocks"], params["cross_blocks"]))
    x = apply_norm(params["final_norm"], x, cfg)
    return act(jnp.einsum("btd,dv->btv", x, params["unembed"].astype(x.dtype)), "logits")


def loss_fn(params, tokens, image_embeds, labels, cfg: ArchConfig,
            act: Activations | None = None):
    logits = forward_train(params, tokens, image_embeds, cfg, act)
    return cross_entropy_loss(logits, labels, cfg.vocab_size)


def cache_specs(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    nb, per = _geometry(cfg)
    hd = cfg.resolved_head_dim
    kv_self = ((nb, per, batch, max_seq, cfg.physical_kv_heads, hd),
               ("layers", "inner", "batch", "cache_seq", "kv_heads", "head_dim"), dtype)
    kv_cross = ((nb, batch, cfg.num_image_tokens, cfg.physical_kv_heads, hd),
                ("layers", "batch", None, "kv_heads", "head_dim"), dtype)
    return {
        "self_k": kv_self, "self_v": kv_self,
        "cross_k": kv_cross, "cross_v": kv_cross,
        "key_pos": ((batch, max_seq), ("batch", "cache_seq"), jnp.int32),
    }


def prefill(params, tokens, image_embeds, cfg: ArchConfig, max_seq: int,
            act: Activations | None = None, dtype=jnp.bfloat16):
    act = act or Activations(lambda x, k: x)
    img = image_embeds.astype(dtype)
    b, t = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    self_cfg = dataclasses.replace(cfg, family="dense")
    pad = [(0, 0), (0, max_seq - t), (0, 0), (0, 0)]

    def super_body(h, blk):
        sp, cp = blk

        def self_body(hh, bp):
            hh, _, (k, v) = _dense_block(bp, hh, self_cfg, act)
            return hh, (jnp.pad(k, pad).astype(dtype), jnp.pad(v, pad).astype(dtype))

        h, (ks, vs) = jax.lax.scan(self_body, h, sp)
        h, (ck, cv) = _cross_apply(cp, h, img, cfg)
        return h, (ks, vs, ck.astype(dtype), cv.astype(dtype))

    x, (ks, vs, cks, cvs) = jax.lax.scan(
        super_body, x, (params["self_blocks"], params["cross_blocks"])
    )
    key_pos = jnp.concatenate(
        [jnp.arange(t, dtype=jnp.int32), jnp.full((max_seq - t,), -1, jnp.int32)]
    )
    cache = {
        "self_k": ks, "self_v": vs, "cross_k": cks, "cross_v": cvs,
        "key_pos": jnp.broadcast_to(key_pos, (b, max_seq)),
    }
    x = apply_norm(params["final_norm"], x, cfg)
    logits = jnp.einsum("btd,dv->btv", x[:, -1:], params["unembed"].astype(x.dtype))
    return logits, cache


def decode_step(params, token, pos, cache, cfg: ArchConfig, dtype=jnp.bfloat16, act=None):
    b = token.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    rows = jnp.arange(b)
    x = jnp.take(params["embed"], token, axis=0).astype(dtype)
    self_cfg = dataclasses.replace(cfg, family="dense")
    key_pos = cache["key_pos"].at[rows, pos].set(pos)
    n_img = cache["cross_k"].shape[2]
    img_pos = jnp.broadcast_to(jnp.arange(n_img, dtype=jnp.int32), (b, n_img))
    far = jnp.full((b,), 2**30, jnp.int32)

    def super_body(h, blk):
        sp, kcs, vcs, cp, ck, cv = blk

        def self_body(hh, layer):
            bp, kc, vc = layer
            hh, new = _dense_block_decode(
                bp, hh, {"k": kc, "v": vc, "key_pos": key_pos}, pos, self_cfg, act
            )
            return hh, (new["k"], new["v"])

        h, (ks, vs) = jax.lax.scan(self_body, h, (sp, kcs, vcs))
        c_in = apply_norm(cp["ln1"], h, cfg)
        cq = jnp.einsum("btd,dhk->bthk", c_in, cp["attn"]["wq"].astype(c_in.dtype))
        h = h + out_project(
            cp["attn"],
            decode_mha(cq, ck.astype(c_in.dtype), cv.astype(c_in.dtype), far, img_pos),
        )
        h = h + mlp_forward(cp["mlp"], apply_norm(cp["ln2"], h, cfg), cfg.activation)
        return h, (ks, vs)

    x, (ks, vs) = jax.lax.scan(
        super_body, x,
        (params["self_blocks"], cache["self_k"], cache["self_v"],
         params["cross_blocks"], cache["cross_k"], cache["cross_v"]),
    )
    new_cache = dict(cache, self_k=ks, self_v=vs, key_pos=key_pos)
    x = apply_norm(params["final_norm"], x, cfg)
    return jnp.einsum("btd,dv->btv", x, params["unembed"].astype(x.dtype)), new_cache
