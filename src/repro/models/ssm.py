"""Mamba-2 block (SSD) — train/prefill via the Pallas chunked kernel,
single-token decode via the explicit recurrence.

Per block (d = d_model, di = expand*d, H = di/P heads, P head_dim, N state):

    z  = x Wz                      (gate branch, di)
    xs = silu(causal_conv1d(x Wx)) (conv branch, di)
    B  = x Wb   (G groups x N, broadcast to heads)
    C  = x Wc
    dt = softplus(x Wdt + dt_bias) (H,)
    a  = -exp(a_log) * dt          (per-head log decay, <= 0)
    y  = SSD(xs*dt, a, B, C) + d_skip * xs
    out = (rmsnorm(y * silu(z))) Wout

Decode keeps (conv_state (K-1, di), ssm_state (H, N, P)) per layer and applies
the O(1) per-token recurrence h' = exp(a) h + dt * B (x) x ;  y = C . h'.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from .common import ParamSpec, rms_norm

__all__ = [
    "ssm_param_specs",
    "ssm_forward",
    "ssm_decode_step",
    "ssm_cache_shapes",
]


def ssm_param_specs(d_model: int, ssm, num_heads_override: int | None = None) -> dict:
    di = ssm.expand * d_model
    h = di // ssm.head_dim
    g, n = ssm.num_groups, ssm.state_dim
    return {
        "wz": ParamSpec((d_model, di), ("embed", "ssm_inner")),
        "wx": ParamSpec((d_model, di), ("embed", "ssm_inner")),
        "wb": ParamSpec((d_model, g * n), ("embed", None)),
        "wc": ParamSpec((d_model, g * n), ("embed", None)),
        "wdt": ParamSpec((d_model, h), ("embed", "ssm_heads"), scale=0.02),
        "dt_bias": ParamSpec((h,), ("ssm_heads",), init="zeros"),
        "a_log": ParamSpec((h,), ("ssm_heads",), init="ones"),
        "d_skip": ParamSpec((h,), ("ssm_heads",), init="ones"),
        "conv_w": ParamSpec((ssm.conv_kernel, di), ("conv", "ssm_inner"), scale=0.5),
        "gnorm": ParamSpec((di,), ("ssm_inner",), init="ones"),
        "wout": ParamSpec((di, d_model), ("ssm_inner", "embed")),
    }


def _proj(x, w, dt):
    return jnp.einsum("btd,df->btf", x, w.astype(dt))


def _causal_conv(xs: jax.Array, conv_w: jax.Array, carry: jax.Array | None = None):
    """Depthwise causal conv along T. xs (B,T,di), conv_w (K,di).

    carry: optional (B, K-1, di) previous context (prefill continuation);
    returns (out (B,T,di), new_carry (B,K-1,di)).
    """
    k = conv_w.shape[0]
    b, t, di = xs.shape
    if carry is None:
        carry = jnp.zeros((b, k - 1, di), dtype=xs.dtype)
    ext = jnp.concatenate([carry, xs], axis=1)           # (B, T+K-1, di)
    out = jnp.zeros_like(xs)
    for i in range(k):  # K is tiny (4): unrolled taps, fuses to FMAs
        out = out + ext[:, i : i + t, :] * conv_w[i][None, None, :].astype(xs.dtype)
    new_carry = ext[:, t:, :]
    return out, new_carry


def _branches(p: dict, x: jax.Array, ssm):
    """Common projections: returns (z, xs_preconv, bmat, cmat, dt, a_coef)."""
    dt_ = x.dtype
    b, t, _ = x.shape
    di = p["wz"].shape[1]
    h = p["wdt"].shape[1]
    g, n = ssm.num_groups, ssm.state_dim
    z = _proj(x, p["wz"], dt_)
    xs = _proj(x, p["wx"], dt_)
    bm = _proj(x, p["wb"], dt_).reshape(b, t, g, n)
    cm = _proj(x, p["wc"], dt_).reshape(b, t, g, n)
    dt_raw = _proj(x, p["wdt"], dt_).astype(jnp.float32) + p["dt_bias"]
    dt_v = jax.nn.softplus(dt_raw)                       # (B,T,H) fp32
    a = -jnp.exp(p["a_log"].astype(jnp.float32)) * dt_v  # <= 0
    return z, xs, bm, cm, dt_v, a


def _broadcast_groups(m: jax.Array, heads: int) -> jax.Array:
    """(B,T,G,N) -> (B,T,H,N) by repeating each group over its heads."""
    b, t, g, n = m.shape
    rep = heads // g
    return jnp.repeat(m, rep, axis=2) if rep > 1 else m


def ssm_forward(
    p: dict,
    x: jax.Array,
    ssm,
    state: tuple[jax.Array, jax.Array] | None = None,
):
    """Full-sequence forward. x (B,T,D).

    Returns (out (B,T,D), (conv_state, ssm_state)) — states returned for
    prefill-to-decode handoff.
    """
    b, t, d = x.shape
    di = p["wz"].shape[1]
    hp = ssm.head_dim
    h = di // hp
    z, xs, bm, cm, dt_v, a = _branches(p, x, ssm)
    conv_carry = state[0] if state is not None else None
    h0 = state[1] if state is not None else None
    xs, conv_state = _causal_conv(xs, p["conv_w"], conv_carry)
    xs = jax.nn.silu(xs)
    xh = xs.reshape(b, t, h, hp)
    x_in = xh * dt_v[..., None].astype(xh.dtype)
    # B/C stay grouped (B, T, G, ds): the SSD kernel group-maps heads in-grid
    y, h_fin = kops.ssd_scan(x_in, a, bm, cm, h0=h0, chunk=ssm.chunk)
    y = y.astype(x.dtype) + p["d_skip"].astype(x.dtype)[None, None, :, None] * xh
    y = y.reshape(b, t, di)
    y = rms_norm(y * jax.nn.silu(z), p["gnorm"])
    out = jnp.einsum("btf,fd->btd", y, p["wout"].astype(x.dtype))
    return out, (conv_state, h_fin)


def ssm_decode_step(
    p: dict,
    x: jax.Array,                       # (B, 1, D)
    conv_state: jax.Array,              # (B, K-1, di)
    ssm_state: jax.Array,               # (B, H, N, P) fp32
    ssm,
):
    """O(1) per-token recurrence. Returns (out (B,1,D), conv_state', ssm_state')."""
    b, _, d = x.shape
    di = p["wz"].shape[1]
    hp = ssm.head_dim
    h = di // hp
    z, xs, bm, cm, dt_v, a = _branches(p, x, ssm)
    # conv over the rolling window
    window = jnp.concatenate([conv_state, xs], axis=1)   # (B, K, di)
    conv_out = jnp.einsum("bkf,kf->bf", window, p["conv_w"].astype(xs.dtype))
    new_conv = window[:, 1:, :]
    xs1 = jax.nn.silu(conv_out)                          # (B, di)
    xh = xs1.reshape(b, h, hp)
    bmat = _broadcast_groups(bm, h)[:, 0]                # (B, H, N)
    cmat = _broadcast_groups(cm, h)[:, 0]
    dt1 = dt_v[:, 0]                                     # (B, H)
    a1 = a[:, 0]                                         # (B, H)
    x_in = (xh * dt1[..., None].astype(xh.dtype)).astype(jnp.float32)
    new_state = (
        jnp.exp(a1)[..., None, None] * ssm_state
        + jnp.einsum("bhn,bhp->bhnp", bmat.astype(jnp.float32), x_in)
    )
    y = jnp.einsum("bhn,bhnp->bhp", cmat.astype(jnp.float32), new_state)
    y = y.astype(x.dtype) + p["d_skip"].astype(x.dtype)[None, :, None] * xh
    y = y.reshape(b, 1, di)
    y = rms_norm(y * jax.nn.silu(z), p["gnorm"])
    out = jnp.einsum("btf,fd->btd", y, p["wout"].astype(x.dtype))
    return out, new_conv, new_state


def ssm_cache_shapes(cfg, batch: int):
    """(conv_state shape/axes, ssm_state shape/axes) for one layer."""
    ssm = cfg.ssm
    di = ssm.expand * cfg.d_model
    h = di // ssm.head_dim
    conv = ((batch, ssm.conv_kernel - 1, di), ("batch", None, "ssm_inner"))
    state = ((batch, h, ssm.state_dim, ssm.head_dim), ("batch", "ssm_heads", None, None))
    return conv, state
