"""Serving example: continuous-batching decode over mixed-length requests.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "minicpm-2b", "--requests", "6", "--max-batch", "3",
          "--new-tokens", "12"])
