"""Quickstart: the paper in 40 lines.

Builds a poorly-connected network (chain of 100 nodes), runs standard
distributed averaging vs the paper's two-tap accelerated consensus with the
Theorem-1 optimal mixing parameter (initialized by the decentralized
Algorithm 1), and prints the measured speedup.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import accel, doi, metrics, simulator, topology, weights

N = 100
g = topology.chain(N)
w = weights.metropolis_hastings(g)

# --- decentralized initialization (Algorithm 1): estimate lambda_2(W) ---
est = doi.estimate_lambda2(w, g, num_iters=N * N, normalize_every=10)
theta = accel.theta_asymptotic(0.5)            # (-1/2, 0, 3/2): gamma = sqrt(2)
alpha = accel.alpha_star(est.lambda2_hat, theta)  # Theorem 1, Eq. (14)
print(f"lambda2 = {accel.lambda2(w):.6f}  (Algorithm-1 estimate {est.lambda2_hat:.6f}, "
      f"{est.total_ticks} communication ticks)")
print(f"alpha*  = {alpha:.4f}; rho drops {accel.lambda2(w):.6f} -> "
      f"{accel.rho_accel(est.lambda2_hat, theta):.6f}")

# --- run both algorithms from the paper's Slope initialization ---
x0 = metrics.slope_init(g.coords, N)
xbar = np.full(N, x0.mean())
t_mem = metrics.averaging_time(lambda s: w @ s, x0, xbar, eps=1e-5)

x, xp = x0.copy(), x0.copy()
err0 = np.linalg.norm(x0 - xbar)
for t_acc in range(1, 10**6):
    x, xp = accel.accelerated_step(w, x, xp, alpha, theta)
    if np.linalg.norm(x - xbar) <= 1e-5 * err0:
        break

print(f"averaging time to 1e-5: memoryless = {t_mem} iters, "
      f"accelerated = {t_acc} iters  ->  {t_mem/t_acc:.1f}x fewer "
      f"(Theorem 3: Theta(N) on a chain)")
