"""Decentralized training demo: 4 'pods' on this host, gradients synchronized
by the paper's accelerated gossip instead of an all-reduce.

Spawns a subprocess with 4 XLA host devices (the flag must be set before jax
initializes), builds the (pod=4, data=1, model=1) mesh, and trains a small LM
with sync modes {allreduce, gossip, accel_gossip}, printing the loss curves
and the consensus round counts (accel needs ~sqrt of the memoryless rounds).

    PYTHONPATH=src python examples/consensus_training.py
"""
import os
import subprocess
import sys

INNER = r"""
import os
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import build
from repro.dist import make_train_step, SyncConfig
from repro.data import SyntheticStream
from repro import optim

cfg = get_config("yi-9b", smoke=True)
model = build(cfg)
opt = optim.adamw(3e-3)
mesh = jax.make_mesh((4, 1, 1), ("pod", "data", "model"))
stream = SyntheticStream(cfg, global_batch=16, seq_len=64, seed=0)

for mode in ("allreduce", "gossip", "accel_gossip"):
    ts = make_train_step(model, opt, mesh, SyncConfig(mode=mode, eps=1e-3),
                         global_batch=16, seq_len=64)
    params, opt_state = ts.init_state(jax.random.PRNGKey(0), model, opt)
    step = jax.jit(ts.fn, donate_argnums=(0, 1))
    losses = []
    for i in range(40):
        batch = jax.tree.map(jnp.asarray, stream.batch_at(i))
        if ts.pod_stacked:
            batch = jax.tree.map(lambda t: t.reshape(4, 4, *t.shape[1:]), batch)
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(np.mean(np.asarray(m["loss"]))))
    rounds = ts.rounds if ts.fabric else 0
    lam2 = ts.fabric.lambda2 if ts.fabric else 0.0
    print(f"{mode:13s} rounds/step={rounds:3d} lambda2={lam2:.3f} "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
print("accel_gossip reaches the same loss as allreduce with bounded-staleness")
print("gradient mixing; rounds ratio gossip/accel shows the paper's speedup.")
"""


def main():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", INNER], env=env)
    sys.exit(r.returncode)


if __name__ == "__main__":
    main()
