"""End-to-end driver: train a ~100M-param LM for a few hundred steps on the
synthetic stream, with checkpointing + auto-resume.

whisper-base's full config is ~100M params and fits CPU memory, so this
trains the REAL config (not the smoke reduction) at short sequence length;
loss visibly drops on the learnable synthetic stream.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (default; full 2B does not fit CPU)")
    args = ap.parse_args()

    losses, _ = train_loop(
        args.arch,
        smoke=args.smoke,
        steps=args.steps,
        global_batch=16,
        seq_len=128,
        lr=3e-3,
        ckpt_dir="/tmp/repro_ckpt_example",
        ckpt_every=100,
        resume="auto",
        log_every=25,
    )
    drop = losses[0] - losses[-1]
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} (drop {drop:.3f}) over "
          f"{len(losses)} steps")
    assert drop > 0.5, "model should learn the synthetic affine-recurrence stream"


if __name__ == "__main__":
    main()
