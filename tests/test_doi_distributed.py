"""Algorithm 1 agreement: host simulation vs in-mesh SPMD vs dense solve.

Three layers of the same algorithm must agree:

* ``core.doi.estimate_lambda2`` (numpy network simulation) tracks the dense
  ``lambda_2(W)`` across the paper's topology families;
* the in-mesh ``dist.gossip.distributed_lambda2`` (shard_map over a 'pod'
  axis, subprocess with forced host devices) tracks the dense value too;
* host and in-mesh agree **bit for bit** at P <= 8 when the host runs the
  fabric matvec with the backend's mul+add contraction recipe
  (``fabric_matvec(w, "fma")``) — same ops, same order, same roundings.

The FP footnote the tests encode: rounding re-injects a lambda_1=1 (mean)
component that the W-applications amplify by (1/lambda_2)^K, so K must stay
moderate on fast-mixing graphs — float64 in-mesh runs use K=40 and the f32
sanity check uses K=16.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import accel, doi, topology, weights

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 420, x64: bool = True) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    if x64:
        env["JAX_ENABLE_X64"] = "1"
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=ROOT,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# Host Algorithm 1 vs dense lambda_2 across the paper's topology families.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make,num_iters", [
    (lambda rng: topology.chain(20), 400),        # chain: K ~ N^2 (Sec III-D)
    (lambda rng: topology.ring(24), 600),
    (lambda rng: topology.grid2d(5), 200),
    (lambda rng: topology.random_geometric(60, rng), 160),
])
def test_host_doi_tracks_dense(make, num_iters, rng):
    g = make(rng)
    w = weights.metropolis_hastings(g)
    lam2 = accel.lambda2(w)
    res = doi.estimate_lambda2(w, g, num_iters=num_iters, normalize_every=10, rng=rng)
    assert abs(res.lambda2_hat - lam2) / lam2 < 5e-3, (res.lambda2_hat, lam2)


def test_host_doi_rgg_draws_regression(rng):
    """Multiple RGG draws: every draw tracks its own dense solve."""
    for _ in range(3):
        g = topology.random_geometric(50, rng)
        w = weights.metropolis_hastings(g)
        lam2 = accel.lambda2(w)
        res = doi.estimate_lambda2(w, g, num_iters=150, normalize_every=10, rng=rng)
        assert abs(res.lambda2_hat - lam2) / lam2 < 1e-2


def test_fabric_matvec_matches_dense_application(rng):
    """Both contraction recipes of the host mirror are exact matvecs up to
    rounding — the permutation decomposition covers every edge exactly once."""
    from repro.dist.gossip import fabric_matvec, make_fabric

    for p, kind in [(4, "ring"), (7, "ring"), (6, "chain"), (2, "chain")]:
        fab = make_fabric(p, kind)
        v = rng.standard_normal(p)
        dense = fab.w @ v
        for contraction in ("fma", "none"):
            out = fabric_matvec(fab.w, contraction)(v)
            np.testing.assert_allclose(out, dense, atol=1e-12)


# ---------------------------------------------------------------------------
# In-mesh Algorithm 1 (subprocess: forced host devices).
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_inmesh_doi_tracks_dense_f64():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist import make_fabric, distributed_lambda2
        # K per graph: (lambda3/lambda2)^K must undercut the tolerance
        # (chain's gap ratio ~0.73 needs K ~ N^2, Sec III-D)
        for p, kind, k in [(8, "ring", 40), (6, "chain", 160), (4, "chain", 40)]:
            fab = make_fabric(p, kind)
            mesh = jax.make_mesh((p,), ("pod",))
            def est(key):
                return distributed_lambda2("pod", p, key, num_iters=k,
                                           fabric=fab, dtype=jnp.float64)[None]
            f = shard_map(est, mesh=mesh, in_specs=P(), out_specs=P("pod"),
                          check_rep=False)
            lam = jax.jit(f)(jax.random.PRNGKey(0))
            err = abs(float(lam[0]) - fab.lambda2)
            assert err < 1e-6, (p, kind, float(lam[0]), fab.lambda2)
            # every pod ends with the same number (max-consensus is exact)
            assert len({float(x) for x in lam}) == 1
        print("OK inmesh f64")
    """)
    assert "OK inmesh f64" in out


@pytest.mark.slow
def test_inmesh_doi_f32_moderate_k():
    """float32 sanity: with K small enough that the (1/lambda2)^K mean
    re-injection stays below tolerance, single precision still tracks."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist import make_fabric, distributed_lambda2
        fab = make_fabric(8, "ring")
        mesh = jax.make_mesh((8,), ("pod",))
        def est(key):
            return distributed_lambda2("pod", 8, key, num_iters=16,
                                       normalize_every=4, fabric=fab,
                                       dtype=jnp.float32)[None]
        f = shard_map(est, mesh=mesh, in_specs=P(), out_specs=P("pod"),
                      check_rep=False)
        lam = float(jax.jit(f)(jax.random.PRNGKey(0))[0])
        assert abs(lam - fab.lambda2) < 1e-3, (lam, fab.lambda2)
        print("OK inmesh f32", lam)
    """, x64=False)
    assert "OK inmesh f32" in out


@pytest.mark.slow
def test_inmesh_doi_bitwise_matches_host_p_le_8():
    """P <= 8, float64: the jitted SPMD trajectory and the host core/doi.py
    simulation (driven through the fabric matvec mirror) agree bit for bit.

    The host mirrors the backend's mul+add contraction; if a backend ever
    stops emitting fmas, the 'none' recipe covers it — the assertion is that
    ONE arithmetic model reproduces the mesh exactly, for every graph tried.
    """
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core import doi, topology
        from repro.dist import make_fabric, distributed_lambda2, fabric_matvec
        graphs = [(4, "ring", topology.ring(4)), (6, "chain", topology.chain(6)),
                  (8, "ring", topology.ring(8))]
        for p, kind, g in graphs:
            fab = make_fabric(p, kind)
            v0 = np.random.default_rng(7).standard_normal(p)
            mesh = jax.make_mesh((p,), ("pod",))
            def est(_):
                return distributed_lambda2("pod", p, None, num_iters=40,
                                           fabric=fab, v_init=v0,
                                           dtype=jnp.float64)[None]
            f = shard_map(est, mesh=mesh, in_specs=P(), out_specs=P("pod"),
                          check_rep=False)
            lam_mesh = np.asarray(jax.jit(f)(jnp.zeros(())))
            hosts = {
                c: doi.estimate_lambda2(
                    fab.w, g, num_iters=40, normalize_every=10,
                    v_init=v0.copy(), matvec=fabric_matvec(fab.w, c),
                ).lambda2_hat
                for c in ("fma", "none")
            }
            match = [c for c, lam in hosts.items()
                     if all(float(x) == lam for x in lam_mesh)]
            assert match, (p, kind, float(lam_mesh[0]), hosts)
            print(p, kind, "bitwise via", match[0])
        print("OK bitwise")
    """)
    assert "OK bitwise" in out
