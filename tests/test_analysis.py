"""Static-analysis subsystem tests (repro.analysis).

Green path: every seed registration passes all four passes, and the passes
provably never execute a simulation round (the scan/pallas impls and the
engine entry are boobytrapped during the run). Red path: each
deliberately-broken fixture trips exactly its pass with the expected rule
id. Plus the satellite seams: fail-fast registration, the cp-counter
reset/context API, the checkify runtime twin, and the CLI.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import algorithms as alg
from repro.analysis import (
    AnalysisFinding,
    fixtures,
    has_errors,
    render_markdown,
    render_text,
    run_all_checks,
)
from repro.analysis.coefficient import traced_coef_sites
from repro.analysis.__main__ import main as analysis_main


def _convex(x, a, b, c):
    return jnp.broadcast_to(
        jnp.asarray([a, b, c], jnp.float32), (x.shape[0], 3))


# ---------------------------------------------------------------------------
# Green path — shared across assertions because the full run is expensive.
# The boobytraps make this single run double as the no-execution proof:
# if any pass evaluated a scan, a pallas kernel, or the engine itself, the
# run would raise instead of returning findings.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def seed_findings():
    from jax._src.lax.control_flow.loops import scan_p
    from jax._src.pallas.pallas_call import pallas_call_p

    from repro.sweep import engine

    def _boom(kind):
        def impl(*a, **k):
            raise AssertionError(
                f"static analysis must not execute {kind} — jaxpr "
                f"inspection only")
        return impl

    old_scan, old_pallas = scan_p.impl, pallas_call_p.impl
    old_run_batch = engine.run_batch
    scan_p.def_impl(_boom("a scan"))
    pallas_call_p.def_impl(_boom("a pallas kernel"))
    engine.run_batch = _boom("the sweep engine")
    try:
        findings = run_all_checks()
    finally:
        scan_p.def_impl(old_scan)
        pallas_call_p.def_impl(old_pallas)
        engine.run_batch = old_run_batch
    return findings


def test_seed_registry_all_contracts_green(seed_findings):
    errors = [f for f in seed_findings if f.severity == "error"]
    assert not errors, render_text(errors)


def test_traced_stream_reported_for_adaptive_only(seed_findings):
    traced = [f for f in seed_findings if f.rule == "coef-mass-traced"]
    assert [f.obj for f in traced] == ["accel_adapt"]
    assert traced[0].severity == "info"


def test_findings_carry_source_locations(seed_findings):
    assert seed_findings, "expected at least the advisory findings"
    for f in seed_findings:
        assert f.passname and f.rule
        assert f.file.endswith(".py") and f.line >= 0, f


def test_dist_coverage_advisories_respect_exempt_list(seed_findings):
    from repro.dist.gossip import DIST_EXEMPT

    advisories = {f.obj for f in seed_findings
                  if f.rule == "mesh-dist-coverage"}
    assert not advisories & set(DIST_EXEMPT)
    covered = {n for n in alg.registered_algorithms()
               if alg.dist_variant(n) is not None}
    assert advisories == set(alg.registered_algorithms()) - covered \
        - set(DIST_EXEMPT)


def test_traced_site_classifier():
    # adaptive stream: data-dependent -> guarded; poly_filter's Horner taps
    # are merely tick-dependent (and individually non-convex by design):
    # NOT guarded — the runtime twin would misfire on them.
    assert traced_coef_sites("accel_adapt") == frozenset({0})
    assert traced_coef_sites("poly_filter") == frozenset()
    assert traced_coef_sites("accel") == frozenset()
    assert traced_coef_sites("push_sum") == frozenset()


# ---------------------------------------------------------------------------
# Red path: the deliberately-broken fixtures.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "spec,passname,rule",
    [(s, p, r) for s, p, r, _ in fixtures.fixture_specs()])
def test_broken_fixture_trips_exactly_one_finding(spec, passname, rule):
    check = {s: c for s, _, _, c in fixtures.fixture_specs()}[spec]
    fixtures.register_fixtures()
    try:
        findings = check((spec,))
    finally:
        fixtures.unregister_fixtures()
    errors = [f for f in findings if f.severity == "error"]
    assert len(errors) == 1, render_text(findings)
    assert errors[0].rule == rule
    assert errors[0].passname == passname
    # the mesh pass names the offending kernel (whole-grid trace), the
    # per-registration passes name the algorithm spec
    assert errors[0].obj == spec or passname == "mesh-kernel"


def test_fixture_selftest_roundtrip():
    report, ok = fixtures.selftest()
    assert ok, report
    assert "self-test passed" in report
    assert "fx_mass_leaker" not in alg.registered_algorithms()  # cleaned up


# ---------------------------------------------------------------------------
# Satellite: fail-fast registration.
# ---------------------------------------------------------------------------

def _mk(name, **overrides):
    body = dict(
        name=name, spec=name,
        round_body=lambda self, prim, params, carry, t:
            (prim(carry[0], carry[0], _convex(carry[0], 0.5, 0.5, 0.0)),),
        ref_coef=lambda self, params: (0.5, 0.5, 0.0))
    body.update(overrides)
    return type("Fx", (alg.ConsensusAlgorithm,), body)


@pytest.mark.parametrize("overrides,match", [
    (dict(num_taps=0), "num_taps"),
    (dict(num_taps=1.5), "num_taps"),
    (dict(num_aux=-1), "num_aux"),
    (dict(invariant="magic"), "invariant"),
    (dict(mass_renorm="router"), "mass_renorm"),
    (dict(round_body=alg.ConsensusAlgorithm.round_body), "round_body"),
    (dict(ref_coef=alg.ConsensusAlgorithm.ref_coef,
          reference_run=alg.ConsensusAlgorithm.reference_run), "ref_coef"),
])
def test_register_algorithm_fails_fast(overrides, match):
    with pytest.raises((ValueError, TypeError), match=match):
        alg.register_algorithm("fx_invalid", _mk("fx_invalid", **overrides))
    assert "fx_invalid" not in alg.registered_algorithms()


def test_valid_registration_and_unregister_roundtrip():
    gen0 = alg.registry_generation()
    alg.register_algorithm("fx_valid", _mk("fx_valid"))
    try:
        assert "fx_valid" in alg.registered_algorithms()
        assert alg.registry_generation() > gen0
    finally:
        alg.unregister_algorithm("fx_valid")
    assert "fx_valid" not in alg.registered_algorithms()
    assert alg.registry_generation() > gen0 + 1  # unregister bumps too


def test_verify_static_entry_point():
    assert not has_errors(alg.verify_static("accel"))
    fixtures.register_fixtures()
    try:
        bad = alg.verify_static("fx_mass_leaker")
    finally:
        fixtures.unregister_fixtures()
    assert any(f.rule == "coef-mass" and f.severity == "error" for f in bad)


# ---------------------------------------------------------------------------
# Satellite: cp fired-counter reset/context API.
# ---------------------------------------------------------------------------

def test_cp_partition_counter_api():
    from repro.kernels import ops

    ops.reset_cp_partition_count()
    assert ops.cp_partition_count() == 0
    with ops.cp_partition_calls() as fired:
        assert fired() == 0
        ops._CP_PARTITION_CALLS += 3  # what the partition rule does
        assert fired() == 3
        with ops.cp_partition_calls() as inner:  # scoped: no leakage
            assert inner() == 0
            ops._CP_PARTITION_CALLS += 2
            assert inner() == 2
        assert fired() == 5
    assert ops.cp_partition_count() == 5
    ops.reset_cp_partition_count()
    assert ops.cp_partition_count() == 0


# ---------------------------------------------------------------------------
# Satellite: the checkify runtime twin.
# ---------------------------------------------------------------------------

def test_debug_checks_twin_is_bit_exact_and_catches_nan():
    from repro.sweep.engine import run_sweep
    from repro.sweep.grid import SweepSpec

    spec = SweepSpec(
        topologies=("chain",), sizes=(8,), designs=("asymptotic",),
        algorithms=("accel", "accel_adapt"), num_trials=2, seed=0)
    r0 = run_sweep(spec, num_iters=15)
    r1 = run_sweep(spec, num_iters=15, debug_checks=True)
    np.testing.assert_array_equal(r0.x_final, r1.x_final)
    np.testing.assert_array_equal(r0.mse, r1.mse)

    class NaNMaker(alg.ConsensusAlgorithm):
        name = spec = "fx_nan_maker"
        num_taps = 1

        def round_body(self, prim, params, carry, t):
            (x,) = carry
            y = prim(x, x, _convex(x, 0.5, 0.5, 0.0))
            return (y + jnp.sqrt(jnp.full_like(y, -1.0)) * 0.0,)

        def ref_coef(self, params):
            return (0.5, 0.5, 0.0)

    alg.register_algorithm("fx_nan_maker", NaNMaker)
    try:
        s2 = SweepSpec(
            topologies=("chain",), sizes=(8,), designs=("asymptotic",),
            algorithms=("fx_nan_maker",), num_trials=2, seed=0)
        assert np.isnan(run_sweep(s2, num_iters=5).x_final).any()  # silent
        with pytest.raises(Exception, match="nonfinite state"):
            run_sweep(s2, num_iters=5, debug_checks=True)
    finally:
        alg.unregister_algorithm("fx_nan_maker")


def test_debug_checks_guards_traced_coefficient_mass():
    """A data-dependent (traced) coefficient stream that leaks mass is
    invisible to the static pass (it can only record the site) but must
    trip the runtime twin's coefficient-mass guard."""
    from repro.sweep.engine import run_sweep
    from repro.sweep.grid import SweepSpec

    class LeakyStream(alg.ConsensusAlgorithm):
        name = spec = "fx_leaky_stream"
        num_taps = 1

        def round_body(self, prim, params, carry, t):
            (x,) = carry
            # data-dependent a: the classifier marks the site traced
            a = 0.49 + 0.0 * jnp.mean(x, axis=(1, 2), keepdims=False)
            coef = jnp.stack(
                [a, jnp.full_like(a, 0.5), jnp.zeros_like(a)], axis=-1)
            return (prim(x, x, coef),)

        def ref_coef(self, params):
            return (0.49, 0.5, 0.0)

    alg.register_algorithm("fx_leaky_stream", LeakyStream)
    try:
        assert traced_coef_sites("fx_leaky_stream") == frozenset({0})
        s = SweepSpec(
            topologies=("chain",), sizes=(8,), designs=("asymptotic",),
            algorithms=("fx_leaky_stream",), num_trials=2, seed=0)
        run_sweep(s, num_iters=5)  # plain path: silent drift
        with pytest.raises(Exception, match="coefficient-mass guard"):
            run_sweep(s, num_iters=5, debug_checks=True)
    finally:
        alg.unregister_algorithm("fx_leaky_stream")


# ---------------------------------------------------------------------------
# CLI and rendering.
# ---------------------------------------------------------------------------

def test_cli_single_algorithm_green_and_markdown_out(tmp_path, capsys):
    out = tmp_path / "analysis.md"
    rc = analysis_main(
        ["--check", "--algorithms", "accel", "--out", str(out)])
    assert rc == 0
    assert "Static analysis" in out.read_text()
    assert "no findings" in capsys.readouterr().out \
        or "finding(s)" in out.read_text()


def test_cli_exits_nonzero_on_error_finding(capsys):
    fixtures.register_fixtures()
    try:
        rc = analysis_main(
            ["--check", "--algorithms", "fx_mass_leaker"])
    finally:
        fixtures.unregister_fixtures()
    assert rc == 1
    assert "coef-mass" in capsys.readouterr().out


def test_finding_schema_and_renderers():
    with pytest.raises(ValueError, match="severity"):
        AnalysisFinding(rule="r", severity="fatal", message="m")
    f_err = AnalysisFinding(rule="coef-mass", severity="error", message="m|m",
                            obj="x", file="a.py", line=3, passname="p")
    f_info = AnalysisFinding(rule="note", severity="info", message="n",
                             obj="y", passname="p")
    assert has_errors([f_info, f_err]) and not has_errors([f_info])
    txt = render_text([f_info, f_err])
    assert txt.index("ERROR") < txt.index("INFO")  # severity-sorted
    assert "[a.py:3]" in txt
    md = render_markdown([f_err])
    assert "\\|" in md and "| error |" in md
    assert "no findings" in render_text([])
