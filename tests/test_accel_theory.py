"""Numeric validation of the paper's theorems (Sections III & V)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import accel, metrics, topology, weights
from repro.core.accel import Theta


def _mh(graph):
    w = weights.metropolis_hastings(graph)
    weights.check_consensus_matrix(w)
    return w


# ---------------------------------------------------------------------------
# Predictor designs.
# ---------------------------------------------------------------------------

def test_ls_design_matches_closed_form():
    th = accel.theta_ls()
    np.testing.assert_allclose(th.as_tuple, (-2 / 3, 1 / 3, 4 / 3), atol=1e-12)


def test_asymptotic_design_gamma_sqrt2():
    for eps in (0.1, 0.5, 2.0):
        th = accel.theta_asymptotic(eps)
        assert abs(th.gamma - np.sqrt(2)) < 1e-12  # eps-independent (Eq. 15)


def test_theta_conditions_enforced():
    with pytest.raises(ValueError):
        Theta(0.5, 0.5, 0.0)   # theta3 < 1
    with pytest.raises(ValueError):
        Theta(0.0, -0.5, 1.5)  # theta2 < 0
    with pytest.raises(ValueError):
        Theta(0.0, 0.5, 1.0)   # sum != 1


# ---------------------------------------------------------------------------
# Theorem 1: alpha* is the argmin of rho(Phi3[alpha] - J).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", ["chain", "ring", "grid", "rgg"])
@pytest.mark.parametrize("design", ["ls", "asym"])
def test_alpha_star_is_argmin(topo, design, rng):
    g = {
        "chain": lambda: topology.chain(30),
        "ring": lambda: topology.ring(30),
        "grid": lambda: topology.grid2d(6),
        "rgg": lambda: topology.random_geometric(40, rng),
    }[topo]()
    w = _mh(g)
    vals = np.linalg.eigvalsh(w)
    if abs(vals[0]) > vals[-2]:  # ensure |lambda_N| <= lambda_2 (paper Sec III-A)
        w = weights.lazy(w)
    th = accel.theta_ls() if design == "ls" else accel.theta_asymptotic(0.5)
    lam2 = accel.lambda2(w)
    a_star = accel.alpha_star(lam2, th)
    assert 0.0 <= a_star < th.alpha_max
    rho_star = accel.spectral_radius_minus_j(w, a_star, th)
    # scan the stability interval: no alpha beats alpha*
    alphas = np.linspace(0.0, th.alpha_max * 0.999, 1200)
    rhos = np.array([accel.spectral_radius_minus_j(w, a, th) for a in alphas])
    assert rho_star <= rhos.min() + 2e-4
    # closed form rho = sqrt(-alpha* theta1) (Section V-C)
    np.testing.assert_allclose(rho_star, accel.rho_accel(lam2, th), atol=1e-9)


def test_analytic_eigenvalues_match_dense():
    g = topology.chain(20)
    w = _mh(g)
    th = accel.theta_asymptotic(0.5)
    for alpha in (0.0, 0.3, 1.0):
        phi = accel.phi3_matrix(w, alpha, th)
        dense = np.sort_complex(np.linalg.eigvals(phi))
        analytic = np.sort_complex(
            accel.phi3_eigenvalues(np.linalg.eigvalsh(w), alpha, th)
        )
        np.testing.assert_allclose(dense, analytic, atol=1e-8)


def test_closed_form_chebyshev_rate():
    """theta=(-eps,0,1+eps): rho* = (1 - sqrt(1-lam^2))/lam, eps-independent."""
    lam = 0.97
    expected = (1 - np.sqrt(1 - lam**2)) / lam
    for eps in (0.1, 0.5, 1.0):
        th = accel.theta_asymptotic(eps)
        np.testing.assert_allclose(accel.rho_accel(lam, th), expected, atol=1e-12)


def test_spectral_radius_rejects_nonsymmetric_w():
    w = np.array([[0.5, 0.5, 0.0], [0.0, 0.5, 0.5], [0.5, 0.0, 0.5]])  # row-stochastic, W != W^T
    th = accel.theta_asymptotic(0.5)
    with pytest.raises(ValueError, match="symmetric"):
        accel.spectral_radius_minus_j(w, 0.3, th)


def test_phi3_eigenvalues_rejects_complex_spectrum():
    th = accel.theta_asymptotic(0.5)
    bad = np.array([1.0, 0.2 + 0.3j, 0.2 - 0.3j])  # spectrum of a non-symmetric W
    with pytest.raises(ValueError, match="symmetric"):
        accel.phi3_eigenvalues(bad, 0.3, th)
    # real spectra passed as complex dtype are fine
    ok = accel.phi3_eigenvalues(np.array([1.0 + 0j, 0.5 + 0j]), 0.3, th)
    assert ok.shape == (4,)


# ---------------------------------------------------------------------------
# Theorem 2 / Theorem 3.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [10, 40, 120])
def test_theorem2_bound_chain(n):
    w = _mh(topology.chain(n))
    th = accel.theta_asymptotic(0.5)
    lam2 = accel.lambda2(w)
    psi = 1.0 - lam2  # rho(W-J) = lam2 here (chain MH: positive spectrum dominates)
    assert accel.rho_accel(lam2, th) <= accel.rho_accel_bound(psi) + 1e-12


def test_theorem3_gain_scaling_chain():
    """Chain: gain = Omega(N) (Section III-C)."""
    th = accel.theta_asymptotic(0.5)
    gains = []
    for n in (20, 40, 80):
        w = _mh(topology.chain(n))
        lam2 = accel.lambda2(w)
        gains.append(metrics.processing_gain(lam2, accel.rho_accel(lam2, th)))
    # doubling N should at least ~double the gain
    assert gains[1] / gains[0] > 1.7
    assert gains[2] / gains[1] > 1.7


def test_gain_bound_theorem3():
    th = accel.theta_asymptotic(0.5)
    for n in (20, 50):
        w = _mh(topology.grid2d(n // 5, 5))
        lam2 = accel.lambda2(w)
        psi = 1.0 - lam2
        gain = metrics.processing_gain(lam2, accel.rho_accel(lam2, th))
        assert gain >= accel.gain_bound(psi) * 0.95  # 1/sqrt(psi) lower bound


# ---------------------------------------------------------------------------
# Property-based invariants (hypothesis).
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(6, 28),
    p=st.floats(0.15, 0.7),
    seed=st.integers(0, 2**31 - 1),
    eps=st.floats(0.05, 2.0),
)
def test_acceleration_never_hurts(n, p, seed, eps):
    """On any connected graph (lazy-fixed), rho(Phi3[alpha*]-J) <= rho(W-J)."""
    rng = np.random.default_rng(seed)
    g = topology.erdos_renyi(n, p, rng)
    if not topology.is_connected(g.adjacency):
        return
    w = weights.lazy(weights.metropolis_hastings(g))  # all-positive spectrum
    lam2 = accel.lambda2(w)
    if lam2 <= 1e-9:  # complete-graph-like: single round exact, nothing to gain
        return
    th = accel.theta_asymptotic(eps)
    rho_w = max(abs(np.linalg.eigvalsh(w)[0]), lam2)
    assert accel.rho_accel(lam2, th) <= rho_w + 1e-9
    a = accel.alpha_star(lam2, th)
    assert 0.0 <= a < th.alpha_max


@settings(max_examples=20, deadline=None)
@given(n=st.integers(6, 24), seed=st.integers(0, 2**31 - 1))
def test_mh_weights_invariants(n, seed):
    rng = np.random.default_rng(seed)
    g = topology.erdos_renyi(n, 0.4, rng)
    if not topology.is_connected(g.adjacency):
        return
    w = weights.metropolis_hastings(g)
    np.testing.assert_allclose(w, w.T, atol=1e-12)          # symmetric
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-12)   # stochastic
    vals = np.linalg.eigvalsh(w)
    assert vals[0] >= -1.0 - 1e-9 and vals[-1] <= 1.0 + 1e-9
    lz = weights.lazy(w)
    assert np.linalg.eigvalsh(lz)[0] >= -1e-9               # positive spectrum


@settings(max_examples=15, deadline=None)
@given(lam=st.floats(0.05, 0.999), eps=st.floats(0.05, 2.0))
def test_rho_formula_consistency(lam, eps):
    th = accel.theta_asymptotic(eps)
    a = accel.alpha_star(lam, th)
    np.testing.assert_allclose(
        accel.rho_accel(lam, th), np.sqrt(max(-a * th.t1, 0.0)), atol=1e-12
    )
