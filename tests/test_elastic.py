"""Elastic runtime: failure detection, graph-edit resize, straggler grace.

The control plane referenced by ``repro.runtime.elastic``'s docstring: a pod
failure is a graph edit followed by a re-solve of the paper's optimization
(Theorem 1) for the surviving fabric — cheap because initialization is O(K)
(Section III-D) — and stragglers get ``backup_rounds`` of slack instead of
eviction.
"""
import numpy as np

from repro.core import accel, topology, weights
from repro.runtime import ElasticFabric, FailureDetector


# ---------------------------------------------------------------------------
# FailureDetector: heartbeat-age transitions.
# ---------------------------------------------------------------------------

def test_heartbeat_age_drives_healthy_to_dead():
    fd = FailureDetector(dead_after_s=10.0)
    fd.heartbeat(0, step_latency=1.0, now=100.0)
    fd.heartbeat(1, step_latency=1.0, now=100.0)
    assert fd.classify(now=105.0) == {0: "healthy", 1: "healthy"}
    # pod 1 stops heartbeating; crosses the age threshold, pod 0 does not
    fd.heartbeat(0, step_latency=1.0, now=109.0)
    cls = fd.classify(now=111.0)
    assert cls[0] == "healthy" and cls[1] == "dead"


def test_heartbeat_revives_a_dead_pod():
    fd = FailureDetector(dead_after_s=10.0)
    fd.heartbeat(0, now=0.0)
    assert fd.classify(now=50.0)[0] == "dead"
    fd.heartbeat(0, now=50.0)  # the pod came back
    assert fd.classify(now=51.0)[0] == "healthy"


def test_straggler_needs_latency_history():
    fd = FailureDetector(dead_after_s=60.0, straggler_factor=2.0)
    now = 0.0
    for pid, lat in [(0, 1.0), (1, 1.0), (2, 1.1), (3, 6.0)]:
        fd.heartbeat(pid, step_latency=lat, now=now)
        fd.heartbeat(pid, step_latency=lat, now=now)
    cls = fd.classify(now=now)
    assert cls[3] == "straggler"
    assert all(cls[p] == "healthy" for p in (0, 1, 2))


def test_straggler_ema_recovers():
    """A slow patch decays out of the EMA; the pod returns to healthy."""
    fd = FailureDetector(dead_after_s=60.0, straggler_factor=2.0)
    for pid in (0, 1):
        fd.heartbeat(pid, step_latency=1.0, now=0.0)
        fd.heartbeat(pid, step_latency=1.0, now=0.0)
    fd.heartbeat(2, step_latency=10.0, now=0.0)
    assert fd.classify(now=0.0)[2] == "straggler"
    for _ in range(60):  # fast steps decay the EMA below 2x median
        fd.heartbeat(2, step_latency=1.0, now=0.0)
    assert fd.classify(now=0.0)[2] == "healthy"


# ---------------------------------------------------------------------------
# Resize: connected (P-1)-pod fabric with re-solved (alpha*, theta).
# ---------------------------------------------------------------------------

def _fabric_graph_connected(fabric) -> bool:
    adj = (np.abs(fabric.w) > 0).astype(np.float64)
    np.fill_diagonal(adj, 0.0)
    return topology.is_connected(adj)


def test_resize_produces_connected_resolved_fabric():
    ef = ElasticFabric(topology="ring")
    f8 = ef.bootstrap(list(range(8)))
    f7 = ef.resize(remove=[5])
    assert ef.members == [0, 1, 2, 3, 4, 6, 7]
    assert f7.num_pods == 7
    assert _fabric_graph_connected(f7)
    # W is a valid consensus matrix for the new graph
    weights.check_consensus_matrix(f7.w)
    # (alpha*, theta): theta carried over, alpha re-solved from the new gap
    assert f7.theta == f8.theta
    assert f7.alpha != f8.alpha
    assert f7.alpha == accel.alpha_star(f7.lambda2, f7.theta)
    assert f7.rho_accel < f7.rho_memoryless  # Theorem 2 still holds post-edit


def test_resize_chain_of_edits_stays_connected():
    ef = ElasticFabric(topology="ring")
    ef.bootstrap(list(range(6)))
    for gone in (2, 4, 0):
        fab = ef.resize(remove=[gone])
        assert _fabric_graph_connected(fab)
        weights.check_consensus_matrix(fab.w)
    assert fab.num_pods == 3
    assert ef.resize_count == 3


def test_resize_accepts_distributed_lambda2_estimate():
    """Irregular fabrics re-solve Theorem 1 from the in-mesh Algorithm 1
    output instead of a dense eigensolve — no W gather."""
    ef = ElasticFabric(topology="ring")
    ef.bootstrap(list(range(8)))
    dense = ef.resize(remove=[3])
    est = dense.lambda2 + 1e-6  # what distributed_lambda2 would hand back
    ef2 = ElasticFabric(topology="ring")
    ef2.bootstrap(list(range(8)))
    approx = ef2.resize(remove=[3], lambda2_estimate=est)
    assert approx.lambda2 == est
    assert approx.alpha == accel.alpha_star(est, approx.theta)
    assert abs(approx.alpha - dense.alpha) < 1e-4


# ---------------------------------------------------------------------------
# Straggler grace path.
# ---------------------------------------------------------------------------

def test_backup_rounds_grace():
    ef = ElasticFabric(topology="ring", backup_rounds=2)
    ef.bootstrap(list(range(8)))
    base = ef.fabric.rounds_for(1e-2)
    assert ef.rounds(1e-2) == base + 2


def test_straggler_gets_grace_not_eviction():
    ef = ElasticFabric(topology="ring", backup_rounds=2)
    ef.bootstrap(list(range(4)))
    # stragglers never trigger a resize — they ride the backup_rounds slack
    assert ef.react({0: "healthy", 1: "straggler", 2: "healthy", 3: "straggler"}) is None
    assert ef.members == [0, 1, 2, 3]
    # a dead pod does; the straggler still stays
    fab = ef.react({0: "healthy", 1: "straggler", 2: "dead", 3: "healthy"})
    assert fab is not None and fab.num_pods == 3
    assert ef.members == [0, 1, 3]
