"""Consensus simulation engine: backend agreement + paper-scale behaviour."""
import numpy as np
import pytest

from repro.core import accel, metrics, simulator, topology, weights


@pytest.fixture
def setup(rng):
    g = topology.random_geometric(60, rng)
    w = weights.metropolis_hastings(g)
    th = accel.theta_asymptotic(0.5)
    a = accel.alpha_star_from_w(w, th)
    x0 = rng.standard_normal((60, 4))
    return w, th, a, x0


def test_backends_agree(setup):
    w, th, a, x0 = setup
    r_np = simulator.simulate(w, x0, 150, alpha=a, theta=th, backend="numpy")
    r_jx = simulator.simulate(w, x0, 150, alpha=a, theta=th, backend="jax")
    r_pl = simulator.simulate(w, x0, 150, alpha=a, theta=th, backend="pallas")
    np.testing.assert_allclose(r_np.mse[:50], r_jx.mse[:50], rtol=1e-3, atol=1e-6)
    np.testing.assert_allclose(r_jx.mse, r_pl.mse, rtol=1e-4, atol=1e-7)


def test_unknown_backend_rejected_before_array_work():
    """Backend validation precedes any allocation (satellite fix)."""
    w = np.eye(3)
    with pytest.raises(ValueError, match="unknown backend"):
        # an x0 that would explode any array work if it were touched first
        simulator.simulate(w, object(), 5, backend="torch")


def test_alpha_without_theta_is_an_error(setup):
    """A non-zero alpha with no predictor design must refuse to run, not
    silently decay to the memoryless baseline (satellite fix)."""
    w, _, a, x0 = setup
    for backend in ("numpy", "jax", "pallas"):
        with pytest.raises(ValueError, match="theta"):
            simulator.simulate(w, x0, 5, alpha=a, theta=None, backend=backend)
    # explicit alpha=0 stays a valid memoryless run, with or without theta
    r = simulator.simulate(w, x0, 5, alpha=0.0, theta=None, backend="numpy")
    assert r.num_iters == 5


def test_accelerated_beats_memoryless(setup):
    w, th, a, x0 = setup
    r_mem = simulator.simulate(w, x0, 300, backend="numpy")
    r_acc = simulator.simulate(w, x0, 300, alpha=a, theta=th, backend="numpy")
    assert r_acc.mse[-1].max() < r_mem.mse[-1].min() * 1e-2


def test_memoryless_matches_linear_recursion(setup, rng):
    w, _, _, _ = setup
    x0 = rng.standard_normal(60)
    r = simulator.simulate(w, x0, 37, backend="numpy")
    np.testing.assert_allclose(r.x_final, np.linalg.matrix_power(w, 37) @ x0, atol=1e-10)


def test_average_is_preserved(setup):
    w, th, a, x0 = setup
    r = simulator.simulate(w, x0, 200, alpha=a, theta=th, backend="numpy")
    np.testing.assert_allclose(r.x_final.mean(axis=0), x0.mean(axis=0), atol=1e-9)


def test_empirical_gain_matches_asymptotic_chain():
    """Fig. 4 behaviour: measured averaging-time ratio ~ asymptotic gain."""
    n = 60
    g = topology.chain(n)
    w = weights.metropolis_hastings(g)
    th = accel.theta_asymptotic(0.5)
    lam2 = accel.lambda2(w)
    a = accel.alpha_star(lam2, th)
    x0 = metrics.slope_init(g.coords, n)
    xbar = np.full(n, x0.mean())
    t_mem = metrics.averaging_time(lambda s: w @ s, x0, xbar, eps=1e-5)
    x, xp = x0.copy(), x0.copy()
    err0 = np.linalg.norm(x0 - xbar)
    t_acc = None
    for t in range(1, 200_000):
        x, xp = accel.accelerated_step(w, x, xp, a, th)
        if np.linalg.norm(x - xbar) <= 1e-5 * err0:
            t_acc = t
            break
    gain_emp = t_mem / t_acc
    gain_asym = metrics.processing_gain(lam2, accel.rho_accel(lam2, th))
    assert 0.5 * gain_asym < gain_emp < 2.0 * gain_asym
