"""Per-architecture smoke tests (reduced configs, CPU): forward/loss/grad
shapes + finiteness, prefill->decode consistency with the teacher-forced
forward, family-specific behaviours (ring cache, SSM state, cross-attn)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build, lm

KEY = jax.random.PRNGKey(0)
B, T = 2, 32


def _batch(cfg, key=KEY, b=B, t=T):
    batch = {
        "tokens": jax.random.randint(key, (b, t), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, t), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (b, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_loss_and_grad(arch):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    gnorm = np.sqrt(sum(float((g.astype(jnp.float32) ** 2).sum()) for g in jax.tree.leaves(grads)))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, 64))(params, batch)
    assert logits.shape[:2] == (B, 1)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    lg, cache = jax.jit(model.decode)(params, tok, jnp.full((B,), T, jnp.int32), cache)
    assert np.isfinite(np.asarray(lg, np.float32)).all()


def test_prefill_decode_match_forward():
    """Decode continuation must reproduce the teacher-forced forward pass."""
    cfg = get_config("yi-9b", smoke=True)
    model = build(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (1, 12), 0, cfg.vocab_size)
    full, _ = lm.forward_train(params, toks, cfg)
    lg_p, cache = model.prefill(params, {"tokens": toks[:, :8]}, 16)
    np.testing.assert_allclose(
        np.asarray(lg_p[0, -1], np.float32), np.asarray(full[0, 7], np.float32),
        rtol=2e-2, atol=2e-2,
    )
    lg_d, _ = model.decode(params, toks[:, 8:9], jnp.asarray([8], jnp.int32), cache)
    np.testing.assert_allclose(
        np.asarray(lg_d[0, 0], np.float32), np.asarray(full[0, 8], np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_ssm_prefill_decode_match_forward():
    """Same consistency for the attention-free (state-carrying) family."""
    cfg = get_config("mamba2-780m", smoke=True)
    model = build(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (1, 20), 0, cfg.vocab_size)
    full, _ = lm.forward_train(params, toks, cfg)
    _, cache = model.prefill(params, {"tokens": toks[:, :16]}, 32)
    lg_d, _ = model.decode(params, toks[:, 16:17], jnp.asarray([16], jnp.int32), cache)
    np.testing.assert_allclose(
        np.asarray(lg_d[0, 0], np.float32), np.asarray(full[0, 16], np.float32),
        rtol=3e-2, atol=3e-2,
    )


def test_sliding_window_ring_cache():
    """Mixtral-family: decode beyond the window uses the ring buffer."""
    cfg = get_config("mixtral-8x7b", smoke=True)  # window = 32
    model = build(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (1, 40), 0, cfg.vocab_size)  # > window
    _, cache = model.prefill(params, {"tokens": toks}, 40)
    assert cache["k"].shape[2] == cfg.sliding_window  # ring, not full seq
    lg, cache = model.decode(params, toks[:, :1], jnp.asarray([40], jnp.int32), cache)
    assert np.isfinite(np.asarray(lg, np.float32)).all()


def test_moe_aux_losses_present():
    from repro.models.mlp import moe_forward
    cfg = get_config("moonshot-v1-16b-a3b", smoke=True)
    model = build(cfg)
    params = model.init(KEY)
    block0 = jax.tree.map(lambda t: t[0], params["blocks"])
    x = jax.random.normal(KEY, (2, 8, cfg.d_model), jnp.bfloat16)
    out, aux = moe_forward(block0["moe"], x, cfg.moe, cfg.activation)
    assert out.shape == x.shape
    # >= 1 by Cauchy-Schwarz in exact arithmetic; bf16 routing fractions and
    # XLA:CPU reduction partitioning (which varies with process load) leave
    # ~1e-2 of fp slack below the bound
    assert float(aux["load_balance"]) >= 1.0 - 1e-2
    assert np.isfinite(float(aux["router_z"]))


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= 1 and near-uniform routing, most tokens route."""
    from repro.models.mlp import moe_forward
    cfg = get_config("mixtral-8x7b", smoke=True)
    model = build(cfg)
    params = model.init(KEY)
    block0 = jax.tree.map(lambda t: t[0], params["blocks"])
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 16, cfg.d_model), jnp.bfloat16)
    out, _ = moe_forward(block0["moe"], x, cfg.moe, cfg.activation)
    # at random init routing is near-uniform; output should be mostly nonzero
    frac_zero = float((jnp.abs(out.astype(jnp.float32)).sum(-1) == 0).mean())
    assert frac_zero < 0.3


def test_vocab_padding_masked():
    """Padded vocab columns never receive probability mass in the loss."""
    from repro.models.common import cross_entropy_loss
    logits = jnp.zeros((1, 4, 512))
    logits = logits.at[..., 300:].set(100.0)  # huge logits in padded region
    labels = jnp.zeros((1, 4), jnp.int32)
    loss_masked = cross_entropy_loss(logits, labels, vocab_size=300, z_coef=0.0)
    assert float(loss_masked) < np.log(300) + 1e-3


def test_hybrid_structure():
    cfg = get_config("zamba2-7b", smoke=True)
    specs = lm.param_specs(cfg)
    assert "shared" in specs and "mamba" in specs and "tail" in specs
    # shared attention block has ONE weight set (no layer stacking)
    assert specs["shared"]["attn"]["wq"].shape[0] == cfg.d_model


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_counts(arch):
    """Full (non-smoke) configs produce abstract specs matching num_params."""
    cfg = get_config(arch)
    model = build(cfg)
    total = 0
    def count(t):
        nonlocal total
        if hasattr(t, "shape") and not isinstance(t, dict):
            n = 1
            for d in t.shape:
                n *= d
            total += n
            return
        for v in t.values():
            count(v)
    count(model.param_specs)
    approx = cfg.num_params()
    assert abs(total - approx) / approx < 0.03  # within 3% of the closed form
