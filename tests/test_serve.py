"""DecodeEngine unit tests: max_batch=1 prefill round-trip (the cache used
to be silently discarded when every leaf dim matched), EOS at prefill time,
mixed-progress slot reuse, and slot exhaustion with waiting requests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build
from repro.serve import DecodeEngine, Request

KEY = jax.random.PRNGKey(0)


def _make(arch: str):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    return cfg, model, model.init(KEY)


def _prompt(cfg, n=5, seed=0):
    r = np.random.default_rng(seed)
    return r.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)


@pytest.mark.parametrize("arch", ["minicpm-2b", "mamba2-780m"])
def test_max_batch1_prefill_cache_round_trip(arch):
    """At max_batch == 1 every cache leaf shape matches the prefill leaf;
    the old first-differing-dim scan found nothing and decode ran on zeros.
    The slot contents must equal the standalone prefill cache exactly."""
    cfg, model, params = _make(arch)
    eng = DecodeEngine(model, params, max_batch=1, max_seq=32)
    prompt = _prompt(cfg)
    eng.submit(Request(0, prompt, max_new_tokens=4))
    eng._admit()

    batch = {"tokens": jnp.asarray(prompt[None], jnp.int32)}
    _, cache1 = jax.jit(lambda p, b: model.prefill(p, b, 32))(params, batch)
    leaves = jax.tree.leaves(eng.cache)
    ones = jax.tree.leaves(cache1)
    axes = jax.tree.leaves(eng._batch_axis)
    assert leaves and len(leaves) == len(ones) == len(axes)
    nonzero_seen = False
    for full, one, ax in zip(leaves, ones, axes):
        assert ax >= 0, "every cache leaf must declare a batch axis"
        got = np.asarray(jax.lax.index_in_dim(full, 0, axis=ax, keepdims=True))
        want = np.asarray(one, dtype=got.dtype)
        np.testing.assert_array_equal(got, want)
        nonzero_seen = nonzero_seen or bool((want != 0).any())
    assert nonzero_seen, "prefill produced an all-zero cache; test is vacuous"


def test_max_batch1_decode_runs_and_is_deterministic():
    cfg, model, params = _make("minicpm-2b")

    def run_once():
        eng = DecodeEngine(model, params, max_batch=1, max_seq=32)
        eng.submit(Request(0, _prompt(cfg), max_new_tokens=6))
        (done,) = eng.run()
        return done.out_tokens

    a, b = run_once(), run_once()
    assert a == b and len(a) == 6


def test_eos_at_prefill_finishes_without_decode_ticks():
    """A request whose FIRST (prefill-time) token is EOS must finish with
    exactly that token instead of decoding max_new_tokens junk."""
    cfg, model, params = _make("minicpm-2b")
    prompt = _prompt(cfg, seed=3)
    probe = DecodeEngine(model, params, max_batch=1, max_seq=32)
    probe.submit(Request(0, prompt, max_new_tokens=4))
    (done,) = probe.run()
    first = done.out_tokens[0]

    eng = DecodeEngine(model, params, max_batch=1, max_seq=32)
    hit = Request(1, prompt, max_new_tokens=4, eos_id=first)
    eng.submit(hit)
    finished = eng.step()
    assert [r.rid for r in finished] == [1]
    assert hit.done and hit.out_tokens == [first]
    # the request never occupied a slot and the pool is still free
    assert hit.slot is None
    assert eng.slot_req == [None] and eng.positions[0] == -1


def test_eos_at_prefill_slot_goes_to_next_waiting_request():
    cfg, model, params = _make("minicpm-2b")
    prompt = _prompt(cfg, seed=3)
    probe = DecodeEngine(model, params, max_batch=1, max_seq=32)
    probe.submit(Request(0, prompt, max_new_tokens=4))
    first = probe.run()[0].out_tokens[0]

    eng = DecodeEngine(model, params, max_batch=1, max_seq=32)
    hit = Request(1, prompt, max_new_tokens=4, eos_id=first)
    tail = Request(2, _prompt(cfg, seed=7), max_new_tokens=3)
    eng.submit(hit)
    eng.submit(tail)
    done = eng.run()
    assert {r.rid for r in done} == {1, 2}
    assert hit.out_tokens == [first]
    assert len(tail.out_tokens) == 3 and tail.done


def test_single_token_budget_takes_no_decode_tick():
    cfg, model, params = _make("minicpm-2b")
    eng = DecodeEngine(model, params, max_batch=2, max_seq=32)
    r = Request(0, _prompt(cfg), max_new_tokens=1)
    eng.submit(r)
    finished = eng.step()
    assert [q.rid for q in finished] == [0] and len(r.out_tokens) == 1


def test_mixed_progress_slot_reuse():
    """6 requests over 2 slots with different prompt lengths and budgets:
    slots recycle mid-flight and every request gets exactly its budget."""
    cfg, model, params = _make("minicpm-2b")
    eng = DecodeEngine(model, params, max_batch=2, max_seq=64)
    reqs = [
        Request(i, _prompt(cfg, n=3 + 2 * i, seed=i), max_new_tokens=2 + i)
        for i in range(6)
    ]
    for q in reqs:
        eng.submit(q)
    done = eng.run()
    assert {q.rid for q in done} == set(range(6))
    for q in reqs:
        assert q.done and len(q.out_tokens) == q.max_new_tokens
    assert eng.slot_req == [None, None] and not eng.waiting


def test_slot_exhaustion_keeps_requests_waiting():
    cfg, model, params = _make("minicpm-2b")
    eng = DecodeEngine(model, params, max_batch=1, max_seq=32)
    reqs = [Request(i, _prompt(cfg, seed=i), max_new_tokens=3) for i in range(3)]
    for q in reqs:
        eng.submit(q)
    eng.step()
    # one slot: exactly one admitted, the rest queued untouched
    assert eng.slot_req[0] is not None and eng.slot_req[0].rid == 0
    assert [q.rid for q in eng.waiting] == [1, 2]
    assert not reqs[1].out_tokens and not reqs[2].out_tokens
    done = eng.run()
    assert {q.rid for q in done} | {0} == {0, 1, 2}
    assert all(len(q.out_tokens) == 3 for q in reqs)
    assert eng.slot_req == [None] and not eng.waiting
