"""Sparse (edge-list) layout: generators, weights, kernels, and the engine.

The contract under test is the ISSUE's acceptance bar: for every registry
algorithm, on every topology family that exists in both layouts, under both
static and failure-injected dynamics and on both backends, the sparse
engine's trajectories match the dense engine's to f32 roundoff — the two
layouts are storage formats of the SAME experiment, sharing RNG draws,
RoundMasks schedules, and (below the spectrum cutoff) bit-identical
coefficients. On top sit the large-N properties only the sparse path can
reach: mean conservation and finite averaging times at N = 1e5.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import topology, weights
from repro.sweep.engine import run_batch, run_ensemble, run_sweep
from repro.sweep.grid import SweepSpec, build_ensemble, build_round_masks


# ---------------------------------------------------------------------------
# generators (property-based)
# ---------------------------------------------------------------------------


def _assert_canonical(edges: np.ndarray) -> None:
    """Edges are i < j rows, lexsorted, unique — the layout-coupling invariant."""
    assert np.all(edges[:, 0] < edges[:, 1])
    order = np.lexsort((edges[:, 1], edges[:, 0]))
    np.testing.assert_array_equal(order, np.arange(len(edges)))
    assert len(np.unique(edges, axis=0)) == len(edges)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(min_value=8, max_value=120), m=st.integers(1, 4),
       seed=st.integers(0, 2**31 - 1))
def test_barabasi_albert_properties(n, m, seed):
    m = min(m, n - 1)
    g = topology.barabasi_albert(n, m, np.random.default_rng(seed))
    _assert_canonical(g.edges)
    assert topology.edges_are_connected(g.n, g.edges)
    # every non-seed node arrives with exactly m distinct edges (seed-star
    # leaves may stay at degree 1; only post-seed nodes carry the m bound)
    assert g.num_edges == m + (n - m - 1) * m
    if n > m + 1:
        assert g.degrees[m + 1:].min() >= m


@settings(max_examples=8, deadline=None)
@given(n=st.integers(min_value=8, max_value=60), seed=st.integers(0, 2**31 - 1))
def test_sparse_rgg_matches_dense_draw(n, seed):
    # identical rng consumption: the sparse generator must return exactly the
    # dense generator's edge set (this is what couples CRN across layouts)
    gd = topology.random_geometric(n, np.random.default_rng(seed))
    gs = topology.random_geometric_sparse(n, np.random.default_rng(seed))
    _assert_canonical(gs.edges)
    np.testing.assert_array_equal(gs.to_dense().adjacency, gd.adjacency)
    np.testing.assert_allclose(gs.coords, gd.coords)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(min_value=8, max_value=80), seed=st.integers(0, 2**31 - 1))
def test_sparse_mh_weights_doubly_stochastic(n, seed):
    g = topology.barabasi_albert(n, 2, np.random.default_rng(seed))
    edge_w, diag_w = weights.metropolis_hastings_edges(g)
    w = np.zeros((g.n, g.n))
    w[g.edges[:, 0], g.edges[:, 1]] = edge_w
    w += w.T
    w[np.diag_indices(g.n)] = diag_w
    np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-12)
    np.testing.assert_allclose(w, w.T)
    weights.check_consensus_matrix(w)
    # and it is the dense MH matrix of the same graph
    np.testing.assert_allclose(
        w, weights.metropolis_hastings(g.to_dense()), atol=1e-12)


@settings(max_examples=6, deadline=None)
@given(n=st.integers(min_value=20, max_value=250),
       seed=st.integers(0, 2**31 - 1))
def test_erdos_renyi_sparse_properties(n, seed):
    """The O(E) geometric-skip sampler yields canonical, connected draws."""
    p = min(1.0, 2.5 * np.log(n) / n)
    g = topology.erdos_renyi_sparse(n, p, np.random.default_rng(seed))
    _assert_canonical(g.edges)
    assert g.n == n
    assert topology.edges_are_connected(g.n, g.edges)


def test_erdos_renyi_sparse_grid_dispatch_above_cutoff():
    """The grid no longer rejects erdos_renyi in the sparse layout: above the
    densify cutoff it routes to the O(E) sampler and the sweep runs."""
    spec = SweepSpec(topologies=("erdos_renyi",), sizes=(2000,),
                     designs=("asymptotic",), alphas=(1.0,),
                     algorithms=("accel",), num_trials=2, layout="auto",
                     seed=0)
    assert spec.resolved_layout == "sparse"
    res = run_sweep(spec, num_iters=10, trial_chunk=1)
    assert res.ensemble.is_sparse
    assert np.all(np.isfinite(res.mse))
    x0, xf = res.ensemble.x0[0], res.x_final[0]
    assert np.abs(xf.sum(axis=0) - x0.sum(axis=0)).max() / 2000 < 1e-3
    assert np.all(res.mse[0, -1] < res.mse[0, 0])


def test_directed_family_is_dense_only():
    spec = SweepSpec(topologies=("directed",), sizes=(12,),
                     designs=("memoryless",), algorithms=("push_sum",),
                     num_trials=1, layout="sparse")
    with pytest.raises(ValueError, match="dense-only"):
        build_ensemble(spec)


def test_deterministic_sparse_families_match_dense():
    pairs = [
        (topology.sparse_chain(9), topology.chain(9)),
        (topology.sparse_ring(9), topology.ring(9)),
        (topology.sparse_grid2d(3, 4), topology.grid2d(3, 4)),
        (topology.sparse_torus2d(3, 4), topology.torus2d(3, 4)),
    ]
    for gs, gd in pairs:
        _assert_canonical(gs.edges)
        np.testing.assert_array_equal(gs.to_dense().adjacency, gd.adjacency)


# ---------------------------------------------------------------------------
# sparse segment-reduce round vs the dense oracle (both kernels)
# ---------------------------------------------------------------------------


def test_segment_round_kernel_matches_dense_oracle():
    from repro.kernels import ops

    rng = np.random.default_rng(3)
    g = topology.random_geometric_sparse(40, rng)
    edge_w, diag_w = weights.metropolis_hastings_edges(g)
    w = g.to_dense().adjacency * 0.0
    w[g.edges[:, 0], g.edges[:, 1]] = edge_w
    w += w.T
    w[np.diag_indices(g.n)] = diag_w
    x = rng.standard_normal((g.n, 5)).astype(np.float32)
    xp = rng.standard_normal((g.n, 5)).astype(np.float32)
    a, b, c = 1.1, 0.25, -0.35
    nbr, wgt, wrev, slot, diag = ops.build_ell(g.edges, edge_w, diag_w, g.n)

    y = np.asarray(ops.segment_round(nbr, wgt, slot, diag, x, xp, a, b, c))
    ref = a * (w @ x) + b * x + c * xp
    np.testing.assert_allclose(y, ref, rtol=1e-5, atol=1e-5)

    # masked: dropped edge mass returns to the source diagonal
    bits = (rng.random(g.num_edges) < 0.6).astype(np.float32)
    ym = np.asarray(
        ops.segment_round(nbr, wgt, slot, diag, x, xp, a, b, c, bits=bits))
    m = np.eye(g.n)
    m[g.edges[:, 0], g.edges[:, 1]] = bits
    m[g.edges[:, 1], g.edges[:, 0]] = bits
    wm = w * m
    weff = wm + np.diag((w - wm).sum(axis=1))
    refm = a * (weff @ x) + b * x + c * xp
    np.testing.assert_allclose(ym, refm, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# engine: sparse == dense per registry algorithm / dynamics / backend
# ---------------------------------------------------------------------------

_TOPOLOGIES = ("chain", "grid2d", "rgg")


def _run_both(algos, dynamics, backend, num_trials=3, iters=40):
    results = []
    for layout in ("dense", "sparse"):
        spec = SweepSpec(
            topologies=_TOPOLOGIES, sizes=(12, 20), designs=("asymptotic",),
            alphas=(1.0,), num_trials=num_trials, seed=7, algorithms=algos,
            dynamics=dynamics, layout=layout,
        )
        ens = build_ensemble(spec)
        masks = build_round_masks(ens, iters, seed=7)
        results.append(
            run_ensemble(ens, num_iters=iters, backend=backend,
                         round_masks=masks))
    return results


@pytest.mark.parametrize("algo", ["memoryless", "accel", "poly_filter:4",
                                  "async_pairwise", "push_sum",
                                  "ratio_consensus:0.5"])
@pytest.mark.parametrize("dyn", ["static", "bernoulli:0.1"])
def test_sparse_matches_dense_jax(algo, dyn):
    r_d, r_s = _run_both((algo,), ("static", dyn), "jax")
    np.testing.assert_allclose(r_s.x_final, r_d.x_final, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(r_s.mse, r_d.mse, rtol=1e-4, atol=1e-8)
    # identical metadata below the spectrum cutoff: same cells, same coefs
    np.testing.assert_array_equal(r_s.ensemble.coefs, r_d.ensemble.coefs)


@pytest.mark.parametrize("algos,dyn", [
    (("memoryless", "accel"), ("static",)),
    (("accel", "async_pairwise"), ("static", "bernoulli:0.1")),
    # asymmetric-base family: static rides the ELL kernel with per-direction
    # weights; the lossy cells exercise the sender-renorm jnp fallback
    # inside the same jitted scan
    (("push_sum", "ratio_consensus:0.5"), ("static", "bernoulli:0.1")),
])
def test_sparse_matches_dense_pallas(algos, dyn):
    r_d, r_s = _run_both(algos, dyn, "pallas", iters=25)
    np.testing.assert_allclose(r_s.x_final, r_d.x_final, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(r_s.mse, r_d.mse, rtol=1e-4, atol=1e-8)


def test_sparse_ratio_family_mass_conserved_under_correlated_loss():
    """Sparse-layout ratio family: total value and total mass survive i.i.d.
    AND correlated (block-outage) packet loss, and the displayed quotient
    still lands on the true average."""
    spec = SweepSpec(topologies=("grid2d",), sizes=(20,),
                     designs=("memoryless",),
                     algorithms=("push_sum", "ratio_consensus:0.5"),
                     dynamics=("bernoulli:0.1", "correlated:0.25:4:5"),
                     num_trials=3, seed=9, layout="sparse")
    ens = build_ensemble(spec)
    masks = build_round_masks(ens, 240, seed=9)
    res = run_ensemble(ens, num_iters=240, round_masks=masks,
                       return_taps=True)
    for name, s, e, (sv, mv) in res.taps:
        np.testing.assert_allclose(
            sv.sum(axis=1), ens.x0[s:e].sum(axis=1), atol=2e-3,
            err_msg=f"{name} lost total value")
        np.testing.assert_allclose(
            mv.sum(axis=1), 20.0, atol=2e-3,
            err_msg=f"{name} lost total mass")
    xbar = ens.x0.sum(axis=1, keepdims=True) / 20.0
    assert np.abs(res.x_final - xbar).max() < 1e-3


def test_trial_chunk_matches_unchunked():
    spec = SweepSpec(topologies=("chain", "rgg"), sizes=(12, 20),
                     designs=("asymptotic",), alphas=(1.0,), num_trials=7,
                     seed=3, algorithms=("accel",),
                     dynamics=("static", "bernoulli:0.2"), layout="sparse")
    ens = build_ensemble(spec)
    masks = build_round_masks(ens, 30, seed=3)
    r0 = run_ensemble(ens, num_iters=30, round_masks=masks)
    r1 = run_ensemble(ens, num_iters=30, round_masks=masks, trial_chunk=3)
    np.testing.assert_allclose(r1.mse, r0.mse, rtol=1e-6, atol=1e-9)
    np.testing.assert_allclose(r1.x_final, r0.x_final, rtol=1e-6, atol=1e-7)


def test_auto_layout_resolution():
    small = SweepSpec(sizes=(16, 64), layout="auto")
    big = SweepSpec(topologies=("ba:3",), sizes=(16, 5000), layout="auto")
    assert small.resolved_layout == "dense"
    assert big.resolved_layout == "sparse"
    with pytest.raises(ValueError):
        SweepSpec(layout="csr")
    ens = build_ensemble(SweepSpec(
        topologies=("chain",), sizes=(10,), designs=("asymptotic",),
        alphas=(1.0,), num_trials=2, layout="sparse"))
    assert ens.is_sparse and ens.ws is None


def test_run_batch_sparse_requires_edge_arrays():
    with pytest.raises(ValueError, match="sparse mode"):
        run_batch(None, np.zeros((1, 4, 2)), np.zeros((1, 3)), num_iters=1)


# ---------------------------------------------------------------------------
# large N: what only the sparse path can reach
# ---------------------------------------------------------------------------


def test_sparse_large_n_mean_conserved_and_converging():
    n = 100_000
    spec = SweepSpec(topologies=("ba:3",), sizes=(n,), designs=("asymptotic",),
                     alphas=(1.0,), num_trials=2, seed=0,
                     algorithms=("accel",), layout="sparse")
    res = run_sweep(spec, num_iters=25, trial_chunk=1)
    x0, xf = res.ensemble.x0[0], res.x_final[0]
    drift = np.abs(xf.sum(axis=0) - x0.sum(axis=0)) / n
    assert np.max(drift) < 1e-3            # segment-sum rounds conserve mass
    assert np.all(np.isfinite(res.mse))
    # MSE falls monotonically-ish: final well below initial on an expander
    assert np.all(res.mse[0, -1] < 1e-2 * res.mse[0, 0])
    at = res.averaging_times(eps=1e-1)
    assert np.all(at >= 0)                 # finite averaging times at N=1e5


# ---------------------------------------------------------------------------
# bn source-block tiling + sender-renorm ELL kernel
# ---------------------------------------------------------------------------


def _ell_fixture(n, f, g=2, seed=5):
    """Batched ELL operands (tile-padded) + matching dense W and bits."""
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(seed)
    bm, bd, bf = 128, 8, 128
    n_t = ops._round_up(n, bm)
    gph = topology.random_geometric_sparse(n, rng)
    edge_w, diag_w = weights.metropolis_hastings_edges(gph)
    nbr, wgt, wrev, slot, diag = ops.build_ell(
        gph.edges, edge_w, np.pad(diag_w, (0, n_t - n)), n_t)
    d_pad = ops._round_up(nbr.shape[1], bd) - nbr.shape[1]
    nbr, wgt, wrev, slot = (
        np.pad(a, ((0, 0), (0, d_pad))) for a in (nbr, wgt, wrev, slot))
    e = gph.num_edges
    bits = (rng.random((g, e)) < 0.6).astype(np.float32)
    bits_p = np.pad(bits, ((0, 0), (0, ops._round_up(e, 128) - e)))
    w = np.zeros((n_t, n_t))
    w[gph.edges[:, 0], gph.edges[:, 1]] = edge_w
    w += w.T
    w[np.diag_indices(n)] = diag_w
    stack = lambda a: jnp.asarray(np.stack([a] * g))
    xs = rng.standard_normal((g, n_t, f)).astype(np.float32)
    xps = rng.standard_normal((g, n_t, f)).astype(np.float32)
    coefs = np.stack([[1.1, 0.2, -0.3]] * g).astype(np.float32)
    return dict(
        gph=gph, w=w, n_t=n_t, bits=bits,
        nbrs=stack(nbr), wgts=stack(wgt.astype(np.float32)),
        wrevs=stack(wrev.astype(np.float32)), slots=stack(slot),
        diags=stack(diag.astype(np.float32)),
        bitsj=jnp.asarray(bits_p), xs=jnp.asarray(xs), xps=jnp.asarray(xps),
        coefs=jnp.asarray(coefs))


def test_segment_round_bn_tiling_matches_full_n():
    """bn < N (multi-block source axis) computes what bn = N computes, for
    the plain, receiver-masked, and sender-masked batched kernels alike."""
    from repro.kernels import ops, segment_round as sk

    fx = _ell_fixture(300, 64)   # n_t = 384 -> 3 source blocks at bn=128
    interp = ops.use_interpret()
    kw = dict(bm=128, bd=8, bf=64, interpret=interp)

    y_full = sk.segment_round_batched_pallas(
        fx["nbrs"], fx["wgts"], fx["diags"], fx["xs"], fx["xps"],
        fx["coefs"], bn=None, **kw)
    y_tile = sk.segment_round_batched_pallas(
        fx["nbrs"], fx["wgts"], fx["diags"], fx["xs"], fx["xps"],
        fx["coefs"], bn=128, **kw)
    np.testing.assert_allclose(
        np.asarray(y_tile), np.asarray(y_full), rtol=1e-6, atol=1e-6)

    y_full = sk.segment_round_masked_batched_pallas(
        fx["nbrs"], fx["wgts"], fx["slots"], fx["diags"], fx["bitsj"],
        fx["xs"], fx["xps"], fx["coefs"], bn=None, **kw)
    y_tile = sk.segment_round_masked_batched_pallas(
        fx["nbrs"], fx["wgts"], fx["slots"], fx["diags"], fx["bitsj"],
        fx["xs"], fx["xps"], fx["coefs"], bn=128, **kw)
    np.testing.assert_allclose(
        np.asarray(y_tile), np.asarray(y_full), rtol=1e-6, atol=1e-6)

    y_full = sk.segment_round_sender_masked_batched_pallas(
        fx["nbrs"], fx["wgts"], fx["wrevs"], fx["slots"], fx["diags"],
        fx["bitsj"], fx["xs"], fx["xps"], fx["coefs"], bn=None, **kw)
    y_tile = sk.segment_round_sender_masked_batched_pallas(
        fx["nbrs"], fx["wgts"], fx["wrevs"], fx["slots"], fx["diags"],
        fx["bitsj"], fx["xs"], fx["xps"], fx["coefs"], bn=128, **kw)
    np.testing.assert_allclose(
        np.asarray(y_tile), np.asarray(y_full), rtol=1e-6, atol=1e-6)


def test_sender_masked_segment_matches_dense_column_renorm():
    """Sparse sender-renorm kernel == dense column-renorm oracle: dropped
    mass W_ji of a dead edge returns to sender i's diagonal."""
    from repro.kernels import ops, segment_round as sk

    fx = _ell_fixture(120, 32)
    gph, w, n_t = fx["gph"], fx["w"], fx["n_t"]
    y = sk.segment_round_sender_masked_batched_pallas(
        fx["nbrs"], fx["wgts"], fx["wrevs"], fx["slots"], fx["diags"],
        fx["bitsj"], fx["xs"], fx["xps"], fx["coefs"],
        bm=128, bd=8, bf=32, bn=None, interpret=ops.use_interpret())
    for i in range(fx["bits"].shape[0]):
        m = np.eye(n_t)
        m[gph.edges[:, 0], gph.edges[:, 1]] = fx["bits"][i]
        m[gph.edges[:, 1], gph.edges[:, 0]] = fx["bits"][i]
        wm = w * m
        weff = wm + np.diag((w - wm).sum(axis=0))
        x_, xp_ = np.asarray(fx["xs"][i]), np.asarray(fx["xps"][i])
        y_ref = 1.1 * (weff @ x_) + 0.2 * x_ - 0.3 * xp_
        np.testing.assert_allclose(
            np.asarray(y[i]), y_ref, rtol=1e-4, atol=1e-4)


def test_build_ell_wrev_is_transposed_weight():
    """wrev[i, d] = W[nbr[i, d], i]: the weight of the reverse direction,
    asymmetric bases included; zero on padding slots."""
    from repro.kernels import ops

    rng = np.random.default_rng(9)
    gph = topology.random_geometric_sparse(60, rng)
    e_fwd = rng.uniform(0.1, 1.0, gph.num_edges)
    e_bwd = rng.uniform(0.1, 1.0, gph.num_edges)
    diag = rng.uniform(0.1, 1.0, gph.n)
    nbr, wgt, wrev, slot, dg = ops.build_ell(
        gph.edges, e_fwd, diag, gph.n, edge_w_rev=e_bwd)
    w = np.zeros((gph.n, gph.n))
    w[gph.edges[:, 0], gph.edges[:, 1]] = e_fwd   # W[i, j]: j -> i weight
    w[gph.edges[:, 1], gph.edges[:, 0]] = e_bwd
    for i in range(gph.n):
        for d in range(nbr.shape[1]):
            if wgt[i, d] == 0.0:
                assert wrev[i, d] == 0.0
            else:
                np.testing.assert_allclose(wgt[i, d], w[i, nbr[i, d]])
                np.testing.assert_allclose(wrev[i, d], w[nbr[i, d], i])


def test_segment_bn_policy_respects_vmem_budget(monkeypatch):
    from repro.kernels import ops

    # small N: one full-N block, no tiling
    bn, n_t = ops.segment_bn(100, 128, 128)
    assert (bn, n_t) == (128, 128)
    # squeeze the budget: the (bn, bf) block must fit 64 KiB -> bn = 128
    monkeypatch.setenv("REPRO_SEGMENT_VMEM_BUDGET", str(64 * 1024))
    bn, n_t = ops.segment_bn(1000, 128, 128)
    assert bn == 128 and n_t % bn == 0 and n_t >= 1000
    assert bn * 128 * 4 <= 64 * 1024
