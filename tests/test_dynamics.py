"""Time-varying consensus: schedule semantics, mass preservation, and the
engine-vs-numpy-reference contract (ISSUE acceptance): a Bernoulli
link-failure sweep matches the per-round masked-W re-normalized reference to
1e-6 in f32 across chain/grid2d/rgg, on both jax and pallas backends."""
import numpy as np
import pytest

from repro.core import dynamics as dyn
from repro.core import topology, weights
from repro.sweep import (
    SweepSpec,
    build_ensemble,
    build_round_masks,
    run_ensemble,
    run_sweep,
)


# ---------------------------------------------------------------------------
# Schedule primitives.
# ---------------------------------------------------------------------------

def test_parse_dynamics():
    assert dyn.parse_dynamics("static") == dyn.DynamicsSpec("static")
    assert dyn.parse_dynamics("bernoulli:0.1") == dyn.DynamicsSpec("bernoulli", p=0.1)
    assert dyn.parse_dynamics("churn:0.05") == dyn.DynamicsSpec("churn", p=0.05)
    assert dyn.parse_dynamics("rewire:0.2:50") == dyn.DynamicsSpec(
        "rewire", p=0.2, period=50)
    assert dyn.parse_dynamics("correlated:0.2") == dyn.DynamicsSpec(
        "correlated", p=0.2)
    assert dyn.parse_dynamics("correlated:0.2:3:10") == dyn.DynamicsSpec(
        "correlated", p=0.2, blocks=3, period=10)
    spec = dyn.DynamicsSpec("bernoulli", p=0.3)
    assert dyn.parse_dynamics(spec) is spec
    for bad in ("chebyshev:0.1", "bernoulli", "bernoulli:2.0", "rewire:0.1",
                "rewire:0.1:0", "static:1", "correlated", "correlated:0.2:0",
                "correlated:2.0"):
        with pytest.raises(ValueError):
            dyn.parse_dynamics(bad)


def test_correlated_bits_are_blockwise_and_held():
    """Correlated outages: bits depend on nodes only through their block,
    whole blocks go down together, and the pattern holds for ``period``
    rounds between redraws."""
    g = topology.chain(24)
    w = weights.metropolis_hastings(g)
    idx = dyn.edge_index(w)
    spec = dyn.parse_dynamics("correlated:0.4:4:5")
    bits = dyn.sample_edge_bits(spec, 60, idx, 24, np.random.default_rng(0))
    blk = (idx * 4) // 24                     # (E, 2) endpoint blocks
    for t in range(60):
        # a round's pattern is a pure function of endpoint block states:
        # within a block interior (both endpoints same block) all edges agree
        for b in range(4):
            inner = (blk[:, 0] == b) & (blk[:, 1] == b)
            assert len(set(bits[t][inner].tolist())) <= 1
        # an edge is up iff BOTH endpoint blocks are up this window
        up = {b: bits[t][(blk[:, 0] == b) & (blk[:, 1] == b)][0]
              for b in range(4)}
        np.testing.assert_array_equal(
            bits[t], (np.vectorize(up.get)(blk[:, 0])
                      & np.vectorize(up.get)(blk[:, 1])).astype(np.uint8))
    # held per window: identical bits within each period-5 window
    for w0 in range(0, 60, 5):
        np.testing.assert_array_equal(
            bits[w0:w0 + 5], np.broadcast_to(bits[w0], (5, len(idx))))
    # some full-block outages actually happen at p=0.4
    assert (bits == 0).any() and (bits == 1).any()


def test_masked_w_sender_renorm_preserves_column_sums():
    """Sender renorm: dropped weight returns to the SENDER's diagonal, so
    column sums (total mass) survive where receiver renorm keeps row sums."""
    rng = np.random.default_rng(1)
    g = topology.random_geometric(16, rng)
    w = weights.push_sum_weights(g)           # column-stochastic, asymmetric
    idx = dyn.edge_index(w)
    for _ in range(5):
        bits = (rng.random(len(idx)) > 0.4).astype(np.uint8)
        ws = dyn.masked_w(w, bits, idx, renorm="sender")
        np.testing.assert_allclose(ws.sum(axis=0), 1.0, atol=1e-12)
        wr = dyn.masked_w(w, bits, idx, renorm="receiver")
        np.testing.assert_allclose(wr.sum(axis=1), w.sum(axis=1), atol=1e-12)
    with pytest.raises(ValueError, match="renorm"):
        dyn.masked_w(w, bits, idx, renorm="midway")


def test_edge_index_matches_graph():
    g = topology.grid2d(3, 4)
    w = weights.metropolis_hastings(g)
    idx = dyn.edge_index(w)
    assert len(idx) == g.num_edges
    assert (idx[:, 0] < idx[:, 1]).all()
    np.testing.assert_array_equal(idx, g.edge_list())


def test_masked_w_stays_doubly_stochastic():
    rng = np.random.default_rng(0)
    w = weights.metropolis_hastings(topology.random_geometric(20, rng))
    idx = dyn.edge_index(w)
    for _ in range(5):
        bits = (rng.random(len(idx)) > 0.4).astype(np.uint8)
        weff = dyn.masked_w(w, bits, idx)
        np.testing.assert_allclose(weff, weff.T, atol=1e-15)
        np.testing.assert_allclose(weff.sum(axis=1), 1.0, atol=1e-12)
        # dropped edges are zeroed, live ones keep the nominal weight
        i, j = idx[:, 0], idx[:, 1]
        np.testing.assert_allclose(weff[i, j], w[i, j] * bits, atol=1e-15)


def test_masked_w_all_down_is_identity():
    w = weights.metropolis_hastings(topology.chain(8))
    idx = dyn.edge_index(w)
    weff = dyn.masked_w(w, np.zeros(len(idx), np.uint8), idx)
    np.testing.assert_allclose(weff, np.eye(8), atol=1e-15)


def test_rewire_holds_between_redraws():
    w = weights.metropolis_hastings(topology.ring(12))
    idx = dyn.edge_index(w)
    rng = np.random.default_rng(3)
    bits = dyn.sample_edge_bits("rewire:0.4:10", 35, idx, 12, rng)
    for t0 in (0, 10, 20, 30):
        block = bits[t0:t0 + 10]
        assert (block == block[0]).all()
    # successive blocks are (generically) different draws
    assert not (bits[0] == bits[10]).all() or not (bits[10] == bits[20]).all()


def test_churn_drops_all_edges_of_down_node():
    g = topology.star(9)
    w = weights.metropolis_hastings(g)
    idx = dyn.edge_index(w)
    rng = np.random.default_rng(1)
    bits = dyn.sample_edge_bits("churn:0.3", 50, idx, 9, rng)
    # reconstruct node-down events: hub is node 0, so a round where every
    # edge is down must exist at p=0.3 (hub down w.p. 0.3 per round)
    assert (bits.min(axis=1) == 0).any()
    # consistency: edges sharing a down endpoint fail together — for the
    # star, bits of edges (0, j) are independent only through node j when
    # the hub is up; when the hub is down the whole row is 0
    hub_down_rows = bits.max(axis=1) == 0
    assert hub_down_rows.sum() > 0


def test_monotone_coupling_across_p():
    """Failure sets are nested across p for cells sharing a graph."""
    spec = SweepSpec(topologies=("rgg",), sizes=(18,), designs=("memoryless",),
                     dynamics=("bernoulli:0.1", "bernoulli:0.4"),
                     graph_trials=2, num_trials=1, seed=11)
    ens = build_ensemble(spec)
    masks = build_round_masks(ens, 40, seed=spec.seed)
    lo = [i for i, c in enumerate(ens.configs) if c.dynamics == "bernoulli:0.1"]
    hi = [i for i, c in enumerate(ens.configs) if c.dynamics == "bernoulli:0.4"]
    for i, j in zip(lo, hi):
        assert ens.configs[i].graph_index == ens.configs[j].graph_index
        # an edge up at p=0.4 is necessarily up at p=0.1 (U >= 0.4 => U >= 0.1)
        assert (masks.bits[:, j] <= masks.bits[:, i]).all()
        assert masks.bits[:, j].mean() < masks.bits[:, i].mean()


# ---------------------------------------------------------------------------
# Engine contract (acceptance criterion).
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def bernoulli_grid():
    spec = SweepSpec(topologies=("chain", "grid2d", "rgg"), sizes=(12,),
                     designs=("memoryless", "asymptotic"), num_trials=3,
                     seed=5, dynamics=("static", "bernoulli:0.2"))
    ens = build_ensemble(spec)
    masks = build_round_masks(ens, 60, seed=spec.seed)
    return spec, ens, masks


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_bernoulli_sweep_matches_numpy_reference(bernoulli_grid, backend):
    """Engine == per-round masked-W re-normalized reference, 1e-6 in f32."""
    _, ens, masks = bernoulli_grid
    res = run_ensemble(ens, num_iters=60, backend=backend, round_masks=masks)
    for i, c in enumerate(ens.configs):
        n = c.n
        e = len(dyn.edge_index(ens.ws[i]))
        x32, mse32 = dyn.simulate_dynamic_reference(
            ens.ws[i][:n, :n], ens.x0[i][:n], tuple(ens.coefs[i]),
            masks.bits[:, i, :e], masks.idx[i, :e], dtype=np.float32,
        )
        np.testing.assert_allclose(
            res.x_final[i][:n], x32, atol=1e-6, rtol=0,
            err_msg=f"{c.topology}/{c.design}/{c.dynamics} vs f32 reference",
        )
        np.testing.assert_allclose(res.mse[i], mse32, atol=1e-6, rtol=0)
        # float64 semantics agree up to f32 rounding accumulation
        x64, mse64 = dyn.simulate_dynamic_reference(
            ens.ws[i][:n, :n], ens.x0[i][:n], tuple(ens.coefs[i]),
            masks.bits[:, i, :e], masks.idx[i, :e], dtype=np.float64,
        )
        np.testing.assert_allclose(res.x_final[i][:n], x64, atol=1e-5, rtol=1e-4)
        # padded nodes never acquire signal
        assert np.all(res.x_final[i][n:] == 0.0)


def test_static_dynamics_cell_equals_static_engine(bernoulli_grid):
    """'static' cells inside a dynamic grid == the mask-free scan."""
    _, ens, masks = bernoulli_grid
    dyn_res = run_ensemble(ens, num_iters=60, backend="jax", round_masks=masks)
    static_res = run_ensemble(ens, num_iters=60, backend="jax")
    for i in dyn_res.cells(dynamics="static"):
        np.testing.assert_allclose(
            dyn_res.x_final[i], static_res.x_final[i], atol=1e-6)
        np.testing.assert_allclose(dyn_res.mse[i], static_res.mse[i], atol=1e-7)


def test_failures_conserve_the_average(bernoulli_grid):
    """Mass preservation: the network mean survives any failure history."""
    _, ens, masks = bernoulli_grid
    res = run_ensemble(ens, num_iters=60, backend="jax", round_masks=masks)
    for i, c in enumerate(ens.configs):
        n = c.n
        np.testing.assert_allclose(
            res.x_final[i][:n].mean(axis=0), ens.x0[i][:n].mean(axis=0),
            atol=1e-5,
        )


def test_sustained_averaging_times_on_bernoulli_cell(bernoulli_grid):
    """First-crossing vs sustained hitting times on masked-dynamics cells.

    Bernoulli masking makes MSE curves non-monotone, so the default
    first-crossing time can under-report; ``sustained=True`` returns the
    first t after which the MSE stays below threshold (satellite feature).
    """
    _, ens, masks = bernoulli_grid
    res = run_ensemble(ens, num_iters=60, backend="jax", round_masks=masks)
    eps = 0.3                      # loose eps: crossings happen inside 60 rounds
    first = res.averaging_times(eps=eps)
    sust = res.averaging_times(eps=eps, sustained=True)
    thresh = (eps * eps) * res.mse[:, 0, :]
    assert first.shape == sust.shape == (ens.num_configs, 3)
    for i in range(ens.num_configs):
        for f in range(3):
            tf, ts = first[i, f], sust[i, f]
            if ts >= 0:
                # sustained is well-defined: below threshold from ts onward,
                # and never earlier than the first crossing
                assert (res.mse[i, ts:, f] <= thresh[i, f]).all()
                assert 0 <= tf <= ts
                if ts > 0:
                    assert res.mse[i, ts - 1, f] > thresh[i, f]
            elif tf >= 0:
                # crossed but did not stay below through the horizon
                assert res.mse[i, -1, f] > thresh[i, f]
    # the two modes genuinely differ somewhere on this non-monotone grid
    both = (first >= 0) & (sust >= 0)
    assert both.any()
    assert (sust[both] >= first[both]).all()


def test_run_sweep_dynamics_axis_end_to_end():
    """run_sweep wires SweepSpec.dynamics -> masks itself, deterministically."""
    spec = SweepSpec(topologies=("chain",), sizes=(10,),
                     designs=("memoryless", "asymptotic"), num_trials=2,
                     seed=9, dynamics=("static", "bernoulli:0.3"))
    r1 = run_sweep(spec, num_iters=120, backend="jax")
    r2 = run_sweep(spec, num_iters=120, backend="jax")
    np.testing.assert_array_equal(r1.mse, r2.mse)   # host RNG is seeded
    assert {c.dynamics for c in r1.configs} == {"static", "bernoulli:0.3"}
    # failures slow convergence: the failed memoryless cell's tail MSE is
    # (weakly) above its static twin's on the identical graph and inits
    [i_s] = r1.cells(design="memoryless", dynamics="static")
    [i_b] = r1.cells(design="memoryless", dynamics="bernoulli:0.3")
    assert r1.mse[i_b, -1].mean() > r1.mse[i_s, -1].mean()


def test_dynamic_grid_shards_across_devices():
    """The (T, G, E) bit schedule shards over 'data' with the grid, incl.
    pad-to-divisibility (G=6 on 4 devices). Subprocess: XLA_FLAGS must
    precede jax init."""
    import os
    import subprocess
    import sys
    import textwrap

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(root, "src")
    code = textwrap.dedent("""
        import jax, numpy as np
        from repro.core import dynamics as dyn
        from repro.sweep import SweepSpec, build_ensemble, build_round_masks, run_ensemble
        assert jax.device_count() == 4
        spec = SweepSpec(topologies=("chain",), sizes=(8, 10, 12),
                         designs=("memoryless",), num_trials=2, seed=0,
                         dynamics=("static", "bernoulli:0.25"))
        ens = build_ensemble(spec)          # G=6, padded to 8
        masks = build_round_masks(ens, 50, seed=0)
        res = run_ensemble(ens, num_iters=50, backend="jax", round_masks=masks)
        assert res.mse.shape == (6, 51, 2)
        i = res.cells(dynamics="bernoulli:0.25")[1]
        c = ens.configs[i]; n = c.n
        e = len(dyn.edge_index(ens.ws[i]))
        x_ref, mse_ref = dyn.simulate_dynamic_reference(
            ens.ws[i][:n, :n], ens.x0[i][:n], tuple(ens.coefs[i]),
            masks.bits[:, i, :e], masks.idx[i, :e], dtype=np.float32)
        err = max(float(np.abs(res.x_final[i][:n] - x_ref).max()),
                  float(np.abs(res.mse[i] - mse_ref).max()))
        assert err < 1e-6, err
        print("OK sharded dynamics", err)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300, env=env, cwd=root)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "OK sharded dynamics" in r.stdout


def test_spec_rejects_malformed_dynamics():
    with pytest.raises(ValueError, match="parameter"):
        SweepSpec(dynamics=("bernoulli",))
    with pytest.raises(ValueError, match="probability"):
        SweepSpec(dynamics=("churn:1.5",))
