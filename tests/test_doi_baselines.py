"""Algorithm 1 (decentralized lambda_2) + comparison baselines."""
import numpy as np
import pytest

from repro.core import accel, baselines, doi, topology, weights


def test_doi_rgg_accuracy(rng):
    """Paper regime: K = 2N, L = 10 on a 200-node RGG -> ~1e-3 accuracy."""
    g = topology.random_geometric(200, rng)
    w = weights.metropolis_hastings(g)
    lam2 = accel.lambda2(w)
    res = doi.estimate_lambda2(w, g, num_iters=2 * g.n, normalize_every=10, rng=rng)
    assert abs(res.lambda2_hat - lam2) / lam2 < 1e-3


def test_doi_chain_needs_more_iterations(rng):
    """Chain: lambda3/lambda2 -> 1, K must grow (paper uses K = N^2)."""
    g = topology.chain(30)
    w = weights.metropolis_hastings(g)
    lam2 = accel.lambda2(w)
    res = doi.estimate_lambda2(w, g, num_iters=g.n**2, normalize_every=10, rng=rng)
    assert abs(res.lambda2_hat - lam2) / lam2 < 1e-3


def test_doi_cost_model():
    """Cost = K + D*K/L + D; with L ~ D this is O(K) (paper Sec III-D)."""
    assert doi.doi_cost(400, 10, 20) == 400 + 20 * 40 + 20
    g = topology.random_geometric(100, np.random.default_rng(1))
    w = weights.metropolis_hastings(g)
    res = doi.estimate_lambda2(w, g, num_iters=200, normalize_every=10)
    d = topology.diameter(g.adjacency)
    assert res.num_max_consensus_ticks == d * (200 // 10) + 2 * d


def test_doi_zero_mean_start(rng):
    g = topology.ring(24)
    w = weights.metropolis_hastings(g)
    v = rng.standard_normal(24)
    v0 = w @ v - v
    assert abs(v0.sum()) < 1e-10  # 1^T W = 1^T kills the mean exactly


# ---------------------------------------------------------------------------
# Polynomial filtering (ref 14).
# ---------------------------------------------------------------------------

def test_polyfilt_beats_memoryless_per_tick(rng):
    g = topology.random_geometric(80, rng)
    w = weights.metropolis_hastings(g)
    lam2 = accel.lambda2(w)
    pf = baselines.design_poly_filter(w, 3)
    assert pf.rho_per_tick() < lam2  # acceleration per communication tick


def test_polyfilt_horner_matches_dense(rng):
    g = topology.ring(40)
    w = weights.metropolis_hastings(g)
    pf = baselines.design_poly_filter(w, 5)
    x = rng.standard_normal(40)
    dense = baselines.poly_filter_matrix(w, pf) @ x
    np.testing.assert_allclose(baselines.poly_filter_step(w, pf, x), dense, atol=1e-10)


def test_polyfilt_preserves_average(rng):
    g = topology.grid2d(5)
    w = weights.metropolis_hastings(g)
    pf = baselines.design_poly_filter(w, 4)
    assert abs(np.polynomial.polynomial.polyval(1.0, pf.coeffs) - 1.0) < 1e-9
    x = rng.standard_normal(25)
    y = baselines.poly_filter_step(w, pf, x)
    np.testing.assert_allclose(y.mean(), x.mean(), atol=1e-12)


def test_polyfilt_ill_conditioning_grows(rng):
    """Paper footnote 2: the Vandermonde system degrades with filter length."""
    g = topology.random_geometric(60, rng)
    w = weights.metropolis_hastings(g)
    c3 = baselines.design_poly_filter(w, 3).cond
    c7 = baselines.design_poly_filter(w, 7).cond
    assert c7 > 50 * c3


# ---------------------------------------------------------------------------
# Finite-time consensus (ref 16).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", [
    lambda: topology.ring(9),
    lambda: topology.chain(8),
    lambda: topology.grid2d(3),
])
def test_finite_time_exact(make):
    g = make()
    w = weights.metropolis_hastings(g)
    q = baselines.finite_time_matrix(w)
    np.testing.assert_allclose(q, np.full((g.n, g.n), 1.0 / g.n), atol=1e-7)


def test_finite_time_iterations_chain():
    """Chain MH has N distinct eigenvalues -> N-1 iterations."""
    w = weights.metropolis_hastings(topology.chain(12))
    assert baselines.finite_time_iterations(w) == 11
