"""Sweep engine: ensemble-vs-simulate agreement, one-compilation contract,
Theorem-2 bound across the chain family, and the Fig. 4 gain trend."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import accel, simulator
from repro.sweep import (
    SweepSpec,
    build_ensemble,
    merge_ensembles,
    run_ensemble,
    run_sweep,
    trace_count,
)


@pytest.fixture(scope="module")
def grid_result():
    """One heterogeneous grid (3 families x 3 designs, mixed sizes), run once."""
    spec = SweepSpec(
        topologies=("chain", "grid2d", "rgg"),
        sizes=(12, 20),
        designs=("memoryless", "ls", "asymptotic"),
        num_trials=3,
        seed=7,
    )
    tc0 = trace_count()
    res = run_sweep(spec, num_iters=120, backend="jax")
    return res, trace_count() - tc0


def test_single_compilation_for_full_grid(grid_result):
    """>=3 topology families x >=3 theta designs -> ONE jitted program."""
    res, compiles = grid_result
    assert res.ensemble.num_configs == 2 * 3 * 3  # sizes x families x designs
    assert compiles == 1


def test_ensemble_matches_per_graph_simulate(grid_result):
    """Every cell of the vmapped ensemble == its standalone simulate() run.

    Cells with n=12 are zero-padded to the grid's Nmax=20 inside the batch,
    so this also proves padding exactness. jax backend on both sides: the
    arithmetic must agree bit-for-bit-ish (same fused scan, G=1 vs G=18).
    """
    res, _ = grid_result
    for i, c in enumerate(res.configs):
        n = c.n
        w = res.ensemble.ws[i][:n, :n]
        x0 = res.ensemble.x0[i][:n]
        r = simulator.simulate(
            w, x0, 120,
            alpha=c.alpha, theta=c.theta,
            backend="jax",
        )
        np.testing.assert_allclose(res.mse[i], r.mse, rtol=1e-5, atol=1e-9)
        np.testing.assert_allclose(res.x_final[i][:n], r.x_final, rtol=1e-4, atol=1e-6)
        # padded nodes never acquire signal
        assert np.all(res.x_final[i][n:] == 0.0)


def test_ensemble_matches_numpy_float64(grid_result):
    """fp32 engine vs float64 numpy semantics on early iterations."""
    res, _ = grid_result
    for i in np.random.default_rng(0).choice(len(res.configs), 4, replace=False):
        c = res.configs[i]
        n = c.n
        r = simulator.simulate(
            res.ensemble.ws[i][:n, :n], res.ensemble.x0[i][:n], 40,
            alpha=c.alpha, theta=c.theta, backend="numpy",
        )
        np.testing.assert_allclose(res.mse[i][:41], r.mse, rtol=1e-3, atol=1e-6)


def test_pallas_sweep_matches_jax_sweep():
    spec = SweepSpec(topologies=("chain", "rgg"), sizes=(14,),
                     designs=("memoryless", "asymptotic"), num_trials=2, seed=3)
    r_jax = run_sweep(spec, num_iters=60, backend="jax")
    r_pal = run_sweep(spec, num_iters=60, backend="pallas")
    np.testing.assert_allclose(r_pal.mse, r_jax.mse, rtol=1e-4, atol=1e-8)
    np.testing.assert_allclose(r_pal.x_final, r_jax.x_final, rtol=1e-4, atol=1e-6)


def test_theorem2_bound_across_chain_family():
    """rho(Phi3[alpha*]-J) <= 1 - sqrt(Psi) for every chain cell (Theorem 2)."""
    spec = SweepSpec(topologies=("chain",), sizes=(10, 24, 48, 96),
                     designs=("asymptotic",), num_trials=1, seed=0)
    ens = build_ensemble(spec)
    assert len(ens.configs) == 4
    for c in ens.configs:
        assert c.psi > 0.0
        assert c.rho_accel <= accel.rho_accel_bound(c.psi) + 1e-12, (
            f"chain n={c.n}: rho={c.rho_accel} > bound {accel.rho_accel_bound(c.psi)}"
        )
        # and the closed form used by the grid metadata matches accel.rho_accel
        np.testing.assert_allclose(
            c.rho_accel, accel.rho_accel(c.lam2, c.theta), atol=1e-9
        )


def test_chain_gain_trend_factor_n():
    """Fig. 4 / Theorem 3: measured gain on chains grows ~linearly with N."""
    spec = SweepSpec(topologies=("chain",), sizes=(10, 20, 40),
                     designs=("memoryless", "asymptotic"),
                     num_trials=1, init="paper", seed=0)
    ens = build_ensemble(spec)
    res = run_ensemble(ens, num_iters=4500, backend="jax")
    times = res.averaging_times(eps=1e-3)[:, 0]
    gains = {}
    for n in (10, 20, 40):
        [i] = res.cells(topology="chain", n=n, design="memoryless")
        [j] = res.cells(topology="chain", n=n, design="asymptotic")
        assert times[i] > 0 and times[j] > 0, f"n={n} did not converge in cap"
        gains[n] = times[i] / times[j]
        theory = res.configs[j].gain_asym
        assert 0.4 * theory < gains[n] < 2.5 * theory
    # doubling N should grow the gain markedly (~2x asymptotically)
    assert gains[20] / gains[10] > 1.5
    assert gains[40] / gains[20] > 1.5


def test_merge_ensembles_repads():
    e1 = build_ensemble(SweepSpec(topologies=("chain",), sizes=(8,),
                                  designs=("memoryless",), num_trials=2, seed=0))
    e2 = build_ensemble(SweepSpec(topologies=("ring",), sizes=(15,),
                                  designs=("memoryless",), num_trials=2, seed=0))
    m = merge_ensembles(e1, e2)
    assert m.n_max == 15 and m.num_configs == 2
    assert m.ws.shape == (2, 15, 15)
    np.testing.assert_allclose(m.ws[0][:8, :8], e1.ws[0])
    assert np.all(m.ws[0][8:] == 0.0) and np.all(m.ws[0][:, 8:] == 0.0)
    assert list(m.node_counts) == [8, 15]


def test_grid_axis_shards_across_devices():
    """G axis over the mesh 'data' axis, incl. pad-to-divisibility (G=3 on 4
    devices). Subprocess: XLA_FLAGS must precede jax init."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(root, "src")
    code = textwrap.dedent("""
        import jax, numpy as np
        from repro.core import simulator
        from repro.sweep import SweepSpec, run_sweep
        assert jax.device_count() == 4
        spec = SweepSpec(topologies=("chain",), sizes=(8, 10, 12),
                         designs=("memoryless",), num_trials=2, seed=0)
        res = run_sweep(spec, num_iters=50, backend="jax")   # G=3, padded to 4
        assert res.mse.shape == (3, 51, 2)
        c = res.configs[1]; n = c.n
        r = simulator.simulate(res.ensemble.ws[1][:n, :n], res.ensemble.x0[1][:n],
                               50, backend="jax")
        err = float(np.abs(r.mse - res.mse[1]).max())
        assert err < 1e-6, err
        print("OK sharded", err)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300, env=env, cwd=root)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "OK sharded" in r.stdout


def test_run_batch_rejects_unknown_backend(rng):
    ws = rng.standard_normal((1, 4, 4))
    x0 = rng.standard_normal((1, 4, 2))
    with pytest.raises(ValueError, match="backend"):
        from repro.sweep import run_batch
        run_batch(ws, x0, np.ones((1, 3)), num_iters=3, backend="tensorflow")


def test_spec_rejects_unknown_design():
    with pytest.raises(ValueError, match="design"):
        SweepSpec(designs=("memoryless", "chebyshev"))
