"""Consensus-algorithm registry: cross-backend conformance (ISSUE acceptance),
the mixed-algorithm one-compilation contract, the async pairwise machinery,
and the ~20-line custom-registration seam the ROADMAP quickstart documents.

The conformance suite iterates the registry and asserts, for EVERY registered
algorithm, its declared conservation law — mean conservation for the
doubly-stochastic family, total-(value, mass) conservation for the push-sum
family — and agreement with its float64/float32 host reference on
chain/grid2d/rgg, static / bernoulli:0.1 / correlated dynamics, jax and
pallas."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import algorithms as alg
from repro.core import dynamics as dyn
from repro.core import baselines, topology, weights
from repro.sweep import (
    SweepSpec,
    build_ensemble,
    build_round_masks,
    run_ensemble,
    run_sweep,
    trace_count,
)


# ---------------------------------------------------------------------------
# Registry mechanics.
# ---------------------------------------------------------------------------

def test_registry_resolves_seed_algorithms():
    names = alg.registered_algorithms()
    for seed in ("memoryless", "accel", "poly_filter", "async_pairwise",
                 "push_sum", "ratio_consensus"):
        assert seed in names
    assert alg.get_algorithm("accel").num_taps == 2
    assert alg.get_algorithm("memoryless").num_taps == 1
    assert alg.get_algorithm("async_pairwise").needs_schedule
    # parameterized specs parse like the dynamics axis
    p5 = alg.get_algorithm("poly_filter:5")
    assert p5.degree == 5 and p5.num_coefs == 6
    # instances are cached per spec string (trace-time identity stability)
    assert alg.get_algorithm("poly_filter:5") is p5
    # the push-sum family declares its invariant class and renorm rule
    ps = alg.get_algorithm("push_sum")
    rc = alg.get_algorithm("ratio_consensus:0.3")
    for a in (ps, rc):
        assert a.num_taps == 2
        assert a.invariant == "mass"
        assert a.mass_renorm == "sender"
        assert not a.symmetric_base
    assert rc.c == 0.3
    with pytest.raises(ValueError, match="self-mass"):
        alg.get_algorithm("ratio_consensus:1.5")
    # the pre-existing family keeps the default declarations
    assert alg.get_algorithm("accel").invariant == "mean"
    assert alg.get_algorithm("accel").mass_renorm == "receiver"


def test_registry_rejects_unknown_algorithm():
    with pytest.raises(ValueError, match="unknown consensus algorithm"):
        alg.get_algorithm("chebyshev")
    with pytest.raises(ValueError, match="algorithm"):
        SweepSpec(algorithms=("accel", "chebyshev"))


def test_pairwise_base_matrix_masks_to_boyd_matrix():
    """One-hot masking of B under the mass-preserving rule == Boyd's W(i,j)."""
    w = weights.metropolis_hastings(topology.random_geometric(12, np.random.default_rng(0)))
    b = alg.pairwise_base_matrix(w)
    np.testing.assert_allclose(b.sum(axis=1), 1.0, atol=1e-12)
    idx = dyn.edge_index(w)
    for e in (0, len(idx) // 2, len(idx) - 1):
        bits = np.zeros(len(idx), np.uint8)
        bits[e] = 1
        weff = dyn.masked_w(b, bits, idx)
        i, j = idx[e]
        expect = np.eye(12)
        expect[i, i] = expect[j, j] = expect[i, j] = expect[j, i] = 0.5
        np.testing.assert_allclose(weff, expect, atol=1e-12)


# ---------------------------------------------------------------------------
# Cross-backend conformance (acceptance criterion).
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def conformance_grid():
    """Every registered algorithm x chain/grid2d/rgg x three dynamics classes."""
    spec = SweepSpec(
        topologies=("chain", "grid2d", "rgg"), sizes=(12,),
        designs=("asymptotic",), algorithms=tuple(alg.registered_algorithms()),
        num_trials=2, seed=5,
        dynamics=("static", "bernoulli:0.1", "correlated:0.25:3:5"),
    )
    ens = build_ensemble(spec)
    masks = build_round_masks(ens, 45, seed=spec.seed)
    assert masks is not None          # async_pairwise forces a schedule
    return ens, masks


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_every_registered_algorithm_matches_host_reference(conformance_grid, backend):
    """Engine == per-tick host reference (1e-6 in f32) for the whole registry,
    plus each algorithm's declared invariant class: mean conservation for the
    doubly-stochastic family, total value/mass conservation (checked on the
    raw carry taps) for the push-sum family."""
    ens, masks = conformance_grid
    res = run_ensemble(ens, num_iters=45, backend=backend, round_masks=masks,
                       return_taps=True)
    part_of = {}
    for name, s, e, taps in res.taps:
        # aux-carry contract: return_taps exposes exactly num_taps state
        # slots — estimator probes / running spectral estimates (num_aux)
        # are internal and never leak into the displayed-state surface
        assert len(taps) == alg.get_algorithm(name).num_taps, name
        for i in range(s, e):
            part_of[i] = (s, taps)
    seen = set()
    for i, c in enumerate(ens.configs):
        a = alg.get_algorithm(c.algorithm)
        seen.add(a.name)
        n = c.n
        e = len(dyn.edge_index(ens.ws[i]))
        # f32 rounding scales with the round's coefficient mass: ~1 for the
        # one-matvec family, the l1 coefficient norm for the Horner ticks;
        # the ratio family's displayed quotient compounds the rounding of
        # two states, hence the extra factor. ref_tol_factor widens the
        # TRAJECTORY comparisons only (feedback/non-normal recursions
        # amplify backend-order noise); the invariant checks below stay at
        # their exact tolerances for every algorithm.
        tol = 1e-6 * max(1.0, float(np.abs(ens.coefs[i]).sum()))
        tol *= a.ref_tol_factor
        if a.invariant == "mass":
            tol *= 4.0
        x32, mse32 = a.reference_run(
            ens.ws[i][:n, :n], ens.x0[i][:n], ens.coefs[i], 45,
            bits=masks.bits[:, i, :e], idx=masks.idx[i, :e], dtype=np.float32,
        )
        err_msg = f"{c.algorithm}/{c.topology}/{c.dynamics} vs f32 reference"
        np.testing.assert_allclose(res.x_final[i][:n], x32, atol=tol, rtol=0,
                                   err_msg=err_msg)
        np.testing.assert_allclose(res.mse[i], mse32, atol=tol, rtol=0,
                                   err_msg=err_msg)
        # float64 semantics agree up to f32 rounding accumulation
        x64, _ = a.reference_run(
            ens.ws[i][:n, :n], ens.x0[i][:n], ens.coefs[i], 45,
            bits=masks.bits[:, i, :e], idx=masks.idx[i, :e], dtype=np.float64,
        )
        np.testing.assert_allclose(res.x_final[i][:n], x64,
                                   atol=1e-5 * a.ref_tol_factor, rtol=1e-4)
        if a.invariant == "mass":
            # push-sum family: the displayed ratio's node mean is NOT
            # invariant, but the TOTAL of each carry tap is — the value tap
            # keeps sum(x0), the mass tap keeps n, under every schedule
            s0, taps = part_of[i]
            sv, mv = taps
            np.testing.assert_allclose(
                sv[i - s0][:n].sum(axis=0), ens.x0[i][:n].sum(axis=0),
                atol=1e-4 * n, err_msg=f"{c.algorithm} lost total value")
            np.testing.assert_allclose(
                mv[i - s0][:n].sum(axis=0), float(n),
                atol=1e-4 * n, err_msg=f"{c.algorithm} lost total mass")
        else:
            # doubly-stochastic family: every effective round matrix keeps
            # the network average, whatever the schedule did
            np.testing.assert_allclose(
                res.x_final[i][:n].mean(axis=0), ens.x0[i][:n].mean(axis=0),
                atol=1e-5, err_msg=f"{c.algorithm} lost the network average")
        # padded nodes never acquire signal
        assert np.all(res.x_final[i][n:] == 0.0)
    assert seen == {alg.get_algorithm(nm).name for nm in alg.registered_algorithms()}


def test_mixed_algorithm_grid_compiles_once_per_backend():
    """ISSUE acceptance: the mixed (memoryless, accel, async_pairwise) grid
    executes as ONE jitted program on each backend."""
    spec = SweepSpec(
        topologies=("chain",), sizes=(10,), designs=("asymptotic",),
        algorithms=("memoryless", "accel", "async_pairwise"),
        num_trials=2, seed=1,
    )
    for backend in ("jax", "pallas"):
        tc0 = trace_count()
        res = run_sweep(spec, num_iters=40, backend=backend)
        assert trace_count() - tc0 == 1, backend
        assert res.ensemble.layout == (
            ("memoryless", 0, 1), ("accel", 1, 2), ("async_pairwise", 2, 3))
        assert {c.algorithm for c in res.configs} == {
            "memoryless", "accel", "async_pairwise"}


def test_pallas_mixed_grid_matches_jax():
    spec = SweepSpec(
        topologies=("chain", "rgg"), sizes=(12,), designs=("asymptotic",),
        algorithms=("accel", "poly_filter:3", "async_pairwise"),
        num_trials=2, seed=3,
    )
    r_jax = run_sweep(spec, num_iters=40, backend="jax")
    r_pal = run_sweep(spec, num_iters=40, backend="pallas")
    np.testing.assert_allclose(r_pal.mse, r_jax.mse, rtol=1e-4, atol=1e-7)
    np.testing.assert_allclose(r_pal.x_final, r_jax.x_final, rtol=1e-4, atol=1e-6)


def test_async_needs_round_masks():
    """run_batch refuses an async partition without a schedule, loudly."""
    spec = SweepSpec(topologies=("chain",), sizes=(8,),
                     algorithms=("async_pairwise",), num_trials=1, seed=0)
    ens = build_ensemble(spec)
    from repro.sweep import run_batch
    with pytest.raises(ValueError, match="round_masks"):
        run_batch(ens.ws, ens.x0, ens.coefs, ens.node_counts,
                  num_iters=5, backend="jax", algos=ens.layout)


def test_async_schedule_one_edge_per_tick_and_dynamics_coupling():
    a = alg.get_algorithm("async_pairwise")
    g = topology.ring(10)
    w = weights.metropolis_hastings(g)
    idx = dyn.edge_index(w)
    rng = dyn.graph_rng(0, ("ring", 10, 0))
    dyn_bits = dyn.sample_edge_bits("bernoulli:0.3", 200, idx, 10, rng)
    bits = a.schedule_bits(dyn_bits, idx, 10, rng)
    # at most one woken edge per tick; zero exactly when the woken edge is down
    assert bits.sum(axis=1).max() == 1
    assert (bits <= dyn_bits).all()
    assert (bits.sum(axis=1) == 0).any()      # some wakes hit a failed link
    assert bits.sum() > 100                   # but most deliver at p=0.3


def test_poly_filter_engine_matches_run_poly_filter_ticks():
    """The registered poly_filter reproduces baselines.run_poly_filter's
    super-iteration states on a static graph (tick-fairness accounting)."""
    spec = SweepSpec(topologies=("chain",), sizes=(10,),
                     algorithms=("poly_filter:3",), num_trials=1, seed=0,
                     init="paper")
    ens = build_ensemble(spec)
    w = np.asarray(ens.ws[0], np.float64)          # the grid's (possibly lazy) W
    filt = baselines.design_poly_filter(w, 3)
    np.testing.assert_allclose(ens.coefs[0][:4], filt.coeffs, atol=1e-6)
    x_ref = np.asarray(ens.x0[0], np.float64)
    res = run_ensemble(ens, num_iters=12, backend="jax")
    for ticks in (3, 6, 9, 12):
        # display state at tick k*m == the m-th super-iteration output
        r = run_ensemble(ens, num_iters=ticks, backend="jax")
        x_ref_t = baselines.run_poly_filter(w, filt, x_ref, ticks)
        np.testing.assert_allclose(r.x_final[0], x_ref_t, atol=1e-5)
    # inside a super-iteration the display state holds (mse flat ticks 0..2)
    np.testing.assert_allclose(res.mse[0][1], res.mse[0][2], atol=1e-7)
    np.testing.assert_allclose(res.mse[0][0], res.mse[0][1], atol=1e-7)


def test_custom_algorithm_registration_quickstart():
    """The ROADMAP's ~20-line seam: register a new rule, sweep it, verify it."""

    class LazyMix(alg.ConsensusAlgorithm):
        """x(t+1) = (x + W_eff x) / 2 — a lazy chain, in one registration."""

        name = spec = "lazy_mix"
        num_taps = 1

        def round_body(self, prim, params, carry, t):
            (x,) = carry
            coef = jnp.broadcast_to(
                jnp.asarray([0.5, 0.5, 0.0], jnp.float32), (x.shape[0], 3))
            return (prim(x, x, coef),)

        def ref_coef(self, params):
            return (0.5, 0.5, 0.0)

    alg.register_algorithm("lazy_mix", LazyMix)
    try:
        spec = SweepSpec(topologies=("chain",), sizes=(9,),
                         algorithms=("lazy_mix", "memoryless"), num_trials=2,
                         seed=2, dynamics=("static", "bernoulli:0.2"))
        res = run_sweep(spec, num_iters=30, backend="jax")
        masks = build_round_masks(res.ensemble, 30, seed=spec.seed)
        for i, c in enumerate(res.configs):
            if c.algorithm != "lazy_mix":
                continue
            e = len(dyn.edge_index(res.ensemble.ws[i]))
            a = alg.get_algorithm("lazy_mix")
            x32, mse32 = a.reference_run(
                res.ensemble.ws[i][:9, :9], res.ensemble.x0[i][:9],
                res.ensemble.coefs[i], 30,
                bits=masks.bits[:, i, :e], idx=masks.idx[i, :e],
                dtype=np.float32)
            np.testing.assert_allclose(res.x_final[i][:9], x32, atol=1e-6)
            np.testing.assert_allclose(res.mse[i], mse32, atol=1e-6)
        # lazy mixing is slower than the plain W round on the same inits
        [i_l] = res.cells(algorithm="lazy_mix", dynamics="static")
        [i_m] = res.cells(algorithm="memoryless", dynamics="static")
        assert res.mse[i_l, -1].mean() > res.mse[i_m, -1].mean()
    finally:
        alg.register_algorithm("lazy_mix", LazyMix)  # leave a clean entry


def test_directed_lossy_cell_ratio_converges_where_memoryless_drifts():
    """Acceptance: on a strongly connected digraph under 10% i.i.d. packet
    loss the naive masked memoryless iteration reaches consensus on a
    Perron-weighted mixture — NOT the average (its sustained averaging time
    never fires) — while push_sum and ratio_consensus converge to the true
    average through the sender-renormalized lossy rounds."""
    spec = SweepSpec(
        topologies=("directed",), sizes=(16,), designs=("memoryless",),
        algorithms=("memoryless", "push_sum", "ratio_consensus:0.5"),
        dynamics=("bernoulli:0.1",), num_trials=3, layout="dense", seed=11)
    ens = build_ensemble(spec)
    masks = build_round_masks(ens, 300, seed=spec.seed)
    res = run_ensemble(ens, num_iters=300, round_masks=masks)
    times = res.averaging_times(eps=1e-3, sustained=True)
    xbar = ens.x0.sum(axis=1) / np.asarray(ens.node_counts)[:, None]
    for i, c in enumerate(ens.configs):
        err = np.abs(res.x_final[i, :16] - xbar[i]).max()
        if c.algorithm == "memoryless":
            assert (times[i] == -1).all(), (c.algorithm, times[i])
            assert err > 1e-3, err        # visibly off the true average
        else:
            assert (times[i] >= 0).all(), (c.algorithm, times[i])
            assert err < 1e-3, (c.algorithm, err)


def test_fig_async_chain_bracketing():
    """Acceptance: async pairwise tick-counts sit between the synchronous
    memoryless and two-tap curves on the chain (tick = E exchanges)."""
    from benchmarks import fig_async

    rows = fig_async.run(topologies=("chain",), size=12, graph_trials=1,
                         num_trials=2, eps=1e-3, backend="jax", seed=0)
    [row] = rows
    assert row["bracketed"], row
    assert row["T_accel"] < row["T_async_ticks"] < row["T_memoryless"], row
