"""Unit tests for the perf-gate comparison core (``benchmarks.run._gate_rows``).

The gate compares like-for-like only: timings carry an execution ``mode``
tag ("compiled" vs "pallas-interpret") and rows whose mode changed against
the baseline are skipped, never ratioed — a baseline stamped in interpret
mode on CPU must not hard-fail (or silently pass) a compiled TPU run.
"""
import benchmarks.run as bench_run


def _row(name, us, mode=None):
    r = {"bench": name, "us_per_call": us}
    if mode is not None:
        r["mode"] = mode
    return r


def test_gate_passes_within_ratio():
    fresh = [_row("sweep_jax_G12", 120.0, "compiled")]
    base = {"sweep_jax_G12": _row("sweep_jax_G12", 100.0, "compiled")}
    lines, failures = bench_run._gate_rows(fresh, base, 1.5)
    assert failures == []
    assert any("ok" in ln for ln in lines)


def test_gate_fails_on_regression():
    fresh = [_row("sweep_jax_G12", 200.0, "compiled")]
    base = {"sweep_jax_G12": _row("sweep_jax_G12", 100.0, "compiled")}
    _, failures = bench_run._gate_rows(fresh, base, 1.5)
    assert failures == [("sweep_jax_G12", 2.0)]


def test_gate_skips_cross_mode_rows():
    # 1000x "regression" that is really interpret-vs-compiled: must SKIP
    fresh = [_row("gossip_round_fused", 100000.0, "pallas-interpret")]
    base = {"gossip_round_fused": _row("gossip_round_fused", 100.0, "compiled")}
    lines, failures = bench_run._gate_rows(fresh, base, 1.5)
    assert failures == []
    assert any("SKIP" in ln and "cross-mode" in ln for ln in lines)
    # and the reverse direction (baseline interpret, fresh compiled)
    fresh = [_row("gossip_round_fused", 100.0, "compiled")]
    base = {"gossip_round_fused": _row(
        "gossip_round_fused", 100000.0, "pallas-interpret")}
    _, failures = bench_run._gate_rows(fresh, base, 1.5)
    assert failures == []


def test_gate_untagged_baseline_still_gates():
    # pre-mode-tag baselines keep gating (no silent skip of real regressions)
    fresh = [_row("ssd_chunked", 300.0, "compiled")]
    base = {"ssd_chunked": _row("ssd_chunked", 100.0)}
    _, failures = bench_run._gate_rows(fresh, base, 1.5)
    assert failures == [("ssd_chunked", 3.0)]


def test_gate_covers_directed_lane_rows():
    # fig_directed's whole-grid timing row is sweep_-prefixed so it gates;
    # its per-cell accuracy rows (directed_*) are tracked, never gated.
    fresh = [
        _row("sweep_directed_pallas_G12x300it", 220.0, "pallas-interpret"),
        _row("directed_push_sum_static", 999999.0, "pallas-interpret"),
    ]
    base = {"sweep_directed_pallas_G12x300it": _row(
        "sweep_directed_pallas_G12x300it", 100.0, "pallas-interpret")}
    lines, failures = bench_run._gate_rows(fresh, base, 1.5)
    assert failures == [("sweep_directed_pallas_G12x300it", 2.2)]
    assert not any("directed_push_sum" in ln for ln in lines)
    # like-for-like only: the same lane re-stamped compiled must skip
    fresh = [_row("sweep_directed_pallas_G12x300it", 220.0, "compiled")]
    lines, failures = bench_run._gate_rows(fresh, base, 1.5)
    assert failures == []
    assert any("SKIP" in ln for ln in lines)


def test_gate_covers_adaptive_lane_rows():
    # fig_adaptive's whole-grid timing row is sweep_-prefixed so it gates;
    # its per-cell accuracy rows (adaptive_* / mtap_*) are tracked, never
    # gated — averaging times are asserted inside the bench itself.
    fresh = [
        _row("sweep_adaptive_pallas_G16x800it", 330.0, "pallas-interpret"),
        _row("adaptive_chain_bernoulli:0.1_adaptive", 999999.0, "pallas-interpret"),
        _row("mtap_chain_accel_m3", 999999.0, "pallas-interpret"),
    ]
    base = {"sweep_adaptive_pallas_G16x800it": _row(
        "sweep_adaptive_pallas_G16x800it", 100.0, "pallas-interpret")}
    lines, failures = bench_run._gate_rows(fresh, base, 1.5)
    assert failures == [("sweep_adaptive_pallas_G16x800it", 3.3)]
    assert not any("adaptive_chain" in ln or "mtap_" in ln for ln in lines)
    # like-for-like only: the same lane re-stamped compiled must skip
    fresh = [_row("sweep_adaptive_pallas_G16x800it", 330.0, "compiled")]
    lines, failures = bench_run._gate_rows(fresh, base, 1.5)
    assert failures == []
    assert any("SKIP" in ln for ln in lines)


def test_gate_covers_autotuned_and_segment_rows():
    # the autotuned batched rows and the ELL segment row are gated prefixes
    fresh = [
        _row("gossip_round_batched_static_G2N128F128", 210.0, "pallas-interpret"),
        _row("gossip_round_batched_tuned_G2N128F128", 100.0, "pallas-interpret"),
        _row("segment_round_N128F128", 400.0, "pallas-interpret"),
    ]
    base = {
        "gossip_round_batched_static_G2N128F128": _row(
            "gossip_round_batched_static_G2N128F128", 100.0, "pallas-interpret"),
        "gossip_round_batched_tuned_G2N128F128": _row(
            "gossip_round_batched_tuned_G2N128F128", 100.0, "pallas-interpret"),
        "segment_round_N128F128": _row(
            "segment_round_N128F128", 100.0, "pallas-interpret"),
    }
    _, failures = bench_run._gate_rows(fresh, base, 1.5)
    assert sorted(n for n, _ in failures) == [
        "gossip_round_batched_static_G2N128F128", "segment_round_N128F128"]


def test_trajectory_roundtrip(tmp_path):
    path = str(tmp_path / "TRAJECTORY.jsonl")
    rows = [
        _row("gossip_round_fused_N200xF300", 1800.0, "pallas-interpret"),
        _row("simulator_numpy", 99.0, "compiled"),  # not gated: not appended
    ]
    bench_run._append_trajectory(rows, path=path)
    # a second append supersedes the first for the same bench name
    bench_run._append_trajectory(
        [_row("gossip_round_fused_N200xF300", 1700.0, "pallas-interpret")],
        path=path)
    got = bench_run._trajectory_rows(path)
    assert set(got) == {"gossip_round_fused_N200xF300"}
    r = got["gossip_round_fused_N200xF300"]
    assert r["us_per_call"] == 1700.0 and r["mode"] == "pallas-interpret"
    # each line carries a commit stamp (env GITHUB_SHA or git rev-parse)
    import json

    lines = [json.loads(ln) for ln in open(path)]
    assert len(lines) == 2 and all("commit" in ln for ln in lines)
    # trajectory rows plug straight into the gate comparison
    fresh = [_row("gossip_round_fused_N200xF300", 3000.0, "pallas-interpret")]
    _, failures = bench_run._gate_rows(fresh, got, 1.5)
    assert failures and failures[0][0] == "gossip_round_fused_N200xF300"


def test_trajectory_tolerates_corruption_and_absence(tmp_path):
    missing = str(tmp_path / "nope.jsonl")
    assert bench_run._trajectory_rows(missing) == {}
    path = tmp_path / "TRAJECTORY.jsonl"
    path.write_text(
        "not json at all\n"
        '{"commit": "abc", "rows": {"sweep_x": {"us_per_call": 5.0, '
        '"mode": "compiled"}}}\n'
        '{"commit": "def"}\n')
    got = bench_run._trajectory_rows(str(path))
    assert got == {"sweep_x": {
        "bench": "sweep_x", "us_per_call": 5.0, "mode": "compiled"}}


def test_gate_ignores_untracked_and_new_rows():
    fresh = [
        _row("simulator_numpy", 999999.0, "compiled"),   # not a gated prefix
        _row("sweep_sparse_new", 50.0, "compiled"),      # no baseline row
    ]
    lines, failures = bench_run._gate_rows(fresh, {}, 1.5)
    assert failures == []
    assert any("NEW" in ln for ln in lines)
    assert not any("simulator_numpy" in ln for ln in lines)
