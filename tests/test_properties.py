"""Property-based tests for ``core.topology`` + ``core.weights``.

Real hypothesis strategies in CI (the ``test`` extra installs it); the
deterministic shim in ``tests/conftest.py`` serves hermetic local images.
Properties, over generated sizes/seeds:

* every generator returns a symmetric 0/1 adjacency with a zero diagonal;
* generators that claim connectivity (deterministic families, RGG's
  resample-until-connected contract) actually deliver it;
* Metropolis-Hastings W on any connected draw is symmetric, doubly
  stochastic, and a strict contraction off the consensus line
  (rho(W - J) < 1) — the Xiao-Boyd conditions the whole paper rests on.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import topology, weights

# deterministic families: builder given n (clamped to each family's domain)
_FAMILIES = [
    ("chain", lambda n: topology.chain(max(n, 2))),
    ("ring", lambda n: topology.ring(max(n, 3))),
    ("grid2d", lambda n: topology.grid2d(max(2, int(round(n ** 0.5))))),
    ("torus2d", lambda n: topology.torus2d(max(2, int(round(n ** 0.5))))),
    ("star", lambda n: topology.star(max(n, 3))),
    ("hypercube", lambda n: topology.hypercube(max(1, n.bit_length() % 5))),
    ("complete", lambda n: topology.complete(max(n, 2))),
]


def _assert_valid_adjacency(g):
    a = g.adjacency
    assert a.shape == (g.n, g.n)
    np.testing.assert_array_equal(a, a.T)            # symmetric
    np.testing.assert_array_equal(np.diag(a), 0.0)   # zero diagonal
    assert set(np.unique(a)) <= {0.0, 1.0}           # 0/1 entries


@settings(max_examples=15, deadline=None)
@given(n=st.integers(min_value=2, max_value=40))
def test_deterministic_families_valid_and_connected(n):
    for _, make in _FAMILIES:
        g = make(n)
        _assert_valid_adjacency(g)
        assert topology.is_connected(g.adjacency), g.name


@settings(max_examples=10, deadline=None)
@given(n=st.integers(min_value=8, max_value=60), seed=st.integers(0, 2**31 - 1))
def test_rgg_draws_connected_as_claimed(n, seed):
    g = topology.random_geometric(n, np.random.default_rng(seed))
    _assert_valid_adjacency(g)
    assert topology.is_connected(g.adjacency)  # the resample contract
    assert g.coords is not None and g.coords.shape == (n, 2)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(min_value=3, max_value=30), seed=st.integers(0, 2**31 - 1),
       p=st.floats(min_value=0.0, max_value=1.0))
def test_erdos_renyi_valid_adjacency(n, seed, p):
    g = topology.erdos_renyi(n, p, np.random.default_rng(seed))
    _assert_valid_adjacency(g)  # no connectivity claim to honour


@settings(max_examples=15, deadline=None)
@given(n=st.integers(min_value=2, max_value=40), seed=st.integers(0, 2**31 - 1))
def test_metropolis_hastings_xiao_boyd_conditions(n, seed):
    rng = np.random.default_rng(seed)
    graphs = [make(n) for _, make in _FAMILIES]
    graphs.append(topology.random_geometric(max(n, 8), rng))
    for g in graphs:
        w = weights.metropolis_hastings(g)
        np.testing.assert_allclose(w, w.T, atol=1e-12)            # symmetric
        np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-9)  # W 1 = 1
        np.testing.assert_allclose(w.sum(axis=0), 1.0, atol=1e-9)  # 1^T W = 1^T
        assert w.min() >= -1e-12                                   # nonneg
        j = weights.averaging_matrix(g.n)
        rho = float(np.max(np.abs(np.linalg.eigvalsh(w - j))))
        assert rho < 1.0 - 1e-12, (g.name, rho)                    # contraction


@settings(max_examples=10, deadline=None)
@given(n=st.integers(min_value=3, max_value=25), seed=st.integers(0, 2**31 - 1))
def test_lazy_map_fixes_negative_spectrum(n, seed):
    """(I + W)/2 guarantees |lambda_N| <= lambda_2 (Theorem 1's condition)."""
    g = topology.random_geometric(max(n, 8), np.random.default_rng(seed))
    w = weights.lazy(weights.metropolis_hastings(g))
    vals = np.sort(np.linalg.eigvalsh(w))
    assert vals[0] >= -1e-10              # all-positive spectrum
    assert abs(vals[0]) <= vals[-2] + 1e-10
