"""Multi-device integration tests, each in a subprocess with forced host
devices (XLA_FLAGS must precede jax init, so they cannot share this process).

Covers: consensus-vs-allreduce exactness at P=2, accel-vs-memoryless round
advantage (host prediction at P=8, asserted in-mesh on the P=4 ring fixture),
the in-mesh Algorithm-1 DOI, pipeline parallelism, int8-wire consensus, and
the sharding-rule unit logic (AbstractMesh, no devices needed). CI runs this
file with 4 forced host devices; each test pins its own count anyway.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 4, timeout: int = 420, x64: bool = False) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    if x64:
        env["JAX_ENABLE_X64"] = "1"
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=ROOT,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.slow
def test_consensus_p2_exactly_matches_allreduce():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import build
        from repro.dist import make_train_step, SyncConfig
        from repro import optim
        mesh = jax.make_mesh((2, 2, 1), ("pod", "data", "model"))
        cfg = get_config("yi-9b", smoke=True)
        model = build(cfg); opt = optim.adamw(1e-3)
        batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
                 "labels": jnp.ones((8, 16), jnp.int32)}
        ts_a = make_train_step(model, opt, mesh, SyncConfig(mode="allreduce"), 8, 16)
        pa, oa = ts_a.init_state(jax.random.PRNGKey(0), model, opt)
        p1, _, m1 = jax.jit(ts_a.fn)(pa, oa, batch)
        ts_g = make_train_step(model, opt, mesh, SyncConfig(mode="accel_gossip", eps=1e-3), 8, 16)
        pg, og = ts_g.init_state(jax.random.PRNGKey(0), model, opt)
        bg = jax.tree.map(lambda t: t.reshape(2, 4, *t.shape[1:]), batch)
        p2, _, m2 = jax.jit(ts_g.fn)(pg, og, bg)
        diff = max(float(jnp.abs(a - b[0]).max())
                   for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        # P=2 ring: lambda2=0 -> one accelerated round averages EXACTLY in
        # real arithmetic; the two programs partition differently (pinned
        # manual region vs pure GSPMD) so only fp reduction order differs
        assert diff < 5e-3, diff
        # the two pod replicas themselves must stay in exact consensus
        gap = max(float(jnp.abs(b[0] - b[1]).max()) for b in jax.tree.leaves(p2))
        assert gap == 0.0, gap
        print("OK exact-to-fp", diff)
    """)
    assert "OK exact-to-fp" in out


@pytest.mark.slow
def test_accel_gossip_round_advantage_p8():
    out = _run("""
        from repro.dist import make_fabric
        fab = make_fabric(8, "ring")
        r_mem = fab.rounds_for_memoryless(1e-3)
        r_acc = fab.rounds_for(1e-3)
        assert r_acc < r_mem / 1.8, (r_mem, r_acc)   # Theorem 2/3 speedup
        print("OK rounds", r_mem, r_acc)
    """, devices=1)
    assert "OK rounds" in out


@pytest.mark.slow
def test_accel_gossip_reaches_eps_in_fewer_rounds_p4_ring():
    """P=4 ring fixture: the *executed* in-mesh recursions hit the consensus
    epsilon at the round counts the fabric's rho_accel/rho_memoryless
    predict, and accelerated needs strictly fewer rounds."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist import make_fabric
        from repro.dist.gossip import accel_gossip, gossip
        mesh = jax.make_mesh((4,), ("pod",))
        fab = make_fabric(4, "ring")
        eps = 1e-3
        r_acc, r_mem = fab.rounds_for(eps), fab.rounds_for_memoryless(eps)
        assert r_acc < r_mem, (r_acc, r_mem)  # Theorem 2 prediction
        x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 128)), jnp.float32)
        target = x.mean(axis=0)
        denom = float(jnp.linalg.norm(x - target[None]))

        def rel_after(run, rounds):
            def body(b):
                return run(b[0], "pod", fab, rounds)[None]
            f = shard_map(body, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
                          check_rep=False)
            y = jax.jit(f)(x)
            return float(jnp.linalg.norm(y - target[None])) / denom

        def first_round_reaching(run):
            for r in range(1, r_mem + 3):
                if rel_after(run, r) <= eps:
                    return r
            return r_mem + 3

        hit_acc = first_round_reaching(accel_gossip)
        hit_mem = first_round_reaching(gossip)
        assert hit_acc < hit_mem, (hit_acc, hit_mem)
        # W is symmetric (normal), so rho^R bounds the memoryless error
        # exactly; Phi3[alpha*] is defective (critically damped — coalesced
        # eigenvalues), so the accelerated transient carries a polynomial
        # factor on top of rho_accel^R: allow one extra round over the
        # spectral prediction.
        assert hit_acc <= r_acc + 1, (hit_acc, r_acc)
        assert hit_mem <= r_mem, (hit_mem, r_mem)
        print("OK p4 rounds", hit_acc, hit_mem, r_acc, r_mem)
    """)
    assert "OK p4 rounds" in out


@pytest.mark.slow
def test_pairwise_gossip_p4_ring_matches_host_and_conserves_mean():
    """The registry's async_pairwise dist variant in-mesh: each round's woken
    pair averages over one two-element ppermute, every state stays equal to
    the host pairwise-matrix product, the pod mean is conserved exactly, and
    the algorithm_gossip registry dispatcher routes to the same program."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist import make_fabric
        from repro.dist.gossip import algorithm_gossip, pairwise_gossip
        mesh = jax.make_mesh((4,), ("pod",))
        fab = make_fabric(4, "ring")
        edges = [(i, j) for i in range(4) for j in range(i + 1, 4)
                 if fab.w[i, j] != 0.0]
        rng = np.random.default_rng(7)
        sched = rng.integers(0, len(edges), size=40)
        x = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)

        def runner(fn, rounds, **kw):
            def body(b):
                return fn(b[0], "pod", fab, rounds, schedule=sched[:rounds],
                          **kw)[None]
            return jax.jit(shard_map(body, mesh=mesh, in_specs=P("pod"),
                                     out_specs=P("pod"), check_rep=False))

        y = runner(pairwise_gossip, 40)(x)
        # host reference: apply the Boyd matrix of each scheduled edge
        ref = np.asarray(x, np.float64)
        for e in sched:
            i, j = edges[int(e)]
            avg = 0.5 * (ref[i] + ref[j])
            ref[i] = ref[j] = avg
        assert float(jnp.abs(y - ref).max()) < 1e-5
        # pod mean conserved exactly up to fp rounding
        assert float(jnp.abs(y.mean(0) - x.mean(0)).max()) < 1e-6
        # and it contracts toward consensus
        spread0 = float(jnp.abs(x - x.mean(0)).max())
        spread = float(jnp.abs(y - y.mean(0)).max())
        assert spread < 0.5 * spread0, (spread, spread0)
        # registry dispatch routes to the identical program
        y2 = runner(algorithm_gossip, 40, algorithm="async_pairwise")(x)
        assert float(jnp.abs(y - y2).max()) == 0.0
        print("OK pairwise", spread / spread0)
    """)
    assert "OK pairwise" in out


@pytest.mark.slow
def test_masked_gossip_degrades_gracefully_p4():
    """Per-round dropped-matching masks: the pod mean is conserved under any
    failure history (mass-preserving re-weighting), an all-ones mask equals
    the unmasked path bit-for-bit, an all-zeros mask freezes the state, and
    the in-mesh run matches the host masked-W reference."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist import make_fabric, edge_permutations
        from repro.dist.gossip import accel_gossip, gossip
        mesh = jax.make_mesh((4,), ("pod",))
        fab = make_fabric(4, "ring")
        perms = edge_permutations(fab.w)
        nm = len(perms)
        R = 12
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)

        def run(kind, mask):
            fn = accel_gossip if kind == "accel" else gossip
            def body(b):
                return fn(b[0], "pod", fab, R, drop_mask=mask)[None]
            f = shard_map(body, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
                          check_rep=False)
            return jax.jit(f)(x)

        mask = jnp.asarray((rng.random((R, nm)) >= 0.4), jnp.float32)
        for kind in ("accel", "mem"):
            y = run(kind, mask)
            # pod mean conserved under failures (up to fp roundoff)
            gap = float(jnp.abs(y.mean(0) - x.mean(0)).max())
            assert gap < 1e-5, (kind, gap)
            # ones-mask == unmasked recursion
            y1 = run(kind, jnp.ones((R, nm), jnp.float32))
            fn = accel_gossip if kind == "accel" else gossip
            def plain(b):
                return fn(b[0], "pod", fab, R)[None]
            y0 = jax.jit(shard_map(plain, mesh=mesh, in_specs=P("pod"),
                                   out_specs=P("pod"), check_rep=False))(x)
            d1 = float(jnp.abs(y1 - y0).max())
            assert d1 < 1e-6, (kind, d1)
        # all matchings down every round: W_eff = I, state frozen (up to the
        # f32 roundoff of re-accumulating (1/3 + 1/3 + 1/3) x per round)
        yz = run("mem", jnp.zeros((R, nm), jnp.float32))
        dz = float(jnp.abs(yz - x).max())
        assert dz < 1e-5, dz

        # host reference: apply the per-round masked (renormalized) W
        diag = np.diag(fab.w).copy()
        m_np = np.asarray(mask)
        xs = np.asarray(x, np.float64)
        for r in range(R):
            w_eff = np.diag(diag)
            for k, (perm, wvec) in enumerate(perms):
                for s, d in perm:
                    w_eff[d, s] += m_np[r, k] * wvec[d]
                    w_eff[d, d] += (1.0 - m_np[r, k]) * wvec[d]
            xs = w_eff @ xs
        y_mem = run("mem", mask)
        dref = float(np.abs(np.asarray(y_mem, np.float64) - xs).max())
        assert dref < 1e-5, dref
        print("OK masked gossip", gap, dref)
    """)
    assert "OK masked gossip" in out


@pytest.mark.slow
def test_adaptive_accel_gossip_p4():
    """In-mesh adaptive recursion: periodic Algorithm-1 re-solve composed
    with the accelerated rounds. Static fabric: the floored estimate pins
    alpha at the nominal alpha*, so the trajectory tracks plain accel_gossip
    to f32 noise and still reaches the mean; pod mean is conserved; the
    registry dispatcher routes to the identical program."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist import make_fabric
        from repro.dist.gossip import accel_gossip, adaptive_accel_gossip, algorithm_gossip
        mesh = jax.make_mesh((4,), ("pod",))
        fab = make_fabric(4, "chain")
        R = max(fab.rounds_for(1e-3), 8)

        def runner(fn, **kw):
            def body(b):
                return fn(b[0], "pod", fab, R, **kw)[None]
            return jax.jit(shard_map(body, mesh=mesh, in_specs=P("pod"),
                                     out_specs=P("pod"), check_rep=False))

        x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 64)), jnp.float32)
        y = runner(adaptive_accel_gossip, resolve_every=4, doi_iters=8)(x)
        target = x.mean(axis=0)
        rel = float(jnp.linalg.norm(y - target[None])
                    / jnp.linalg.norm(x - target[None]))
        assert rel < 2e-3, rel
        # pod mean conserved through estimator + re-solve composition
        assert float(jnp.abs(y.mean(0) - x.mean(0)).max()) < 1e-5
        # floored-at-nominal on a static fabric == plain accel up to the f32
        # in-mesh alpha* re-solve's last-ulp coefficient difference
        y0 = runner(accel_gossip)(x)
        assert float(jnp.abs(y - y0).max()) < 1e-4
        # registry dispatch routes to the identical program
        y2 = runner(algorithm_gossip, algorithm="accel_adapt",
                    resolve_every=4, doi_iters=8)(x)
        assert float(jnp.abs(y - y2).max()) == 0.0
        print("OK adaptive gossip", rel)
    """)
    assert "OK adaptive gossip" in out


@pytest.mark.slow
def test_inmesh_doi_matches_theory():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist import make_fabric, distributed_lambda2
        mesh = jax.make_mesh((4,), ("pod",))
        fab = make_fabric(4, "chain")
        def est(key):
            return distributed_lambda2("pod", 4, key, num_iters=40,
                                       topology_kind="chain",
                                       dtype=jnp.float64)[None]
        f = shard_map(est, mesh=mesh, in_specs=P(), out_specs=P("pod"),
                      check_rep=False)
        lam = float(jax.jit(f)(jax.random.PRNGKey(3))[0])
        assert abs(lam - fab.lambda2) < 1e-4, (lam, fab.lambda2)
        print("OK doi", lam)
    """, x64=True)
    assert "OK doi" in out


@pytest.mark.slow
def test_pipeline_matches_reference():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.pipeline import pipeline_forward, reference_forward
        mesh = jax.make_mesh((4,), ("stage",))
        rng = np.random.default_rng(0)
        w1 = jnp.asarray(rng.standard_normal((4, 2, 16, 32)), jnp.float32) * 0.1
        w2 = jnp.asarray(rng.standard_normal((4, 2, 32, 16)), jnp.float32) * 0.1
        x = jnp.asarray(rng.standard_normal((6, 3, 16)), jnp.float32)
        out = pipeline_forward(w1, w2, x, mesh)
        ref = reference_forward(w1, w2, x)
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-5, err
        print("OK pipeline", err)
    """)
    assert "OK pipeline" in out


@pytest.mark.slow
def test_int8_wire_consensus_still_converges():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist import make_fabric
        from repro.dist.gossip import accel_gossip
        from repro.dist.compression import Int8Wire
        mesh = jax.make_mesh((4,), ("pod",))
        fab = make_fabric(4, "ring")
        R = fab.rounds_for(1e-3)
        def body(x):
            x = x[0]
            out = accel_gossip(x, "pod", fab, R, wire=Int8Wire())
            return out[None]
        f = shard_map(body, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
                      check_rep=False)
        x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 64)), jnp.float32)
        y = jax.jit(f)(x)
        target = x.mean(axis=0)
        rel = float(jnp.linalg.norm(y - target[None]) / jnp.linalg.norm(x - target[None]))
        assert rel < 5e-2, rel   # int8 noise floors above eps but well-mixed
        print("OK wire", rel)
    """)
    assert "OK wire" in out


@pytest.mark.slow
def test_pallas_sweep_partitions_over_g_on_4_devices():
    """The tentpole seam: backend="pallas" under a forced 4-device mesh runs
    the batched round kernels through their custom_partitioning wrappers —
    the G axis shards over "data" (the partition callback must actually
    fire), dense and sparse layouts both match the jax backend to f32
    tolerances, and a dynamic grid with a sender-renorm partition (push_sum)
    exercises the masked + column-masked kernel variants under the mesh."""
    out = _run("""
        import numpy as np, jax
        assert jax.device_count() == 4, jax.device_count()
        from repro.kernels import ops
        from repro.sweep import SweepSpec, build_ensemble, run_ensemble
        from repro.sweep.engine import build_round_masks

        for layout in ("dense", "sparse"):
            spec = SweepSpec(
                topologies=("chain", "rgg"), sizes=(12, 20),
                designs=("asymptotic",), alphas=(1.0,), num_trials=3,
                seed=7, algorithms=("accel", "push_sum"),
                dynamics=("static", "bernoulli:0.2"), layout=layout,
            )
            ens = build_ensemble(spec)
            masks = build_round_masks(ens, 30, seed=7)
            with ops.cp_partition_calls() as fired_in_scope:
                r_p = run_ensemble(ens, num_iters=30, backend="pallas",
                                   round_masks=masks)
                fired = fired_in_scope()
            assert fired > 0, (layout, fired)  # GSPMD used our partition rule
            r_j = run_ensemble(ens, num_iters=30, backend="jax",
                               round_masks=masks)
            np.testing.assert_allclose(
                r_p.x_final, r_j.x_final, rtol=2e-4, atol=1e-5)
            np.testing.assert_allclose(
                r_p.mse, r_j.mse, rtol=2e-4, atol=1e-7)
            print("OK cp", layout, fired)
    """)
    assert "OK cp dense" in out and "OK cp sparse" in out


def test_sharding_rules_abstract_mesh():
    """Rule logic is device-free (AbstractMesh)."""
    out = _run("""
        from jax.sharding import AbstractMesh, PartitionSpec as P
        from repro.dist.sharding import partition_spec
        mesh = AbstractMesh((("data", 16), ("model", 16)))
        # TP beats cache_seq for 'model' when kv_heads divide
        s = partition_spec((32, 32768, 32, 128), ("batch", "cache_seq", "kv_heads", "head_dim"), mesh)
        assert s == P("data", None, "model"), s
        # kv_heads=4 can't: cache_seq gets 'model' (flash-decode style)
        s = partition_spec((32, 32768, 4, 128), ("batch", "cache_seq", "kv_heads", "head_dim"), mesh)
        assert s == P("data", "model"), s
        # non-divisible batch (8 % 16 != 0) replicates; cache_seq keeps 'model'
        s = partition_spec((8, 32768, 4, 128), ("batch", "cache_seq", "kv_heads", "head_dim"), mesh)
        assert s == P(None, "model"), s
        # embed FSDP + vocab TP
        s = partition_spec((51968, 512), ("vocab", "embed"), mesh)
        assert s == P("model", "data"), s
        # non-divisible dims are replicated, not unevenly sharded
        s = partition_spec((56,), ("heads",), mesh)
        assert s == P(), s
        multi = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
        s = partition_spec((256, 4096), ("batch", None), multi)
        assert s == P(("pod", "data")), s
        print("OK rules")
    """, devices=1)
    assert "OK rules" in out
