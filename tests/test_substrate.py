"""Substrate: data pipeline, optimizers, checkpointing, elastic runtime,
compression wire, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import optim
from repro.checkpoint import AsyncCheckpointer, latest_valid, restore, save
from repro.configs import get_config
from repro.data import SyntheticStream
from repro.dist.compression import BF16Wire, Int8Wire
from repro.models import build
from repro.runtime import ElasticFabric, FailureDetector
from repro.serve import DecodeEngine, Request


# ---------------------------------------------------------------------------
# Data.
# ---------------------------------------------------------------------------

def test_stream_deterministic_and_resumable():
    cfg = get_config("yi-9b", smoke=True)
    s = SyntheticStream(cfg, global_batch=8, seq_len=16, seed=3)
    b1 = s.batch_at(5)
    b2 = s.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])  # pure function of step
    assert not np.array_equal(s.batch_at(6)["tokens"], b1["tokens"])


def test_stream_host_sharding_disjoint():
    cfg = get_config("yi-9b", smoke=True)
    shards = [
        SyntheticStream(cfg, 8, 16, seed=3, shard=i, num_shards=4).batch_at(0)["tokens"]
        for i in range(4)
    ]
    assert all(s.shape == (2, 16) for s in shards)
    flat = np.stack([s.ravel() for s in shards])
    assert len({tuple(r) for r in flat}) == 4  # different streams per shard


def test_stream_labels_shift():
    cfg = get_config("yi-9b", smoke=True)
    b = SyntheticStream(cfg, 4, 32, seed=0, noise=0.0).batch_at(0)
    # noiseless: labels follow the affine rule from tokens
    nxt = (b["tokens"].astype(np.int64) * 7 + 3) % cfg.vocab_size
    np.testing.assert_array_equal(b["labels"], nxt)


# ---------------------------------------------------------------------------
# Optimizers.
# ---------------------------------------------------------------------------

def _quad_problem(opt, steps=60):
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros((3, 3)), "b": jnp.zeros(3)}

    def loss(p):
        return jnp.sum((p["w"].sum(0) + p["b"] - target) ** 2)

    state = opt.init(params)
    for _ in range(steps):
        grads = jax.grad(loss)(params)
        updates, state = opt.update(grads, state, params)
        params = jax.tree.map(jnp.add, params, updates)
    return float(loss(params))


def test_adamw_converges():
    assert _quad_problem(optim.adamw(0.1, weight_decay=0.0)) < 1e-2


def test_adafactor_converges():
    # normalized (sign-like) updates: lr must be below the target scale
    assert _quad_problem(optim.adafactor(0.1), steps=500) < 0.1


def test_adafactor_state_is_factored():
    opt = optim.adafactor(1e-2)
    params = {"w": jnp.zeros((64, 128))}
    st_ = opt.init(params)
    assert st_["v"]["w"]["vr"].shape == (64,)
    assert st_["v"]["w"]["vc"].shape == (128,)


def test_wsd_schedule_shape():
    s = optim.wsd_schedule(1.0, warmup=10, total=100)
    assert float(s(jnp.asarray(5))) == pytest.approx(0.5)      # warming
    assert float(s(jnp.asarray(50))) == pytest.approx(1.0)     # stable
    assert float(s(jnp.asarray(100))) < 0.02                   # decayed
    c = optim.cosine_schedule(1.0, warmup=10, total=100)
    assert float(c(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)


def test_grad_clipping():
    opt = optim.adamw(1.0, clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    huge = {"w": jnp.full(4, 1e9)}
    updates, _ = opt.update(huge, state, params)
    assert np.all(np.isfinite(np.asarray(updates["w"])))


# ---------------------------------------------------------------------------
# Checkpointing.
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    state = {"a": np.arange(10, dtype=np.float32), "n": {"b": np.eye(3)}}
    save(str(tmp_path), 7, state, extra={"cfg": "yi"})
    step, loaded, extra = restore(os.path.join(str(tmp_path), "step_00000007"))
    assert step == 7 and extra == {"cfg": "yi"}
    np.testing.assert_array_equal(loaded["a"], state["a"])
    np.testing.assert_array_equal(loaded["n"]["b"], state["n"]["b"])


def test_checkpoint_corruption_detected(tmp_path):
    state = {"a": np.arange(10, dtype=np.float32)}
    save(str(tmp_path), 1, state)
    p2 = save(str(tmp_path), 2, state)
    # corrupt the newest
    fname = [f for f in os.listdir(p2) if f.endswith(".npy")][0]
    with open(os.path.join(p2, fname), "r+b") as f:
        f.seek(60)
        f.write(b"\xff\xff\xff\xff")
    step, path = latest_valid(str(tmp_path))
    assert step == 1  # falls back past the corrupt checkpoint


def test_checkpoint_partial_write_ignored(tmp_path):
    state = {"a": np.zeros(4, np.float32)}
    save(str(tmp_path), 1, state)
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))  # crashed writer
    step, _ = latest_valid(str(tmp_path))
    assert step == 1


def test_async_checkpointer_retention(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in range(5):
        ck.submit(s, {"x": np.full(3, s, np.float32)})
        ck.close(flush=True) if s == 4 else None
    step, path = latest_valid(str(tmp_path))
    assert step == 4
    kept = [d for d in os.listdir(str(tmp_path)) if d.startswith("step_")]
    assert len(kept) <= 2


# ---------------------------------------------------------------------------
# Elastic runtime.
# ---------------------------------------------------------------------------

def test_elastic_resize_reoptimizes():
    ef = ElasticFabric(topology="ring")
    f8 = ef.bootstrap(list(range(8)))
    r8 = ef.rounds(eps=1e-2)
    f7 = ef.resize(remove=[3])
    assert ef.members == [0, 1, 2, 4, 5, 6, 7]
    assert f7.num_pods == 7
    assert f7.lambda2 < f8.lambda2  # smaller ring mixes faster
    assert ef.rounds(1e-2) <= r8
    # alpha* always re-solved for the new graph
    assert f7.alpha != f8.alpha


def test_failure_detector_classifies():
    fd = FailureDetector(dead_after_s=10.0, straggler_factor=2.0)
    now = 1000.0
    for pid, lat in [(0, 1.0), (1, 1.1), (2, 0.9), (3, 5.0)]:
        fd.heartbeat(pid, step_latency=lat, now=now)
        fd.heartbeat(pid, step_latency=lat, now=now)
    fd.heartbeat(4, step_latency=1.0, now=now - 50)  # stale
    cls = fd.classify(now=now)
    assert cls[3] == "straggler" and cls[4] == "dead"
    assert cls[0] == "healthy"


def test_elastic_react_to_dead_pod():
    ef = ElasticFabric(topology="ring")
    ef.bootstrap(list(range(4)))
    new_fab = ef.react({0: "healthy", 1: "dead", 2: "healthy", 3: "straggler"})
    assert new_fab is not None and new_fab.num_pods == 3
    assert ef.react({0: "healthy"}) is None  # no change -> no resize


# ---------------------------------------------------------------------------
# Compression wire.
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
def test_int8_wire_error_bounded(seed, scale):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal(256) * scale, jnp.float32)
    wire = Int8Wire()
    err = jnp.zeros_like(x)
    payload, err = wire.encode_decode(x, err)
    # quantization error bounded by half a step
    step = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.abs(payload - x).max()) <= step * 0.51 + 1e-9


def test_int8_error_feedback_unbiased():
    """Accumulated transmitted signal tracks the true signal over rounds."""
    r = np.random.default_rng(0)
    wire = Int8Wire()
    x = jnp.asarray(r.standard_normal(64), jnp.float32)
    err = jnp.zeros_like(x)
    sent = jnp.zeros_like(x)
    for _ in range(30):
        p, err = wire.encode_decode(x, err)
        sent = sent + p
    np.testing.assert_allclose(sent / 30, x, rtol=0.02, atol=0.02)


# ---------------------------------------------------------------------------
# Serving engine.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["minicpm-2b", "mamba2-780m"])
def test_engine_continuous_batching(arch):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = DecodeEngine(model, params, max_batch=3, max_seq=64)
    r = np.random.default_rng(0)
    reqs = [
        Request(i, r.integers(0, cfg.vocab_size, size=(4 + 3 * i,)).astype(np.int32),
                max_new_tokens=5)
        for i in range(6)
    ]
    for q in reqs:
        eng.submit(q)
    done = eng.run()
    assert len(done) == 6
    assert all(len(q.out_tokens) == 5 for q in done)


def test_engine_greedy_matches_sequential():
    """Batched continuous decode ~= one-at-a-time decode (greedy).

    Rows are mathematically independent, but XLA CPU vectorizes B=3 vs B=1
    matmuls differently; near-ties at random init can flip argmax. Require
    strong (not bitwise) agreement.
    """
    cfg = get_config("minicpm-2b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    r = np.random.default_rng(1)
    prompts = [r.integers(0, cfg.vocab_size, size=(5,)).astype(np.int32) for _ in range(3)]

    def run(max_batch):
        eng = DecodeEngine(model, params, max_batch=max_batch, max_seq=32)
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, max_new_tokens=4))
        return {q.rid: q.out_tokens for q in eng.run()}

    a, b = run(max_batch=3), run(max_batch=1)
    a2 = run(max_batch=3)
    assert a == a2  # engine is deterministic for a fixed slot layout
    # prefill runs at B=1 in both configs -> the first generated token of
    # every request must match exactly. Later tokens legitimately diverge at
    # random init: near-uniform logits + different XLA vectorization at
    # B=3 vs B=1 flip argmax ties, and greedy decoding then chains apart.
    assert all(a[rid][0] == b[rid][0] for rid in a), (a, b)
