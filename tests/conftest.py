"""Shared fixtures. NOTE: no XLA_FLAGS device forcing here — smoke tests and
benches must see the real (single) device; only launch/dryrun.py forces 512
host devices, and multi-device tests spawn subprocesses with their own flags.

Also provides a deterministic fallback for ``hypothesis`` (see the ``test``
extra in pyproject.toml): hermetic images that bake only the runtime deps can
still collect and run the property-based tests. The fallback implements the
tiny slice of the API these tests use — ``given`` with keyword strategies,
``settings(max_examples=..., deadline=...)``, ``st.integers``/``st.floats`` —
by sampling a fixed number of examples from a CRC-seeded generator, so runs
are reproducible across processes (``hash()`` is salted; crc32 is not).
"""
import sys
import types
import zlib

import numpy as np
import pytest

try:  # pragma: no cover - exercised only when hypothesis is absent
    import hypothesis  # noqa: F401
except ModuleNotFoundError:  # pragma: no cover
    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _floats(min_value, max_value, **_kw):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats

    _DEFAULT_EXAMPLES = 10

    def _given(**strategies):
        def deco(fn):
            def runner():
                # settings() may sit outside given() (sets the attr on this
                # runner) or inside it (sets it on the wrapped fn) — both are
                # valid hypothesis orderings.
                n = getattr(
                    runner, "_hyp_max_examples",
                    getattr(fn, "_hyp_max_examples", _DEFAULT_EXAMPLES),
                )
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode("utf-8"))
                )
                for _ in range(n):
                    fn(**{k: s.sample(rng) for k, s in strategies.items()})

            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner

        return deco

    def _settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._hyp_max_examples = max_examples
            return fn

        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture
def rng():
    return np.random.default_rng(0)
