"""Shared fixtures. NOTE: no XLA_FLAGS device forcing here — smoke tests and
benches must see the real (single) device; only launch/dryrun.py forces 512
host devices, and multi-device tests spawn subprocesses with their own flags.
"""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
