"""Autotuner contract tests: mode semantics, cache determinism, and the
bit-identicality guarantee that candidate tiles only repartition the output
grid (bm/bf) while the contraction tiles (bk/bd) stay pinned — so every
candidate computes the exact same floats.
"""
import json

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import autotune, ops


@pytest.fixture(autouse=True)
def _clean_cache(tmp_path, monkeypatch):
    """Every test gets an empty private JSON cache + empty memory cache."""
    monkeypatch.setenv("REPRO_KERNEL_TUNE_CACHE", str(tmp_path / "tune.json"))
    autotune.clear_memory_cache()
    yield
    autotune.clear_memory_cache()


def test_mode_off_reproduces_static_tiles(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_TUNE", "off")
    calls = []
    assert autotune.get_tiles("round", 200, 300, bench=calls.append) \
        == autotune.static_round_tiles(300)
    assert autotune.get_tiles("segment", 200, 300, bench=calls.append) \
        == autotune.static_segment_tiles(300)
    assert calls == []  # off never measures


def test_mode_cache_miss_degrades_to_static_without_timing(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_TUNE", "cache")
    calls = []
    assert autotune.get_tiles("round", 128, 128, bench=calls.append) \
        == autotune.static_round_tiles(128)
    assert calls == []  # cache mode never invokes the bench closure


def test_mode_full_times_each_candidate_once_then_hits_cache(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_TUNE", "full")
    cands = autotune.round_candidates(256, 256)
    calls = []

    def bench(tiles):
        calls.append(tuple(tiles))

    # deterministic fake timer: make candidate (256, 128, 128) the winner
    def fake_time(bench_fn, tiles, reps=3):
        bench_fn(tiles)
        return 0.1 if tuple(tiles) == (256, 128, 128) else 1.0

    monkeypatch.setattr(autotune, "time_candidate", fake_time)
    won = autotune.get_tiles("round", 256, 256, bench=bench)
    assert won == (256, 128, 128)
    assert sorted(set(calls)) == sorted(cands)  # every candidate timed once

    # second call: in-process cache hit, no timing at all
    calls.clear()
    assert autotune.get_tiles("round", 256, 256, bench=bench) == won
    assert calls == []

    # drop the memory cache: the JSON cache must serve the same winner,
    # and even plain `cache` mode must now return it
    autotune.clear_memory_cache()
    monkeypatch.setenv("REPRO_KERNEL_TUNE", "cache")
    assert autotune.get_tiles("round", 256, 256, bench=bench) == won
    assert calls == []

    # the file itself is namespaced by device kind
    data = json.loads(autotune.cache_path().read_text())
    assert autotune.device_key() in data


def test_invalid_mode_raises(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_TUNE", "sometimes")
    with pytest.raises(ValueError, match="REPRO_KERNEL_TUNE"):
        autotune.get_tiles("round", 128, 128)


def test_corrupt_cache_file_is_ignored(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_TUNE", "cache")
    autotune.cache_path().write_text("{not json")
    assert autotune.get_tiles("round", 128, 128) \
        == autotune.static_round_tiles(128)


def test_candidates_pin_contraction_tiles():
    for bm, bk, bf in autotune.round_candidates(512, 512):
        assert bk == 128
    for bm, bd, bf in autotune.segment_candidates(512, 512):
        assert bd == 8


@settings(max_examples=6, deadline=None)
@given(n=st.integers(1, 3), f=st.integers(1, 3), seed=st.integers(0, 999))
def test_round_outputs_bit_identical_across_candidate_tiles(n, f, seed):
    """The autotuner's core guarantee: any candidate (bm, bf) computes the
    exact same bits as any other, dense and ELL alike, because only the
    output-parallel grid varies. Each candidate pads to its own tiles
    exactly as the sweep engine does, then the unpadded block is compared.
    """
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    nn, ff = 100 * n, 100 * f          # deliberately not tile multiples
    g, interp = 2, ops.use_interpret()
    w = rng.standard_normal((nn, nn)).astype(np.float32) * 0.1
    ws0 = np.stack([w] * g)
    xs0 = rng.standard_normal((g, nn, ff)).astype(np.float32)
    xps0 = rng.standard_normal((g, nn, ff)).astype(np.float32)
    cfs = jnp.asarray(np.tile([1.1, 0.2, -0.3], (g, 1)), jnp.float32)

    outs = []
    for bm, bk, bf in autotune.round_candidates(nn, ff):
        n_pad = ops._round_up(nn, max(bm, bk)) - nn
        f_pad = ops._round_up(ff, bf) - ff
        y = ops.gossip_round_batched_pallas(
            jnp.asarray(np.pad(ws0, ((0, 0), (0, n_pad), (0, n_pad)))),
            jnp.asarray(np.pad(xs0, ((0, 0), (0, n_pad), (0, f_pad)))),
            jnp.asarray(np.pad(xps0, ((0, 0), (0, n_pad), (0, f_pad)))),
            cfs, bm=bm, bk=bk, bf=bf, interpret=interp)
        outs.append(np.asarray(y)[:, :nn, :ff])
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])


def test_segment_outputs_bit_identical_across_candidate_tiles():
    import jax.numpy as jnp

    from repro.core import topology, weights

    rng = np.random.default_rng(11)
    gph = topology.random_geometric_sparse(150, rng)
    e_w, d_w = weights.metropolis_hastings_edges(gph)
    nn, ff, interp = gph.n, 96, ops.use_interpret()
    x = jnp.asarray(rng.standard_normal((nn, ff)), jnp.float32)
    xp = jnp.asarray(rng.standard_normal((nn, ff)), jnp.float32)

    outs = []
    for bm, bd, bf in autotune.segment_candidates(nn, ff):
        n_pad = ops._round_up(nn, bm) - nn
        nbr, wgt, wrev, slot, diag = ops.build_ell(
            gph.edges, e_w, np.pad(d_w, (0, n_pad)), nn + n_pad)
        d_pad = ops._round_up(nbr.shape[1], bd) - nbr.shape[1]
        nbr, wgt = (np.pad(a, ((0, 0), (0, d_pad))) for a in (nbr, wgt))
        f_pad = ops._round_up(ff, bf) - ff
        from repro.kernels.segment_round import segment_round_pallas
        y = segment_round_pallas(
            jnp.asarray(nbr), jnp.asarray(wgt, jnp.float32),
            jnp.asarray(diag, jnp.float32),
            jnp.pad(x, ((0, n_pad), (0, f_pad))),
            jnp.pad(xp, ((0, n_pad), (0, f_pad))),
            jnp.asarray([[1.1, 0.2, -0.3]], jnp.float32),
            bm=bm, bd=bd, bf=bf, interpret=interp)
        outs.append(np.asarray(y)[:nn, :ff])
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])


def test_ops_tiles_entry_points_respect_off_mode(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_TUNE", "off")
    assert ops.round_tiles(200, 300) == ops._round_tiles(300)
    assert ops.segment_tiles(200, 300) == ops._segment_tiles(300)


def test_require_compiled_raises_on_interpret_backend(monkeypatch):
    import jax

    if jax.default_backend() == "tpu":  # pragma: no cover - CPU CI
        pytest.skip("compiled backend available: nothing to refuse")
    monkeypatch.setenv("REPRO_REQUIRE_COMPILED", "1")
    with pytest.raises(RuntimeError, match="REPRO_REQUIRE_COMPILED"):
        ops.use_interpret()
