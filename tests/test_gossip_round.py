"""Fused gossip-round kernel: backend equivalence on paper-realistic draws.

The contract (ISSUE acceptance): numpy float64 reference, jnp oracle
(``ref.gossip_round_ref``) and the Pallas kernel (interpret mode on CPU)
agree to 1e-5 on random (W, alpha, theta) draws, for both the single-graph
and the batched-grid variants.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import accel, topology, weights
from repro.kernels import ops, ref


def _draw_config(rng, n):
    """(W, theta, alpha*) from a connected Erdos-Renyi draw, lazy-fixed."""
    p = min(1.0, 2.5 * np.log(max(n, 2)) / n)
    for _ in range(100):
        g = topology.erdos_renyi(n, p, rng)
        if topology.is_connected(g.adjacency):
            break
    else:
        raise RuntimeError("no connected draw")
    w = weights.lazy(weights.metropolis_hastings(g))
    th = accel.theta_asymptotic(float(rng.uniform(0.1, 1.5)))
    lam2 = accel.lambda2(w)
    a = accel.alpha_star(lam2, th) if lam2 > 1e-9 else 0.0
    return w, th, a


def _coef(alpha, th):
    return (1.0 - alpha + alpha * th.t3, alpha * th.t2, alpha * th.t1)


@pytest.mark.parametrize("n,f", [(8, 1), (31, 7), (60, 40), (128, 300), (150, 513)])
def test_fused_round_matches_numpy_reference(n, f, rng):
    w, th, alpha = _draw_config(rng, n)
    x = rng.standard_normal((n, f))
    xp = rng.standard_normal((n, f))
    a, b, c = _coef(alpha, th)

    y_np = a * (w @ x) + b * x + c * xp                      # float64 reference
    y_ref = ref.gossip_round_ref(
        jnp.asarray(w, jnp.float32), jnp.asarray(x, jnp.float32),
        jnp.asarray(xp, jnp.float32), a, b, c,
    )
    y_ker = ops.gossip_round(
        jnp.asarray(w, jnp.float32), jnp.asarray(x, jnp.float32),
        jnp.asarray(xp, jnp.float32), a, b, c,
    )
    np.testing.assert_allclose(np.asarray(y_ker), y_np, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_fused_round_equals_unfused_pair(rng):
    """Fusion is a pure perf change: same math as matvec + consensus_update."""
    n, f = 70, 33
    w, th, alpha = _draw_config(rng, n)
    a, b, c = _coef(alpha, th)
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    xp = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    fused = ops.gossip_round(jnp.asarray(w, jnp.float32), x, xp, a, b, c)
    pair = ops.consensus_update(
        ops.gossip_matvec(jnp.asarray(w, jnp.float32), x), x, xp, a, b, c
    )
    np.testing.assert_allclose(np.asarray(fused), np.asarray(pair),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("g,n,f", [(1, 16, 3), (4, 40, 5), (7, 33, 130)])
def test_batched_round_matches_per_graph(g, n, f, rng):
    """The batched-grid kernel row-for-row equals G single-graph calls."""
    ws, coefs = [], []
    for _ in range(g):
        w, th, alpha = _draw_config(rng, n)
        ws.append(w)
        coefs.append(_coef(alpha, th))
    ws = jnp.asarray(np.stack(ws), jnp.float32)
    coefs = jnp.asarray(np.asarray(coefs), jnp.float32)
    xs = jnp.asarray(rng.standard_normal((g, n, f)), jnp.float32)
    xps = jnp.asarray(rng.standard_normal((g, n, f)), jnp.float32)

    y = ops.gossip_round_batched(ws, xs, xps, coefs)
    y_ref = ref.gossip_round_batched_ref(ws, xs, xps, coefs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    for i in range(g):
        yi = ops.gossip_round(ws[i], xs[i], xps[i], *[coefs[i, k] for k in range(3)])
        np.testing.assert_allclose(np.asarray(y[i]), np.asarray(yi),
                                   rtol=1e-5, atol=1e-5)


def test_batched_round_heterogeneous_coefficients(rng):
    """Each graph must read ITS coefficient row (regression for grid mixups)."""
    g, n, f = 3, 12, 2
    w = np.eye(n)  # identity W isolates the coefficient path: y = (a+b)x + c xp
    ws = jnp.asarray(np.stack([w] * g), jnp.float32)
    coefs = jnp.asarray([[1.0, 0.0, 0.0], [0.5, 0.25, 0.25], [2.0, -1.0, 0.5]],
                        jnp.float32)
    xs = jnp.asarray(rng.standard_normal((g, n, f)), jnp.float32)
    xps = jnp.asarray(rng.standard_normal((g, n, f)), jnp.float32)
    y = ops.gossip_round_batched(ws, xs, xps, coefs)
    for i in range(3):
        a, b, c = (float(coefs[i, k]) for k in range(3))
        np.testing.assert_allclose(
            np.asarray(y[i]), (a + b) * np.asarray(xs[i]) + c * np.asarray(xps[i]),
            rtol=1e-5, atol=1e-6,
        )


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(2, 80), f=st.integers(1, 20),
    a=st.floats(-2, 2), b=st.floats(-2, 2), c=st.floats(-2, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_round_property(n, f, a, b, c, seed):
    r = np.random.default_rng(seed)
    w = jnp.asarray(r.standard_normal((n, n)), jnp.float32)
    x = jnp.asarray(r.standard_normal((n, f)), jnp.float32)
    xp = jnp.asarray(r.standard_normal((n, f)), jnp.float32)
    y = ops.gossip_round(w, x, xp, a, b, c)
    yr = ref.gossip_round_ref(w, x, xp, a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-4)
