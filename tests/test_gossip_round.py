"""Fused gossip-round kernel: backend equivalence on paper-realistic draws.

The contract (ISSUE acceptance): numpy float64 reference, jnp oracle
(``ref.gossip_round_ref``) and the Pallas kernel (interpret mode on CPU)
agree to 1e-5 on random (W, alpha, theta) draws, for both the single-graph
and the batched-grid variants.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import accel, topology, weights
from repro.kernels import ops, ref


def _draw_config(rng, n):
    """(W, theta, alpha*) from a connected Erdos-Renyi draw, lazy-fixed."""
    p = min(1.0, 2.5 * np.log(max(n, 2)) / n)
    for _ in range(100):
        g = topology.erdos_renyi(n, p, rng)
        if topology.is_connected(g.adjacency):
            break
    else:
        raise RuntimeError("no connected draw")
    w = weights.lazy(weights.metropolis_hastings(g))
    th = accel.theta_asymptotic(float(rng.uniform(0.1, 1.5)))
    lam2 = accel.lambda2(w)
    a = accel.alpha_star(lam2, th) if lam2 > 1e-9 else 0.0
    return w, th, a


def _coef(alpha, th):
    return (1.0 - alpha + alpha * th.t3, alpha * th.t2, alpha * th.t1)


@pytest.mark.parametrize("n,f", [(8, 1), (31, 7), (60, 40), (128, 300), (150, 513)])
def test_fused_round_matches_numpy_reference(n, f, rng):
    w, th, alpha = _draw_config(rng, n)
    x = rng.standard_normal((n, f))
    xp = rng.standard_normal((n, f))
    a, b, c = _coef(alpha, th)

    y_np = a * (w @ x) + b * x + c * xp                      # float64 reference
    y_ref = ref.gossip_round_ref(
        jnp.asarray(w, jnp.float32), jnp.asarray(x, jnp.float32),
        jnp.asarray(xp, jnp.float32), a, b, c,
    )
    y_ker = ops.gossip_round(
        jnp.asarray(w, jnp.float32), jnp.asarray(x, jnp.float32),
        jnp.asarray(xp, jnp.float32), a, b, c,
    )
    np.testing.assert_allclose(np.asarray(y_ker), y_np, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_fused_round_equals_unfused_pair(rng):
    """Fusion is a pure perf change: same math as matvec + consensus_update."""
    n, f = 70, 33
    w, th, alpha = _draw_config(rng, n)
    a, b, c = _coef(alpha, th)
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    xp = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    fused = ops.gossip_round(jnp.asarray(w, jnp.float32), x, xp, a, b, c)
    pair = ops.consensus_update(
        ops.gossip_matvec(jnp.asarray(w, jnp.float32), x), x, xp, a, b, c
    )
    np.testing.assert_allclose(np.asarray(fused), np.asarray(pair),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("g,n,f", [(1, 16, 3), (4, 40, 5), (7, 33, 130)])
def test_batched_round_matches_per_graph(g, n, f, rng):
    """The batched-grid kernel row-for-row equals G single-graph calls."""
    ws, coefs = [], []
    for _ in range(g):
        w, th, alpha = _draw_config(rng, n)
        ws.append(w)
        coefs.append(_coef(alpha, th))
    ws = jnp.asarray(np.stack(ws), jnp.float32)
    coefs = jnp.asarray(np.asarray(coefs), jnp.float32)
    xs = jnp.asarray(rng.standard_normal((g, n, f)), jnp.float32)
    xps = jnp.asarray(rng.standard_normal((g, n, f)), jnp.float32)

    y = ops.gossip_round_batched(ws, xs, xps, coefs)
    y_ref = ref.gossip_round_batched_ref(ws, xs, xps, coefs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    for i in range(g):
        yi = ops.gossip_round(ws[i], xs[i], xps[i], *[coefs[i, k] for k in range(3)])
        np.testing.assert_allclose(np.asarray(y[i]), np.asarray(yi),
                                   rtol=1e-5, atol=1e-5)


def test_batched_round_heterogeneous_coefficients(rng):
    """Each graph must read ITS coefficient row (regression for grid mixups)."""
    g, n, f = 3, 12, 2
    w = np.eye(n)  # identity W isolates the coefficient path: y = (a+b)x + c xp
    ws = jnp.asarray(np.stack([w] * g), jnp.float32)
    coefs = jnp.asarray([[1.0, 0.0, 0.0], [0.5, 0.25, 0.25], [2.0, -1.0, 0.5]],
                        jnp.float32)
    xs = jnp.asarray(rng.standard_normal((g, n, f)), jnp.float32)
    xps = jnp.asarray(rng.standard_normal((g, n, f)), jnp.float32)
    y = ops.gossip_round_batched(ws, xs, xps, coefs)
    for i in range(3):
        a, b, c = (float(coefs[i, k]) for k in range(3))
        np.testing.assert_allclose(
            np.asarray(y[i]), (a + b) * np.asarray(xs[i]) + c * np.asarray(xps[i]),
            rtol=1e-5, atol=1e-6,
        )


# ---------------------------------------------------------------------------
# Masked (time-varying topology) variants.
# ---------------------------------------------------------------------------

def _draw_mask(rng, n, p=0.3):
    """Symmetric 0/1 activity mask with ones on the diagonal."""
    u = np.triu(rng.random((n, n)) >= p, 1).astype(np.float64)
    return u + u.T + np.eye(n)


@pytest.mark.parametrize("n,f", [(8, 1), (31, 7), (60, 40), (150, 513)])
def test_masked_round_matches_masked_w_reference(n, f, rng):
    """Kernel == dense re-normalized W_eff matmul (the dynamics contract)."""
    from repro.core import dynamics as dyn

    w, th, alpha = _draw_config(rng, n)
    m = _draw_mask(rng, n)
    x = rng.standard_normal((n, f))
    xp = rng.standard_normal((n, f))
    a, b, c = _coef(alpha, th)

    idx = dyn.edge_index(w)
    bits = m[idx[:, 0], idx[:, 1]].astype(np.uint8)
    weff = dyn.masked_w(w, bits, idx)                        # float64 reference
    y_np = a * (weff @ x) + b * x + c * xp

    args32 = [jnp.asarray(v, jnp.float32) for v in (w, m, x, xp)]
    y_ker = ops.gossip_round_masked(*args32, a, b, c)
    y_ref = ref.gossip_round_masked_ref(*args32, a, b, c)
    np.testing.assert_allclose(np.asarray(y_ker), y_np, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_masked_round_all_ones_mask_is_unmasked(rng):
    n, f = 40, 5
    w, th, alpha = _draw_config(rng, n)
    a, b, c = _coef(alpha, th)
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    xp = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    w32 = jnp.asarray(w, jnp.float32)
    y_m = ops.gossip_round_masked(w32, jnp.ones((n, n), jnp.float32), x, xp, a, b, c)
    y = ops.gossip_round(w32, x, xp, a, b, c)
    np.testing.assert_allclose(np.asarray(y_m), np.asarray(y), rtol=1e-6, atol=1e-6)


def test_masked_round_all_zeros_mask_freezes_state(rng):
    """Every edge down => W_eff = I: the matvec term collapses to X."""
    n, f = 12, 3
    w, th, alpha = _draw_config(rng, n)
    a, b, c = _coef(alpha, th)
    x = rng.standard_normal((n, f))
    xp = rng.standard_normal((n, f))
    m = np.eye(n)
    y = ops.gossip_round_masked(
        jnp.asarray(w, jnp.float32), jnp.asarray(m, jnp.float32),
        jnp.asarray(x, jnp.float32), jnp.asarray(xp, jnp.float32), a, b, c)
    np.testing.assert_allclose(np.asarray(y), (a + b) * x + c * xp,
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("g,n,f", [(1, 16, 3), (4, 40, 5), (7, 33, 130)])
def test_masked_batched_matches_per_graph(g, n, f, rng):
    """The masked batched kernel row-for-row equals G masked single calls."""
    ws, ms, coefs = [], [], []
    for _ in range(g):
        w, th, alpha = _draw_config(rng, n)
        ws.append(w)
        ms.append(_draw_mask(rng, n))
        coefs.append(_coef(alpha, th))
    ws = jnp.asarray(np.stack(ws), jnp.float32)
    ms = jnp.asarray(np.stack(ms), jnp.float32)
    coefs = jnp.asarray(np.asarray(coefs), jnp.float32)
    xs = jnp.asarray(rng.standard_normal((g, n, f)), jnp.float32)
    xps = jnp.asarray(rng.standard_normal((g, n, f)), jnp.float32)

    y = ops.gossip_round_masked_batched(ws, ms, xs, xps, coefs)
    y_ref = ref.gossip_round_masked_batched_ref(ws, ms, xs, xps, coefs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    for i in range(g):
        yi = ops.gossip_round_masked(
            ws[i], ms[i], xs[i], xps[i], *[coefs[i, k] for k in range(3)])
        np.testing.assert_allclose(np.asarray(y[i]), np.asarray(yi),
                                   rtol=1e-5, atol=1e-5)


def test_masked_batched_heterogeneous_masks(rng):
    """Each graph must read ITS mask slice (regression for grid mixups)."""
    g, n, f = 3, 10, 2
    w = weights.lazy(weights.metropolis_hastings(topology.complete(n)))
    ws = jnp.asarray(np.stack([w] * g), jnp.float32)
    coefs = jnp.asarray([[1.0, 0.0, 0.0]] * g, jnp.float32)
    masks = np.stack([np.eye(n),
                      np.ones((n, n)),
                      _draw_mask(np.random.default_rng(4), n)])
    xs = jnp.asarray(rng.standard_normal((g, n, f)), jnp.float32)
    xps = jnp.asarray(rng.standard_normal((g, n, f)), jnp.float32)
    y = ops.gossip_round_masked_batched(
        ws, jnp.asarray(masks, jnp.float32), xs, xps, coefs)
    # cell 0: frozen; cell 1: plain W @ x
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(xs[0]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(y[1]), w @ np.asarray(xs[1]),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(2, 80), f=st.integers(1, 20),
    a=st.floats(-2, 2), b=st.floats(-2, 2), c=st.floats(-2, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_round_property(n, f, a, b, c, seed):
    """Kernel vs oracle on arbitrary dense W and arbitrary 0/1 masks."""
    r = np.random.default_rng(seed)
    w = jnp.asarray(r.standard_normal((n, n)), jnp.float32)
    m = jnp.asarray(_draw_mask(r, n, p=0.5), jnp.float32)
    x = jnp.asarray(r.standard_normal((n, f)), jnp.float32)
    xp = jnp.asarray(r.standard_normal((n, f)), jnp.float32)
    y = ops.gossip_round_masked(w, m, x, xp, a, b, c)
    yr = ref.gossip_round_masked_ref(w, m, x, xp, a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(2, 80), f=st.integers(1, 20),
    a=st.floats(-2, 2), b=st.floats(-2, 2), c=st.floats(-2, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_round_property(n, f, a, b, c, seed):
    r = np.random.default_rng(seed)
    w = jnp.asarray(r.standard_normal((n, n)), jnp.float32)
    x = jnp.asarray(r.standard_normal((n, f)), jnp.float32)
    xp = jnp.asarray(r.standard_normal((n, f)), jnp.float32)
    y = ops.gossip_round(w, x, xp, a, b, c)
    yr = ref.gossip_round_ref(w, x, xp, a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-4)


def _column_stochastic_w(rng, n, p=0.35):
    """Column-stochastic push-sum W on a random symmetric support."""
    sup = (rng.random((n, n)) < p)
    sup = sup | sup.T
    np.fill_diagonal(sup, True)
    w = sup * rng.uniform(0.1, 1.0, (n, n))
    return (w / w.sum(axis=0, keepdims=True)).astype(np.float64)


def test_sender_masked_batched_matches_column_renorm_reference(rng):
    """Column-masked fused round: dropped edge mass returns to the SENDER's
    diagonal, so W_eff = W.*M + diag(colsum(W.*(1-M))) stays column
    stochastic under any symmetric mask — the push-sum family's invariant.
    """
    g, n, f = 3, 128, 128
    ws = np.stack([_column_stochastic_w(rng, n) for _ in range(g)])
    bits = (rng.random((g, n, n)) < 0.7)
    ms = np.zeros((g, n, n))
    for i in range(g):
        m = np.triu(bits[i], 1)
        ms[i] = m + m.T
        np.fill_diagonal(ms[i], 1.0)
    xs = rng.standard_normal((g, n, f))
    xps = rng.standard_normal((g, n, f))
    coefs = np.stack([[1.1, 0.2, -0.3]] * g)

    y = ops.gossip_round_sender_masked_batched_pallas(
        jnp.asarray(ws, jnp.float32), jnp.asarray(ms, jnp.float32),
        jnp.asarray(xs, jnp.float32), jnp.asarray(xps, jnp.float32),
        jnp.asarray(coefs, jnp.float32),
        bm=128, bk=128, bf=128, interpret=ops.use_interpret())

    for i in range(g):
        wm = ws[i] * ms[i]
        weff = wm + np.diag((ws[i] - wm).sum(axis=0))
        np.testing.assert_allclose(weff.sum(axis=0), 1.0, atol=1e-12)
        y_ref = 1.1 * (weff @ xs[i]) + 0.2 * xs[i] - 0.3 * xps[i]
        np.testing.assert_allclose(
            np.asarray(y[i]), y_ref, rtol=1e-4, atol=1e-4)


def test_sender_masked_all_ones_mask_equals_plain_round(rng):
    g, n, f = 2, 128, 128
    ws = np.stack([_column_stochastic_w(rng, n) for _ in range(g)])
    xs = jnp.asarray(rng.standard_normal((g, n, f)), jnp.float32)
    xps = jnp.asarray(rng.standard_normal((g, n, f)), jnp.float32)
    coefs = jnp.asarray(np.stack([[0.9, 0.3, -0.2]] * g), jnp.float32)
    wsj = jnp.asarray(ws, jnp.float32)
    interp = ops.use_interpret()
    y = ops.gossip_round_sender_masked_batched_pallas(
        wsj, jnp.ones((g, n, n), jnp.float32), xs, xps, coefs,
        bm=128, bk=128, bf=128, interpret=interp)
    y0 = ops.gossip_round_batched_pallas(
        wsj, xs, xps, coefs, bm=128, bk=128, bf=128, interpret=interp)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(y0), rtol=1e-6, atol=1e-6)


def test_sender_masked_requires_square_tiles(rng):
    g, n, f = 1, 128, 128
    z = jnp.zeros((g, n, f), jnp.float32)
    w = jnp.zeros((g, n, n), jnp.float32)
    c = jnp.zeros((g, 3), jnp.float32)
    with pytest.raises(ValueError, match="square"):
        ops.gossip_round_sender_masked_batched_pallas(
            w, w, z, z, c, bm=128, bk=64, bf=128, interpret=True)
