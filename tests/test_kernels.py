"""Per-kernel correctness: shape/dtype sweeps against the pure-jnp oracles
(ref.py), plus autodiff checks for the custom-vjp SSD scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# consensus_update (fused two-tap FMA).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(7,), (1024,), (257, 33), (4, 5, 6), (2, 3, 4, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_consensus_update_sweep(shape, dtype, rng):
    xw, x, xp = (jnp.asarray(rng.standard_normal(shape), dtype) for _ in range(3))
    y = ops.consensus_update(xw, x, xp, 1.3, 0.2, -0.5)
    yr = ref.consensus_update_ref(xw, x, xp, 1.3, 0.2, -0.5)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), rtol=tol, atol=tol
    )


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 3000),
    a=st.floats(-2, 2), b=st.floats(-2, 2), c=st.floats(-2, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_consensus_update_property(n, a, b, c, seed):
    r = np.random.default_rng(seed)
    xw, x, xp = (jnp.asarray(r.standard_normal(n), jnp.float32) for _ in range(3))
    y = ops.consensus_update(xw, x, xp, a, b, c)
    np.testing.assert_allclose(
        y, a * xw + b * x + c * xp, rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# gossip_matvec (blocked W @ X).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,f", [(8, 1), (50, 3), (128, 512), (200, 300), (73, 640)])
def test_gossip_matvec_sweep(n, f, rng):
    w = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    np.testing.assert_allclose(
        ops.gossip_matvec(w, x), ref.gossip_matvec_ref(w, x), rtol=1e-4, atol=1e-4
    )


def test_gossip_matvec_bf16_inputs(rng):
    w = jnp.asarray(rng.standard_normal((64, 64)), jnp.bfloat16)
    x = jnp.asarray(rng.standard_normal((64, 32)), jnp.bfloat16)
    y = ops.gossip_matvec(w, x)
    assert y.dtype == jnp.float32  # fp32 accumulation contract
    np.testing.assert_allclose(y, ref.gossip_matvec_ref(w, x), rtol=1e-2, atol=1e-2)


# ---------------------------------------------------------------------------
# ssd_scan (chunked Mamba-2 SSD) vs the naive recurrence oracle.
# ---------------------------------------------------------------------------

def _ssd_inputs(rng, b, t, h, g, dh, ds):
    x = jnp.asarray(rng.standard_normal((b, t, h, dh)), jnp.float32)
    a = -jnp.abs(jnp.asarray(rng.standard_normal((b, t, h)), jnp.float32)) * 0.15
    bb = jnp.asarray(rng.standard_normal((b, t, g, ds)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((b, t, g, ds)), jnp.float32)
    return x, a, bb, cc


@pytest.mark.parametrize("b,t,h,g,dh,ds,chunk", [
    (1, 32, 2, 1, 8, 16, 16),
    (2, 256, 4, 2, 16, 32, 64),
    (1, 96, 3, 1, 8, 8, 32),   # t not a power of chunk count
    (2, 40, 2, 2, 4, 8, 16),   # t % chunk != 0 -> padded path
])
def test_ssd_scan_vs_recurrence(b, t, h, g, dh, ds, chunk, rng):
    x, a, bb, cc = _ssd_inputs(rng, b, t, h, g, dh, ds)
    y, hf = ops.ssd_scan(x, a, bb, cc, chunk=chunk)
    b_h = jnp.repeat(bb, h // g, axis=2)
    c_h = jnp.repeat(cc, h // g, axis=2)
    yr, hr = ref.ssd_scan_ref(x, a, b_h, c_h)
    np.testing.assert_allclose(y, yr, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(hf, hr, rtol=3e-4, atol=3e-4)


def test_ssd_scan_state_carry(rng):
    """Splitting a sequence across two calls with h0 == one full call."""
    b, t, h, g, dh, ds, chunk = 1, 64, 2, 1, 8, 16, 16
    x, a, bb, cc = _ssd_inputs(rng, b, t, h, g, dh, ds)
    y_full, h_full = ops.ssd_scan(x, a, bb, cc, chunk=chunk)
    half = t // 2
    y1, h1 = ops.ssd_scan(x[:, :half], a[:, :half], bb[:, :half], cc[:, :half], chunk=chunk)
    y2, h2 = ops.ssd_scan(x[:, half:], a[:, half:], bb[:, half:], cc[:, half:], h0=h1, chunk=chunk)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h2, h_full, rtol=2e-4, atol=2e-4)


def test_ssd_custom_vjp_gradcheck(rng):
    b, t, h, g, dh, ds, chunk = 1, 48, 2, 1, 8, 12, 16
    x, a, bb, cc = _ssd_inputs(rng, b, t, h, g, dh, ds)

    def f_kernel(x, a, bb, cc):
        y, hf = ops.ssd_scan(x, a, bb, cc, chunk=chunk)
        return (y ** 2).sum() + (hf ** 2).sum()

    def f_oracle(x, a, bb, cc):
        y, hf = ref.ssd_scan_ref(x, a, jnp.repeat(bb, h // g, 2), jnp.repeat(cc, h // g, 2))
        return (y ** 2).sum() + (hf ** 2).sum()

    g1 = jax.grad(f_kernel, (0, 1, 2, 3))(x, a, bb, cc)
    g2 = jax.grad(f_oracle, (0, 1, 2, 3))(x, a, bb, cc)
    for u, v in zip(g1, g2):
        rel = float(jnp.abs(u - v).max() / (jnp.abs(v).max() + 1e-9))
        assert rel < 2e-3


def test_ssd_decay_stability(rng):
    """a <= 0 contract: outputs stay finite over long sequences."""
    b, t, h, g, dh, ds = 1, 512, 2, 1, 8, 16
    x, a, bb, cc = _ssd_inputs(rng, b, t, h, g, dh, ds)
    y, hf = ops.ssd_scan(x, a * 10, bb, cc, chunk=128)  # strong decay
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(hf).all())
