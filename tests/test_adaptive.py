"""Property tests for the adaptive/M-tap layer (time-varying coefficients).

Three groups:

1. **Host/traced twin agreement** — ``accel.alpha_star_jnp`` must match the
   host ``accel.alpha_star`` to f64 roundoff across the (lambda_2, theta)
   plane; the in-scan re-solve of ``accel_adapt`` is only trustworthy if the
   twins agree everywhere the estimator can wander.
2. **M-tap frontier algebra** — ``m_tap_weights(2, .)`` is exactly Theorem 1
   with the asymptotic design; the M >= 3 true-interval design achieves its
   advertised rate on the *discrete* chain spectrum and is locally optimal
   there (a direct search over genuine 3-tap weights cannot beat it —
   Golub-Varga saturation, checked numerically, not assumed).
3. **Aux-carry semantics in the engine** — an ``accel_adapt`` cell whose
   nominal floor is seeded WRONG (far below the true lambda_2) must still
   reach a sustained averaging time: the in-scan estimator has to lift
   lam_hat above the bad floor and change alpha mid-run inside the one
   jitted scan. Mean conservation is asserted with the aux slots present.
   This is also where ``accel_adapt`` gets its TIGHT trajectory conformance
   (static regime, floor pins the coefficient stream) — the registry-wide
   conformance bound in tests/test_algorithms.py is deliberately loose for
   this algorithm because heavy-masking regimes are Lyapunov-divergent
   across backends.
"""
import numpy as np
import pytest

from repro.core import accel, algorithms, topology, weights
from repro.runtime.elastic import ElasticFabric
from repro.sweep import engine, grid


def _chain_interval(n):
    w = weights.metropolis_hastings(topology.chain(n))
    vals = np.linalg.eigvalsh(w)
    return w, float(vals[0]), float(vals[-2])


# ---------------------------------------------------------------------------
# 1. alpha* twins across the (lambda_2, theta) plane.
# ---------------------------------------------------------------------------

def test_alpha_star_jnp_matches_host_to_f64_roundoff():
    from jax.experimental import enable_x64
    import jax.numpy as jnp

    lams = np.linspace(0.0, 0.999999, 251)
    thetas = [accel.theta_ls()] + [
        accel.theta_asymptotic(e) for e in (0.05, 0.5, 2.0)
    ]
    with enable_x64():
        for th in thetas:
            host = np.array([accel.alpha_star(lam, th) for lam in lams])
            twin = np.asarray(
                accel.alpha_star_jnp(jnp.asarray(lams, jnp.float64), th)
            )
            np.testing.assert_allclose(twin, host, rtol=1e-12, atol=1e-12)
            # tuple form (what the round body passes) == Theta form
            tup = np.asarray(accel.alpha_star_jnp(
                jnp.asarray(lams, jnp.float64), th.as_tuple))
            np.testing.assert_array_equal(tup, twin)


def test_alpha_star_jnp_f32_cutoff_is_graceful():
    # memoryless design theta = (0, 0, 1): den == 0, the traced twin must
    # return exactly 0.0 (not nan) in the engine's own dtype
    import jax.numpy as jnp

    out = accel.alpha_star_jnp(jnp.float32(0.7), (0.0, 0.0, 1.0))
    assert float(out) == 0.0


# ---------------------------------------------------------------------------
# 2. M-tap frontier algebra.
# ---------------------------------------------------------------------------

def test_m2_weights_are_exactly_theorem1():
    th = accel.theta_asymptotic(0.5)
    for lam2 in (0.3, 0.9, 0.9872, 0.999):
        wts, rho = accel.m_tap_weights(2, lam2)
        al = accel.alpha_star(lam2, th)
        expect = (1.0 - al + al * th.t3, al * th.t2, al * th.t1)
        np.testing.assert_allclose(wts, expect, rtol=1e-9, atol=1e-12)
        np.testing.assert_allclose(rho, accel.rho_accel(lam2, th), rtol=1e-9)
        # and the symmetric interval reduction agrees
        a, b, c, rho_i = accel.two_tap_interval_weights(-lam2, lam2)
        np.testing.assert_allclose((a, b, c, rho_i), (*expect, rho), rtol=1e-9,
                                   atol=1e-12)


def _rho_on_spectrum(wts, eigvals):
    """Exact asymptotic rate of an M-tap recursion on a discrete spectrum:
    max over non-consensus eigenvalues of the companion-polynomial root
    magnitudes of  mu^M = (a lam + b) mu^{M-1} + sum_m c_m mu^{M-1-m}."""
    a, b, cs = wts[0], wts[1], wts[2:]
    worst = 0.0
    for lam in eigvals:
        poly = np.concatenate(([1.0, -(a * lam + b)], -np.asarray(cs)))
        worst = max(worst, float(np.abs(np.roots(poly)).max()))
    return worst


def test_m3_design_rate_is_exact_on_chain_spectrum():
    _, lam_n, lam2 = _chain_interval(16)
    wts, rho = accel.m_tap_weights(3, lam2, lam_n)
    vals = np.linalg.eigvalsh(weights.metropolis_hastings(topology.chain(16)))
    got = _rho_on_spectrum(wts, vals[:-1])  # drop the consensus eigenvalue
    np.testing.assert_allclose(got, rho, rtol=1e-7)
    # the true-interval rate strictly beats the symmetric Theorem-1 rate
    assert rho < accel.m_tap_weights(2, lam2)[1] - 1e-3


def test_m3_saturation_direct_search_cannot_beat_two_taps():
    """Golub-Varga saturation on the discrete chain spectrum: perturbing the
    analytic weights over GENUINE 3-tap space (c2 != 0), holding the
    consensus fixed point (sum of weights == 1), never improves the rate."""
    _, lam_n, lam2 = _chain_interval(16)
    wts, rho = accel.m_tap_weights(3, lam2, lam_n)
    assert wts[3] == 0.0  # the analytic optimum puts zero weight on tap 3
    vals = np.linalg.eigvalsh(weights.metropolis_hastings(topology.chain(16)))
    spectrum = vals[:-1]
    rng = np.random.default_rng(0)
    best = np.inf
    for scale in (1e-3, 1e-2, 5e-2):
        for _ in range(120):
            d = rng.normal(size=4) * scale
            d -= d.mean()  # keep sum(weights) == 1: consensus stays fixed
            best = min(best, _rho_on_spectrum(wts + d, spectrum))
    assert best >= rho - 1e-6


def test_interval_and_bound_validation():
    with pytest.raises(ValueError):
        accel.two_tap_interval_weights(0.9, 0.2)
    with pytest.raises(ValueError):
        accel.two_tap_interval_weights(-1.0, 0.5)
    with pytest.raises(ValueError):
        accel.m_tap_weights(1, 0.9)
    with pytest.raises(ValueError):
        accel.averaging_time_lower_bound(0.0, -0.3, 0.9)
    with pytest.raises(ValueError):
        accel.averaging_time_lower_bound(1e-3, 0.9, 0.2)
    with pytest.raises(ValueError):
        algorithms.get_algorithm("accel_adapt:1.5")  # eta outside [0, 1]


def test_lower_bound_chain16_and_monotonicity():
    _, lam_n, lam2 = _chain_interval(16)
    t = accel.averaging_time_lower_bound(1e-4, lam_n, lam2)
    assert t == 51  # the floor fig_adaptive's mtap rows are measured against
    assert accel.averaging_time_lower_bound(1e-6, lam_n, lam2) > t
    # tighter interval -> weaker lower bound
    assert accel.averaging_time_lower_bound(1e-4, lam_n, 0.9) < t


# ---------------------------------------------------------------------------
# 3. Aux-carry semantics in the engine.
# ---------------------------------------------------------------------------

def _adaptive_cell(seed=3):
    spec = grid.SweepSpec(
        topologies=("chain",), sizes=(12,), designs=("asymptotic",),
        num_trials=2, algorithms=("accel_adapt",), dynamics=("static",),
        seed=seed,
    )
    return grid.build_ensemble(spec)


def test_adaptive_recovers_from_wrong_nominal_floor():
    ens = _adaptive_cell()
    baseline = engine.run_ensemble(ens, num_iters=400, backend="jax")
    t_good = baseline.averaging_times(eps=1e-3, sustained=True)
    assert (t_good >= 0).all()

    # Sabotage the nominal floor: halve lam2_nom in the param row. Tick 0
    # runs a badly detuned alpha*; the ONLY way to a sustained time is the
    # in-scan estimator lifting lam_hat above the wrong floor — i.e. the
    # coefficient row genuinely changes mid-run inside the jitted scan.
    ens_bad = _adaptive_cell()
    ens_bad.coefs[:, 0] *= 0.5
    res = engine.run_ensemble(ens_bad, num_iters=400, backend="jax")
    t_bad = res.averaging_times(eps=1e-3, sustained=True)
    assert (t_bad >= 0).all()
    # adaptation recovers most of the tuning: no worse than 3x the
    # correctly-seeded run (a frozen wrong alpha would not converge this
    # fast — the chain's detuned rho is far from the tuned one)
    assert (t_bad <= 3 * t_good).all()


def test_mean_conserved_with_aux_slots_present():
    ens = _adaptive_cell(seed=7)
    res = engine.run_ensemble(ens, num_iters=60, backend="jax",
                              return_taps=True)
    mask = ens.mask()[:, :, None]
    m0 = (ens.x0 * mask).sum(axis=1) / mask.sum(axis=1)
    mf = (res.x_final * mask).sum(axis=1) / mask.sum(axis=1)
    np.testing.assert_allclose(mf, m0, atol=2e-5)
    # the taps view exposes exactly num_taps slots — estimator state
    # (probe block, lam_hat, mask) never leaks into the displayed carry
    (spec_name, _, _, taps), = res.taps
    assert spec_name == "accel_adapt"
    assert len(taps) == algorithms.get_algorithm("accel_adapt").num_taps


def test_adaptive_static_matches_accel_tightly():
    """The TIGHT trajectory check the registry-wide conformance suite cannot
    make: on a static graph the floor pins the coefficient stream to the
    nominal alpha*, so accel_adapt must track plain accel to f32 noise
    (the in-scan f32 re-solve differs from the host-precomputed coefficient
    row only in the last ulp)."""
    spec = grid.SweepSpec(
        topologies=("chain",), sizes=(12,), designs=("asymptotic",),
        num_trials=2, algorithms=("accel", "accel_adapt"), seed=11,
    )
    ens = grid.build_ensemble(spec)
    res = engine.run_ensemble(ens, num_iters=120, backend="jax")
    (i_accel,) = res.cells(algorithm="accel")
    (i_adapt,) = res.cells(algorithm="accel_adapt")
    np.testing.assert_allclose(res.mse[i_adapt], res.mse[i_accel],
                               rtol=1e-4, atol=5e-7)


def test_refresh_lambda2_floors_and_counts():
    ef = ElasticFabric(topology="ring")
    fab0 = ef.bootstrap([0, 1, 2, 3])
    # estimate below nominal: floored — same tuning, but the re-tune is
    # counted (the control plane did act on fresh information)
    fab1 = ef.refresh_lambda2(0.5 * fab0.lambda2)
    assert fab1.lambda2 == pytest.approx(fab0.lambda2)
    assert fab1.alpha == pytest.approx(fab0.alpha)
    assert ef.retune_count == 1 and ef.resize_count == 0
    # degradation: estimate above nominal re-solves Theorem 1 upward,
    # without touching the member list
    lam_up = 0.5 * (fab0.lambda2 + 1.0)
    fab2 = ef.refresh_lambda2(lam_up)
    assert fab2.lambda2 == pytest.approx(lam_up)
    assert fab2.alpha > fab0.alpha
    assert ef.members == [0, 1, 2, 3] and ef.resize_count == 0
    with pytest.raises(RuntimeError):
        ElasticFabric().refresh_lambda2(0.5)
